"""DASH-style NArray container tests (ISSUE 8 tentpole, container half).

Distribution patterns (blocked / cyclic / block-cyclic / tiled) are
checked for owner-map/index-map consistency and full roundtrips against
numpy; the algorithm set (``copy`` / ``transform`` / ``min_element`` /
``reduce``) runs differentially against the same host mirror.  The
``engine_impl`` fixture runs everything under both batched-kernel
implementations; tiled column access additionally pins the strided-IR
dispatch count (one gather per owning tile).
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (BlockCyclicDist, BlockedDist, CyclicDist, NArray,
                        TileDist, dart_exit, dart_init, narray_copy)
from repro.core.runtime import DartConfig

N_UNITS = 4


@pytest.fixture()
def ctx(engine_impl):
    c = dart_init(n_units=N_UNITS, config=DartConfig(
        non_collective_pool_bytes=1 << 14, team_pool_bytes=1 << 14))
    c.engine.impl = engine_impl
    yield c
    dart_exit(c)


ALL_1D_DISTS = [BlockedDist(), CyclicDist(), BlockCyclicDist(2),
                BlockCyclicDist(3)]


# ---------------------------------------------------------------------------
# pattern algebra (no runtime needed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ALL_1D_DISTS)
@pytest.mark.parametrize("total", [1, 4, 11, 16])
def test_owner_and_index_map_are_inverse(dist, total):
    shape = dist.bind((total,), N_UNITS)
    seen = {}
    for u in range(N_UNITS):
        gmap = dist.global_index_map(u).reshape(-1)
        for loc, g in enumerate(gmap):
            if g >= 0:
                seen[int(g)] = (u, loc)
    assert sorted(seen) == list(range(total))      # exact cover, no dupes
    for g in range(total):
        assert dist.owner(g) == seen[g]


def test_tile_owner_and_index_map_are_inverse():
    dist = TileDist((2, 2))
    dist.bind((5, 7), 4)                           # uneven: padded tiles
    seen = {}
    for u in range(4):
        gmap = dist.global_index_map(u).reshape(-1)
        for loc, g in enumerate(gmap):
            if g >= 0:
                seen[int(g)] = (u, loc)
    assert sorted(seen) == list(range(35))
    for g in range(35):
        assert dist.owner(g) == seen[g]


def test_dist_validation():
    with pytest.raises(ValueError):
        CyclicDist().bind((4, 4), 4)               # cyclic is 1-D
    with pytest.raises(ValueError):
        TileDist((3, 2)).bind((6, 6), 4)           # grid != team size
    with pytest.raises(ValueError):
        TileDist((2, 2)).bind((6,), 4)             # tiled is 2-D
    with pytest.raises(ValueError):
        BlockCyclicDist(0)


# ---------------------------------------------------------------------------
# container roundtrips + element access
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ALL_1D_DISTS)
def test_roundtrip_and_scalar_access_1d(ctx, dist):
    na = NArray(ctx, (13,), jnp.float32, dist=dist)
    ref = np.random.RandomState(5).randn(13).astype(np.float32)
    na.from_numpy(ref)
    np.testing.assert_array_equal(na.to_numpy(), ref)
    assert float(na[7]) == ref[7]
    na[7] = -1.5
    ref[7] = -1.5
    np.testing.assert_array_equal(na.to_numpy(), ref)


def test_roundtrip_blocked_2d_uneven(ctx):
    na = NArray(ctx, (7, 3), jnp.int32, dist="blocked")
    ref = np.arange(21, dtype=np.int32).reshape(7, 3)
    na.from_numpy(ref)
    np.testing.assert_array_equal(na.to_numpy(), ref)
    assert int(na[6, 2]) == 20                     # last row (padded unit)
    with pytest.raises(IndexError):
        na[7, 0]
    with pytest.raises(IndexError):
        na[21]


def test_roundtrip_tiled(ctx):
    na = NArray(ctx, (6, 6), jnp.float32, dist=TileDist((2, 2)))
    ref = np.random.RandomState(9).randn(6, 6).astype(np.float32)
    na.from_numpy(ref)
    np.testing.assert_array_equal(na.to_numpy(), ref)
    assert float(na[4, 5]) == ref[4, 5]


def test_tiled_get_col_is_strided_one_dispatch_per_tile(ctx):
    """A global column read lowers to ONE strided gather per owning
    tile (seg = 1 elem, stride = tile cols, count = tile rows)."""
    na = NArray(ctx, (6, 6), jnp.float32, dist=TileDist((2, 2)), shm=False)
    ref = np.random.RandomState(2).randn(6, 6).astype(np.float32)
    na.from_numpy(ref)
    ctx.engine.flush()
    d0 = ctx.engine.dispatch_count
    col = na.get_col(1)
    used = ctx.engine.dispatch_count - d0
    np.testing.assert_array_equal(col, ref[:, 1])
    assert used <= 2                               # 2 owning tiles, not 6 rows
    with pytest.raises(TypeError):
        NArray(ctx, (8,), jnp.float32, dist="blocked").get_col(0)


# ---------------------------------------------------------------------------
# algorithm set
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ALL_1D_DISTS)
def test_min_element_global_index(ctx, dist):
    na = NArray(ctx, (11,), jnp.float32, dist=dist)
    ref = np.random.RandomState(3).randn(11).astype(np.float32)
    na.from_numpy(ref)
    g, v = na.min_element()
    assert g == int(ref.argmin())
    assert float(v) == ref.min()


def test_min_element_tie_resolves_lowest_index(ctx):
    na = NArray(ctx, (8,), jnp.int32, dist=CyclicDist())
    ref = np.array([5, 1, 9, 1, 7, 1, 8, 6], np.int32)
    na.from_numpy(ref)
    g, v = na.min_element()
    assert (g, int(v)) == (1, 1)


@pytest.mark.parametrize("op", ["sum", "prod", "min", "max"])
def test_reduce_matches_numpy(ctx, op):
    na = NArray(ctx, (9,), jnp.int32, dist=BlockCyclicDist(2))
    ref = np.random.RandomState(4).randint(1, 5, size=9).astype(np.int32)
    na.from_numpy(ref)
    want = {"sum": ref.sum(), "prod": ref.prod(),
            "min": ref.min(), "max": ref.max()}[op]
    assert int(na.reduce(op)) == int(want)


def test_transform_in_place_and_out(ctx):
    na = NArray(ctx, (10,), jnp.float32, dist=CyclicDist())
    ref = np.arange(10, dtype=np.float32)
    na.from_numpy(ref)
    na.transform(lambda x: x * 3 + 1)
    np.testing.assert_array_equal(na.to_numpy(), ref * 3 + 1)
    out = NArray(ctx, (10,), jnp.float32, dist=CyclicDist())
    na.transform(lambda x: -x, out=out)
    np.testing.assert_array_equal(out.to_numpy(), -(ref * 3 + 1))
    bad = NArray(ctx, (10,), jnp.float32, dist="blocked")
    with pytest.raises(ValueError):
        na.transform(lambda x: x, out=bad)


def test_copy_same_and_cross_distribution(ctx):
    ref = np.random.RandomState(6).randn(12).astype(np.float32)
    src = NArray(ctx, (12,), jnp.float32, dist=CyclicDist())
    src.from_numpy(ref)
    same = NArray(ctx, (12,), jnp.float32, dist=CyclicDist())
    narray_copy(src, same)
    np.testing.assert_array_equal(same.to_numpy(), ref)
    cross = NArray(ctx, (12,), jnp.float32, dist=BlockCyclicDist(3))
    narray_copy(src, cross)
    np.testing.assert_array_equal(cross.to_numpy(), ref)
    with pytest.raises(ValueError):
        narray_copy(src, NArray(ctx, (8,), jnp.float32, dist="blocked"))


def test_route_stats_count_classifier_decisions(ctx):
    na = NArray(ctx, (8,), jnp.float32, dist="blocked")       # shm=True
    na.fill(1.0)
    na.to_numpy()
    assert na.route_stats["local"] == N_UNITS      # zero-copy host views
    nb = NArray(ctx, (8,), jnp.float32, dist="blocked", shm=False)
    nb.fill(1.0)
    nb.to_numpy()
    assert nb.route_stats["onesided"] == N_UNITS   # forced engine path
