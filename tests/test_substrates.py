"""Tests: data pipeline, checkpointing, fault tolerance, optimizer,
gradient compression."""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import (CheckpointConfig, CheckpointManager,
                              load_checkpoint, save_checkpoint)
from repro.data import DataConfig, ShardedLoader, make_dataset
from repro.ft import (ClusterState, HeartbeatMonitor, StragglerTracker,
                      plan_remesh)
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_int8, decompress_int8)
from repro.optim.compression import ErrorFeedback


# ------------------------------------------------------------- data --------

def test_synthetic_data_deterministic_and_rank_disjoint():
    base = dict(vocab=100, seq_len=8, global_batch=8, seed=7, dp_size=2)
    d0 = make_dataset(DataConfig(dp_rank=0, **base))
    d1 = make_dataset(DataConfig(dp_rank=1, **base))
    b0a, b0b = d0.batch_at(3), d0.batch_at(3)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])  # determinism
    b1 = d1.batch_at(3)
    assert not np.array_equal(b0a["tokens"], b1["tokens"])       # disjoint
    assert b0a["tokens"].shape == (4, 8)                          # local B
    np.testing.assert_array_equal(d0.batch_at(4)["tokens"][:, 1:],
                                  d0.batch_at(4)["labels"][:, :-1])


def test_memmap_dataset(tmp_path):
    path = tmp_path / "tokens.bin"
    arr = np.arange(10000, dtype=np.int32)
    arr.tofile(path)
    cfg = DataConfig(vocab=1 << 20, seq_len=16, global_batch=4,
                     source="memmap", path=str(path))
    ds = make_dataset(cfg)
    b = ds.batch_at(0)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["labels"], b["tokens"] + 1)


def test_loader_resume_exactly():
    cfg = DataConfig(vocab=50, seq_len=4, global_batch=2)
    ds = make_dataset(cfg)
    loader = ShardedLoader(ds, prefetch=1)
    seen = [next(loader) for _ in range(3)]
    state = loader.state_dict()
    nxt = next(loader)
    loader.close()
    resumed = ShardedLoader.resume(ds, state, prefetch=1)
    nxt2 = next(resumed)
    resumed.close()
    np.testing.assert_array_equal(nxt["tokens"], nxt2["tokens"])


# -------------------------------------------------------- checkpoint -------

def _tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 7, t, extra={"data_step": 42})
    restored, extra = load_checkpoint(tmp_path, t)
    assert extra == {"data_step": 42}
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, restored)


def test_checkpoint_corruption_detected(tmp_path):
    t = _tree()
    d = save_checkpoint(tmp_path, 1, t)
    # flip bytes in a leaf file
    f = d / "arr_000000.npy"
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        load_checkpoint(tmp_path, t)


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 3, t)
    # simulate a crashed half-write at a later step
    crashed = tmp_path / "step_000000009.tmp"
    crashed.mkdir()
    (crashed / "arr_000000.npy").write_bytes(b"garbage")
    restored, _ = load_checkpoint(tmp_path, t)   # picks committed step 3
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(t["w"]))


def test_checkpoint_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(root=str(tmp_path), keep=2))
    t = _tree()
    for step in (1, 2, 3, 4):
        mgr.save(step, jax.tree.map(lambda a: a + step, t),
                 extra={"s": step})
    mgr.wait()
    kept = sorted(p.name for p in tmp_path.iterdir())
    assert kept == ["step_000000003", "step_000000004"]
    restored, extra = mgr.restore_latest(t)
    assert extra["s"] == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(t["w"]) + 4)


def test_checkpoint_manager_concurrent_writers_no_deadlock(tmp_path):
    """Regression: concurrent async saves must use distinct MCS queue
    nodes (same-unit self-enqueue used to deadlock)."""
    mgr = CheckpointManager(CheckpointConfig(root=str(tmp_path), keep=12))
    t = _tree()
    for step in range(10):            # > MAX_WRITERS concurrent saves
        mgr.save(step, t, extra={"s": step})
    mgr.wait()                        # must not hang
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir())
    assert steps == list(range(10))


def test_checkpoint_resume_determinism(tmp_path):
    """Full restart loop: state+data cursor restored => identical run."""
    cfg = DataConfig(vocab=64, seq_len=4, global_batch=2)
    ds = make_dataset(cfg)

    def run(n_steps, start_state=None, start_cursor=0):
        params = (start_state if start_state is not None
                  else jnp.zeros((64,)))
        loader = ShardedLoader(ds, start_step=start_cursor, prefetch=1)
        for _ in range(n_steps):
            b = next(loader)
            params = params + np.bincount(
                b["tokens"].ravel(), minlength=64)
        cursor = loader.state_dict()["step"]
        loader.close()
        return params, cursor

    full, _ = run(6)
    half, cur = run(3)
    save_checkpoint(tmp_path, 3, {"p": half}, extra={"cursor": cur})
    restored, extra = load_checkpoint(tmp_path, {"p": half})
    resumed, _ = run(3, start_state=restored["p"],
                     start_cursor=extra["cursor"])
    np.testing.assert_array_equal(np.asarray(full), np.asarray(resumed))


# ---------------------------------------------------------------- ft -------

def test_heartbeat_declares_dead():
    clock = {"t": 0.0}
    cluster = ClusterState(n_hosts=4, devices_per_host=8)
    mon = HeartbeatMonitor(cluster, interval_s=1.0, miss_threshold=3,
                           clock=lambda: clock["t"])
    clock["t"] = 2.0
    for h in (0, 1, 2):
        mon.beat(h)
    clock["t"] = 4.0
    assert mon.sweep() == [3]
    assert cluster.alive_hosts == [0, 1, 2]


def test_plan_remesh_shrinks_data_axis():
    cluster = ClusterState(n_hosts=64, devices_per_host=8)   # 512 devices
    for h in (5, 6, 7, 8):
        cluster.alive[h] = False                              # lose 32 dev
    plan = plan_remesh(cluster, model_parallel=16, pods=2)
    assert plan.mesh_axes == ("pod", "data", "model")
    pods, data, model = plan.mesh_shape
    assert model == 16 and pods == 2
    assert pods * data * model <= 60 * 8
    assert plan.dropped_devices == 60 * 8 - pods * data * model


def test_plan_remesh_raises_when_model_axis_unsatisfiable():
    cluster = ClusterState(n_hosts=1, devices_per_host=8)
    with pytest.raises(RuntimeError):
        plan_remesh(cluster, model_parallel=16)


def test_straggler_tracker_and_rebalance():
    tr = StragglerTracker(n_hosts=4, ratio=1.5)
    for _ in range(10):
        for h, t in enumerate([1.0, 1.0, 1.0, 2.5]):
            tr.record(h, t)
    assert tr.stragglers() == [3]
    plan = tr.rebalance_plan({0: 4, 1: 4, 2: 4, 3: 4})
    assert plan[3] == 3 and sum(plan.values()) == 16


# ------------------------------------------------------ optimizer ----------

def test_adamw_decreases_quadratic_loss():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(cfg, g, opt, params)
    assert float(loss(params)) < l0 * 0.05
    assert int(opt["step"]) == 50


def test_adamw_grad_clip_metric():
    cfg = AdamWConfig(grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw_update(cfg, g, opt, params)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


# ---------------------------------------------------- compression ----------

@given(st.integers(0, 2 ** 31 - 1), st.floats(0.1, 100.0))
@settings(max_examples=20, deadline=None)
def test_int8_roundtrip_error_bound(seed, scale):
    rng = np.random.RandomState(seed % (2 ** 31))
    x = jnp.asarray(rng.randn(64) * scale, jnp.float32)
    q, s = compress_int8(x)
    y = decompress_int8(q, s)
    assert q.dtype == jnp.int8
    # quantization error bounded by half a step
    step = float(s)
    assert float(jnp.max(jnp.abs(y - x))) <= step * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    rng = np.random.RandomState(0)
    g_stream = [jnp.asarray(rng.randn(128) * 0.01, jnp.float32)
                for _ in range(50)]
    # without EF: accumulate quantized; with EF: residual carried
    acc_plain = np.zeros(128)
    acc_ef = np.zeros(128)
    residual = jnp.zeros(128)
    for g in g_stream:
        q, s = compress_int8(g)
        acc_plain += np.asarray(decompress_int8(q, s))
        corrected = g + residual
        q2, s2 = compress_int8(corrected)
        d2 = decompress_int8(q2, s2)
        residual = corrected - d2
        acc_ef += np.asarray(d2)
    truth = np.sum([np.asarray(g) for g in g_stream], axis=0)
    err_plain = np.linalg.norm(acc_plain - truth)
    err_ef = np.linalg.norm(acc_ef - truth)
    assert err_ef < err_plain * 0.9
