"""Examples must run end-to-end (deliverable b)."""

import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).parent.parent


def run_example(name, timeout=540, args=()):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "examples" / name), *args],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, (
        f"{name} failed\n--- stdout ---\n{proc.stdout[-3000:]}\n"
        f"--- stderr ---\n{proc.stderr[-3000:]}")
    return proc.stdout


def test_quickstart():
    out = run_example("quickstart.py")
    assert "lock-protected counter: 800" in out
    assert "done." in out


def test_halo_exchange():
    out = run_example("halo_exchange.py")
    assert "OK — one-sided halo exchange matches" in out


def test_narray_stencil():
    out = run_example("narray_stencil.py")
    assert "OK — tiled NArray stencil matches dense reference" in out
    assert "halo dispatches/step" in out


def test_serve_batch():
    out = run_example("serve_batch.py")
    assert "completed 10 requests" in out
    assert "continuous pass completed 10 requests" in out
    assert "10 prefix hits" in out
    assert out.strip().endswith("OK")


def test_train_lm_with_restart():
    out = run_example("train_lm.py")
    assert "resumed from step" in out
    assert "OK — training resumed from checkpoint" in out
