"""Tests for the shape-stable flush substrate (DispatchPlan layer):
plan-cache retrace behavior, bucketed/padded dispatch equivalence,
the Pallas segmented-copy fast path, collectives donation semantics,
and the waitall lane-error fix."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (DART_TEAM_ALL, DartConfig, dart_exit, dart_flush,
                        dart_get_blocking, dart_get_nb, dart_init,
                        dart_memalloc, dart_put, dart_put_blocking,
                        dart_team_memalloc_aligned, dart_waitall)
from repro.core import collectives as _coll
from repro.core import onesided as _os
from repro.kernels import segmented_copy as sc


@pytest.fixture()
def ctx(engine_impl):
    # engine-impl parametrization (conftest.py): every ctx-based test
    # in this module runs under both impl='ref' and impl='pallas'
    c = dart_init(n_units=4, config=DartConfig(
        non_collective_pool_bytes=8192, team_pool_bytes=8192))
    c.engine.impl = engine_impl
    yield c
    dart_exit(c)


# ------------------------------------------------------- bucket mechanics --

def test_bucket_pow2():
    assert sc.bucket_pow2(0) == 1
    assert sc.bucket_pow2(1) == 1
    assert sc.bucket_pow2(5) == 8
    assert sc.bucket_pow2(8) == 8
    assert sc.bucket_pow2(9) == 16
    assert sc.bucket_pow2(3, floor=16) == 16


def test_pack_descriptors_pads_with_noops():
    desc, flat, seg = sc.pack_descriptors(
        [1, 2, 0], [10, 20, 30], [3, 5, 2],
        [np.full(3, 7, np.uint8), np.full(5, 8, np.uint8),
         np.full(2, 9, np.uint8)])
    assert desc.shape == (4, 6)                  # k=3 → bucket 4
    assert desc[3, sc.LEN] == 0                  # padding is a no-op
    assert seg == sc.SEG_FLOOR
    assert flat.shape[0] >= 10 + seg             # payload + window margin
    np.testing.assert_array_equal(desc[:3, sc.START], [0, 3, 8])
    assert list(flat[:10]) == [7] * 3 + [8] * 5 + [9] * 2


def test_padding_descriptors_do_not_touch_arena():
    """len=0 descriptors (bucket padding) must leave every arena byte
    untouched — masked lanes are dropped, not clamped to offset 0."""
    arena = jnp.arange(2 * 32, dtype=jnp.uint8).reshape(2, 32)
    before = np.asarray(arena).copy()
    desc, flat, seg = sc.pack_descriptors([1], [30], [2],
                                          [np.array([255, 254], np.uint8)])
    fn, _ = sc.scatter_plan(arena.shape, desc.shape[0], seg, flat.shape[0],
                            ordered=False, impl="ref", donate=False)
    out = np.asarray(fn(arena, desc, flat)).copy()
    assert list(out[1, 30:]) == [255, 254]
    out[1, 30:] = before[1, 30:]
    np.testing.assert_array_equal(out, before)   # nothing else moved


def test_pack_acc_descriptors_identity_padded():
    """Accumulate staging: the descriptor gains the op column, every
    payload owns a seg-aligned slot, and ALL padding bytes — short-
    payload tails and whole bucket-padding slots — decode to the op's
    identity element (true no-ops by value)."""
    pays = [np.asarray([3.0], np.float32).view(np.uint8),
            np.asarray([2.0, 4.0], np.float32).view(np.uint8)]
    desc, flat, seg = sc.pack_acc_descriptors(
        [0, 1], [32, 64], [4, 8], pays, "prod", jnp.float32)
    assert desc.shape == (4, 7)                    # k=2 → bucket 4, +op col
    assert list(desc[:, sc.OPCODE]) == [sc.REDUCE_OPS["prod"]] * 4
    np.testing.assert_array_equal(desc[:2, sc.LEN], [4, 8])
    np.testing.assert_array_equal(desc[:, sc.START],
                                  [0, seg, 2 * seg, 3 * seg])
    vals = flat.view(np.float32)
    assert vals[0] == 3.0 and list(vals[seg // 4:seg // 4 + 2]) == [2., 4.]
    # every byte not covered by a payload is the identity (1.0)
    mask = np.ones(flat.size, bool)
    mask[:4] = mask[seg:seg + 8] = False
    assert np.all(flat.view(np.float32)[mask.reshape(-1, 4).all(1)] == 1.0)


def test_op_identity_table():
    assert sc.op_identity("sum", jnp.float32) == 0.0
    assert sc.op_identity("prod", jnp.int32) == 1
    assert sc.op_identity("min", jnp.float32) == np.inf
    assert sc.op_identity("max", jnp.float32) == -np.inf
    assert sc.op_identity("min", jnp.int32) == np.iinfo(np.int32).max
    assert sc.op_identity("max", jnp.uint8) == 0
    with pytest.raises(ValueError):
        sc.op_identity("xor", jnp.int32)


def test_accumulate_padding_descriptors_do_not_touch_arena():
    """len=0 accumulate descriptors (bucket padding) must leave every
    arena byte untouched under both impls — masked lanes are dropped
    (ref) or keep the window (pallas), and their payload is the
    identity anyway."""
    base = np.arange(2 * 64, dtype=np.uint8).reshape(2, 64)
    desc, flat, seg = sc.pack_acc_descriptors(
        [1], [32], [8], [np.asarray([5, 5], np.int32).view(np.uint8)],
        "sum", jnp.int32)
    for impl in ("ref", "pallas"):
        fn, _ = sc.accumulate_plan((2, 64), desc.shape[0], seg,
                                   flat.shape[0], op="sum",
                                   dtype=jnp.int32, fetch=False,
                                   impl=impl, donate=False)
        out = np.asarray(fn(jnp.asarray(base), desc, flat)).copy()
        got = out[1, 32:40].view(np.int32).copy()
        expect = base[1, 32:40].view(np.int32) + 5
        np.testing.assert_array_equal(got, expect)
        out[1, 32:40] = base[1, 32:40]
        np.testing.assert_array_equal(out, base)   # nothing else moved


# ------------------------------------------------------ retrace behavior ---

def test_warm_flushes_zero_recompiles_within_buckets(ctx):
    """The acceptance criterion: after warmup, a steady-state loop of
    epochs with VARYING run lengths and payload sizes (within the
    pow2 buckets) performs ZERO plan-cache misses — every flush hits a
    cached compiled kernel."""
    g = dart_memalloc(ctx, 8192, unit=0)

    def epoch(k, n_floats):
        hs = [dart_put(ctx, g + 512 * i,
                       jnp.full((n_floats,), float(i + 1), jnp.float32))
              for i in range(k)]
        dart_flush(ctx)
        dart_waitall(hs)

    epoch(8, 16)                                 # warm the (8, 64B) plan
    epoch(8, 16)
    c0, h0 = ctx.engine.compile_count, ctx.engine.plan_cache_hits
    for k, n in [(5, 16), (7, 9), (8, 12), (6, 10), (5, 16), (8, 13)]:
        epoch(k, n)                              # k≤8, 33..64B: same bucket
    assert ctx.engine.compile_count == c0, \
        "varying-size warm epochs must not recompile"
    assert ctx.engine.plan_cache_hits > h0


def test_get_runs_share_plans_across_sizes(ctx):
    g = dart_memalloc(ctx, 4096, unit=1)
    for i in range(8):
        dart_put_blocking(ctx, g + 128 * i,
                          jnp.full((16,), float(i), jnp.float32))

    def gets(sizes):
        hs = [dart_get_nb(ctx, g + 128 * i, (n,), jnp.float32)
              for i, n in enumerate(sizes)]
        dart_flush(ctx)
        return [np.asarray(h.value()) for h in hs]

    gets([16, 9, 12])                            # warm the bucket
    c0 = ctx.engine.compile_count
    for sizes in ([12, 16, 10], [9, 9], [16, 11, 13]):
        vals = gets(sizes)
        for i, (n, v) in enumerate(zip(sizes, vals)):
            assert np.all(v == float(i)) and v.shape == (n,)
    assert ctx.engine.compile_count == c0


# -------------------------------------------- bucketed dispatch oracle -----

def _apply_blocking(ops):
    """Oracle: the same ops as a strict blocking sequence."""
    c = dart_init(n_units=4, config=DartConfig(
        non_collective_pool_bytes=1024, team_pool_bytes=1024))
    try:
        g = dart_memalloc(c, 1024, unit=0)
        for row, off, payload in ops:
            dart_put_blocking(c, g.setunit(row) + off, payload)
        return np.asarray(c.state[_os.WORLD_POOLID]).copy()
    finally:
        dart_exit(c)


@given(st.lists(st.tuples(st.integers(0, 3),      # row
                          st.integers(0, 1020),   # offset
                          st.integers(1, 64)),    # payload bytes
                min_size=1, max_size=12),
       st.booleans())
@settings(max_examples=20, deadline=None)
def test_bucketed_dispatch_byte_identical_to_blocking(op_specs, use_pallas):
    """Property: one coalesced bucketed/padded flush produces bytes
    identical to the equivalent blocking sequence — overlapping runs,
    mixed sizes, and ops hard against the pool end included."""
    pool = 1024
    ops = []
    for row, off, nbytes in op_specs:
        off = min(off, pool - nbytes)            # headroom edge: off+n≤pool
        payload = (np.arange(nbytes, dtype=np.int64) * 37 + off + row
                   ).astype(np.uint8)
        ops.append((row, off, payload))
    expected = _apply_blocking(ops)

    c = dart_init(n_units=4, config=DartConfig(
        non_collective_pool_bytes=pool, team_pool_bytes=pool))
    try:
        c.engine.impl = "pallas" if use_pallas else "ref"
        g = dart_memalloc(c, pool, unit=0)
        hs = [dart_put(c, g.setunit(row) + off, payload)
              for row, off, payload in ops]
        dart_flush(c)
        dart_waitall(hs)
        got = np.asarray(c.state[_os.WORLD_POOLID])
        np.testing.assert_array_equal(got, expected)
    finally:
        dart_exit(c)


def test_pallas_gather_matches_ref(ctx):
    g = dart_memalloc(ctx, 2048, unit=2)
    sizes = [4, 17, 8, 1]
    for i, n in enumerate(sizes):
        dart_put_blocking(ctx, g + 256 * i,
                          (np.arange(n) + 5 * i).astype(np.uint8))
    for impl in ("ref", "pallas"):
        ctx.engine.impl = impl
        hs = [dart_get_nb(ctx, g + 256 * i, (n,), jnp.uint8)
              for i, n in enumerate(sizes)]
        d0 = ctx.engine.dispatch_count
        dart_flush(ctx)
        assert ctx.engine.dispatch_count - d0 == 1
        for i, (n, h) in enumerate(zip(sizes, hs)):
            np.testing.assert_array_equal(
                np.asarray(h.value()), np.arange(n, dtype=np.uint8) + 5 * i)


# ------------------------------------- mixed get run: one counted dispatch -

def test_mixed_get_run_is_one_dispatch_including_decode(ctx):
    """The per-op typed decode must ride inside the single counted
    dispatch (host-side, from one shared device→host copy) — no
    trailing per-op device launches after the gather."""
    g = dart_memalloc(ctx, 2048, unit=0)
    sizes = [(3,), (7,), (2, 4)]
    dtypes = [jnp.float32, jnp.int32, jnp.uint8]
    for i, (shp, dt) in enumerate(zip(sizes, dtypes)):
        dart_put_blocking(ctx, g + 256 * i,
                          (jnp.arange(int(np.prod(shp))) + i).astype(dt
                                                                     ).reshape(shp))
    hs = [dart_get_nb(ctx, g + 256 * i, shp, dt)
          for i, (shp, dt) in enumerate(zip(sizes, dtypes))]
    d0 = ctx.engine.dispatch_count
    dart_flush(ctx)
    vals = [h.value() for h in hs]               # decode: zero dispatches
    assert ctx.engine.dispatch_count - d0 == 1
    for i, (shp, dt, v) in enumerate(zip(sizes, dtypes, vals)):
        assert v.shape == shp and v.dtype == jnp.dtype(dt)
        np.testing.assert_array_equal(
            np.asarray(v).reshape(-1),
            (np.arange(int(np.prod(shp))) + i).astype(np.asarray(v).dtype))


# ----------------------------------------------------- waitall lane error --

def test_waitall_cleared_engine_names_the_dropped_lane():
    """A queued op silently dropped by engine.clear() must surface an
    error naming ITS OWN (pool, row) lane — and handles on other, live
    engines in the same waitall must still complete."""
    ctx_dead = dart_init(n_units=2, config=DartConfig(
        non_collective_pool_bytes=1024, team_pool_bytes=1024))
    ctx_live = dart_init(n_units=2, config=DartConfig(
        non_collective_pool_bytes=1024, team_pool_bytes=1024))
    try:
        gd = dart_memalloc(ctx_dead, 256, unit=1)
        gl = dart_memalloc(ctx_live, 256, unit=0)
        h_dead = dart_put(ctx_dead, gd, jnp.ones((4,), jnp.int32))
        h_live = dart_put(ctx_live, gl, jnp.full((4,), 5, jnp.int32))
        dart_exit(ctx_dead)                      # clears its engine
        with pytest.raises(RuntimeError) as exc:
            dart_waitall([h_live, h_dead])
        # the error names the dropped op's lane, not a generic/wrong op
        assert f"pool {h_dead.poolid}, row {h_dead.row}" in str(exc.value)
        assert h_live.state in ("issued", "complete")   # live op dispatched
        out = dart_get_blocking(ctx_live, gl, (4,), jnp.int32)
        assert np.all(np.asarray(out) == 5)
    finally:
        dart_exit(ctx_live)


# ------------------------------------------------- collectives donation ----

def test_functional_collectives_do_not_donate_snapshot():
    """engine=None is the purely functional contract: the caller's
    retained heap snapshot must stay alive and unchanged after
    bcast/scatter/scatter_typed (previously those three donated the
    arena and deleted the snapshot)."""
    ctx = dart_init(n_units=4, config=DartConfig(
        non_collective_pool_bytes=1024, team_pool_bytes=1024))
    try:
        g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 256)
        dart_put_blocking(ctx, g, jnp.full((8,), 3, jnp.int32))
        snap = dict(ctx.state)
        poolid = ctx.teams[DART_TEAM_ALL].poolid
        before = np.asarray(snap[poolid]).copy()

        s1, _ = _coll.dart_bcast(snap, ctx.heap, ctx.teams_by_slot, g,
                                 32, engine=None)
        s2, _ = _coll.dart_scatter(
            snap, ctx.heap, ctx.teams_by_slot, g,
            np.arange(4 * 16, dtype=np.uint8).reshape(4, 16), engine=None)
        s3, _ = _coll.dart_scatter_typed(
            snap, ctx.heap, ctx.teams_by_slot, g,
            jnp.arange(8, dtype=jnp.int32).reshape(4, 2), engine=None)
        s4, red = _coll.dart_allreduce(snap, ctx.heap, ctx.teams_by_slot,
                                       g, (8,), jnp.int32, "sum",
                                       engine=None)
        assert np.all(np.asarray(red) == 3)        # only row 0 holds 3s
        s5, _ = _coll.dart_reduce(snap, ctx.heap, ctx.teams_by_slot, g,
                                  (8,), jnp.int32, "sum", 0, engine=None)
        for new_state in (s1, s2, s3, s4, s5):
            assert not new_state[poolid].is_deleted()
        # the snapshot arena was neither deleted nor mutated
        assert not snap[poolid].is_deleted()
        np.testing.assert_array_equal(np.asarray(snap[poolid]), before)
    finally:
        dart_exit(ctx)


def test_scatter_typed_canonicalizes_wide_dtypes(ctx):
    """int64/float64 inputs canonicalize to 32-bit inside the jit; the
    kernel's byte mask must be computed from the canonical dtype or
    the bucket padding zeroes the 4 bytes after each row's segment."""
    from repro.core import runtime as rt
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 256)
    sentinel = jnp.full((4,), 0xAB, jnp.uint8)
    for u in range(4):
        dart_put_blocking(ctx, g.setunit(u) + 12, sentinel)
    rt.dart_scatter_typed(ctx, g,
                          np.arange(12, dtype=np.int64).reshape(4, 3))
    vals, _ = rt.dart_gather_typed(ctx, g, (3,), jnp.int32)
    np.testing.assert_array_equal(np.asarray(vals),
                                  np.arange(12).reshape(4, 3))
    for u in range(4):                   # bytes past the segment intact
        tail = dart_get_blocking(ctx, g.setunit(u) + 12, (4,), jnp.uint8)
        assert np.all(np.asarray(tail) == 0xAB)


def test_oversize_arena_refused_loudly():
    """Arenas beyond the flat int32 addressing range must raise, not
    silently drop writes."""
    with pytest.raises(NotImplementedError):
        sc.check_flat_addressable((4, 1 << 30))
    sc.check_flat_addressable((4, 1 << 20))      # normal pools fine


def test_collective_sizes_share_bucketed_plans(ctx):
    """Varying collective sizes within a bucket reuse cached kernels."""
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 512)
    from repro.core import runtime as rt
    rt.dart_bcast(ctx, g, 40)                    # warm the 64B bucket
    c0 = ctx.engine.compile_count
    for nbytes in (33, 64, 57, 48):
        rt.dart_bcast(ctx, g, nbytes)
    assert ctx.engine.compile_count == c0
    rt.dart_gather_typed(ctx, g, (9,), jnp.float32)   # warm 16-elem bucket
    c0 = ctx.engine.compile_count
    for n in (10, 16, 12):
        vals, _ = rt.dart_gather_typed(ctx, g, (n,), jnp.float32)
        assert vals.shape == (4, n)
    assert ctx.engine.compile_count == c0
