"""Numerics tests for the sequence-mixing cores: chunked formulations
vs step-by-step recurrence oracles (rwkv6 WKV, mamba2 SSD)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig, reduced_for_smoke
from repro.models.mamba2 import ssd_decode_step, ssd_forward
from repro.models.rwkv6 import _wkv_chunked, _wkv_scan
from repro.models import api
from repro.configs import get_config


def _rand(shape, seed, scale=1.0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(*shape) * scale, jnp.float32)


@pytest.mark.parametrize("s", [5, 16, 33, 64])
@pytest.mark.parametrize("chunk", [8, 16])
def test_wkv_chunked_matches_scan(s, chunk):
    b, H, hd = 2, 3, 8
    r = _rand((b, s, H, hd), 0, 0.5)
    k = _rand((b, s, H, hd), 1, 0.5)
    v = _rand((b, s, H, hd), 2, 0.5)
    # log decays in [-5, 0] (the shared floor)
    lw = -jnp.abs(_rand((b, s, H, hd), 3, 1.5))
    lw = jnp.maximum(lw, -5.0)
    u = _rand((H, hd), 4, 0.3)
    s0 = _rand((b, H, hd, hd), 5, 0.2)

    y_scan, sl_scan = _wkv_scan(r, k, v, jnp.exp(lw), u, s0)
    y_chunk, sl_chunk = _wkv_chunked(r, k, v, lw, u, s0, chunk)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_scan),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sl_chunk), np.asarray(sl_scan),
                               rtol=1e-4, atol=1e-4)


def test_wkv_chunked_strong_decay_stable():
    """Floor keeps the factorized form finite under extreme decay."""
    b, s, H, hd = 1, 32, 2, 8
    r = _rand((b, s, H, hd), 0)
    k = _rand((b, s, H, hd), 1)
    v = _rand((b, s, H, hd), 2)
    lw = jnp.full((b, s, H, hd), -5.0)     # hardest case at the floor
    u = _rand((H, hd), 3)
    s0 = jnp.zeros((b, H, hd, hd))
    y, sl = _wkv_chunked(r, k, v, lw, u, s0, 16)
    assert np.isfinite(np.asarray(y)).all()
    y2, _ = _wkv_scan(r, k, v, jnp.exp(lw), u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def _ssd_oracle(A_log, xh, Bm, Cm, dt, h0):
    """Step-by-step SSD recurrence (pure python loop)."""
    b, s, H, hd = xh.shape
    ds = Bm.shape[-1]
    A = -np.exp(np.asarray(A_log, np.float64))
    h = np.asarray(h0, np.float64).copy()
    ys = np.zeros((b, s, H, hd))
    xh, Bm, Cm, dt = (np.asarray(t, np.float64) for t in (xh, Bm, Cm, dt))
    for t in range(s):
        a = np.exp(dt[:, t] * A[None, :])                    # (b,H)
        h = h * a[..., None, None] + np.einsum(
            "bh,bhd,bs->bhds", dt[:, t], xh[:, t], Bm[:, t])
        ys[:, t] = np.einsum("bhds,bs->bhd", h, Cm[:, t])
    return ys, h


@pytest.mark.parametrize("s,chunk", [(16, 8), (24, 8), (64, 16), (7, 16)])
def test_ssd_forward_matches_recurrence(s, chunk):
    b, H, hd, ds = 2, 3, 4, 5
    A_log = _rand((H,), 0, 0.3)
    xh = _rand((b, s, H, hd), 1, 0.5)
    Bm = _rand((b, s, ds), 2, 0.5)
    Cm = _rand((b, s, ds), 3, 0.5)
    dt = jnp.abs(_rand((b, s, H), 4, 0.5)) + 0.01
    h0 = _rand((b, H, hd, ds), 5, 0.1)

    y, h_last = ssd_forward(A_log, xh, Bm, Cm, dt, chunk, h0=h0)
    y_ref, h_ref = _ssd_oracle(A_log, xh, Bm, Cm, dt, h0)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_last), h_ref, rtol=2e-4,
                               atol=2e-4)


def test_ssd_decode_step_matches_recurrence():
    b, H, hd, ds = 2, 3, 4, 5
    A_log = _rand((H,), 0, 0.3)
    xh = _rand((b, 1, H, hd), 1)
    Bm = _rand((b, 1, ds), 2)
    Cm = _rand((b, 1, ds), 3)
    dt = jnp.abs(_rand((b, 1, H), 4)) + 0.01
    h0 = _rand((b, H, hd, ds), 5, 0.1)
    y, h = ssd_decode_step(A_log, xh, Bm, Cm, dt, h0)
    y_ref, h_ref = _ssd_oracle(A_log, xh, Bm, Cm, dt, h0)
    np.testing.assert_allclose(np.asarray(y[:, 0]), y_ref[:, 0],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-5, atol=1e-5)


def test_rwkv_arch_consistent_across_impls():
    """Full rwkv6 model: chunked vs scan give the same logits."""
    cfg_c = reduced_for_smoke(get_config("rwkv6-1.6b"))
    cfg_s = dataclasses.replace(cfg_c, rwkv_impl="scan")
    params = api.init_params(cfg_c, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                cfg_c.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    lc, _ = api.forward_train(cfg_c, params, batch)
    ls, _ = api.forward_train(cfg_s, params, batch)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(ls),
                               rtol=5e-4, atol=5e-4)
