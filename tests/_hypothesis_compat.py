"""Graceful-degradation shim for ``hypothesis``.

Test modules import ``given``/``settings``/``st`` from here instead of
from ``hypothesis`` directly:

    from _hypothesis_compat import given, settings, st

When hypothesis is installed (see tests/requirements-test.txt) the real
library is re-exported unchanged and tests get full shrinking/property
coverage.  When it is absent — this container does not ship it and the
driver forbids installing packages — a miniature, API-compatible
fallback runs each property test over a *seeded* random sample of the
strategy space.  No shrinking, but deterministic per test name, so the
suite stays green and still exercises randomized inputs.

Only the strategy combinators the repo actually uses are implemented:
``integers``, ``floats``, ``booleans``, ``just``, ``sampled_from``,
``lists``, ``tuples``, ``one_of``, ``builds``.
"""

from __future__ import annotations

try:  # real hypothesis available: re-export verbatim
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random
    import zlib

    _DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        """A sampler: ``example(rng)`` draws one value."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

        # hypothesis supports `a | b` on strategies
        def __or__(self, other):
            return _Strategy(
                lambda rng: (self if rng.random() < 0.5 else other)
                .example(rng))

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: rng.choice(seq))

        @staticmethod
        def lists(elements, min_size=0, max_size=10, unique=False):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                out = [elements.example(rng) for _ in range(n)]
                if unique:
                    seen, uniq = set(), []
                    for v in out:
                        if v not in seen:
                            seen.add(v)
                            uniq.append(v)
                    out = uniq
                return out
            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda rng: tuple(s.example(rng) for s in strategies))

        @staticmethod
        def one_of(*strategies):
            return _Strategy(lambda rng: rng.choice(strategies).example(rng))

        @staticmethod
        def builds(target, *args, **kwargs):
            def draw(rng):
                a = [s.example(rng) for s in args]
                kw = {k: s.example(rng) for k, s in kwargs.items()}
                return target(*a, **kw)
            return _Strategy(draw)

    st = _StrategiesModule()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        """Accepts and records max_examples; other knobs are no-ops."""
        def deco(fn):
            fn._compat_settings = {"max_examples": max_examples}
            return fn
        return deco

    def given(*g_args, **g_kwargs):
        """Run the test over a deterministic random sample of the space."""
        def deco(fn):
            def wrapper():
                cfg = (getattr(wrapper, "_compat_settings", None)
                       or getattr(fn, "_compat_settings", None)
                       or {"max_examples": _DEFAULT_MAX_EXAMPLES})
                rng = random.Random(
                    zlib.crc32(fn.__qualname__.encode("utf-8")))
                for _ in range(cfg["max_examples"]):
                    args = [s.example(rng) for s in g_args]
                    kwargs = {k: s.example(rng)
                              for k, s in g_kwargs.items()}
                    try:
                        fn(*args, **kwargs)
                    except Exception:
                        print(f"Falsifying example: {fn.__name__}"
                              f"(*{args!r}, **{kwargs!r})")
                        raise
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            # carry settings applied *outside* given
            if hasattr(fn, "_compat_settings"):
                wrapper._compat_settings = fn._compat_settings
            return wrapper
        return deco
