"""Run device-plane checks in subprocesses with forced host device counts.

The main pytest process must keep jax at 1 device (per instructions), so
anything needing a mesh > 1 runs as a child python process.
"""

import os
import pathlib
import subprocess
import sys

import pytest

HERE = pathlib.Path(__file__).parent
SRC = str(HERE.parent / "src")

pytestmark = pytest.mark.multidevice


def run_script(name, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(HERE / "multidevice" / name)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, (
        f"{name} failed\n--- stdout ---\n{proc.stdout[-4000:]}\n"
        f"--- stderr ---\n{proc.stderr[-4000:]}")
    assert "ALL:OK" in proc.stdout
    return proc.stdout


def test_shmem_and_team_collectives():
    out = run_script("shmem_checks.py")
    assert "CHECK:shmem_put_ring:OK" in out
    assert "CHECK:team_psum:OK" in out
    assert "CHECK:sharded_heap_putget:OK" in out


def test_pallas_comm_kernels_vs_oracle():
    out = run_script("kernel_checks.py")
    assert "CHECK:ring_reduce_scatter_bf16:OK" in out
