"""Pallas flash-attention kernel vs dense oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_single)
from repro.models.layers import causal_mask, gqa_scores_and_mix


def _rand(shape, seed, dtype=jnp.float32):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(*shape), dtype)


def dense_single(q, k, v, causal):
    s, hd = q.shape
    sc = (q.astype(jnp.float32) @ k.astype(jnp.float32).T
          / np.sqrt(hd))
    if causal:
        mask = np.tril(np.ones((s, k.shape[0]), bool))
        sc = jnp.where(mask, sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    return w @ v.astype(jnp.float32)


@pytest.mark.parametrize("s,blk", [(128, 128), (256, 128), (512, 256)])
@pytest.mark.parametrize("hd", [64, 128])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_single_matches_dense(s, blk, hd, causal):
    q = _rand((s, hd), 0)
    k = _rand((s, hd), 1)
    v = _rand((s, hd), 2)
    out = flash_attention_single(q, k, v, causal=causal, block_q=blk,
                                 block_k=blk)
    ref = dense_single(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_gqa_matches_model_attention(dtype):
    b, s, hq, hkv, hd = 2, 256, 4, 2, 64
    q = _rand((b, s, hq, hd), 3, dtype)
    k = _rand((b, s, hkv, hd), 4, dtype)
    v = _rand((b, s, hkv, hd), 5, dtype)
    out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    ref = gqa_scores_and_mix(q, k, v, causal_mask(s, s, 0))
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol)


def test_flash_rectangular_kv():
    """Non-square (cross-attention-like) shapes, non-causal."""
    s, t, hd = 128, 384, 64
    q = _rand((s, hd), 6)
    k = _rand((t, hd), 7)
    v = _rand((t, hd), 8)
    out = flash_attention_single(q, k, v, causal=False, block_q=128,
                                 block_k=128)
    ref = dense_single(q, k, v, False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
