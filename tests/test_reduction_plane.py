"""Differential property suite for the reduction plane (queued
``dart_accumulate`` / ``dart_get_accumulate`` + the op-identity-padded
allreduce/reduce).

The core oracle is a **naive blocking reference**: a host numpy arena
to which every op applies immediately and strictly sequentially.
Random interleaved sequences of put / accumulate / get_accumulate /
get / per-target flush / waitall run on the coalesced engine and must
leave the device arena **byte-identical** to the oracle — including
overlapping accumulates (commutative, so they may share a vectorized
dispatch), mixed-op splits, accumulate-vs-put splits, pool-end
headroom, and ``impl='pallas'`` vs ``'ref'``.

Numeric exactness: payload values are small integers (also when stored
as floats), so every intermediate sum/product is exactly representable
and the commutative reassociation inside a vectorized run is bitwise
equal to the sequential order.
"""

import random

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (DART_TEAM_ALL, DartConfig, dart_accumulate,
                        dart_accumulate_blocking, dart_allreduce,
                        dart_exit, dart_flush, dart_get_accumulate,
                        dart_get_blocking, dart_init, dart_memalloc,
                        dart_put, dart_put_blocking, dart_reduce,
                        dart_team_memalloc_aligned, dart_waitall)
from repro.core import onesided as _os
from repro.core import runtime as rt
from repro.kernels import segmented_copy as sc

POOL = 2048
N_UNITS = 4

OPS = ("sum", "prod", "min", "max")


def _mk_ctx(impl="ref", pool=POOL):
    c = dart_init(n_units=N_UNITS, config=DartConfig(
        non_collective_pool_bytes=pool, team_pool_bytes=pool))
    c.engine.impl = impl
    return c


@pytest.fixture()
def ctx(engine_impl):
    c = _mk_ctx(engine_impl)
    yield c
    dart_exit(c)


class Oracle:
    """The blocking reference: a host arena, ops applied in program
    order, one at a time."""

    def __init__(self, rows: int, pool: int):
        self.arena = np.zeros((rows, pool), np.uint8)

    def put(self, row, off, payload):
        self.arena[row, off:off + payload.size] = payload

    def get(self, row, off, nbytes):
        return self.arena[row, off:off + nbytes].copy()

    def accumulate(self, row, off, vals, op):
        dt = vals.dtype
        n = vals.size * dt.itemsize
        cur = self.arena[row, off:off + n].copy().view(dt)
        if op == "sum":
            new = cur + vals
        elif op == "prod":
            new = cur * vals
        elif op == "min":
            new = np.minimum(cur, vals)
        else:
            new = np.maximum(cur, vals)
        self.arena[row, off:off + n] = new.astype(dt).view(np.uint8)

    def get_accumulate(self, row, off, vals, op):
        old = self.get(row, off, vals.size * vals.dtype.itemsize)
        self.accumulate(row, off, vals, op)
        return old


def _rand_vals(rng, dtype, n):
    """Small-integer payloads: sums/products stay exactly representable
    so commutative reassociation is bitwise-equal to sequential."""
    return np.asarray([rng.randint(1, 3) for _ in range(n)], dtype)


def _device_arena(ctx):
    return np.asarray(ctx.state[_os.WORLD_POOLID])


# ------------------------------------------- the differential loop --------

@pytest.mark.parametrize("dtype", ["int32", "float32"])
@pytest.mark.parametrize("op", OPS)
def test_differential_sequences_vs_blocking_oracle(op, dtype, engine_impl):
    """≥ 200 generated op sequences per op class (100 here × 2 engine
    impls): random interleavings of accumulate (dominant), put,
    get_accumulate, per-target flush, and waitall, checked
    byte-identical against the sequential oracle after every
    sequence."""
    dt = np.dtype(dtype)
    ctx = _mk_ctx(engine_impl)
    oracle = Oracle(N_UNITS, POOL)
    g = dart_memalloc(ctx, POOL, unit=0)
    # string seed: deterministic across processes (str.__hash__ is not)
    rng = random.Random(f"{op}/{dtype}/{engine_impl}")
    try:
        for _ in range(100):
            handles = []
            for _ in range(rng.randint(2, 8)):
                row = rng.randrange(N_UNITS)
                n = rng.randint(1, 12)
                max_e = POOL // dt.itemsize - n
                # bias some ops hard against the pool end (headroom:
                # the padded seg window crosses the pool boundary,
                # which also exercises the pallas→ref fallback)
                e_off = max_e if rng.random() < 0.15 else \
                    rng.randint(0, max_e)
                off = e_off * dt.itemsize
                vals = _rand_vals(rng, dt, n)
                kind = rng.choices(["acc", "put", "gacc", "flush_t"],
                                   weights=[6, 2, 1, 1])[0]
                if kind == "acc":
                    handles.append(dart_accumulate(
                        ctx, g.setunit(row) + off, vals, op))
                    oracle.accumulate(row, off, vals, op)
                elif kind == "put":
                    handles.append(dart_put(
                        ctx, g.setunit(row) + off, vals))
                    oracle.put(row, off,
                               vals.view(np.uint8).reshape(-1))
                elif kind == "gacc":
                    old, h = dart_get_accumulate(
                        ctx, g.setunit(row) + off, vals, op)
                    expect = oracle.get_accumulate(row, off, vals, op)
                    assert np.asarray(old).tobytes() == expect.tobytes()
                    handles.append(h)
                else:
                    dart_flush(ctx, g, target=row)
            if rng.random() < 0.5:
                dart_waitall(handles)
            else:
                dart_flush(ctx)
            np.testing.assert_array_equal(_device_arena(ctx),
                                          oracle.arena)
    finally:
        dart_exit(ctx)


@given(st.lists(st.tuples(st.sampled_from(["acc", "put", "get"]),
                          st.sampled_from(OPS),
                          st.integers(0, N_UNITS - 1),   # row
                          st.integers(0, POOL // 4 - 8), # element offset
                          st.integers(1, 8)),            # elements
                min_size=1, max_size=10),
       st.booleans())
@settings(max_examples=25, deadline=None)
def test_interleaved_ops_byte_identical(op_specs, use_pallas):
    """Property (collected via the _hypothesis_compat shim): any
    interleaving of mixed-op accumulates, puts, and reads matches the
    sequential oracle — mixed-op overlap splits runs, reads flush
    their lane first."""
    ctx = _mk_ctx("pallas" if use_pallas else "ref")
    oracle = Oracle(N_UNITS, POOL)
    g = dart_memalloc(ctx, POOL, unit=0)
    try:
        for i, (kind, op, row, e_off, n) in enumerate(op_specs):
            off = e_off * 4
            vals = (np.arange(n, dtype=np.int32) % 3) + 1 + (i % 2)
            ptr = g.setunit(row) + off
            if kind == "acc":
                dart_accumulate(ctx, ptr, vals, op)
                oracle.accumulate(row, off, vals, op)
            elif kind == "put":
                dart_put(ctx, ptr, vals)
                oracle.put(row, off, vals.view(np.uint8).reshape(-1))
            else:
                got = np.asarray(dart_get_blocking(
                    ctx, ptr, (n,), jnp.int32))
                expect = oracle.get(row, off, n * 4).view(np.int32)
                np.testing.assert_array_equal(got, expect)
        dart_flush(ctx)
        np.testing.assert_array_equal(_device_arena(ctx), oracle.arena)
    finally:
        dart_exit(ctx)


# --------------------------------------------- coalescing + run splits ----

def test_same_op_accumulates_one_dispatch(ctx):
    """Acceptance criterion: N same-op accumulates to one pool flush
    as ONE counted dispatch — even with overlapping ranges."""
    g = dart_memalloc(ctx, 1024, unit=0)
    d0 = ctx.engine.dispatch_count
    hs = [dart_accumulate(ctx, g + 8 * (i % 3),
                          jnp.full((4,), 1, jnp.int32))
          for i in range(8)]
    dart_flush(ctx)
    assert ctx.engine.dispatch_count - d0 == 1
    dart_waitall(hs)
    out = np.asarray(dart_get_blocking(ctx, g, (10,), jnp.int32))
    # 8 ops striped over offsets 0/8/16: elem 0,1 get ops@0 (3); elem
    # 2,3 get ops@0+ops@8 (3+3); elem 4,5 ops@8+@16 (3+2); elem 6,7 @16
    np.testing.assert_array_equal(out, [3, 3, 6, 6, 5, 5, 2, 2, 0, 0])


def test_mixed_op_overlap_splits_runs(ctx):
    g = dart_memalloc(ctx, 512, unit=1)
    dart_put_blocking(ctx, g, jnp.full((4,), 2, jnp.int32))
    d0 = ctx.engine.dispatch_count
    dart_accumulate(ctx, g, jnp.full((4,), 3, jnp.int32), "sum")
    dart_accumulate(ctx, g, jnp.full((4,), 4, jnp.int32), "prod")
    dart_accumulate(ctx, g, jnp.full((4,), 10, jnp.int32), "min")
    dart_flush(ctx)
    assert ctx.engine.dispatch_count - d0 == 3   # one per op class
    out = np.asarray(dart_get_blocking(ctx, g, (4,), jnp.int32))
    np.testing.assert_array_equal(out, [10, 10, 10, 10])  # min(20, 10)


def test_accumulate_vs_put_overlap_splits(ctx):
    """put → acc → put on one cell must resolve exactly sequentially
    (the accumulate reads the first put's value, the last put wins)."""
    g = dart_memalloc(ctx, 256, unit=2)
    dart_put(ctx, g, jnp.full((4,), 5, jnp.int32))
    dart_accumulate(ctx, g, jnp.full((4,), 1, jnp.int32), "sum")
    dart_put(ctx, g + 8, jnp.full((2,), 9, jnp.int32))
    dart_flush(ctx)
    out = np.asarray(dart_get_blocking(ctx, g, (4,), jnp.int32))
    np.testing.assert_array_equal(out, [6, 6, 9, 9])


def test_mixed_dtype_accumulates_split(ctx):
    g = dart_memalloc(ctx, 256, unit=0)
    d0 = ctx.engine.dispatch_count
    dart_accumulate(ctx, g, jnp.full((2,), 1, jnp.int32), "sum")
    dart_accumulate(ctx, g + 64, jnp.full((2,), 1.5, jnp.float32), "sum")
    dart_flush(ctx)
    assert ctx.engine.dispatch_count - d0 == 2
    assert list(np.asarray(dart_get_blocking(
        ctx, g, (2,), jnp.int32))) == [1, 1]
    assert list(np.asarray(dart_get_blocking(
        ctx, g + 64, (2,), jnp.float32))) == [1.5, 1.5]


def test_get_accumulate_overlap_splits_and_orders(ctx):
    """Two overlapping fetch-accumulates must each see the sequential
    pre-value (the second observes the first's effect)."""
    g = dart_memalloc(ctx, 256, unit=3)
    dart_put_blocking(ctx, g, jnp.full((4,), 10, jnp.int32))
    h1 = ctx.engine.get_accumulate(ctx.heap, ctx.teams_by_slot, g,
                                   np.full((4,), 1, np.int32), "sum")
    h2 = ctx.engine.get_accumulate(ctx.heap, ctx.teams_by_slot, g,
                                   np.full((4,), 2, np.int32), "sum")
    d0 = ctx.engine.dispatch_count
    dart_flush(ctx)
    assert ctx.engine.dispatch_count - d0 == 2     # overlap split
    assert list(np.asarray(h1.value())) == [10] * 4
    assert list(np.asarray(h2.value())) == [11] * 4
    out = np.asarray(dart_get_blocking(ctx, g, (4,), jnp.int32))
    np.testing.assert_array_equal(out, [13] * 4)


def test_disjoint_get_accumulates_share_one_dispatch(ctx):
    g = dart_memalloc(ctx, 512, unit=0)
    for i in range(4):
        dart_put_blocking(ctx, g + 32 * i,
                          jnp.full((4,), i + 1, jnp.int32))
    hs = [ctx.engine.get_accumulate(
            ctx.heap, ctx.teams_by_slot, g + 32 * i,
            np.full((4,), 10, np.int32), "sum") for i in range(4)]
    d0 = ctx.engine.dispatch_count
    dart_flush(ctx)
    assert ctx.engine.dispatch_count - d0 == 1
    for i, h in enumerate(hs):
        assert list(np.asarray(h.value())) == [i + 1] * 4
        assert list(np.asarray(dart_get_blocking(
            ctx, g + 32 * i, (4,), jnp.int32))) == [i + 11] * 4


def test_accumulate_pool_end_headroom(ctx):
    """An accumulate hard against the pool end: the padded seg window
    would cross the boundary (pallas falls back to ref), bytes outside
    the op's exact range stay untouched."""
    pool = ctx.config.non_collective_pool_bytes
    g = dart_memalloc(ctx, pool, unit=1)
    sentinel = jnp.full((4,), 0xCD, jnp.uint8)
    dart_put_blocking(ctx, g + pool - 16, sentinel)
    dart_accumulate_blocking(ctx, g + pool - 12,
                             jnp.full((3,), 7, jnp.int32), "sum")
    tail = np.asarray(dart_get_blocking(ctx, g + pool - 16, (4,),
                                        jnp.uint8))
    np.testing.assert_array_equal(tail, [0xCD] * 4)
    out = np.asarray(dart_get_blocking(ctx, g + pool - 12, (3,),
                                       jnp.int32))
    np.testing.assert_array_equal(out, [7, 7, 7])


# ------------------------------------------------ initiation checks -------

def test_unknown_op_rejected_at_initiation(ctx):
    g = dart_memalloc(ctx, 256, unit=0)
    with pytest.raises(ValueError):
        dart_accumulate(ctx, g, jnp.ones((2,), jnp.int32), "xor")
    assert ctx.engine.pending_ops() == 0


def test_misaligned_accumulate_rejected(ctx):
    g = dart_memalloc(ctx, 256, unit=0)
    with pytest.raises(ValueError):
        dart_accumulate(ctx, g + 2, jnp.ones((2,), jnp.int32))
    assert ctx.engine.pending_ops() == 0


def test_accumulate_bounds_checked_at_initiation(ctx):
    pool = ctx.config.non_collective_pool_bytes
    g = dart_memalloc(ctx, 128, unit=0)
    with pytest.raises(ValueError):
        dart_accumulate(ctx, g + (pool - 4 - g.addr),
                        jnp.zeros(4, jnp.int32))
    assert ctx.engine.pending_ops() == 0


# --------------------------------------- allreduce / reduce correctness ---

def test_allreduce_identity_padding_all_ops(ctx):
    """min/max/prod need true identities (±inf / 1) in the padded
    lanes — negative values and non-pow2 element counts exercise it."""
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 256)
    vals = {0: [-5, 2, 7], 1: [4, -9, 1], 2: [0, 3, -2], 3: [8, 8, 8]}
    expect = {"sum": [7, 4, 14], "prod": [0, -432, -112],
              "min": [-5, -9, -2], "max": [8, 8, 8]}
    for op in OPS:
        for u, v in vals.items():
            dart_put_blocking(ctx, g.setunit(u),
                              jnp.asarray(v, jnp.float32))
        red = np.asarray(dart_allreduce(ctx, g, (3,), jnp.float32, op))
        np.testing.assert_array_equal(red, expect[op])
        for u in range(N_UNITS):
            got = np.asarray(dart_get_blocking(
                ctx, g.setunit(u), (3,), jnp.float32))
            np.testing.assert_array_equal(got, expect[op])


def test_reduce_lands_on_root_only(ctx):
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 256)
    for u in range(N_UNITS):
        dart_put_blocking(ctx, g.setunit(u),
                          jnp.full((5,), u + 1, jnp.int32))
    red = np.asarray(dart_reduce(ctx, g, (5,), jnp.int32, "sum", root=2))
    np.testing.assert_array_equal(red, [10] * 5)
    for u in range(N_UNITS):
        got = np.asarray(dart_get_blocking(ctx, g.setunit(u), (5,),
                                           jnp.int32))
        np.testing.assert_array_equal(got,
                                      [10 if u == 2 else u + 1] * 5)


def test_allreduce_does_not_touch_adjacent_bytes(ctx):
    """The padded reduce write-back is masked to the true byte length:
    a sentinel right after the reduced segment must survive."""
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 256)
    for u in range(N_UNITS):
        dart_put_blocking(ctx, g.setunit(u), jnp.full((3,), u, jnp.int32))
        dart_put_blocking(ctx, g.setunit(u) + 12,
                          jnp.full((4,), 0xEE, jnp.uint8))
    dart_allreduce(ctx, g, (3,), jnp.int32, "sum")
    for u in range(N_UNITS):
        tail = np.asarray(dart_get_blocking(ctx, g.setunit(u) + 12,
                                            (4,), jnp.uint8))
        np.testing.assert_array_equal(tail, [0xEE] * 4)


def test_allreduce_sees_queued_puts(ctx):
    """Collectives close the pool's epoch first: queued puts are
    ordered before the reduction."""
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 128)
    for u in range(N_UNITS):
        dart_put(ctx, g.setunit(u), jnp.full((2,), u + 1, jnp.float32))
    red = np.asarray(dart_allreduce(ctx, g, (2,), jnp.float32, "sum"))
    np.testing.assert_array_equal(red, [10.0, 10.0])


def test_scalar_allreduce(ctx):
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 64)
    for u in range(N_UNITS):
        dart_put_blocking(ctx, g.setunit(u),
                          jnp.asarray(float(u + 1), jnp.float32))
    red = dart_allreduce(ctx, g, (), jnp.float32, "max")
    assert np.asarray(red).shape == ()
    assert float(np.asarray(red)) == 4.0


# -------------------------------------------- zero-recompile regression ---

def test_allreduce_zero_recompiles_steady_state(ctx):
    """The assertable form of the closed ROADMAP item: a steady-state
    loop over varying (shape, dtype, op) allreduces performs ZERO plan
    compiles after warmup — the op-identity padding buckets the
    element count, so the exact shape never keys a kernel."""
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 512)
    combos = [((5,), jnp.float32, "sum"), ((7,), jnp.float32, "min"),
              ((6,), jnp.int32, "sum"), ((8,), jnp.int32, "max"),
              ((2, 3), jnp.float32, "prod")]
    for shape, dt, op in combos:                  # warm every bucket
        dart_allreduce(ctx, g, shape, dt, op)
    c0 = ctx.engine.compile_count
    for shape, dt, op in [((6,), jnp.float32, "sum"),
                          ((8,), jnp.float32, "min"),
                          ((5,), jnp.int32, "sum"),
                          ((7,), jnp.int32, "max"),
                          ((3, 2), jnp.float32, "prod"),
                          ((8,), jnp.float32, "sum")]:
        red = dart_allreduce(ctx, g, shape, dt, op)
        assert np.asarray(red).shape == shape
    assert ctx.engine.compile_count == c0, \
        "varying-shape allreduce recompiled in steady state"
    assert ctx.engine.plan_cache_hits > 0


def test_accumulate_zero_recompiles_steady_state(ctx):
    g = dart_memalloc(ctx, 2048, unit=0)

    def epoch(k, n):
        hs = [dart_accumulate(ctx, g + 64 * i,
                              jnp.full((n,), 1, jnp.int32))
              for i in range(k)]
        dart_flush(ctx)
        dart_waitall(hs)

    epoch(8, 16)                                  # warm (8, 64B) bucket
    c0 = ctx.engine.compile_count
    for k, n in [(5, 16), (7, 9), (8, 12), (6, 10), (4, 16), (8, 13)]:
        epoch(k, n)
    assert ctx.engine.compile_count == c0, \
        "varying-size accumulate epochs recompiled in steady state"


# ---------------------------------------------------- typed front-end -----

def test_typed_accumulate_coalesces_in_epoch(ctx):
    ga = ctx.alloc((8,), jnp.int32)
    ga.scatter(np.zeros((N_UNITS, 8), np.int32))
    d0 = ctx.engine.dispatch_count
    with ga.epoch():
        for u in range(N_UNITS):
            ga.at[u, 2:6].add(jnp.full((4,), u + 1, jnp.int32))
    assert ctx.engine.dispatch_count - d0 == 1
    for u in range(N_UNITS):
        got = np.asarray(ga[u].get())
        np.testing.assert_array_equal(
            got, [0, 0] + [u + 1] * 4 + [0, 0])


def test_typed_accumulate_ops_and_get_accumulate(ctx):
    ga = ctx.alloc((4,), jnp.float32)
    ga.scatter(np.tile(np.asarray([2., 4., 6., 8.], np.float32),
                       (N_UNITS, 1)))
    ga.at[1, :].mul(jnp.full((4,), 2.0, jnp.float32)).wait()
    np.testing.assert_array_equal(np.asarray(ga[1].get()),
                                  [4., 8., 12., 16.])
    ga.at[1, 1:3].min(jnp.full((2,), 5.0, jnp.float32)).wait()
    np.testing.assert_array_equal(np.asarray(ga[1].get()),
                                  [4., 5., 5., 16.])
    old = ga.at[1, 0:2].get_accumulate(
        jnp.full((2,), 100.0, jnp.float32), "max")
    np.testing.assert_array_equal(np.asarray(old), [4., 5.])
    np.testing.assert_array_equal(np.asarray(ga[1].get()),
                                  [100., 100., 5., 16.])
    h = ga.accumulate(2, slice(0, 2), jnp.full((2,), 1.0, jnp.float32))
    h.wait()
    np.testing.assert_array_equal(np.asarray(ga[2].get()),
                                  [3., 5., 6., 8.])


def test_typed_reduce_and_allreduce(ctx):
    ga = ctx.alloc((3,), jnp.int32)
    ga.scatter(np.arange(N_UNITS * 3, dtype=np.int32).reshape(
        N_UNITS, 3))
    red = np.asarray(ga.reduce("max", root=1))
    np.testing.assert_array_equal(red, [9, 10, 11])
    np.testing.assert_array_equal(np.asarray(ga[1].get()), [9, 10, 11])
    np.testing.assert_array_equal(np.asarray(ga[0].get()), [0, 1, 2])


# ------------------------------------------------- lifecycle / teardown ---

def test_queued_accumulate_dropped_by_destroy_fails_handle(ctx):
    from repro.core import dart_team_create, dart_team_destroy
    from repro.core.group import DartGroup
    tid = dart_team_create(ctx, DART_TEAM_ALL, DartGroup((0, 1)))
    gt = dart_team_memalloc_aligned(ctx, tid, 128)
    h = dart_accumulate(ctx, gt, jnp.ones((2,), jnp.int32))
    dart_team_destroy(ctx, tid)
    with pytest.raises(RuntimeError, match="window destroyed"):
        h.wait()


def test_accumulate_handle_state_machine(ctx):
    g = dart_memalloc(ctx, 256, unit=0)
    h = dart_accumulate(ctx, g, jnp.ones((4,), jnp.int32))
    assert h.state == "queued" and not h.test()
    dart_flush(ctx)
    assert h.state in ("issued", "complete")
    h.wait()
    assert h.state == "complete"
