"""Tests for the background progress plane (`core/progress.py`) and
the thread-safe CommEngine underneath it.

The headline test is the threaded differential: N submitter threads
drive a random put/get/accumulate mix — with the progress daemon
flushing concurrently at aggressive watermarks — and the final arena
must be byte-identical to a single-threaded oracle replay.  Each
thread owns a disjoint offset window, so the final state is
interleaving-independent and the comparison is exact, under both
``impl='ref'`` and ``'pallas'`` (conftest's ``engine_impl``).
"""

import random
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DartConfig, ProgressPlane, dart_accumulate,
                        dart_exit, dart_flush, dart_get_nb, dart_init,
                        dart_memalloc, dart_put, dart_waitall)

N_THREADS = 6
OPS_PER_THREAD = 30
WIN_BYTES = 256                       # per-thread disjoint window


@pytest.fixture()
def ctx(engine_impl):
    c = dart_init(n_units=4, config=DartConfig(
        non_collective_pool_bytes=1 << 15, team_pool_bytes=4096))
    c.engine.impl = engine_impl
    yield c
    dart_exit(c)


def _spin_until(pred, timeout=10.0, msg="condition"):
    deadline = time.monotonic() + timeout
    while not pred():
        assert time.monotonic() < deadline, f"timed out waiting: {msg}"
        time.sleep(0.002)


# --------------------------------------------------- watermark triggers ----

def test_watermark_ops_triggers_background_flush(ctx):
    plane = ctx.start_progress(watermark_ops=4, watermark_bytes=1 << 30,
                               idle_s=60.0)
    g = dart_memalloc(ctx, 64, unit=1)
    before = ctx.engine.dispatch_count
    hs = [dart_put(ctx, g + 4 * i, jnp.asarray([i], jnp.int32))
          for i in range(4)]
    # idle deadline is 60s and the byte watermark unreachable: only the
    # op watermark can have drained the lane (spin on the counter — it
    # is bumped just after the flush empties the queue)
    _spin_until(lambda: plane.watermark_flushes >= 1,
                msg="op-watermark flush")
    assert ctx.engine.pending_ops() == 0
    assert plane.idle_flushes == 0
    assert ctx.engine.dispatch_count > before
    dart_waitall(hs)
    assert plane.errors == []


def test_watermark_bytes_triggers_background_flush(ctx):
    plane = ctx.start_progress(watermark_ops=10**6,
                               watermark_bytes=256, idle_s=60.0)
    g = dart_memalloc(ctx, 1024, unit=2)
    h = dart_put(ctx, g, jnp.zeros(128, jnp.int32))      # 512 bytes
    _spin_until(lambda: plane.watermark_flushes >= 1,
                msg="byte-watermark flush")
    assert ctx.engine.pending_ops() == 0
    assert plane.idle_flushes == 0
    h.wait()


def test_idle_deadline_flushes_stragglers(ctx):
    """One tiny op below both watermarks still lands within idle_s —
    the progress guarantee for a submitter that just stops calling."""
    plane = ctx.start_progress(watermark_ops=10**6,
                               watermark_bytes=1 << 30, idle_s=0.02)
    g = dart_memalloc(ctx, 16, unit=0)
    dart_put(ctx, g, jnp.asarray([7], jnp.int32))
    _spin_until(lambda: plane.idle_flushes >= 1,
                msg="idle-deadline flush")
    assert ctx.engine.pending_ops() == 0
    assert plane.watermark_flushes == 0


def test_below_watermark_stays_queued(ctx):
    ctx.start_progress(watermark_ops=100, watermark_bytes=1 << 30,
                       idle_s=60.0)
    g = dart_memalloc(ctx, 64, unit=1)
    dart_put(ctx, g, jnp.asarray([1], jnp.int32))
    time.sleep(0.05)
    assert ctx.engine.pending_ops() == 1    # nothing crossed a trigger


# ------------------------------------------------------ clean shutdown -----

def test_stop_drains_queued_ops(ctx):
    """stop(drain=True) flushes what is still queued — shutdown never
    drops ops — and the daemon is gone afterwards."""
    plane = ctx.start_progress(watermark_ops=10**6,
                               watermark_bytes=1 << 30, idle_s=60.0)
    g = dart_memalloc(ctx, 64, unit=3)
    hs = [dart_put(ctx, g + 4 * i, jnp.asarray([i + 1], jnp.int32))
          for i in range(3)]
    assert ctx.engine.pending_ops() == 3
    ctx.stop_progress(drain=True)
    assert not plane.running
    assert ctx.engine.pending_ops() == 0
    dart_waitall(hs)                        # all complete, none dropped
    assert all(h.state == "complete" for h in hs)


def test_dart_exit_stops_plane(engine_impl):
    c = dart_init(n_units=2, config=DartConfig(
        non_collective_pool_bytes=4096, team_pool_bytes=4096))
    c.engine.impl = engine_impl
    plane = c.start_progress()
    assert plane.running
    dart_exit(c)
    assert not plane.running


def test_start_progress_is_idempotent(ctx):
    p1 = ctx.start_progress()
    p2 = ctx.start_progress()
    assert p1 is p2


def test_invalid_knobs_rejected(ctx):
    with pytest.raises(ValueError):
        ProgressPlane(ctx.engine, watermark_ops=0)
    with pytest.raises(ValueError):
        ProgressPlane(ctx.engine, idle_s=0.0)


# ------------------------------------------- threaded differential test ----

def _apply_oracle(arena, base, op_list):
    """Replay one thread's program serially against a numpy arena row."""
    for kind, off, payload in op_list:
        if kind == "put":
            arena[base + off:base + off + len(payload)] = payload
        else:                               # accumulate(sum)
            arena[base + off:base + off + len(payload)] += payload


def test_threaded_differential_vs_serial_oracle(ctx):
    """N submitter threads × random put/accumulate/get mix, progress
    daemon flushing underneath at aggressive watermarks: the final
    arena is byte-identical to the serial oracle replay.  Per-thread
    windows are disjoint, so the answer is interleaving-independent."""
    ctx.start_progress(watermark_ops=3, watermark_bytes=1 << 10,
                       idle_s=0.005)
    n_words = WIN_BYTES // 4
    g = dart_memalloc(ctx, WIN_BYTES * N_THREADS, unit=1)

    # pre-generate every thread's program so the oracle replays exactly
    programs = []
    for t in range(N_THREADS):
        rng = random.Random(1000 + t)
        ops = []
        for _ in range(OPS_PER_THREAD):
            kind = rng.choice(["put", "acc", "get"])
            n = rng.randint(1, 8)
            off = rng.randint(0, n_words - n) * 4
            payload = [rng.randint(-50, 50) for _ in range(n)]
            ops.append((kind, off, payload))
        programs.append(ops)

    errs = []

    def worker(t):
        try:
            base = t * WIN_BYTES
            hs = []
            for kind, off, payload in programs[t]:
                if kind == "get":
                    hs.append(dart_get_nb(ctx, g + base + off,
                                          (len(payload),), jnp.int32))
                elif kind == "put":
                    hs.append(dart_put(ctx, g + base + off,
                                       jnp.asarray(payload, jnp.int32)))
                else:
                    hs.append(dart_accumulate(ctx, g + base + off,
                                              jnp.asarray(payload,
                                                          jnp.int32)))
            dart_waitall(hs)
        except BaseException as e:          # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=worker, args=(t,))
          for t in range(N_THREADS)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    if errs:
        raise errs[0]
    ctx.stop_progress(drain=True)

    # oracle: same programs replayed serially on a numpy arena
    want_words = np.zeros(n_words * N_THREADS, np.int64)
    for t, ops in enumerate(programs):
        word_ops = [(k, off // 4, np.asarray(p, np.int64))
                    for k, off, p in ops if k != "get"]
        _apply_oracle(want_words, t * (WIN_BYTES // 4), word_ops)

    got = np.asarray(dart_get_nb(ctx, g, (n_words * N_THREADS,),
                                 jnp.int32).value())
    np.testing.assert_array_equal(got, want_words.astype(np.int32))


def test_threaded_submitters_dispatch_counters_consistent(ctx):
    """Counter integrity under contention: ops_enqueued is exact and
    every enqueued op is dispatched by the time the queue is empty."""
    ctx.start_progress(watermark_ops=5, idle_s=0.005)
    g = dart_memalloc(ctx, 4 * N_THREADS * OPS_PER_THREAD, unit=2)
    start = ctx.engine.ops_enqueued
    all_hs = [[] for _ in range(N_THREADS)]

    def worker(t):
        base = t * OPS_PER_THREAD
        for k in range(OPS_PER_THREAD):
            all_hs[t].append(dart_put(ctx, g + 4 * (base + k),
                                      jnp.asarray([base + k], jnp.int32)))

    ts = [threading.Thread(target=worker, args=(t,))
          for t in range(N_THREADS)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    assert ctx.engine.ops_enqueued - start == N_THREADS * OPS_PER_THREAD
    dart_waitall([h for hs in all_hs for h in hs])
    assert ctx.engine.pending_ops() == 0
    got = np.asarray(dart_get_nb(ctx, g, (N_THREADS * OPS_PER_THREAD,),
                                 jnp.int32).value())
    np.testing.assert_array_equal(
        got, np.arange(N_THREADS * OPS_PER_THREAD, dtype=np.int32))


def test_waitall_races_concurrent_flusher(ctx):
    """The waitall lane-scan fix: handles issued by a flush that runs
    between waitall's own flush and its scan are reported complete —
    never blamed with a stale 'dropped before dispatch' error."""
    g = dart_memalloc(ctx, 4 * 64, unit=0)
    stop = threading.Event()

    def flusher():
        while not stop.is_set():
            dart_flush(ctx)

    f = threading.Thread(target=flusher)
    f.start()
    try:
        for round_no in range(25):
            hs = [dart_put(ctx, g + 4 * i,
                           jnp.asarray([round_no], jnp.int32))
                  for i in range(8)]
            dart_waitall(hs)               # must never raise
            assert all(h.state == "complete" for h in hs)
    finally:
        stop.set()
        f.join(timeout=10)
