"""Serving engine integration tests."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.models.config import reduced_for_smoke
from repro.serve import ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = reduced_for_smoke(get_config("llama3-8b"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, max_batch=3, max_seq=48)


def test_engine_serves_batched_requests(engine):
    rng = np.random.RandomState(0)
    reqs = [engine.submit(rng.randint(0, 100, size=rng.randint(3, 9))
                          .astype(np.int32), max_new_tokens=5)
            for _ in range(7)]
    done = engine.drain()
    assert done == 7
    for r in reqs:
        assert r.done.is_set()
        assert r.output.shape == (5,)


def test_engine_greedy_matches_manual_decode(engine):
    """Engine output == manual prefill+decode for a single request."""
    cfg = engine.cfg
    prompt = np.arange(1, 7, dtype=np.int32)
    req = engine.submit(prompt, max_new_tokens=4)
    engine.drain()

    import jax.numpy as jnp
    batch = {"tokens": jnp.asarray(prompt[None])}
    logits, cache = api.forward_prefill(cfg, engine.params, batch,
                                        engine.max_seq)
    toks = []
    nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    toks.append(int(nxt[0, 0]))
    for _ in range(3):
        logits, cache = api.forward_decode(cfg, engine.params, nxt, cache)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        toks.append(int(nxt[0, 0]))
    np.testing.assert_array_equal(req.output, toks)


def test_engine_eos_truncation(engine):
    prompt = np.arange(1, 5, dtype=np.int32)
    # run once to find what the model emits, then use its first token
    # as the EOS to force truncation at length 1
    r0 = engine.submit(prompt, max_new_tokens=6)
    engine.drain()
    eos = int(r0.output[0])
    r1 = engine.submit(prompt, max_new_tokens=6, eos_id=eos)
    engine.drain()
    assert r1.output.tolist() == [eos]


def test_engine_wave_early_exits_when_all_rows_hit_eos(engine):
    """The decode loop stops once every wave member is finished, not
    at the wave's max ``max_new_tokens``."""
    prompt = np.arange(1, 5, dtype=np.int32)
    r0 = engine.submit(prompt, max_new_tokens=8)
    engine.drain()
    assert engine.last_wave_steps == 8             # no EOS: full budget
    eos = int(r0.output[0])
    for _ in range(engine.max_batch):              # whole wave EOSes at once
        engine.submit(prompt, max_new_tokens=8, eos_id=eos)
    engine.drain()
    assert engine.last_wave_steps == 1
    # mixed wave: the longest *live* row bounds the steps
    engine.submit(prompt, max_new_tokens=8, eos_id=eos)
    r = engine.submit(prompt, max_new_tokens=3)
    engine.drain()
    assert engine.last_wave_steps == 3
    assert r.output.shape == (3,)


def test_engine_submit_rids_unique_under_concurrency(engine):
    import threading

    rids, lock = [], threading.Lock()

    def worker():
        mine = [engine.submit(np.arange(1, 4, dtype=np.int32),
                              max_new_tokens=1).rid for _ in range(50)]
        with lock:
            rids.extend(mine)

    ts = [threading.Thread(target=worker) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(set(rids)) == len(rids) == 400
    engine.drain()                                 # leave the queue clean
