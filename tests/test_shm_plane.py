"""Shared-memory plane: zero-copy writes, shm-direct collectives, and
the routing-correctness regressions (ISSUE 10).

Covers, per the satellite list:

* the per-pool ``shm_supported`` cache (mixed-visibility pools must not
  poison each other; invalidation on destroy/exit);
* the headroom bounds check (typed :class:`ShmBoundsError` carrying
  (poolid, row, off, nbytes) instead of a truncated-slice reshape
  crash);
* the hoisted hot-path classifier (ONE top-level engine-lock
  acquisition per routed get; zero dlpack probes in the steady state);
* shm-put vs jitted-put byte identity under random interleavings, both
  engine impls, with the ProgressPlane daemon live — plus chaos-marked
  runs proving the fault plane's failed-lane semantics hold on the shm
  write path;
* shm-direct collective equivalence vs the engine collectives at zero
  jitted dispatches.
"""

import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DART_TEAM_ALL, DartConfig, DartError, DartGroup,
                        ShmBoundsError, UnitFailedError, dart_exit,
                        dart_get_nb, dart_init, dart_put,
                        dart_put_blocking, dart_shm_view,
                        dart_team_create, dart_team_destroy,
                        dart_team_memalloc_shared, invalidate_shm_cache,
                        shm_supported, shm_writable)
from repro.core import onesided as _os
from repro.core import runtime as rt

POOL_BYTES = 8192


@pytest.fixture()
def ctx(engine_impl):
    c = dart_init(n_units=4, config=DartConfig(
        non_collective_pool_bytes=POOL_BYTES, team_pool_bytes=POOL_BYTES))
    c.engine.impl = engine_impl
    yield c
    dart_exit(c)


def _require_shm(ctx):
    if not shm_writable(ctx):
        pytest.skip("backend arenas not host-writable")


def _lane_of(ctx, gptr):
    return _os.deref(ctx.heap, ctx.teams_by_slot, gptr)


# ------------------------------------------------ per-pool cache ----------

def test_mixed_visibility_cache_is_per_pool(ctx):
    """Regression: the support cache was one boolean per *context*, so
    the first probed pool's answer misrouted every other pool under
    mixed visibility.  A device-only pool (simulated by an arena whose
    dlpack probe fails) must cache False for ITSELF only."""
    _require_shm(ctx)
    teamid = dart_team_create(ctx, DART_TEAM_ALL, DartGroup((0, 1)))
    g_bad = rt.dart_team_memalloc_aligned(ctx, teamid, 64)
    pool_bad, _, _ = _lane_of(ctx, g_bad)

    real = ctx.state[pool_bad]
    ctx.state[pool_bad] = object()          # dlpack probe fails
    try:
        assert shm_supported(ctx, pool_bad) is False
        # the negative answer must NOT have poisoned the other pools
        g_good = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 64)
        pool_good, _, _ = _lane_of(ctx, g_good)
        assert shm_supported(ctx, pool_good) is True
        assert shm_writable(ctx, pool_good) is True
    finally:
        ctx.state[pool_bad] = real
    # the False is CACHED (same pool, arena now probe-able again) ...
    assert shm_supported(ctx, pool_bad) is False
    # ... until explicitly invalidated
    invalidate_shm_cache(ctx, pool_bad)
    assert shm_supported(ctx, pool_bad) is True
    # destroy drops the pool's cache entry; exit clears the whole cache
    dart_team_destroy(ctx, teamid)
    assert pool_bad not in ctx._shm_cache
    assert shm_supported(ctx, pool_bad) is False       # pool is gone


def test_cache_cleared_on_exit():
    c = dart_init(n_units=2, config=DartConfig(
        non_collective_pool_bytes=1024, team_pool_bytes=1024))
    if not shm_writable(c):
        dart_exit(c)
        pytest.skip("backend arenas not host-writable")
    assert c._shm_cache            # probe populated it
    dart_exit(c)
    assert c._shm_cache == {}
    assert shm_supported(c) is False


# ------------------------------------------------ headroom check ----------

def test_shm_view_headroom_typed_error(ctx):
    """An overrunning span raises ShmBoundsError (typed, lane-
    addressed) instead of silently truncating the slice."""
    _require_shm(ctx)
    g = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 64)
    bad = g.incaddr(POOL_BYTES - 8)         # 8 B of headroom left
    with pytest.raises(ShmBoundsError) as ei:
        dart_shm_view(ctx, bad, (4,), jnp.float32)      # needs 16 B
    err = ei.value
    poolid, row, off = _lane_of(ctx, bad)
    assert err.poolid == poolid
    assert err.row == row
    assert err.off == off
    assert err.nbytes == 16
    # part of the DartError ladder AND a ValueError (legacy symptom)
    assert isinstance(err, DartError) and isinstance(err, ValueError)


def test_shm_put_overrun_matches_engine_error(ctx):
    """The write side keeps the ENGINE's geometry error verbatim — an
    overrunning blocking put raises the same ValueError whether it
    would have routed shm or not."""
    _require_shm(ctx)
    g = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 64)
    bad = g.incaddr(POOL_BYTES - 8)
    with pytest.raises(ValueError, match="overruns"):
        dart_put_blocking(ctx, bad, jnp.zeros((4,), jnp.float32))


# ---------------------------------------- hoisted hot-path classifier -----

class _CountingLock:
    """RLock proxy counting TOP-LEVEL acquisitions (depth 0 → 1);
    nested re-entries (e.g. the ordering flush inside a routed get) are
    free under an RLock and don't count."""

    def __init__(self, inner):
        self._inner = inner
        self._depth = 0
        self.toplevel = 0

    def __enter__(self):
        self._inner.acquire()
        if self._depth == 0:
            self.toplevel += 1
        self._depth += 1
        return self

    def __exit__(self, *exc):
        self._depth -= 1
        self._inner.release()
        return False

    def acquire(self, *a, **kw):
        ok = self._inner.acquire(*a, **kw)
        if ok:
            if self._depth == 0:
                self.toplevel += 1
            self._depth += 1
        return ok

    def release(self):
        self._depth -= 1
        self._inner.release()


def test_routed_get_single_lock_acquisition_no_steady_probes(ctx):
    """Satellite 3: a routed get takes the engine lock ONCE at top
    level (deref + cached probe + flush + view under one hold) and
    never re-probes dlpack support per deref."""
    _require_shm(ctx)
    g = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 256)
    dart_put_blocking(ctx, g.setunit(1), jnp.arange(8, dtype=jnp.float32))
    rt.dart_get_blocking(ctx, g.setunit(1), (8,), jnp.float32)  # warm cache

    real = ctx.engine.lock
    proxy = _CountingLock(real)
    ctx.engine.lock = proxy
    try:
        probes0 = ctx._shm_probe_count
        for _ in range(10):
            before = proxy.toplevel
            v = rt.dart_get_blocking(ctx, g.setunit(1), (8,), jnp.float32)
            assert proxy.toplevel - before == 1
            np.testing.assert_array_equal(np.asarray(v),
                                          np.arange(8, dtype=np.float32))
        assert ctx._shm_probe_count - probes0 == 0
    finally:
        ctx.engine.lock = real


# ----------------------------------- shm put: routing + byte identity -----

def test_shm_put_zero_dispatch_roundtrip(ctx):
    _require_shm(ctx)
    g = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 256)
    d0, p0 = ctx.engine.dispatch_count, ctx.engine.shm_puts
    dart_put_blocking(ctx, g.setunit(3), jnp.arange(16, dtype=jnp.int32))
    assert ctx.engine.dispatch_count == d0      # zero jitted dispatches
    assert ctx.engine.shm_puts == p0 + 1
    got = rt.dart_get_blocking(ctx, g.setunit(3), (16,), jnp.int32)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.arange(16, dtype=np.int32))


def test_shm_put_strided(ctx):
    _require_shm(ctx)
    g = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 512)
    payload = jnp.arange(16, dtype=jnp.float32)      # 4 segs × 16 B
    dart_put_blocking(ctx, g.setunit(0), payload, stride=64, count=4)
    for i in range(4):
        seg = rt.dart_get_blocking(ctx, g.setunit(0).incaddr(64 * i),
                                   (4,), jnp.float32)
        np.testing.assert_array_equal(np.asarray(seg),
                                      np.arange(4 * i, 4 * i + 4,
                                                dtype=np.float32))


def test_shm_put_ordered_after_queued_ops(ctx):
    """Program order vs queued epochs: a queued engine put to the same
    lane lands BEFORE the shm put (ordering flush), and a queued get
    dispatched before the shm put reads the PRE-put bytes (read
    fence)."""
    _require_shm(ctx)
    g = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 64)
    t = g.setunit(2)
    dart_put(ctx, t, jnp.full((4,), 1.0, jnp.float32))   # queued
    h = dart_get_nb(ctx, t, (4,), jnp.float32)           # queued after
    ctx.engine.flush(h.poolid, h.row)                    # get dispatched
    dart_put_blocking(ctx, t, jnp.full((4,), 2.0, jnp.float32))  # shm
    # the get was ordered before the shm write: it sees the 1.0 epoch
    np.testing.assert_array_equal(np.asarray(h.value()),
                                  np.full(4, 1.0, np.float32))
    got = rt.dart_get_blocking(ctx, t, (4,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.full(4, 2.0, np.float32))


def test_put_nb_stays_on_engine(ctx):
    """Non-blocking puts never shm-route — their contract is queued
    coalescing (1 batched dispatch per epoch close)."""
    _require_shm(ctx)
    ga = ctx.alloc((4,), jnp.float32)
    p0 = ctx.engine.shm_puts
    with ctx.epoch():
        for u in ga.units:
            ga[u].put_nb(jnp.full((4,), float(u)))
    assert ctx.engine.shm_puts == p0
    np.testing.assert_array_equal(np.asarray(ga.gather())[:, 0],
                                  [0.0, 1.0, 2.0, 3.0])


def test_shm_put_byte_identity_differential(ctx):
    """The acceptance differential: random interleavings of blocking
    puts / queued puts / queued gets on a default-shm array vs the
    identical program on a shm=False oracle (pure engine path), with
    the ProgressPlane daemon live on the subject.  Final heap bytes
    and every get's bytes must be identical."""
    _require_shm(ctx)
    oracle = dart_init(n_units=4, config=DartConfig(
        non_collective_pool_bytes=POOL_BYTES, team_pool_bytes=POOL_BYTES))
    oracle.engine.impl = ctx.engine.impl
    try:
        ga_s = ctx.alloc((8,), jnp.float32)              # shm-routed
        ga_o = oracle.alloc((8,), jnp.float32, shm=False)
        ctx.start_progress(watermark_ops=2, idle_s=0.001)

        rng = np.random.default_rng(1234)
        pending = []
        for _ in range(60):
            u = int(rng.integers(0, 4))
            op = rng.choice(["put", "put_nb", "get"])
            if op == "put":
                val = rng.random(8, dtype=np.float32)
                ga_s[u].put(val)
                ga_o[u].put(val)
            elif op == "put_nb":
                val = rng.random(8, dtype=np.float32)
                pending.append((ga_s[u].put_nb(val),
                                ga_o[u].put_nb(val)))
            else:
                np.testing.assert_array_equal(np.asarray(ga_s[u].get()),
                                              np.asarray(ga_o[u].get()))
        for hs, ho in pending:
            hs.wait()
            ho.wait()
        np.testing.assert_array_equal(np.asarray(ga_s.gather()),
                                      np.asarray(ga_o.gather()))
        assert ctx.engine.shm_puts > 0          # the route was exercised
        assert oracle.engine.shm_puts == 0      # ... and only on subject
    finally:
        dart_exit(oracle)


# ----------------------------------------- chaos: fault-plane parity ------

@pytest.mark.chaos
def test_shm_put_rejected_on_poisoned_lane(ctx):
    """Enqueue-boundary parity: a poisoned lane rejects the shm write
    with the same typed error as an engine enqueue — and the bytes
    must NOT land."""
    _require_shm(ctx)
    g = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 64)
    t = g.setunit(1)
    dart_put_blocking(ctx, t, jnp.full((4,), 7.0, jnp.float32))
    poolid, row, _ = _lane_of(ctx, t)
    plane = ctx.attach_faults(seed=0)
    plane.schedule(kind="poison", poolid=poolid, row=row, after=0)
    with pytest.raises(DartError, match="poisoned"):
        dart_put_blocking(ctx, t, jnp.full((4,), 9.0, jnp.float32))
    assert ctx.engine.clear_lane(poolid, row) is not None
    ctx.engine.attach_faults(None)
    got = rt.dart_get_blocking(ctx, t, (4,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.full(4, 7.0, np.float32))


@pytest.mark.chaos
def test_shm_put_fail_fast_on_dead_unit(ctx):
    _require_shm(ctx)
    g = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 64)
    ctx.engine.mark_unit_dead(2, reason="test death")
    with pytest.raises(UnitFailedError) as ei:
        dart_put_blocking(ctx, g.setunit(2), jnp.zeros((4,), jnp.float32))
    assert ei.value.unit == 2
    # survivors unaffected
    dart_put_blocking(ctx, g.setunit(1), jnp.ones((4,), jnp.float32))
    assert ctx.engine.shm_puts >= 1


@pytest.mark.chaos
def test_shm_put_blocked_by_lane_failed_during_ordering_flush(ctx):
    """A queued op that exhausts retries during the shm put's own
    ordering flush fails the lane — the host write is ordered AFTER
    the hole and must not apply."""
    _require_shm(ctx)
    g = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 64)
    t = g.setunit(1)
    dart_put_blocking(ctx, t, jnp.full((4,), 5.0, jnp.float32))
    poolid, row, _ = _lane_of(ctx, t)
    plane = ctx.attach_faults(seed=0)
    plane.schedule(kind="fail", poolid=poolid, row=row, times=0)
    dart_put(ctx, t, jnp.full((4,), 6.0, jnp.float32))   # queued, doomed
    with pytest.raises(DartError):
        dart_put_blocking(ctx, t, jnp.full((4,), 8.0, jnp.float32))
    assert ctx.engine.clear_lane(poolid, row) is not None
    ctx.engine.attach_faults(None)
    got = rt.dart_get_blocking(ctx, t, (4,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.full(4, 5.0, np.float32))   # 8 never landed


# --------------------------------------- shm-direct collectives -----------

def test_shm_collectives_zero_dispatch_equivalence(ctx):
    """bcast/gather/scatter (+typed) on a default-shm array are served
    shm-direct — ZERO jitted dispatches — and byte-identical to the
    engine collectives on a shm=False oracle."""
    _require_shm(ctx)
    oracle = dart_init(n_units=4, config=DartConfig(
        non_collective_pool_bytes=POOL_BYTES, team_pool_bytes=POOL_BYTES))
    oracle.engine.impl = ctx.engine.impl
    try:
        for dtype in (jnp.float32, jnp.int32, jnp.bfloat16):
            ga_s = ctx.alloc((4,), dtype)
            ga_o = oracle.alloc((4,), dtype, shm=False)
            vals = (jnp.arange(16).reshape(4, 4) + 1).astype(dtype)

            ga_s.scatter(vals)
            ga_o.scatter(vals)
            d0, c0 = ctx.engine.dispatch_count, ctx.engine.shm_collective_ops
            got_s = ga_s.gather()
            assert ctx.engine.dispatch_count == d0     # shm-direct gather
            np.testing.assert_array_equal(np.asarray(got_s),
                                          np.asarray(ga_o.gather()))

            ga_s.broadcast(1).wait()
            ga_o.broadcast(1).wait()
            assert ctx.engine.dispatch_count == d0     # shm-direct bcast
            assert ctx.engine.shm_collective_ops > c0
            np.testing.assert_array_equal(np.asarray(ga_s.gather()),
                                          np.asarray(ga_o.gather()))
    finally:
        dart_exit(oracle)


def test_shm_byte_collectives_equivalence(ctx):
    """The raw byte-plane dart_gather/dart_scatter also route."""
    _require_shm(ctx)
    g = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 64)
    vals = np.arange(4 * 16, dtype=np.uint8).reshape(4, 16)
    d0 = ctx.engine.dispatch_count
    rt.dart_scatter(ctx, g, vals).wait()
    out, h = rt.dart_gather(ctx, g, 16)
    h.wait()
    assert ctx.engine.dispatch_count == d0
    np.testing.assert_array_equal(np.asarray(out), vals)


def test_shm_collectives_ordered_after_queued_puts(ctx):
    """Epoch ordering parity with the engine collectives: queued
    one-sided puts land before the shm-direct collective reads."""
    _require_shm(ctx)
    ga = ctx.alloc((2,), jnp.float32)
    for u in ga.units:
        ga[u].put_nb(jnp.full((2,), float(u)))          # all queued
    gat = np.asarray(ga.gather())                       # shm-direct
    np.testing.assert_array_equal(gat[:, 0], [0.0, 1.0, 2.0, 3.0])


def test_shm_collective_fallback_on_non_writable_pool(ctx):
    """A pool whose arena is not host-writable falls back to the
    engine collective (per-pool fallback) instead of failing."""
    _require_shm(ctx)
    ga = ctx.alloc((4,), jnp.float32)
    poolid, _, _ = _lane_of(ctx, ga.gptr)
    ga[0].put(jnp.ones((4,), jnp.float32))              # settle pool
    # force the cached probe to "readable but not writable"
    ctx._shm_cache[poolid] = (True, False)
    try:
        d0 = ctx.engine.dispatch_count
        ga.broadcast(0).wait()
        assert ctx.engine.dispatch_count > d0           # engine path
    finally:
        invalidate_shm_cache(ctx, poolid)
    np.testing.assert_array_equal(np.asarray(ga.gather()),
                                  np.ones((4, 4), np.float32))


# --------------------------------------------------- live windows ---------

def test_view_is_live_window_across_shm_puts(ctx):
    _require_shm(ctx)
    g = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 64)
    dart_put_blocking(ctx, g, jnp.zeros((4,), jnp.float32))
    view = dart_shm_view(ctx, g, (4,), jnp.float32)
    assert not view.flags.writeable
    dart_put_blocking(ctx, g, jnp.full((4,), 3.0, jnp.float32))
    np.testing.assert_array_equal(np.asarray(view),
                                  np.full(4, 3.0, np.float32))


def test_shm_put_threaded_with_progress_daemon(ctx):
    """Thread-safety: concurrent shm puts + queued engine traffic +
    the background drain loop; every unit's block must end at one of
    the two writers' final values with no torn bytes."""
    _require_shm(ctx)
    ctx.start_progress(watermark_ops=2, idle_s=0.001)
    ga = ctx.alloc((16,), jnp.int32)
    stop = threading.Event()
    errors = []

    def writer(base):
        try:
            i = 0
            while not stop.is_set():
                u = i % 4
                ga[u].put(jnp.full((16,), base + i, jnp.int32))
                ga[u].put_nb(jnp.full((16,), base + i, jnp.int32))
                i += 1
        except Exception as e:    # noqa: BLE001 - surfaced below
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(b,))
               for b in (1_000, 2_000_000)]
    for t in threads:
        t.start()
    import time
    time.sleep(0.3)
    stop.set()
    for t in threads:
        t.join()
    assert not errors
    rows = np.asarray(ga.gather())
    for r in rows:
        assert len(set(r.tolist())) == 1    # no torn block
