"""Blocked (flash-style) causal GQA vs the dense reference."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.models.config import reduced_for_smoke
from repro.models.layers import (blocked_causal_gqa, causal_mask,
                                 gqa_scores_and_mix)


def _rand(shape, seed, dtype=jnp.float32):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(*shape), dtype)


@pytest.mark.parametrize("s,block", [(16, 4), (32, 8), (64, 64), (24, 8)])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
def test_blocked_matches_dense(s, block, hq, hkv):
    b, hd = 2, 16
    q = _rand((b, s, hq, hd), 0)
    k = _rand((b, s, hkv, hd), 1)
    v = _rand((b, s, hkv, hd), 2)
    dense = gqa_scores_and_mix(q, k, v, causal_mask(s, s, 0))
    blocked = blocked_causal_gqa(q, k, v, block)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_blocked_with_softcap():
    b, s, hq, hkv, hd = 1, 32, 4, 2, 8
    q = _rand((b, s, hq, hd), 3)
    k = _rand((b, s, hkv, hd), 4)
    v = _rand((b, s, hkv, hd), 5)
    dense = gqa_scores_and_mix(q, k, v, causal_mask(s, s, 0), softcap=30.0)
    blocked = blocked_causal_gqa(q, k, v, 8, softcap=30.0)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_full_model_same_logits_with_blocked_attention():
    cfg = reduced_for_smoke(get_config("llama3-8b"))
    cfg_b = dataclasses.replace(cfg, attn_block=8)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    l0, _ = api.forward_train(cfg, params, batch)
    l1, _ = api.forward_train(cfg_b, params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l0),
                               rtol=5e-4, atol=5e-4)


def test_blocked_gradients_match():
    b, s, hq, hkv, hd = 1, 16, 4, 2, 8
    q = _rand((b, s, hq, hd), 6)
    k = _rand((b, s, hkv, hd), 7)
    v = _rand((b, s, hkv, hd), 8)

    def f_dense(q, k, v):
        return gqa_scores_and_mix(q, k, v, causal_mask(s, s, 0)).sum()

    def f_block(q, k, v):
        return blocked_causal_gqa(q, k, v, 4).sum()

    gd = jax.grad(f_dense, argnums=(0, 1, 2))(q, k, v)
    gb = jax.grad(f_block, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gd, gb):
        np.testing.assert_allclose(np.asarray(b_), np.asarray(a),
                                   rtol=1e-4, atol=1e-4)
