"""Fault plane tests: typed error ladder, retry/deadline semantics,
unit-death degradation, drain gating, and the seeded chaos harness.

The chaos tests (``-m chaos``) replay a seeded random op schedule with
injected faults against a fault-free oracle context and assert the
survivable-fault contract: surviving lanes' final arenas are
byte-identical to the oracle, and every failed handle raises a typed
:class:`~repro.core.faults.DartError` subclass.  Both engine impls run
via the shared ``engine_impl`` fixture.
"""

import random
import time

import numpy as np
import pytest

from repro.core import (DartConfig, DartError, FaultPlane, FaultSpec,
                        FlushTimeoutError, RetriesExhaustedError,
                        TransientDispatchFault, UnitFailedError,
                        WindowDestroyedError, dart_accumulate, dart_exit,
                        dart_get, dart_get_blocking, dart_init,
                        dart_memalloc, dart_put, dart_team_create,
                        dart_team_destroy, dart_waitall)
from repro.core.group import DartGroup
from repro.ft.elastic import (ClusterState, HeartbeatMonitor,
                              StragglerTracker, plan_remesh, units_of_host)

N_UNITS = 4
WORLD = 0                        # WORLD poolid


@pytest.fixture()
def ctx(engine_impl):
    c = dart_init(n_units=N_UNITS, config=DartConfig(
        non_collective_pool_bytes=8192, team_pool_bytes=8192))
    c.engine.impl = engine_impl
    yield c
    dart_exit(c)


def _plane(ctx, **kw):
    return ctx.attach_faults(seed=kw.pop("seed", 0), **kw)


# ------------------------------------------------------- error ladder ----

def test_error_ladder_parentage():
    for cls in (UnitFailedError, FlushTimeoutError, RetriesExhaustedError,
                TransientDispatchFault):
        assert issubclass(cls, DartError)
        assert issubclass(cls, RuntimeError)
    assert issubclass(WindowDestroyedError, DartError)
    assert issubclass(WindowDestroyedError, KeyError)
    e = DartError("x")
    assert e.poolid is None and e.unit is None and e.teamid is None


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(kind="explode")
    with pytest.raises(ValueError, match="fail_rate"):
        FaultPlane(fail_rate=1.5)
    plane = FaultPlane(seed=3)
    with pytest.raises(TypeError, match="not both"):
        plane.schedule(FaultSpec(kind="fail"), poolid=0)


# ------------------------------------------------- retry semantics -------

def test_transient_fault_retries_and_recovers(ctx):
    plane = _plane(ctx)
    plane.schedule(kind="fail", poolid=WORLD, row=1, times=2)
    g = dart_memalloc(ctx, 256, unit=1)
    h = dart_put(ctx, g, np.arange(16, dtype=np.uint8))
    ctx.engine.flush()
    h.wait()                                 # recovered, not failed
    out = dart_get_blocking(ctx, g, (16,), np.uint8)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.arange(16, dtype=np.uint8))
    fs = ctx.engine.fault_stats()
    assert fs["retries"] == 2
    assert fs["failed_runs"] == 0
    assert fs["injector"]["injected_fails"] == 2


def test_drop_fault_is_retried_like_pre_fail(ctx):
    plane = _plane(ctx)
    plane.schedule(kind="drop", poolid=WORLD, row=2, times=1)
    g = dart_memalloc(ctx, 128, unit=2)
    h = dart_put(ctx, g, np.full(8, 7, np.uint8))
    h.wait()
    assert ctx.engine.fault_stats()["injector"]["injected_drops"] == 1
    np.testing.assert_array_equal(
        np.asarray(dart_get_blocking(ctx, g, (8,), np.uint8)), 7)


def test_delay_fault_counts_and_completes(ctx):
    plane = _plane(ctx)
    plane.schedule(kind="delay", poolid=WORLD, row=0, delay_s=0.001,
                   times=1)
    g = dart_memalloc(ctx, 128, unit=0)
    dart_put(ctx, g, np.full(8, 9, np.uint8)).wait()
    assert ctx.engine.fault_stats()["injector"]["injected_delays"] == 1


def test_retries_exhausted_typed_and_lane_fails_fast(ctx):
    plane = _plane(ctx)
    plane.schedule(kind="fail", poolid=WORLD, row=2, times=0)  # unlimited
    g = dart_memalloc(ctx, 128, unit=2)
    h = dart_put(ctx, g, np.arange(8, dtype=np.uint8))
    ctx.engine.flush()                       # flush itself never raises
    with pytest.raises(RetriesExhaustedError) as ei:
        h.wait()
    assert ei.value.poolid == WORLD and ei.value.row == 2
    assert isinstance(ei.value, RuntimeError)
    assert h.state == "failed"
    with pytest.raises(RetriesExhaustedError):
        h.test()                             # test() propagates too
    # the lane is failed: enqueues fail fast until cleared
    with pytest.raises(RetriesExhaustedError):
        dart_put(ctx, g, np.arange(8, dtype=np.uint8))
    assert ctx.engine.fault_stats()["enqueue_rejections"] == 1
    # clear the lane, clear the (still-firing) spec: lane usable again
    err = ctx.engine.clear_lane(WORLD, 2)
    assert isinstance(err, RetriesExhaustedError)
    plane.specs.clear()
    dart_put(ctx, g, np.full(8, 5, np.uint8)).wait()
    np.testing.assert_array_equal(
        np.asarray(dart_get_blocking(ctx, g, (8,), np.uint8)), 5)


def test_flush_deadline_typed_timeout(ctx):
    ctx.engine.flush_deadline_s = 1e-4
    ctx.engine.retry_limit = 1_000_000       # deadline must bind first
    plane = _plane(ctx)
    plane.schedule(kind="fail", poolid=WORLD, row=1, times=0)
    g = dart_memalloc(ctx, 128, unit=1)
    h = dart_put(ctx, g, np.arange(8, dtype=np.uint8))
    ctx.engine.flush()
    with pytest.raises(FlushTimeoutError) as ei:
        h.wait()
    assert ei.value.poolid == WORLD and ei.value.row == 1
    assert ctx.engine.fault_stats()["flush_timeouts"] == 1


def test_put_post_dispatch_fault_is_idempotently_retried(ctx):
    plane = _plane(ctx)
    plane.schedule(kind="fail", poolid=WORLD, row=1, times=1,
                   issued=True)              # strikes AFTER the kernel
    g = dart_memalloc(ctx, 128, unit=1)
    h = dart_put(ctx, g, np.arange(16, dtype=np.uint8))
    h.wait()
    np.testing.assert_array_equal(
        np.asarray(dart_get_blocking(ctx, g, (16,), np.uint8)),
        np.arange(16, dtype=np.uint8))
    assert ctx.engine.fault_stats()["retries"] == 1


def test_accumulate_post_fault_at_most_once(ctx):
    """A post-dispatch fault on an accumulate run aborts instead of
    retrying, and the differential assertion: the target holds exactly
    ONE application of the op (the faulted attempt's kernel ran)."""
    g = dart_memalloc(ctx, 128, unit=1)
    dart_put(ctx, g, np.full(8, 10, np.int32)).wait()
    plane = _plane(ctx)
    plane.schedule(kind="fail", poolid=WORLD, row=1, times=1,
                   issued=True, op_kind="acc")
    h = dart_accumulate(ctx, g, np.full(8, 3, np.int32))
    ctx.engine.flush()
    with pytest.raises(DartError, match="at-most-once"):
        h.wait()
    fs = ctx.engine.fault_stats()
    assert fs["at_most_once_aborts"] == 1
    assert fs["retries"] == 0                # never re-issued
    ctx.engine.clear_lane(WORLD, 1)
    out = np.asarray(dart_get_blocking(ctx, g, (8,), np.int32))
    np.testing.assert_array_equal(out, 13)   # applied exactly once


def test_accumulate_pre_fault_retries(ctx):
    """A pre-dispatch accumulate fault provably never issued: retrying
    is safe and the result is exactly one application."""
    g = dart_memalloc(ctx, 128, unit=2)
    dart_put(ctx, g, np.full(8, 1, np.int32)).wait()
    plane = _plane(ctx)
    plane.schedule(kind="fail", poolid=WORLD, row=2, times=2,
                   op_kind="acc")
    h = dart_accumulate(ctx, g, np.full(8, 5, np.int32))
    h.wait()
    assert ctx.engine.fault_stats()["retries"] == 2
    np.testing.assert_array_equal(
        np.asarray(dart_get_blocking(ctx, g, (8,), np.int32)), 6)


def test_failed_run_fails_later_ops_on_lane_program_order(ctx):
    """Op N failing must doom op N+1 on the same lane within the same
    flush (the later write must not apply past the hole), while other
    pools' runs in the same flush dispatch normally.  (The innocent op
    lives in a different pool: WORLD-pool runs can legitimately span
    rows, and a run shares its dispatch's fate.)"""
    from repro.core import dart_team_memalloc_aligned
    plane = _plane(ctx)
    plane.schedule(kind="fail", poolid=WORLD, row=1, times=0)
    g1 = dart_memalloc(ctx, 256, unit=1)
    gt = dart_team_memalloc_aligned(ctx, 0, 256).setunit(3)
    h_a = dart_put(ctx, g1, np.full(16, 1, np.uint8))
    # overlapping second put splits the run → two runs on lane (0, 1)
    h_b = dart_put(ctx, g1 + 8, np.full(16, 2, np.uint8))
    h_c = dart_put(ctx, gt, np.full(16, 3, np.uint8))
    ctx.engine.flush()
    with pytest.raises(RetriesExhaustedError):
        h_a.wait()
    with pytest.raises(DartError):
        h_b.wait()
    h_c.wait()                               # other pool unaffected
    np.testing.assert_array_equal(
        np.asarray(dart_get_blocking(ctx, gt, (16,), np.uint8)), 3)


def test_dart_waitall_propagates_typed_error(ctx):
    plane = _plane(ctx)
    plane.schedule(kind="fail", poolid=WORLD, row=2, times=0)
    g_ok = dart_memalloc(ctx, 128, unit=0)
    g_bad = dart_memalloc(ctx, 128, unit=2)
    hs = [dart_put(ctx, g_ok, np.full(8, 1, np.uint8)),
          dart_put(ctx, g_bad, np.full(8, 2, np.uint8))]
    ctx.engine.flush()
    with pytest.raises(RetriesExhaustedError):
        dart_waitall(hs)


# ------------------------------------------- enqueue-boundary faults -----

def test_poison_spec_fails_lane_at_enqueue(ctx):
    plane = _plane(ctx)
    plane.schedule(kind="poison", poolid=WORLD, row=1, after=1)
    g = dart_memalloc(ctx, 128, unit=1)
    dart_put(ctx, g, np.full(8, 4, np.uint8)).wait()   # op 1 passes
    with pytest.raises(DartError, match="poisoned"):
        dart_put(ctx, g, np.full(8, 5, np.uint8))
    assert plane.stats()["poisons"] == 1
    err = ctx.engine.clear_lane(WORLD, 1)
    assert err is not None
    dart_put(ctx, g, np.full(8, 6, np.uint8)).wait()


def test_unit_dead_spec_at_op_n(ctx):
    """'unit dies at op N': the first N enqueues to the unit succeed,
    the N+1st (and everything after) fails with UnitFailedError."""
    plane = _plane(ctx)
    plane.schedule(kind="unit_dead", unit=3, after=2)
    g = dart_memalloc(ctx, 256, unit=3)
    h1 = dart_put(ctx, g, np.full(8, 1, np.uint8))
    h2 = dart_put(ctx, g + 64, np.full(8, 2, np.uint8))
    with pytest.raises(UnitFailedError) as ei:
        dart_put(ctx, g + 128, np.full(8, 3, np.uint8))
    assert ei.value.unit == 3
    # death also doomed the two queued ops on the dead unit's lanes
    for h in (h1, h2):
        with pytest.raises(UnitFailedError):
            h.wait()
    assert 3 in ctx.engine.dead_units


def test_mark_unit_dead_dooms_queued_ops_and_spares_survivors(ctx):
    g1 = dart_memalloc(ctx, 128, unit=1)
    g2 = dart_memalloc(ctx, 128, unit=2)
    h_dead = dart_put(ctx, g2, np.full(8, 9, np.uint8))
    h_live = dart_put(ctx, g1, np.full(8, 8, np.uint8))
    doomed = ctx.engine.mark_unit_dead(2, reason="test kill")
    assert doomed == 1
    with pytest.raises(UnitFailedError, match="declared dead"):
        h_dead.wait()
    h_live.wait()                            # surviving lane flushes
    np.testing.assert_array_equal(
        np.asarray(dart_get_blocking(ctx, g1, (8,), np.uint8)), 8)
    # fail-fast on new enqueues, idempotent re-kill, then revive
    with pytest.raises(UnitFailedError):
        dart_put(ctx, g2, np.full(8, 1, np.uint8))
    assert ctx.engine.mark_unit_dead(2) == 0
    ctx.engine.revive_unit(2)
    dart_put(ctx, g2, np.full(8, 7, np.uint8)).wait()


def test_get_on_dead_unit_rejected(ctx):
    g = dart_memalloc(ctx, 128, unit=1)
    ctx.engine.mark_unit_dead(1)
    with pytest.raises(UnitFailedError):
        dart_get(ctx, g, (8,), np.uint8)


# ------------------------------------------------- window destruction ----

def test_team_destroy_raises_typed_window_error(ctx):
    from repro.core import dart_team_memalloc_aligned
    tid = dart_team_create(ctx, 0, DartGroup((0, 1)))
    gt = dart_team_memalloc_aligned(ctx, tid, 256)
    h = dart_put(ctx, gt, np.full(8, 1, np.uint8))
    poolid = h.poolid
    dart_team_destroy(ctx, tid)
    with pytest.raises(WindowDestroyedError) as ei:
        h.wait()
    assert ei.value.teamid == tid and ei.value.poolid == poolid
    assert isinstance(ei.value, KeyError)
    assert isinstance(ei.value, RuntimeError)
    assert "window destroyed" in str(ei.value)


# ------------------------------------------------- progress drain gate ---

def test_progress_drain_gate_skips_background_drain(ctx):
    plane = _plane(ctx)
    plane.schedule(kind="skip_drain", poolid=WORLD, row=1, times=0)
    pp = ctx.start_progress(watermark_ops=1, idle_s=0.001)
    g = dart_memalloc(ctx, 128, unit=1)
    h = dart_put(ctx, g, np.full(8, 3, np.uint8))
    deadline = time.monotonic() + 2.0
    while pp.drains_skipped == 0:
        assert time.monotonic() < deadline, "drain gate never consulted"
        time.sleep(0.002)
    assert h.state == "queued"               # stranded by the gate
    h.wait()                                 # foreground flush ignores it
    assert h.state == "complete"
    ctx.stop_progress()


# ---------------------------------------------------- heartbeat wiring ---

class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_sweep_boundary_exactly_threshold():
    clk = _FakeClock()
    cluster = ClusterState(n_hosts=2, devices_per_host=1)
    mon = HeartbeatMonitor(cluster, interval_s=1.0, miss_threshold=3,
                           clock=clk)
    clk.t = 3.0 - 1e-9                       # just under: alive
    assert mon.sweep() == []
    clk.t = 3.0                              # exactly threshold: dead
    assert mon.sweep() == [0, 1]
    assert mon.sweep() == []                 # only *newly* dead reported


def test_sweep_failures_marks_units_dead(ctx):
    clk = _FakeClock()
    cluster = ClusterState(n_hosts=2, devices_per_host=2)
    mon = HeartbeatMonitor(cluster, interval_s=1.0, miss_threshold=2,
                           clock=clk)
    ctx.attach_heartbeat_monitor(mon, devices_per_host=2)
    assert ctx.sweep_failures() == []
    clk.t = 10.0
    mon.beat(0)                              # host 0 stays alive
    assert ctx.sweep_failures() == [2, 3]    # host 1 = units 2, 3
    g = dart_memalloc(ctx, 128, unit=2)
    with pytest.raises(UnitFailedError, match="unit 2 is dead"):
        dart_put(ctx, g, np.full(8, 1, np.uint8))
    # surviving unit unaffected
    g0 = dart_memalloc(ctx, 128, unit=0)
    dart_put(ctx, g0, np.full(8, 2, np.uint8)).wait()


def test_units_of_host():
    assert units_of_host(0, 4) == (0, 1, 2, 3)
    assert units_of_host(2, 4) == (8, 9, 10, 11)
    assert units_of_host(3, 1) == (3,)


# --------------------------------------------------- elastic satellites --

def test_plan_remesh_zero_survivors():
    cluster = ClusterState(n_hosts=2, devices_per_host=4)
    for h in range(2):
        cluster.alive[h] = False
    with pytest.raises(RuntimeError, match="not enough devices"):
        plan_remesh(cluster, model_parallel=4)


def test_plan_remesh_survivors_below_model_parallel():
    cluster = ClusterState(n_hosts=4, devices_per_host=2)
    for h in (1, 2, 3):
        cluster.alive[h] = False             # 2 devices < model=4
    with pytest.raises(RuntimeError, match="model_parallel=4"):
        plan_remesh(cluster, model_parallel=4)


def test_straggler_rebalance_single_alive_host():
    tr = StragglerTracker(n_hosts=3)
    tr.record(0, 1.0)                        # only host 0 ever reports
    assert tr.stragglers() == []             # no peers to be slower than
    plan = tr.rebalance_plan({0: 4})
    assert plan == {0: 4}                    # nothing to shift, no crash


# --------------------------------------------------------- chaos ---------

ACC_DTYPE = np.int32
SLOT_ELEMS = 16                              # int32 per slot (64 B)
SLOTS = 3


class _Mirror:
    """One context's view of the chaos schedule's allocations."""

    def __init__(self, ctx):
        self.ctx = ctx
        self.gptrs = {u: dart_memalloc(ctx, SLOTS * SLOT_ELEMS * 4, u)
                      for u in range(N_UNITS)}

    def slot(self, u, s):
        return self.gptrs[u] + s * SLOT_ELEMS * 4


def _chaos_schedule(rng, n_ops):
    """Seeded op schedule: (kind, unit, slot, payload-seed) tuples plus
    flush points."""
    ops = []
    for i in range(n_ops):
        kind = rng.choice(["put", "put", "acc", "get"])
        ops.append((kind, rng.randrange(N_UNITS), rng.randrange(SLOTS),
                    rng.randrange(1, 100)))
        if rng.random() < 0.15:
            ops.append(("flush", None, None, None))
    ops.append(("flush", None, None, None))
    return ops


def _run_schedule(ctx, mirror, ops, accept=None):
    """Apply the schedule; returns (handles, accepted-op index set).
    ``accept`` (oracle replay) restricts to the subject's accepted ops
    so both sides applied the identical op sequence."""
    handles, accepted = [], set()
    for i, (kind, u, s, seed) in enumerate(ops):
        if kind == "flush":
            ctx.engine.flush()
            continue
        if accept is not None and i not in accept:
            continue
        val = (np.arange(SLOT_ELEMS, dtype=ACC_DTYPE) * seed) % 251
        try:
            if kind == "put":
                handles.append(dart_put(ctx, mirror.slot(u, s), val))
            elif kind == "acc":
                handles.append(dart_accumulate(ctx, mirror.slot(u, s),
                                               val))
            else:
                handles.append(ctx.engine.get(
                    ctx.heap, ctx.teams_by_slot, mirror.slot(u, s),
                    (SLOT_ELEMS,), ACC_DTYPE))
        except DartError:
            continue                         # enqueue rejected (subject)
        accepted.add(i)
    ctx.engine.flush()
    return handles, accepted


def _assert_differential(subject, oracle, handles):
    """The survivable-fault contract: every failed handle raises a
    typed DartError, and every surviving lane is byte-identical to the
    fault-free oracle."""
    n_failed = 0
    for h in handles:
        if h.state == "failed":
            n_failed += 1
            with pytest.raises(DartError):
                h.wait()
    dead = subject.ctx.engine.dead_units
    failed_rows = {row for (pid, row) in subject.ctx.engine.failed_lanes
                   if pid == WORLD}
    surviving = [u for u in range(N_UNITS)
                 if u not in dead and u not in failed_rows]
    assert surviving, "chaos schedule killed every lane"
    for u in surviving:
        got = np.asarray(dart_get_blocking(
            subject.ctx, subject.gptrs[u],
            (SLOTS * SLOT_ELEMS,), ACC_DTYPE))
        want = np.asarray(dart_get_blocking(
            oracle.ctx, oracle.gptrs[u],
            (SLOTS * SLOT_ELEMS,), ACC_DTYPE))
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"lane (0, {u}) diverged")
    return n_failed


@pytest.mark.chaos
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_differential_vs_oracle(engine_impl, seed):
    """Randomized fault schedules vs the fault-free oracle: transient
    faults (absorbed by retry), a mid-schedule unit death, and a lane
    poisoning — surviving lanes must match the oracle byte-for-byte."""
    cfg = DartConfig(non_collective_pool_bytes=8192,
                     team_pool_bytes=8192)
    subj_ctx = dart_init(n_units=N_UNITS, config=cfg)
    orac_ctx = dart_init(n_units=N_UNITS, config=cfg)
    subj_ctx.engine.impl = orac_ctx.engine.impl = engine_impl
    try:
        rng = random.Random(1000 + seed)
        plane = subj_ctx.attach_faults(seed=seed)
        # recoverable transients on two lanes
        plane.schedule(kind="fail", poolid=WORLD, row=rng.randrange(2),
                       times=rng.randrange(1, 3))
        plane.schedule(kind="delay", poolid=WORLD, row=1,
                       delay_s=0.0005, times=2)
        # unit 3 dies mid-schedule; lane (0, 2) poisoned later
        plane.schedule(kind="unit_dead", unit=3,
                       after=rng.randrange(2, 6))
        plane.schedule(kind="poison", poolid=WORLD, row=2,
                       after=rng.randrange(4, 10))

        subject, oracle = _Mirror(subj_ctx), _Mirror(orac_ctx)
        ops = _chaos_schedule(rng, n_ops=40)
        handles, accepted = _run_schedule(subj_ctx, subject, ops)
        _run_schedule(orac_ctx, oracle, ops, accept=accepted)
        _assert_differential(subject, oracle, handles)
        fs = subj_ctx.engine.fault_stats()
        assert fs["retries"] <= subj_ctx.engine.retry_limit * max(
            1, fs["injector"]["specs_fired"])       # retries bounded
    finally:
        dart_exit(subj_ctx)
        dart_exit(orac_ctx)


@pytest.mark.chaos
def test_chaos_rate_driven_faults_all_absorbed(engine_impl):
    """Pure rate-driven transients well under the retry budget: every
    handle completes and the arenas match the oracle exactly (the
    retry loop is invisible to callers)."""
    cfg = DartConfig(non_collective_pool_bytes=8192,
                     team_pool_bytes=8192)
    subj_ctx = dart_init(n_units=N_UNITS, config=cfg)
    orac_ctx = dart_init(n_units=N_UNITS, config=cfg)
    subj_ctx.engine.impl = orac_ctx.engine.impl = engine_impl
    subj_ctx.engine.retry_limit = 8          # 0.15^9 ≈ never exhausts
    subj_ctx.engine.retry_base_s = 1e-5
    try:
        subj_ctx.attach_faults(seed=42, fail_rate=0.15)
        subject, oracle = _Mirror(subj_ctx), _Mirror(orac_ctx)
        rng = random.Random(77)
        # puts/gets only: rate faults can strike post-acc (at-most-once
        # aborts are scheduled-fault territory, asserted separately)
        ops = [op for op in _chaos_schedule(rng, n_ops=30)
               if op[0] != "acc"]
        handles, accepted = _run_schedule(subj_ctx, subject, ops)
        _run_schedule(orac_ctx, oracle, ops, accept=accepted)
        n_failed = _assert_differential(subject, oracle, handles)
        assert n_failed == 0
        assert not subj_ctx.engine.failed_lanes
        assert subj_ctx.engine.fault_stats()["retries"] > 0
    finally:
        dart_exit(subj_ctx)
        dart_exit(orac_ctx)
