"""MCS queuing-lock tests (paper §IV.B.6, Fig. 6)."""

import threading
import time

import pytest

from repro.core import (FREE, DartLock, LockService, Team, ThreadedAtomics,
                        group_from_units)


def make_service(n=8, placement="unit0"):
    atomics = ThreadedAtomics(n)
    service = LockService(atomics, tail_placement=placement)
    team = Team(teamid=0, group=group_from_units(range(n)), slot=0)
    return atomics, service, team


def test_uncontended_acquire_release():
    _, svc, team = make_service(4)
    lock = svc.create_lock(team)
    svc.acquire(lock, 2)
    assert not svc.try_acquire(lock, 3)      # held -> try fails
    svc.release(lock, 2)
    assert svc.try_acquire(lock, 3)          # free -> try succeeds
    svc.release(lock, 3)
    assert lock.is_free_hint(svc.atomics)


def test_mutual_exclusion_under_contention():
    n = 8
    _, svc, team = make_service(n)
    lock = svc.create_lock(team)
    counter = {"v": 0, "in_cs": 0, "max_in_cs": 0}
    iters = 50

    def worker(u):
        for _ in range(iters):
            svc.acquire(lock, u)
            counter["in_cs"] += 1
            counter["max_in_cs"] = max(counter["max_in_cs"],
                                       counter["in_cs"])
            v = counter["v"]
            counter["v"] = v + 1             # non-atomic unless excluded
            counter["in_cs"] -= 1
            svc.release(lock, u)

    threads = [threading.Thread(target=worker, args=(u,)) for u in range(n)]
    for t in threads: t.start()
    for t in threads: t.join()
    assert counter["v"] == n * iters         # no lost updates
    assert counter["max_in_cs"] == 1         # never two units in the CS


def test_fifo_ordering():
    """MCS guarantees FIFO ordering of lock acquisition (paper §IV.B.6)."""
    n = 6
    _, svc, team = make_service(n)
    lock = svc.create_lock(team)
    order = []
    svc.acquire(lock, 0)                     # hold so others queue up
    started = []

    def worker(u):
        started.append(u)
        svc.acquire(lock, u)
        order.append(u)
        time.sleep(0.001)
        svc.release(lock, u)

    threads = []
    for u in range(1, n):                    # start in deterministic order
        t = threading.Thread(target=worker, args=(u,))
        t.start()
        while u not in started:
            time.sleep(0.0005)
        time.sleep(0.005)                    # let u reach fetch_and_store
        threads.append(t)
    svc.release(lock, 0)
    for t in threads: t.join()
    assert order == list(range(1, n))        # strict FIFO


def test_multiple_locks_per_team():
    _, svc, team = make_service(4)
    l1, l2 = svc.create_lock(team), svc.create_lock(team)
    svc.acquire(l1, 0)
    svc.acquire(l2, 1)                        # independent locks don't block
    svc.release(l1, 0)
    svc.release(l2, 1)


def test_tail_placement_unit0_vs_round_robin():
    """Beyond-paper §VI: balanced tails spread atomic traffic."""
    at0, svc0, team = make_service(4, placement="unit0")
    locks0 = [svc0.create_lock(team) for _ in range(8)]
    assert all(l.tail.home_unit == 0 for l in locks0)   # paper behaviour

    at1, svc1, team1 = make_service(4, placement="round_robin")
    locks1 = [svc1.create_lock(team1) for _ in range(8)]
    homes = [l.tail.home_unit for l in locks1]
    assert sorted(set(homes)) == [0, 1, 2, 3]           # spread out
    # traffic accounting: bang on all locks, unit0 placement concentrates
    for svc, locks, at in ((svc0, locks0, at0), (svc1, locks1, at1)):
        for i, l in enumerate(locks):
            svc.acquire(l, i % 4)
            svc.release(l, i % 4)
    tail_traffic0 = at0.home_traffic[0]
    tail_traffic1 = max(at1.home_traffic.values())
    assert tail_traffic0 > tail_traffic1     # congestion reduced


def test_non_member_acquire_raises():
    _, svc, _ = make_service(4)
    team = Team(teamid=1, group=group_from_units([0, 1]), slot=1)
    lock = svc.create_lock(team)
    with pytest.raises(KeyError):
        svc.acquire(lock, 3)


def test_release_timeout_on_unregistered_successor():
    """A successor that swapped the tail but never registers (died
    between fetch_and_store and the next-cell store) must not spin the
    releaser forever: with ``timeout`` the release raises instead."""
    atomics, svc, team = make_service(4)
    lock = svc.create_lock(team)
    svc.acquire(lock, 0)
    # fake a vanished successor: tail no longer == 0, next cell stays FREE
    atomics.fetch_and_store(lock.tail, 3)
    t0 = time.monotonic()
    with pytest.raises(TimeoutError, match="never registered"):
        svc.release(lock, 0, timeout=0.05)
    assert time.monotonic() - t0 < 2.0       # bounded, not a busy hang


def test_release_backoff_hands_off():
    """The backoff path (successor registers late) still hands off
    correctly — the exponential sleep must poll until the registration
    lands, not give up or miss the notify."""
    atomics, svc, team = make_service(4)
    lock = svc.create_lock(team)
    svc.acquire(lock, 0)
    got = []

    def late_successor():
        svc.acquire(lock, 1)                 # queues behind 0
        got.append(1)
        svc.release(lock, 1)

    t = threading.Thread(target=late_successor)
    t.start()
    while atomics.load(lock.tail) != 1:      # wait for the tail swap
        time.sleep(0.0005)
    svc.release(lock, 0, timeout=10)         # backoff until registered
    t.join(timeout=10)
    assert got == [1]
    assert lock.is_free_hint(atomics)


def test_destroy_lock_frees_cells():
    """destroy_lock returns the tail + per-member next cells to the
    provider (they used to leak: only the registry entry was dropped)."""
    atomics, svc, team = make_service(4)
    lock = svc.create_lock(team)
    names = [lock.tail.name] + [c.name for c in lock.next_cells.values()]
    assert all(n in atomics._cells for n in names)
    svc.destroy_lock(lock)
    assert all(n not in atomics._cells for n in names)
    # the name space is reusable — a leaked cell would collide here
    atomics.make_cell(names[0], 0, FREE)
