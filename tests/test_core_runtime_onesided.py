"""Integration tests: runtime context + one-sided ops (paper §IV.B.3-5)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (DART_TEAM_ALL, DartConfig, GlobalPtr, dart_exit,
                        dart_get, dart_get_blocking, dart_init,
                        dart_memalloc, dart_memfree, dart_put,
                        dart_put_blocking, dart_team_create,
                        dart_team_destroy, dart_team_memalloc_aligned,
                        dart_team_myid, dart_team_size, dart_testall,
                        dart_waitall, group_from_units)
from repro.core import dart_allreduce, dart_barrier, dart_bcast


@pytest.fixture()
def ctx():
    c = dart_init(n_units=4, config=DartConfig(
        non_collective_pool_bytes=4096, team_pool_bytes=4096))
    yield c
    dart_exit(c)


def test_init_creates_team_all(ctx):
    assert dart_team_size(ctx, DART_TEAM_ALL) == 4
    assert dart_team_myid(ctx, DART_TEAM_ALL, 2) == 2


def test_noncollective_put_get_roundtrip(ctx):
    g = dart_memalloc(ctx, 256, unit=2)
    assert not g.is_collective and g.unitid == 2
    val = jnp.arange(16, dtype=jnp.float32)
    dart_put_blocking(ctx, g, val)
    out = dart_get_blocking(ctx, g, (16,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(val))


def test_noncollective_isolation_between_units(ctx):
    """Same offset on different units are distinct locations (Fig. 4)."""
    g0 = dart_memalloc(ctx, 64, unit=0)
    g3 = dart_memalloc(ctx, 64, unit=3)
    assert g0.addr == g3.addr == 0
    dart_put_blocking(ctx, g0, jnp.full((16,), 7, jnp.int32))
    dart_put_blocking(ctx, g3, jnp.full((16,), 9, jnp.int32))
    assert np.asarray(dart_get_blocking(ctx, g0, (16,), jnp.int32))[0] == 7
    assert np.asarray(dart_get_blocking(ctx, g3, (16,), jnp.int32))[0] == 9


def test_collective_alloc_aligned_symmetric(ctx):
    """Any member can address any member's portion at the same offset."""
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 128)
    assert g.is_collective
    for u in range(4):
        dart_put_blocking(ctx, g.setunit(u),
                          jnp.full((8,), u, jnp.float32))
    for u in range(4):
        out = dart_get_blocking(ctx, g.setunit(u), (8,), jnp.float32)
        assert np.all(np.asarray(out) == u)


def test_collective_second_alloc_offset_identical(ctx):
    g1 = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 128)
    g2 = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 128)
    assert g2.addr == g1.addr + 128     # shared cursor: same offset for all


def test_subteam_translation_and_pools(ctx):
    sub = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([1, 3]))
    assert dart_team_size(ctx, sub) == 2
    assert dart_team_myid(ctx, sub, 3) == 1      # abs -> rel translation
    g = dart_team_memalloc_aligned(ctx, sub, 64)
    dart_put_blocking(ctx, g.setunit(3), jnp.arange(4, dtype=jnp.int32))
    out = dart_get_blocking(ctx, g.setunit(3), (4,), jnp.int32)
    np.testing.assert_array_equal(np.asarray(out), [0, 1, 2, 3])
    with pytest.raises(KeyError):
        # unit 0 is not a member of the sub-team
        dart_get_blocking(ctx, g.setunit(0), (4,), jnp.int32)
    dart_team_destroy(ctx, sub)


def test_team_destroy_recycles_slot(ctx):
    """Paper §IV.B.2: teamlist slots are reused after destroy."""
    t1 = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([0, 1]))
    slot1 = ctx.teams[t1].slot
    dart_team_destroy(ctx, t1)
    t2 = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([2, 3]))
    assert ctx.teams[t2].slot == slot1
    assert t2 != t1                      # teamIDs themselves never reused


def test_nonblocking_put_get_handles(ctx):
    g = dart_memalloc(ctx, 1024, unit=1)
    hs = []
    for k in range(4):
        hs.append(dart_put(ctx, g + 128 * k,
                           jnp.full((32,), k, jnp.float32)))
    dart_waitall(hs)
    vals = []
    gets = []
    for k in range(4):
        v, h = dart_get(ctx, g + 128 * k, (32,), jnp.float32)
        vals.append(v); gets.append(h)
    dart_waitall(gets)
    assert dart_testall(gets)
    for k, v in enumerate(vals):
        assert np.all(np.asarray(v) == k)


def test_put_get_bounds_checked(ctx):
    g = dart_memalloc(ctx, 128, unit=0)
    near_end = GlobalPtr(unitid=0, segid=g.segid, flags=g.flags,
                         addr=ctx.config.non_collective_pool_bytes - 4)
    with pytest.raises(ValueError):
        dart_put_blocking(ctx, near_end, jnp.zeros(16, jnp.float32))
    with pytest.raises(ValueError):
        dart_get_blocking(ctx, near_end, (16,), jnp.float32)


def test_memfree_reuse(ctx):
    g1 = dart_memalloc(ctx, 256, unit=0)
    dart_memfree(ctx, g1)
    g2 = dart_memalloc(ctx, 128, unit=0)
    assert g2.addr == g1.addr


def test_bcast_and_allreduce(ctx):
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 128)
    for u in range(4):
        dart_put_blocking(ctx, g.setunit(u),
                          jnp.full((4,), float(u + 1), jnp.float32))
    red = dart_allreduce(ctx, g, (4,), jnp.float32, op="sum")
    assert np.all(np.asarray(red) == 1 + 2 + 3 + 4)
    # after allreduce every member holds the reduced value
    for u in range(4):
        out = dart_get_blocking(ctx, g.setunit(u), (4,), jnp.float32)
        assert np.all(np.asarray(out) == 10.0)
    # bcast root's bytes
    dart_put_blocking(ctx, g.setunit(2), jnp.full((4,), 42.0, jnp.float32))
    dart_bcast(ctx, g.setunit(2), 16)
    for u in range(4):
        out = dart_get_blocking(ctx, g.setunit(u), (4,), jnp.float32)
        assert np.all(np.asarray(out) == 42.0)
    dart_barrier(ctx)


@given(st.integers(0, 3), st.integers(0, 24),
       st.sampled_from(["float32", "int32", "bfloat16"]),
       st.integers(1, 16))
@settings(max_examples=25, deadline=None)
def test_put_get_property(unit, word_off, dtype, n):
    """What you put at (unit, offset) is exactly what you get back."""
    ctx = dart_init(n_units=4, config=DartConfig(
        non_collective_pool_bytes=4096, team_pool_bytes=4096))
    try:
        g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 2048)
        ptr = g.setunit(unit) + word_off * 4
        val = (jnp.arange(n) + 1).astype(dtype)
        dart_put_blocking(ctx, ptr, val)
        out = dart_get_blocking(ctx, ptr, (n,), dtype)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(val))
    finally:
        dart_exit(ctx)
