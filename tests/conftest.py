"""Shared fixtures for the engine-facing test modules.

``engine_impl`` parametrizes a module's ``ctx`` fixture over BOTH
batched-kernel implementations — ``CommEngine(impl='ref')`` (XLA
segmented scatter/gather) and ``impl='pallas'`` (the hand-tiled
descriptor-grid kernels, interpret-mode off TPU) — so every
engine-facing test runs under both instead of pallas being
spot-checked ad hoc.  The impl switch must never change semantics
(runs that fail the Pallas window precondition fall back to ref
per-dispatch), which is exactly what running the whole module twice
asserts.
"""

import pytest


@pytest.fixture(params=["ref", "pallas"])
def engine_impl(request):
    return request.param
