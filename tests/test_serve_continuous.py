"""Continuous-batching serve plane: scheduler invariants, the PGAS
KV-block pool, the prefix-cache service (incl. refcount exactness under
concurrency and LRU eviction), and the continuous engine end to end."""

import threading

import numpy as np
import pytest

from repro.core import DartConfig, dart_init
from repro.serve import (BlockId, ContinuousScheduler, KVBlockPool,
                         PoolExhausted, PrefixCacheService,
                         chain_keys, pack_kv_blocks, pool_bytes_needed,
                         unpack_kv_blocks)


class _Req:
    def __init__(self, rid, max_new_tokens=4, eos_id=None):
        self.rid = rid
        self.max_new_tokens = max_new_tokens
        self.eos_id = eos_id


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_admit_fifo_until_slots_full():
    s = ContinuousScheduler(max_batch=2)
    for i in range(3):
        s.enqueue(_Req(i))
    a = s.admit_next()
    b = s.admit_next()
    assert (a.req.rid, b.req.rid) == (0, 1)        # FIFO
    assert {a.slot, b.slot} == {0, 1}
    assert s.admit_next() is None                  # no free slot
    assert s.n_waiting == 1 and s.n_resident == 2 and s.n_free == 0


def test_scheduler_retire_on_budget_frees_slot_for_waiting():
    s = ContinuousScheduler(max_batch=1)
    s.enqueue(_Req(0, max_new_tokens=2))
    s.enqueue(_Req(1, max_new_tokens=1))
    seq = s.admit_next()
    assert not s.note_token(seq.slot, 7)
    assert s.note_token(seq.slot, 8)               # budget reached
    retired = s.retire(seq.slot)
    assert retired.emitted == [7, 8]
    nxt = s.admit_next()                           # slot immediately reusable
    assert nxt is not None and nxt.req.rid == 1 and nxt.slot == seq.slot
    assert s.admitted == 2 and s.retired == 1


def test_scheduler_eos_retires_early_and_keeps_token():
    s = ContinuousScheduler(max_batch=1)
    s.enqueue(_Req(0, max_new_tokens=10, eos_id=99))
    seq = s.admit_next()
    assert not s.note_token(seq.slot, 5)
    assert s.note_token(seq.slot, 99)              # EOS
    assert seq.eos_seen and seq.emitted == [5, 99]
    with pytest.raises(RuntimeError):
        s.note_token(seq.slot, 1)                  # finished: retire first


def test_scheduler_retire_runs_hook_and_empty_slot_raises():
    s = ContinuousScheduler(max_batch=1)
    s.enqueue(_Req(0, max_new_tokens=1))
    seq = s.admit_next()
    released = []
    seq.on_retire = lambda sq: released.append(sq.slot)
    s.note_token(seq.slot, 1)
    s.retire(seq.slot)
    assert released == [seq.slot]
    with pytest.raises(KeyError):
        s.retire(seq.slot)
    with pytest.raises(KeyError):
        s.note_token(seq.slot, 1)


# ---------------------------------------------------------------------------
# KV block pool
# ---------------------------------------------------------------------------

N_UNITS = 2
BLOCK_ELEMS = 8
N_BLOCKS = 6


@pytest.fixture()
def ctx():
    import jax.numpy as jnp
    pool_bytes = pool_bytes_needed(64, BLOCK_ELEMS, N_UNITS, jnp.float32)
    return dart_init(n_units=N_UNITS,
                     config=DartConfig(team_pool_bytes=pool_bytes,
                                       non_collective_pool_bytes=1 << 14))


@pytest.fixture()
def pool(ctx):
    return KVBlockPool(ctx, n_blocks=N_BLOCKS, block_elems=BLOCK_ELEMS)


def test_pool_round_robin_and_exhaustion(pool):
    bids = [pool.alloc() for _ in range(pool.n_blocks)]
    assert len(set(bids)) == pool.n_blocks
    per_unit = {u: sum(1 for b in bids if b.unit == u)
                for u in {b.unit for b in bids}}
    assert len(per_unit) == N_UNITS                # spread across units
    assert max(per_unit.values()) - min(per_unit.values()) <= 1
    with pytest.raises(PoolExhausted):
        pool.alloc()
    pool.free(bids[0])
    assert pool.alloc() == bids[0]


def test_pool_one_sided_roundtrip_with_per_target_flush(pool):
    rng = np.random.RandomState(3)
    bids = [pool.alloc() for _ in range(4)]
    payloads = {b: rng.randn(BLOCK_ELEMS).astype(np.float32)
                for b in bids}
    for b, p in payloads.items():
        pool.write_nb(b, p)                        # queued puts
    handles = {b: pool.read_nb(b) for b in bids}
    for u in sorted({b.unit for b in bids}):
        pool.flush_unit(u)                         # per-target flush
    for b in bids:
        np.testing.assert_array_equal(
            np.asarray(handles[b].value()), payloads[b])


def test_pool_block_gptr_addresses_owner_row(pool):
    bid = BlockId(unit=pool.ga.units[-1], index=1)
    gp = pool.block_gptr(bid)
    assert gp.unitid == bid.unit
    assert gp == pool.block_ref(bid).gptr


def test_pool_refcounts_are_atomic_fetch_add(pool):
    bid = pool.alloc()
    assert pool.rc_load(bid) == 0
    assert pool.rc_add(bid, +1) == 0               # returns pre-value
    assert pool.rc_add(bid, +1) == 1
    assert pool.rc_load(bid) == 2
    pool.rc_add(bid, -2)
    assert pool.rc_load(bid) == 0


# ---------------------------------------------------------------------------
# prefix keys + block packing
# ---------------------------------------------------------------------------

def test_chain_keys_name_their_whole_left_context():
    a = np.arange(16, dtype=np.int32)
    b = a.copy(); b[12] = 999                      # diverge in chunk 3
    ka, kb = chain_keys(a, 4), chain_keys(b, 4)
    assert ka[:3] == kb[:3]                        # shared prefix shares keys
    assert ka[3] != kb[3]                          # divergence changes the key
    c = a.copy(); c[0] = 999                       # diverge in chunk 0
    kc = chain_keys(c, 4)
    assert all(x != y for x, y in zip(ka, kc))     # chained: all downstream differ
    with pytest.raises(ValueError):
        chain_keys(np.arange(6, dtype=np.int32), 4)


def test_pack_unpack_kv_blocks_roundtrip():
    L, kv, hd, bt, max_seq, n_tok = 3, 2, 4, 4, 16, 8
    rng = np.random.RandomState(0)
    cache = {"k": rng.randn(L, 1, max_seq, kv, hd).astype(np.float32),
             "v": rng.randn(L, 1, max_seq, kv, hd).astype(np.float32)}
    blocks = pack_kv_blocks(cache, n_tok, bt)
    assert len(blocks) == n_tok // bt
    assert all(b.size == 2 * L * bt * kv * hd for b in blocks)
    k, v = unpack_kv_blocks(blocks, n_layers=L, kv_heads=kv, head_dim=hd,
                            block_tokens=bt, max_seq=max_seq,
                            dtype=np.float32)
    np.testing.assert_array_equal(k[:, :, :n_tok], cache["k"][:, :, :n_tok])
    np.testing.assert_array_equal(v[:, :, :n_tok], cache["v"][:, :, :n_tok])
    assert not k[:, :, n_tok:].any() and not v[:, :, n_tok:].any()


# ---------------------------------------------------------------------------
# prefix cache service (synthetic payloads — no model)
# ---------------------------------------------------------------------------

BT = 4          # block_tokens for the service tests


def _svc(ctx, n_blocks):
    pool = KVBlockPool(ctx, n_blocks=n_blocks, block_elems=BLOCK_ELEMS)
    return PrefixCacheService(ctx, pool, block_tokens=BT), pool


def _prompt(*vals):
    return np.asarray(vals, np.int32)


def _payloads(n, seed):
    rng = np.random.RandomState(seed)
    return [rng.randn(BLOCK_ELEMS).astype(np.float32) for _ in range(n)]


def test_prefix_insert_then_lookup_roundtrips_blocks(ctx):
    svc, pool = _svc(ctx, 8)
    toks = _prompt(*range(8))                      # 2 chunks
    pays = _payloads(2, seed=1)
    assert svc.lookup(toks) is None                # cold miss
    assert svc.insert(toks, pays, next_token=42) == 2
    hit = svc.lookup(toks)
    assert hit is not None and hit.next_token == 42
    vals = hit.fetch()
    for got, want in zip(vals, pays):
        np.testing.assert_array_equal(got, want)
    assert all(pool.rc_load(b) == 1 for b in hit.blocks)   # pinned
    hit.release()
    hit.release()                                  # idempotent
    assert all(pool.rc_load(b) == 0 for b in hit.blocks)
    assert svc.stats.hits == 1 and svc.stats.misses == 1


def test_prefix_fetch_batches_one_gather_per_owner(ctx):
    """SATELLITE: restoring a B-block prefix issues ONE segmented
    strided gather per owner run — dispatch_count grows by the number
    of owner lanes, not by B (was: one get_nb per block)."""
    svc, pool = _svc(ctx, 8)
    toks = _prompt(*range(16))                     # 4 chunks
    pays = _payloads(4, seed=7)
    svc.insert(toks, pays, next_token=9)
    ctx.engine.flush()                             # drain the insert puts
    hit = svc.lookup(toks)
    owners = {b.unit for b in hit.blocks}
    assert len(hit.blocks) == 4 and len(owners) == N_UNITS
    d0 = ctx.engine.dispatch_count
    vals = hit.fetch()
    used = ctx.engine.dispatch_count - d0
    assert used == len(owners)                     # 1 dispatch per lane
    assert used < len(hit.blocks)                  # NOT per-block
    # round-robin allocation gives consecutive rows per owner -> the
    # per-owner batch is exactly one arithmetic-progression run
    assert svc.stats.fetch_runs == len(owners)
    assert svc.stats.fetch_get_nb_ops == len(owners)
    for got, want in zip(vals, pays):
        np.testing.assert_array_equal(got, want)
    hit.release()


def test_pool_read_run_nb_strided_stack(pool):
    """read_run_nb(step>1) is one strided gather returning the block
    stack in run order, byte-identical to per-block reads."""
    rng = np.random.RandomState(11)
    unit = pool.ga.units[0]
    rows = [0, 2]                                  # stride-2 row run
    pays = {r: rng.randn(BLOCK_ELEMS).astype(np.float32) for r in rows}
    for r, p in pays.items():
        pool.write_nb(BlockId(unit=unit, index=r), p)
    d0 = pool.ctx.engine.dispatch_count
    h = pool.read_run_nb(unit, start=0, count=2, step=2)
    pool.flush_unit(unit)
    stack = np.asarray(h.value())
    assert stack.shape == (2, BLOCK_ELEMS)
    # one flush: the queued puts and the strided gather ride <=2 dispatches
    assert pool.ctx.engine.dispatch_count - d0 <= 2
    for i, r in enumerate(rows):
        np.testing.assert_array_equal(stack[i], pays[r])


def test_prefix_shared_chunks_not_republished(ctx):
    svc, pool = _svc(ctx, 8)
    a = _prompt(*range(8))
    b = np.concatenate([a[:4], _prompt(90, 91, 92, 93)])   # shares chunk 0
    svc.insert(a, _payloads(2, seed=2), next_token=1)
    published = svc.insert(b, _payloads(2, seed=3), next_token=2)
    assert published == 1                          # chunk 0 reused
    assert svc.stats.shared_blocks == 1
    assert len(svc) == 3
    # partial overlap is NOT a hit: b's full chain must be present
    c = np.concatenate([a[:4], _prompt(70, 71, 72, 73)])
    assert svc.lookup(c) is None


def test_prefix_refcounts_exact_under_concurrent_lookups(ctx):
    svc, pool = _svc(ctx, 8)
    toks = _prompt(*range(8))
    svc.insert(toks, _payloads(2, seed=4), next_token=7)
    n_threads, iters, errs = 6, 12, []

    def worker():
        try:
            for _ in range(iters):
                hit = svc.lookup(toks)
                assert hit is not None
                hit.fetch()
                hit.release()
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    ts = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
    assert svc.stats.hits == n_threads * iters
    ent_bids = [e.bid for e in svc._dir.values()]
    assert all(pool.rc_load(b) == 0 for b in ent_bids)     # exact: all unpinned


def test_prefix_lru_eviction_reclaims_oldest_unreferenced(ctx):
    svc, pool = _svc(ctx, 2)                       # room for 2 blocks
    a, b, c = (_prompt(*range(i, i + 4)) for i in (0, 10, 20))
    svc.insert(a, _payloads(1, seed=5), next_token=1)
    svc.insert(b, _payloads(1, seed=6), next_token=2)
    assert pool.n_free == 0
    hb = svc.lookup(b)                             # refresh + pin b
    hb.release()                                   # unpinned, but recent
    svc.insert(c, _payloads(1, seed=7), next_token=3)      # evicts LRU = a
    assert svc.stats.evictions == 1
    assert svc.lookup(a) is None                   # a gone
    assert svc.lookup(c) is not None               # c resident
    assert svc.stats.insert_skipped == 0


def test_prefix_pinned_blocks_never_evicted(ctx):
    svc, pool = _svc(ctx, 2)
    a, b = _prompt(*range(4)), _prompt(*range(10, 14))
    svc.insert(a, _payloads(1, seed=8), next_token=1)
    svc.insert(b, _payloads(1, seed=9), next_token=2)
    ha = svc.lookup(a)                             # pin a (LRU after b refresh)
    svc.lookup(b).release()                        # b most recent, unpinned
    # full pool + a pinned: the evictor must take b (newer but free),
    # never the pinned LRU block
    svc.insert(_prompt(*range(20, 24)), _payloads(1, seed=10), next_token=3)
    assert svc.lookup(a) is not None               # a survived (pinned)
    assert svc.lookup(b) is None                   # b was the victim
    ha.release()
    # everything pinned -> nothing evictable -> insert skipped, no crash
    hits = [svc.lookup(p) for p in (a, _prompt(*range(20, 24)))]
    assert all(h is not None for h in hits)
    assert svc.insert(_prompt(*range(30, 34)), _payloads(1, seed=11),
                      next_token=4) == 0
    assert svc.stats.insert_skipped == 1
    for h in hits:
        h.release()


# ---------------------------------------------------------------------------
# continuous engine (end to end, real model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine():
    import jax

    from repro.configs import get_config
    from repro.models import api
    from repro.models.config import reduced_for_smoke
    from repro.serve import ContinuousEngine

    cfg = reduced_for_smoke(get_config("llama3-8b"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return ContinuousEngine(cfg, params, max_batch=3, max_seq=64,
                            block_tokens=8, n_cache_blocks=32)


def test_continuous_serves_more_requests_than_slots(engine):
    rng = np.random.RandomState(0)
    reqs = [engine.submit(rng.randint(1, 100, size=rng.randint(3, 9))
                          .astype(np.int32), max_new_tokens=n)
            for n in (5, 3, 7, 4, 6, 2, 5)]
    assert engine.run_until_idle() == 7
    for r in reqs:
        assert r.done.is_set()
        assert r.output.shape == (r.max_new_tokens,)
    assert engine.scheduler.n_resident == 0
    assert engine.scheduler.retired >= 7


def test_continuous_greedy_matches_manual_decode(engine):
    """Engine output == manual prefill+decode over the bucket-padded
    prompt (left-pad to pow2 is the engine's shape-stability contract)."""
    import jax.numpy as jnp

    from repro.models import api

    cfg = engine.cfg
    prompt = np.arange(1, 7, dtype=np.int32)
    req = engine.submit(prompt, max_new_tokens=4)
    engine.run_until_idle()

    padded = engine._padded_prompt(prompt)
    assert padded.size == 8 and padded[:2].tolist() == [0, 0]
    batch = {"tokens": jnp.asarray(padded[None])}
    logits, cache = api.forward_prefill(cfg, engine.params, batch,
                                        engine.max_seq)
    toks = []
    nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    toks.append(int(nxt[0, 0]))
    for _ in range(3):
        logits, cache = api.forward_decode(cfg, engine.params, nxt, cache)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        toks.append(int(nxt[0, 0]))
    np.testing.assert_array_equal(req.output, toks)


def test_continuous_eos_truncates_and_frees_slot_early(engine):
    prompt = np.arange(1, 5, dtype=np.int32)
    r0 = engine.submit(prompt, max_new_tokens=6)
    engine.run_until_idle()
    eos = int(r0.output[0])
    steps0 = engine.decode_steps
    r1 = engine.submit(prompt, max_new_tokens=6, eos_id=eos)
    engine.run_until_idle()
    assert r1.output.tolist() == [eos]
    # EOS on the prefill token: the sequence retired without a single
    # decode step burned on it
    assert engine.decode_steps == steps0


def test_continuous_prefix_hit_serves_identical_tokens_without_prefill(engine):
    rng = np.random.RandomState(7)
    prompt = rng.randint(1, 100, size=11).astype(np.int32)
    r0 = engine.submit(prompt, max_new_tokens=5)
    engine.run_until_idle()
    hits0, prefills0 = engine.prefix.stats.hits, engine.prefills
    r1 = engine.submit(prompt, max_new_tokens=5)
    engine.run_until_idle()
    assert engine.prefix.stats.hits == hits0 + 1
    assert engine.prefills == prefills0            # no recompute
    np.testing.assert_array_equal(r0.output, r1.output)


def test_continuous_prefix_blocks_byte_identical_to_recompute(engine):
    """The KV bytes restored from the global block pool == a fresh
    prefill of the same padded prompt (the recompute oracle)."""
    import jax.numpy as jnp

    from repro.models import api

    cfg = engine.cfg
    rng = np.random.RandomState(11)
    prompt = rng.randint(1, 100, size=13).astype(np.int32)
    engine.submit(prompt, max_new_tokens=2)
    engine.run_until_idle()

    padded = engine._padded_prompt(prompt)
    hit = engine.prefix.lookup(padded)
    assert hit is not None
    k, v = unpack_kv_blocks(
        hit.fetch(), n_layers=cfg.n_layers, kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, block_tokens=engine.block_tokens,
        max_seq=engine.max_seq, dtype=cfg.cdtype)
    hit.release()

    _, oracle = api.forward_prefill(cfg, engine.params,
                                    {"tokens": jnp.asarray(padded[None])},
                                    engine.max_seq)
    n = padded.size
    np.testing.assert_array_equal(k[:, :, :n],
                                  np.asarray(oracle["k"])[:, :, :n])
    np.testing.assert_array_equal(v[:, :, :n],
                                  np.asarray(oracle["v"])[:, :, :n])


def test_continuous_steady_state_never_retraces(engine):
    """After warmup, repeat traffic adds no prefill buckets, no decode
    retraces, and no DART plan compiles."""
    rng = np.random.RandomState(3)
    prompts = [rng.randint(1, 100, size=n).astype(np.int32)
               for n in (4, 6, 9, 13)]
    for p in prompts:                              # warmup pass
        engine.submit(p, max_new_tokens=3)
    engine.run_until_idle()

    misses0 = engine.prefill_shape_misses
    jit0 = (engine._prefill._cache_size() + engine._decode._cache_size()
            + engine._insert._cache_size())
    plans0 = engine.dart.engine.compile_count
    for p in prompts:                              # steady state
        engine.submit(p, max_new_tokens=3)
    engine.run_until_idle()
    assert engine.prefill_shape_misses == misses0
    assert (engine._prefill._cache_size() + engine._decode._cache_size()
            + engine._insert._cache_size()) == jit0
    assert engine.dart.engine.compile_count == plans0


def test_continuous_submit_rejects_overflowing_budget(engine):
    prompt = np.arange(1, 40, dtype=np.int32)      # bucket 64
    with pytest.raises(ValueError):
        engine.submit(prompt, max_new_tokens=10)   # 64 + 10 > max_seq 64


# ---------------------------------------------------------------------------
# fault plane: per-request deadlines + dead-owner degradation
# ---------------------------------------------------------------------------

def test_continuous_deadline_frees_pinned_slot(engine):
    """A stuck sequence cannot pin a slot forever: past its wall-clock
    deadline it retires with finish_reason 'timeout' and the slot is
    immediately reusable."""
    import time as _time

    free0 = engine.scheduler.n_free
    req = engine.submit(np.arange(1, 6, dtype=np.int32),
                        max_new_tokens=40, deadline_s=0.02)
    engine._ingest()
    engine._admit_all()
    assert engine.scheduler.n_free == free0 - 1    # resident, pinned
    _time.sleep(0.03)
    engine._sweep_deadlines()
    assert req.done.is_set()
    assert req.finish_reason == "timeout"
    assert engine.scheduler.n_free == free0        # slot freed
    assert engine.stats()["timeouts"] >= 1


def test_continuous_deadline_times_out_waiting_request(engine):
    """An already-expired waiting request is finalized with 'timeout'
    before it ever takes a slot; fresh requests still complete."""
    import time as _time

    expired = engine.submit(np.arange(1, 5, dtype=np.int32),
                            max_new_tokens=4, deadline_s=1e-4)
    fresh = engine.submit(np.arange(1, 5, dtype=np.int32),
                          max_new_tokens=4)
    _time.sleep(0.002)
    engine.run_until_idle()
    assert expired.finish_reason == "timeout"
    assert expired.output.size == 0
    assert fresh.done.is_set() and fresh.finish_reason in ("eos", "length")
    assert fresh.output.shape == (4,)


def test_continuous_submit_rejects_nonpositive_deadline(engine):
    with pytest.raises(ValueError, match="deadline_s"):
        engine.submit(np.arange(1, 5, dtype=np.int32), deadline_s=0.0)


@pytest.fixture()
def fresh_engine(engine):
    """A private engine (unit death is permanent, so these tests must
    not poison the module-scoped one).  Reuses the module fixture's
    cfg/params — only the serve+DART planes are rebuilt."""
    from repro.serve import ContinuousEngine

    return ContinuousEngine(engine.cfg, engine.params, max_batch=3,
                            max_seq=64, block_tokens=8, n_units=4,
                            n_cache_blocks=32)


def test_continuous_dead_owner_degrades_to_recompute(fresh_engine):
    """Killing a block-owner unit degrades the serve plane instead of
    crashing it: the dead owner's cache entries become misses
    (recompute), its blocks leave the pool, and every request not
    owned by the dead unit completes."""
    eng = fresh_engine
    rng = np.random.RandomState(5)
    prompts = [rng.randint(1, 100, size=n).astype(np.int32)
               for n in (13, 9, 11)]
    for p in prompts:
        eng.submit(p, max_new_tokens=3)
    assert eng.run_until_idle() == 3

    padded = eng._padded_prompt(prompts[0])
    hit = eng.prefix.lookup(padded)
    assert hit is not None
    owners = {bid.unit for bid in hit.blocks}
    hit.release()
    victim = min(owners)

    dir0 = len(eng.prefix)
    eng.note_unit_death(victim)
    assert victim in eng.dart.engine.dead_units
    assert victim in eng.kv_pool.dead_units
    assert len(eng.prefix) < dir0                  # dead entries purged
    assert all(b.unit != victim for b in eng.kv_pool._freelist)
    assert eng.prefix.stats.dead_block_purges > 0

    # the dead owner's prefix now misses → recompute, and it completes
    prefills0 = eng.prefills
    reqs = [eng.submit(p, max_new_tokens=3) for p in prompts]
    assert eng.run_until_idle() == 3
    for r in reqs:
        assert r.done.is_set() and r.finish_reason in ("eos", "length")
        assert r.output.shape == (3,)
    assert eng.prefills > prefills0                # recomputed, not crashed


def test_continuous_resident_on_dead_owner_retires_unit_failed(fresh_engine):
    """A resident restored from prefix blocks owned by a dying unit is
    retired with finish_reason 'unit_failed' (slot freed); residents
    not touching the dead owner keep decoding."""
    eng = fresh_engine
    prompt = np.arange(1, 14, dtype=np.int32)
    eng.submit(prompt, max_new_tokens=3)
    assert eng.run_until_idle() == 1               # publish the prefix

    req = eng.submit(prompt, max_new_tokens=30)
    eng._ingest()
    eng._admit_all()                               # admitted via prefix hit
    seq = next(s for s in eng.scheduler.residents if s.req is req)
    assert seq.prefix_hit and seq.block_owners
    victim = seq.block_owners[0]

    retired = eng.note_unit_death(victim)
    assert retired == 1
    assert req.done.is_set()
    assert req.finish_reason == "unit_failed"
    assert eng.scheduler.n_resident == 0           # slot freed
    assert eng.stats()["unit_failed_retired"] == 1
