"""Unit tests: logical-axis rules, size-aware specs, HLO collective
parser, roofline arithmetic."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import parse_collectives
from repro.sharding.rules import (DEFAULT_TRAIN_RULES, fsdp_rules,
                                  logical_to_spec, logical_to_spec_sized)


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
RULES = fsdp_rules(dict(DEFAULT_TRAIN_RULES, batch=("data",)))


def test_spec_basic_mapping():
    spec = logical_to_spec(("vocab", "embed"), RULES)
    assert spec == P("model", "data")


def test_spec_no_axis_reuse():
    # both logical axes map to 'model'; second claim must drop
    spec = logical_to_spec(("q_heads", "mlp"), DEFAULT_TRAIN_RULES)
    assert spec == P("model", None)


def test_sized_spec_drops_non_divisible():
    # 60 experts don't divide model=16 -> experts drops, mlp picks it up
    spec = logical_to_spec_sized(("experts", "embed", "mlp"),
                                 (60, 2048, 1408), DEFAULT_TRAIN_RULES,
                                 MESH)
    assert spec == P(None, None, "model")
    # 64 experts do divide -> experts takes model, mlp drops
    spec = logical_to_spec_sized(("experts", "embed", "mlp"),
                                 (64, 2048, 1024), DEFAULT_TRAIN_RULES,
                                 MESH)
    assert spec == P("model", None, None)


def test_sized_spec_fsdp_embed():
    spec = logical_to_spec_sized(("embed", "mlp"), (4096, 14336),
                                 RULES, MESH)
    assert spec == P("data", "model")
    # odd embed dim -> FSDP drops rather than padding
    spec = logical_to_spec_sized(("embed", "mlp"), (4097, 14336),
                                 RULES, MESH)
    assert spec == P(None, "model")


from _hypothesis_compat import given, settings, st

LOGICAL = [None, "embed", "vocab", "q_heads", "kv_heads", "mlp",
           "experts", "batch", "seq", "layers"]


@given(st.lists(st.sampled_from(LOGICAL), min_size=1, max_size=5),
       st.lists(st.integers(1, 4096), min_size=1, max_size=5))
@settings(max_examples=100, deadline=None)
def test_sized_spec_properties(names, dims):
    """Invariants: every mesh axis used at most once; every sharded dim
    is divisible by its axis size; spec length == rank."""
    n = min(len(names), len(dims))
    names, dims = names[:n], dims[:n]
    spec = logical_to_spec_sized(names, dims, RULES, MESH)
    assert len(spec) == n
    used = []
    for entry, dim in zip(spec, dims):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        for a in axes:
            assert a in MESH.axis_names
            used.append(a)
            assert dim % MESH.shape[a] == 0
    assert len(used) == len(set(used)), f"axis reused: {spec}"


HLO = """
HloModule test
fused_computation {
  ...
}
ENTRY main {
  %p0 = bf16[16,512]{1,0} parameter(0)
  %ag = bf16[256,512]{1,0} all-gather(%p0), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %rs = bf16[8,128]{1,0} reduce-scatter(%y), replica_groups=[32,8]<=[256], dimensions={0}
  %cp = f32[64,64]{1,0} collective-permute(%z), source_target_pairs={{0,1},{1,2}}
  %a2a = bf16[128,32]{1,0} all-to-all(%w), replica_groups=[16,16]<=[256]
}
"""


def test_parse_collectives_formulas():
    st = parse_collectives(HLO, 256)
    assert st.op_counts == {"all-gather": 1, "all-reduce": 1,
                            "reduce-scatter": 1, "collective-permute": 1,
                            "all-to-all": 1}
    ag = 256 * 512 * 2 * 15 / 16          # out_bytes * (g-1)/g
    ar = 2 * 1024 * 4 * 3 / 4             # 2 * bytes * (g-1)/g, g=4
    rs = 8 * 128 * 2 * 7                  # out_bytes * (g-1), g=8
    cp = 64 * 64 * 4
    a2a = 128 * 32 * 2 * 15 / 16
    assert st.op_bytes["all-gather"] == pytest.approx(ag)
    assert st.op_bytes["all-reduce"] == pytest.approx(ar)
    assert st.op_bytes["reduce-scatter"] == pytest.approx(rs)
    assert st.op_bytes["collective-permute"] == pytest.approx(cp)
    assert st.op_bytes["all-to-all"] == pytest.approx(a2a)
    assert st.per_device_link_bytes == pytest.approx(
        ag + ar + rs + cp + a2a)


def test_parse_collectives_ignores_done_and_singleton_groups():
    txt = """
  %ag1 = bf16[16,4]{1,0} all-gather-start(%p), replica_groups=[256,1]<=[256]
  %agd = bf16[16,4]{1,0} all-gather-done(%ag1)
"""
    st = parse_collectives(txt, 256)
    # group size 1 => no traffic
    assert st.per_device_link_bytes == 0


def test_real_compiled_module_parse():
    """End-to-end: compile a tiny sharded matmul and find its psum."""
    import jax.numpy as jnp
    if jax.device_count() != 1:
        pytest.skip("needs the default single-device pytest process")
    # single device: no collectives expected; parser returns 0 cleanly
    co = jax.jit(lambda a, b: a @ b).lower(
        jnp.ones((8, 8)), jnp.ones((8, 8))).compile()
    st = parse_collectives(co.as_text(), 1)
    assert st.per_device_link_bytes == 0
