"""Tests for the symmetric heap + allocators (paper §IV.B.3)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (ALIGNMENT, BlockAllocator, OutOfGlobalMemory,
                        SymmetricHeap, align_up, from_bytes, nbytes_of,
                        to_bytes)


# ------------------------------------------------------- block allocator ----

def test_block_allocator_first_fit_and_free():
    a = BlockAllocator(1024)
    o1 = a.alloc(100)            # -> 0, rounded to 128
    o2 = a.alloc(100)            # -> 128
    assert (o1, o2) == (0, 128)
    a.free(o1)
    assert a.alloc(50) == 0      # first fit reuses the hole
    with pytest.raises(OutOfGlobalMemory):
        a.alloc(2048)


def test_block_allocator_coalescing():
    a = BlockAllocator(512)
    offs = [a.alloc(128) for _ in range(4)]   # exhausts the pool
    with pytest.raises(OutOfGlobalMemory):
        a.alloc(1)
    for o in offs:
        a.free(o)
    assert a.alloc(512) == 0     # holes coalesced back into one block


@given(st.lists(st.integers(1, 300), min_size=1, max_size=30))
@settings(max_examples=50)
def test_block_allocator_no_overlap_property(sizes):
    """Live allocations never overlap and stay in-bounds."""
    a = BlockAllocator(1 << 16)
    live = []
    for i, s in enumerate(sizes):
        try:
            off = a.alloc(s)
        except OutOfGlobalMemory:
            continue
        live.append((off, align_up(s)))
        if i % 4 == 3 and live:
            o, _ = live.pop(0)
            a.free(o)
    live.sort()
    for (o1, l1), (o2, _) in zip(live, live[1:]):
        assert o1 + l1 <= o2
    for o, l in live:
        assert 0 <= o and o + l <= (1 << 16)
        assert o % ALIGNMENT == 0


# ------------------------------------------------------- heap + pools -------

def test_symmetric_heap_pools():
    h = SymmetricHeap(n_units=4)
    world = h.reserve_pool(n_rows=4, pool_bytes=1024, collective=False)
    team = h.reserve_pool(n_rows=4, pool_bytes=1024, collective=True)
    # non-collective: per-unit independent cursors (paper Fig. 4)
    o_u0 = h.memalloc_local(world, 0, 100)
    o_u1 = h.memalloc_local(world, 1, 300)
    o_u0b = h.memalloc_local(world, 0, 100)
    assert o_u0 == 0 and o_u1 == 0       # each unit starts at its own base
    assert o_u0b == 128
    # collective: one shared cursor -> aligned & symmetric (paper Fig. 5)
    c1 = h.memalloc_aligned(team, 256)
    c2 = h.memalloc_aligned(team, 256)
    assert (c1, c2) == (0, 256)
    assert len(team.table) == 2
    rec = team.table.query(c2 + 10)      # address inside second alloc
    assert rec.offset == c2
    h.memfree_aligned(team, c1)
    assert len(team.table) == 1
    assert h.memalloc_aligned(team, 128) == 0   # slot recycled


def test_block_allocator_free_introspection():
    a = BlockAllocator(1024)
    o1, o2 = a.alloc(128), a.alloc(128)
    o3 = a.alloc(128)                          # live: [0,128,256), tail free
    assert a.bytes_live() == 384
    assert a.bytes_free() == 640
    assert a.largest_free() == 640             # the tail block
    a.free(o1)
    assert a.bytes_free() == 768
    assert a.largest_free() == 640             # hole at 0 is not adjacent
    a.free(o2)
    assert a.bytes_free() == 896
    assert a.largest_free() == 640             # [0,256) still split by o3
    a.free(o3)
    assert a.bytes_free() == 1024
    assert a.largest_free() == 1024            # everything coalesced


def test_team_memfree_then_realloc_returns_coalesced_block():
    """Runtime-level allocator reuse: dart_team_memfree returns blocks
    to the team pool, adjacent holes coalesce, and a re-alloc spanning
    the combined extent succeeds at the original offset."""
    from repro.core import (DART_TEAM_ALL, DartConfig, dart_exit, dart_init,
                            dart_team_memalloc_aligned, dart_team_memfree)
    ctx = dart_init(n_units=2, config=DartConfig(team_pool_bytes=4096))
    try:
        g1 = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 1024)
        g2 = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 1024)
        assert (g1.addr, g2.addr) == (0, 1024)
        alloc = ctx.heap.windows.lookup(DART_TEAM_ALL).shared_alloc
        dart_team_memfree(ctx, DART_TEAM_ALL, g1)
        dart_team_memfree(ctx, DART_TEAM_ALL, g2)
        assert alloc.bytes_live() == 0
        assert alloc.largest_free() == 4096    # holes coalesced
        g3 = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 2048)
        assert g3.addr == 0                    # spans both former blocks
        # and the translation table tracks only the live allocation
        assert len(ctx.heap.windows.lookup(DART_TEAM_ALL).table) == 1
    finally:
        dart_exit(ctx)


def test_global_array_request_overflowing_team_pool_raises():
    """A GlobalArray-sized request larger than team_pool_bytes must
    surface OutOfGlobalMemory from the pool allocator."""
    import jax.numpy as jnp
    from repro.core import DartConfig, dart_exit, dart_init
    ctx = dart_init(n_units=2, config=DartConfig(team_pool_bytes=2048))
    try:
        ctx.alloc((256,), jnp.float32)         # 1 KiB fits
        with pytest.raises(OutOfGlobalMemory):
            ctx.alloc((512,), jnp.float32)     # 2 KiB > remaining 1 KiB
        with pytest.raises(OutOfGlobalMemory):
            ctx.alloc((4096,), jnp.float64)    # 32 KiB > whole pool
    finally:
        dart_exit(ctx)


def test_translation_table_query_miss():
    h = SymmetricHeap(n_units=2)
    team = h.reserve_pool(n_rows=2, pool_bytes=512, collective=True)
    h.memalloc_aligned(team, 128)
    with pytest.raises(KeyError):
        team.table.query(500)


def test_heap_state_shapes():
    h = SymmetricHeap(n_units=3)
    h.reserve_pool(n_rows=3, pool_bytes=100, collective=False)  # rounds up
    state = h.init_state()
    assert state[0].shape == (3, 128)
    assert state[0].dtype == jnp.uint8


# ------------------------------------------------- byte conversion ----------

DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32, jnp.uint8, jnp.float16,
          jnp.int8]


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [(7,), (3, 5), (2, 3, 4), ()])
def test_bytes_roundtrip(dtype, shape):
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(*shape) * 3, dtype=dtype)
    b = to_bytes(x)
    assert b.dtype == jnp.uint8
    assert b.size == nbytes_of(shape, dtype)
    y = from_bytes(b, shape, dtype)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@given(st.integers(1, 64), st.sampled_from(["float32", "int32", "bfloat16"]))
@settings(max_examples=30)
def test_bytes_roundtrip_property(n, dtype):
    x = jnp.arange(n).astype(dtype)
    assert np.array_equal(np.asarray(from_bytes(to_bytes(x), (n,), dtype)),
                          np.asarray(x))
