"""Device-plane checks for shmem ops + team collectives.

Run in a subprocess with 8 forced host devices (see
tests/test_multidevice.py) so the main pytest process keeps 1 device.
Prints CHECK:<name>:OK per assertion block and ALL:OK at the end.
"""

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh, shard_map

from repro.core import (team_all_gather, team_all_to_all, team_barrier,
                        team_broadcast, team_pmax, team_psum,
                        team_reduce_scatter)
from repro.core.onesided import (shmem_get, shmem_get_dynamic,
                                 shmem_halo_exchange, shmem_put)

N = 8
mesh = make_mesh((N,), ("unit",))
GROUPS = [[0, 1, 2, 3], [4, 5, 6, 7]]


def check(name, ok):
    assert ok, name
    print(f"CHECK:{name}:OK", flush=True)


# ---------------------------------------------------------- shmem_put ------
pool_bytes = 1024
arena = jnp.zeros((N, pool_bytes), jnp.uint8)
vals = jnp.arange(N * 4, dtype=jnp.float32).reshape(N, 4)  # per-unit payload
ring = [(i, (i + 1) % N) for i in range(N)]


def put_body(arena_row, v):
    return shmem_put(arena_row, v, 128, ring, "unit")


f = jax.jit(shard_map(put_body, mesh=mesh,
                          in_specs=(P("unit", None), P("unit", None)),
                          out_specs=P("unit", None)))
arena2 = f(arena, vals)
got = np.asarray(arena2)[:, 128:128 + 16]
expect = np.asarray(
    jax.vmap(lambda v: jax.lax.bitcast_convert_type(v, jnp.uint8).reshape(-1))
    (jnp.roll(vals, 1, axis=0)))
check("shmem_put_ring", np.array_equal(got, expect))

# ---------------------------------------------------------- shmem_get ------
rev = [((i + 1) % N, i) for i in range(N)]   # get from right neighbour


def get_body(arena_row):
    return shmem_get(arena_row, 128, 16, rev, "unit", (4,), jnp.float32)


g = jax.jit(shard_map(get_body, mesh=mesh, in_specs=P("unit", None),
                          out_specs=P("unit")))
fetched = np.asarray(g(arena2)).reshape(N, 4)
check("shmem_get_ring", np.allclose(fetched, np.roll(np.asarray(
    np.roll(vals, 1, axis=0)), -1, axis=0)))

# --------------------------------------------------- shmem_get_dynamic -----


def dyn_body(arena_row, src):
    return shmem_get_dynamic(arena_row, 128, 16, src[0], "unit",
                             (4,), jnp.float32)


srcs = jnp.array([[3]] * N, dtype=jnp.int32)   # everyone reads unit 3
d = jax.jit(shard_map(dyn_body, mesh=mesh,
                          in_specs=(P("unit", None), P("unit", None)),
                          out_specs=P("unit"), check_vma=False))
out = np.asarray(d(arena2, srcs)).reshape(N, 4)
row3 = np.asarray(jnp.roll(vals, 1, axis=0))[3]
check("shmem_get_dynamic", np.allclose(out, np.tile(row3, (N, 1))))

# ------------------------------------------------------- halo exchange -----


def halo_body(arena_row, v):
    return shmem_halo_exchange(arena_row, v, v + 100.0, 0, 256,
                               "unit", N, wrap=False)


h = jax.jit(shard_map(halo_body, mesh=mesh,
                          in_specs=(P("unit", None), P("unit", None)),
                          out_specs=P("unit", None)))
arena3 = np.asarray(h(jnp.zeros((N, pool_bytes), jnp.uint8), vals))
left_halo = arena3[:, 0:16].view(np.float32).reshape(N, 4)
right_halo = arena3[:, 256:272].view(np.float32).reshape(N, 4)
v_np = np.asarray(vals)
# unit i's left halo = unit i-1's right_val (v+100); right halo = unit
# i+1's left_val (v); edges untouched (zeros).
check("halo_left", np.allclose(left_halo[1:], v_np[:-1] + 100.0)
      and np.allclose(left_halo[0], 0))
check("halo_right", np.allclose(right_halo[:-1], v_np[1:])
      and np.allclose(right_halo[-1], 0))

# ------------------------------------------------- team collectives --------
x = jnp.arange(N, dtype=jnp.float32)


def coll_body(xi):
    s = team_psum(xi, "unit", GROUPS)
    m = team_pmax(xi, "unit", GROUPS)
    b = team_broadcast(xi, "unit", 1, GROUPS)
    ag = team_all_gather(xi, "unit", GROUPS)
    t = team_barrier("unit", GROUPS)
    return s, m, b, ag, t.reshape(1)


c = jax.jit(shard_map(coll_body, mesh=mesh, in_specs=P("unit"),
                          out_specs=(P("unit"),) * 5, check_vma=False))
s, m, b, ag, t = c(x)
check("team_psum", np.allclose(np.asarray(s), [6] * 4 + [22] * 4))
check("team_pmax", np.allclose(np.asarray(m), [3] * 4 + [7] * 4))
check("team_broadcast", np.allclose(np.asarray(b), [1] * 4 + [5] * 4))
ag = np.asarray(ag).reshape(N, 4)
check("team_all_gather", np.allclose(ag[0], [0, 1, 2, 3])
      and np.allclose(ag[7], [4, 5, 6, 7]))
check("team_barrier", np.all(np.asarray(t) == 4))

# reduce_scatter: each unit contributes [0..3], gets 1 reduced element


def rs_body(xi):
    return team_reduce_scatter(xi[0], "unit", GROUPS)


xs = jnp.tile(jnp.arange(4, dtype=jnp.float32)[None], (N, 1))
rs = jax.jit(shard_map(rs_body, mesh=mesh, in_specs=P("unit", None),
                           out_specs=P("unit"), check_vma=False))
out = np.asarray(rs(xs)).reshape(-1)
check("team_reduce_scatter", np.allclose(out, [0, 4, 8, 12] * 2))

# all_to_all within groups


def a2a_body(xi):
    return team_all_to_all(xi[0], "unit", 0, 0, GROUPS)[None]


xs = jnp.arange(N * 4, dtype=jnp.float32).reshape(N, 4)
a2a = jax.jit(shard_map(a2a_body, mesh=mesh, in_specs=P("unit", None),
                            out_specs=P("unit", None), check_vma=False))
out = np.asarray(a2a(xs)).reshape(N, 4)
blk = np.asarray(xs).reshape(2, 4, 4)
for gidx in range(2):
    check(f"team_all_to_all_g{gidx}",
          np.allclose(out[gidx * 4:(gidx + 1) * 4], blk[gidx].T))

# ------------------------------------- heap put/get on a sharded mesh ------
from repro.core import (DART_TEAM_ALL, DartConfig, dart_exit,
                        dart_get_blocking, dart_init, dart_put_blocking,
                        dart_team_memalloc_aligned)

ctx = dart_init(n_units=N, mesh=mesh, unit_axes=("unit",),
                config=DartConfig(non_collective_pool_bytes=4096,
                                  team_pool_bytes=4096))
gp = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 256)
for u in range(N):
    dart_put_blocking(ctx, gp.setunit(u), jnp.full((8,), u, jnp.float32))
ok = all(np.all(np.asarray(
    dart_get_blocking(ctx, gp.setunit(u), (8,), jnp.float32)) == u)
    for u in range(N))
check("sharded_heap_putget", ok)
shard_rows = {d: s for d, s in zip(
    ctx.state[1].sharding.device_set,
    [None] * N)}
check("heap_is_row_sharded",
      ctx.state[1].sharding.is_equivalent_to(
          NamedSharding(mesh, P(("unit",), None)), 2))
dart_exit(ctx)

# ----------------------- compressed all-reduce (DCN lever) -----------------
from repro.optim.compression import compressed_allreduce_ref

g_global = jnp.asarray(np.random.RandomState(5).randn(N, 64), jnp.float32)


def comp_body(g):
    red, resid = compressed_allreduce_ref(g[0], "unit")
    return red[None], resid[None]


cf = jax.jit(shard_map(comp_body, mesh=mesh,
                           in_specs=P("unit", None),
                           out_specs=(P("unit", None), P("unit", None)),
                           check_vma=False))
red, resid = cf(g_global)
red = np.asarray(red)
truth = np.asarray(g_global).sum(axis=0)
# every unit holds the same reduced value, close to the true sum
for u in range(N):
    assert np.allclose(red[u], red[0])
err = np.abs(red[0] - truth).max()
scale = np.abs(np.asarray(g_global)).max() / 127.0
check("compressed_allreduce_err_bound", err <= N * scale * 0.51 + 1e-6)
# error feedback: residual equals the per-unit quantization error
check("compressed_allreduce_residual_shape",
      np.asarray(resid).shape == (N, 64))

print("ALL:OK", flush=True)
