"""Pallas comm-kernel checks vs pure-jnp oracles (interpret mode, 8 devs).

Sweeps shapes/dtypes per the test instructions; every kernel result is
assert_allclose'd against the ref.py oracle running in the same
shard_map configuration.
"""

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import make_mesh

from repro.kernels.ops import (make_rdma_put, make_ring_all_gather,
                               make_ring_reduce_scatter)

N = 8
mesh = make_mesh((N,), ("unit",))

SHAPES = [(8, 128), (16, 256), (5, 128), (32, 512)]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32]


def check(name, ok):
    assert ok, name
    print(f"CHECK:{name}:OK", flush=True)


def rand(shape, dtype, seed):
    rng = np.random.RandomState(seed)
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(rng.randint(-100, 100, size=shape), dtype=dtype)
    return jnp.asarray(rng.randn(*shape), dtype=dtype)


# ------------------------------------------------------------ rdma_put -----
for shape in SHAPES:
    for dtype in DTYPES:
        for offset in (1, 2, -1):
            x = rand((N * shape[0], shape[1]), dtype, 0)
            out = make_rdma_put(mesh, "unit", offset=offset)(x)
            ref = make_rdma_put(mesh, "unit", offset=offset, impl="ref")(x)
            np.testing.assert_allclose(
                np.asarray(out, np.float64), np.asarray(ref, np.float64),
                err_msg=f"rdma_put {shape} {dtype.__name__} off={offset}")
        print(f"CHECK:rdma_put_{shape[0]}x{shape[1]}_{dtype.__name__}:OK",
              flush=True)

# ----------------------------------------------------- ring all-gather -----
for shape in SHAPES:
    for dtype in DTYPES:
        x = rand((N * shape[0], shape[1]), dtype, 1)
        out = make_ring_all_gather(mesh, "unit")(x)
        ref = make_ring_all_gather(mesh, "unit", impl="ref")(x)
        np.testing.assert_allclose(
            np.asarray(out, np.float64), np.asarray(ref, np.float64),
            err_msg=f"ring_ag {shape} {dtype.__name__}")
        # every unit's copy equals the full gathered array
        per_unit = np.asarray(out, np.float64).reshape(N, N * shape[0],
                                                       shape[1])
        full = np.asarray(x, np.float64)
        for u in range(N):
            np.testing.assert_allclose(per_unit[u], full)
        print(f"CHECK:ring_allgather_{shape[0]}x{shape[1]}_"
              f"{dtype.__name__}:OK", flush=True)

# ------------------------------------------------- ring reduce-scatter -----
for shape in [(8, 128), (16, 256)]:
    for dtype in [jnp.float32, jnp.int32]:
        # per-unit contribution: (N*chunk, n); global input (N*N*chunk, n)
        x = rand((N * N * shape[0], shape[1]), dtype, 2)
        out = make_ring_reduce_scatter(mesh, "unit")(x)
        ref = make_ring_reduce_scatter(mesh, "unit", impl="ref")(x)
        # ring accumulation order differs from psum_scatter's tree order:
        # bitwise equality is not expected for floats, closeness is.
        tol = {} if jnp.issubdtype(dtype, jnp.integer) else dict(
            rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(out, np.float64), np.asarray(ref, np.float64),
            err_msg=f"ring_rs {shape} {dtype.__name__}", **tol)
        # direct oracle: sum of per-unit blocks
        blocks = np.asarray(x, np.float64).reshape(N, N, shape[0], shape[1])
        expect = blocks.sum(axis=0).reshape(N * shape[0], shape[1])
        np.testing.assert_allclose(np.asarray(out, np.float64), expect,
                                   **tol)
        print(f"CHECK:ring_reduce_scatter_{shape[0]}x{shape[1]}_"
              f"{dtype.__name__}:OK", flush=True)

# bf16 reduce-scatter with tolerance (accumulation order differs)
x = rand((N * N * 8, 128), jnp.bfloat16, 3)
out = make_ring_reduce_scatter(mesh, "unit")(x)
ref = make_ring_reduce_scatter(mesh, "unit", impl="ref")(x)
np.testing.assert_allclose(np.asarray(out, np.float64),
                           np.asarray(ref, np.float64), rtol=0.05, atol=0.5)
print("CHECK:ring_reduce_scatter_bf16:OK", flush=True)

print("ALL:OK", flush=True)
