"""Differential property suite for the strided transfer IR (ISSUE 8).

Every test drives the engine through strided ``(stride, count)`` runs and
checks the resulting arena / fetched bytes against a naive element-wise
numpy oracle.  The ``engine_impl`` fixture (conftest.py) runs the whole
module under BOTH batched-kernel implementations — ``ref`` and the
hand-tiled ``pallas`` descriptor-grid kernels — so stridedness can never
become a ref-only feature.

Covered:

* strided put / get / accumulate byte-identity vs the oracle,
* N-element fixed-stride transfers dispatching as 1 coalesced dispatch,
* overlap splitting (covering-interval disjointness is conservative:
  overlapping strided runs demote/split but stay byte-correct),
* pow2 bucketing of the count column — varying ``count`` loops reuse one
  plan per bucket (zero steady-state recompiles under ref),
* randomized interleavings of contiguous + strided puts/accumulates.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import dart_exit, dart_init
from repro.core.runtime import DartConfig
from repro.core import runtime as rt
from repro.kernels.segmented_copy import bucket_pow2

N_UNITS = 4
POOL = 1 << 13


@pytest.fixture()
def ctx(engine_impl):
    c = dart_init(n_units=N_UNITS, config=DartConfig(
        non_collective_pool_bytes=POOL, team_pool_bytes=POOL))
    c.engine.impl = engine_impl
    yield c
    dart_exit(c)


def _oracle_scatter(base, off_b, seg_b, stride_b, count, payload):
    """Element-wise reference: write count segments of seg_b bytes."""
    out = bytearray(base)
    for s in range(count):
        dst = off_b + s * stride_b
        out[dst:dst + seg_b] = payload[s * seg_b:(s + 1) * seg_b]
    return bytes(out)


def _unit_bytes(ctx, ga, unit):
    return np.asarray(ga[unit].get()).tobytes()


# ---------------------------------------------------------------------------
# put / get byte-identity + single-dispatch acceptance
# ---------------------------------------------------------------------------

def test_strided_put_matches_oracle_one_dispatch(ctx):
    """ACCEPTANCE: a strided put of N elements with fixed stride is ONE
    coalesced dispatch and byte-identical to the element-wise oracle."""
    ga = ctx.alloc((6, 5), jnp.float32)
    base = np.arange(30, dtype=np.float32).reshape(6, 5)
    ga[1].put(jnp.asarray(base))
    col = np.array([9., 8., 7., 6., 5., 4.], np.float32)
    d0 = ctx.engine.dispatch_count
    h = ga.at[1, :, 3].put_nb(jnp.asarray(col))
    h.wait()
    assert ctx.engine.dispatch_count == d0 + 1     # 1, not N=6
    want = _oracle_scatter(base.tobytes(), off_b=3 * 4, seg_b=4,
                           stride_b=5 * 4, count=6, payload=col.tobytes())
    assert _unit_bytes(ctx, ga, 1) == want


def test_strided_get_matches_oracle_one_dispatch(ctx):
    ga = ctx.alloc((8, 3), jnp.int32)
    base = np.arange(24, dtype=np.int32).reshape(8, 3)
    ga[2].put(jnp.asarray(base))
    d0 = ctx.engine.dispatch_count
    got = ga.at[2, 1:8:3, 0].get()                 # rows 1,4,7 col 0
    assert ctx.engine.dispatch_count == d0 + 1
    np.testing.assert_array_equal(np.asarray(got), base[1:8:3, 0])


def test_strided_gets_coalesce_across_targets(ctx):
    """N strided get_nb ops to distinct units flush as ONE dispatch."""
    ga = ctx.alloc((4, 4), jnp.float32)
    ref = {}
    for u in ga.units:
        m = np.random.RandomState(u).randn(4, 4).astype(np.float32)
        ga[u].put(jnp.asarray(m))
        ref[u] = m
    ctx.engine.flush()
    d0 = ctx.engine.dispatch_count
    hs = {u: ga.at[u, :, 2].get_nb() for u in ga.units}
    ctx.engine.flush()
    assert ctx.engine.dispatch_count == d0 + 1
    for u, h in hs.items():
        np.testing.assert_array_equal(np.asarray(h.value()), ref[u][:, 2])


def test_strided_and_contiguous_mix_one_dispatch(ctx):
    """A flush mixing contiguous and strided puts stays one dispatch
    (stride 0 / count 1 is the degenerate row of the same descriptor)."""
    ga = ctx.alloc((4, 4), jnp.float32)
    ga[0].put(jnp.zeros((4, 4), jnp.float32))
    ga[1].put(jnp.zeros((4, 4), jnp.float32))
    ctx.engine.flush()
    d0 = ctx.engine.dispatch_count
    ga.at[0, 1].put_nb(jnp.full((4,), 5.0))        # contiguous row
    ga.at[1, :, 1].put_nb(jnp.full((4,), 7.0))     # strided column
    ctx.engine.flush()
    assert ctx.engine.dispatch_count == d0 + 1
    np.testing.assert_array_equal(np.asarray(ga[0].get())[1], 5.0)
    np.testing.assert_array_equal(np.asarray(ga[1].get())[:, 1], 7.0)


# ---------------------------------------------------------------------------
# overlap splitting
# ---------------------------------------------------------------------------

def test_overlapping_strided_puts_last_writer_wins(ctx):
    """Two strided puts whose covering intervals overlap split/demote
    but preserve queue order (last-writer-wins), like contiguous ops."""
    ga = ctx.alloc((16,), jnp.int32)
    ga[0].put(jnp.zeros((16,), jnp.int32))
    ctx.engine.flush()
    ga.at[0, 0:16:2].put_nb(jnp.full((8,), 1, jnp.int32))
    ga.at[0, 0:16:4].put_nb(jnp.full((4,), 2, jnp.int32))  # overlaps
    ctx.engine.flush()
    want = np.zeros(16, np.int32)
    want[0:16:2] = 1
    want[0:16:4] = 2
    np.testing.assert_array_equal(np.asarray(ga[0].get()), want)


def test_strided_put_then_covering_contiguous_put(ctx):
    ga = ctx.alloc((12,), jnp.float32)
    ga[0].put(jnp.zeros((12,), jnp.float32))
    ctx.engine.flush()
    ga.at[0, 0:12:3].put_nb(jnp.full((4,), 3.0))
    ga.at[0, 2:9].put_nb(jnp.full((7,), 4.0))      # covers part of it
    ctx.engine.flush()
    want = np.zeros(12, np.float32)
    want[0:12:3] = 3.0
    want[2:9] = 4.0
    np.testing.assert_array_equal(np.asarray(ga[0].get()), want)


def test_disjoint_strided_interleave_still_one_dispatch(ctx):
    """Interleaved columns (disjoint covering proven per element but
    conservative intervals overlap) stay byte-correct regardless of
    how the engine splits them."""
    ga = ctx.alloc((4, 4), jnp.float32)
    ga[3].put(jnp.zeros((4, 4), jnp.float32))
    ctx.engine.flush()
    ga.at[3, :, 0].put_nb(jnp.full((4,), 1.0))
    ga.at[3, :, 3].put_nb(jnp.full((4,), 2.0))
    ctx.engine.flush()
    got = np.asarray(ga[3].get())
    np.testing.assert_array_equal(got[:, 0], 1.0)
    np.testing.assert_array_equal(got[:, 3], 2.0)
    np.testing.assert_array_equal(got[:, 1:3], 0.0)


# ---------------------------------------------------------------------------
# strided accumulate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op,dt", [("sum", jnp.float32), ("max", jnp.int32),
                                   ("prod", jnp.float32), ("min", jnp.int32)])
def test_strided_accumulate_matches_oracle(ctx, op, dt):
    ga = ctx.alloc((5, 4), dt)
    rng = np.random.RandomState(17)
    base = rng.randint(1, 9, size=(5, 4)).astype(np.dtype(dt))
    ga[0].put(jnp.asarray(base))
    ctx.engine.flush()
    upd = rng.randint(1, 9, size=(5,)).astype(np.dtype(dt))
    ga.at[0, :, 2].accumulate(jnp.asarray(upd), op)
    ctx.engine.flush()
    combine = {"sum": np.add, "prod": np.multiply,
               "min": np.minimum, "max": np.maximum}[op]
    want = base.copy()
    want[:, 2] = combine(base[:, 2], upd)
    np.testing.assert_array_equal(np.asarray(ga[0].get()), want)


def test_strided_get_accumulate_returns_pre_values(ctx):
    ga = ctx.alloc((4, 3), jnp.int32)
    base = np.arange(12, dtype=np.int32).reshape(4, 3)
    ga[1].put(jnp.asarray(base))
    ctx.engine.flush()
    old = ga.at[1, :, 1].get_accumulate(jnp.full((4,), 10, jnp.int32), "sum")
    ctx.engine.flush()
    np.testing.assert_array_equal(np.asarray(old), base[:, 1])
    got = np.asarray(ga[1].get())
    np.testing.assert_array_equal(got[:, 1], base[:, 1] + 10)


# ---------------------------------------------------------------------------
# pow2 bucketing of count + plan reuse
# ---------------------------------------------------------------------------

def test_count_buckets_pow2_zero_steady_state_recompiles(ctx):
    """A loop over varying (stride, count) geometries reuses cached
    plans after warmup: under ref the descriptor is pure data, so a
    second sweep of the SAME bucket shapes compiles nothing new."""
    if ctx.engine.impl == "pallas":
        pytest.skip("pallas grids rebucket by (sseg, cb); ref is the "
                    "plan-stability pin (see check_bench_schema)")
    ga = ctx.alloc((16, 8), jnp.float32)
    ga[0].put(jnp.zeros((16, 8), jnp.float32))
    ctx.engine.flush()

    def sweep():
        for count in (2, 3, 5, 8, 13):
            ga.at[0, 0:count, 1].put_nb(
                jnp.full((count,), float(count)))
            ctx.engine.flush()
            _ = ga.at[0, 0:count, 2].get()
    sweep()                                        # warmup: compiles
    c0 = ctx.engine.compile_count
    sweep()                                        # steady state
    assert ctx.engine.compile_count == c0          # zero recompiles
    assert ctx.engine.plan_cache_hits > 0


def test_bucket_pow2_count_floor():
    assert bucket_pow2(1, 1) == 1
    assert bucket_pow2(3, 1) == 4
    assert bucket_pow2(5, 1) == 8
    assert bucket_pow2(8, 1) == 8


# ---------------------------------------------------------------------------
# randomized differential interleavings
# ---------------------------------------------------------------------------

def test_random_interleaved_strided_ops_match_oracle(ctx):
    """Random mixes of contiguous/strided puts + strided sums against a
    numpy mirror, flushed at random points — byte-identical arenas."""
    R, C = 6, 5
    ga = ctx.alloc((R, C), jnp.float32)
    rng = np.random.RandomState(23)
    mirror = {u: np.zeros((R, C), np.float32) for u in ga.units}
    for u in ga.units:
        ga[u].put(jnp.zeros((R, C), jnp.float32))
    ctx.engine.flush()
    for step in range(40):
        u = int(rng.choice(ga.units))
        kind = rng.randint(3)
        if kind == 0:                              # contiguous row put
            r = rng.randint(R)
            v = rng.randn(C).astype(np.float32)
            ga.at[u, r].put_nb(jnp.asarray(v))
            mirror[u][r] = v
        elif kind == 1:                            # strided column put
            c = rng.randint(C)
            v = rng.randn(R).astype(np.float32)
            ga.at[u, :, c].put_nb(jnp.asarray(v))
            mirror[u][:, c] = v
        else:                                      # strided column sum
            c = rng.randint(C)
            v = rng.randn(R).astype(np.float32)
            ga.at[u, :, c].add(jnp.asarray(v))
            mirror[u][:, c] += v
        if rng.rand() < 0.3:
            ctx.engine.flush()
    ctx.engine.flush()
    for u in ga.units:
        np.testing.assert_allclose(np.asarray(ga[u].get()), mirror[u],
                                   rtol=0, atol=0)


# ---------------------------------------------------------------------------
# slice-edge semantics (satellite: step<0 / step>extent / empty)
# ---------------------------------------------------------------------------

def test_negative_step_raises_value_error(ctx):
    ga = ctx.alloc((8,), jnp.float32)
    with pytest.raises(ValueError):
        ga.at[0, ::-1].get()
    with pytest.raises(ValueError):
        ga.at[0, 6:2:-2].put(jnp.zeros((2,), jnp.float32))


def test_step_larger_than_extent_degenerates_to_first(ctx):
    ga = ctx.alloc((8,), jnp.float32)
    ga[0].put(jnp.arange(8, dtype=jnp.float32))
    got = ga.at[0, 0:8:100].get()
    np.testing.assert_array_equal(np.asarray(got), [0.0])


def test_empty_slice_zero_dispatches_born_complete(ctx):
    ga = ctx.alloc((8,), jnp.float32)
    ga[0].put(jnp.arange(8, dtype=jnp.float32))
    ctx.engine.flush()
    d0 = ctx.engine.dispatch_count
    assert ga.at[0, 3:3].get().shape == (0,)
    h = ga.at[0, 5:5].put_nb(jnp.zeros((0,), jnp.float32))
    assert h.state == "complete"
    ctx.engine.flush()
    assert ctx.engine.dispatch_count == d0
    np.testing.assert_array_equal(np.asarray(ga[0].get()),
                                  np.arange(8, dtype=np.float32))


def test_raw_engine_strided_validation(ctx):
    """Engine-level guardrails: bad stride/count geometry raises before
    anything is queued."""
    g = rt.dart_memalloc(ctx, 256, unit=0)
    with pytest.raises(ValueError):
        ctx.engine.put(ctx.heap, ctx.teams_by_slot, g,
                       jnp.zeros((8,), jnp.float32), stride=2, count=4)
    with pytest.raises(ValueError):                # overruns the pool
        ctx.engine.put(ctx.heap, ctx.teams_by_slot, g,
                       jnp.zeros((8,), jnp.float32), stride=1 << 12,
                       count=8)
    with pytest.raises(ValueError):                # count !| total bytes
        ctx.engine.put(ctx.heap, ctx.teams_by_slot, g,
                       jnp.zeros((7,), jnp.float32), stride=64, count=3)
    rt.dart_memfree(ctx, g)
