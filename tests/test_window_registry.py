"""Window registry + per-target flush + overlap-aware coalescing.

Covers the teamlist slot-reuse routing bug (paper §IV.B.2/§IV.B.4):
slots are explicitly reused after ``dart_team_destroy`` while pool ids
grow monotonically, so the old ``slot + 1`` dereference sent a
recreated team's collective pointers to a dropped (or foreign) pool.
Dereference is now keyed through the heap's ``WindowRegistry``
(teamid → live PoolMeta, carried on the Team at creation), and the
engine grew the ``MPI_Win_flush_local(rank, win)`` analogue plus
mixed-size run coalescing.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DART_TEAM_ALL, DartConfig, WindowDestroyedError,
                        dart_exit, dart_flush, dart_get_blocking,
                        dart_get_nb, dart_init, dart_memalloc, dart_put,
                        dart_put_blocking, dart_shm_view, dart_team_create,
                        dart_team_destroy, dart_team_memalloc_aligned,
                        dart_team_memalloc_shared, dart_test, dart_wait,
                        dart_waitall, deref, group_from_units,
                        shm_supported)
from repro.core import runtime as rt


TEAMLIST_IMPLS = ("paper", "freelist")


def _mk_ctx(impl="paper", n_units=4, pool=8192):
    return dart_init(n_units=n_units, config=DartConfig(
        non_collective_pool_bytes=pool, team_pool_bytes=pool,
        teamlist_impl=impl))


@pytest.fixture(params=TEAMLIST_IMPLS)
def ctx(request):
    c = _mk_ctx(request.param)
    yield c
    dart_exit(c)


# ------------------------------------------------- slot-reuse routing ------

def test_destroy_recreate_roundtrip_on_reused_slot(ctx):
    """THE regression: destroy a team, recreate on the same slot, then
    put/get through the new team's collective pointer.  Before the
    window registry this KeyError'd (the new team's slot+1 named the
    dropped pool) or aliased a foreign pool."""
    t1 = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([0, 1]))
    slot1 = ctx.teams[t1].slot
    dart_team_destroy(ctx, t1)
    t2 = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([1, 2]))
    assert ctx.teams[t2].slot == slot1          # slot really is reused
    g = dart_team_memalloc_aligned(ctx, t2, 256)
    val = jnp.arange(16, dtype=jnp.float32) * 2.0
    dart_put_blocking(ctx, g.setunit(2), val)
    out = dart_get_blocking(ctx, g.setunit(2), (16,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(val))


def test_destroy_recreate_no_cross_team_aliasing(ctx):
    """A recreated team's pool starts zeroed and never shows the dead
    team's bytes, and deref resolves to the NEW pool id."""
    t1 = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([0, 1]))
    g1 = dart_team_memalloc_aligned(ctx, t1, 128)
    dart_put_blocking(ctx, g1.setunit(1), jnp.full((8,), 77, jnp.int32))
    old_poolid = ctx.teams[t1].poolid
    dart_team_destroy(ctx, t1)
    t2 = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([0, 1]))
    assert ctx.teams[t2].slot == ctx.teams_by_slot[ctx.teams[t2].slot].slot
    assert ctx.teams[t2].poolid != old_poolid   # pool ids never reused
    g2 = dart_team_memalloc_aligned(ctx, t2, 128)
    pid, row, off = deref(ctx.heap, ctx.teams_by_slot, g2.setunit(1))
    assert pid == ctx.teams[t2].poolid
    out = dart_get_blocking(ctx, g2.setunit(1), (8,), jnp.int32)
    assert np.all(np.asarray(out) == 0)         # fresh zeroed window


def test_many_destroy_create_cycles(ctx):
    """Repeated churn keeps routing correct on every generation."""
    for k in range(5):
        t = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([0, 3]))
        g = dart_team_memalloc_aligned(ctx, t, 64)
        dart_put_blocking(ctx, g.setunit(3), jnp.full((4,), k, jnp.int32))
        out = dart_get_blocking(ctx, g.setunit(3), (4,), jnp.int32)
        assert np.all(np.asarray(out) == k)
        dart_team_destroy(ctx, t)


def test_window_registry_lookup_after_destroy_raises(ctx):
    t = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([0, 1]))
    meta = ctx.heap.windows.lookup(t)
    assert meta.poolid == ctx.teams[t].poolid
    dart_team_destroy(ctx, t)
    with pytest.raises(WindowDestroyedError):
        ctx.heap.windows.lookup(t)


def test_dangling_pointer_semantics(ctx):
    """A pointer retained past its team's destruction is dangling (the
    gptr names the slot, not the teamid — docs/API.md "Windows"): it
    fails deref while the slot is empty, and resolves against the new
    occupant's membership once the slot is reused."""
    t1 = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([0, 1]))
    g1 = dart_team_memalloc_aligned(ctx, t1, 128)
    dart_team_destroy(ctx, t1)
    with pytest.raises(KeyError):           # slot unoccupied
        deref(ctx.heap, ctx.teams_by_slot, g1.setunit(1))
    t2 = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([2, 3]))
    assert ctx.teams[t2].slot == g1.segid   # slot reused
    with pytest.raises(KeyError):           # unit 1 not in the occupant
        deref(ctx.heap, ctx.teams_by_slot, g1.setunit(1))


def test_team_carries_pool_binding(ctx):
    """The binding rides on the Team object from creation."""
    t = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([2, 3]))
    team = ctx.teams[t]
    assert team.poolid == ctx.heap.windows.lookup(t).poolid
    assert team.poolid in ctx.state


# ------------------------------------- destroy with queued engine ops ------

def test_destroy_fails_queued_ops_and_flush_survives(ctx):
    """Queued ops on a destroyed window fail with a clear error, and a
    later whole-engine flush must not KeyError on the dropped pool."""
    t = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([0, 1]))
    g = dart_team_memalloc_aligned(ctx, t, 256)
    gw = dart_memalloc(ctx, 256, unit=0)
    h_doomed = dart_put(ctx, g.setunit(1), jnp.ones((8,), jnp.int32))
    h_get = dart_get_nb(ctx, g.setunit(1), (8,), jnp.int32)
    h_world = dart_put(ctx, gw, jnp.full((8,), 5, jnp.int32))
    dart_team_destroy(ctx, t)
    with pytest.raises(RuntimeError, match="window destroyed"):
        dart_wait(h_doomed)
    with pytest.raises(RuntimeError, match="window destroyed"):
        h_get.value()
    with pytest.raises(RuntimeError, match="window destroyed"):
        dart_test(h_doomed)
    assert h_doomed.state == "failed"
    ctx.engine.flush()                  # must not KeyError on state[pid]
    dart_wait(h_world)                  # the surviving pool is untouched
    out = dart_get_blocking(ctx, gw, (8,), jnp.int32)
    assert np.all(np.asarray(out) == 5)


def test_destroy_waitall_reports_failed_handle(ctx):
    t = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([0, 1]))
    g = dart_team_memalloc_aligned(ctx, t, 128)
    h = dart_put(ctx, g.setunit(0), jnp.ones((4,), jnp.int32))
    dart_team_destroy(ctx, t)
    with pytest.raises(RuntimeError, match="window destroyed"):
        dart_waitall([h])


# ------------------------------------------------- per-target flush --------

def test_per_target_flush_isolation(ctx):
    """The acceptance criterion: flushing unit A's queued puts must not
    dispatch unit B's queued ops on the same pool."""
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 1024)
    ha = [dart_put(ctx, g.setunit(1) + 128 * i,
                   jnp.full((8,), i, jnp.float32)) for i in range(3)]
    hb = [dart_put(ctx, g.setunit(2) + 128 * i,
                   jnp.full((8,), 10 + i, jnp.float32)) for i in range(3)]
    d0 = ctx.engine.dispatch_count
    dart_flush(ctx, g, target=1)
    assert ctx.engine.dispatch_count - d0 == 1      # A's 3 puts, 1 batch
    assert all(h.state != "queued" for h in ha)
    assert all(h.state == "queued" for h in hb)     # B untouched
    assert ctx.engine.pending_ops() == 3
    dart_flush(ctx, g, target=2)
    assert ctx.engine.dispatch_count - d0 == 2
    assert all(h.state != "queued" for h in hb)
    for i in range(3):
        assert np.all(np.asarray(dart_get_blocking(
            ctx, g.setunit(1) + 128 * i, (8,), jnp.float32)) == i)
        assert np.all(np.asarray(dart_get_blocking(
            ctx, g.setunit(2) + 128 * i, (8,), jnp.float32)) == 10 + i)


def test_handle_wait_flushes_only_its_lane(ctx):
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 512)
    h1 = dart_put(ctx, g.setunit(1), jnp.ones((8,), jnp.float32))
    h2 = dart_put(ctx, g.setunit(3), jnp.ones((8,), jnp.float32))
    dart_wait(h1)
    assert h2.state == "queued"                     # other target untouched
    assert ctx.engine.pending_ops() == 1
    dart_wait(h2)
    assert ctx.engine.pending_ops() == 0


def test_typed_ref_flush_per_target(ctx):
    ga = ctx.alloc((8,), jnp.float32)
    with pytest.raises(Exception):
        ga.flush(99)                                # non-member rejected
    h1 = ga[1].put_nb(jnp.full((8,), 1.5, jnp.float32))
    h2 = ga[2].put_nb(jnp.full((8,), 2.5, jnp.float32))
    d0 = ctx.engine.dispatch_count
    ga[1].flush()
    assert ctx.engine.dispatch_count - d0 == 1
    assert h1.state != "queued" and h2.state == "queued"
    ga.flush()                                      # whole-window flush
    assert h2.state != "queued"
    np.testing.assert_array_equal(np.asarray(ga[2].get()),
                                  np.full((8,), 2.5, np.float32))


def test_waitall_coalesces_across_lanes_but_preserves_isolation(ctx):
    """waitall flushes the UNION of its handles' lanes as one epoch —
    N same-size puts to N units stay ONE dispatch — while a queued op
    to a unit outside the handle list keeps accumulating."""
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 512)
    hs = [dart_put(ctx, g.setunit(u), jnp.full((8,), float(u),
                                               jnp.float32))
          for u in range(3)]
    bystander = dart_put(ctx, g.setunit(3), jnp.full((8,), 9.0,
                                                     jnp.float32))
    d0 = ctx.engine.dispatch_count
    dart_waitall(hs)
    assert ctx.engine.dispatch_count - d0 == 1      # one coalesced batch
    assert bystander.state == "queued"              # lane 3 untouched
    dart_wait(bystander)
    for u in range(3):
        assert np.all(np.asarray(dart_get_blocking(
            ctx, g.setunit(u), (8,), jnp.float32)) == u)


def test_dart_flush_target_without_gptr_rejected(ctx):
    with pytest.raises(ValueError):
        dart_flush(ctx, None, target=1)


def test_get_nb_value_flushes_only_own_lane(ctx):
    """A read of unit A must see A's queued puts but leave B queued."""
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 256)
    dart_put(ctx, g.setunit(1), jnp.full((4,), 7.0, jnp.float32))
    hb = dart_put(ctx, g.setunit(2), jnp.full((4,), 8.0, jnp.float32))
    out = dart_get_nb(ctx, g.setunit(1), (4,), jnp.float32).value()
    assert np.all(np.asarray(out) == 7.0)           # RAW ordering on A
    assert hb.state == "queued"                     # B still accumulating
    dart_wait(hb)


# -------------------------------------------- overlap-aware coalescing -----

def test_mixed_size_disjoint_puts_one_dispatch(ctx):
    """The acceptance criterion: N non-overlapping puts of DIFFERENT
    sizes coalesce into ONE pad-to-max segmented dispatch."""
    g = dart_memalloc(ctx, 4096, unit=0)
    sizes = [4, 16, 8, 32, 1, 24]
    hs = []
    d0, c0 = ctx.engine.dispatch_count, ctx.engine.ops_coalesced
    for i, n in enumerate(sizes):
        hs.append(dart_put(ctx, g + 256 * i,
                           jnp.full((n,), float(i + 1), jnp.float32)))
    dart_flush(ctx)
    assert ctx.engine.dispatch_count - d0 == 1
    assert ctx.engine.ops_coalesced - c0 == len(sizes)
    dart_waitall(hs)
    for i, n in enumerate(sizes):
        out = np.asarray(dart_get_blocking(ctx, g + 256 * i,
                                           (n,), jnp.float32))
        assert np.all(out == i + 1)
        # the padded window must not have smeared past the payload
        tail = np.asarray(dart_get_blocking(
            ctx, g + 256 * i + 4 * n, (4,), jnp.float32))
        assert np.all(tail == 0)


def test_mixed_size_disjoint_rows_share_dispatch(ctx):
    """Disjointness is per-row: same offsets on different units never
    overlap, so mixed sizes still share the dispatch."""
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 512)
    d0 = ctx.engine.dispatch_count
    hs = [dart_put(ctx, g.setunit(u), jnp.full((4 * (u + 1),), float(u),
                                               jnp.float32))
          for u in range(4)]
    dart_flush(ctx)
    assert ctx.engine.dispatch_count - d0 == 1
    dart_waitall(hs)
    for u in range(4):
        out = np.asarray(dart_get_blocking(ctx, g.setunit(u),
                                           (4 * (u + 1),), jnp.float32))
        assert np.all(out == u)


def test_overlapping_mixed_size_puts_split_and_order(ctx):
    """Overlapping ranges of different sizes must NOT share a hoisted
    dispatch: program order (last writer wins) is preserved by run
    splitting."""
    g = dart_memalloc(ctx, 512, unit=0)
    d0 = ctx.engine.dispatch_count
    dart_put(ctx, g, jnp.full((8,), 1.0, jnp.float32))       # 32B
    dart_put(ctx, g + 16, jnp.full((2,), 2.0, jnp.float32))  # 8B, overlaps
    dart_flush(ctx)
    assert ctx.engine.dispatch_count - d0 == 2               # split
    out = np.asarray(dart_get_blocking(ctx, g, (8,), jnp.float32))
    np.testing.assert_array_equal(out, [1, 1, 1, 1, 2, 2, 1, 1])


def test_mixed_size_gets_one_dispatch(ctx):
    g = dart_memalloc(ctx, 2048, unit=1)
    sizes = [4, 12, 8]
    for i, n in enumerate(sizes):
        dart_put_blocking(ctx, g + 128 * i,
                          (jnp.arange(n) + 10 * i).astype(jnp.float32))
    hs = [dart_get_nb(ctx, g + 128 * i, (n,), jnp.float32)
          for i, n in enumerate(sizes)]
    d0 = ctx.engine.dispatch_count
    dart_flush(ctx)
    assert ctx.engine.dispatch_count - d0 == 1
    for i, (n, h) in enumerate(zip(sizes, hs)):
        np.testing.assert_array_equal(
            np.asarray(h.value()), np.arange(n, dtype=np.float32) + 10 * i)


def test_overlapping_mixed_size_gets_still_coalesce(ctx):
    """Reads commute: overlapping gets of different sizes need no
    disjointness split — one dispatch, each decoding its own prefix."""
    g = dart_memalloc(ctx, 512, unit=0)
    dart_put_blocking(ctx, g, jnp.arange(8, dtype=jnp.float32))
    hs = [dart_get_nb(ctx, g, (8,), jnp.float32),
          dart_get_nb(ctx, g + 16, (2,), jnp.float32)]   # overlaps
    d0 = ctx.engine.dispatch_count
    dart_flush(ctx)
    assert ctx.engine.dispatch_count - d0 == 1
    np.testing.assert_array_equal(np.asarray(hs[0].value()),
                                  np.arange(8, dtype=np.float32))
    np.testing.assert_array_equal(np.asarray(hs[1].value()), [4.0, 5.0])


def test_mixed_sizes_near_pool_end_stay_correct(ctx):
    """Headroom guard: a small put hard against the pool end must not
    join a larger-padded run (the padded window would clamp its start).
    Correct bytes either way; this pins the semantics, not the count."""
    pool = ctx.config.non_collective_pool_bytes
    g = dart_memalloc(ctx, 4096, unit=0)
    big = jnp.full((64,), 3.0, jnp.float32)              # 256B at offset 0
    small_off = pool - 4                                 # last 4 bytes
    tail_ptr = g + (small_off - g.addr)
    dart_put(ctx, g, big)
    dart_put(ctx, tail_ptr, jnp.full((1,), 9.0, jnp.float32))
    dart_flush(ctx)
    assert np.all(np.asarray(
        dart_get_blocking(ctx, g, (64,), jnp.float32)) == 3.0)
    assert np.all(np.asarray(
        dart_get_blocking(ctx, tail_ptr, (1,), jnp.float32)) == 9.0)


def test_same_size_runs_unchanged(ctx):
    """The pre-registry uniform rule still holds: same-size overlapping
    puts share one in-order dispatch (last writer wins)."""
    g = dart_memalloc(ctx, 256, unit=0)
    d0 = ctx.engine.dispatch_count
    dart_put(ctx, g, jnp.full((8,), 1.0, jnp.float32))
    dart_put(ctx, g, jnp.full((8,), 2.0, jnp.float32))
    dart_flush(ctx)
    assert ctx.engine.dispatch_count - d0 == 1
    assert np.all(np.asarray(
        dart_get_blocking(ctx, g, (8,), jnp.float32)) == 2.0)


# ------------------------------------------------- shm read-path fixes -----

def test_shm_view_flushes_target_lane(ctx):
    """Direct dart_shm_view callers must see queued puts (the 'every
    read path flushes first' invariant)."""
    if not shm_supported(ctx):
        pytest.skip("backend arenas not host-visible")
    gs = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 256)
    dart_put(ctx, gs.setunit(2), jnp.full((8,), 4.5, jnp.float32))
    view = dart_shm_view(ctx, gs.setunit(2), (8,), jnp.float32)
    assert np.all(np.asarray(view) == 4.5)


def test_shm_supported_empty_state_returns_false():
    c = _mk_ctx()
    shm_supported(c)                        # warm the per-context cache
    dart_exit(c)
    # liveness must trump the warm cache: no stale True, no StopIteration
    assert shm_supported(c) is False


def test_shm_supported_probes_addressed_pool(ctx):
    t = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([0, 1]))
    pid = ctx.teams[t].poolid
    backend_visible = shm_supported(ctx)    # warms the cache
    assert shm_supported(ctx, pid) == backend_visible
    dart_team_destroy(ctx, t)
    # the dropped pool must report False even with the cache warm
    assert shm_supported(ctx, pid) is False
    assert shm_supported(ctx, poolid=10**6) is False        # absent pool
    assert shm_supported(ctx) == backend_visible            # others intact


# ------------------------------------- typed collectives: one dispatch -----

def test_gather_typed_single_counted_dispatch(ctx):
    # shm=False: this test pins the jitted-engine dispatch contract;
    # the shm-direct (0-dispatch) route is tests/test_shm_plane.py's
    ga = ctx.alloc((4,), jnp.float32, shm=False)
    for u in range(4):
        ga[u].put(jnp.full((4,), float(u), jnp.float32))
    d0 = ctx.engine.dispatch_count
    rows = ga.gather()
    assert ctx.engine.dispatch_count - d0 == 1
    np.testing.assert_array_equal(
        np.asarray(rows),
        np.repeat(np.arange(4, dtype=np.float32)[:, None], 4, axis=1))


def test_scatter_typed_single_counted_dispatch(ctx):
    ga = ctx.alloc((4,), jnp.int32, shm=False)
    vals = jnp.arange(16, dtype=jnp.int32).reshape(4, 4)
    d0 = ctx.engine.dispatch_count
    rt.dart_scatter_typed(ctx, ga.gptr, vals)
    assert ctx.engine.dispatch_count - d0 == 1
    for u in range(4):
        np.testing.assert_array_equal(np.asarray(ga[u].get()),
                                      np.asarray(vals[u]))


def test_scatter_typed_roundtrip_dtypes(ctx):
    for dtype in (jnp.float32, jnp.int32, jnp.bfloat16):
        ga = ctx.alloc((3,), dtype)
        vals = (jnp.arange(12).reshape(4, 3) + 1).astype(dtype)
        ga.scatter(vals)
        got = ga.gather()
        assert (np.asarray(got).tobytes() == np.asarray(vals).tobytes())
        ga.free()
