"""Tests for the locality-aware non-blocking engine (CommEngine):
coalesced flush, handle state machine, shm fast path, dispatch counts."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (DART_TEAM_ALL, DartConfig, Locality,
                        classify_locality, dart_exit, dart_flush, dart_get,
                        dart_get_blocking, dart_get_nb, dart_init,
                        dart_memalloc, dart_put, dart_put_blocking,
                        dart_team_memalloc_aligned,
                        dart_team_memalloc_shared, dart_test, dart_testall,
                        dart_wait, dart_waitall, shm_supported)
from repro.core import onesided as _os


@pytest.fixture()
def ctx(engine_impl):
    # engine-impl parametrization (conftest.py): every ctx-based test
    # in this module runs under both impl='ref' and impl='pallas'
    c = dart_init(n_units=4, config=DartConfig(
        non_collective_pool_bytes=8192, team_pool_bytes=8192))
    c.engine.impl = engine_impl
    yield c
    dart_exit(c)


# ----------------------------------------------------- handle lifecycle ----

def test_handle_state_machine(ctx):
    g = dart_memalloc(ctx, 512, unit=1)
    h = dart_put(ctx, g, jnp.arange(8, dtype=jnp.float32))
    assert h.state == "queued"
    assert not dart_test(h)                 # false before flush
    dart_flush(ctx)
    assert h.state in ("issued", "complete")
    dart_wait(h)
    assert dart_test(h)                     # true after flush+wait
    assert h.state == "complete"


def test_wait_on_queued_handle_triggers_flush(ctx):
    g = dart_memalloc(ctx, 256, unit=0)
    h = dart_put(ctx, g, jnp.full((16,), 3, jnp.int32))
    assert ctx.engine.pending_ops() == 1
    dart_wait(h)                            # implicit epoch close
    assert ctx.engine.pending_ops() == 0
    out = dart_get_blocking(ctx, g, (16,), jnp.int32)
    assert np.all(np.asarray(out) == 3)


def test_get_nb_value_flushes(ctx):
    g = dart_memalloc(ctx, 256, unit=2)
    dart_put(ctx, g, jnp.arange(4, dtype=jnp.int32))     # still queued
    h = dart_get_nb(ctx, g, (4,), jnp.int32)
    assert h.state == "queued" and not h.test()
    np.testing.assert_array_equal(np.asarray(h.value()), [0, 1, 2, 3])
    assert h.state == "complete"


def test_waitall_testall_mixed_pools(ctx):
    """Handles over the WORLD pool and a team pool in one epoch."""
    gw = dart_memalloc(ctx, 512, unit=0)
    gt = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 512)
    hs = [dart_put(ctx, gw, jnp.full((8,), 1, jnp.int32)),
          dart_put(ctx, gt.setunit(2), jnp.full((8,), 2, jnp.int32)),
          dart_put(ctx, gw + 128, jnp.full((8,), 3, jnp.int32)),
          dart_put(ctx, gt.setunit(3), jnp.full((8,), 4, jnp.int32))]
    assert not dart_testall(hs)
    dart_waitall(hs)
    assert dart_testall(hs)
    assert np.all(np.asarray(
        dart_get_blocking(ctx, gw, (8,), jnp.int32)) == 1)
    assert np.all(np.asarray(
        dart_get_blocking(ctx, gt.setunit(2), (8,), jnp.int32)) == 2)
    assert np.all(np.asarray(
        dart_get_blocking(ctx, gw + 128, (8,), jnp.int32)) == 3)
    assert np.all(np.asarray(
        dart_get_blocking(ctx, gt.setunit(3), (8,), jnp.int32)) == 4)


def test_flush_single_pool_leaves_other_queued(ctx):
    gw = dart_memalloc(ctx, 256, unit=0)
    gt = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 256)
    hw = dart_put(ctx, gw, jnp.ones((4,), jnp.int32))
    ht = dart_put(ctx, gt, jnp.ones((4,), jnp.int32))
    dart_flush(ctx, gw)
    assert hw.state != "queued"
    assert ht.state == "queued"
    dart_flush(ctx)
    assert ht.state != "queued"


# ------------------------------------------------ coalescing + counters ----

def test_coalesced_flush_fewer_dispatches_and_bit_identical(ctx):
    """The acceptance-criterion test: N queued puts flush as ONE jitted
    dispatch (vs N for the blocking path), with identical bytes."""
    n_ops = 8
    g = dart_memalloc(ctx, 4096, unit=0)

    # blocking baseline: one dispatch per put
    d0 = ctx.engine.dispatch_count
    for k in range(n_ops):
        dart_put_blocking(ctx, g + 128 * k,
                          jnp.full((13,), float(k), jnp.float32))
    blocking_dispatches = ctx.engine.dispatch_count - d0
    assert blocking_dispatches == n_ops
    blocking_bytes = [np.asarray(dart_get_blocking(
        ctx, g + 128 * k, (13,), jnp.float32)).tobytes()
        for k in range(n_ops)]

    # coalesced: same values through the queue, one dispatch total
    for k in range(n_ops):          # clear the slots first
        dart_put_blocking(ctx, g + 128 * k, jnp.zeros((13,), jnp.float32))
    d0 = ctx.engine.dispatch_count
    hs = [dart_put(ctx, g + 128 * k,
                   jnp.full((13,), float(k), jnp.float32))
          for k in range(n_ops)]
    dart_flush(ctx)
    coalesced_dispatches = ctx.engine.dispatch_count - d0
    assert coalesced_dispatches == 1
    assert coalesced_dispatches < blocking_dispatches
    dart_waitall(hs)
    for k in range(n_ops):
        got = np.asarray(dart_get_blocking(
            ctx, g + 128 * k, (13,), jnp.float32)).tobytes()
        assert got == blocking_bytes[k]


def test_coalesced_gets_one_dispatch(ctx):
    g = dart_memalloc(ctx, 2048, unit=1)
    for k in range(6):
        dart_put_blocking(ctx, g + 128 * k, jnp.full((4,), k, jnp.int32))
    hs = [dart_get_nb(ctx, g + 128 * k, (4,), jnp.int32) for k in range(6)]
    d0 = ctx.engine.dispatch_count
    dart_flush(ctx)
    assert ctx.engine.dispatch_count - d0 == 1
    for k, h in enumerate(hs):
        assert np.all(np.asarray(h.value()) == k)


def test_program_order_overlapping_puts_last_writer_wins(ctx):
    g = dart_memalloc(ctx, 256, unit=0)
    dart_put(ctx, g, jnp.full((8,), 1, jnp.float32))
    dart_put(ctx, g, jnp.full((8,), 2, jnp.float32))     # same size: one run
    dart_put(ctx, g, jnp.full((4,), 3, jnp.float32))     # new size: new run
    dart_flush(ctx)
    out = np.asarray(dart_get_blocking(ctx, g, (8,), jnp.float32))
    np.testing.assert_array_equal(out, [3, 3, 3, 3, 2, 2, 2, 2])


def test_queued_put_bounds_checked_at_initiation(ctx):
    g = dart_memalloc(ctx, 128, unit=0)
    near_end = g + (ctx.config.non_collective_pool_bytes - 4 - g.addr)
    with pytest.raises(ValueError):
        dart_put(ctx, near_end, jnp.zeros(16, jnp.float32))
    assert ctx.engine.pending_ops() == 0     # nothing was enqueued


def test_epoch_counter_advances_on_flush(ctx):
    g = dart_memalloc(ctx, 256, unit=0)
    e0 = ctx.engine.epoch
    dart_put(ctx, g, jnp.ones((4,), jnp.float32))
    assert ctx.engine.epoch == e0            # enqueue is not an epoch close
    dart_flush(ctx)
    assert ctx.engine.epoch == e0 + 1
    dart_flush(ctx)                          # empty flush: no epoch close
    assert ctx.engine.epoch == e0 + 1


def test_get_nb_dropped_by_clear_raises():
    """A queued get whose op was cleared (dart_exit) must raise from
    value(), not silently return None."""
    ctx = dart_init(n_units=2, config=DartConfig(
        non_collective_pool_bytes=1024, team_pool_bytes=1024))
    g = dart_memalloc(ctx, 256, unit=0)
    h = dart_get_nb(ctx, g, (4,), jnp.int32)
    dart_exit(ctx)                          # engine.clear() drops the op
    with pytest.raises(RuntimeError):
        h.value()


def test_put_dropped_by_clear_raises_on_wait():
    """Same for a queued put: wait()/waitall must not report a lost
    write as success."""
    ctx = dart_init(n_units=2, config=DartConfig(
        non_collective_pool_bytes=1024, team_pool_bytes=1024))
    g = dart_memalloc(ctx, 256, unit=0)
    h1 = dart_put(ctx, g, jnp.ones((4,), jnp.int32))
    h2 = dart_put(ctx, g + 128, jnp.ones((4,), jnp.int32))
    dart_exit(ctx)
    with pytest.raises(RuntimeError):
        dart_wait(h1)
    with pytest.raises(RuntimeError):
        dart_waitall([h2])


# ----------------------------------------------------- shm fast path -------

def test_shm_fastpath_equivalence_and_zero_dispatch(ctx):
    """Zero-copy read == jitted-get result byte-for-byte, with no jitted
    dispatch issued by the routed blocking get."""
    if not shm_supported(ctx):
        pytest.skip("backend arenas not host-visible")
    gs = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 1024)
    val = jnp.arange(32, dtype=jnp.float32) * 1.5
    dart_put_blocking(ctx, gs.setunit(1), val)
    assert classify_locality(ctx, gs) is Locality.SHM_LOCAL

    jitted = _os.dart_get_blocking(ctx.state, ctx.heap, ctx.teams_by_slot,
                                   gs.setunit(1), (32,), jnp.float32)
    d0 = ctx.engine.dispatch_count
    routed = dart_get_blocking(ctx, gs.setunit(1), (32,), jnp.float32)
    assert ctx.engine.dispatch_count == d0   # no jitted dispatch
    assert np.asarray(routed).tobytes() == np.asarray(jitted).tobytes()


def test_shm_fastpath_sees_queued_puts(ctx):
    """The locality route must flush the pool first (RAW ordering)."""
    if not shm_supported(ctx):
        pytest.skip("backend arenas not host-visible")
    gs = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 256)
    dart_put(ctx, gs.setunit(2), jnp.full((8,), 9.0, jnp.float32))  # queued
    out = dart_get_blocking(ctx, gs.setunit(2), (8,), jnp.float32)
    assert np.all(np.asarray(out) == 9.0)


def test_non_shm_pointer_classifies_remote(ctx):
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 128)
    assert classify_locality(ctx, g) is Locality.REMOTE


# ------------------------------------------------- benchmark smoke ---------

@pytest.mark.slow
def test_put_get_benchmark_quick_runs_new_series():
    """`benchmarks/put_get.py` must run the coalesced + shm_fastpath
    series (acceptance criterion); quick mode keeps this cheap."""
    from benchmarks.common import Report
    from benchmarks import put_get
    report = Report()
    put_get.run(report, full=False, repeats=2, quick=True)
    names = [name for name, _, _ in report.rows]
    assert any(n.startswith("coalesced/put_flush/") for n in names)
    assert any(n.startswith("coalesced/get_flush/") for n in names)
    assert any(n.startswith("shm_fastpath/") for n in names)
    # typed GlobalArray front-end series: blocking put/get overhead vs
    # the raw byte API, the coalesced non-blocking path, and the
    # constant-overhead model fit
    assert any(n.startswith("typed_api/put/") for n in names)
    assert any(n.startswith("typed_api/get/") for n in names)
    assert any(n.startswith("typed_api/put_nb_coalesced/") for n in names)
    assert any(n.startswith("typed_api/overhead_fit/") for n in names)


@pytest.mark.slow
def test_engine_profile_machine_readable():
    """`benchmarks.run` emits BENCH_engine.json from this profile: the
    dispatch-count wins (coalescing, per-target isolation, mixed-size
    hoisting) must be present and assertable in the payload."""
    from benchmarks import put_get
    profile = put_get.engine_profile(repeats=2, quick=True)
    s = profile["series"]
    assert profile["schema"] == "BENCH_engine/v8"
    assert s["blocking"]["dispatches"] == profile["n_ops"]
    assert s["coalesced"]["dispatches"] == 1
    assert s["mixed_size_coalesced"]["dispatches"] == 1
    assert s["per_target_flush"]["dispatches_target_only"] == 1
    assert s["per_target_flush"]["ops_left_queued"] == profile["n_ops"] // 2
    # flush cost model: a warm (plan-cache-hit) flush must beat the
    # cold (compile) flush by >= 5x, and the steady-state loop of
    # varying-size epochs must not recompile at all
    fc = profile["flush_cost"]
    assert fc["compiles_cold"] >= 1
    assert fc["recompiles_steady_state"] == 0
    assert fc["cold_vs_warm_speedup"] >= 5.0
    assert profile["plan_cache"]["plan_cache_hits"] > 0
    # v3 reduce plane: N accumulates coalesce into ONE dispatch (vs
    # n_ops blocking), and the varying (shape, dtype, op)
    # allreduce+accumulate steady-state loop performs zero recompiles
    # — the assertable form of the shape-stable-allreduce ROADMAP item
    rp = profile["reduce_plane"]
    assert rp["acc_dispatches_blocking"] == profile["n_ops"]
    assert rp["acc_dispatches_coalesced"] == 1
    assert rp["allreduce_compiles_cold"] >= 1
    assert rp["allreduce_warm_recompiles"] == 0
    assert rp["recompiles_steady_state"] == 0
    # v6 strided IR: a column of N elements is ONE dispatch, its µs/op
    # stays within ~2x of the contiguous row path, and a varying-stride
    # loop at fixed buckets never recompiles (stride is plan DATA)
    sd = profile["strided"]
    assert sd["dispatches_per_strided_put"] == 1
    assert sd["dispatches_per_strided_get"] == 1
    assert sd["recompiles_steady_state"] == 0
    nr = profile["narray"]
    assert nr["get_col_dispatches"] <= nr["owning_tiles"]
    # v8 shm plane: a locality-routed put on a host-visible arena is a
    # locked host-side memcpy — zero jitted dispatches, >= 5x faster
    # than the jitted blocking put — and intra-node collectives run
    # shm-direct at zero dispatches with no steady-state recompiles
    sp = profile["shm_plane"]
    assert sp["shm_put_dispatches"] == 0
    assert sp["shm_put_speedup"] >= 5.0
    assert sp["broadcast_dispatches"] == 0
    assert sp["gather_dispatches"] == 0
    assert sp["scatter_dispatches"] == 0
    assert sp["recompiles_steady_state"] == 0
    import json
    json.dumps(profile)                  # machine-readable, no jnp leaks


# ------------------------------------------------- property-based ----------

@given(st.integers(2, 6), st.integers(0, 48),
       st.sampled_from(["float32", "int32", "bfloat16", "uint8"]),
       st.integers(1, 32))
@settings(max_examples=15, deadline=None)
def test_engine_roundtrip_property(n_units, word_off, dtype, n):
    """put → flush → get identity under random offsets/dtypes/units."""
    ctx = dart_init(n_units=n_units, config=DartConfig(
        non_collective_pool_bytes=4096, team_pool_bytes=4096))
    try:
        g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 2048)
        ptr = g.setunit(word_off % n_units) + word_off * 4
        val = (jnp.arange(n) + 1).astype(dtype)
        h = dart_put(ctx, ptr, val)
        dart_flush(ctx)
        dart_wait(h)
        out = dart_get_blocking(ctx, ptr, (n,), dtype)
        assert (np.asarray(out).tobytes() == np.asarray(val).tobytes())
    finally:
        dart_exit(ctx)


@given(st.integers(1, 10), st.integers(0, 7))
@settings(max_examples=10, deadline=None)
def test_engine_many_puts_property(k, base_slot):
    """k queued same-size puts to distinct slots flush to one dispatch
    and every slot reads back its own payload."""
    ctx = dart_init(n_units=2, config=DartConfig(
        non_collective_pool_bytes=8192, team_pool_bytes=8192))
    try:
        g = dart_memalloc(ctx, 4096, unit=1)
        d0 = ctx.engine.dispatch_count
        hs = [dart_put(ctx, g + 128 * (base_slot + i),
                       jnp.full((7,), float(i), jnp.float32))
              for i in range(k)]
        dart_flush(ctx)
        assert ctx.engine.dispatch_count - d0 == 1
        dart_waitall(hs)
        for i in range(k):
            out = dart_get_blocking(ctx, g + 128 * (base_slot + i),
                                    (7,), jnp.float32)
            assert np.all(np.asarray(out) == i)
    finally:
        dart_exit(ctx)
