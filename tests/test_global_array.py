"""Tests for the typed GlobalArray front-end over the byte-offset DART
core (docs/API.md): allocators, NumPy-style addressing, engine
coalescing, typed collectives, local zero-copy view, epochs."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (DART_TEAM_ALL, DartConfig, GlobalArray, GlobalRef,
                        OutOfGlobalMemory, dart_exit, dart_init,
                        dart_team_create, group_from_units, shm_supported)
from repro.core.array import _element_run


@pytest.fixture()
def ctx(engine_impl):
    # engine-impl parametrization (conftest.py): every ctx-based test
    # in this module runs under both impl='ref' and impl='pallas'
    c = dart_init(n_units=4, config=DartConfig(
        non_collective_pool_bytes=8192, team_pool_bytes=8192))
    c.engine.impl = engine_impl
    yield c
    dart_exit(c)


# ------------------------------------------------------- allocators --------

def test_ctx_alloc_identity_and_roundtrip(ctx):
    ga = ctx.alloc((8,), jnp.float32)
    assert isinstance(ga, GlobalArray)
    assert ga.units == (0, 1, 2, 3)
    assert ga.shape == (8,) and ga.dtype == jnp.dtype(jnp.float32)
    assert ga.nbytes_per_unit == 32
    val = jnp.arange(8, dtype=jnp.float32)
    ga[2].put(val)
    np.testing.assert_array_equal(np.asarray(ga[2].get()), np.asarray(val))
    # other units untouched
    assert np.all(np.asarray(ga[1].get()) == 0)


def test_team_alloc_scopes_units(ctx):
    team = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([1, 3]))
    ga = ctx.teams[team].alloc(ctx, (4,), jnp.int32)
    assert ga.units == (1, 3)
    ga[3].put(np.array([5, 6, 7, 8]))
    np.testing.assert_array_equal(np.asarray(ga[3].get()), [5, 6, 7, 8])
    with pytest.raises(KeyError):
        ga[0]                                  # not a member
    with pytest.raises(KeyError):
        ga.at[2, 0:2]


def test_alloc_overflow_raises_out_of_global_memory(ctx):
    # a GlobalArray-sized request that overflows team_pool_bytes (8192)
    with pytest.raises(OutOfGlobalMemory):
        ctx.alloc((4096,), jnp.float32)        # 16 KiB per unit


def test_free_then_realloc_reuses_coalesced_block(ctx):
    """dart_team_memfree → re-alloc returns the coalesced block."""
    a = ctx.alloc((256,), jnp.float32)         # 1 KiB
    b = ctx.alloc((256,), jnp.float32)
    assert b.gptr.addr > a.gptr.addr
    a_addr = a.gptr.addr
    a.free()
    b.free()
    # both holes coalesced: a single allocation spanning the combined
    # extent fits again, at the first block's offset
    c = ctx.alloc((512,), jnp.float32)
    assert c.gptr.addr == a_addr


# ------------------------------------------------------- addressing --------

def test_at_slicing_translates_to_element_runs(ctx):
    ga = ctx.alloc((8,), jnp.float32)
    ga[1].put(jnp.zeros((8,), jnp.float32))
    ga.at[1, 3:7].put(jnp.full((4,), 9.0))
    out = np.asarray(ga[1].get())
    np.testing.assert_array_equal(out, [0, 0, 0, 9, 9, 9, 9, 0])
    np.testing.assert_array_equal(np.asarray(ga.at[1, 3:7].get()),
                                  [9.0] * 4)
    # scalar element, negative index
    assert float(np.asarray(ga.at[1, 3].get())) == 9.0
    assert float(np.asarray(ga.at[1, -1].get())) == 0.0
    ga.at[1, -1].put(2.5)                      # scalar broadcast put
    assert float(np.asarray(ga.at[1, 7].get())) == 2.5


def test_ref_chaining_and_gptr_consistency(ctx):
    ga = ctx.alloc((16,), jnp.int32)
    ref = ga[2][4:12][2:4]                     # chained slicing composes
    assert ref.shape == (2,) and ref.offset == 6
    # the substrate pointer is base + element_offset * itemsize
    assert ref.gptr - ga.gptr.setunit(2) == 6 * 4
    ref.put(np.array([11, 22]))
    out = np.asarray(ga[2].get())
    assert out[6] == 11 and out[7] == 22


def test_multidim_leading_axis_runs(ctx):
    ga = ctx.alloc((4, 3), jnp.float32)
    ga[0].put(jnp.arange(12, dtype=jnp.float32).reshape(4, 3))
    # whole row (integer leading index)
    np.testing.assert_array_equal(np.asarray(ga.at[0, 2].get()),
                                  [6.0, 7.0, 8.0])
    # contiguous row range
    np.testing.assert_array_equal(
        np.asarray(ga.at[0, 1:3].get()),
        np.arange(3, 9, dtype=np.float32).reshape(2, 3))
    # element inside a row
    assert float(np.asarray(ga.at[0, 2, 1].get())) == 7.0


def test_non_contiguous_indexing_lowers_or_rejects():
    # strided selections now lower to ONE (seg, stride, count) run
    assert _element_run((8,), slice(0, 8, 2)) == (0, (4,), 1, 2, 4)
    assert _element_run((4, 3), (slice(1, 3), 1)) == (4, (2,), 1, 3, 2)
    assert _element_run((4, 3), (slice(1, 3), slice(0, 2))) == (3, (2, 2), 2, 3, 2)
    # column selections after a FULL slice are strided runs too
    assert _element_run((4, 3), (slice(None), 1)) == (1, (4,), 1, 3, 4)
    assert _element_run((4, 3), (slice(None), slice(0, 2))) == (0, (4, 2), 2, 3, 4)
    # genuinely unaddressable: >1 strided level after dense-tail collapse
    with pytest.raises(IndexError):
        _element_run((4, 3, 2), (slice(0, 4, 2), slice(0, 2), slice(0, 1)))
    with pytest.raises(IndexError):
        _element_run((4,), (1, 2))             # too many indices
    with pytest.raises(IndexError):
        _element_run((4,), 4)                  # out of range
    with pytest.raises(TypeError):
        _element_run((4,), "x")
    with pytest.raises(ValueError):
        _element_run((8,), slice(None, None, -1))  # negative step
    # step > extent degenerates to the first element, not an error
    assert _element_run((8,), slice(0, 8, 16)) == (0, (1,), 1, 0, 1)
    # empty slice -> zero-element marker run
    assert _element_run((8,), slice(3, 3)) == (3, (0,), 0, 0, 1)
    # full trailing slices stay contiguous (stride 0 / count 1 degenerate)
    assert _element_run((4, 3), (slice(1, 3), slice(None))) == (3, (2, 3), 6, 0, 1)
    assert _element_run((4, 3), (slice(None), slice(None))) == (0, (4, 3), 12, 0, 1)


def test_put_shape_mismatch_raises(ctx):
    ga = ctx.alloc((8,), jnp.float32)
    with pytest.raises(ValueError):
        ga.at[0, 0:4].put(jnp.zeros((5,), jnp.float32))


# --------------------------------------------- engine lowering / epochs ----

def test_put_nb_distinct_units_flush_as_one_dispatch(ctx):
    """ACCEPTANCE: N typed put_nb calls to distinct units flush as
    exactly 1 engine dispatch (ctx.engine.dispatch_count)."""
    ga = ctx.alloc((8,), jnp.float32)
    d0 = ctx.engine.dispatch_count
    hs = [ga[u].put_nb(jnp.full((8,), float(u))) for u in ga.units]
    assert all(h.state == "queued" for h in hs)
    assert ctx.engine.dispatch_count == d0     # nothing dispatched yet
    with ctx.epoch():
        pass                                   # close the epoch
    assert ctx.engine.dispatch_count - d0 == 1
    assert all(h.state != "queued" for h in hs)
    for u in ga.units:
        assert np.all(np.asarray(ga[u].get()) == float(u))


def test_epoch_context_flushes_queued_ops(ctx):
    ga = ctx.alloc((4,), jnp.int32)
    with ctx.epoch():
        h = ga[1].put_nb(np.array([1, 2, 3, 4]))
        assert h.state == "queued"
        assert ctx.engine.pending_ops() == 1
    assert h.state != "queued"
    assert ctx.engine.pending_ops() == 0


def test_array_epoch_scopes_to_own_pool(ctx):
    team = dart_team_create(ctx, DART_TEAM_ALL, group_from_units([0, 1]))
    ga_all = ctx.alloc((4,), jnp.int32)
    ga_team = ctx.teams[team].alloc(ctx, (4,), jnp.int32)
    with ga_team.epoch():
        h_all = ga_all[0].put_nb(np.ones(4, np.int32))
        h_team = ga_team[1].put_nb(np.ones(4, np.int32))
    assert h_team.state != "queued"            # team pool flushed
    assert h_all.state == "queued"             # other pool still open
    with ctx.epoch():
        pass
    assert h_all.state != "queued"


def test_get_nb_value_flushes_and_sees_queued_puts(ctx):
    ga = ctx.alloc((6,), jnp.float32)
    ga[3].put_nb(jnp.arange(6, dtype=jnp.float32))   # still queued
    h = ga[3].get_nb()
    assert h.state == "queued"
    np.testing.assert_array_equal(np.asarray(h.value()),
                                  np.arange(6, dtype=np.float32))
    assert h.state == "complete"


# ------------------------------------------------- typed collectives -------

def test_allreduce_broadcast_gather_scatter(ctx):
    ga = ctx.alloc((4,), jnp.float32)
    with ctx.epoch():
        for u in ga.units:
            ga[u].put_nb(jnp.full((4,), float(u + 1)))
    red = ga.allreduce("sum")
    np.testing.assert_array_equal(np.asarray(red), [10.0] * 4)  # 1+2+3+4
    # allreduce replaced every member's block
    np.testing.assert_array_equal(np.asarray(ga[2].get()), [10.0] * 4)

    ga[1].put(jnp.array([7.0, 8.0, 9.0, 10.0]))
    ga.broadcast(1).wait()
    gat = np.asarray(ga.gather())
    assert gat.shape == (4, 4)
    np.testing.assert_array_equal(gat, np.tile([7, 8, 9, 10], (4, 1)))

    vals = np.arange(16, dtype=np.float32).reshape(4, 4)
    ga.scatter(vals)
    for i, u in enumerate(ga.units):
        np.testing.assert_array_equal(np.asarray(ga[u].get()), vals[i])
    with pytest.raises(ValueError):
        ga.scatter(np.zeros((3, 4), np.float32))


def test_collectives_ordered_after_queued_puts(ctx):
    """A typed collective closes the epoch first (RAW ordering)."""
    ga = ctx.alloc((2,), jnp.float32)
    for u in ga.units:
        ga[u].put_nb(jnp.full((2,), float(u)))       # all queued
    gat = np.asarray(ga.gather())
    np.testing.assert_array_equal(gat[:, 0], [0.0, 1.0, 2.0, 3.0])


def test_gather_is_one_dispatch(ctx):
    # shm=False pins the ENGINE contract — the default shm=True alloc
    # goes shm-direct on host-visible arenas (0 dispatches; covered by
    # tests/test_shm_plane.py)
    ga = ctx.alloc((8,), jnp.float32, shm=False)
    ga[0].put(jnp.ones((8,), jnp.float32))     # settle the pool
    d0 = ctx.engine.dispatch_count
    ga.gather()
    assert ctx.engine.dispatch_count - d0 == 1


# ------------------------------------------------- local zero-copy ---------

def test_local_view_zero_copy_zero_dispatch(ctx):
    if not shm_supported(ctx):
        pytest.skip("backend arenas not host-visible")
    ga = ctx.alloc((8,), jnp.float32)
    val = jnp.arange(8, dtype=jnp.float32) * 0.5
    ga[0].put(val)
    d0 = ctx.engine.dispatch_count
    lv = ga.local
    assert ctx.engine.dispatch_count == d0     # zero jitted dispatches
    assert isinstance(lv, np.ndarray) and not lv.flags.writeable
    np.testing.assert_array_equal(lv, np.asarray(val))
    # any member's block via local_view, and RAW ordering over the queue
    ga[2].put_nb(jnp.full((8,), 4.0))
    np.testing.assert_array_equal(ga.local_view(2), [4.0] * 8)


def test_alloc_shm_false_takes_jitted_path(ctx):
    ga = ctx.alloc((8,), jnp.float32, shm=False)
    assert not ga.gptr.is_shm
    ga[0].put(jnp.ones((8,), jnp.float32))
    d0 = ctx.engine.dispatch_count
    out = ga.local                             # falls back to jitted get
    assert ctx.engine.dispatch_count - d0 == 1
    assert np.all(np.asarray(out) == 1.0)


# ------------------------------------------------- property-based ----------

@given(st.integers(2, 6), st.integers(1, 16), st.integers(0, 10),
       st.sampled_from(["float32", "int32", "bfloat16", "uint8"]))
@settings(max_examples=15, deadline=None)
def test_typed_roundtrip_property(n_units, n, start, dtype):
    """put → get identity through the typed layer for random units,
    run offsets, and dtypes — the hand-rolled byte arithmetic the
    typed layer replaces, exercised end to end."""
    ctx = dart_init(n_units=n_units, config=DartConfig(
        non_collective_pool_bytes=4096, team_pool_bytes=4096))
    try:
        ga = ctx.alloc((start + n,), dtype)
        unit = ga.units[start % n_units]
        val = (jnp.arange(n) + 1).astype(dtype)
        ga.at[unit, start:start + n].put(val)
        out = ga.at[unit, start:start + n].get()
        assert np.asarray(out).tobytes() == np.asarray(val).tobytes()
    finally:
        dart_exit(ctx)


@given(st.integers(2, 8))
@settings(max_examples=8, deadline=None)
def test_typed_coalesce_property(k):
    """k typed put_nb to k distinct slots of one unit: one dispatch."""
    ctx = dart_init(n_units=2, config=DartConfig(
        non_collective_pool_bytes=8192, team_pool_bytes=8192))
    try:
        ga = ctx.alloc((8 * k,), jnp.float32)
        d0 = ctx.engine.dispatch_count
        with ctx.epoch():
            for i in range(k):
                ga.at[1, 8 * i:8 * (i + 1)].put_nb(
                    jnp.full((8,), float(i)))
        assert ctx.engine.dispatch_count - d0 == 1
        for i in range(k):
            assert np.all(np.asarray(
                ga.at[1, 8 * i:8 * (i + 1)].get()) == float(i))
    finally:
        dart_exit(ctx)
