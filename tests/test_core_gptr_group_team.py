"""Unit + property tests for gptr/group/team (paper §III, §IV.B.1/2/4)."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (DART_GPTR_NULL, GlobalPtr, DartGroup, FreeListTeamList,
                        Team, TeamList, TeamListFullError, TeamPartition,
                        dart_group_addmember, dart_group_delmember,
                        dart_group_init, dart_group_intersect,
                        dart_group_split, dart_group_union, group_from_units)
from repro.core.gptr import ADDR_MAX, FLAG_COLLECTIVE, SEG_MAX, UNIT_MAX


# ---------------------------------------------------------------- gptr ----

gptrs = st.builds(
    GlobalPtr,
    unitid=st.integers(0, UNIT_MAX),
    segid=st.integers(0, SEG_MAX),
    flags=st.integers(0, (1 << 16) - 1),
    addr=st.integers(0, ADDR_MAX),
)


@given(gptrs)
def test_gptr_pack_unpack_roundtrip(g):
    assert GlobalPtr.unpack(g.pack()) == g


@given(gptrs)
def test_gptr_words_roundtrip(g):
    assert GlobalPtr.from_words(g.to_words()) == g


@given(gptrs, st.integers(0, 1 << 20))
def test_gptr_incaddr(g, n):
    if g.addr + n > ADDR_MAX:
        with pytest.raises(ValueError):
            g.incaddr(n)
    else:
        g2 = g.incaddr(n)
        assert g2.addr == g.addr + n
        assert (g2.unitid, g2.segid, g2.flags) == (g.unitid, g.segid, g.flags)


@given(gptrs, st.integers(0, 1 << 20))
def test_gptr_decaddr_and_sub_int(g, n):
    """decaddr / ``- int`` mirror incaddr with a lower-bound check."""
    if n > g.addr:
        with pytest.raises(ValueError):
            g.decaddr(n)
        with pytest.raises(ValueError):
            g - n
    else:
        g2 = g.decaddr(n)
        assert g2.addr == g.addr - n
        assert (g2.unitid, g2.segid, g2.flags) == (g.unitid, g.segid, g.flags)
        assert (g - n) == g2


@given(gptrs, st.integers(0, 1 << 20))
def test_gptr_inc_dec_roundtrip(g, n):
    if g.addr + n <= ADDR_MAX:
        assert (g + n) - n == g
        assert (g + n).decaddr(n) == g


def test_gptr_decaddr_edge_cases():
    g = GlobalPtr(unitid=0, segid=0, flags=0, addr=128)
    assert g.decaddr(128).addr == 0            # down to exactly zero
    assert g.decaddr(0) == g
    assert g.decaddr(-64).addr == 192          # negative = incaddr
    with pytest.raises(ValueError):
        g.decaddr(129)                         # below the pool base


def test_gptr_addrdiff_same_segment():
    g = GlobalPtr(unitid=1, segid=3, flags=FLAG_COLLECTIVE, addr=256)
    assert g.addrdiff(g) == 0
    assert (g + 128) - g == 128
    assert g - (g + 128) == -128               # signed distance
    # collective pointers: unit-independent offsets (aligned & symmetric)
    assert g.setunit(5) - g == 0
    assert (g.setunit(5) + 64) - g == 64


def test_gptr_addrdiff_rejects_mismatched_segments():
    coll = GlobalPtr(unitid=0, segid=2, flags=FLAG_COLLECTIVE, addr=128)
    with pytest.raises(ValueError):
        coll.addrdiff(GlobalPtr(unitid=0, segid=3, flags=FLAG_COLLECTIVE,
                                addr=0))       # different segment
    with pytest.raises(ValueError):
        coll.addrdiff(GlobalPtr(unitid=0, segid=2, flags=0, addr=0))
    # non-collective: offsets are per-unit partitions — unit must match
    nc0 = GlobalPtr(unitid=0, segid=0, flags=0, addr=256)
    nc1 = GlobalPtr(unitid=1, segid=0, flags=0, addr=128)
    with pytest.raises(ValueError):
        nc0 - nc1
    assert nc0 - (nc0 + 128) == -128


def test_gptr_is_128_bits():
    g = GlobalPtr(unitid=UNIT_MAX, segid=SEG_MAX, flags=(1 << 16) - 1,
                  addr=ADDR_MAX)
    assert g.pack() == (1 << 128) - 1
    assert DART_GPTR_NULL.pack() == 0


def test_gptr_flags_semantics():
    g = GlobalPtr(unitid=3, segid=2, flags=FLAG_COLLECTIVE, addr=128)
    assert g.is_collective
    assert g.setunit(7).unitid == 7
    assert not DART_GPTR_NULL.is_collective


def test_gptr_range_validation():
    with pytest.raises(ValueError):
        GlobalPtr(unitid=-1, segid=0, flags=0, addr=0)
    with pytest.raises(ValueError):
        GlobalPtr(unitid=0, segid=SEG_MAX + 1, flags=0, addr=0)


# --------------------------------------------------------------- group ----

unit_lists = st.lists(st.integers(0, 1000), max_size=40)


@given(unit_lists, unit_lists)
def test_group_union_is_sorted_dedup_set_union(a, b):
    """Paper §IV.B.1: dart_group_union merge-sorts its inputs."""
    ga, gb = group_from_units(a), group_from_units(b)
    gu = dart_group_union(ga, gb)
    assert list(gu.members) == sorted(set(a) | set(b))


@given(unit_lists)
def test_group_addmember_order_independent(units):
    """Any insertion order yields the ascending-ordered group (Fig. 2)."""
    import random
    g1 = group_from_units(units)
    shuffled = list(units)
    random.Random(0).shuffle(shuffled)
    g2 = group_from_units(shuffled)
    assert g1 == g2
    assert list(g1.members) == sorted(set(units))


@given(unit_lists, unit_lists)
def test_group_intersect(a, b):
    gi = dart_group_intersect(group_from_units(a), group_from_units(b))
    assert list(gi.members) == sorted(set(a) & set(b))


@given(unit_lists, st.integers(1, 8))
def test_group_split_partitions(units, n):
    g = group_from_units(units)
    parts = dart_group_split(g, n)
    assert len(parts) == n
    recombined = [u for p in parts for u in p.members]
    assert recombined == list(g.members)          # contiguous, order kept
    sizes = [p.size() for p in parts]
    assert max(sizes) - min(sizes) <= 1           # balanced


def test_group_invariant_rejects_disorder():
    with pytest.raises(ValueError):
        DartGroup((3, 1))
    with pytest.raises(ValueError):
        DartGroup((1, 1))


def test_group_membership():
    g = group_from_units([5, 1, 9])
    assert g.ismember(5) and g.ismember(1) and g.ismember(9)
    assert not g.ismember(2)
    assert dart_group_delmember(g, 5).members == (1, 9)


# ---------------------------------------------------------------- team ----

@pytest.mark.parametrize("cls", [TeamList, FreeListTeamList])
def test_teamlist_alloc_reuse(cls):
    """Paper §IV.B.2: slots are reused after team destruction."""
    tl = cls(capacity=4)
    s0 = tl.alloc(100)
    s1 = tl.alloc(101)
    assert (s0, s1) == (0, 1)
    assert tl.lookup(101) == 1
    tl.free(100)
    assert tl.alloc(102) == 0          # freed slot is recycled
    tl.alloc(103); tl.alloc(104)
    with pytest.raises(TeamListFullError):
        tl.alloc(105)


@pytest.mark.parametrize("cls", [TeamList, FreeListTeamList])
def test_teamlist_lowest_slot_first(cls):
    tl = cls(capacity=8)
    for t in range(5):
        tl.alloc(t)
    tl.free(1); tl.free(3)
    assert tl.alloc(10) == 1           # deterministic: lowest free slot
    assert tl.alloc(11) == 3


@given(st.lists(st.integers(0, 500), min_size=1, max_size=30, unique=True))
def test_teamlist_impls_agree(ops):
    """The O(1) free-list variant (§VI) matches the paper allocator."""
    a, b = TeamList(64), FreeListTeamList(64)
    for i, t in enumerate(ops):
        assert a.alloc(t) == b.alloc(t)
        if i % 3 == 2:
            a.free(t); b.free(t)
    assert a.live() == b.live()


def test_team_unit_translation():
    """Paper §IV.B.4: absolute <-> relative unit translation."""
    g = group_from_units([2, 5, 11, 30])
    team = Team(teamid=7, group=g, slot=3)
    assert [team.myid(u) for u in (2, 5, 11, 30)] == [0, 1, 2, 3]
    assert team.myid(4) == -1
    assert [team.unit_at(r) for r in range(4)] == [2, 5, 11, 30]


def test_team_partition_validation():
    g1, g2 = group_from_units([0, 1]), group_from_units([2, 3])
    t1 = Team(teamid=1, group=g1, slot=0)
    t2 = Team(teamid=2, group=g2, slot=1)
    p = TeamPartition((t1, t2))
    assert p.axis_index_groups == [[0, 1], [2, 3]]
    assert p.team_of(3) is t2
    bad = Team(teamid=3, group=group_from_units([4, 5, 6]), slot=2)
    with pytest.raises(ValueError):
        TeamPartition((t1, bad))
