"""Deliverable (e)/(f) gate: the dry-run artifact set is complete.

Validates experiments/dryrun/*.json — every (arch × shape) cell on both
production meshes either compiled ok or is an explicitly documented
skip (long_500k on full-attention archs).  Runs against the committed
artifacts; regenerate with `python -m repro.launch.dryrun --all`
(+ `--multi-pod`).
"""

import json
import pathlib

import pytest

from repro.configs import ARCH_IDS, SHAPES, cell_applicable, get_config

DRYRUN = pathlib.Path(__file__).parent.parent / "experiments" / "dryrun"

pytestmark = pytest.mark.skipif(
    not DRYRUN.exists(), reason="dry-run artifacts not generated")


@pytest.mark.parametrize("mesh", ["16x16", "2x16x16"])
@pytest.mark.parametrize("shape", list(SHAPES))
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cell_artifact(arch, shape, mesh):
    p = DRYRUN / f"{arch}__{shape}__{mesh}.json"
    assert p.exists(), f"missing dry-run cell {p.name}"
    rec = json.loads(p.read_text())
    applicable, why = cell_applicable(get_config(arch), shape)
    if not applicable:
        assert rec.get("applicable") is False
        assert rec.get("skip_reason")
        return
    assert rec.get("ok"), f"{p.name}: {rec.get('error')}"
    assert rec["n_devices"] == (512 if mesh == "2x16x16" else 256)
    assert rec["flops_per_device"] > 0
    assert rec["bytes_accessed_per_device"] > 0
    assert "memory_analysis" in rec


def test_single_pod_table_has_40_cells():
    cells = [p for p in DRYRUN.glob("*__16x16.json")]
    assert len(cells) >= 40


def test_roofline_derivation_runs():
    from benchmarks.roofline import analyse
    ok_cells = 0
    for p in DRYRUN.glob("*__16x16.json"):
        rec = json.loads(p.read_text())
        if rec.get("tag") or not rec.get("ok"):
            continue
        a = analyse(rec)
        assert set(a) >= {"t_compute_s", "t_memory_s", "t_collective_s",
                          "dominant", "roofline_fraction"}
        assert a["dominant"] in ("compute", "memory", "collective")
        ok_cells += 1
    assert ok_cells >= 30
