"""Per-arch smoke tests: reduced config, one forward + one train step on
CPU, asserting output shapes and no NaNs (full configs are exercised
only via the dry-run)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import api
from repro.models.config import reduced_for_smoke

B, S = 2, 16


def make_batch(cfg, rng):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens,
             "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "encdec":
        batch["enc_frames"] = jax.random.normal(
            rng, (B, cfg.n_audio_frames, cfg.d_model))
    if cfg.family == "vlm":
        P = cfg.n_vision_patches
        batch["vision_embeds"] = jax.random.normal(rng, (B, P, cfg.d_model))
        pos = jnp.broadcast_to(jnp.arange(P + S)[None], (B, P + S))
        batch["position_ids"] = jnp.broadcast_to(pos[None], (3, B, P + S))
    return batch


def loss_fn(cfg, params, batch):
    logits, aux = api.forward_train(cfg, params, batch)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None],
                               axis=-1).mean()
    return nll + aux


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch_id):
    cfg = reduced_for_smoke(get_config(arch_id))
    rng = jax.random.PRNGKey(0)
    params = api.init_params(cfg, rng)
    batch = make_batch(cfg, rng)

    logits, aux = jax.jit(
        lambda p, b: api.forward_train(cfg, p, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), "NaN/inf in logits"

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, b: loss_fn(cfg, p, b)))(params, batch)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    # one SGD step keeps outputs finite
    params2 = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                           params, grads)
    loss2 = jax.jit(lambda p, b: loss_fn(cfg, p, b))(params2, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_prefill_decode_consistency(arch_id):
    """decode(prefill(prompt)) logits == train-forward logits."""
    cfg = reduced_for_smoke(get_config(arch_id))
    rng = jax.random.PRNGKey(1)
    params = api.init_params(cfg, rng)
    batch = make_batch(cfg, rng)
    max_seq = S + 4 + (cfg.n_vision_patches if cfg.family == "vlm" else 0)

    logits, _ = jax.jit(
        lambda p, b: api.forward_train(cfg, p, b))(params, batch)
    pre, cache = jax.jit(
        lambda p, b: api.forward_prefill(cfg, p, b, max_seq))(params, batch)
    np.testing.assert_allclose(np.asarray(pre[:, 0]),
                               np.asarray(logits[:, -1]),
                               rtol=2e-4, atol=2e-4)

    nxt = jnp.argmax(pre[:, 0], -1).astype(jnp.int32)[:, None]
    dec, _ = jax.jit(
        lambda p, t, c: api.forward_decode(cfg, p, t, c))(params, nxt, cache)
    ext = dict(batch, tokens=jnp.concatenate([batch["tokens"], nxt], 1))
    ext["labels"] = ext["tokens"]
    if cfg.family == "vlm":
        P = cfg.n_vision_patches
        pos = jnp.broadcast_to(jnp.arange(P + S + 1)[None], (B, P + S + 1))
        ext["position_ids"] = jnp.broadcast_to(pos[None], (3, B, P + S + 1))
    ext_logits, _ = jax.jit(
        lambda p, b: api.forward_train(cfg, p, b))(params, ext)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(ext_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_full_configs():
    """Full-config parameter counts are in the expected ballpark."""
    expect = {
        "llama3-8b": (7.0e9, 9.5e9),
        "llama3-405b": (390e9, 430e9),
        "command-r-35b": (32e9, 40e9),
        "command-r-plus-104b": (95e9, 115e9),
        "olmoe-1b-7b": (6.0e9, 8.0e9),
        "qwen2-moe-a2.7b": (12e9, 16e9),
        "zamba2-1.2b": (0.9e9, 1.6e9),
        "whisper-small": (0.18e9, 0.35e9),
        "rwkv6-1.6b": (1.4e9, 2.2e9),
        "qwen2-vl-2b": (1.2e9, 2.3e9),
    }
    for arch, (lo, hi) in expect.items():
        n = api.param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}," \
                              f" {hi/1e9}]B"
    # MoE active < total
    moe = get_config("olmoe-1b-7b")
    assert api.active_param_count(moe) < api.param_count(moe)
