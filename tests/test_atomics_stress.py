"""Threaded contention stress tests for the atomics + lock plane
(`core/atomics.py`, `core/atomic_ops.py`, `core/lock.py`), which
previously had only happy-path coverage.

The control-plane concurrency model (docs in core/atomics.py): units
are host threads — checkpoint writers, serving handlers — sharing one
DartContext.  These tests drive real ``threading.Thread`` contention
through every provider:

* ``ThreadedAtomics`` — the in-process provider;
* ``dart_fetch_and_add`` / ``dart_compare_and_swap`` — atomics on heap
  cells addressed by global pointers (serialized by the per-context
  mutex, each op a read-modify-write against the engine-flushed heap);
* the MCS ``LockService`` — mutual exclusion, FIFO hand-off, and the
  ``held()`` guard releasing on exception.
"""

import threading

import pytest

from repro.core import (DartConfig, LockService, ThreadedAtomics,
                        dart_compare_and_swap, dart_exit,
                        dart_fetch_and_add, dart_init, dart_memalloc)
from repro.core.atomic_ops import HeapAtomicsProvider, _read_i32
from repro.core.team import Team


N_THREADS = 8
N_INCR = 25


@pytest.fixture()
def ctx():
    c = dart_init(n_units=N_THREADS, config=DartConfig(
        non_collective_pool_bytes=4096, team_pool_bytes=4096))
    yield c
    dart_exit(c)


def _run_threads(fn, n=N_THREADS):
    errs = []

    def wrap(i):
        try:
            fn(i)
        except BaseException as e:  # noqa: BLE001 - surface to the test
            errs.append(e)

    ts = [threading.Thread(target=wrap, args=(i,)) for i in range(n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    if errs:
        raise errs[0]


# ------------------------------------------------------ heap atomics ------

def test_threaded_fetch_and_add_sums_exactly(ctx):
    """N threads × M increments through dart_fetch_and_add: the final
    cell value is exactly N*M and every fetched old value is unique
    (each RMW observed a distinct state)."""
    g = dart_memalloc(ctx, 4, unit=0)
    seen = [[] for _ in range(N_THREADS)]

    def worker(i):
        for _ in range(N_INCR):
            seen[i].append(dart_fetch_and_add(ctx, g, 1))

    _run_threads(worker)
    assert _read_i32(ctx, g) == N_THREADS * N_INCR
    olds = sorted(v for s in seen for v in s)
    assert olds == list(range(N_THREADS * N_INCR))


def test_threaded_cas_increment_loop_is_exact(ctx):
    """CAS-retry increments from N threads lose no update."""
    g = dart_memalloc(ctx, 4, unit=1)

    def worker(i):
        for _ in range(N_INCR):
            # atomic load = fetch_and_add(0) for the RMW ordering; the
            # old "may observe the arena mid-donation" caveat on bare
            # _read_i32 is gone — raw state reads now hold the engine
            # lock (see test_donation_race_closed below)
            old = dart_fetch_and_add(ctx, g, 0)
            while True:
                seen = dart_compare_and_swap(ctx, g, old, old + 1)
                if seen == old:
                    break
                old = seen

    _run_threads(worker)
    assert _read_i32(ctx, g) == N_THREADS * N_INCR


def test_donation_race_closed(ctx):
    """The donation race is CLOSED, not documented: threads hammering
    fetch_and_add (whose _read_i32/_write_i32 read and replace raw
    ``ctx.state``) race threads enqueueing puts and flushing (whose
    jitted dispatch *donates* the arena).  Before the engine lock, the
    reader could observe a deleted buffer mid-donation; now every raw
    state access serializes with every flush, so the run is exact and
    byte-identical to the serial oracle."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import dart_flush, dart_get_blocking, dart_put

    ctr = dart_memalloc(ctx, 4, unit=0)
    data = dart_memalloc(ctx, 4 * N_THREADS * N_INCR, unit=3)

    def worker(i):
        if i % 2 == 0:                    # atomics lane: raw state RMWs
            for _ in range(2 * N_INCR):
                dart_fetch_and_add(ctx, ctr, 1)
        else:                             # engine lane: queued puts + flush
            base = i * N_INCR
            for k in range(N_INCR):
                dart_put(ctx, data + 4 * (base + k),
                         jnp.asarray([base + k], jnp.int32))
                dart_flush(ctx)

    _run_threads(worker)
    n_atomics = (N_THREADS + 1) // 2
    assert _read_i32(ctx, ctr) == n_atomics * 2 * N_INCR
    got = np.asarray(dart_get_blocking(ctx, data, (N_THREADS * N_INCR,),
                                       jnp.int32))
    want = np.zeros(N_THREADS * N_INCR, np.int32)   # the serial oracle
    for i in range(1, N_THREADS, 2):
        base = i * N_INCR
        want[base:base + N_INCR] = np.arange(base, base + N_INCR)
    np.testing.assert_array_equal(got, want)


def test_threaded_mixed_add_deltas(ctx):
    """Mixed positive/negative deltas from racing threads sum exactly."""
    g = dart_memalloc(ctx, 4, unit=2)
    deltas = [(-1) ** i * (i + 1) for i in range(N_THREADS)]

    def worker(i):
        for _ in range(N_INCR):
            dart_fetch_and_add(ctx, g, deltas[i])

    _run_threads(worker)
    assert _read_i32(ctx, g) == N_INCR * sum(deltas)


# ------------------------------------------------ ThreadedAtomics ---------

def test_provider_fetch_and_add_contention():
    atomics = ThreadedAtomics(N_THREADS)
    cell = atomics.make_cell("ctr", 0, 0)

    def worker(i):
        for _ in range(200):
            atomics.fetch_and_add(cell, 1)

    _run_threads(worker)
    assert atomics.load(cell) == N_THREADS * 200


def test_provider_cas_single_winner_per_round():
    """Exactly one thread wins each CAS round (atomicity of
    compare_and_swap under contention)."""
    atomics = ThreadedAtomics(N_THREADS)
    cell = atomics.make_cell("gate", 0, 0)
    wins = [0] * N_THREADS
    barrier = threading.Barrier(N_THREADS)

    def worker(i):
        for round_no in range(20):
            barrier.wait()
            if atomics.compare_and_swap(cell, round_no,
                                        round_no + 1) == round_no:
                wins[i] += 1

    _run_threads(worker)
    assert sum(wins) == 20                     # one winner per round
    assert atomics.load(cell) == 20


# ---------------------------------------------------------- MCS lock ------

def _team_of(ctx):
    return ctx.teams[0]


def _assert_mutual_exclusion(locks, lock, provider_units, acquire_ctx):
    """Drive N threads through acquire/critical-section/release with a
    deliberately racy counter; mutual exclusion makes it exact."""
    state = {"ctr": 0, "inside": 0, "max_inside": 0}

    def worker(u):
        for _ in range(N_INCR):
            with acquire_ctx(lock, u):
                state["inside"] += 1
                state["max_inside"] = max(state["max_inside"],
                                          state["inside"])
                v = state["ctr"]
                state["ctr"] = v + 1           # racy unless excluded
                state["inside"] -= 1

    _run_threads(worker, n=len(provider_units))
    assert state["ctr"] == len(provider_units) * N_INCR
    assert state["max_inside"] == 1
    assert lock.is_free_hint(locks.atomics)


def test_mcs_lock_mutual_exclusion_threaded(ctx):
    locks = LockService(ctx.atomics)
    lock = locks.create_lock(_team_of(ctx))
    _assert_mutual_exclusion(locks, lock, range(N_THREADS),
                             lambda lk, u: locks.held(lk, u))


def test_mcs_lock_round_robin_placement_threaded(ctx):
    locks = LockService(ctx.atomics, tail_placement="round_robin")
    lock = locks.create_lock(_team_of(ctx))
    _assert_mutual_exclusion(locks, lock, range(N_THREADS),
                             lambda lk, u: locks.held(lk, u))


def test_mcs_lock_over_heap_atomics_threaded(ctx):
    """The lock state living in DART global memory (HeapAtomicsProvider,
    paper Fig. 6 layout) under real thread contention."""
    provider = HeapAtomicsProvider(ctx, ctx.atomics)
    locks = LockService(provider)
    lock = locks.create_lock(_team_of(ctx))
    units = range(4)                    # heap RMWs are slower: fewer units

    state = {"ctr": 0}

    def worker(u):
        for _ in range(5):
            with locks.held(lock, u):
                v = state["ctr"]
                state["ctr"] = v + 1

    _run_threads(worker, n=len(list(units)))
    assert state["ctr"] == 4 * 5
    assert lock.is_free_hint(provider)
    # destroy returns the tail/next cells' heap bytes (free_cell over
    # the heap provider = dart_memfree of each gptr-addressed cell)
    locks.destroy_lock(lock)
    assert provider._cells == {}


def test_lock_released_on_exception(ctx):
    """held() must release on exception — a successor blocked in
    wait_notify would otherwise hang forever."""
    locks = LockService(ctx.atomics)
    lock = locks.create_lock(_team_of(ctx))

    with pytest.raises(RuntimeError, match="boom"):
        with locks.held(lock, 0):
            assert not lock.is_free_hint(locks.atomics)
            raise RuntimeError("boom")
    assert lock.is_free_hint(locks.atomics)

    # a queued successor behind a failing holder still gets the lock
    got = []

    def failing_holder():
        try:
            with locks.held(lock, 1):
                barrier.wait()             # successor is now queueing
                raise RuntimeError("late failure")
        except RuntimeError:
            pass

    def successor():
        barrier.wait()
        with locks.held(lock, 2, timeout=10):
            got.append("locked")

    barrier = threading.Barrier(2)
    _run_threads(lambda i: (failing_holder if i == 0 else successor)(),
                 n=2)
    assert got == ["locked"]
    assert lock.is_free_hint(locks.atomics)


def test_lock_fifo_handoff_order():
    """MCS hand-off is FIFO: units that queue in order acquire in
    STRICT order.  Enqueues are serialized by polling each waiter's
    registration in its predecessor's 'next' cell, so the assertion
    is on the exact order, not just eventual acquisition."""
    import time

    atomics = ThreadedAtomics(4)
    team = Team(teamid=0, group=type("G", (), {
        "members": (0, 1, 2, 3), "size": lambda self: 4})(),
        slot=0, parent=None, poolid=0)
    locks = LockService(atomics)
    lock = locks.create_lock(team)
    order = []

    locks.acquire(lock, 0)
    waiters = []

    def waiter(u):
        locks.acquire(lock, u)
        order.append(u)
        locks.release(lock, u)

    for u, pred in ((1, 0), (2, 1)):
        t = threading.Thread(target=waiter, args=(u,))
        t.start()
        waiters.append(t)
        # wait until u is registered behind its predecessor before
        # letting the next waiter enqueue (deadline-bounded poll)
        deadline = time.monotonic() + 10
        while atomics.load(lock.next_cells[pred]) != u:
            assert time.monotonic() < deadline, \
                f"unit {u} never registered behind {pred}"
            time.sleep(0.001)
    locks.release(lock, 0)
    for t in waiters:
        t.join(timeout=10)
    assert order == [1, 2]                 # strict FIFO, not just both
    assert lock.is_free_hint(atomics)
