"""Paper §VI shared-memory windows + §IV.B.6 heap atomics."""

import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DART_TEAM_ALL, DartConfig, HeapAtomicsProvider,
                        LockService, dart_compare_and_swap, dart_exit,
                        dart_fetch_and_add, dart_fetch_and_store,
                        dart_init, dart_put_blocking, dart_shm_view,
                        dart_team_memalloc_aligned,
                        dart_team_memalloc_shared, shm_supported)
from repro.core.atomics import ThreadedAtomics


@pytest.fixture()
def ctx():
    c = dart_init(n_units=4, config=DartConfig(
        non_collective_pool_bytes=4096, team_pool_bytes=4096))
    yield c
    dart_exit(c)


# ------------------------------------------------------------- shm ---------

def test_shm_view_zero_copy_roundtrip(ctx):
    if not shm_supported(ctx):
        pytest.skip("backend arenas not host-visible")
    g = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 256)
    val = jnp.arange(16, dtype=jnp.float32)
    dart_put_blocking(ctx, g.setunit(2), val)
    view = dart_shm_view(ctx, g.setunit(2), (16,), jnp.float32)
    np.testing.assert_array_equal(view, np.asarray(val))
    assert not view.flags.writeable            # read-only snapshot


def test_shm_view_is_live_window(ctx):
    """Views are LIVE windows on the arena (MPI-3 shm semantics): a
    later shm-routed put through the same window is visible in a view
    taken earlier, because the shm write mutates the arena in place
    instead of donating a successor.  (An ENGINE-path write — e.g. any
    put on a shm=False pool — still re-installs a new arena, which an
    old view does not follow.)"""
    if not shm_supported(ctx):
        pytest.skip("backend arenas not host-visible")
    g = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 64)
    dart_put_blocking(ctx, g, jnp.full((4,), 1.0, jnp.float32))
    v1 = dart_shm_view(ctx, g, (4,), jnp.float32)
    assert np.all(v1 == 1.0)
    dart_put_blocking(ctx, g, jnp.full((4,), 2.0, jnp.float32))
    v2 = dart_shm_view(ctx, g, (4,), jnp.float32)
    assert np.all(v2 == 2.0)
    assert np.all(v1 == 2.0)    # v1 observed the in-place window write


def test_shm_requires_flag(ctx):
    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 64)
    with pytest.raises(ValueError, match="FLAG_SHM"):
        dart_shm_view(ctx, g, (4,), jnp.float32)


# --------------------------------------------------------- heap atomics ----

def test_heap_atomics_semantics(ctx):
    from repro.core.runtime import dart_memalloc
    g = dart_memalloc(ctx, 4, unit=1)
    dart_put_blocking(ctx, g, jnp.asarray([5], jnp.int32))
    assert dart_fetch_and_add(ctx, g, 3) == 5
    assert dart_fetch_and_store(ctx, g, 100) == 8
    assert dart_compare_and_swap(ctx, g, 100, 7) == 100
    assert dart_compare_and_swap(ctx, g, 999, 0) == 7   # no swap
    assert dart_fetch_and_add(ctx, g, 0) == 7


def test_heap_atomics_thread_safety(ctx):
    from repro.core.runtime import dart_memalloc
    g = dart_memalloc(ctx, 4, unit=0)
    dart_put_blocking(ctx, g, jnp.asarray([0], jnp.int32))

    def worker():
        for _ in range(25):
            dart_fetch_and_add(ctx, g, 1)

    ts = [threading.Thread(target=worker) for _ in range(4)]
    for t in ts: t.start()
    for t in ts: t.join()
    assert dart_fetch_and_add(ctx, g, 0) == 100


def test_mcs_lock_with_heap_state(ctx):
    """The MCS LockService running with its lock state in DART global
    memory (the paper Fig. 6 layout), via HeapAtomicsProvider."""
    notifier = ThreadedAtomics(4)
    provider = HeapAtomicsProvider(ctx, notifier)
    svc = LockService(provider)
    lock = svc.create_lock(ctx.teams[DART_TEAM_ALL])

    counter = {"v": 0}
    def worker(u):
        for _ in range(20):
            svc.acquire(lock, u)
            counter["v"] += 1
            svc.release(lock, u)

    ts = [threading.Thread(target=worker, args=(u,)) for u in range(4)]
    for t in ts: t.start()
    for t in ts: t.join()
    assert counter["v"] == 80
    # tail cell lives in the WORLD pool on unit 0 (paper: unit 0)
    assert lock.tail.unitid == 0
