"""Teamlist allocator benchmarks (paper §IV.B.2 + §VI).

The paper flags the linear teamlist scan as a scalability issue and
proposes a linked-list alternative; we measure the faithful linear
allocator against the O(1) free-list variant at growing live-team
counts, for the three hot operations (create / lookup / destroy).
"""

from __future__ import annotations

from repro.core import FreeListTeamList, TeamList

from .common import Report, time_call


def run(report: Report, *, repeats: int = 50):
    for live in (16, 128, 1024):
        for cls, tag in ((TeamList, "paper_linear"),
                         (FreeListTeamList, "freelist")):
            tl = cls(capacity=live + 8)
            for t in range(live):
                tl.alloc(t)
            worst = live - 1           # the paper's worst case: last slot

            t = time_call(lambda: tl.lookup(worst), repeats=repeats)
            report.add(f"teamlist/lookup_live{live}/{tag}", t.mean_us)

            def create_destroy():
                tid = 10_000_000
                tl.alloc(tid)
                tl.free(tid)

            t = time_call(create_destroy, repeats=repeats)
            report.add(f"teamlist/create_destroy_live{live}/{tag}",
                       t.mean_us)
