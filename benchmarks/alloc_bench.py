"""Global-memory management benchmarks (paper §IV.B.3).

Cost of collective aligned allocation (translation-table insert +
shared-cursor alloc), non-collective allocation, pointer dereference,
and gptr pack/unpack — the constant-overhead ingredients of every DART
one-sided op.
"""

from __future__ import annotations

from repro.core import (DART_TEAM_ALL, DartConfig, GlobalPtr, dart_exit,
                        dart_init, dart_memalloc, dart_memfree,
                        dart_team_memalloc_aligned, dart_team_memfree)
from repro.core.onesided import deref

from .common import Report, time_call


def run(report: Report, *, repeats: int = 200):
    ctx = dart_init(n_units=16, config=DartConfig(
        non_collective_pool_bytes=1 << 22, team_pool_bytes=1 << 22))

    def coll_alloc_free():
        g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 4096)
        dart_team_memfree(ctx, DART_TEAM_ALL, g)

    t = time_call(coll_alloc_free, repeats=repeats)
    report.add("globmem/collective_alloc_free", t.mean_us)

    def local_alloc_free():
        g = dart_memalloc(ctx, 4096, unit=3)
        dart_memfree(ctx, g)

    t = time_call(local_alloc_free, repeats=repeats)
    report.add("globmem/noncollective_alloc_free", t.mean_us)

    g = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 4096)

    def deref_collective():
        deref(ctx.heap, ctx.teams_by_slot, g.setunit(7))

    t = time_call(deref_collective, repeats=repeats)
    report.add("gptr/deref_collective", t.mean_us,
               "incl. abs->rel unit translation")

    g2 = dart_memalloc(ctx, 4096, unit=5)

    def deref_noncollective():
        deref(ctx.heap, ctx.teams_by_slot, g2)

    t = time_call(deref_noncollective, repeats=repeats)
    report.add("gptr/deref_noncollective", t.mean_us,
               "no unit translation (paper §IV.B.4)")

    def pack_unpack():
        GlobalPtr.unpack(g.pack())

    t = time_call(pack_unpack, repeats=repeats)
    report.add("gptr/pack_unpack", t.mean_us)
    dart_exit(ctx)
