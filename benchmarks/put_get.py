"""Paper figures 8–15: DTCT / DTIT / bandwidth of DART put/get vs the
raw substrate (semantically equivalent jitted XLA ops).

Mirrors §V of the paper:

* DTCT — blocking put/get completion time, message sizes 1B…2MiB
* DTIT — non-blocking put/get *initiation* time (call returns after
  issuing; completion explicitly not awaited — §V.A)
* bandwidth — many overlapping non-blocking ops, then waitall
* three relative placements.  On this CPU container the three are
  physically identical (one device); they still exercise the three
  distinct runtime paths (self-access, intra-pod neighbour, cross-pod
  unit translation).  On a real mesh the same benchmark binds units to
  chips, so the placement dimension becomes physical.
* overhead model fit: t_DART(m) − t_raw(m) = c (constant), as in the
  paper's analysis (they report c ≈ 0 blocking, ~80–130 ns
  non-blocking on Cray XE6; ours is µs-scale because the per-call cost
  is Python dispatch rather than a C library call — same model, shifted
  constant; see EXPERIMENTS.md §Paper-repro).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DART_TEAM_ALL, DartConfig, dart_exit, dart_init,
                        dart_team_memalloc_aligned, dart_waitall)
from repro.core import runtime as rt
from repro.core.onesided import _arena_read, _arena_write

from .common import Report, fit_constant_overhead, time_call

N_UNITS = 16
PLACEMENTS = {
    "intra_unit": (0, 0),        # self-access
    "inter_unit_ici": (0, 1),    # intra-pod neighbour
    "inter_pod_dcn": (0, 8),     # unit in the "other pod" half
}


def _mk_ctx(pool_bytes: int):
    return dart_init(n_units=N_UNITS, config=DartConfig(
        non_collective_pool_bytes=pool_bytes,
        team_pool_bytes=pool_bytes))


def run(report: Report, *, full: bool = False, repeats: int = 20):
    max_pow = 21 if full else 18
    sizes = [2 ** p for p in range(0, max_pow + 1, 3)]
    pool = 1 << 22
    ctx = _mk_ctx(pool)
    gp = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, pool // 2)
    team = ctx.teams[DART_TEAM_ALL]
    poolid = team.slot + 1

    fits = {}
    for place, (src, dst) in PLACEMENTS.items():
        ptr = gp.setunit(dst)
        t_dart_put, t_raw_put = [], []
        t_dart_get, t_raw_get = [], []
        t_dart_puti, t_dart_geti = [], []
        for nbytes in sizes:
            n = max(nbytes // 4, 1)
            val = jnp.arange(n, dtype=jnp.float32)
            payload = jax.lax.bitcast_convert_type(val, jnp.uint8
                                                   ).reshape(-1)
            row = jnp.uint32(team.myid(dst))
            off = jnp.uint32(ptr.addr)

            # --- blocking put (DTCT) --------------------------------
            def dart_put_block():
                rt.dart_put_blocking(ctx, ptr, val)

            def raw_put_block():
                ctx.state[poolid] = _arena_write(
                    ctx.state[poolid], row, off, payload)
                ctx.state[poolid].block_until_ready()

            td = time_call(dart_put_block, repeats=repeats)
            tr = time_call(raw_put_block, repeats=repeats)
            t_dart_put.append(td.mean_us)
            t_raw_put.append(tr.mean_us)
            report.add(f"dtct_put/{place}/{nbytes}B/dart", td.mean_us,
                       f"raw={tr.mean_us:.3f}us")

            # --- blocking get (DTCT) --------------------------------
            def dart_get_block():
                rt.dart_get_blocking(ctx, ptr, (n,), jnp.float32)

            def raw_get_block():
                _arena_read(ctx.state[poolid], row, off,
                            int(n * 4)).block_until_ready()

            td = time_call(dart_get_block, repeats=repeats)
            tr = time_call(raw_get_block, repeats=repeats)
            t_dart_get.append(td.mean_us)
            t_raw_get.append(tr.mean_us)
            report.add(f"dtct_get/{place}/{nbytes}B/dart", td.mean_us,
                       f"raw={tr.mean_us:.3f}us")

            # --- non-blocking initiation (DTIT) ---------------------
            def dart_put_init():
                rt.dart_put(ctx, ptr, val)

            def dart_get_init():
                rt.dart_get(ctx, ptr, (n,), jnp.float32)

            ti = time_call(dart_put_init, repeats=repeats)
            t_dart_puti.append(ti.mean_us)
            report.add(f"dtit_put/{place}/{nbytes}B/dart", ti.mean_us)
            ti = time_call(dart_get_init, repeats=repeats)
            t_dart_geti.append(ti.mean_us)
            report.add(f"dtit_get/{place}/{nbytes}B/dart", ti.mean_us)

        for kind, td, tr in (("put", t_dart_put, t_raw_put),
                             ("get", t_dart_get, t_raw_get)):
            c, se = fit_constant_overhead(sizes, td, tr)
            fits[f"{kind}/{place}"] = (c, se)
            report.add(f"overhead_fit/{kind}/{place}", c,
                       f"stderr={se:.3f}us (model t_DART-t_raw=c)")

    # --- bandwidth (figs 12-15): overlapping non-blocking then waitall --
    for place, (src, dst) in PLACEMENTS.items():
        ptr = gp.setunit(dst)
        for nbytes in [2 ** p for p in range(10, max_pow + 1, 4)]:
            n = nbytes // 4
            val = jnp.arange(n, dtype=jnp.float32)
            inflight = 8

            def dart_put_bw():
                hs = [rt.dart_put(ctx, ptr + (i * nbytes) % (pool // 4),
                                  val) for i in range(inflight)]
                dart_waitall(hs)

            t = time_call(dart_put_bw, repeats=max(repeats // 2, 5))
            bw = inflight * nbytes / (t.mean_us * 1e-6) / 1e9
            report.add(f"bw_put_nb/{place}/{nbytes}B", t.mean_us,
                       f"{bw:.3f}GB/s")

            def dart_get_bw():
                out = [rt.dart_get(ctx, ptr + (i * nbytes) % (pool // 4),
                                   (n,), jnp.float32)[1]
                       for i in range(inflight)]
                dart_waitall(out)

            t = time_call(dart_get_bw, repeats=max(repeats // 2, 5))
            bw = inflight * nbytes / (t.mean_us * 1e-6) / 1e9
            report.add(f"bw_get_nb/{place}/{nbytes}B", t.mean_us,
                       f"{bw:.3f}GB/s")

    # --- §VI shared-memory window: zero-copy view vs one-sided get -----
    from repro.core import (dart_shm_view, dart_team_memalloc_shared,
                            shm_supported)
    if shm_supported(ctx):
        gs = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 1 << 18)
        for nbytes in (64, 4096, 262144):
            n = nbytes // 4
            rt.dart_put_blocking(ctx, gs.setunit(1),
                                 jnp.arange(n, dtype=jnp.float32))

            def shm_read():
                dart_shm_view(ctx, gs.setunit(1), (n,), jnp.float32)

            def get_read():
                rt.dart_get_blocking(ctx, gs.setunit(1), (n,), jnp.float32)

            ts = time_call(shm_read, repeats=repeats)
            tg = time_call(get_read, repeats=repeats)
            report.add(f"shm_view/{nbytes}B", ts.mean_us,
                       f"get={tg.mean_us:.3f}us "
                       f"speedup={tg.mean_us / ts.mean_us:.1f}x")

    dart_exit(ctx)
    return fits
