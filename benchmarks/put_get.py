"""Paper figures 8–15: DTCT / DTIT / bandwidth of DART put/get vs the
raw substrate (semantically equivalent jitted XLA ops).

Mirrors §V of the paper:

* DTCT — blocking put/get completion time, message sizes 1B…2MiB
* DTIT — non-blocking put/get *initiation* time (call returns after
  issuing; completion explicitly not awaited — §V.A)
* bandwidth — many overlapping non-blocking ops, then waitall
* three relative placements.  On this CPU container the three are
  physically identical (one device); they still exercise the three
  distinct runtime paths (self-access, intra-pod neighbour, cross-pod
  unit translation).  On a real mesh the same benchmark binds units to
  chips, so the placement dimension becomes physical.
* overhead model fit: t_DART(m) − t_raw(m) = c (constant), as in the
  paper's analysis (they report c ≈ 0 blocking, ~80–130 ns
  non-blocking on Cray XE6; ours is µs-scale because the per-call cost
  is Python dispatch rather than a C library call — same model, shifted
  constant; see EXPERIMENTS.md §Paper-repro).
* `typed_api` series — the typed GlobalArray front-end (docs/API.md)
  vs the raw `dart_put`/`dart_get` byte API, blocking and coalesced
  non-blocking, with the same constant-overhead model fit applied to
  the layering cost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DART_TEAM_ALL, DartConfig, dart_exit, dart_init,
                        dart_team_memalloc_aligned, dart_waitall)
from repro.core import runtime as rt
from repro.core.onesided import _arena_read, _arena_write

from .common import Report, fit_constant_overhead, time_call

#: ops per coalesced-flush epoch in the `coalesced` series
COALESCE_N = 16

N_UNITS = 16
PLACEMENTS = {
    "intra_unit": (0, 0),        # self-access
    "inter_unit_ici": (0, 1),    # intra-pod neighbour
    "inter_pod_dcn": (0, 8),     # unit in the "other pod" half
}


def _mk_ctx(pool_bytes: int):
    return dart_init(n_units=N_UNITS, config=DartConfig(
        non_collective_pool_bytes=pool_bytes,
        team_pool_bytes=pool_bytes))


def run(report: Report, *, full: bool = False, repeats: int = 20,
        quick: bool = False):
    max_pow = 21 if full else (12 if quick else 18)
    sizes = [2 ** p for p in range(0, max_pow + 1, 3)]
    pool = 1 << 22
    ctx = _mk_ctx(pool)
    gp = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, pool // 2)
    team = ctx.teams[DART_TEAM_ALL]
    poolid = team.poolid                 # window-registry binding

    placements = (dict(list(PLACEMENTS.items())[:1]) if quick
                  else PLACEMENTS)
    fits = {}
    for place, (src, dst) in placements.items():
        ptr = gp.setunit(dst)
        t_dart_put, t_raw_put = [], []
        t_dart_get, t_raw_get = [], []
        t_dart_puti, t_dart_geti = [], []
        for nbytes in sizes:
            n = max(nbytes // 4, 1)
            val = jnp.arange(n, dtype=jnp.float32)
            payload = jax.lax.bitcast_convert_type(val, jnp.uint8
                                                   ).reshape(-1)
            row = jnp.uint32(team.myid(dst))
            off = jnp.uint32(ptr.addr)

            # --- blocking put (DTCT) --------------------------------
            def dart_put_block():
                rt.dart_put_blocking(ctx, ptr, val)

            def raw_put_block():
                ctx.state[poolid] = _arena_write(
                    ctx.state[poolid], row, off, payload)
                ctx.state[poolid].block_until_ready()

            td = time_call(dart_put_block, repeats=repeats)
            tr = time_call(raw_put_block, repeats=repeats)
            t_dart_put.append(td.mean_us)
            t_raw_put.append(tr.mean_us)
            report.add(f"dtct_put/{place}/{nbytes}B/dart", td.mean_us,
                       f"raw={tr.mean_us:.3f}us")

            # --- blocking get (DTCT) --------------------------------
            def dart_get_block():
                rt.dart_get_blocking(ctx, ptr, (n,), jnp.float32)

            def raw_get_block():
                _arena_read(ctx.state[poolid], row, off,
                            int(n * 4)).block_until_ready()

            td = time_call(dart_get_block, repeats=repeats)
            tr = time_call(raw_get_block, repeats=repeats)
            t_dart_get.append(td.mean_us)
            t_raw_get.append(tr.mean_us)
            report.add(f"dtct_get/{place}/{nbytes}B/dart", td.mean_us,
                       f"raw={tr.mean_us:.3f}us")

            # --- non-blocking initiation (DTIT) ---------------------
            def dart_put_init():
                rt.dart_put(ctx, ptr, val)

            def dart_get_init():
                # initiation only: enqueue without dispatch (the eager
                # rt.dart_get flushes the pool, which would time a full
                # jitted dispatch instead)
                rt.dart_get_nb(ctx, ptr, (n,), jnp.float32)

            ti = time_call(dart_put_init, repeats=repeats)
            t_dart_puti.append(ti.mean_us)
            report.add(f"dtit_put/{place}/{nbytes}B/dart", ti.mean_us)
            rt.dart_flush(ctx)          # drain the timed initiations
            ti = time_call(dart_get_init, repeats=repeats)
            t_dart_geti.append(ti.mean_us)
            report.add(f"dtit_get/{place}/{nbytes}B/dart", ti.mean_us)
            rt.dart_flush(ctx)

        for kind, td, tr in (("put", t_dart_put, t_raw_put),
                             ("get", t_dart_get, t_raw_get)):
            c, se = fit_constant_overhead(sizes, td, tr)
            fits[f"{kind}/{place}"] = (c, se)
            report.add(f"overhead_fit/{kind}/{place}", c,
                       f"stderr={se:.3f}us (model t_DART-t_raw=c)")

    # --- bandwidth (figs 12-15): overlapping non-blocking then waitall --
    for place, (src, dst) in placements.items():
        ptr = gp.setunit(dst)
        for nbytes in [2 ** p for p in range(10, max_pow + 1, 4)]:
            n = nbytes // 4
            val = jnp.arange(n, dtype=jnp.float32)
            inflight = 8

            def dart_put_bw():
                hs = [rt.dart_put(ctx, ptr + (i * nbytes) % (pool // 4),
                                  val) for i in range(inflight)]
                dart_waitall(hs)

            t = time_call(dart_put_bw, repeats=max(repeats // 2, 5))
            bw = inflight * nbytes / (t.mean_us * 1e-6) / 1e9
            report.add(f"bw_put_nb/{place}/{nbytes}B", t.mean_us,
                       f"{bw:.3f}GB/s")

            def dart_get_bw():
                out = [rt.dart_get(ctx, ptr + (i * nbytes) % (pool // 4),
                                   (n,), jnp.float32)[1]
                       for i in range(inflight)]
                dart_waitall(out)

            t = time_call(dart_get_bw, repeats=max(repeats // 2, 5))
            bw = inflight * nbytes / (t.mean_us * 1e-6) / 1e9
            report.add(f"bw_get_nb/{place}/{nbytes}B", t.mean_us,
                       f"{bw:.3f}GB/s")

    # --- coalesced engine: N queued puts + one flush vs N blocking puts.
    # The derived column records jitted-dispatch counts from the engine's
    # counter — the paper's request-aggregation win made measurable.
    for nbytes in ([64, 4096] if quick else [64, 4096, 65536]):
        n = max(nbytes // 4, 1)
        val = jnp.arange(n, dtype=jnp.float32)
        stride = ((nbytes + 127) // 128) * 128

        def blocking_n_puts():
            for i in range(COALESCE_N):
                rt.dart_put_blocking(ctx, gp + i * stride, val)

        def coalesced_n_puts():
            hs = [rt.dart_put(ctx, gp + i * stride, val)
                  for i in range(COALESCE_N)]
            rt.dart_flush(ctx)
            dart_waitall(hs)

        d0 = ctx.engine.dispatch_count
        blocking_n_puts()
        d_block = ctx.engine.dispatch_count - d0
        d0 = ctx.engine.dispatch_count
        coalesced_n_puts()
        d_coal = ctx.engine.dispatch_count - d0
        assert d_coal < d_block, "coalesced flush must dispatch less"

        tb = time_call(blocking_n_puts, repeats=repeats)
        tc = time_call(coalesced_n_puts, repeats=repeats)
        report.add(f"coalesced/put_flush/{nbytes}B/{COALESCE_N}ops",
                   tc.mean_us,
                   f"blocking={tb.mean_us:.3f}us dispatches={d_coal}"
                   f"vs{d_block} speedup={tb.mean_us / tc.mean_us:.2f}x")

        def coalesced_n_gets():
            hs = [rt.dart_get_nb(ctx, gp + i * stride, (n,), jnp.float32)
                  for i in range(COALESCE_N)]
            rt.dart_flush(ctx)
            dart_waitall(hs)

        tg = time_call(coalesced_n_gets, repeats=repeats)
        report.add(f"coalesced/get_flush/{nbytes}B/{COALESCE_N}ops",
                   tg.mean_us)

    # --- §VI shared-memory window: zero-copy view vs one-sided get -----
    from repro.core import (Locality, classify_locality, dart_shm_view,
                            dart_team_memalloc_shared, shm_supported)
    if shm_supported(ctx):
        gs = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 1 << 18)
        shm_sizes = (64, 4096) if quick else (64, 4096, 262144)
        for nbytes in shm_sizes:
            n = nbytes // 4
            rt.dart_put_blocking(ctx, gs.setunit(1),
                                 jnp.arange(n, dtype=jnp.float32))

            def shm_read():
                dart_shm_view(ctx, gs.setunit(1), (n,), jnp.float32)

            def get_read():
                # force the jitted path (what a remote target would pay)
                from repro.core import onesided as _os
                _os.dart_get_blocking(ctx.state, ctx.heap,
                                      ctx.teams_by_slot, gs.setunit(1),
                                      (n,), jnp.float32)

            def routed_read():
                # runtime path: locality classifier picks the shm view
                rt.dart_get_blocking(ctx, gs.setunit(1), (n,), jnp.float32)

            assert classify_locality(ctx, gs) is Locality.SHM_LOCAL
            ts = time_call(shm_read, repeats=repeats)
            tg = time_call(get_read, repeats=repeats)
            tr = time_call(routed_read, repeats=repeats)
            report.add(f"shm_view/{nbytes}B", ts.mean_us,
                       f"get={tg.mean_us:.3f}us "
                       f"speedup={tg.mean_us / ts.mean_us:.1f}x")
            report.add(f"shm_fastpath/{nbytes}B", tr.mean_us,
                       f"jitted_get={tg.mean_us:.3f}us "
                       f"speedup={tg.mean_us / tr.mean_us:.1f}x")

    # --- typed GlobalArray front-end vs the raw byte API ----------------
    # The DASH-over-DART layering cost: same substrate ops underneath,
    # so t_typed(m) - t_raw(m) should be the constant per-call translation
    # overhead (the §V.C model applied one layer up).  `shm=False` keeps
    # the typed get on the jitted path so both sides pay the same kernel.
    dst = 1
    typed_sizes = [64, 4096] if quick else [64, 4096, 65536]
    t_typed_put, t_raw_put = [], []
    t_typed_get, t_raw_get = [], []
    for nbytes in typed_sizes:
        n = nbytes // 4
        ga = ctx.alloc((n,), jnp.float32, shm=False)
        gp_raw = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, nbytes)
        ptr_raw = gp_raw.setunit(dst)
        val = jnp.arange(n, dtype=jnp.float32)
        ref = ga[dst]

        def typed_put_block():
            ref.put(val)

        def raw_put_block():
            rt.dart_put_blocking(ctx, ptr_raw, val)

        td = time_call(typed_put_block, repeats=repeats)
        tr = time_call(raw_put_block, repeats=repeats)
        t_typed_put.append(td.mean_us)
        t_raw_put.append(tr.mean_us)
        report.add(f"typed_api/put/{nbytes}B", td.mean_us,
                   f"raw={tr.mean_us:.3f}us "
                   f"overhead={td.mean_us - tr.mean_us:.3f}us")

        def typed_get_block():
            ref.get()

        def raw_get_block():
            rt.dart_get_blocking(ctx, ptr_raw, (n,), jnp.float32)

        td = time_call(typed_get_block, repeats=repeats)
        tr = time_call(raw_get_block, repeats=repeats)
        t_typed_get.append(td.mean_us)
        t_raw_get.append(tr.mean_us)
        report.add(f"typed_api/get/{nbytes}B", td.mean_us,
                   f"raw={tr.mean_us:.3f}us "
                   f"overhead={td.mean_us - tr.mean_us:.3f}us")

        # coalesced non-blocking: N typed put_nb in one epoch vs the raw
        # enqueue + flush — both must land in ONE batched dispatch.
        def typed_coalesced():
            with ctx.epoch():
                for u in range(COALESCE_N):
                    ga[u % N_UNITS].put_nb(val)

        def raw_coalesced():
            hs = [rt.dart_put(ctx, gp_raw.setunit(u % N_UNITS), val)
                  for u in range(COALESCE_N)]
            rt.dart_flush(ctx)
            dart_waitall(hs)

        d0 = ctx.engine.dispatch_count
        typed_coalesced()
        assert ctx.engine.dispatch_count - d0 == 1, \
            "typed epoch must flush as one dispatch"
        tt = time_call(typed_coalesced, repeats=repeats)
        tc = time_call(raw_coalesced, repeats=repeats)
        report.add(f"typed_api/put_nb_coalesced/{nbytes}B/{COALESCE_N}ops",
                   tt.mean_us,
                   f"raw={tc.mean_us:.3f}us "
                   f"overhead={tt.mean_us - tc.mean_us:.3f}us")
        ga.free()
        rt.dart_team_memfree(ctx, DART_TEAM_ALL, gp_raw)

    for kind, td, tr in (("put", t_typed_put, t_raw_put),
                         ("get", t_typed_get, t_raw_get)):
        c, se = fit_constant_overhead(typed_sizes, td, tr)
        fits[f"typed/{kind}"] = (c, se)
        report.add(f"typed_api/overhead_fit/{kind}", c,
                   f"stderr={se:.3f}us (model t_typed-t_raw=c)")

    dart_exit(ctx)
    return fits


def engine_profile(*, repeats: int = 20, quick: bool = False) -> dict:
    """Machine-readable engine trajectory (written to
    ``benchmarks/out/BENCH_engine.json`` by ``benchmarks.run``):
    dispatch counts + µs/op for the blocking, coalesced, per-target
    flush, and mixed-size (overlap-aware) series, the flush cost
    model (cold compile vs warm plan-cache-hit µs/op + the
    steady-state recompile count), PLUS — schema v3 — the
    ``reduce_plane`` block: coalesced-vs-blocking accumulate µs/op
    and dispatch counts, the op-identity-padded allreduce's cold vs
    warm cost, and ``recompiles_steady_state`` over a varying
    (shape, dtype, op) allreduce+accumulate loop (pinned to 0 by the
    schema guard), PLUS — schema v4 — the ``overlap`` block: flush
    latency hidden under a device-compute window by the background
    :class:`~repro.core.progress.ProgressPlane`, progress-on vs
    progress-off wall time with steady-state recompiles still zero,
    PLUS — schema v7 — the ``faults`` block: clean vs
    transient-faulted flush µs/op (bounded retries, nothing
    exhausted), survivor throughput after a unit death, and zero
    steady-state recompiles on the retry path, PLUS — schema v8 —
    the ``shm_plane`` block: intra-node zero-copy puts through the
    shared-memory window vs the jitted blocking path (the guard pins
    shm ≥ 5× faster µs/op), shm-direct broadcast/gather/scatter at
    ZERO jitted dispatches, and zero steady-state recompiles (the shm
    route never traces anything)."""
    from repro.kernels import segmented_copy as sc
    n_ops = 8 if quick else 16
    nbytes = 4096
    n = nbytes // 4
    val = jnp.arange(n, dtype=jnp.float32)
    ctx = _mk_ctx(1 << 22)
    gp = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 1 << 20)
    stride = ((nbytes + 127) // 128) * 128
    series = {}

    def measure(name, fn, ops_per_call):
        rt.dart_flush(ctx)
        d0 = ctx.engine.dispatch_count
        fn()
        dispatches = ctx.engine.dispatch_count - d0
        t = time_call(fn, repeats=repeats)
        series[name] = {
            "dispatches": dispatches,
            "ops": ops_per_call,
            "us_per_op": round(t.mean_us / ops_per_call, 3),
            "us_per_call": round(t.mean_us, 3),
        }

    def blocking():
        for i in range(n_ops):
            rt.dart_put_blocking(ctx, gp + i * stride, val)

    def coalesced():
        hs = [rt.dart_put(ctx, gp + i * stride, val)
              for i in range(n_ops)]
        rt.dart_flush(ctx)
        dart_waitall(hs)

    def per_target():
        # half the ops target unit 1, half unit 2; flushing unit 1's
        # lane must dispatch ONE batch and leave unit 2 queued
        hs = []
        for u in (1, 2):
            hs += [rt.dart_put(ctx, gp.setunit(u) + i * stride, val)
                   for i in range(n_ops // 2)]
        rt.dart_flush(ctx, gp, target=1)
        rt.dart_flush(ctx)
        dart_waitall(hs)

    def mixed_sizes():
        hs = [rt.dart_put(ctx, gp + i * stride,
                          jnp.arange(max(n // (1 + i % 3), 1),
                                     dtype=jnp.float32))
              for i in range(n_ops)]
        rt.dart_flush(ctx)
        dart_waitall(hs)

    measure("blocking", blocking, n_ops)
    measure("coalesced", coalesced, n_ops)
    measure("per_target_flush", per_target, n_ops)
    measure("mixed_size_coalesced", mixed_sizes, n_ops)

    # --- flush cost model (schema v2): cold vs warm ------------------
    # Cold = the first coalesced flush after the plan cache is emptied
    # (pays DispatchPlan build + XLA trace/compile + dispatch).  Warm =
    # steady-state flushes of VARYING run lengths / payload sizes
    # within the same buckets (plan-cache hits: dispatch only).  The
    # paper's constant-overhead model (§V.C) only holds if warm is the
    # common case and compiles never recur — `recompiles_steady_state`
    # asserts the latter, tests pin it to zero.
    import time as _time

    def one_epoch(k, n_floats):
        hs = [rt.dart_put(ctx, gp + i * stride,
                          jnp.arange(n_floats, dtype=jnp.float32))
              for i in range(k)]
        rt.dart_flush(ctx)
        dart_waitall(hs)

    sc.clear_plan_cache()
    c0 = ctx.engine.compile_count
    t0 = _time.perf_counter()
    one_epoch(n_ops, n)                       # COLD: builds + compiles
    cold_us = (_time.perf_counter() - t0) * 1e6
    compiles_cold = ctx.engine.compile_count - c0

    warm_shapes = [(n_ops, n), (n_ops - 1, max(n - 7, 1)),
                   (n_ops - 3, max(n - 1, 1)), (n_ops, max(n // 2 + 1, 1)),
                   (n_ops - 2, n)]

    def warm_loop():
        for k, nf in warm_shapes:
            one_epoch(k, nf)

    warm_loop()                               # settle every warm shape
    c0 = ctx.engine.compile_count
    t = time_call(warm_loop, repeats=repeats)
    recompiles = ctx.engine.compile_count - c0
    warm_us = t.mean_us / len(warm_shapes)
    flush_cost = {
        "cold_us_per_op": round(cold_us / n_ops, 3),
        "warm_us_per_op": round(warm_us / n_ops, 3),
        "cold_vs_warm_speedup": round(cold_us / max(warm_us, 1e-9), 2),
        "compiles_cold": compiles_cold,
        "recompiles_steady_state": recompiles,
        "warm_epoch_shapes": len(warm_shapes),
    }

    # --- reduce plane (schema v3): queued accumulate + shape-stable ---
    # allreduce.  Coalesced accumulate (N queued + one flush = ONE
    # segmented read-modify-write dispatch) vs the blocking sequence,
    # then the op-identity-padded allreduce's cold (first bucket
    # compile) vs warm (plan-cache hit, varying shapes) µs, and the
    # combined steady-state recompile count over varying (shape,
    # dtype, op) for BOTH allreduce and accumulate — the assertable
    # form of the closed ROADMAP item.
    def acc_blocking():
        for i in range(n_ops):
            rt.dart_accumulate_blocking(ctx, gp + i * stride, val, "sum")

    def acc_coalesced():
        hs = [rt.dart_accumulate(ctx, gp + i * stride, val, "sum")
              for i in range(n_ops)]
        rt.dart_flush(ctx)
        dart_waitall(hs)

    rt.dart_flush(ctx)
    acc_blocking()                            # settle the acc plans
    d0 = ctx.engine.dispatch_count
    acc_blocking()
    acc_disp_blocking = ctx.engine.dispatch_count - d0
    d0 = ctx.engine.dispatch_count
    acc_coalesced()
    acc_disp_coalesced = ctx.engine.dispatch_count - d0
    tb = time_call(acc_blocking, repeats=repeats)
    tc = time_call(acc_coalesced, repeats=repeats)

    gr = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, 4096)
    ar_elems = 96                             # buckets to 128
    c0 = ctx.engine.compile_count
    t0 = _time.perf_counter()
    rt.dart_allreduce(ctx, gr, (ar_elems,), jnp.float32, "sum")  # COLD
    ar_cold_us = (_time.perf_counter() - t0) * 1e6
    ar_compiles_cold = ctx.engine.compile_count - c0

    ar_warm_shapes = [(96,), (100,), (128,), (65,), (8, 12)]

    def ar_warm():
        for s in ar_warm_shapes:              # all in the 128 bucket
            rt.dart_allreduce(ctx, gr, s, jnp.float32, "sum")

    ar_warm()                                 # settle every warm shape
    c0 = ctx.engine.compile_count
    t = time_call(ar_warm, repeats=repeats)
    ar_recompiles = ctx.engine.compile_count - c0
    ar_warm_us = t.mean_us / len(ar_warm_shapes)

    steady_combos = [((9,), jnp.float32, "sum"),
                     ((14,), jnp.float32, "min"),
                     ((12,), jnp.int32, "sum"),
                     ((16,), jnp.int32, "max"),
                     ((3, 4), jnp.float32, "prod")]

    def steady_loop(shift):
        for (shape, dt, op_name) in steady_combos:
            n_el = max(int(np.prod(shape)) - shift, 1)
            rt.dart_allreduce(ctx, gr, (n_el,), dt, op_name)
            hs = [rt.dart_accumulate(ctx, gp + i * stride,
                                     jnp.arange(n_el, dtype=dt),
                                     op_name)
                  for i in range(max(n_ops - shift, 1))]
            rt.dart_flush(ctx)
            dart_waitall(hs)

    steady_loop(0)                            # warm every bucket family
    steady_loop(1)
    c0 = ctx.engine.compile_count
    for shift in (2, 3, 1, 0, 2):
        steady_loop(shift)
    reduce_recompiles = ctx.engine.compile_count - c0

    reduce_plane = {
        "acc_blocking_us_per_op": round(tb.mean_us / n_ops, 3),
        "acc_coalesced_us_per_op": round(tc.mean_us / n_ops, 3),
        "acc_dispatches_blocking": acc_disp_blocking,
        "acc_dispatches_coalesced": acc_disp_coalesced,
        "acc_coalesced_vs_blocking_speedup": round(
            tb.mean_us / max(tc.mean_us, 1e-9), 2),
        "allreduce_cold_us": round(ar_cold_us, 3),
        "allreduce_warm_us": round(ar_warm_us, 3),
        "allreduce_cold_vs_warm_speedup": round(
            ar_cold_us / max(ar_warm_us, 1e-9), 2),
        "allreduce_compiles_cold": ar_compiles_cold,
        "allreduce_warm_recompiles": ar_recompiles,
        "recompiles_steady_state": reduce_recompiles,
    }

    # --- overlap (schema v4): flush latency hidden under the device-
    # compute window by the background ProgressPlane.  The body
    # enqueues n_over large puts, sits in a device-busy host-idle
    # window, then completes.  With progress OFF the flush's full host
    # cost lands after the window (serial); with progress ON the
    # daemon crosses its op watermark at the last enqueue and flushes
    # DURING the window, so completion finds the lane already drained.
    # On this single-core CPU container the host-idle window is
    # emulated with a sleep sized from the measured flush cost — real
    # jitted compute here would saturate the same core the flush
    # needs; on a device mesh the window is genuine accelerator time
    # and the same body holds (EXPERIMENTS.md honesty rule).
    over_bytes = (1 << 14) if quick else (1 << 16)
    n_over = 8
    over_val = jnp.arange(over_bytes // 4, dtype=jnp.float32)
    go = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL,
                                    over_bytes * (n_over + 1))

    def over_enqueue():
        return [rt.dart_put(ctx, go + i * over_bytes, over_val)
                for i in range(n_over)]

    def over_flush_only():
        hs = over_enqueue()
        rt.dart_flush(ctx)
        dart_waitall(hs)

    over_flush_only()                          # settle the put plans
    over_reps = max(repeats // 2, 5)
    t_fl = time_call(over_flush_only, repeats=over_reps)
    compute_s = max(2.0 * t_fl.mean_us * 1e-6, 0.002)

    def overlap_off():
        hs = over_enqueue()
        _time.sleep(compute_s)                 # host-idle compute window
        rt.dart_flush(ctx)
        dart_waitall(hs)

    def overlap_on():
        hs = over_enqueue()
        _time.sleep(compute_s)
        dart_waitall(hs)

    c0 = ctx.engine.compile_count
    t_off = time_call(overlap_off, repeats=over_reps)
    # op watermark == n_over: the daemon fires exactly once per body,
    # right after the last enqueue, producing the SAME coalesced run
    # (and plan-cache key) as the foreground flush — zero recompiles.
    plane = ctx.start_progress(watermark_ops=n_over,
                               watermark_bytes=1 << 30, idle_s=60.0)
    t_on = time_call(overlap_on, repeats=over_reps)
    ctx.stop_progress(drain=True)
    over_recompiles = ctx.engine.compile_count - c0

    overlap = {
        "n_ops": n_over,
        "nbytes": over_bytes,
        "compute_window_us": round(compute_s * 1e6, 3),
        "flush_only_us": round(t_fl.mean_us, 3),
        "progress_off_us": round(t_off.mean_us, 3),
        "progress_on_us": round(t_on.mean_us, 3),
        "overlap_speedup": round(
            t_off.mean_us / max(t_on.mean_us, 1e-9), 3),
        "background_flushes": plane.flushes,
        "watermark_ops": n_over,
        "recompiles_steady_state": over_recompiles,
    }

    # isolation numbers for the per-target series: dispatches seen by
    # the target-1 flush alone, with target 2 still queued
    hs = []
    for u in (1, 2):
        hs += [rt.dart_put(ctx, gp.setunit(u) + i * stride, val)
               for i in range(n_ops // 2)]
    d0 = ctx.engine.dispatch_count
    rt.dart_flush(ctx, gp, target=1)
    series["per_target_flush"]["dispatches_target_only"] = \
        ctx.engine.dispatch_count - d0
    series["per_target_flush"]["ops_left_queued"] = ctx.engine.pending_ops()
    rt.dart_flush(ctx)
    dart_waitall(hs)

    # --- strided transfer IR (schema v6) -----------------------------
    # One strided run = ONE descriptor at every layer (ISSUE 8): a
    # matrix column of N elements moves as a single dispatch, and its
    # µs/op must stay within ~2x of the contiguous row path (it was
    # ~Nx when strided access exploded into per-element descriptors).
    # Stride/count are descriptor DATA, so a varying-stride loop at
    # fixed (seg, count) buckets recompiles NOTHING after warmup.
    rows = cols = 32 if quick else 64
    # shm=False: both series ride the counted one-sided engine path
    # (the zero-copy SHM view would make the contiguous baseline a
    # host memcpy and the ratio meaningless)
    sga = ctx.alloc((rows, cols), jnp.float32, shm=False)
    sga[1].put(jnp.zeros((rows, cols), jnp.float32))
    rt.dart_flush(ctx)
    rowv = jnp.arange(cols, dtype=jnp.float32)
    colv = jnp.arange(rows, dtype=jnp.float32)

    def contig_put():
        sga.at[1, 0].put_nb(rowv)
        rt.dart_flush(ctx)

    def strided_put():
        sga.at[1, :, 0].put_nb(colv)
        rt.dart_flush(ctx)

    def contig_get():
        sga.at[1, 0].get()

    def strided_get():
        sga.at[1, :, 0].get()

    for warm in (contig_put, strided_put, contig_get, strided_get):
        warm()
    d0 = ctx.engine.dispatch_count
    strided_put()
    sput_dispatches = ctx.engine.dispatch_count - d0
    d0 = ctx.engine.dispatch_count
    strided_get()
    sget_dispatches = ctx.engine.dispatch_count - d0
    t_cput = time_call(contig_put, repeats=repeats)
    t_sput = time_call(strided_put, repeats=repeats)
    t_cget = time_call(contig_get, repeats=repeats)
    t_sget = time_call(strided_get, repeats=repeats)

    def varying_stride_loop():
        # same seg (1 elem) and count (rows) buckets, stride varies:
        # plan keys never change, only descriptor data does
        for c in (0, 1, 3, cols - 1):
            sga.at[1, :, c].put_nb(colv)
            rt.dart_flush(ctx)
            sga.at[1, :, c].get()

    varying_stride_loop()                     # warm every geometry
    c0 = ctx.engine.compile_count
    varying_stride_loop()
    strided = {
        "elems": rows,
        "contiguous_put_us_per_op": round(t_cput.mean_us / cols, 3),
        "strided_put_us_per_op": round(t_sput.mean_us / rows, 3),
        "contiguous_get_us_per_op": round(t_cget.mean_us / cols, 3),
        "strided_get_us_per_op": round(t_sget.mean_us / rows, 3),
        "put_vs_contiguous_ratio": round(
            (t_sput.mean_us / rows) / max(t_cput.mean_us / cols, 1e-9), 3),
        "get_vs_contiguous_ratio": round(
            (t_sget.mean_us / rows) / max(t_cget.mean_us / cols, 1e-9), 3),
        "dispatches_per_strided_put": sput_dispatches,
        "dispatches_per_strided_get": sget_dispatches,
        "recompiles_steady_state": ctx.engine.compile_count - c0,
    }

    # --- narray (schema v6): DASH-style container over the strided IR
    from repro.core import NArray, TileDist
    gr = gc = 4                               # N_UNITS = 16 unit grid
    na = NArray(ctx, (rows, cols), jnp.float32,
                dist=TileDist((gr, gc)), shm=False)
    na.from_numpy(np.zeros((rows, cols), np.float32))
    rt.dart_flush(ctx)

    def narray_col():
        na.get_col(1)

    narray_col()
    d0 = ctx.engine.dispatch_count
    narray_col()
    col_dispatches = ctx.engine.dispatch_count - d0
    t_col = time_call(narray_col, repeats=repeats)
    t_red = time_call(lambda: na.reduce("sum"), repeats=repeats)
    narray = {
        "dist": f"tiled({gr}x{gc})",
        "col_elems": rows,
        "get_col_us_per_elem": round(t_col.mean_us / rows, 3),
        "get_col_dispatches": col_dispatches,   # one per owning tile
        "owning_tiles": gr,
        "reduce_us": round(t_red.mean_us, 3),
    }

    # --- fault plane (schema v7) -------------------------------------
    # Retry/degradation cost model: a clean coalesced flush epoch vs
    # one with scheduled transient dispatch faults (each absorbed by
    # the bounded retry loop), plus survivor throughput after a unit
    # death.  The schema guard pins: retries fired but stayed bounded
    # (retries_exhausted == 0), degraded-mode throughput > 0, and zero
    # steady-state recompiles — the retry path replays the SAME
    # compiled dispatch plan, it never retraces.
    from repro.core import UnitFailedError
    team_poolid = ctx.teams[DART_TEAM_ALL].poolid

    def clean_epoch():
        hs = [rt.dart_put(ctx, gp + i * stride, val)
              for i in range(n_ops)]
        rt.dart_flush(ctx)
        dart_waitall(hs)

    clean_epoch()
    t_clean = time_call(clean_epoch, repeats=repeats)

    plane = ctx.attach_faults(seed=7)
    # measure the retry mechanism, not the backoff sleep
    ctx.engine.retry_base_s = 1e-5
    ctx.engine.retry_max_s = 1e-4

    def faulty_epoch():
        # two transient pre-dispatch faults per epoch, both retryable
        plane.schedule(kind="fail", poolid=team_poolid, times=2)
        clean_epoch()

    faulty_epoch()                            # warm (plans already hot)
    r0 = ctx.engine.retries
    c0 = ctx.engine.compile_count
    t_faulty = time_call(faulty_epoch, repeats=repeats)
    retries_fired = ctx.engine.retries - r0
    fault_recompiles = ctx.engine.compile_count - c0

    # degraded mode: unit 3 dies; survivors 1 and 2 keep flushing
    dead_unit = 3
    ctx.engine.mark_unit_dead(dead_unit, reason="bench")
    n_done = 0
    t0 = _time.perf_counter()
    for _ in range(repeats):
        hs = []
        for u in (1, 2, dead_unit):
            try:
                hs.append(rt.dart_put(ctx, gp.setunit(u), val))
            except UnitFailedError:
                pass                          # dead lane fails fast
        rt.dart_flush(ctx)
        dart_waitall(hs)
        n_done += len(hs)
    degraded_s = _time.perf_counter() - t0
    stats = ctx.engine.fault_stats()
    faults_block = {
        "clean_us_per_op": round(t_clean.mean_us / n_ops, 3),
        "faulty_us_per_op": round(t_faulty.mean_us / n_ops, 3),
        "retry_overhead_ratio": round(
            t_faulty.mean_us / max(t_clean.mean_us, 1e-9), 3),
        "retries": retries_fired,
        "retries_exhausted": stats["retries_exhausted"],
        "at_most_once_aborts": stats["at_most_once_aborts"],
        "injected_fails": plane.counters["injected_fails"],
        "dead_unit": dead_unit,
        "degraded_ops_done": n_done,
        "degraded_ops_per_s": round(n_done / max(degraded_s, 1e-9), 1),
        "enqueue_rejections": stats["enqueue_rejections"],
        "recompiles_steady_state": fault_recompiles,
    }
    ctx.engine.attach_faults(None)

    # --- shm plane (schema v8) ---------------------------------------
    # Write-side zero-copy: blocking puts on a FLAG_SHM pointer route
    # through the shared-memory window (locked host memcpy, zero
    # jitted dispatches) vs the identical puts on the non-shm `gp`
    # riding the jitted scatter.  Collectives on the shm pool go
    # shm-direct: the guard pins all three at 0 dispatches.
    from repro.core import dart_team_memalloc_shared
    ctx.engine.revive_unit(dead_unit)          # heal the faults block
    gshm = dart_team_memalloc_shared(ctx, DART_TEAM_ALL, 1 << 20)
    tshm = gshm.setunit(1)

    def shm_put():
        for i in range(n_ops):
            rt.dart_put_blocking(ctx, tshm + i * stride, val)

    def jitted_put():
        for i in range(n_ops):
            rt.dart_put_blocking(ctx, gp.setunit(1) + i * stride, val)

    shm_put()
    jitted_put()                               # plans hot
    c0 = ctx.engine.compile_count
    d0 = ctx.engine.dispatch_count
    t_shm_put = time_call(shm_put, repeats=repeats)
    shm_put_dispatches = ctx.engine.dispatch_count - d0
    t_jit_put = time_call(jitted_put, repeats=repeats)

    def shm_get():
        for i in range(n_ops):
            rt.dart_get_blocking(ctx, tshm + i * stride, (n,), jnp.float32)

    shm_get()
    t_shm_get = time_call(shm_get, repeats=repeats)

    rt.dart_flush(ctx)
    d0 = ctx.engine.dispatch_count
    rt.dart_bcast(ctx, gshm, nbytes).wait()
    bcast_dispatches = ctx.engine.dispatch_count - d0
    d0 = ctx.engine.dispatch_count
    gat, gh = rt.dart_gather(ctx, gshm, nbytes)
    gh.wait()
    gather_dispatches = ctx.engine.dispatch_count - d0
    d0 = ctx.engine.dispatch_count
    rt.dart_scatter(ctx, gshm, np.asarray(gat)).wait()
    scatter_dispatches = ctx.engine.dispatch_count - d0
    t_bcast = time_call(lambda: rt.dart_bcast(ctx, gshm, nbytes).wait(),
                        repeats=repeats)
    shm_plane = {
        "shm_put_us_per_op": round(t_shm_put.mean_us / n_ops, 3),
        "jitted_put_us_per_op": round(t_jit_put.mean_us / n_ops, 3),
        "shm_put_speedup": round(
            t_jit_put.mean_us / max(t_shm_put.mean_us, 1e-9), 2),
        "shm_get_us_per_op": round(t_shm_get.mean_us / n_ops, 3),
        "shm_put_dispatches": shm_put_dispatches,
        "broadcast_us": round(t_bcast.mean_us, 3),
        "broadcast_dispatches": bcast_dispatches,
        "gather_dispatches": gather_dispatches,
        "scatter_dispatches": scatter_dispatches,
        "shm_puts": ctx.engine.shm_puts,
        "shm_collective_ops": ctx.engine.shm_collective_ops,
        "recompiles_steady_state": ctx.engine.compile_count - c0,
    }

    profile = {
        "schema": "BENCH_engine/v8",
        "n_ops": n_ops,
        "nbytes": nbytes,
        "quick": quick,
        "series": series,
        "flush_cost": flush_cost,
        "reduce_plane": reduce_plane,
        "overlap": overlap,
        "strided": strided,
        "narray": narray,
        "faults": faults_block,
        "shm_plane": shm_plane,
        "plan_cache": {
            "compile_count": ctx.engine.compile_count,
            "plan_cache_hits": ctx.engine.plan_cache_hits,
            **sc.plan_cache_stats(),
        },
        "engine_totals": {
            "dispatch_count": ctx.engine.dispatch_count,
            "ops_enqueued": ctx.engine.ops_enqueued,
            "ops_coalesced": ctx.engine.ops_coalesced,
        },
    }
    dart_exit(ctx)
    return profile
