"""DART team collectives vs raw lax (paper §IV.B.5 overhead story).

Runs in a subprocess-friendly way on the host plane: the DART
collective path (team translation + segment lookup + jitted op) vs the
identical raw jitted op, per payload size.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (DART_TEAM_ALL, DartConfig, dart_allreduce,
                        dart_bcast, dart_exit, dart_init,
                        dart_team_memalloc_aligned)
from repro.core import runtime as rt

from .common import Report, fit_constant_overhead, time_call


def run(report: Report, *, repeats: int = 20):
    n_units = 16
    pool = 1 << 21
    ctx = dart_init(n_units=n_units, config=DartConfig(
        non_collective_pool_bytes=4096, team_pool_bytes=pool))
    gp = dart_team_memalloc_aligned(ctx, DART_TEAM_ALL, pool // 2)
    poolid = ctx.teams[DART_TEAM_ALL].poolid   # window-registry binding

    sizes = [2 ** p for p in range(6, 19, 4)]
    t_dart, t_raw = [], []
    for nbytes in sizes:
        n = nbytes // 4
        shape = (n,)

        @jax.jit
        def raw_allreduce(arena):
            raw = jax.lax.dynamic_slice(arena, (0, 0),
                                        (arena.shape[0], n * 4))
            vals = jax.vmap(lambda r: jax.lax.bitcast_convert_type(
                r.reshape(n, 4), jnp.float32).reshape(-1))(raw)
            return vals.sum(axis=0)

        def dart_ar():
            dart_allreduce(ctx, gp, shape, jnp.float32, op="sum")

        def raw_ar():
            raw_allreduce(ctx.state[poolid]).block_until_ready()

        td = time_call(dart_ar, repeats=repeats)
        tr = time_call(raw_ar, repeats=repeats)
        t_dart.append(td.mean_us)
        t_raw.append(tr.mean_us)
        report.add(f"allreduce/{nbytes}B/dart", td.mean_us,
                   f"raw={tr.mean_us:.3f}us")

        def dart_bc():
            dart_bcast(ctx, gp, nbytes)

        t = time_call(dart_bc, repeats=repeats)
        report.add(f"bcast/{nbytes}B/dart", t.mean_us)

    c, se = fit_constant_overhead(sizes, t_dart, t_raw)
    report.add("overhead_fit/allreduce", c, f"stderr={se:.3f}us")
    dart_exit(ctx)
