"""Serving-plane benchmark: continuous batching vs the synchronous wave.

    PYTHONPATH=src python -m benchmarks.serve_bench [--quick]

Open-loop protocol: a seeded Poisson arrival process (exponential
inter-arrival gaps — submit times do NOT depend on service progress,
so queueing delay is measured, not hidden) drives the SAME trace of
templated prompts with heterogeneous ``max_new_tokens`` through both
engines:

* :class:`repro.serve.ServeEngine` — the synchronous-wave baseline;
* :class:`repro.serve.ContinuousEngine` — per-step admit/retire over
  fixed slots with the PGAS prefix/KV-block cache.

Each engine first replays the full trace once untimed (warmup: jit
caches, DART dispatch plans, and — for the continuous engine — the
prefix directory go warm), then replays it paced for the timed pass.
Reported per engine: useful tokens/s (emitted tokens / makespan) and
p50/p99 request latency.  For the continuous engine the timed pass
additionally pins the PGAS story: prefix-hit rate, hit traffic served
by one-sided ``get_nb`` + per-target flush (engine dispatch deltas
prove the coalescing plane carried it), and ZERO steady-state
recompiles (jit cache sizes + prefill buckets + DART plan compiles all
flat).

Results merge as the ``serving`` block into
``benchmarks/out/BENCH_engine.json`` (schema BENCH_engine/v8) —
run ``python -m benchmarks.run --quick`` first;
``scripts/check_bench_schema.py`` enforces the acceptance pins.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Tuple

import numpy as np

from .common import OUT_DIR

Trace = List[Tuple[float, np.ndarray, int]]   # (arrival_s, prompt, budget)


def make_trace(rng: np.random.RandomState, *, n_requests: int,
               n_templates: int, rate_rps: float, len_lo: int = 3,
               len_hi: int = 14, budget_lo: int = 4,
               budget_hi: int = 20) -> Trace:
    """Open-loop Poisson trace over repeated prompt templates.

    Few templates + many requests = the repeat traffic real serving
    sees (popular prompts), which is what the prefix cache converts
    into one-sided block reads."""
    templates = [
        rng.randint(1, 400, size=int(rng.randint(len_lo, len_hi + 1)))
        .astype(np.int32)
        for _ in range(n_templates)]
    trace: Trace = []
    t = 0.0
    for _ in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        tpl = templates[int(rng.randint(n_templates))]
        budget = int(rng.randint(budget_lo, budget_hi + 1))
        trace.append((t, tpl, budget))
    return trace


def play(engine, trace: Trace, *, paced: bool) -> List:
    """Submit the trace (paced = honor the Poisson arrival times,
    open-loop) and wait for every request to finish."""
    reqs = []
    t0 = time.perf_counter()
    for at, prompt, budget in trace:
        if paced:
            delay = at - (time.perf_counter() - t0)
            if delay > 0:
                time.sleep(delay)
        reqs.append(engine.submit(prompt, max_new_tokens=budget))
    for r in reqs:
        if not r.done.wait(timeout=300):
            raise RuntimeError(f"request {r.rid} never completed")
    return reqs


def summarize(reqs) -> Dict[str, float]:
    lat_ms = np.array([(r.t_done - r.t_submit) * 1e3 for r in reqs])
    tokens = int(sum(len(r.output) for r in reqs))
    makespan = max(r.t_done for r in reqs) - min(r.t_submit for r in reqs)
    return {
        "n_requests": len(reqs),
        "tokens": tokens,
        "makespan_s": round(makespan, 4),
        "tokens_per_s": round(tokens / max(makespan, 1e-9), 2),
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
        "mean_ms": round(float(lat_ms.mean()), 3),
    }


def run(*, quick: bool = False, seed: int = 0) -> Dict[str, object]:
    import jax

    from repro.configs import get_config
    from repro.models import api
    from repro.models.config import reduced_for_smoke
    from repro.serve import ContinuousEngine, ServeEngine

    cfg = reduced_for_smoke(get_config("llama3-8b"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    max_batch = 4
    max_seq = 64
    n_requests = 24 if quick else 96
    rate_rps = 60.0 if quick else 80.0

    rng = np.random.RandomState(seed)
    trace = make_trace(rng, n_requests=n_requests, n_templates=6,
                       rate_rps=rate_rps)

    # -- synchronous-wave baseline --------------------------------------
    wave = ServeEngine(cfg, params, max_batch=max_batch, max_seq=max_seq)
    wave.run_forever()
    play(wave, trace, paced=False)            # warmup (untimed)
    wave_reqs = play(wave, trace, paced=True)
    wave.stop()
    wave_sum = summarize(wave_reqs)

    # -- continuous engine ----------------------------------------------
    cont = ContinuousEngine(cfg, params, max_batch=max_batch,
                            max_seq=max_seq, block_tokens=8,
                            n_cache_blocks=128)
    cont.run_forever()
    play(cont, trace, paced=False)            # warmup (untimed)
    s0 = cont.stats()
    jit0 = (cont._prefill._cache_size() + cont._decode._cache_size()
            + cont._insert._cache_size())
    cont_reqs = play(cont, trace, paced=True)
    s1 = cont.stats()
    jit1 = (cont._prefill._cache_size() + cont._decode._cache_size()
            + cont._insert._cache_size())
    cont.stop()
    cont_sum = summarize(cont_reqs)

    p0, p1 = s0["prefix"], s1["prefix"]
    lookups = p1["lookups"] - p0["lookups"]
    hits = p1["hits"] - p0["hits"]
    recompiles = ((jit1 - jit0)
                  + (s1["prefill_shape_misses"]
                     - s0["prefill_shape_misses"])
                  + (s1["engine_plan_compiles"]
                     - s0["engine_plan_compiles"]))

    serving = {
        "n_requests": n_requests,
        "poisson_rate_rps": rate_rps,
        "seed": seed,
        "max_batch": max_batch,
        "quick": quick,
        "wave": wave_sum,
        "continuous": {
            **cont_sum,
            "decode_steps": s1["decode_steps"] - s0["decode_steps"],
            "prefills": s1["prefills"] - s0["prefills"],
            "recompiles_steady_state": recompiles,
            "engine_dispatches": (s1["engine_dispatches"]
                                  - s0["engine_dispatches"]),
        },
        "speedup_tokens_per_s": round(
            cont_sum["tokens_per_s"]
            / max(wave_sum["tokens_per_s"], 1e-9), 3),
        "prefix_lookups": lookups,
        "prefix_hits": hits,
        "prefix_hit_rate": round(hits / max(lookups, 1), 3),
        "hit_fetch_get_nb_ops": (p1["fetch_get_nb_ops"]
                                 - p0["fetch_get_nb_ops"]),
        "hit_fetch_flushes": p1["fetch_flushes"] - p0["fetch_flushes"],
        "hit_fetch_dispatches": (p1["fetch_dispatches"]
                                 - p0["fetch_dispatches"]),
        "prefix_evictions": p1["evictions"] - p0["evictions"],
    }
    return serving


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke trace for CI (24 requests)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    serving = run(quick=args.quick, seed=args.seed)

    jpath = OUT_DIR / "BENCH_engine.json"
    if jpath.exists():
        profile = json.loads(jpath.read_text())
    else:   # standalone run: a serving-only stub (CI runs benchmarks.run
            # first, so the full profile is normally already there)
        profile = {"schema": "BENCH_engine/v8"}
    profile["serving"] = serving
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    with open(jpath, "w") as f:
        json.dump(profile, f, indent=2, sort_keys=True)
        f.write("\n")

    c, w = serving["continuous"], serving["wave"]
    print(f"serving: continuous {c['tokens_per_s']} tok/s "
          f"(p50 {c['p50_ms']}ms p99 {c['p99_ms']}ms) vs wave "
          f"{w['tokens_per_s']} tok/s (p50 {w['p50_ms']}ms p99 "
          f"{w['p99_ms']}ms) -> {serving['speedup_tokens_per_s']}x; "
          f"prefix hit rate {serving['prefix_hit_rate']} "
          f"({serving['hit_fetch_get_nb_ops']} get_nb, "
          f"{serving['hit_fetch_flushes']} per-target flushes, "
          f"{serving['hit_fetch_dispatches']} dispatches), "
          f"{c['recompiles_steady_state']} steady-state recompiles")
    print(f"# wrote {jpath}")


if __name__ == "__main__":
    main()
