"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV (spec) and writes
benchmarks/out/*.csv.  Mapping to the paper:

    put_get    — figs 8/9 (DTCT), 10/11 (DTIT), 12–15 (bandwidth),
                 + the §V.C constant-overhead model fit
                 + the typed_api series (GlobalArray front-end vs raw
                 byte API; runs in --quick too)
    collective — §IV.B.5 collectives overhead
    lock       — §IV.B.6 MCS lock + §VI balanced-tail comparison
    teamlist   — §IV.B.2 slot allocator + §VI O(1) variant
    alloc      — §IV.B.3 allocation/dereference costs

Roofline tables (§Roofline) are produced by the dry-run pipeline
(``python -m repro.launch.dryrun --all`` then
``python -m benchmarks.roofline``), not by this wall-clock harness.
"""

from __future__ import annotations

import argparse
import json
import sys

from .common import OUT_DIR, Report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full message-size sweep (to 2MiB)")
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: tiny sweeps, 2 repeats — checks every "
                         "suite still runs, numbers are not meaningful")
    ap.add_argument("--only", default=None,
                    help="run a single suite: put_get|collective|lock|"
                         "teamlist|alloc")
    ap.add_argument("--repeats", type=int, default=20)
    args = ap.parse_args()
    if args.quick:
        args.repeats = 2
        args.full = False

    from . import (alloc_bench, collective_bench, lock_bench, put_get,
                   teamlist_bench)

    slow_repeats = args.repeats if args.quick else max(args.repeats, 50)
    suites = {
        "put_get": lambda r: put_get.run(r, full=args.full,
                                         repeats=args.repeats,
                                         quick=args.quick),
        "collective": lambda r: collective_bench.run(
            r, repeats=args.repeats),
        "lock": lambda r: lock_bench.run(r, repeats=slow_repeats),
        "teamlist": lambda r: teamlist_bench.run(r, repeats=slow_repeats),
        "alloc": lambda r: alloc_bench.run(r, repeats=slow_repeats),
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    failures = 0
    for name, fn in suites.items():
        print(f"# === suite: {name} ===", flush=True)
        report = Report()
        try:
            fn(report)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# suite {name} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
        path = report.save(f"{name}.csv")
        print(f"# wrote {path}", flush=True)

    if "put_get" in suites:
        # machine-readable engine trajectory (schema BENCH_engine/v8:
        # dispatch counts + µs/op for blocking vs coalesced vs
        # per-target vs mixed-size, the flush cost model — cold
        # compile vs warm plan-cache-hit µs/op and steady-state
        # recompile count — plus the v6 strided + narray series:
        # strided-vs-contiguous µs/op ratio, 1-dispatch strided runs,
        # varying-stride zero-recompile pin, tiled NArray column
        # gather — and the v7 faults series: clean vs faulted
        # flush µs/op, bounded retries, survivor throughput after
        # a unit death): the perf numbers dashboards diff across
        # PRs.
        # scripts/check_bench_schema.py (run by `make verify`) fails
        # CI on schema drift.
        try:
            profile = put_get.engine_profile(repeats=args.repeats,
                                             quick=args.quick)
            OUT_DIR.mkdir(parents=True, exist_ok=True)
            jpath = OUT_DIR / "BENCH_engine.json"
            with open(jpath, "w") as f:
                json.dump(profile, f, indent=2, sort_keys=True)
                f.write("\n")
            print(f"# wrote {jpath}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# engine profile FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
