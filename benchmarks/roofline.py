"""Roofline analysis from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
derives, per (arch × shape × mesh):

    compute term    = HLO_FLOPs_per_device / peak_FLOPs
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_link_bytes_per_device / collective_bw

Hardware constants (v5e-like, per instructions): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI; we assume ring collectives use 2 links
concurrently => 100 GB/s effective per-chip collective bandwidth
(DESIGN.md §7).  cost_analysis() is per-device post-SPMD (verified
empirically — see EXPERIMENTS.md §Dry-run methodology), so no /chips is
applied.

MODEL_FLOPS uses 6·N·D (dense) / 6·N_active·D (MoE) for training; for
inference steps the factor is 2·N (forward only) per token.  The ratio MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy
waste (remat=full targets ~6/8 = 0.75 for training).

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / ICI link
COLL_BW = 2 * LINK_BW        # bidirectional ring: 2 links in flight

SHAPE_TOKENS = {
    # tokens processed per executed step
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,        # one token per sequence
    "long_500k": 1,
}


def model_flops(rec: Dict) -> float:
    n = rec["active_param_count"]
    tokens = SHAPE_TOKENS[rec["shape"]]
    if rec["kind"] == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def analyse(rec: Dict) -> Optional[Dict]:
    if not rec.get("ok"):
        return None
    n_dev = rec["n_devices"]
    t_compute = rec["flops_per_device"] / PEAK_FLOPS
    t_memory = rec["bytes_accessed_per_device"] / HBM_BW
    t_coll = rec["collective_link_bytes_per_device"] / COLL_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_total = rec["flops_per_device"] * n_dev
    useful = mf / hlo_total if hlo_total else 0.0
    step_time = max(terms.values())
    # roofline fraction: useful model FLOPs per chip-second vs peak
    mfu_bound = (mf / n_dev / step_time) / PEAK_FLOPS if step_time else 0.0
    return {
        **{f"t_{k}_s": v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_fraction": mfu_bound,
        "step_time_bound_s": step_time,
    }


LEVER = {
    ("train", "compute"): "cut HLO/MODEL flops gap (remat policy, fused "
                          "attention) — compute-bound is the good case",
    ("train", "memory"): "raise arithmetic intensity: larger per-chip "
                         "batch, bf16 master/opt state, fused norms",
    ("train", "collective"): "shrink FSDP/TP traffic: 2D sharding, "
                             "overlapped all-gathers, grad compression",
    ("prefill", "compute"): "fused block attention; good case",
    ("prefill", "memory"): "KV cache layout + flash-style tiling",
    ("prefill", "collective"): "sequence-parallel attention instead of "
                               "activation all-gathers",
    ("decode", "compute"): "batch more sequences per chip",
    ("decode", "memory"): "decode is weight/KV-bandwidth bound by nature: "
                          "quantize weights/KV, widen batch",
    ("decode", "collective"): "keep TP collectives off the token path "
                              "(all-gather weights once, ring KV)",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--md", default=None, help="write markdown table here")
    args = ap.parse_args()

    rows: List[Dict] = []
    for p in sorted(pathlib.Path(args.dir).glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("tag"):
            continue
        if rec["mesh"] != args.mesh:
            continue
        if not rec.get("applicable", True):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skip": rec.get("skip_reason", "n/a")})
            continue
        a = analyse(rec)
        if a is None:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skip": "FAILED: " + rec.get("error", "?")})
            continue
        rows.append({"arch": rec["arch"], "shape": rec["shape"],
                     "kind": rec["kind"], **a})

    hdr = (f"| arch | shape | compute s | memory s | collective s | "
           f"dominant | MODEL/HLO | roofline frac | lever |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if "skip" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — | {r['skip'][:60]} |")
            continue
        lever = LEVER.get((r["kind"], r["dominant"]), "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.1%} | {lever[:70]} |")
    table = "\n".join(lines)
    print(table)
    if args.md:
        pathlib.Path(args.md).write_text(table + "\n")


if __name__ == "__main__":
    main()
