"""Shared benchmark machinery: timers, CSV output, overhead-model fit."""

from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

OUT_DIR = pathlib.Path(__file__).parent / "out"


@dataclasses.dataclass
class Timing:
    mean_us: float
    std_us: float
    n: int


def time_call(fn: Callable[[], None], *, repeats: int = 30,
              warmup: int = 5) -> Timing:
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        samples.append((time.perf_counter() - t0) * 1e6)
    a = np.asarray(samples)
    # drop outliers beyond 3 MAD (scheduler noise on a 1-core box)
    med = np.median(a)
    mad = np.median(np.abs(a - med)) + 1e-9
    a = a[np.abs(a - med) < 5 * mad]
    return Timing(float(a.mean()), float(a.std()), len(a))


def fit_constant_overhead(sizes: Sequence[int],
                          t_dart_us: Sequence[float],
                          t_raw_us: Sequence[float]
                          ) -> Tuple[float, float]:
    """Paper §V model: t_DART(m) − t_raw(m) = c.

    Least-squares constant fit; returns (c_us, std_err_us)."""
    d = np.asarray(t_dart_us) - np.asarray(t_raw_us)
    c = float(d.mean())
    se = float(d.std(ddof=1) / np.sqrt(len(d))) if len(d) > 1 else 0.0
    return c, se


class Report:
    """Collects `name,us_per_call,derived` CSV rows (benchmarks spec)."""

    def __init__(self):
        self.rows: List[Tuple[str, float, str]] = []

    def add(self, name: str, us: float, derived: str = ""):
        self.rows.append((name, us, derived))
        print(f"{name},{us:.3f},{derived}")

    def save(self, fname: str):
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        p = OUT_DIR / fname
        with open(p, "w") as f:
            f.write("name,us_per_call,derived\n")
            for name, us, derived in self.rows:
                f.write(f"{name},{us:.3f},{derived}\n")
        return p
