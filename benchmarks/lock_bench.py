"""MCS lock benchmarks (paper §IV.B.6 + §VI future work).

* uncontended acquire/release latency
* contended throughput (N threads hammering one lock)
* tail-placement congestion: unit0 (paper) vs round_robin
  (beyond-paper §VI) — measured via the atomics provider's per-home
  traffic counters, plus a naive central spinlock baseline for
  contrast.
"""

from __future__ import annotations

import threading
import time

from repro.core import (LockService, Team, ThreadedAtomics,
                        group_from_units)

from .common import Report, time_call


def _mk(n=8, placement="unit0"):
    at = ThreadedAtomics(n)
    svc = LockService(at, tail_placement=placement)
    team = Team(teamid=0, group=group_from_units(range(n)), slot=0)
    return at, svc, team


def run(report: Report, *, repeats: int = 200):
    # -- uncontended latency ---------------------------------------------
    _, svc, team = _mk()
    lock = svc.create_lock(team)

    def acq_rel():
        svc.acquire(lock, 0)
        svc.release(lock, 0)

    t = time_call(acq_rel, repeats=repeats)
    report.add("lock/uncontended_acq_rel", t.mean_us)

    def try_acq():
        svc.try_acquire(lock, 0)
        svc.release(lock, 0)

    t = time_call(try_acq, repeats=repeats)
    report.add("lock/uncontended_try_acq_rel", t.mean_us)

    # -- contended throughput --------------------------------------------
    for n_threads in (2, 4, 8):
        _, svc, team = _mk(n_threads)
        lock = svc.create_lock(team)
        iters = 200

        def worker(u):
            for _ in range(iters):
                svc.acquire(lock, u)
                svc.release(lock, u)

        t0 = time.perf_counter()
        ts = [threading.Thread(target=worker, args=(u,))
              for u in range(n_threads)]
        for th in ts:
            th.start()
        for th in ts:
            th.join()
        dt = time.perf_counter() - t0
        per_cs = dt / (n_threads * iters) * 1e6
        report.add(f"lock/contended_{n_threads}threads", per_cs,
                   f"{n_threads * iters / dt:.0f} cs/s")

    # -- tail placement congestion (paper §VI) ----------------------------
    for placement in ("unit0", "round_robin"):
        at, svc, team = _mk(8, placement)
        locks = [svc.create_lock(team) for _ in range(16)]
        for i, l in enumerate(locks):
            for _ in range(50):
                svc.acquire(l, i % 8)
                svc.release(l, i % 8)
        peak = max(at.home_traffic.values())
        total = sum(at.home_traffic.values())
        report.add(f"lock/tail_traffic_peak/{placement}", float(peak),
                   f"total={total} imbalance={peak / (total / 8):.2f}x")
