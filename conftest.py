import pathlib
import sys

# repo root on sys.path so `benchmarks` (top-level package) is importable
# from tests; `repro` itself comes from PYTHONPATH=src per the README.
ROOT = pathlib.Path(__file__).parent
for p in (str(ROOT), str(ROOT / "src"), str(ROOT / "tests")):
    if p not in sys.path:
        sys.path.insert(0, p)
