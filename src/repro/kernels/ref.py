"""Pure-jnp oracles for the Pallas comm kernels.

Each function mirrors the SPMD signature of its kernel counterpart and
is meant to be called inside the same ``shard_map``; implementations
use only ``jax.lax`` collectives / ``jnp`` ops (no Pallas), so they
serve as the correctness reference on any backend.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rdma_put_ref(x: jax.Array, *, axis_name: str, num_devices: int,
                 offset: int = 1) -> jax.Array:
    """Reference for rdma_put: result = tile received from my left
    ``offset``-neighbour == ppermute by +offset."""
    perm = [(i, (i + offset) % num_devices) for i in range(num_devices)]
    return jax.lax.ppermute(x, axis_name, perm)


def rdma_get_ref(x: jax.Array, *, axis_name: str, num_devices: int,
                 offset: int = 1) -> jax.Array:
    return rdma_put_ref(x, axis_name=axis_name, num_devices=num_devices,
                        offset=-offset)


def ring_all_gather_ref(x: jax.Array, *, axis_name: str,
                        num_devices: int) -> jax.Array:
    return jax.lax.all_gather(x, axis_name, tiled=True)


def ring_reduce_scatter_ref(x: jax.Array, *, axis_name: str,
                            num_devices: int) -> jax.Array:
    return jax.lax.psum_scatter(x, axis_name, tiled=True)
