"""Jitted top-level wrappers for the Pallas comm kernels.

Each wrapper closes over a mesh + axis name, shard_maps the SPMD kernel
over it, and jits the result.  ``impl`` selects the Pallas kernel
(``'pallas'``, interpret-mode on CPU / compiled on TPU) or the pure-JAX
oracle (``'ref'``).
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from . import ref as _ref
from .rdma import rdma_get, rdma_put
from .ring_allgather import ring_all_gather
from .ring_reduce_scatter import ring_reduce_scatter

Impl = Literal["pallas", "ref"]


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def make_rdma_put(mesh: jax.sharding.Mesh, axis_name: str,
                  offset: int = 1, impl: Impl = "pallas"):
    n = mesh.shape[axis_name]

    def body(x):
        if impl == "ref":
            return _ref.rdma_put_ref(x, axis_name=axis_name,
                                     num_devices=n, offset=offset)
        return rdma_put(x, axis_name=axis_name, num_devices=n,
                        offset=offset, interpret=_interpret_default())

    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=P(axis_name, None),
        out_specs=P(axis_name, None), check_vma=False))


def make_ring_all_gather(mesh: jax.sharding.Mesh, axis_name: str,
                         impl: Impl = "pallas"):
    n = mesh.shape[axis_name]

    def body(x):
        if impl == "ref":
            return _ref.ring_all_gather_ref(x, axis_name=axis_name,
                                            num_devices=n)
        return ring_all_gather(x, axis_name=axis_name, num_devices=n,
                               interpret=_interpret_default())

    # input sharded over units; output replicated (every unit holds all)
    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=P(axis_name, None),
        out_specs=P(axis_name, None), check_vma=False))


def make_ring_reduce_scatter(mesh: jax.sharding.Mesh, axis_name: str,
                             impl: Impl = "pallas"):
    n = mesh.shape[axis_name]

    def body(x):
        if impl == "ref":
            return _ref.ring_reduce_scatter_ref(x, axis_name=axis_name,
                                                num_devices=n)
        return ring_reduce_scatter(x, axis_name=axis_name, num_devices=n,
                                   interpret=_interpret_default())

    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=P(axis_name, None),
        out_specs=P(axis_name, None), check_vma=False))
