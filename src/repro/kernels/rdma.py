"""Pallas TPU kernels for DART one-sided put (RDMA).

The paper's hot spot IS communication: DART put/get over MPI-3 RMA.
On TPU the native one-sided substrate is the inter-chip ICI DMA —
``pltpu.make_async_remote_copy`` is a true RDMA put with send/recv
semaphores, the literal analogue of ``MPI_Rput`` in a passive-target
epoch (send_sem ≙ local completion, recv_sem ≙ remote completion — the
two completion events of paper §III's blocking semantics).

Hardware adaptation note (DESIGN.md §2): TPU ICI RDMA is **put-only**;
there is no remote-read primitive.  DART's *get* therefore lowers to
the mirrored put under SPMD (the owner pushes to the reader) — same
data motion, opposite initiator.  This is a documented semantic
adaptation, not a degenerate port: Cray Gemini (the paper's fabric)
also implements get as a put-descriptor handshake at the NIC level.

Tiling: messages are blocked over rows with an explicit
``pl.BlockSpec`` so each grid step stages one ``(block_m, n)`` tile
through VMEM.  The MXU is not involved (pure data movement); the block
shape targets the DMA-efficient 128-lane layout: ``n`` should be a
multiple of 128 and ``block_m`` chosen so ``block_m * n * itemsize``
fits comfortably in VMEM (≤ ~4 MiB to leave room for double buffering
by the pipeline).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _put_block_kernel(x_ref, o_ref, send_sem, recv_sem, *,
                      axis_name: str, num_devices: int, offset: int):
    """Copy my VMEM tile into the peer ``(my_id + offset) % N``'s tile."""
    my_id = jax.lax.axis_index(axis_name)
    dst = jax.lax.rem(my_id + offset + num_devices, num_devices)
    copy = pltpu.make_async_remote_copy(
        src_ref=x_ref, dst_ref=o_ref,
        send_sem=send_sem, recv_sem=recv_sem,
        device_id=dst, device_id_type=pltpu.DeviceIdType.LOGICAL)
    copy.start()
    copy.wait()          # send complete locally AND my incoming tile landed


def rdma_put(x: jax.Array, *, axis_name: str, num_devices: int,
             offset: int = 1, block_m: int | None = None,
             interpret: bool = True) -> jax.Array:
    """One-sided put of ``x`` to the unit ``offset`` hops away (SPMD).

    Call inside ``shard_map``; every unit pushes its ``x`` to
    ``(my_id + offset) % N`` and the result is the tile received from
    ``(my_id - offset) % N``.  Rows are tiled through VMEM via
    ``BlockSpec``.
    """
    m, n = x.shape
    if block_m is None:
        # target ≤ 2 MiB per tile, multiple-of-8 rows (sublane packing)
        rows = max(1, min(m, (2 * 1024 * 1024) // max(1, n * x.dtype.itemsize)))
        block_m = max(1, min(m, (rows // 8) * 8 or rows))
    grid = (pl.cdiv(m, block_m),)
    spec = pl.BlockSpec((block_m, n), lambda i: (i, 0))
    kernel = functools.partial(_put_block_kernel, axis_name=axis_name,
                               num_devices=num_devices, offset=offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA],
        interpret=interpret,
    )(x)


def rdma_get(x: jax.Array, *, axis_name: str, num_devices: int,
             offset: int = 1, block_m: int | None = None,
             interpret: bool = True) -> jax.Array:
    """One-sided get from the unit ``offset`` hops away.

    TPU RDMA is put-only; under SPMD, "I get from my left neighbour" is
    exactly "everyone puts to their right neighbour" — the mirrored
    permutation (see module docstring).
    """
    return rdma_put(x, axis_name=axis_name, num_devices=num_devices,
                    offset=-offset, block_m=block_m, interpret=interpret)
