"""Ring all-gather built from one-sided puts (Pallas TPU kernel).

The DART-style construction of a collective from one-sided operations:
N-1 forwarding steps around the ring, each step one RDMA put of the
block received in the previous step to the right neighbour.  On real
hardware each hop is a neighbour-only ICI transfer (bandwidth-optimal:
moves (N-1)/N of the result per link); in interpret mode the DMAs are
emulated faithfully on CPU.

VMEM note: the output ref holds the full gathered array; per-step DMAs
address one block slot via a dynamic row slice, so resident traffic per
step is one block, independent of N.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ring_allgather_kernel(x_ref, o_ref, local_sem, send_sem, recv_sem, *,
                           axis_name: str, num_devices: int):
    my_id = jax.lax.axis_index(axis_name)
    chunk = x_ref.shape[0]
    right = jax.lax.rem(my_id + 1, num_devices)

    # 1. place my own block into my slot of the output
    local = pltpu.make_async_copy(
        x_ref, o_ref.at[pl.ds(my_id * chunk, chunk)], local_sem)
    local.start()
    local.wait()

    # 2. N-1 forwarding steps: push the block I most recently obtained
    #    to my right neighbour's matching slot.
    for step in range(num_devices - 1):
        slot = jax.lax.rem(my_id - step + num_devices, num_devices)
        src = o_ref.at[pl.ds(slot * chunk, chunk)]
        rdma = pltpu.make_async_remote_copy(
            src_ref=src, dst_ref=src,
            send_sem=send_sem, recv_sem=recv_sem,
            device_id=right, device_id_type=pltpu.DeviceIdType.LOGICAL)
        rdma.start()
        rdma.wait()      # my outgoing sent + my incoming (from left) landed


def ring_all_gather(x: jax.Array, *, axis_name: str, num_devices: int,
                    interpret: bool = True) -> jax.Array:
    """All-gather ``x`` (per-unit block) along the ring.  SPMD: call
    inside shard_map; returns the (num_devices*chunk, n) gathered array
    on every unit."""
    chunk, n = x.shape
    kernel = functools.partial(_ring_allgather_kernel, axis_name=axis_name,
                               num_devices=num_devices)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((num_devices * chunk, n), x.dtype),
        scratch_shapes=[pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA,
                        pltpu.SemaphoreType.DMA],
        interpret=interpret,
    )(x)
