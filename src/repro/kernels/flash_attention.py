"""Flash attention (Pallas TPU kernel).

The roofline table (EXPERIMENTS.md §Roofline) shows every full-attention
cell memory-bound, dominated by materialized (S, S) score tensors; the
pure-JAX blocked attention (models/layers.blocked_causal_gqa) is the
XLA-level fix, and this kernel is the TPU-native one: scores never
leave VMEM.

Tiling: grid = (num_q_blocks, num_kv_blocks); each step loads a
``(block_q, hd)`` query tile and ``(block_k, hd)`` K/V tiles into VMEM
via BlockSpec, runs one ``(block_q, block_k)`` MXU matmul, and
maintains the online-softmax running max / denominator / accumulator in
VMEM scratch across the kv-block dimension of the grid.  Causal tiles
above the diagonal are skipped with ``pl.when`` (half the FLOPs).

Block sizes should be multiples of 128 on the lane dim and chosen so
2·(block·hd) + block² tiles fit VMEM (≤ ~2 MiB per buffer at defaults).
Batch and heads are vmapped outside (they prepend grid dimensions).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  num_kv_blocks: int):
    qi = pl.program_id(0)
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (kj * block_k <= qi * block_q + block_q - 1) if causal else True

    @pl.when(run)
    def _body():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale     # (bq, bk)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = kj * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(cols <= rows, s, -1e30)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...]
                      / l_scr[...][:, None]).astype(o_ref.dtype)


def flash_attention_single(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """One (seq, head_dim) attention problem.  q: (S,hd), k/v: (T,hd)."""
    s, hd = q.shape
    t = k.shape[0]
    bq, bk = min(block_q, s), min(block_k, t)
    assert s % bq == 0 and t % bk == 0, (s, t, bq, bk)
    nq, nk = s // bq, t // bk
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / np.sqrt(hd), causal=causal,
        block_q=bq, block_k=bk, num_kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(nq, nk),
        in_specs=[
            pl.BlockSpec((bq, hd), lambda qi, kj: (qi, 0)),
            pl.BlockSpec((bk, hd), lambda qi, kj: (kj, 0)),
            pl.BlockSpec((bk, hd), lambda qi, kj: (kj, 0)),
        ],
        out_specs=pl.BlockSpec((bq, hd), lambda qi, kj: (qi, 0)),
        out_shape=jax.ShapeDtypeStruct((s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running denominator
            pltpu.VMEM((bq, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """GQA flash attention.  q: (B,S,Hq,hd); k/v: (B,T,Hkv,hd)."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qr = q.transpose(0, 2, 1, 3).reshape(b * hkv, g, s, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, k.shape[1], hd)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, v.shape[1], hd)
    fn = functools.partial(flash_attention_single, causal=causal,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)
    out = jax.vmap(lambda qg, kk, vv: jax.vmap(
        lambda q1: fn(q1, kk, vv))(qg))(qr, kr, vr)     # (b*hkv, g, s, hd)
    return out.reshape(b, hkv * g, s, hd).transpose(0, 2, 1, 3)
