"""Shape-stable segmented copy: the DispatchPlan substrate for
``CommEngine.flush`` (and the host-plane collectives).

The paper's §V.C case for DART-MPI is that the runtime adds only a
*constant, small* per-call overhead over the raw substrate.  Our
substrate is XLA, where every distinct input *shape* costs a trace +
compile — so a flush path that specializes kernels on the exact
``(run length, payload size)`` pair pays compile + host-staging costs
on every new epoch shape instead of a constant dispatch overhead.
This module removes the shape dependence:

* **Bucketing** — run length ``k`` and the per-op segment size are
  rounded up to the next power of two (:func:`bucket_pow2`), and the
  run is padded with masked no-op descriptors (``len = 0``).  A small
  fixed family of compiled kernels therefore serves *all* epochs; a
  steady-state loop of varying-size epochs performs zero recompiles
  after warmup.
* **Packed descriptors** — ``rows/offs/lens/starts/strides/counts``
  travel as ONE ``(k, 6)`` int32 array (:func:`pack_descriptors`), and
  every payload byte travels as ONE flat uint8 buffer assembled
  host-side into a bucketed staging array: two host→device transfers
  per flush instead of 3–5 tiny ones plus a per-op eager
  ``jnp.concatenate`` chain.  A descriptor names a *strided run* —
  ``count`` segments of ``len`` bytes, ``stride`` bytes apart — so a
  matrix column or tile halo is ONE descriptor, not one per element;
  contiguous ops are the ``stride=0, count=1`` degenerate case.
* **Flat-index addressing** — kernels address the arena as a flat byte
  string: op *i* touches positions
  ``row*P + off + (lane//len)*stride + lane%len`` for
  ``lane < len*count`` (payloads stay dense in lane order); masked
  lanes are routed to distinct out-of-range indices and dropped
  (scatter, ``mode='drop'``) or filled with zeros (gather,
  ``mode='fill'``).  Because only valid lanes produce in-range
  indices, padding never clamps, smears across rows, or needs pool
  headroom — the bounds check at initiation is the only range
  requirement.  One formula serves contiguous and strided ops alike,
  so stride/count live in the traced descriptor *data*, never the plan
  key: a varying-stride loop performs zero recompiles.
* **Vectorized vs ordered** — runs whose byte ranges are provably
  disjoint (``_RunMeta`` tracks this while the run is grown) dispatch
  as ONE vectorized segmented update (``unique_indices`` scatter);
  only overlapping uniform runs keep the sequential ``fori_loop`` so
  last-writer-wins program order is preserved.
* **Reduction plane** — accumulate runs (``dart_accumulate`` /
  ``dart_get_accumulate``) ride the same substrate through segmented
  read-modify-write kernels (:func:`accumulate_plan`): descriptors
  gain an op column, every payload slot is pre-filled with the op's
  **identity element** (:func:`op_identity` — masked lanes are no-ops
  by value as well as by mask), and only the run's ``(k, seg)``
  windows are ever bitcast to the dtype, never the arena.  Disjoint
  runs vectorize; overlapping same-op runs keep the ordered RMW loop
  (one dispatch either way — the ops commute).
* **Plan cache** — compiled executables are cached process-wide by
  ``(kind, impl, arena shape, buckets, ...)``; the engine counts
  misses (``compile_count``) and hits (``plan_cache_hits``) so tests
  and ``BENCH_engine/v6`` can *assert* the steady state compiles
  nothing.

``impl='pallas'`` selects the hand-tiled Pallas kernel (grid over
descriptors, scalar-prefetched descriptor table; interpret-mode off
TPU), mirroring the ``impl`` switch in :mod:`repro.kernels.ops`.  The
Pallas path stages pad-to-bucket windows through VMEM and therefore
requires ``off + (count-1)*stride + sseg <= pool_bytes`` for every
descriptor (``sseg`` = the per-segment bucket of
:func:`strided_buckets`); :func:`pallas_ok` checks this host-side and
callers fall back to the XLA (``'ref'``) kernels when it fails, so
semantics never depend on the impl choice.  TPU grids execute
sequentially, so the one Pallas scatter kernel serves ordered runs
too; strided runs widen its grid to ``(k, cb)`` — one step per
(descriptor, segment).
"""

from __future__ import annotations

import functools
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# descriptor columns: desc[i] = (row, off, len, start, stride, count[, op])
# One descriptor now names a *strided run*: ``count`` segments of
# ``len`` bytes each, the j-th segment landing at ``off + j*stride``.
# A contiguous op is the degenerate case ``stride=0, count=1`` (so
# every pre-existing plan shape is unchanged); padding rows are
# all-zero (``count=0`` ⇒ zero valid lanes).  Accumulate descriptors
# carry a seventh column — the op code — so the packed table is
# self-describing (telemetry/debugging and the run split rule both
# read it); the combine function itself is static in the plan key,
# since XLA must trace it.
ROW, OFF, LEN, START, STRIDE, COUNT, OPCODE = 0, 1, 2, 3, 4, 5, 6
DESC_COLS = 6           # put/get descriptor width
ACC_DESC_COLS = 7       # accumulate descriptor width (adds OPCODE)

#: element-wise reduction ops of the reduction plane (dart_accumulate /
#: dart_allreduce): name → descriptor op code.
REDUCE_OPS = {"sum": 0, "prod": 1, "min": 2, "max": 3}

#: smallest segment bucket — tiny ops (1..16 B) share one compiled shape
SEG_FLOOR = 16
#: smallest run-length bucket — runs of 1..4 ops share one compiled
#: shape (a single blocking op and a short epoch hit the same plan)
K_FLOOR = 4
#: smallest flat-payload staging bucket
FLAT_FLOOR = 64


def bucket_pow2(n: int, floor: int = 1) -> int:
    """Next power of two >= max(n, floor) — the shape-stability rule."""
    n = max(int(n), floor, 1)
    return 1 << (n - 1).bit_length()


def pack_descriptors(rows: Sequence[int], offs: Sequence[int],
                     lens: Sequence[int],
                     payloads: Optional[Sequence[np.ndarray]] = None,
                     strides: Optional[Sequence[int]] = None,
                     counts: Optional[Sequence[int]] = None
                     ) -> Tuple[np.ndarray, Optional[np.ndarray], int]:
    """Host-side staging: k ops → one bucketed ``(k', 6)`` int32
    descriptor table (k' = pow2 bucket of k, padded with all-zero
    no-ops) and, for puts, one bucketed flat uint8 payload buffer.

    ``lens`` are **per-segment** bytes; op *i* moves
    ``lens[i] * counts[i]`` bytes in total (``counts`` defaults to all
    ones, ``strides`` to all zeros — the contiguous degenerate case,
    which packs byte-for-byte like the historical ``(k, 4)`` format).
    The segment-size bucket covers the *total* bytes of the largest
    op, so a strided run's dense payload/window footprint fits one
    descriptor row.  ``starts`` index into the flat buffer, where
    payloads pack densely (segment j of op i at
    ``start + j*len``); the buffer carries a trailing ``seg`` bytes of
    zero margin so a pad-to-bucket window read starting at any valid
    ``start`` stays in range (the Pallas path relies on this; the XLA
    path is range-safe regardless).  Returns ``(desc, flat, seg)``
    with ``flat is None`` for gathers.
    """
    k = len(rows)
    kb = bucket_pow2(k, K_FLOOR)
    lens = np.asarray(lens, np.int64)
    counts = (np.ones(k, np.int64) if counts is None
              else np.asarray(counts, np.int64))
    strides = (np.zeros(k, np.int64) if strides is None
               else np.asarray(strides, np.int64))
    totals = lens * counts
    seg = bucket_pow2(int(totals.max()) if k else 1, SEG_FLOOR)
    desc = np.zeros((kb, DESC_COLS), np.int32)
    desc[:k, ROW] = rows
    desc[:k, OFF] = offs
    desc[:k, LEN] = lens
    desc[:k, STRIDE] = strides
    desc[:k, COUNT] = counts
    starts = np.zeros(k, np.int64)
    np.cumsum(totals[:-1], out=starts[1:])
    desc[:k, START] = starts
    flat = None
    if payloads is not None:
        # sized by the BUCKETS, not the actual payload total, so the
        # flat staging shape is a pure function of (kb, seg) and warm
        # epochs with any payload mix inside the bucket reuse the plan
        flat = np.zeros(max(kb * seg + seg, FLAT_FLOOR), np.uint8)
        for s, p in zip(starts, payloads):
            flat[int(s):int(s) + p.size] = p
    return desc, flat, seg


def op_identity(op: str, dtype) -> np.ndarray:
    """The identity element of ``op`` over ``dtype`` — the value whose
    accumulation is a no-op (``x op identity == x``):

    ======  ==================  =====================
    op      floating            integral
    ======  ==================  =====================
    sum     ``0.0``             ``0``
    prod    ``1.0``             ``1``
    min     ``+inf``            ``iinfo(dtype).max``
    max     ``-inf``            ``iinfo(dtype).min``
    ======  ==================  =====================

    Padding lanes of accumulate payloads and masked element lanes of
    the bucketed allreduce carry this value, so pow2 bucketing never
    changes a reduction's result — masked lanes are no-ops *by value*
    as well as by index mask.
    """
    if op not in REDUCE_OPS:
        raise ValueError(f"unknown reduction op {op!r} "
                         f"(supported: {sorted(REDUCE_OPS)})")
    dt = jnp.dtype(dtype)
    floating = jnp.issubdtype(dt, jnp.floating)
    if op == "sum":
        v = 0
    elif op == "prod":
        v = 1
    elif op == "min":
        v = np.inf if floating else np.iinfo(dt).max
    else:                                        # max
        v = -np.inf if floating else np.iinfo(dt).min
    return np.asarray(v, dt)


def identity_bytes(op: str, dtype) -> np.ndarray:
    """``op``'s identity element as its little-endian byte pattern
    (``itemsize`` uint8 values) — the fill for accumulate payload
    staging buffers."""
    scalar = op_identity(op, dtype)
    return np.frombuffer(scalar.tobytes(), np.uint8).copy()


def pack_acc_descriptors(rows: Sequence[int], offs: Sequence[int],
                         lens: Sequence[int],
                         payloads: Sequence[np.ndarray],
                         op: str, dtype,
                         strides: Optional[Sequence[int]] = None,
                         counts: Optional[Sequence[int]] = None
                         ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Host-side staging for an accumulate run: k read-modify-write ops
    → one bucketed ``(k', 7)`` int32 descriptor table (columns
    ``row, off, len, start, stride, count, op``; ``lens`` per-segment,
    as in :func:`pack_descriptors`) plus one flat uint8 payload buffer.

    Unlike :func:`pack_descriptors` (whose payloads pack densely), each
    accumulate op owns a full seg-aligned slot (``start = i * seg``)
    **pre-filled with the op's identity element**
    (:func:`identity_bytes`): every padded lane — the tail of a short
    payload and all lanes of bucket-padding descriptors — decodes to
    the identity, so combining it is arithmetically a no-op even
    before the index mask drops it.  A strided op's payload packs
    densely *within* its slot (``len*count`` bytes, then identity
    fill).  The flat staging size is a pure function of the
    ``(k', seg)`` buckets, keeping warm epochs on the cached plan.
    """
    k = len(rows)
    kb = bucket_pow2(k, K_FLOOR)
    lens = np.asarray(lens, np.int64)
    counts = (np.ones(k, np.int64) if counts is None
              else np.asarray(counts, np.int64))
    strides = (np.zeros(k, np.int64) if strides is None
               else np.asarray(strides, np.int64))
    totals = lens * counts
    seg = bucket_pow2(int(totals.max()) if k else 1, SEG_FLOOR)
    desc = np.zeros((kb, ACC_DESC_COLS), np.int32)
    desc[:k, ROW] = rows
    desc[:k, OFF] = offs
    desc[:k, LEN] = lens
    desc[:k, STRIDE] = strides
    desc[:k, COUNT] = counts
    desc[:k, START] = np.arange(k, dtype=np.int64) * seg
    desc[k:, START] = np.arange(k, kb, dtype=np.int64) * seg
    desc[:, OPCODE] = REDUCE_OPS[op]
    # exactly kb*seg (>= FLAT_FLOOR: kb >= 4, seg >= 16): the kernels
    # reshape the flat buffer to (kb, seg) payload slots
    ident = identity_bytes(op, dtype)
    flat = np.tile(ident, kb * seg // ident.size)
    for i, p in enumerate(payloads):
        flat[i * seg:i * seg + p.size] = p
    return desc, flat, seg


def check_flat_addressable(arena_shape: Tuple[int, int]) -> None:
    """The segmented kernels address the arena as a flat int32 byte
    index (``row * pool_bytes + off + lane``; OOB markers sit just
    above ``rows * pool_bytes``).  Without x64, index arithmetic stays
    int32, so arenas at or beyond 2**30 total bytes would overflow
    *silently* (mode='drop' would discard the wrapped indices — lost
    puts, zero-filled gets).  Refuse loudly instead."""
    n_cells = int(arena_shape[0]) * int(arena_shape[1])
    if n_cells >= 1 << 30:
        raise NotImplementedError(
            f"arena of {n_cells} bytes exceeds the flat int32 "
            "addressing range of the segmented-copy kernels (see "
            "ROADMAP: int64-lane variant for >1 GiB heaps)")


def strided_buckets(desc: np.ndarray, seg: int) -> Tuple[int, int]:
    """``(sseg, cb)`` buckets for the 2-D Pallas grid: the per-segment
    window bytes (pow2 of the largest ``LEN``) and the segment-count
    grid extent (pow2 of the largest ``COUNT``).  For an all-contiguous
    run this is exactly ``(seg, 1)`` — every total IS its segment — so
    contiguous Pallas plans stay in their historical shape family."""
    lens = desc[:, LEN]
    counts = desc[:, COUNT]
    sseg = bucket_pow2(int(lens.max()) if lens.size else 1, SEG_FLOOR)
    cb = bucket_pow2(int(counts.max()) if counts.size else 1, 1)
    return min(sseg, seg), cb


def pallas_ok(desc: np.ndarray, seg: int, pool_bytes: int) -> bool:
    """True iff every descriptor's padded windows fit the pool — the
    precondition for the VMEM-windowed Pallas kernels.  A strided
    descriptor's last segment window starts at
    ``off + (count-1)*stride`` and spans ``sseg`` padded bytes."""
    sseg, _ = strided_buckets(desc, seg)
    last = desc[:, OFF] + np.maximum(desc[:, COUNT] - 1, 0) * desc[:, STRIDE]
    return bool(np.all(last + sseg <= pool_bytes))


# --------------------------------------------------------------------------
# XLA ('ref') kernels — flat-index scatter/gather, shapes fixed by buckets
# --------------------------------------------------------------------------


def _lane_mask(desc: jax.Array, seg: int) -> Tuple[jax.Array, jax.Array]:
    """(k, seg) lane grid + validity mask (``lane < len*count``) for a
    descriptor table; callers turn invalid lanes into out-of-range
    flat indices (dropped by scatters, zero-filled by gathers).  Lane
    space is *dense*: lane ``j*len + r`` is byte ``r`` of segment
    ``j`` — payloads and gather windows pack without gaps."""
    lane = jnp.arange(seg, dtype=jnp.int32)[None, :]
    valid = lane < (desc[:, LEN] * desc[:, COUNT])[:, None]
    return valid, lane


def _strided_dst(desc: jax.Array, lane: jax.Array, P) -> jax.Array:
    """Flat arena byte index per dense lane:
    ``row*P + off + (lane // len)*stride + lane % len``.  The
    contiguous degenerate case (``stride=0, count=1``) reduces to the
    historical ``row*P + off + lane`` for every valid lane — ONE
    formula serves both, so varying stride/count mixes never leave the
    plan's shape family.  ``len`` is clamped to 1 so padding rows
    divide safely; their (garbage) indices are masked off by callers
    before use."""
    safe_len = jnp.maximum(desc[:, LEN], 1)[:, None]
    return (desc[:, ROW][:, None] * P + desc[:, OFF][:, None]
            + (lane // safe_len) * desc[:, STRIDE][:, None]
            + lane % safe_len)


def _ref_scatter_vec(arena: jax.Array, desc: jax.Array, flat: jax.Array,
                     *, seg: int) -> jax.Array:
    """Disjoint segmented put as ONE vectorized update: every valid lane
    lands via a unique-index scatter, masked lanes are dropped."""
    R, P = arena.shape
    n_cells = R * P
    valid, lane = _lane_mask(desc, seg)
    k = desc.shape[0]
    dst = _strided_dst(desc, lane, P)
    oob = n_cells + jnp.arange(k * seg, dtype=jnp.int32).reshape(k, seg)
    dst = jnp.where(valid, dst, oob)
    src_idx = jnp.where(valid, desc[:, START][:, None] + lane,
                        flat.shape[0])
    src = jnp.take(flat, src_idx, mode="fill", fill_value=0)
    out = arena.reshape(-1).at[dst.reshape(-1)].set(
        src.reshape(-1), mode="drop", unique_indices=True)
    return out.reshape(R, P)


def _ref_scatter_ordered(arena: jax.Array, desc: jax.Array,
                         flat: jax.Array, *, seg: int) -> jax.Array:
    """Overlap-tolerant segmented put: descriptors apply strictly in
    queue order (``fori_loop``), preserving last-writer-wins."""
    R, P = arena.shape
    n_cells = R * P
    lane = jnp.arange(seg, dtype=jnp.int32)

    def body(i, a):
        safe_len = jnp.maximum(desc[i, LEN], 1)
        valid = lane < desc[i, LEN] * desc[i, COUNT]
        dst = (desc[i, ROW] * P + desc[i, OFF]
               + (lane // safe_len) * desc[i, STRIDE] + lane % safe_len)
        dst = jnp.where(valid, dst, n_cells + lane)
        src = jnp.take(flat, jnp.where(valid, desc[i, START] + lane,
                                       flat.shape[0]),
                       mode="fill", fill_value=0)
        return a.at[dst].set(src, mode="drop", unique_indices=True)

    return jax.lax.fori_loop(0, desc.shape[0], body,
                             arena.reshape(-1)).reshape(R, P)


def _ref_gather(arena: jax.Array, desc: jax.Array, *, seg: int
                ) -> jax.Array:
    """Segmented get: (k, seg) pad-to-bucket byte windows in one
    dispatch; masked lanes read as zero."""
    R, P = arena.shape
    valid, lane = _lane_mask(desc, seg)
    idx = jnp.where(valid, _strided_dst(desc, lane, P), R * P)
    return jnp.take(arena.reshape(-1), idx, mode="fill", fill_value=0)


#: elementwise combine (window ⊕ payload) per reduction op, shared by
#: the ref and Pallas RMW kernels.
_ELT_COMBINE = {"sum": jnp.add, "prod": jnp.multiply, "min": jnp.minimum,
                "max": jnp.maximum}


def _bytes_as(raw: jax.Array, dt) -> jax.Array:
    """Reinterpret a flat uint8 buffer as typed elements (the
    ``from_bytes`` bitcast, kept local so the kernel layer has no
    dependency on ``repro.core``)."""
    dt = jnp.dtype(dt)
    if dt == jnp.uint8:
        return raw
    n = raw.size // dt.itemsize
    return jax.lax.bitcast_convert_type(raw.reshape(n, dt.itemsize), dt)


def _typed_as_bytes(typed: jax.Array) -> jax.Array:
    if typed.dtype == jnp.uint8:
        return typed.reshape(-1)
    return jax.lax.bitcast_convert_type(typed.reshape(-1),
                                        jnp.uint8).reshape(-1)


def _ref_accumulate_vec(arena: jax.Array, desc: jax.Array,
                        flat: jax.Array, *, seg: int, op: str, dt,
                        fetch: bool):
    """Byte-disjoint segmented read-modify-write in ONE vectorized
    dispatch: gather every op's current byte window, bitcast to the
    run's dtype, combine with the (identity-padded) payload slots,
    bitcast back, and scatter the combined bytes.  Only the ``(k,
    seg)`` windows are ever bitcast — never the arena — so the cost
    scales with the run, not the pool.  Masked lanes take the familiar
    route: distinct out-of-range destinations, dropped by the scatter;
    their payload decodes to the op identity anyway (no-op by value
    too).  With ``fetch`` the gathered pre-update windows — already in
    hand — are returned as well (``MPI_Get_accumulate``; the run
    builder keeps fetch runs byte-disjoint, so read-all-then-apply-all
    equals the sequential order)."""
    R, P = arena.shape
    dt = jnp.dtype(dt)
    n_cells = R * P
    valid, lane = _lane_mask(desc, seg)
    k = desc.shape[0]
    dst = _strided_dst(desc, lane, P)
    oob = n_cells + jnp.arange(k * seg, dtype=jnp.int32).reshape(k, seg)
    dst = jnp.where(valid, dst, oob)
    old = jnp.take(arena.reshape(-1), dst, mode="fill",
                   fill_value=0)                       # (k, seg) bytes
    old_t = _bytes_as(old.reshape(-1), dt).reshape(k, seg // dt.itemsize)
    pay_t = _bytes_as(flat, dt).reshape(k, seg // dt.itemsize)
    comb = _ELT_COMBINE[op](old_t, pay_t)
    comb_b = _typed_as_bytes(comb).reshape(k, seg)
    out = arena.reshape(-1).at[dst.reshape(-1)].set(
        comb_b.reshape(-1), mode="drop",
        unique_indices=True).reshape(R, P)
    return (out, old) if fetch else out


def _ref_accumulate_ordered(arena: jax.Array, desc: jax.Array,
                            flat: jax.Array, *, seg: int, op: str, dt):
    """Overlap-tolerant accumulate: descriptors read-modify-write
    strictly in queue order (``fori_loop``), one window at a time —
    the RMW analogue of :func:`_ref_scatter_ordered`.  (Commutative
    ops make any order correct; sequential keeps it bitwise equal to
    the blocking reference even for non-associative float rounding.)"""
    R, P = arena.shape
    dt = jnp.dtype(dt)
    n_cells = R * P
    eseg = seg // dt.itemsize
    lane = jnp.arange(seg, dtype=jnp.int32)

    def body(i, a):
        safe_len = jnp.maximum(desc[i, LEN], 1)
        valid = lane < desc[i, LEN] * desc[i, COUNT]
        idx = (desc[i, ROW] * P + desc[i, OFF]
               + (lane // safe_len) * desc[i, STRIDE] + lane % safe_len)
        idx = jnp.where(valid, idx, n_cells + lane)
        old_b = jnp.take(a, jnp.where(valid, idx, n_cells),
                         mode="fill", fill_value=0)
        old_t = _bytes_as(old_b, dt).reshape(eseg)
        pay_t = _bytes_as(flat[desc[i, START] + lane], dt).reshape(eseg)
        comb_b = _typed_as_bytes(_ELT_COMBINE[op](old_t, pay_t))
        return a.at[idx].set(comb_b, mode="drop", unique_indices=True)

    return jax.lax.fori_loop(0, desc.shape[0], body,
                             arena.reshape(-1)).reshape(R, P)


# --------------------------------------------------------------------------
# Pallas kernels — grid over descriptors, scalar-prefetched table
# --------------------------------------------------------------------------


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pallas_scatter_kernel(desc_ref, flat_ref, arena_ref, o_ref, *,
                           sseg: int):
    """Grid step (i, c): segment ``c`` of descriptor ``i``.  Inactive
    steps (``c >= count`` or a padding row) clamp their window to
    ``(0, 0)`` and their flat read to ``0``, mask every lane, and
    write the window back unchanged — safe because the TPU grid is
    sequential, so the read observes all prior writes."""
    i = pl.program_id(0)
    c = pl.program_id(1)
    ln = desc_ref[i, LEN]
    cnt = desc_ref[i, COUNT]
    active = (c < cnt) & (ln > 0)
    row = jnp.where(active, desc_ref[i, ROW], 0)
    off = jnp.where(active, desc_ref[i, OFF] + c * desc_ref[i, STRIDE], 0)
    st = jnp.where(active, desc_ref[i, START] + c * ln, 0)
    seg_bytes = flat_ref[pl.ds(st, sseg)]
    window = o_ref[pl.ds(row, 1), pl.ds(off, sseg)]
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, sseg), 1)
    mask = active & (lane < ln)
    o_ref[pl.ds(row, 1), pl.ds(off, sseg)] = jnp.where(
        mask, seg_bytes[None, :], window)


def _pallas_gather_kernel(desc_ref, arena_ref, o_ref, *, sseg: int):
    """Grid step (i, c): read segment ``c`` of descriptor ``i`` from
    the arena and pack it densely at ``c*len`` of output row ``i``
    (zero-initialised on the row's first step)."""
    i = pl.program_id(0)
    c = pl.program_id(1)
    ln = desc_ref[i, LEN]
    cnt = desc_ref[i, COUNT]
    active = (c < cnt) & (ln > 0)
    row = jnp.where(active, desc_ref[i, ROW], 0)
    off = jnp.where(active, desc_ref[i, OFF] + c * desc_ref[i, STRIDE], 0)
    wr = jnp.where(active, c * ln, 0)

    @pl.when(c == 0)
    def _zero_row():
        o_ref[...] = jnp.zeros_like(o_ref)

    window = arena_ref[pl.ds(row, 1), pl.ds(off, sseg)]
    cur = o_ref[pl.ds(0, 1), pl.ds(wr, sseg)]
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, sseg), 1)
    mask = active & (lane < ln)
    o_ref[pl.ds(0, 1), pl.ds(wr, sseg)] = jnp.where(mask, window, cur)


def _pallas_acc_kernel(desc_ref, flat_ref, arena_ref, o_ref, *,
                       seg: int, op: str, dt):
    """Per-descriptor read-modify-write: load the byte window, bitcast
    to the run's dtype, combine with the (identity-padded) payload
    slot, bitcast back, and store the masked result.  The grid is
    sequential, so overlapping descriptors apply strictly in order —
    RMW-safe by construction."""
    i = pl.program_id(0)
    row = desc_ref[i, ROW]
    off = desc_ref[i, OFF]
    ln = desc_ref[i, LEN]
    st = desc_ref[i, START]
    window = o_ref[pl.ds(row, 1), pl.ds(off, seg)]      # (1, seg) uint8
    pay = flat_ref[pl.ds(st, seg)]                      # (seg,)
    dt = jnp.dtype(dt)
    isz = dt.itemsize
    if isz == 1:
        wt, pt = window.reshape(seg), pay
    else:
        wt = jax.lax.bitcast_convert_type(
            window.reshape(seg // isz, isz), dt)
        pt = jax.lax.bitcast_convert_type(pay.reshape(seg // isz, isz),
                                          dt)
    comb = _ELT_COMBINE[op](wt, pt)
    if isz == 1:
        cb = comb.reshape(1, seg)
    else:
        cb = jax.lax.bitcast_convert_type(comb, jnp.uint8).reshape(1, seg)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, seg), 1)
    o_ref[pl.ds(row, 1), pl.ds(off, seg)] = jnp.where(lane < ln, cb,
                                                      window)


def _pallas_accumulate(arena: jax.Array, desc: jax.Array,
                       flat: jax.Array, *, seg: int, op: str, dt
                       ) -> jax.Array:
    k = desc.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[pl.BlockSpec(flat.shape, lambda i, *_: (0,)),
                  pl.BlockSpec(arena.shape, lambda i, *_: (0, 0))],
        out_specs=pl.BlockSpec(arena.shape, lambda i, *_: (0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_pallas_acc_kernel, seg=seg, op=op, dt=dt),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={2: 0},       # arena (arg after desc, flat)
        interpret=_interpret_default(),
    )(desc, flat, arena)


def _pallas_scatter(arena: jax.Array, desc: jax.Array, flat: jax.Array,
                    *, seg: int, sseg: int, cb: int) -> jax.Array:
    """Segmented scatter over a 2-D ``(descriptor, segment)`` grid.
    The grid is sequential on TPU (and in interpret mode), so this
    kernel is valid for ordered (overlapping) runs as well as disjoint
    ones.  A contiguous run has ``cb == 1, sseg == seg`` — exactly the
    historical one-step-per-descriptor shape."""
    k = desc.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k, cb),
        in_specs=[pl.BlockSpec(flat.shape, lambda i, c, *_: (0,)),
                  pl.BlockSpec(arena.shape, lambda i, c, *_: (0, 0))],
        out_specs=pl.BlockSpec(arena.shape, lambda i, c, *_: (0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_pallas_scatter_kernel, sseg=sseg),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={2: 0},       # arena (arg after desc, flat)
        interpret=_interpret_default(),
    )(desc, flat, arena)


def _pallas_gather(arena: jax.Array, desc: jax.Array, *, seg: int,
                   sseg: int, cb: int) -> jax.Array:
    """Segmented gather over a 2-D ``(descriptor, segment)`` grid.
    Output rows are ``seg`` wide for contiguous runs (``cb == 1`` —
    byte-identical to the historical layout) and ``seg + sseg`` wide
    otherwise: the last dense segment write (at ``(count-1)*len``) may
    overrun ``seg`` by up to ``sseg - len`` padded bytes, and the host
    decode only reads the first ``nbytes`` of each row anyway."""
    k = desc.shape[0]
    seg_out = seg if cb == 1 else seg + sseg
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k, cb),
        in_specs=[pl.BlockSpec(arena.shape, lambda i, c, *_: (0, 0))],
        out_specs=pl.BlockSpec((1, seg_out), lambda i, c, *_: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_pallas_gather_kernel, sseg=sseg),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k, seg_out), jnp.uint8),
        interpret=_interpret_default(),
    )(desc, arena)


# --------------------------------------------------------------------------
# The plan cache
# --------------------------------------------------------------------------

_PLAN_CACHE: Dict[Tuple, Callable] = {}
_BUILD_COUNT = [0]      # process-total plan builds (≈ XLA compiles)
# flushes may now run concurrently (submitter threads + the background
# ProgressPlane), so the cache is guarded: one builder per key, and the
# hit/build counters stay exact.  build() only wraps a jax.jit (cheap;
# the XLA compile happens lazily on first call), so holding the lock
# across it is fine.
_PLAN_LOCK = threading.Lock()


def cached_plan(key: Tuple, build: Callable[[], Callable]
                ) -> Tuple[Callable, bool]:
    """Process-wide executable cache (the DispatchPlan layer): returns
    ``(fn, hit)``.  A miss runs ``build()`` — which creates a fresh
    ``jax.jit`` wrapper, so exactly one XLA trace+compile follows on
    first call — and records it; hits are the steady state."""
    with _PLAN_LOCK:
        fn = _PLAN_CACHE.get(key)
        if fn is not None:
            return fn, True
        fn = build()
        _PLAN_CACHE[key] = fn
        _BUILD_COUNT[0] += 1
        return fn, False


def clear_plan_cache() -> None:
    """Drop every cached executable (benchmarks use this to measure a
    true cold flush: rebuilt plans re-trace and re-compile)."""
    with _PLAN_LOCK:
        _PLAN_CACHE.clear()


def plan_cache_stats() -> Dict[str, int]:
    with _PLAN_LOCK:
        return {"size": len(_PLAN_CACHE), "builds": _BUILD_COUNT[0]}


def scatter_plan(arena_shape: Tuple[int, int], kb: int, seg: int,
                 flat_len: int, *, ordered: bool, impl: str = "ref",
                 donate: bool = True, sseg: Optional[int] = None,
                 cb: Optional[int] = None) -> Tuple[Callable, bool]:
    """fn(arena, desc, flat) -> arena'. ``ordered`` keeps the
    sequential loop (overlapping uniform runs); otherwise the
    vectorized unique-index scatter runs.  The Pallas impl is
    inherently ordered (sequential grid) so one kernel serves both.

    ``(sseg, cb)`` are the :func:`strided_buckets` of the run —
    **Pallas-only** grid parameters, defaulting to the contiguous
    family ``(seg, 1)``.  The ref kernels read stride/count from the
    descriptor table itself (ONE traced formula), so ref callers pass
    ``None`` and a varying-stride loop never leaves the cached plan.
    """
    check_flat_addressable(arena_shape)
    sseg = seg if sseg is None else sseg
    cb = 1 if cb is None else cb
    key = ("scatter", impl, arena_shape, kb, seg, flat_len, ordered,
           donate, sseg, cb)

    def build():
        if impl == "pallas":
            fn = functools.partial(_pallas_scatter, seg=seg, sseg=sseg,
                                   cb=cb)
        else:
            fn = functools.partial(
                _ref_scatter_ordered if ordered else _ref_scatter_vec,
                seg=seg)
        return jax.jit(fn, donate_argnums=(0,) if donate else ())

    return cached_plan(key, build)


def accumulate_plan(arena_shape: Tuple[int, int], kb: int, seg: int,
                    flat_len: int, *, op: str, dtype, fetch: bool,
                    ordered: bool = False, impl: str = "ref",
                    donate: bool = True) -> Tuple[Callable, bool]:
    """fn(arena, desc, flat) -> arena'  (or ``(arena', old_windows)``
    with ``fetch`` — the ``MPI_Get_accumulate`` form, old values as
    ``(kb, seg)`` pad-to-bucket uint8 windows read before any of the
    run applies).

    The combine op and dtype are static in the key (XLA traces the
    combine); the descriptor's op column keeps the packed table
    self-describing.  Only the run's ``(k, seg)`` windows are bitcast
    to the dtype — never the arena — so a dispatch costs O(run), not
    O(pool).  Mirroring :func:`scatter_plan`: byte-disjoint runs take
    the vectorized gather-combine-scatter; overlapping runs
    (``ordered``) keep the sequential per-descriptor RMW loop — still
    ONE dispatch, and bitwise equal to the blocking order.  The Pallas
    kernel is a sequential descriptor grid, valid for both.  Fetch
    runs always take the vectorized ref path (the run builder keeps
    them byte-disjoint, so read-all-then-apply-all is
    order-equivalent and the gathered old windows come for free).

    Strided accumulate runs ride the REF kernels only (the engine's
    impl picker routes any run containing ``count > 1`` to ref): the
    Pallas RMW kernel's identity-padded slot layout is pinned to the
    exact ``kb*seg`` flat buffer, which leaves no room for a padded
    per-segment window scheme."""
    check_flat_addressable(arena_shape)
    dt = jnp.dtype(dtype)
    if op not in REDUCE_OPS:
        raise ValueError(f"unknown reduction op {op!r}")
    if seg % dt.itemsize or arena_shape[1] % dt.itemsize:
        raise ValueError(
            f"accumulate of {dt} needs element-aligned segment/pool "
            f"bytes (seg={seg}, pool_bytes={arena_shape[1]})")
    if fetch:
        impl = "ref"        # fused fetch rides the vectorized ref path
    key = ("accumulate", impl, arena_shape, kb, seg, flat_len, op,
           str(dt), fetch, ordered, donate)

    def build():
        if impl == "pallas":
            fn = functools.partial(_pallas_accumulate, seg=seg, op=op,
                                   dt=dt)
        elif ordered and not fetch:
            fn = functools.partial(_ref_accumulate_ordered, seg=seg,
                                   op=op, dt=dt)
        else:
            fn = functools.partial(_ref_accumulate_vec, seg=seg, op=op,
                                   dt=dt, fetch=fetch)
        return jax.jit(fn, donate_argnums=(0,) if donate else ())

    return cached_plan(key, build)


def gather_plan(arena_shape: Tuple[int, int], kb: int, seg: int, *,
                impl: str = "ref", sseg: Optional[int] = None,
                cb: Optional[int] = None) -> Tuple[Callable, bool]:
    """fn(arena, desc) -> (kb, >=seg) uint8 pad-to-bucket windows; each
    op's bytes pack densely from column 0 of its row (decode reads the
    first ``nbytes``).  ``(sseg, cb)`` as in :func:`scatter_plan`:
    Pallas-only, ``None`` (→ ``(seg, 1)``) for the ref impl and for
    contiguous Pallas runs, whose rows stay exactly ``seg`` wide."""
    check_flat_addressable(arena_shape)
    sseg = seg if sseg is None else sseg
    cb = 1 if cb is None else cb
    key = ("gather", impl, arena_shape, kb, seg, sseg, cb)

    def build():
        if impl == "pallas":
            return jax.jit(functools.partial(_pallas_gather, seg=seg,
                                             sseg=sseg, cb=cb))
        return jax.jit(functools.partial(_ref_gather, seg=seg))

    return cached_plan(key, build)
