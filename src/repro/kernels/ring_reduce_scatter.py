"""Ring reduce-scatter built from one-sided puts (Pallas TPU kernel).

Same DART-style construction as the all-gather: N-1 steps; at each step
every unit pushes its running partial to the right neighbour, receives
the partial for the next slot from the left, and folds in its own local
block.  After N-1 steps unit *i* holds the fully reduced chunk *i*.

Slot schedule (derived in ops docstring): with ``acc`` initialized to
local block ``(my+N-1) % N``, after step *s* the received partial is
for slot ``(my+N-2-s) % N``; the final slot is ``my``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ring_reduce_scatter_kernel(x_ref, o_ref, acc_ref, rbuf_ref,
                                send_sem, recv_sem, *,
                                axis_name: str, num_devices: int):
    my_id = jax.lax.axis_index(axis_name)
    chunk = o_ref.shape[0]
    right = jax.lax.rem(my_id + 1, num_devices)

    first = jax.lax.rem(my_id + num_devices - 1, num_devices)
    acc_ref[...] = x_ref[pl.ds(first * chunk, chunk)]

    for step in range(num_devices - 1):
        rdma = pltpu.make_async_remote_copy(
            src_ref=acc_ref, dst_ref=rbuf_ref,
            send_sem=send_sem, recv_sem=recv_sem,
            device_id=right, device_id_type=pltpu.DeviceIdType.LOGICAL)
        rdma.start()
        rdma.wait()
        slot = jax.lax.rem(my_id + num_devices - 2 - step + num_devices,
                           num_devices)
        acc_ref[...] = rbuf_ref[...] + x_ref[pl.ds(slot * chunk, chunk)]

    o_ref[...] = acc_ref[...]


def ring_reduce_scatter(x: jax.Array, *, axis_name: str, num_devices: int,
                        interpret: bool = True) -> jax.Array:
    """Reduce-scatter along the ring.  SPMD: call inside shard_map with
    per-unit input of shape (num_devices*chunk, n); returns this unit's
    reduced (chunk, n) block."""
    total_m, n = x.shape
    if total_m % num_devices:
        raise ValueError("leading dim must divide num_devices")
    chunk = total_m // num_devices
    kernel = functools.partial(_ring_reduce_scatter_kernel,
                               axis_name=axis_name,
                               num_devices=num_devices)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((chunk, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((chunk, n), x.dtype),   # acc
            pltpu.VMEM((chunk, n), x.dtype),   # receive buffer
            pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(x)
