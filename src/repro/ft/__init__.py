from .elastic import (ClusterState, ElasticPlan, HeartbeatMonitor,
                      StragglerTracker, plan_remesh)
