"""Fault tolerance: heartbeats, elastic remesh planning, stragglers.

The control-plane loop for 1000+-node runs:

  1. :class:`HeartbeatMonitor` — hosts report liveness; a host missing
     ``miss_threshold`` consecutive beats is declared dead.
  2. :func:`plan_remesh` — given the surviving hosts, compute the
     largest production-shaped mesh (keeping the model axis intact,
     shrinking data/pod), the checkpoint step to restore, and the new
     DART team layout.  Restore re-shards via the layout-independent
     checkpoint format (checkpoint/manager.py).
  3. :class:`StragglerTracker` — per-host step-time EWMAs; hosts slower
     than ``ratio`` × median are flagged; the mitigation hook either
     reassigns their data shards (micro-batch rebalancing) or proposes
     eviction, which feeds back into (2).

All decisions are host-side metadata, so this module is exact on CPU —
the same code drives the real cluster, with heartbeats carried by the
DART non-collective heap (each host puts its beat counter into its
WORLD-window slot; the coordinator gets them one-sidedly — classic PGAS
monitoring, zero participation from workers).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple


def units_of_host(host: int, devices_per_host: int) -> Tuple[int, ...]:
    """DART units living on ``host``: units are the flattened device
    space, ``devices_per_host`` contiguous units per host — the mapping
    :meth:`DartContext.sweep_failures` uses to turn a dead host into
    engine unit deaths."""
    base = host * devices_per_host
    return tuple(range(base, base + devices_per_host))


@dataclasses.dataclass
class ClusterState:
    n_hosts: int
    devices_per_host: int
    alive: Dict[int, bool] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        for h in range(self.n_hosts):
            self.alive.setdefault(h, True)

    @property
    def alive_hosts(self) -> List[int]:
        return [h for h, ok in sorted(self.alive.items()) if ok]


class HeartbeatMonitor:
    """Declares hosts dead after ``miss_threshold`` missed beats."""

    def __init__(self, cluster: ClusterState, interval_s: float = 10.0,
                 miss_threshold: int = 3, clock=time.monotonic):
        self.cluster = cluster
        self.interval_s = interval_s
        self.miss_threshold = miss_threshold
        self._clock = clock
        now = clock()
        self._last_beat: Dict[int, float] = {
            h: now for h in range(cluster.n_hosts)}

    def beat(self, host: int):
        self._last_beat[host] = self._clock()

    def sweep(self) -> List[int]:
        """Returns hosts newly declared dead."""
        now = self._clock()
        newly_dead = []
        for h, ok in self.cluster.alive.items():
            if not ok:
                continue
            missed = (now - self._last_beat[h]) / self.interval_s
            if missed >= self.miss_threshold:
                self.cluster.alive[h] = False
                newly_dead.append(h)
        return newly_dead


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    participating_hosts: Tuple[int, ...]
    dropped_devices: int
    restore_step: Optional[int]
    note: str


def plan_remesh(cluster: ClusterState, *, model_parallel: int = 16,
                pods: int = 1, restore_step: Optional[int] = None
                ) -> ElasticPlan:
    """Largest (pod, data, model) mesh on the surviving hosts.

    The model axis is load-bearing (weights are sharded over it), so it
    is held fixed; the data axis shrinks to the largest multiple the
    surviving device count supports.  TPU reality note: losing a host
    inside a pod slice usually costs the slice's torus links — this
    planner models the scheduler-level re-slice decision.
    """
    alive = cluster.alive_hosts
    total = len(alive) * cluster.devices_per_host
    per_pod = total // max(pods, 1)
    data = per_pod // model_parallel
    if data < 1:
        raise RuntimeError(
            f"not enough devices to keep model_parallel={model_parallel}: "
            f"{total} left")
    used = pods * data * model_parallel
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    if pods > 1:
        shape, axes = (pods, data, model_parallel), ("pod", "data", "model")
    else:
        shape, axes = (data, model_parallel), ("data", "model")
    hosts_needed = used // cluster.devices_per_host
    return ElasticPlan(
        mesh_shape=shape, mesh_axes=axes,
        participating_hosts=tuple(alive[:hosts_needed]),
        dropped_devices=total - used,
        restore_step=restore_step,
        note=(f"kept model={model_parallel}, data {data}; "
              f"{total - used} devices idle"),
    )


class StragglerTracker:
    """Per-host EWMA step times; flags and mitigates stragglers."""

    def __init__(self, n_hosts: int, alpha: float = 0.2,
                 ratio: float = 1.5):
        self.alpha = alpha
        self.ratio = ratio
        self.ewma: Dict[int, Optional[float]] = {h: None
                                                 for h in range(n_hosts)}

    def record(self, host: int, step_time_s: float):
        prev = self.ewma[host]
        self.ewma[host] = (step_time_s if prev is None
                           else self.alpha * step_time_s
                           + (1 - self.alpha) * prev)

    def median(self) -> Optional[float]:
        vals = sorted(v for v in self.ewma.values() if v is not None)
        if not vals:
            return None
        return vals[len(vals) // 2]

    def stragglers(self) -> List[int]:
        med = self.median()
        if med is None:
            return []
        return [h for h, v in self.ewma.items()
                if v is not None and v > self.ratio * med]

    def rebalance_plan(self, local_batches: Dict[int, int]
                       ) -> Dict[int, int]:
        """Shift one micro-batch from each straggler to the fastest
        hosts (keeps the global batch constant)."""
        plan = dict(local_batches)
        slow = self.stragglers()
        if not slow:
            return plan
        fast = sorted((h for h, v in self.ewma.items()
                       if v is not None and h not in slow),
                      key=lambda h: self.ewma[h])
        for i, s in enumerate(slow):
            if plan.get(s, 0) > 1 and fast:
                dst = fast[i % len(fast)]
                plan[s] -= 1
                plan[dst] = plan.get(dst, 0) + 1
        return plan
