"""Token data pipeline: deterministic, checkpointable, shardable.

Two sources behind one interface:

* :class:`SyntheticLM` — seeded Zipf-ish token stream (benchmarks,
  smoke tests, dry-runs; no external data gate).
* :class:`MemmapTokens` — flat binary token file (np.memmap), the
  standard "packed tokens" format.

:class:`ShardedLoader` slices each global batch by data-parallel rank
(host), prefetches on a background thread, and exposes an exact cursor
(``state_dict``/``load_state_dict``) so checkpoint/restart resumes the
stream without duplication or loss — the data-side half of
fault-tolerant training.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"         # 'synthetic' | 'memmap'
    path: Optional[str] = None        # for memmap
    dp_rank: int = 0
    dp_size: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.dp_size == 0
        return self.global_batch // self.dp_size


class SyntheticLM:
    """Deterministic synthetic token stream (Zipf-like marginals)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        probs = 1.0 / np.arange(1, cfg.vocab + 1) ** 1.1
        self._probs = probs / probs.sum()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        # counter-based RNG: batch content is a pure function of
        # (seed, step, rank) -> restart-safe and dp-disjoint
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed, counter=[step, cfg.dp_rank, 0, 0]))
        tok = rng.choice(cfg.vocab, size=(cfg.local_batch, cfg.seq_len + 1),
                         p=self._probs).astype(np.int32)
        return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}


class MemmapTokens:
    """Packed-token binary file, strided disjointly by (step, rank)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.path, "memmap source needs DataConfig.path"
        self._data = np.memmap(cfg.path, dtype=np.int32, mode="r")
        self._n_tokens = self._data.shape[0]
        need = (cfg.seq_len + 1) * cfg.global_batch
        if self._n_tokens < need:
            raise ValueError(f"dataset too small: {self._n_tokens} tokens "
                             f"< one global batch ({need})")

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        span = cfg.seq_len + 1
        per_step = cfg.global_batch * span
        start = (step * per_step) % max(self._n_tokens - per_step, 1)
        rank_off = cfg.dp_rank * cfg.local_batch * span
        flat = np.asarray(self._data[start + rank_off:
                                     start + rank_off
                                     + cfg.local_batch * span])
        tok = flat.reshape(cfg.local_batch, span)
        return {"tokens": tok[:, :-1].astype(np.int32),
                "labels": tok[:, 1:].astype(np.int32)}


def make_dataset(cfg: DataConfig):
    if cfg.source == "synthetic":
        return SyntheticLM(cfg)
    if cfg.source == "memmap":
        return MemmapTokens(cfg)
    raise ValueError(cfg.source)


class ShardedLoader:
    """Background-prefetching loader with an exact resume cursor."""

    def __init__(self, dataset, start_step: int = 0, prefetch: int = 2):
        self.dataset = dataset
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._next_to_produce = start_step
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            batch = self.dataset.batch_at(self._next_to_produce)
            self._q.put((self._next_to_produce, batch))
            self._next_to_produce += 1

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.step = step + 1          # cursor = next step to consume
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    # -- checkpointable cursor ------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    @classmethod
    def resume(cls, dataset, state: Dict[str, int], prefetch: int = 2):
        return cls(dataset, start_step=int(state["step"]),
                   prefetch=prefetch)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
