from .pipeline import (DataConfig, SyntheticLM, MemmapTokens,
                       make_dataset, ShardedLoader)
