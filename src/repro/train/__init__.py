from .step import (TrainState, loss_fn, make_train_step, train_step,
                   abstract_train_state, train_state_logical)
