"""Training step: loss, grads, AdamW, metrics.

``make_train_step(cfg, opt_cfg)`` returns a pure function
``(state, batch) -> (state, metrics)`` suitable for ``jax.jit`` with
donated state.  Sharding is injected from outside via in/out_shardings
and the activation constraints the model emits inside a
``sharding_context``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import api
from ..models.config import ModelConfig
from ..optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                           abstract_opt_state, opt_logical_axes)

TrainState = Dict[str, Any]     # {'params':…, 'opt':…}


def loss_fn(cfg: ModelConfig, params, batch):
    if cfg.bf16_params_compute:
        # mixed precision: master weights stay f32 in the optimizer; the
        # forward consumes a bf16 cast, so FSDP weight all-gathers move
        # half the bytes (the cast happens before the gather — XLA sinks
        # the convert to the sharded side).
        params = jax.tree.map(
            lambda p: p.astype(cfg.cdtype)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
    logits, aux = api.forward_train(cfg, params, batch)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = nll.size
    loss = nll.sum() / denom
    # z-loss keeps the softmax normalizer bounded (stability at scale)
    zloss = 1e-4 * jnp.mean(jnp.square(
        jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)))
    return loss + aux + zloss, {"loss": loss, "aux": aux}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    def train_step(state: TrainState, batch
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        (total, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(state["params"])
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, state["opt"], state["params"])
        metrics = {"total_loss": total, **parts, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, state, batch):
    return make_train_step(cfg, opt_cfg)(state, batch)


def init_train_state(cfg: ModelConfig, rng) -> TrainState:
    params = api.init_params(cfg, rng)
    return {"params": params, "opt": adamw_init(params)}


def abstract_train_state(cfg: ModelConfig) -> TrainState:
    ap = api.abstract_params(cfg)
    return {"params": ap, "opt": abstract_opt_state(ap)}


def train_state_logical(cfg: ModelConfig):
    pl = api.logical_axes(cfg)
    return {"params": pl, "opt": opt_logical_axes(pl)}
