"""Gradient compression for the DCN (pod) axis with error feedback.

At 1000+ nodes the inter-pod all-reduce rides DCN, which is an order of
magnitude slower than ICI; int8 quantization cuts those bytes 4x vs
fp32 (2x vs bf16).  Error feedback (Karimireddy et al. 2019) keeps the
quantization bias from accumulating: the residual of each compression
is added back before the next one.

``compressed_allreduce_ref`` is the reference composition used by
train_step when ``compress_dcn=True``: quantize → psum over 'pod' →
dequantize, with the error-feedback state threaded functionally.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


class ErrorFeedback:
    """Functional error-feedback helpers (state = residual tree)."""

    @staticmethod
    def init(tree):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)

    @staticmethod
    def apply(grads, residual):
        return jax.tree.map(
            lambda g, r: g.astype(jnp.float32) + r, grads, residual)

    @staticmethod
    def update(corrected, compressed_roundtrip):
        return jax.tree.map(lambda c, d: c - d, corrected,
                            compressed_roundtrip)


def compressed_allreduce_ref(g: jax.Array, axis: Optional[str],
                             residual: Optional[jax.Array] = None
                             ) -> Tuple[jax.Array, jax.Array]:
    """Quantized all-reduce over ``axis`` with error feedback.

    Inside shard_map/jit: int8-quantize the (error-corrected) gradient,
    sum the int32-widened payload over the axis, dequantize with the
    max-scale.  Returns (reduced, new_residual).
    """
    g32 = g.astype(jnp.float32)
    if residual is not None:
        g32 = g32 + residual
    if axis is None:
        q, scale = compress_int8(g32)
        roundtrip = decompress_int8(q, scale)
        return roundtrip, g32 - roundtrip
    # agree on one scale (cheap scalar pmax) so the int8 sum dequantizes
    # exactly: sum_i q_i * s == (sum_i q_i) * s
    amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_residual = g32 - q.astype(jnp.float32) * scale
    summed = jax.lax.psum(q.astype(jnp.int32), axis)
    return summed.astype(jnp.float32) * scale, new_residual
