"""AdamW with ZeRO-sharded state.

The optimizer state tree mirrors the parameter tree, so the ZeRO
sharding falls out of the same logical-axis rules: ``opt_logical_axes``
reuses the params' logical tree for mu/nu (+ fp32 master copy when
params are low-precision).  On the production mesh with FSDP rules this
is ZeRO-3: weights, grads and optimizer state all sharded over
data×model.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params):
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(f32, abstract_params),
        "nu": jax.tree.map(f32, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_logical_axes(param_logical):
    return {
        "mu": param_logical,
        "nu": param_logical,
        "step": (),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step (fp32 math).  Returns (params', opt', metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * jnp.square(g32)
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
