from .adamw import (AdamWConfig, adamw_init, adamw_update,
                    opt_logical_axes, abstract_opt_state)
from .compression import (compress_int8, decompress_int8,
                          compressed_allreduce_ref, ErrorFeedback)
