"""olmoe-1b-7b — MoE 64 experts top-8 [arXiv:2409.02060; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab=50304, rope_theta=10000.0,
    n_experts=64, top_k=8, expert_d_ff=1024, n_shared_experts=0,
)
