"""whisper-small — enc-dec, conv frontend stubbed
[arXiv:2212.04356; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    head_dim=64, d_ff=3072, vocab=51865,
    mlp_type="gelu", use_bias=True, norm_type="layernorm",
    tie_embeddings=True, n_audio_frames=1500,
)
