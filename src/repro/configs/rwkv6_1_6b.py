"""rwkv6-1.6b — Finch, data-dependent decay, attention-free
[arXiv:2404.05892; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=7168, vocab=65536, norm_type="layernorm",
    rwkv_head_dim=64, rwkv_lora_dim=64,
)
