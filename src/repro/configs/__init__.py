"""Architecture registry: the 10 assigned configs + shape cells.

Every (arch × shape) pair defines one dry-run cell (40 total).
``long_500k`` requires sub-quadratic sequence mixing and is therefore
only applicable to the SSM/hybrid archs (DESIGN.md §4 records the
skips); the inapplicable cells are listed with ``applicable=False`` so
the dry-run report shows them as explicit skips, not omissions.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig, reduced_for_smoke

_MODULES = {
    "llama3-8b": "llama3_8b",
    "command-r-plus-104b": "command_r_plus_104b",
    "llama3-405b": "llama3_405b",
    "command-r-35b": "command_r_35b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a27b",
    "zamba2-1.2b": "zamba2_1_2b",
    "whisper-small": "whisper_small",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "qwen2-vl-2b": "qwen2_vl_2b",
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """(applicable?, reason-if-not)."""
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("full quadratic attention at 524288 context — "
                       "skipped per instructions (DESIGN.md §4)")
    return True, ""


def all_cells() -> List[Tuple[str, str, bool, str]]:
    """[(arch_id, shape_name, applicable, reason)] — the 40 cells."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES:
            ok, why = cell_applicable(cfg, s)
            out.append((a, s, ok, why))
    return out
