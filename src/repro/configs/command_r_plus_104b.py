"""command-r-plus-104b — dense GQA, no-bias, parallel block
[hf:CohereForAI/c4ai-command-r-plus; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab=256000, rope_theta=75_000_000.0,
    parallel_block=True, norm_type="layernorm",
)
