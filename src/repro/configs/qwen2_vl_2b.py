"""qwen2-vl-2b — M-RoPE, dynamic-resolution vision (frontend stubbed)
[arXiv:2409.12191; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151936, rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24), n_vision_patches=256,
)
