"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=151936, rope_theta=1_000_000.0,
    n_experts=60, top_k=4, expert_d_ff=1408, n_shared_experts=4,
)
