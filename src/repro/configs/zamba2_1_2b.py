"""zamba2-1.2b — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_conv=4, ssm_chunk=64,
    shared_attn_every=6,
)
