"""command-r-35b — dense GQA, no-bias, parallel block
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22528, vocab=256000, rope_theta=8_000_000.0,
    parallel_block=True, norm_type="layernorm",
)
