import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell, on the single-pod 16x16
mesh AND the 2-pod 2x16x16 mesh:

    lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(**specs)
    compiled = lowered.compile()
    memory_analysis / cost_analysis / collective parse  ->  JSON

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --all --out experiments/dryrun
    python -m repro.launch.dryrun --all --multi-pod

The two env lines above MUST stay the first statements: jax locks the
device count at first init, and only the dry-run wants 512 host
devices.
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, ShapeCell, all_cells, cell_applicable, get_config
from repro.launch import specs as S
from repro.launch.hlo_analysis import parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.sharding.rules import sharding_context
from repro.train.step import (abstract_train_state, make_train_step)

OUT_DEFAULT = pathlib.Path("experiments/dryrun")

#: baseline execution config for dry-run cells (the paper-faithful /
#: production-default starting point of §Perf): full per-layer remat for
#: training, none for serving; layer scans unrolled so cost_analysis
#: counts every layer (XLA counts while bodies once — see hlo_analysis).
BASELINE_TRAIN = dict(remat="full")
BASELINE_SERVE = dict(remat="none")


def _overrides_for(cell: ShapeCell, unroll_layers: bool,
                   overrides: Optional[Dict[str, Any]] = None):
    base = dict(BASELINE_TRAIN if cell.kind == "train" else BASELINE_SERVE)
    if unroll_layers:
        base["scan_unroll"] = 1_000_000     # clamped to n_layers in api
    if overrides:
        base.update(overrides)
    return base


def build_cell(arch: str, shape: str, multi_pod: bool,
               unroll_layers: bool = True,
               config_overrides: Optional[Dict[str, Any]] = None):
    """Returns (cfg, mesh, jitted-step, abstract-args tuple)."""
    cell = SHAPES[shape]
    cfg = get_config(arch)
    cfg = dataclasses.replace(
        cfg, **_overrides_for(cell, unroll_layers, config_overrides))
    mesh = make_production_mesh(multi_pod=multi_pod)

    if cell.kind == "train":
        rules = S.train_rules(mesh)
        state_sh = S.train_state_shardings(cfg, mesh, rules)
        batch, batch_pspecs = S.batch_specs(cfg, cell, mesh)
        batch_sh = S.spec_to_shardings(batch_pspecs, mesh)
        opt_cfg = AdamWConfig()
        inner = make_train_step(cfg, opt_cfg)

        def step(state, b):
            with sharding_context(mesh, rules):
                return inner(state, b)

        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        args = (abstract_train_state(cfg), batch)

    elif cell.kind == "prefill":
        rules = S.serve_rules(mesh, sp=bool(cfg.sp_serve),
                              dp_all=bool(cfg.dp_serve))
        param_sh = S.param_shardings(cfg, mesh, rules)
        batch, batch_pspecs = S.batch_specs(cfg, cell, mesh)
        batch_sh = S.spec_to_shardings(batch_pspecs, mesh)
        max_seq = cell.seq_len + (cfg.n_vision_patches
                                  if cfg.family == "vlm" else 0)
        cache_sh = S.cache_shardings(cfg, mesh, cell.global_batch, max_seq)

        def step(params, b):
            with sharding_context(mesh, rules):
                return api.forward_prefill(cfg, params, b, max_seq)

        jitted = jax.jit(step, in_shardings=(param_sh, batch_sh),
                         out_shardings=(None, cache_sh))
        args = (api.abstract_params(cfg), batch)

    else:  # decode
        rules = S.serve_rules(mesh, sp=bool(cfg.sp_serve),
                              dp_all=bool(cfg.dp_serve))
        param_sh = S.param_shardings(cfg, mesh, rules)
        b = cell.global_batch
        max_seq = cell.seq_len
        cache = api.abstract_cache(cfg, b, max_seq)
        cache_sh = S.cache_shardings(cfg, mesh, b, max_seq)
        tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        tok_sh = S.spec_to_shardings(
            {"t": S.batch_specs(cfg, cell, mesh)[1]["tokens"]}, mesh)["t"]

        def step(params, t, c):
            with sharding_context(mesh, rules):
                return api.forward_decode(cfg, params, t, c)

        jitted = jax.jit(step, in_shardings=(param_sh, tok_sh, cache_sh),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(2,))
        args = (api.abstract_params(cfg), tokens, cache)

    return cfg, mesh, jitted, args


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: pathlib.Path,
             unroll_layers: bool = True,
             config_overrides: Optional[Dict[str, Any]] = None,
             tag: str = "") -> Dict[str, Any]:
    cell = SHAPES[shape]
    cfg0 = get_config(arch)
    ok, why = cell_applicable(cfg0, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "kind": cell.kind, "applicable": ok, "tag": tag,
    }
    if not ok:
        rec["skip_reason"] = why
        _write(out_dir, rec, tag)
        print(f"[skip] {arch} x {shape} ({mesh_name}): {why}")
        return rec

    t0 = time.time()
    try:
        cfg, mesh, jitted, args = build_cell(
            arch, shape, multi_pod, unroll_layers, config_overrides)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        n_dev = int(np.prod(list(mesh.shape.values())))
        txt = compiled.as_text()
        coll = parse_collectives(txt, n_dev)

        rec.update({
            "ok": True,
            "n_devices": n_dev,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops_per_device": float(cost.get("flops", 0.0)),
            "bytes_accessed_per_device": float(
                cost.get("bytes accessed", 0.0)),
            "collective_link_bytes_per_device":
                coll.per_device_link_bytes,
            "collective_op_counts": coll.op_counts,
            "collective_op_bytes": coll.op_bytes,
            "memory_analysis": {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes",
                          "output_size_in_bytes",
                          "temp_size_in_bytes",
                          "generated_code_size_in_bytes")
                if hasattr(mem, k)
            },
            "param_count": api.param_count(cfg),
            "active_param_count": api.active_param_count(cfg),
            "hlo_bytes": len(txt),
        })
        print(f"[ok] {arch} x {shape} ({mesh_name}{'/' + tag if tag else ''}) "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s "
              f"flops/dev {rec['flops_per_device']:.3e} "
              f"coll B/dev {coll.per_device_link_bytes:.3e}")
    except Exception as e:  # noqa: BLE001 — record and continue
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        print(f"[FAIL] {arch} x {shape} ({mesh_name}): {e}")
    _write(out_dir, rec, tag)
    return rec


def _write(out_dir: pathlib.Path, rec: Dict[str, Any], tag: str = ""):
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json"
    (out_dir / name).write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=str(OUT_DEFAULT))
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep layer scans rolled (faster compile, "
                         "while-body costs counted once)")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides k=v (e.g. remat=dots)")
    ap.add_argument("--tag", default="", help="suffix for output files")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose JSON is already ok")
    ap.add_argument("--max-unroll-layers", type=int, default=80,
                    help="archs deeper than this compile rolled; their "
                         "exact costs come from repro.launch.ldiff")
    ap.add_argument("--rolled-archs", default="zamba2-1.2b",
                    help="comma-separated archs that always compile "
                         "rolled (nested-scan hybrids; costs via ldiff)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            pass
        overrides[k] = v

    out = pathlib.Path(args.out)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        todo = [(a, s) for a, s, _, _ in all_cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    n_fail = 0
    for mp in meshes:
        for a, s in todo:
            mesh_name = "2x16x16" if mp else "16x16"
            suffix = f"__{args.tag}" if args.tag else ""
            existing = out / f"{a}__{s}__{mesh_name}{suffix}.json"
            if args.skip_existing and existing.exists():
                old = json.loads(existing.read_text())
                if old.get("ok") or not old.get("applicable", True):
                    print(f"[cached] {a} x {s} ({mesh_name})")
                    continue
            unroll = (not args.no_unroll and
                      a not in args.rolled_archs.split(",") and
                      get_config(a).n_layers <= args.max_unroll_layers)
            rec = run_cell(a, s, mp, out, unroll_layers=unroll,
                           config_overrides=overrides or None,
                           tag=args.tag)
            if rec.get("applicable") and not rec.get("ok"):
                n_fail += 1
    print(f"dry-run complete; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
