"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --arch llama3-8b --smoke --steps 100 --batch 8 --seq 128

* builds the (possibly reduced) config and mesh,
* shards state via the logical-axis rules when >1 device is present,
* streams deterministic synthetic (or memmap) data,
* checkpoints asynchronously every ``--ckpt-every`` steps and resumes
  from the latest checkpoint (params, opt, data cursor) — kill it at
  any step and rerun: the loss curve continues exactly,
* tracks per-step wall time through the straggler tracker (host 0
  stands in for the fleet on a single-host run).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, ShardedLoader, make_dataset
from repro.ft import StragglerTracker
from repro.models.config import reduced_for_smoke
from repro.optim.adamw import AdamWConfig
from repro.sharding.rules import sharding_context
from repro.train.step import init_train_state, make_train_step
from repro.launch import specs as S
from repro.launch.mesh import make_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (for the ~100M example)")
    ap.add_argument("--n-layers", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced_for_smoke(cfg)
    over = {}
    if args.d_model:
        over.update(d_model=args.d_model,
                    head_dim=args.d_model // cfg.n_heads)
    if args.n_layers:
        over.update(n_layers=args.n_layers)
    if over:
        cfg = dataclasses.replace(cfg, **over)

    n_dev = jax.device_count()
    mesh = rules = None
    if n_dev > 1:
        model_par = max(d for d in (1, 2, 4, 8) if n_dev % d == 0)
        mesh = make_mesh((n_dev // model_par, model_par),
                         ("data", "model"))
        rules = S.train_rules(mesh)

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          total_steps=args.steps)
    inner = make_train_step(cfg, opt_cfg)

    def step_fn(state, batch):
        with sharding_context(mesh, rules):
            return inner(state, batch)

    if mesh is not None:
        state_sh = S.train_state_shardings(cfg, mesh, rules)
        jitted = jax.jit(step_fn, in_shardings=(state_sh, None),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0,))

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, source=args.data,
                      path=args.data_path)
    dataset = make_dataset(dcfg)

    mgr = None
    start_step, cursor = 0, 0
    state = None
    if args.ckpt_dir:
        mgr = CheckpointManager(CheckpointConfig(root=args.ckpt_dir))
        try:
            like = init_train_state(cfg, jax.random.PRNGKey(0))
            state, extra = mgr.restore_latest(like)
            start_step = int(extra["step"])
            cursor = int(extra["cursor"])
            print(f"resumed from step {start_step} (cursor {cursor})")
        except FileNotFoundError:
            state = None
    if state is None:
        state = init_train_state(cfg, jax.random.PRNGKey(0))

    loader = ShardedLoader(dataset, start_step=cursor)
    tracker = StragglerTracker(n_hosts=1)

    from repro.models import api as mapi
    print(f"training {cfg.arch_id} ({mapi.param_count(cfg)/1e6:.1f}M "
          f"params) on {n_dev} device(s), steps {start_step}..{args.steps}")

    losses = []
    for step in range(start_step, args.steps):
        batch_np = next(loader)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        if cfg.family == "encdec":
            batch["enc_frames"] = jnp.zeros(
                (args.batch, cfg.n_audio_frames, cfg.d_model), cfg.cdtype)
        if cfg.family == "vlm":
            pp = cfg.n_vision_patches
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, pp, cfg.d_model), cfg.cdtype)
            pos = jnp.broadcast_to(jnp.arange(pp + args.seq)[None],
                                   (args.batch, pp + args.seq))
            batch["position_ids"] = jnp.broadcast_to(
                pos[None], (3, args.batch, pp + args.seq))
        t0 = time.perf_counter()
        state, metrics = jitted(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        tracker.record(0, dt)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq / dt
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms "
                  f"({tok_s:.0f} tok/s)")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state,
                     extra={"step": step + 1,
                            "cursor": loader.state_dict()["step"]})
    if mgr:
        mgr.wait()
    loader.close()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
