"""Production meshes (single-pod 16x16 and 2-pod 2x16x16).

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]
              ) -> jax.sharding.Mesh:
    """Arbitrary mesh with the Auto axis type (test/bench helper)."""
    return compat.make_mesh(shape, axes)


def mesh_axis_names(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """The data-parallel axes present in this mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
