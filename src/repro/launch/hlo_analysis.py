"""Parse collective traffic out of compiled HLO text (§Roofline).

``collective_bytes`` is not in ``cost_analysis()``; we regex every
all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op out of ``compiled.as_text()`` and convert each to
*per-device link bytes* with the standard ring-algorithm formulas:

    all-gather          out_bytes * (g-1)/g
    reduce-scatter      in_bytes  * (g-1)/g      (== out*(g-1))
    all-reduce          2 * bytes * (g-1)/g      (RS+AG)
    all-to-all          bytes * (g-1)/g
    collective-permute  bytes                    (one hop)

with g = replica-group size parsed from the op.  Ops inside while-loop
bodies are counted once per iteration by multiplying with the loop trip
count, which XLA publishes in the while op's backend config or which we
extract from the loop-condition constant; the dry-run additionally
unrolls the layer scans (ModelConfig.scan_unroll) so the dominant
collectives are all top-level and exact.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"^[ \t]*(?:%|\w)?\S*[ \t]*=[ \t]*(?P<shape>\([^)]*\)|\S+?)[ \t]+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(",
    re.M)

_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")

_GROUPS_RE = re.compile(r"replica_groups=\{(?P<body>[^}]*(?:\}[^}]*)*?)\}\}")
_GROUPS_ALT_RE = re.compile(r"replica_groups=\[(?P<d0>\d+),(?P<d1>\d+)\]")

_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_ALT_RE.search(line)       # iota form [g, n/g]
    if m:
        return int(m.group("d1"))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group("body").split("}")[0]
        ids = [t for t in first.replace("{", "").split(",") if t.strip()]
        if ids:
            return len(ids)
    return n_devices


@dataclasses.dataclass
class CollectiveStats:
    per_device_link_bytes: float = 0.0
    op_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    op_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        eol = hlo_text.find("\n", m.start("shape"))
        line = hlo_text[m.start("shape"):eol if eol > 0 else None]
        if "-done(" in line:
            continue                       # started ops counted at -start
        shape_bytes = _shape_bytes(m.group("shape"))
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        frac = (g - 1) / g
        if op == "all-gather":
            cost = shape_bytes * frac          # shape is the gathered out
        elif op == "reduce-scatter":
            cost = shape_bytes * (g - 1)       # shape is the scattered out
        elif op == "all-reduce":
            cost = 2 * shape_bytes * frac
        elif op == "all-to-all":
            cost = shape_bytes * frac
        else:                                  # collective-permute
            cost = shape_bytes
        stats.per_device_link_bytes += cost
        stats.op_counts[op] = stats.op_counts.get(op, 0) + 1
        stats.op_bytes[op] = stats.op_bytes.get(op, 0.0) + cost
    return stats
