"""Layer-differencing cost extraction for cells too deep to unroll.

For very deep models (llama3-405b: 126 layers), unrolling the layer
scan for exact cost accounting is compile-prohibitive.  Instead we
lower the SAME cell (same shapes, same sharding) at two shallow depths
L1 < L2 with the scan still unrolled, extract

    per_layer = (cost(L2) − cost(L1)) / (L2 − L1)
    base      = cost(L1) − L1 · per_layer

and extrapolate ``cost(L) = base + L · per_layer``.  Valid because the
per-layer HLO is depth-independent (stacked params only change the
leading dim) and the non-layer work (embed, head, optimizer epilogue)
is exactly the L-independent ``base``.  The full-depth cell is still
compiled (rolled) to prove shardability and get memory analysis; only
the three roofline scalars come from the extrapolation.

    python -m repro.launch.ldiff --arch llama3-405b --shape train_4k
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import pathlib

from repro.launch.dryrun import OUT_DEFAULT, run_cell


def extrapolate(rec1, rec2, rec_full, l1: int, l2: int, l_full: int):
    out = dict(rec_full)
    for key in ("flops_per_device", "bytes_accessed_per_device",
                "collective_link_bytes_per_device"):
        per_layer = (rec2[key] - rec1[key]) / (l2 - l1)
        base = rec1[key] - l1 * per_layer
        out[key] = base + l_full * per_layer
    out["cost_method"] = f"ldiff({l1},{l2})->L={l_full}"
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--l1", type=int, default=6)
    ap.add_argument("--l2", type=int, default=12)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=str(OUT_DEFAULT))
    args = ap.parse_args()

    out = pathlib.Path(args.out)
    from repro.configs import get_config
    l_full = get_config(args.arch).n_layers

    r1 = run_cell(args.arch, args.shape, args.multi_pod, out,
                  unroll_layers=True,
                  config_overrides={"n_layers": args.l1},
                  tag=f"ldiff{args.l1}")
    r2 = run_cell(args.arch, args.shape, args.multi_pod, out,
                  unroll_layers=True,
                  config_overrides={"n_layers": args.l2},
                  tag=f"ldiff{args.l2}")
    rf = run_cell(args.arch, args.shape, args.multi_pod, out,
                  unroll_layers=False, tag="rolledfull")
    assert r1.get("ok") and r2.get("ok") and rf.get("ok"), "ldiff failed"
    rec = extrapolate(r1, r2, rf, args.l1, args.l2, l_full)
    rec["tag"] = ""
    mesh_name = rec["mesh"]
    (out / f"{args.arch}__{args.shape}__{mesh_name}.json").write_text(
        json.dumps(rec, indent=1))
    print(f"[ldiff] wrote extrapolated cell for {args.arch} x "
          f"{args.shape}: flops/dev {rec['flops_per_device']:.3e}")


if __name__ == "__main__":
    main()
