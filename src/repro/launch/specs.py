"""Input/state/cache ShapeDtypeStructs + shardings per (arch × shape).

``input_specs`` follows the assignment: ShapeDtypeStruct stand-ins for
every model input (weak-type-correct, shardable, no device
allocation).  Modality frontends are stubs — whisper gets precomputed
frame embeddings, qwen2-vl precomputed patch embeddings + M-RoPE ids.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import ShapeCell
from ..models import api
from ..models.config import ModelConfig
from ..optim.adamw import abstract_opt_state, opt_logical_axes
from ..sharding.rules import (AxisRules, DEFAULT_TRAIN_RULES, fsdp_rules,
                              logical_to_spec_sized, sized_spec_tree)
from .mesh import dp_axes

# ---------------------------------------------------------------------------
# rule tables per mode
# ---------------------------------------------------------------------------


def train_rules(mesh: Mesh, fsdp: bool = True) -> AxisRules:
    rules = dict(DEFAULT_TRAIN_RULES)
    rules["batch"] = dp_axes(mesh)
    if fsdp:
        rules = fsdp_rules(rules)
    return rules


def serve_rules(mesh: Mesh, sp: bool = False,
                dp_all: bool = False) -> AxisRules:
    """Inference: TP-only params (no FSDP all-gathers per step).

    sp=True: sequence-parallel serving — activations seq-sharded over
    'model', weights replicated.  The right regime when head counts
    don't divide the model axis (e.g. qwen2-vl's 12 heads on model=16
    force replicated-activation all-gathers under TP; §Perf).
    dp_all=True: decode batch sharded over data AND model (pure-DP
    decode; weights replicated)."""
    rules = dict(DEFAULT_TRAIN_RULES)
    rules["batch"] = dp_axes(mesh)
    rules["embed"] = None
    if sp:
        for k in ("vocab", "q_heads", "kv_heads", "mlp", "experts",
                  "act_heads"):
            rules[k] = None
        rules["seq"] = "model"
    if dp_all:
        for k in ("vocab", "q_heads", "kv_heads", "mlp", "experts",
                  "act_heads"):
            rules[k] = None
        rules["batch"] = tuple(dp_axes(mesh)) + ("model",)
    return rules


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------


def _tok(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def _dp_if_divisible(mesh: Mesh, b: int):
    axes = dp_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    return axes if axes and b % size == 0 else None


def batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (abstract batch tree, PartitionSpec tree)."""
    b, s = cell.global_batch, cell.seq_len
    dp = _dp_if_divisible(mesh, b)
    if cell.kind == "decode":
        inputs = {"tokens": _tok(b, 1)}
        specs = {"tokens": P(dp, None)}
        return inputs, specs

    # sequence-parallel serving: shard prompt seq dims over 'model'
    sp = bool(getattr(cfg, "sp_serve", 0)) and cell.kind == "prefill"
    m = mesh.shape.get("model", 1)
    seq_ax = "model" if sp and s % m == 0 else None

    inputs: Dict[str, Any] = {"tokens": _tok(b, s)}
    specs: Dict[str, Any] = {"tokens": P(dp, seq_ax)}
    if cell.kind == "train":
        inputs["labels"] = _tok(b, s)
        specs["labels"] = P(dp, None)
    if cfg.family == "encdec":
        inputs["enc_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.n_audio_frames, cfg.d_model), cfg.cdtype)
        specs["enc_frames"] = P(dp, None, None)
    if cfg.family == "vlm":
        pp = cfg.n_vision_patches
        inputs["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, pp, cfg.d_model), cfg.cdtype)
        specs["vision_embeds"] = P(
            dp, "model" if sp and pp % m == 0 else None, None)
        inputs["position_ids"] = jax.ShapeDtypeStruct(
            (3, b, pp + s), jnp.int32)
        specs["position_ids"] = P(
            None, dp, "model" if sp and (pp + s) % m == 0 else None)
    return inputs, specs


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, b: int) -> Dict[str, Any]:
    """PartitionSpec tree matching ``api.init_cache`` structure.

    Sharding strategy: batch over the DP axes when divisible; the
    head-like dim over 'model' when divisible, otherwise the sequence
    dim of KV buffers over 'model' (whisper's 12 KV heads / 500k
    single-batch cells)."""
    dp = _dp_if_divisible(mesh, b)
    m = mesh.shape.get("model", 1)

    def kv_spec(kv_heads: int, seq: int):
        if kv_heads % m == 0:
            return P(None, dp, None, "model", None)
        if seq % m == 0:
            return P(None, dp, "model", None, None)
        return P(None, dp, None, None, None)

    c: Dict[str, Any] = {"pos": P()}
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        c["k"] = kv_spec(cfg.n_kv_heads, 1)      # seq filled by caller
        c["v"] = c["k"]
        if cfg.family == "encdec":
            c["ck"] = kv_spec(cfg.n_kv_heads, cfg.n_audio_frames)
            c["cv"] = c["ck"]
    elif cfg.family == "hybrid":
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        c["h"] = P(None, dp, "model" if cfg.n_heads % m == 0 else None,
                   None, None)
        c["conv"] = P(None, dp, None,
                      "model" if conv_dim % m == 0 else None)
        c["shared_k"] = kv_spec(cfg.n_kv_heads, 1)
        c["shared_v"] = c["shared_k"]
    elif cfg.family == "ssm":
        hsh = "model" if cfg.rwkv_n_heads % m == 0 else None
        c["s"] = P(None, dp, hsh, None, None)
        dsh = "model" if cfg.d_model % m == 0 else None
        c["last_att"] = P(None, dp, dsh)
        c["last_ffn"] = P(None, dp, dsh)
    return c


def cache_shardings(cfg: ModelConfig, mesh: Mesh, b: int, max_seq: int):
    pspecs = cache_pspecs(cfg, mesh, b)
    # fix up kv seq-sharding choice now that max_seq is known
    m = mesh.shape.get("model", 1)
    if cfg.family in ("dense", "moe", "vlm", "encdec") \
            and cfg.n_kv_heads % m != 0 and max_seq % m == 0:
        dp = _dp_if_divisible(mesh, b)
        pspecs["k"] = P(None, dp, "model", None, None)
        pspecs["v"] = pspecs["k"]
    if cfg.family == "hybrid" and cfg.n_kv_heads % m != 0 \
            and max_seq % m == 0:
        dp = _dp_if_divisible(mesh, b)
        pspecs["shared_k"] = P(None, dp, "model", None, None)
        pspecs["shared_v"] = pspecs["shared_k"]
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# state shardings
# ---------------------------------------------------------------------------


def param_shardings(cfg: ModelConfig, mesh: Mesh, rules: AxisRules):
    return sized_spec_tree(api.logical_axes(cfg), api.abstract_params(cfg),
                           rules, mesh)


def train_state_shardings(cfg: ModelConfig, mesh: Mesh, rules: AxisRules):
    from ..train.step import abstract_train_state, train_state_logical
    return sized_spec_tree(train_state_logical(cfg), abstract_train_state(cfg),
                           rules, mesh)


def spec_to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
