from .step import make_decode_step, make_prefill_step
from .engine import ServeEngine, Request
