from .engine import ContinuousEngine, Request, ServeEngine
from .kv_blocks import BlockId, KVBlockPool, PoolExhausted, pool_bytes_needed
from .prefix_cache import (PrefixCacheService, PrefixHit, PrefixStats,
                           chain_keys, pack_kv_blocks, unpack_kv_blocks)
from .scheduler import ContinuousScheduler, SeqState
from .step import (init_batched_cache, make_batched_decode_step,
                   make_decode_step, make_prefill_step, make_slot_insert)
