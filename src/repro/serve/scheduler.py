"""Continuous-batching scheduler: fixed decode slots, per-step churn.

The synchronous wave loop packed a batch, decoded it to the wave's max
``max_new_tokens``, and only then looked at the queue again — arrivals
during a wave waited, and retired rows kept burning decode slots.  This
scheduler replaces the wave with *slots*:

* the engine owns ``max_batch`` decode slots of a fixed-shape batched
  cache (shape-stable: the decode step never retraces);
* ``admit`` binds a waiting request to a free slot (the engine prefills
  only that slot — resident slots keep decoding);
* ``note_token`` records one decoded token per resident slot per step
  and reports retirement: EOS (the early-exit path that the wave engine
  only had wave-globally) or the request's own ``max_new_tokens``;
* ``retire`` frees the slot immediately, so the next step can admit a
  waiting request into it without stalling the batch.

Pure bookkeeping — no JAX, no DART.  The engine drives it; the PGAS
planes (KV block pool, prefix-cache service) hang off the per-sequence
record via ``on_retire`` callbacks (releasing pinned cache blocks is
the canonical one).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional, Tuple


@dataclasses.dataclass
class SeqState:
    """One resident sequence: a request bound to a decode slot."""

    req: object                      # serve.engine.Request (duck-typed)
    slot: int
    pos: int = 0                     # decode position (cache pos)
    emitted: List[int] = dataclasses.field(default_factory=list)
    eos_seen: bool = False
    prefix_hit: bool = False
    # owner units of the prefix-cache blocks this sequence restored
    # from (empty for prefilled sequences) — the serve engine retires
    # residents whose owner set intersects a dead unit.
    block_owners: Tuple[int, ...] = ()
    on_retire: Optional[Callable[["SeqState"], None]] = None

    @property
    def done(self) -> bool:
        return (self.eos_seen
                or len(self.emitted) >= self.req.max_new_tokens)


class ContinuousScheduler:
    """Admit/evict bookkeeping over ``max_batch`` fixed decode slots."""

    def __init__(self, max_batch: int):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self.waiting: Deque[object] = deque()
        self.slots: List[Optional[SeqState]] = [None] * max_batch
        self._free: Deque[int] = deque(range(max_batch))
        # counters for the serving bench
        self.admitted = 0
        self.retired = 0

    # -- queue side ------------------------------------------------------
    def enqueue(self, req) -> None:
        """FIFO-append a request to the waiting line."""
        self.waiting.append(req)

    # -- introspection ---------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_waiting(self) -> int:
        return len(self.waiting)

    @property
    def residents(self) -> List[SeqState]:
        return [s for s in self.slots if s is not None]

    @property
    def n_resident(self) -> int:
        return self.max_batch - len(self._free)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.n_resident > 0

    # -- admit -----------------------------------------------------------
    def admit_next(self) -> Optional[SeqState]:
        """Bind the oldest waiting request to a free slot.

        Returns the new :class:`SeqState` (the engine prefills it), or
        ``None`` when there is nothing waiting or no slot is free —
        resident sequences are never preempted.
        """
        if not self.waiting or not self._free:
            return None
        req = self.waiting.popleft()
        slot = self._free.popleft()
        assert self.slots[slot] is None, f"slot {slot} double-assigned"
        seq = SeqState(req=req, slot=slot)
        self.slots[slot] = seq
        self.admitted += 1
        return seq

    # -- per-step accounting ---------------------------------------------
    def note_token(self, slot: int, token: int) -> bool:
        """Record one decoded token for the resident in ``slot``.

        Returns True when the sequence is finished — EOS emitted (the
        token is kept, matching the wave engine's inclusive truncation)
        or its own ``max_new_tokens`` reached — and should be retired.
        """
        seq = self.slots[slot]
        if seq is None:
            raise KeyError(f"slot {slot} has no resident sequence")
        if seq.done:
            raise RuntimeError(
                f"slot {slot} already finished; retire it first")
        seq.emitted.append(int(token))
        seq.pos += 1
        if (seq.req.eos_id is not None
                and int(token) == int(seq.req.eos_id)):
            seq.eos_seen = True
        return seq.done

    # -- retire ----------------------------------------------------------
    def retire(self, slot: int) -> SeqState:
        """Free ``slot`` and return its sequence (caller finalizes the
        request).  Runs the sequence's ``on_retire`` hook (block-cache
        release) before the slot becomes reusable."""
        seq = self.slots[slot]
        if seq is None:
            raise KeyError(f"slot {slot} has no resident sequence")
        if seq.on_retire is not None:
            seq.on_retire(seq)
        self.slots[slot] = None
        self._free.append(slot)
        self.retired += 1
        return seq

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"ContinuousScheduler(resident={self.n_resident}/"
                f"{self.max_batch}, waiting={self.n_waiting})")
