"""Serving engines over the DART PGAS runtime.

Two schedulers share one request surface (:class:`Request`):

* :class:`ServeEngine` — the synchronous *wave* baseline: the
  scheduler packs up to ``max_batch`` requests per wave, prefills them
  together, decodes until every wave member is finished (early-exit on
  all-EOS), and only then looks at the queue again.  Kept as the
  benchmark baseline the continuous engine is measured against.

* :class:`ContinuousEngine` — continuous batching over fixed decode
  slots: new requests are admitted into free slots *while resident
  sequences keep decoding* (per-slot cache positions via the vmapped
  decode step — serve/step.py), and retire on EOS or their own
  ``max_new_tokens`` without stalling the batch.  Its prefix/KV cache
  is a PGAS-native service: prefill KV state is published block-wise
  into a :class:`~repro.serve.kv_blocks.KVBlockPool` carved from the
  DART team window, and repeat prompts restore it with one-sided
  ``get_nb`` + per-target flush instead of recomputing
  (serve/prefix_cache.py; docs/API.md "Serving plane").

Shape stability: the continuous decode step is traced ONCE (fixed
``(max_batch, 1, 1)`` tokens + fixed batched cache), prefill lengths
bucket to pow2, and the engine counts bucket misses
(``prefill_shape_misses``) so the serving bench can pin zero
steady-state recompiles.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (DART_TEAM_ALL, DartConfig, DartContext, dart_init,
                    dart_team_memalloc_aligned)
from ..core.faults import DartError
from ..models import api
from ..models.config import ModelConfig
from .kv_blocks import KVBlockPool, pool_bytes_needed
from .prefix_cache import (PrefixCacheService, pack_kv_blocks,
                           unpack_kv_blocks)
from .scheduler import ContinuousScheduler, SeqState
from .step import (init_batched_cache, make_batched_decode_step,
                   make_decode_step, make_prefill_step, make_slot_insert)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # wall-clock budget from submit: a request older than this retires
    # with finish_reason "timeout" (and frees its slot) instead of
    # pinning a slot forever.  None = no deadline.
    deadline_s: Optional[float] = None
    # filled by the engine:
    output: Optional[np.ndarray] = None
    # "eos" | "length" | "timeout" | "unit_failed" (None until done)
    finish_reason: Optional[str] = None
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    # wall-clock marks for the serving bench (open-loop latency)
    t_submit: float = 0.0
    t_done: float = 0.0


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


class ServeEngine:
    """Synchronous-wave baseline scheduler (see module docstring)."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_seq: int = 256, pad_id: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.pad_id = pad_id
        self._q: "queue.Queue[Request]" = queue.Queue()
        self._prefill = jax.jit(make_prefill_step(cfg, max_seq))
        self._decode = jax.jit(make_decode_step(cfg))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # submit() is documented thread-safe: serving workers share the
        # engine, so the rid counter increments under a lock (an
        # unlocked `x += 1` loses ids under concurrent submitters).
        self._rid_lock = threading.Lock()
        self._next_rid = 0
        #: decode steps the most recent wave actually ran (early-exit
        #: makes this < the wave's max ``max_new_tokens`` when every
        #: member finished on EOS first)
        self.last_wave_steps = 0
        # PGAS bookkeeping: the cache segment for a full wave
        self.dart: DartContext = dart_init(
            n_units=max_batch,
            config=DartConfig(team_pool_bytes=1 << 20,
                              non_collective_pool_bytes=1 << 16))
        self.cache_gptr = dart_team_memalloc_aligned(
            self.dart, DART_TEAM_ALL, 1 << 18)
        # background progress plane: cache-segment puts queued by other
        # components (prefix-cache writers, migration jobs) drain while
        # the wave loop sits in jitted prefill/decode — the serving
        # loop never has to flush for traffic it didn't enqueue.
        self.dart.start_progress()

    # -- client API ------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> Request:
        with self._rid_lock:
            rid = self._next_rid
            self._next_rid += 1
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      t_submit=time.perf_counter())
        self._q.put(req)
        return req

    def run_forever(self):
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
        self.dart.stop_progress(drain=True)

    def drain(self) -> int:
        """Process queued requests on the caller thread until empty.
        Returns the number of completed requests.  (No ``_q.empty()``
        pre-check: the take itself is the emptiness test, so a request
        racing in between check and take can't be half-dropped.)"""
        done = 0
        while True:
            wave = self._take_wave()
            if not wave:
                return done
            self._run_wave(wave)
            done += len(wave)

    # -- engine internals --------------------------------------------------
    def _loop(self):
        while not self._stop.is_set():
            wave = self._take_wave(block=True)
            if wave:
                self._run_wave(wave)

    def _take_wave(self, block: bool = False) -> List[Request]:
        wave: List[Request] = []
        try:
            first = self._q.get(timeout=0.1 if block else 0.0)
            wave.append(first)
        except queue.Empty:
            return wave
        while len(wave) < self.max_batch:
            try:
                wave.append(self._q.get_nowait())
            except queue.Empty:
                break
        return wave

    def _run_wave(self, wave: List[Request]):
        cfg = self.cfg
        b = len(wave)
        plen = max(len(r.prompt) for r in wave)
        toks = np.full((b, plen), self.pad_id, np.int32)
        for i, r in enumerate(wave):
            toks[i, -len(r.prompt):] = r.prompt      # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "encdec":
            batch["enc_frames"] = jnp.zeros(
                (b, cfg.n_audio_frames, cfg.d_model), cfg.cdtype)
        if cfg.family == "vlm":
            pp = cfg.n_vision_patches
            batch["vision_embeds"] = jnp.zeros((b, pp, cfg.d_model),
                                               cfg.cdtype)
            pos = jnp.broadcast_to(jnp.arange(pp + plen)[None],
                                   (b, pp + plen))
            batch["position_ids"] = jnp.broadcast_to(pos[None],
                                                     (3, b, pp + plen))

        logits, cache = self._prefill(self.params, batch)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]

        # decode with early exit: stop as soon as every wave member is
        # finished — EOS emitted inside its own max_new_tokens window,
        # or its window exhausted — instead of always burning the
        # wave's max
        max_new = max(r.max_new_tokens for r in wave)
        outs = [nxt]
        eos_seen = [False] * b

        def _note_eos(step_count: int) -> None:
            host = np.asarray(outs[-1])[:, 0]
            for i, r in enumerate(wave):
                if (r.eos_id is not None and not eos_seen[i]
                        and step_count <= r.max_new_tokens
                        and int(host[i]) == int(r.eos_id)):
                    eos_seen[i] = True

        def _all_done(step_count: int) -> bool:
            return all(eos_seen[i] or step_count >= r.max_new_tokens
                       for i, r in enumerate(wave))

        steps = 1
        _note_eos(steps)
        while steps < max_new and not _all_done(steps):
            nxt, _, cache = self._decode(self.params, nxt, cache)
            outs.append(nxt)
            steps += 1
            _note_eos(steps)
        self.last_wave_steps = steps
        gen = np.asarray(jnp.concatenate(outs, axis=1))   # (b, steps)

        for i, r in enumerate(wave):
            o = gen[i, :r.max_new_tokens]
            if r.eos_id is not None:
                hits = np.nonzero(o == r.eos_id)[0]
                if hits.size:
                    o = o[:hits[0] + 1]
            r.output = o
            r.t_done = time.perf_counter()
            r.done.set()


class ContinuousEngine:
    """Continuous-batching engine with the PGAS prefix/KV cache.

    Per decode step: ingest arrivals, admit waiting requests into free
    slots (prefill or one-sided prefix-cache restore), run ONE fixed-
    shape vmapped decode step over all ``max_batch`` slots, retire
    finished sequences, repeat.  See the module docstring.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_seq: int = 256, pad_id: int = 0,
                 block_tokens: int = 8, n_units: int = 4,
                 n_cache_blocks: int = 64, prefix_cache: bool = True):
        if block_tokens & (block_tokens - 1):
            raise ValueError(f"block_tokens must be a power of two, "
                             f"got {block_tokens}")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.pad_id = pad_id
        self.block_tokens = block_tokens
        self._q: "queue.Queue[Request]" = queue.Queue()
        self._rid_lock = threading.Lock()
        self._next_rid = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.scheduler = ContinuousScheduler(max_batch)

        self._prefill = jax.jit(make_prefill_step(cfg, max_seq))
        self._decode = jax.jit(make_batched_decode_step(cfg))
        self._insert = jax.jit(make_slot_insert())
        self._caches = init_batched_cache(cfg, max_batch, max_seq)
        self._tokens = jnp.zeros((max_batch, 1, 1), jnp.int32)

        # shape-stability accounting (the serving bench pins zero
        # steady-state recompiles on these + the DART plan cache)
        self._prefill_shapes: set = set()
        self.prefill_shape_misses = 0
        self.decode_steps = 0
        self.prefills = 0
        # fault-plane accounting (docs/API.md "Failure model")
        self.timeouts = 0
        self.unit_failed_retired = 0
        self.degraded_fetches = 0

        # the PGAS serving plane: KV blocks + prefix directory live in
        # a DART team window sized for the pool
        self._cacheable = bool(prefix_cache) and cfg.family in (
            "dense", "moe")
        block_elems = (2 * cfg.n_layers * block_tokens
                       * cfg.n_kv_heads * cfg.head_dim)
        pool_bytes = (pool_bytes_needed(n_cache_blocks, block_elems,
                                        n_units, cfg.cdtype)
                      if self._cacheable else 1 << 16)
        self.dart: DartContext = dart_init(
            n_units=n_units,
            config=DartConfig(team_pool_bytes=pool_bytes,
                              non_collective_pool_bytes=1 << 16))
        if self._cacheable:
            self.kv_pool = KVBlockPool(
                self.dart, n_blocks=n_cache_blocks,
                block_elems=block_elems, dtype=cfg.cdtype)
            self.prefix = PrefixCacheService(
                self.dart, self.kv_pool, block_tokens=block_tokens)
        else:
            self.kv_pool = None
            self.prefix = None
        # queued block publishes drain in the background while the
        # engine sits in jitted prefill/decode
        self.dart.start_progress()

    # -- client API ------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None) -> Request:
        """Thread-safe enqueue.  Validates that the prompt's pow2
        prefill bucket plus the decode budget fits ``max_seq``.
        ``deadline_s`` bounds the request's wall clock from now: past
        it the sequence retires with finish_reason ``"timeout"`` and
        frees its slot (a stuck request can never pin a slot)."""
        prompt = np.asarray(prompt, np.int32)
        bucket = self._bucket(len(prompt))
        if bucket + max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt bucket {bucket} + max_new_tokens "
                f"{max_new_tokens} exceeds max_seq {self.max_seq}")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be positive, "
                             f"got {deadline_s}")
        with self._rid_lock:
            rid = self._next_rid
            self._next_rid += 1
        req = Request(rid=rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      deadline_s=deadline_s,
                      t_submit=time.perf_counter())
        self._q.put(req)
        return req

    def run_until_idle(self) -> int:
        """Serve on the caller thread until queue, waiting line, and
        slots are all empty.  Returns requests completed."""
        before = self.scheduler.retired
        while True:
            self._ingest()
            self._sweep_deadlines()
            self._admit_all()
            if self.scheduler.n_resident == 0:
                if self._q.empty() and not self.scheduler.waiting:
                    return self.scheduler.retired - before
                continue
            self._decode_once()

    def run_forever(self):
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=10)
        self.dart.stop_progress(drain=True)

    # -- stats -----------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        s: Dict[str, object] = {
            "decode_steps": self.decode_steps,
            "prefills": self.prefills,
            "prefill_shape_misses": self.prefill_shape_misses,
            "admitted": self.scheduler.admitted,
            "retired": self.scheduler.retired,
            "engine_dispatches": self.dart.engine.dispatch_count,
            "engine_plan_compiles": self.dart.engine.compile_count,
            "timeouts": self.timeouts,
            "unit_failed_retired": self.unit_failed_retired,
            "degraded_fetches": self.degraded_fetches,
        }
        if self.prefix is not None:
            s["prefix"] = self.prefix.stats.snapshot()
        return s

    # -- engine internals ------------------------------------------------
    def _loop(self):
        while not self._stop.is_set():
            self._ingest(block=True)
            self._sweep_deadlines()
            self._admit_all()
            if self.scheduler.n_resident:
                self._decode_once()

    def _ingest(self, block: bool = False) -> None:
        try:
            timeout = 0.05 if (block and not self.scheduler.has_work()) \
                else None
            if timeout is not None:
                self.scheduler.enqueue(self._q.get(timeout=timeout))
            else:
                self.scheduler.enqueue(self._q.get_nowait())
        except queue.Empty:
            return
        while True:
            try:
                self.scheduler.enqueue(self._q.get_nowait())
            except queue.Empty:
                return

    def _admit_all(self) -> None:
        while True:
            seq = self.scheduler.admit_next()
            if seq is None:
                return
            self._admit(seq)

    # -- fault plane / degradation ---------------------------------------
    def _expired(self, req, now: float) -> bool:
        return (req.deadline_s is not None
                and now - req.t_submit >= req.deadline_s)

    def _sweep_deadlines(self) -> None:
        """Retire residents past their wall-clock deadline (freeing
        their slots) and time out expired waiting requests before they
        ever take a slot."""
        now = time.perf_counter()
        for seq in self.scheduler.residents:
            if self._expired(seq.req, now):
                self._retire(seq.slot, reason="timeout")
        if any(self._expired(r, now) for r in self.scheduler.waiting):
            keep = []
            for req in self.scheduler.waiting:
                if self._expired(req, now):
                    self.timeouts += 1
                    self._finalize(req, np.zeros(0, np.int32), "timeout")
                else:
                    keep.append(req)
            self.scheduler.waiting = type(self.scheduler.waiting)(keep)

    def note_unit_death(self, unit: int) -> int:
        """Degrade around a dead PGAS unit: the DART engine fails the
        unit's lanes fast, the KV pool and prefix directory stop
        handing out its blocks, and residents whose restored prefix
        lives on it retire with finish_reason ``"unit_failed"`` (the
        client retries; everyone else keeps decoding).  Returns the
        number of residents retired."""
        self.dart.engine.mark_unit_dead(unit, reason="serve plane")
        if self.kv_pool is not None:
            self.kv_pool.note_unit_dead(unit)
        if self.prefix is not None:
            self.prefix.note_unit_dead(unit)
        retired = 0
        for seq in self.scheduler.residents:
            if unit in seq.block_owners:
                self._retire(seq.slot, reason="unit_failed")
                self.unit_failed_retired += 1
                retired += 1
        return retired

    def _bucket(self, plen: int) -> int:
        return max(self.block_tokens, _next_pow2(plen))

    def _padded_prompt(self, prompt: np.ndarray) -> np.ndarray:
        bucket = self._bucket(len(prompt))
        padded = np.full(bucket, self.pad_id, np.int32)
        padded[bucket - len(prompt):] = prompt       # left-pad
        return padded

    def _prefill_batch(self, padded: np.ndarray) -> Dict[str, jax.Array]:
        cfg = self.cfg
        batch = {"tokens": jnp.asarray(padded[None])}
        if cfg.family == "encdec":
            batch["enc_frames"] = jnp.zeros(
                (1, cfg.n_audio_frames, cfg.d_model), cfg.cdtype)
        if cfg.family == "vlm":
            pp = cfg.n_vision_patches
            plen = padded.size
            batch["vision_embeds"] = jnp.zeros((1, pp, cfg.d_model),
                                               cfg.cdtype)
            pos = jnp.broadcast_to(jnp.arange(pp + plen)[None],
                                   (1, pp + plen))
            batch["position_ids"] = jnp.broadcast_to(pos[None],
                                                     (3, 1, pp + plen))
        return batch

    def _admit(self, seq: SeqState) -> None:
        """Prefill-or-restore one admitted sequence into its slot."""
        cfg = self.cfg
        padded = self._padded_prompt(seq.req.prompt)
        bucket = padded.size

        hit = self.prefix.lookup(padded) if self.prefix else None
        if hit is not None:
            # one-sided restore: get_nb per block + per-target flush.
            # A fetch that trips over a dead owner (death raced the
            # pin) degrades to a recompute, never a crash.
            try:
                blocks = hit.fetch()
            except DartError:
                hit.release()
                self.degraded_fetches += 1
                hit = None
        if hit is not None:
            k, v = unpack_kv_blocks(
                blocks, n_layers=cfg.n_layers, kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim, block_tokens=self.block_tokens,
                max_seq=self.max_seq, dtype=cfg.cdtype)
            slot_cache = {"pos": jnp.int32(bucket),
                          "k": jnp.asarray(k), "v": jnp.asarray(v)}
            nxt = hit.next_token
            seq.prefix_hit = True
            seq.block_owners = tuple(sorted(
                {bid.unit for bid in hit.blocks}))
            seq.on_retire = lambda s, h=hit: h.release()
        else:
            key = (1, bucket)
            if key not in self._prefill_shapes:
                self._prefill_shapes.add(key)
                self.prefill_shape_misses += 1
            logits, slot_cache = self._prefill(
                self.params, self._prefill_batch(padded))
            nxt = int(jnp.argmax(logits[0, -1]))
            self.prefills += 1
            if self.prefix is not None:
                self.prefix.insert(
                    padded,
                    pack_kv_blocks(slot_cache, bucket, self.block_tokens),
                    nxt)

        self._caches = self._insert(self._caches, slot_cache,
                                    jnp.int32(seq.slot))
        self._tokens = self._tokens.at[seq.slot, 0, 0].set(int(nxt))
        seq.pos = bucket
        if self.scheduler.note_token(seq.slot, int(nxt)):
            self._retire(seq.slot)

    def _decode_once(self) -> None:
        self._tokens, self._caches = self._decode(
            self.params, self._tokens, self._caches)
        self.decode_steps += 1
        toks = np.asarray(self._tokens)[:, 0, 0]
        for seq in self.scheduler.residents:
            if self.scheduler.note_token(seq.slot, int(toks[seq.slot])):
                self._retire(seq.slot)

    def _retire(self, slot: int, reason: Optional[str] = None) -> None:
        seq = self.scheduler.retire(slot)    # runs on_retire (unpin)
        if reason is None:
            reason = "eos" if seq.eos_seen else "length"
        if reason == "timeout":
            self.timeouts += 1
        self._finalize(seq.req, np.asarray(seq.emitted, np.int32),
                       reason)

    def _finalize(self, req: Request, output: np.ndarray,
                  reason: str) -> None:
        req.output = output
        req.finish_reason = reason
        req.t_done = time.perf_counter()
        req.done.set()
