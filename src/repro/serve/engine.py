"""Batched serving engine over the DART PGAS runtime.

A production-shaped single-controller engine:

* requests arrive on a thread-safe queue (``submit``),
* the scheduler packs up to ``max_batch`` requests per wave,
* prefill builds the KV/state cache for the wave, decode steps run
  until every sequence hits its ``max_new_tokens`` or EOS,
* the KV cache is registered as a DART collective segment — a
  team-wide aligned allocation whose per-unit rows are the cache shards
  (the PGAS picture of disaggregated KV; DESIGN.md §4) — so other
  components (e.g. a prefix-cache service or a migration job) can
  address it with global pointers without engine participation.

The engine is deliberately synchronous per wave (no continuous
batching) — the PGAS integration, not the scheduler, is the paper's
story; continuous batching would slot into ``_run_wave``.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (DART_TEAM_ALL, DartConfig, DartContext, dart_init,
                    dart_team_memalloc_aligned)
from ..models import api
from ..models.config import ModelConfig
from .step import make_decode_step, make_prefill_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: Optional[np.ndarray] = None
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_seq: int = 256, pad_id: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.pad_id = pad_id
        self._q: "queue.Queue[Request]" = queue.Queue()
        self._prefill = jax.jit(make_prefill_step(cfg, max_seq))
        self._decode = jax.jit(make_decode_step(cfg))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._next_rid = 0
        # PGAS bookkeeping: the cache segment for a full wave
        self.dart: DartContext = dart_init(
            n_units=max_batch,
            config=DartConfig(team_pool_bytes=1 << 20,
                              non_collective_pool_bytes=1 << 16))
        self.cache_gptr = dart_team_memalloc_aligned(
            self.dart, DART_TEAM_ALL, 1 << 18)
        # background progress plane: cache-segment puts queued by other
        # components (prefix-cache writers, migration jobs) drain while
        # the wave loop sits in jitted prefill/decode — the serving
        # loop never has to flush for traffic it didn't enqueue.
        self.dart.start_progress()

    # -- client API ------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> Request:
        req = Request(rid=self._next_rid, prompt=np.asarray(prompt,
                                                            np.int32),
                      max_new_tokens=max_new_tokens, eos_id=eos_id)
        self._next_rid += 1
        self._q.put(req)
        return req

    def run_forever(self):
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)
        self.dart.stop_progress(drain=True)

    def drain(self) -> int:
        """Process queued requests on the caller thread until empty.
        Returns the number of completed requests."""
        done = 0
        while not self._q.empty():
            wave = self._take_wave()
            if not wave:
                break
            self._run_wave(wave)
            done += len(wave)
        return done

    # -- engine internals --------------------------------------------------
    def _loop(self):
        while not self._stop.is_set():
            wave = self._take_wave(block=True)
            if wave:
                self._run_wave(wave)

    def _take_wave(self, block: bool = False) -> List[Request]:
        wave: List[Request] = []
        try:
            first = self._q.get(timeout=0.1 if block else 0.0)
            wave.append(first)
        except queue.Empty:
            return wave
        while len(wave) < self.max_batch:
            try:
                wave.append(self._q.get_nowait())
            except queue.Empty:
                break
        return wave

    def _run_wave(self, wave: List[Request]):
        cfg = self.cfg
        b = len(wave)
        plen = max(len(r.prompt) for r in wave)
        toks = np.full((b, plen), self.pad_id, np.int32)
        for i, r in enumerate(wave):
            toks[i, -len(r.prompt):] = r.prompt      # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "encdec":
            batch["enc_frames"] = jnp.zeros(
                (b, cfg.n_audio_frames, cfg.d_model), cfg.cdtype)
        if cfg.family == "vlm":
            pp = cfg.n_vision_patches
            batch["vision_embeds"] = jnp.zeros((b, pp, cfg.d_model),
                                               cfg.cdtype)
            pos = jnp.broadcast_to(jnp.arange(pp + plen)[None],
                                   (b, pp + plen))
            batch["position_ids"] = jnp.broadcast_to(pos[None],
                                                     (3, b, pp + plen))

        logits, cache = self._prefill(self.params, batch)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]

        max_new = max(r.max_new_tokens for r in wave)
        outs = [nxt]
        for _ in range(max_new - 1):
            nxt, _, cache = self._decode(self.params, nxt, cache)
            outs.append(nxt)
        gen = np.asarray(jnp.concatenate(outs, axis=1))   # (b, max_new)

        for i, r in enumerate(wave):
            o = gen[i, :r.max_new_tokens]
            if r.eos_id is not None:
                hits = np.nonzero(o == r.eos_id)[0]
                if hits.size:
                    o = o[:hits[0] + 1]
            r.output = o
            r.done.set()
