"""Global prefix/KV cache service over the DART block pool.

The millions-of-users serving story (ROADMAP): popular prompts repeat,
so their prefill KV state is computed once, published into the PGAS
block pool, and every later request restores it with one-sided reads —
no recompute, no engine participation from the block owners.

Protocol (docs/API.md "Serving plane"):

* **keys** — prompts are chunked into ``block_tokens`` runs of the
  *bucket-padded* token ids; chunk i's key is the blake2b chain hash
  ``h_i = H(h_{i-1} || chunk_i)``.  The chain makes a block's key name
  its whole left context (same first chunk + same history ⇒ same K/V
  bytes, because prefill is deterministic), so blocks are shared
  between any prompts with a common padded prefix.
* **lookup** — a *full* hit (every chunk key present, terminal key has
  a recorded next token) pins each block with a
  ``dart_fetch_and_add(+1)`` refcount, then ``fetch()`` batches the
  hit's block rows per owner into arithmetic-progression runs and
  issues ONE strided segmented gather per run (``read_run_nb``) plus
  ONE per-target flush per owner unit — the whole prefix restores in
  one dispatch per lane with O(owners) descriptors, not one
  ``get_nb`` per block.  Partial overlaps fall back
  to recompute (chunked prefill is future work), so refcounts stay
  exact: only full hits pin.
* **insert** — after a miss's prefill, each chunk's packed K/V is
  queued one-sided (``put_nb``) into a fresh block; the writes stay
  queued so neighbouring blocks coalesce at the next flush (foreground
  read, atomic, or the background progress plane).
* **eviction** — LRU over *unreferenced* blocks (refcount 0), the scan
  serialized through the runtime's :class:`~repro.core.lock.LockService`
  MCS lock (the cross-component critical section of paper §IV.B.6);
  host metadata is additionally guarded by a directory mutex.

The directory itself (key → block id, LRU ticks) is controller
metadata; the cache *state* — block bytes and refcounts — lives in
DART global memory, addressed by :class:`~repro.core.gptr.GlobalPtr`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from .kv_blocks import BlockId, KVBlockPool, PoolExhausted


def chain_keys(tokens: np.ndarray, block_tokens: int) -> List[bytes]:
    """Chain-hash keys for the padded prompt's ``block_tokens`` chunks.

    ``tokens`` length must be a multiple of ``block_tokens`` (the
    engine pads prompts to pow2 buckets that are)."""
    tokens = np.ascontiguousarray(np.asarray(tokens, np.int32))
    if tokens.ndim != 1 or tokens.size % block_tokens:
        raise ValueError(
            f"need a 1-D multiple-of-{block_tokens} token run, got "
            f"shape {tokens.shape}")
    keys, prev = [], b"dart-prefix-cache"
    for c in range(tokens.size // block_tokens):
        chunk = tokens[c * block_tokens:(c + 1) * block_tokens]
        prev = hashlib.blake2b(prev + chunk.tobytes(),
                               digest_size=16).digest()
        keys.append(prev)
    return keys


def pack_kv_blocks(cache, n_tokens: int, block_tokens: int
                   ) -> List[np.ndarray]:
    """Pack a single-sequence prefill cache (leaves ``k``/``v`` of
    shape ``(L, 1, max_seq, kv, hd)``) into per-chunk flat block
    payloads: ``[K-chunk || V-chunk]`` raveled, one per chunk."""
    k = np.asarray(cache["k"])[:, 0]          # (L, max_seq, kv, hd)
    v = np.asarray(cache["v"])[:, 0]
    out = []
    for c in range(n_tokens // block_tokens):
        sl = slice(c * block_tokens, (c + 1) * block_tokens)
        out.append(np.stack([k[:, sl], v[:, sl]]).ravel())
    return out


def unpack_kv_blocks(blocks: List[np.ndarray], *, n_layers: int,
                     kv_heads: int, head_dim: int, block_tokens: int,
                     max_seq: int, dtype) -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of :func:`pack_kv_blocks`: rebuild full ``k``/``v``
    leaves ``(L, 1, max_seq, kv, hd)`` with the blocks' positions
    filled and the tail zeroed (decode overwrites it)."""
    k = np.zeros((n_layers, 1, max_seq, kv_heads, head_dim), dtype)
    v = np.zeros_like(k)
    for c, flat in enumerate(blocks):
        pair = np.asarray(flat).reshape(
            2, n_layers, block_tokens, kv_heads, head_dim)
        sl = slice(c * block_tokens, (c + 1) * block_tokens)
        k[:, 0, sl] = pair[0]
        v[:, 0, sl] = pair[1]
    return k, v


def _index_runs(indices: List[int]) -> List[Tuple[int, int, int]]:
    """Split sorted distinct row indices into maximal arithmetic-
    progression runs ``(start, step, count)`` — each run lowers onto
    ONE strided gather descriptor in :meth:`KVBlockPool.read_run_nb`."""
    runs: List[Tuple[int, int, int]] = []
    i, n = 0, len(indices)
    while i < n:
        if i + 1 == n:
            runs.append((indices[i], 1, 1))
            break
        step = indices[i + 1] - indices[i]
        j = i + 1
        while j + 1 < n and indices[j + 1] - indices[j] == step:
            j += 1
        runs.append((indices[i], step, j - i + 1))
        i = j + 1
    return runs


@dataclasses.dataclass
class _DirEntry:
    bid: BlockId
    tick: int


@dataclasses.dataclass
class PrefixStats:
    lookups: int = 0
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    insert_blocks: int = 0
    shared_blocks: int = 0
    insert_skipped: int = 0
    fetch_get_nb_ops: int = 0
    fetch_runs: int = 0
    fetch_flushes: int = 0
    fetch_dispatches: int = 0
    publish_put_nb_ops: int = 0
    dead_block_purges: int = 0
    dead_misses: int = 0

    def snapshot(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class PrefixHit:
    """A pinned full-prefix hit: fetch the blocks, then release."""

    def __init__(self, service: "PrefixCacheService",
                 blocks: List[BlockId], next_token: int, n_tokens: int):
        self.service = service
        self.blocks = blocks
        self.next_token = int(next_token)
        self.n_tokens = int(n_tokens)
        self._released = False

    def fetch(self) -> List[np.ndarray]:
        """One-sided read of every block, BATCHED per owner: the hit's
        block rows on each unit are split into maximal arithmetic-
        progression runs and each run is ONE strided segmented gather
        (``pool.read_run_nb``) — so a B-block prefix restores in
        ``O(owners)`` descriptors and one dispatch per owner lane, not
        ``B`` per-block ``get_nb`` ops."""
        svc, pool = self.service, self.service.pool
        engine = pool.ctx.engine
        dead = {bid.unit for bid in self.blocks} & pool.dead_units
        if dead:
            # owner died between pin and fetch (lookup already filters
            # dead owners): surface the typed error so the caller can
            # degrade to recompute instead of reading a dead lane
            from ..core.faults import UnitFailedError
            err = UnitFailedError(
                f"prefix blocks owned by dead unit(s) {sorted(dead)}")
            err.unit = min(dead)
            raise err
        with svc._mutex:
            d0 = engine.dispatch_count
        by_owner: Dict[int, List[int]] = {}
        for bid in self.blocks:
            by_owner.setdefault(bid.unit, []).append(bid.index)
        pending = []                           # (unit, start, step, handle)
        for u in sorted(by_owner):
            for start, step, count in _index_runs(sorted(set(by_owner[u]))):
                pending.append((u, start, step,
                                pool.read_run_nb(u, start, count, step)))
        for u in sorted(by_owner):
            pool.flush_unit(u)                 # per-target flush
        fetched: Dict[BlockId, np.ndarray] = {}
        for u, start, step, h in pending:
            stack = np.asarray(h.value())      # (count, block_elems)
            for i, row in enumerate(stack):
                fetched[BlockId(unit=u, index=start + i * step)] = row
        vals = [fetched[bid] for bid in self.blocks]
        with svc._mutex:
            svc.stats.fetch_get_nb_ops += len(pending)
            svc.stats.fetch_runs += len(pending)
            svc.stats.fetch_flushes += len(by_owner)
            svc.stats.fetch_dispatches += engine.dispatch_count - d0
        return vals

    def release(self) -> None:
        """Unpin (refcount −1 per block); idempotent."""
        if self._released:
            return
        self._released = True
        for bid in self.blocks:
            self.service.pool.rc_add(bid, -1)


class PrefixCacheService:
    """Prompt-prefix-hash directory over a :class:`KVBlockPool`."""

    def __init__(self, ctx, pool: KVBlockPool, *, block_tokens: int):
        self.ctx = ctx
        self.pool = pool
        self.block_tokens = int(block_tokens)
        self.stats = PrefixStats()
        self._dir: Dict[bytes, _DirEntry] = {}
        self._next_token: Dict[bytes, int] = {}
        self._tick = 0
        self._mutex = threading.Lock()
        team = ctx.teams[pool.team]
        # the eviction critical section rides the runtime's MCS lock —
        # the serialization point other controllers/components share
        self._evict_lock = ctx.locks.create_lock(team)
        self._home_unit = team.unit_at(0)

    # -- lookup ----------------------------------------------------------
    def lookup(self, padded_tokens: np.ndarray) -> Optional[PrefixHit]:
        """Full-prompt lookup.  On a hit every block is pinned (atomic
        refcount +1) *before* the caller fetches, so eviction can never
        reuse a block out from under a resident sequence."""
        keys = chain_keys(padded_tokens, self.block_tokens)
        with self._mutex:
            self.stats.lookups += 1
            entries = [self._dir.get(k) for k in keys]
            nxt = self._next_token.get(keys[-1])
            if any(e is None for e in entries) or nxt is None:
                self.stats.misses += 1
                return None
            # blocks on a dead owner are unreadable: purge them and
            # degrade to a miss (recompute), never an exception
            dead = [k for k, e in zip(keys, entries)
                    if e.bid.unit in self.pool.dead_units]
            if dead:
                for k in dead:
                    self._dir.pop(k, None)
                    self._next_token.pop(k, None)
                    self.stats.dead_block_purges += 1
                self.stats.dead_misses += 1
                self.stats.misses += 1
                return None
            # pin under the directory mutex: the evictor also holds it
            # while it checks refcount==0, so pin-vs-evict serializes
            for e in entries:
                self.pool.rc_add(e.bid, +1)
                self._tick += 1
                e.tick = self._tick
            self.stats.hits += 1
            return PrefixHit(self, [e.bid for e in entries], nxt,
                             n_tokens=len(keys) * self.block_tokens)

    # -- insert ----------------------------------------------------------
    def insert(self, padded_tokens: np.ndarray,
               blocks: List[np.ndarray], next_token: int) -> int:
        """Publish a miss's prefill: one queued one-sided put per new
        chunk block (shared chunks are kept, not rewritten).  Returns
        the number of NEW blocks published.  Exhaustion (nothing
        evictable) skips the remaining chunks — serving never fails on
        a cache-full condition."""
        keys = chain_keys(padded_tokens, self.block_tokens)
        if len(blocks) != len(keys):
            raise ValueError(
                f"{len(blocks)} block payloads for {len(keys)} chunks")
        published = 0
        for key, payload in zip(keys, blocks):
            with self._mutex:
                ent = self._dir.get(key)
                if ent is not None:            # shared prefix: keep it
                    self._tick += 1
                    ent.tick = self._tick
                    self.stats.shared_blocks += 1
                    continue
            bid = self._alloc_with_evict()
            if bid is None:
                with self._mutex:
                    self.stats.insert_skipped += 1
                continue
            self.pool.write_nb(bid, payload)   # queued; coalesces
            with self._mutex:
                if key in self._dir:           # racing insert won
                    self.stats.shared_blocks += 1
                    self.pool.free(bid)
                    continue
                self._tick += 1
                self._dir[key] = _DirEntry(bid=bid, tick=self._tick)
                self.stats.insert_blocks += 1
                self.stats.publish_put_nb_ops += 1
                published += 1
        with self._mutex:
            self._next_token[keys[-1]] = int(next_token)
        return published

    # -- eviction --------------------------------------------------------
    def _alloc_with_evict(self) -> Optional[BlockId]:
        while True:
            try:
                return self.pool.alloc()
            except PoolExhausted:
                if not self.evict_lru():
                    return None

    def evict_lru(self) -> bool:
        """Reclaim the least-recently-used *unreferenced* block.
        Serialized through the LockService MCS lock (lock order:
        eviction lock → directory mutex, everywhere)."""
        with self.ctx.locks.held(self._evict_lock, self._home_unit):
            # refcount check AND removal both under the directory
            # mutex: lookup pins under the same mutex, so a block seen
            # at refcount 0 here cannot be pinned before we free it
            with self._mutex:
                victims = sorted(self._dir.items(),
                                 key=lambda kv: kv[1].tick)
                for key, ent in victims:
                    if self.pool.rc_load(ent.bid) != 0:
                        continue               # pinned by a resident
                    del self._dir[key]
                    self._next_token.pop(key, None)
                    self.stats.evictions += 1
                    self.pool.free(ent.bid)
                    return True
                return False

    # -- degradation -----------------------------------------------------
    def note_unit_dead(self, unit: int) -> int:
        """Drop every directory entry whose block lives on ``unit``:
        the bytes are unreadable, so later lookups of those prefixes
        miss and recompute.  The blocks are NOT freed back to the pool
        (the pool already purged the dead owner's capacity).  Returns
        the number of entries purged."""
        with self._mutex:
            dead_keys = [k for k, e in self._dir.items()
                         if e.bid.unit == unit]
            for k in dead_keys:
                del self._dir[k]
                self._next_token.pop(k, None)
                self.stats.dead_block_purges += 1
            return len(dead_keys)

    def __len__(self) -> int:
        with self._mutex:
            return len(self._dir)
