"""Block-granular KV cache pool carved from the DART team window.

The serving plane's cache currency is the *block*: the packed K/V state
of ``block_tokens`` consecutive positions across every layer, one
fixed-size element run in a :class:`~repro.core.array.GlobalArray` row.
Blocks are distributed round-robin across the team's units, so the
pool is a PGAS-native service: any component holding a
:class:`BlockId` can mint the block's :class:`~repro.core.gptr.GlobalPtr`
and read or write it one-sided — queued ``put_nb``/``get_nb`` through
the CommEngine, coalescing with its neighbours at the next (per-target)
flush — without the serving loop's participation.

Two planes share the team window:

* **data plane** — ``(rows, block_elems)`` of the cache dtype per unit,
  allocated ``shm=False`` so every read is a counted one-sided engine
  op (the serving bench asserts the dispatch trajectory);
* **refcount plane** — ``(rows,)`` int32 per unit, one cell per block,
  updated only with ``dart_fetch_and_add`` (via the typed
  ``GlobalRef.fetch_add``) so pin/unpin is atomic across however many
  threads serve lookups.

Allocation/free-list bookkeeping is controller-local host metadata;
the *state* (bytes + refcounts) lives in DART global memory.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Deque

import jax.numpy as jnp

from ..core import DART_TEAM_ALL, GlobalPtr, GlobalRef
from ..core.faults import DartError
from ..core.globmem import ALIGNMENT, align_up


class PoolExhausted(DartError):
    """No free block and (if the caller tried) nothing evictable.
    Part of the typed :class:`~repro.core.faults.DartError` ladder
    (still a ``RuntimeError``)."""


@dataclasses.dataclass(frozen=True, order=True)
class BlockId:
    """A block's home: ``unit``'s row, block ``index`` inside it."""

    unit: int
    index: int


def pool_bytes_needed(n_blocks: int, block_elems: int, n_units: int,
                      dtype=jnp.float32) -> int:
    """Per-member team-pool bytes for a pool of ``n_blocks`` blocks:
    the data-plane rows plus the refcount rows, each aligned."""
    rows = (n_blocks + n_units - 1) // n_units
    data = align_up(rows * block_elems * jnp.dtype(dtype).itemsize)
    rc = align_up(rows * 4)
    return data + rc + 2 * ALIGNMENT


class KVBlockPool:
    """Fixed-size pool of GlobalPtr-addressed KV cache blocks."""

    def __init__(self, ctx, *, n_blocks: int, block_elems: int,
                 dtype=jnp.float32, team: int = DART_TEAM_ALL):
        self.ctx = ctx
        self.team = team
        self.dtype = jnp.dtype(dtype)
        self.block_elems = int(block_elems)
        n_units = ctx.teams[team].size()
        self.rows = (n_blocks + n_units - 1) // n_units
        self.n_blocks = self.rows * n_units
        # data plane: shm=False keeps even blocking reads on the counted
        # one-sided engine path (no zero-copy shortcut hiding traffic)
        self.ga = ctx.alloc((self.rows, self.block_elems), self.dtype,
                            team=team, shm=False)
        # refcount plane: one int32 cell per block, atomics-only
        self.rc = ctx.alloc((self.rows,), jnp.int32, team=team, shm=False)
        units = self.ga.units
        self._freelist: Deque[BlockId] = deque(
            BlockId(unit=units[b % n_units], index=b // n_units)
            for b in range(self.n_blocks))
        self._lock = threading.Lock()
        # units declared dead (note_unit_dead): their blocks are never
        # handed out again and their refcount cells are unreachable —
        # rc_add against them degrades to a no-op instead of an
        # engine-path UnitFailedError.
        self.dead_units: set = set()

    # -- allocation (controller-local metadata) --------------------------
    @property
    def n_free(self) -> int:
        return len(self._freelist)

    def alloc(self) -> BlockId:
        with self._lock:
            if not self._freelist:
                raise PoolExhausted(
                    f"all {self.n_blocks} KV blocks in use")
            return self._freelist.popleft()

    def free(self, bid: BlockId) -> None:
        with self._lock:
            if bid.unit in self.dead_units:
                return          # dead owner's capacity is gone, not free
            self._freelist.append(bid)

    def note_unit_dead(self, unit: int) -> int:
        """Degrade around a dead owner: purge its blocks from the
        freelist (the pool shrinks — its HBM is gone) and stop touching
        its refcount cells.  Returns the number of free blocks purged;
        in-use blocks on the unit are the caller's to retire
        (``PrefixCacheService.note_unit_dead`` / the serve engine)."""
        with self._lock:
            self.dead_units.add(unit)
            before = len(self._freelist)
            self._freelist = deque(b for b in self._freelist
                                   if b.unit != unit)
            return before - len(self._freelist)

    # -- addressing ------------------------------------------------------
    def block_ref(self, bid: BlockId) -> GlobalRef:
        """Typed ref to the block's element run in its owner's row."""
        return self.ga.at[bid.unit, bid.index]

    def block_gptr(self, bid: BlockId) -> GlobalPtr:
        """The substrate-layer 128-bit pointer any component can use to
        address this block without the pool object."""
        return self.block_ref(bid).gptr

    # -- data plane (one-sided, engine-queued) ---------------------------
    def write_nb(self, bid: BlockId, values):
        """Queue a one-sided put of the whole block; returns the
        Handle.  Left queued on purpose: neighbouring block writes
        coalesce into one dispatch at the next flush (foreground or
        the background progress plane)."""
        return self.block_ref(bid).put_nb(values)

    def read_nb(self, bid: BlockId):
        """Queue a one-sided get of the whole block; ``handle.value()``
        after a per-target flush yields the typed block."""
        return self.block_ref(bid).get_nb()

    def read_run_nb(self, unit: int, start: int, count: int, step: int = 1):
        """Queue ONE segmented gather of ``count`` whole blocks at rows
        ``start, start+step, ...`` of ``unit`` — a single strided
        descriptor (seg = block bytes, stride = ``step`` rows) instead
        of ``count`` per-block ``get_nb`` ops.  ``handle.value()`` is
        the ``(count, block_elems)`` stack in run order."""
        if count < 1 or step < 1:
            raise ValueError(f"need count>=1 step>=1, got {count}/{step}")
        stop = start + (count - 1) * step + 1
        return self.ga.at[unit, start:stop:step].get_nb()

    def flush_unit(self, unit: int) -> None:
        """Per-target flush of one owner's lane (the
        ``MPI_Win_flush_local(rank, win)`` analogue) — other units'
        queued epochs keep accumulating."""
        self.ga.flush(unit)

    # -- refcount plane (one-sided atomics) ------------------------------
    def rc_ref(self, bid: BlockId) -> GlobalRef:
        return self.rc.at[bid.unit, bid.index:bid.index + 1]

    def rc_add(self, bid: BlockId, delta: int) -> int:
        """Atomic ``dart_fetch_and_add`` on the block's refcount cell;
        returns the pre-update count.  Against a dead owner this is a
        no-op returning 0 — the cell's HBM is gone and pin/unpin
        accounting on it is moot (degradation, not an exception)."""
        if bid.unit in self.dead_units:
            return 0
        return self.rc_ref(bid).fetch_add(delta)

    def rc_load(self, bid: BlockId) -> int:
        """Current refcount (an add of 0 — same atomic path)."""
        return self.rc_add(bid, 0)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"KVBlockPool(blocks={self.n_blocks}, "
                f"elems={self.block_elems}, free={self.n_free})")
