"""Serving steps: prefill builds the KV/state cache; decode advances it
one token.  The decode cache lives in the DART symmetric-heap picture:
a per-unit partition of a team-wide aligned allocation (DESIGN.md §4) —
operationally it is a donated pytree sharded by the cache rules.

Two decode shapes:

* :func:`make_decode_step` — the wave engine's shared-position batch
  step (one scalar ``pos`` for the whole wave);
* :func:`make_batched_decode_step` — the continuous engine's per-slot
  step: ``vmap`` over ``max_batch`` independent single-sequence caches,
  so every slot carries its own position (admits at different times
  decode side by side) while the traced shape stays FIXED — the
  serving loop never retraces after warmup.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import api
from ..models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, batch) -> Tuple[jax.Array, Dict]:
        return api.forward_prefill(cfg, params, batch, max_seq)
    return prefill_step


def make_decode_step(cfg: ModelConfig, sample: str = "greedy",
                     temperature: float = 1.0):
    def decode_step(params, tokens, cache):
        logits, cache = api.forward_decode(cfg, params, tokens, cache)
        if sample == "greedy":
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        else:
            raise ValueError(sample)
        return nxt[:, None], logits, cache
    return decode_step


def make_batched_decode_step(cfg: ModelConfig, sample: str = "greedy"):
    """Per-slot decode for the continuous engine.

    ``tokens`` is ``(max_batch, 1, 1)`` int32 and ``caches`` is the
    per-slot cache pytree — every leaf of ``api.init_cache(cfg, 1,
    max_seq)`` gains a leading slot axis, including the scalar ``pos``
    (→ ``(max_batch,)``), which is what gives each slot its own decode
    position.  Returns ``(next_tokens (max_batch, 1, 1), new caches)``.
    Free slots decode garbage at fixed cost; the scheduler ignores
    their tokens — the price of a shape-stable step.
    """
    if sample != "greedy":
        raise ValueError(sample)

    def one(params, tok, cache):
        logits, cache = api.forward_decode(cfg, params, tok, cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    def step(params, tokens, caches):
        return jax.vmap(one, in_axes=(None, 0, 0))(params, tokens, caches)

    return step


def make_slot_insert():
    """Write one sequence's cache (leaves of ``init_cache(cfg, 1, ...)``)
    into slot ``slot`` of the batched cache pytree.  ``slot`` is a
    traced scalar, so one compile covers every slot index."""

    def insert(caches, slot_cache, slot):
        def put(batched, leaf):
            leaf = leaf[None].astype(batched.dtype)
            start = (slot,) + (0,) * (batched.ndim - 1)
            return jax.lax.dynamic_update_slice(batched, leaf, start)
        return jax.tree.map(put, caches, slot_cache)

    return insert


def init_batched_cache(cfg: ModelConfig, max_batch: int, max_seq: int):
    """Zeroed per-slot cache pytree: each leaf of the single-sequence
    cache with a leading ``max_batch`` slot axis."""
    one = api.init_cache(cfg, 1, max_seq)
    return jax.tree.map(
        lambda l: jnp.zeros((max_batch,) + l.shape, l.dtype), one)
