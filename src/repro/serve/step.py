"""Serving steps: prefill builds the KV/state cache; decode advances it
one token.  The decode cache lives in the DART symmetric-heap picture:
a per-unit partition of a team-wide aligned allocation (DESIGN.md §4) —
operationally it is a donated pytree sharded by the cache rules.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import api
from ..models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, batch) -> Tuple[jax.Array, Dict]:
        return api.forward_prefill(cfg, params, batch, max_seq)
    return prefill_step


def make_decode_step(cfg: ModelConfig, sample: str = "greedy",
                     temperature: float = 1.0):
    def decode_step(params, tokens, cache):
        logits, cache = api.forward_decode(cfg, params, tokens, cache)
        if sample == "greedy":
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        else:
            raise ValueError(sample)
        return nxt[:, None], logits, cache
    return decode_step
