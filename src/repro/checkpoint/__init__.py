from .manager import (CheckpointConfig, CheckpointManager, load_checkpoint,
                      save_checkpoint)
