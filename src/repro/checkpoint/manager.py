"""Sharded, async, atomic checkpointing with resharding restore.

Layout (one directory per step):

    <root>/step_000123.tmp/          # written here first
        manifest.json                # tree structure, shapes, dtypes, crc
        arr_000000.npy … arr_N.npy   # one file per leaf
    <root>/step_000123/              # atomic rename on commit

Design points for the 1000+-node posture (DESIGN.md §5):

* **Atomicity** — the manifest is written last inside the tmp dir and
  the directory is renamed into place; a crash mid-write leaves only a
  ``.tmp`` that restore ignores and cleanup deletes.  The rename is the
  commit point.
* **Async** — ``save_async`` snapshots device arrays to host
  (``jax.device_get`` on the calling thread, cheap relative to a step)
  then hands serialization to a writer thread; training continues.  The
  writer is guarded by a DART MCS lock (paper §IV.B.6) so concurrent
  writers (e.g. elastic restart racing a periodic save) serialize FIFO.
* **Shard-layout independence** — leaves are saved as full (global)
  arrays with their tree paths; restore re-shards onto whatever mesh
  the surviving cluster built (elastic remesh), via ``jax.device_put``
  with the new shardings.
* **Integrity** — per-leaf CRC32 in the manifest; restore verifies and
  refuses corrupt files.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import re
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core import (DartConfig, LockService, Team, ThreadedAtomics,
                    group_from_units)


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    root: str
    keep: int = 3                 # retained checkpoints
    async_save: bool = True


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def save_checkpoint(root: pathlib.Path, step: int, tree,
                    extra: Optional[Dict[str, Any]] = None) -> pathlib.Path:
    """Synchronous atomic save of a pytree of (device or host) arrays."""
    root = pathlib.Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:09d}"
    tmp = root / f"step_{step:09d}.tmp"
    if tmp.exists():
        for f in tmp.iterdir():
            f.unlink()
    tmp.mkdir(parents=True, exist_ok=True)

    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i:06d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({
            "path": path, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
        })
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        raise FileExistsError(final)
    tmp.rename(final)                      # commit point
    return final


def load_checkpoint(root: pathlib.Path, tree_like,
                    step: Optional[int] = None,
                    shardings=None) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``tree_like``; reshard onto
    ``shardings`` (same treedef) if given."""
    root = pathlib.Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = root / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    by_path = {rec["path"]: rec for rec in manifest["leaves"]}

    flat = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves, treedef = flat
    sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                 else [None] * len(leaves))
    out = []
    for (kp, like), sh in zip(leaves, sh_leaves):
        rec = by_path[jax.tree_util.keystr(kp)]
        arr = np.load(d / rec["file"])
        if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != rec["crc32"]:
            raise IOError(f"checkpoint corruption in {rec['file']} "
                          f"({rec['path']})")
        if sh is not None:
            arr = jax.device_put(arr, sh)
        out.append(arr)
    return jax.tree.unflatten(jax.tree.structure(tree_like), out), \
        manifest["extra"]


def latest_step(root: pathlib.Path) -> Optional[int]:
    root = pathlib.Path(root)
    if not root.exists():
        return None
    steps = [int(m.group(1)) for p in root.iterdir()
             if (m := re.fullmatch(r"step_(\d+)", p.name))]
    return max(steps) if steps else None


class CheckpointManager:
    """Async manager with retention + MCS-lock-serialized writers.

    Each concurrent writer thread claims a distinct DART unit id from a
    pool before acquiring the lock: an MCS queue node belongs to one
    acquirer, so two in-flight acquisitions must never share a unit id
    (a same-unit self-enqueue loses its wakeup — found the hard way in
    an earlier revision's deadlock)."""

    MAX_WRITERS = 8

    def __init__(self, cfg: CheckpointConfig,
                 n_units: int = MAX_WRITERS):
        self.cfg = cfg
        self.root = pathlib.Path(cfg.root)
        # DART lock guarding the writer critical section (paper §IV.B.6)
        self._atomics = ThreadedAtomics(n_units)
        self._locks = LockService(self._atomics)
        team = Team(teamid=0, group=group_from_units(range(n_units)),
                    slot=0)
        self._lock = self._locks.create_lock(team)
        self._pending: List[threading.Thread] = []
        self._errors: List[BaseException] = []
        self._free_ids = list(range(n_units))
        self._ids_cv = threading.Condition()

    def _claim_writer_id(self) -> int:
        with self._ids_cv:
            while not self._free_ids:
                self._ids_cv.wait()
            return self._free_ids.pop()

    def _release_writer_id(self, unit: int) -> None:
        with self._ids_cv:
            self._free_ids.append(unit)
            self._ids_cv.notify()

    def save(self, step: int, tree, extra=None):
        if not self.cfg.async_save:
            self._locked_save(step, tree, extra)
            return
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                 tree)

        def _bg():
            try:
                self._locked_save(step, host_tree, extra)
            except BaseException as e:  # noqa: BLE001
                self._errors.append(e)

        t = threading.Thread(target=_bg, daemon=True)
        t.start()
        self._pending.append(t)

    def _locked_save(self, step, tree, extra):
        unit = self._claim_writer_id()
        try:
            self._locks.acquire(self._lock, unit)
            try:
                save_checkpoint(self.root, step, tree, extra)
                self._gc()
            finally:
                self._locks.release(self._lock, unit)
        finally:
            self._release_writer_id(unit)

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1]) for p in self.root.iterdir()
                       if p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        for s in steps[:-self.cfg.keep] if self.cfg.keep else []:
            d = self.root / f"step_{s:09d}"
            for f in d.iterdir():
                f.unlink()
            d.rmdir()
        # drop aborted tmp dirs
        for p in self.root.iterdir():
            if p.name.endswith(".tmp"):
                for f in p.iterdir():
                    f.unlink()
                p.rmdir()

    def wait(self):
        for t in self._pending:
            t.join()
        self._pending.clear()
        if self._errors:
            raise self._errors.pop()

    def restore_latest(self, tree_like, shardings=None):
        return load_checkpoint(self.root, tree_like, shardings=shardings)
