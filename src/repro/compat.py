"""Version-compatibility shims for the installed JAX.

The repo targets recent JAX APIs but must degrade gracefully on older
installs (the container pins whatever it pins).  Two shims live here:

* ``AxisType`` — ``jax.sharding.AxisType`` only exists on newer JAX.
  Older versions have no axis-type concept; every mesh axis behaves
  like the ``Auto`` type, so the correct fallback is simply to omit
  the argument.
* ``make_mesh`` — wraps ``jax.make_mesh`` and passes
  ``axis_types=(AxisType.Auto, ...)`` only when the installed JAX
  understands it.  On very old versions without ``jax.make_mesh`` at
  all, falls back to constructing ``jax.sharding.Mesh`` directly.

Use these instead of ``from jax.sharding import AxisType`` anywhere in
src/, examples/, or tests/.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np

try:  # jax >= 0.5-ish
    from jax.sharding import AxisType  # type: ignore[attr-defined]
    HAS_AXIS_TYPE = True
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None
    HAS_AXIS_TYPE = False

if hasattr(jax, "shard_map"):          # jax >= 0.6 top-level alias
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        # the replication check was named check_rep before the vma rework
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...], *,
              devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with the Auto axis type where supported."""
    if hasattr(jax, "make_mesh"):
        if HAS_AXIS_TYPE:
            return jax.make_mesh(shape, axes, devices=devices,
                                 axis_types=(AxisType.Auto,) * len(axes))
        return jax.make_mesh(shape, axes, devices=devices)
    devs = np.asarray(devices if devices is not None
                      else jax.devices()[: int(np.prod(shape))])
    return jax.sharding.Mesh(devs.reshape(shape), axes)
