"""Model API: schema → (init | abstract | logical) params + forward fns.

Single entry points used by train/serve/launch:

    schema(cfg)              -> pytree of PSpec
    init_params(cfg, rng)    -> pytree of arrays
    abstract_params(cfg)     -> pytree of ShapeDtypeStruct (dry-run)
    logical_axes(cfg)        -> pytree of logical-name tuples
    forward_train(cfg, params, batch)          -> (logits, aux)
    forward_prefill(cfg, params, batch, cache) -> (logits, cache)
    forward_decode(cfg, params, tokens, cache) -> (logits, cache)
    init_cache(cfg, batch_size, max_seq)       -> cache pytree (zeros)
    abstract_cache(cfg, batch_size, max_seq)   -> ShapeDtypeStruct tree

Layers are stacked and iterated with ``lax.scan`` (compile time O(1) in
depth — required for the 512-device dry-run of 126-layer models).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from . import layers as L
from .layers import PSpec, is_pspec
from .mamba2 import apply_mamba2, mamba2_schema
from .moe import apply_moe, moe_schema
from .rwkv6 import (apply_rwkv_att, apply_rwkv_ffn, rwkv_att_schema,
                    rwkv_ffn_schema)

# ===========================================================================
# schemas
# ===========================================================================


def _attn_mlp_block_schema(cfg: ModelConfig, mlp: bool = True,
                           cross: bool = False):
    s = {"ln1": L.norm_schema(cfg), "attn": L.attn_schema(cfg)}
    if cross:
        s["ln_cross"] = L.norm_schema(cfg)
        s["cross"] = L.attn_schema(cfg)
    if mlp:
        if not cfg.parallel_block:
            s["ln2"] = L.norm_schema(cfg)
        s["mlp"] = L.mlp_schema(cfg)
    return s


def _moe_block_schema(cfg: ModelConfig):
    return {"ln1": L.norm_schema(cfg), "attn": L.attn_schema(cfg),
            "ln2": L.norm_schema(cfg), "moe": moe_schema(cfg)}


def _mamba_block_schema(cfg: ModelConfig):
    return {"ln1": L.norm_schema(cfg), "mamba": mamba2_schema(cfg)}


def _rwkv_block_schema(cfg: ModelConfig):
    return {"ln1": L.norm_schema(cfg), "att": rwkv_att_schema(cfg),
            "ln2": L.norm_schema(cfg), "ffn": rwkv_ffn_schema(cfg)}


def _stack(schema_tree, n: int):
    """Prepend a stacked 'layers' axis to every PSpec in the tree."""
    return jax.tree.map(
        lambda ps: PSpec((n,) + ps.shape, ("layers",) + ps.logical,
                         init=ps.init, scale=ps.scale),
        schema_tree, is_leaf=is_pspec)


def schema(cfg: ModelConfig):
    s: Dict[str, Any] = {"embed": L.embed_schema(cfg),
                         "final_norm": L.norm_schema(cfg)}
    if cfg.family in ("dense", "vlm"):
        s["blocks"] = _stack(_attn_mlp_block_schema(cfg), cfg.n_layers)
    elif cfg.family == "moe":
        s["blocks"] = _stack(_moe_block_schema(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        s["blocks"] = _stack(_mamba_block_schema(cfg), cfg.n_layers)
        s["shared"] = _attn_mlp_block_schema(cfg)      # one shared block
    elif cfg.family == "ssm":
        s["blocks"] = _stack(_rwkv_block_schema(cfg), cfg.n_layers)
    elif cfg.family == "encdec":
        s["enc_blocks"] = _stack(_attn_mlp_block_schema(cfg),
                                 cfg.n_enc_layers)
        s["enc_final_norm"] = L.norm_schema(cfg)
        s["blocks"] = _stack(
            _attn_mlp_block_schema(cfg, cross=True), cfg.n_layers)
    else:
        raise ValueError(cfg.family)
    return s


# ===========================================================================
# schema -> params / abstract / logical
# ===========================================================================


def _init_leaf(ps: PSpec, key, dtype):
    if ps.init == "zeros":
        return jnp.zeros(ps.shape, dtype)
    if ps.init == "ones":
        return jnp.ones(ps.shape, dtype)
    scale = ps.scale
    if ps.init == "out_proj":        # scaled-down residual projections
        scale = ps.scale / np.sqrt(2.0)
    return (jax.random.normal(key, ps.shape, jnp.float32)
            * scale).astype(dtype)


def init_params(cfg: ModelConfig, rng: jax.Array):
    sch = schema(cfg)
    leaves, treedef = jax.tree.flatten(sch, is_leaf=is_pspec)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(ps, k, cfg.pdtype) for ps, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(cfg: ModelConfig):
    return jax.tree.map(
        lambda ps: jax.ShapeDtypeStruct(ps.shape, cfg.pdtype),
        schema(cfg), is_leaf=is_pspec)


def logical_axes(cfg: ModelConfig):
    return jax.tree.map(lambda ps: ps.logical, schema(cfg),
                        is_leaf=is_pspec)


def param_count(cfg: ModelConfig) -> int:
    return sum(int(np.prod(ps.shape)) for ps in
               jax.tree.leaves(schema(cfg), is_leaf=is_pspec))


def active_param_count(cfg: ModelConfig) -> int:
    """MoE: params touched per token (shared + top_k experts)."""
    if cfg.family != "moe":
        return param_count(cfg)
    total = param_count(cfg)
    expert_p = 3 * cfg.d_model * cfg.expert_d_ff
    inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * expert_p
    return total - inactive


# ===========================================================================
# blocks: single-layer applications (params = one layer's slice)
# ===========================================================================


def _apply_attn_mlp_block(p, cfg: ModelConfig, x, *, mode, positions,
                          cache=None, cache_pos=None, kv_x=None):
    """dense / vlm / hybrid-shared / whisper-enc/dec block."""
    h = L.apply_norm(p["ln1"], cfg, x)
    attn_mode = mode
    a, new_cache = L.attention(p["attn"], cfg, h, positions=positions,
                               mode=attn_mode, cache=cache,
                               cache_pos=cache_pos)
    if cfg.parallel_block and "mlp" in p:
        m = L.apply_mlp(p["mlp"], cfg, h)
        return x + a + m, new_cache
    x = x + a
    if "cross" in p:
        hc = L.apply_norm(p["ln_cross"], cfg, x)
        c, cross_cache = L.attention(
            p["cross"], cfg, hc, mode="cross", cache=cache, kv_x=kv_x)
        x = x + c
    if "mlp" in p:
        h2 = L.apply_norm(p["ln2"], cfg, x)
        x = x + L.apply_mlp(p["mlp"], cfg, h2)
    return x, new_cache


def _apply_moe_block(p, cfg: ModelConfig, x, *, mode, positions,
                     cache=None, cache_pos=None):
    h = L.apply_norm(p["ln1"], cfg, x)
    a, new_cache = L.attention(p["attn"], cfg, h, positions=positions,
                               mode=mode, cache=cache, cache_pos=cache_pos)
    x = x + a
    h2 = L.apply_norm(p["ln2"], cfg, x)
    m, aux = apply_moe(p["moe"], cfg, h2)
    return x + m, new_cache, aux


def _apply_mamba_block(p, cfg: ModelConfig, x, *, mode, cache=None):
    h = L.apply_norm(p["ln1"], cfg, x)
    m, new_cache = apply_mamba2(p["mamba"], cfg, h, mode=mode, cache=cache)
    return x + m, new_cache


def _apply_rwkv_block(p, cfg: ModelConfig, x, *, mode, cache=None):
    h = L.apply_norm(p["ln1"], cfg, x)
    a, att_cache = apply_rwkv_att(p["att"], cfg, h, mode=mode,
                                  cache=None if cache is None else
                                  {"s": cache["s"], "last": cache["last_att"]})
    x = x + a
    h2 = L.apply_norm(p["ln2"], cfg, x)
    f, ffn_cache = apply_rwkv_ffn(p["ffn"], cfg, h2, mode=mode,
                                  cache=None if cache is None else
                                  {"last": cache["last_ffn"]})
    x = x + f
    new_cache = None
    if att_cache is not None:
        new_cache = {"s": att_cache["s"], "last_att": att_cache["last"],
                     "last_ffn": ffn_cache["last"]}
    return x, new_cache


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ===========================================================================
# forward passes
# ===========================================================================


def _positions(cfg, b, s, start=0):
    pos = jnp.arange(s, dtype=jnp.int32)[None, :] + start    # (1,S)
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(pos[None], (3, b, s))
    return pos


def _scan_blocks(cfg, blocks, x, body):
    """lax.scan over stacked layer params; body(x, p_layer) -> x."""
    if not cfg.scan_layers:
        n = jax.tree.leaves(blocks)[0].shape[0]
        aux_total = jnp.zeros((), jnp.float32)
        for i in range(n):
            p_i = jax.tree.map(lambda a: a[i], blocks)
            x, aux = body(x, p_i)
            aux_total = aux_total + aux
        return x, aux_total

    def scan_body(carry, p_i):
        x = carry
        x, aux = body(x, p_i)
        return x, aux

    n = jax.tree.leaves(blocks)[0].shape[0]
    x, auxs = jax.lax.scan(scan_body, x, blocks,
                           unroll=min(max(cfg.scan_unroll, 1), n))
    return x, jnp.sum(auxs)


def forward_train(cfg: ModelConfig, params, batch
                  ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits, aux_loss)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], cfg, tokens)

    if cfg.family == "vlm" and "vision_embeds" in batch:
        vis = batch["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
        positions = batch["position_ids"]           # (3,B,P+S) from specs
    else:
        positions = _positions(cfg, b, x.shape[1])

    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch["enc_frames"])
        x = x + jnp.asarray(
            L.sinusoidal_positions(s, cfg.d_model), x.dtype)[None]

    aux = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "vlm"):
        def body(x, p):
            fn = _maybe_remat(
                lambda xx: _apply_attn_mlp_block(
                    p, cfg, xx, mode="causal", positions=positions)[0], cfg)
            return fn(x), jnp.zeros((), jnp.float32)
        x, _ = _scan_blocks(cfg, params["blocks"], x, body)

    elif cfg.family == "moe":
        def body(x, p):
            fn = _maybe_remat(
                lambda xx: _apply_moe_block(
                    p, cfg, xx, mode="causal", positions=positions)[::2],
                cfg)
            out = fn(x)
            return out[0], out[1]
        x, aux = _scan_blocks(cfg, params["blocks"], x, body)

    elif cfg.family == "hybrid":
        x = _hybrid_forward(cfg, params, x, positions, mode="train")[0]

    elif cfg.family == "ssm":
        def body(x, p):
            fn = _maybe_remat(
                lambda xx: _apply_rwkv_block(p, cfg, xx, mode="train")[0],
                cfg)
            return fn(x), jnp.zeros((), jnp.float32)
        x, _ = _scan_blocks(cfg, params["blocks"], x, body)

    elif cfg.family == "encdec":
        def body(x, p):
            fn = _maybe_remat(
                lambda xx: _apply_attn_mlp_block(
                    p, cfg, xx, mode="causal", positions=positions,
                    kv_x=enc_out)[0], cfg)
            return fn(x), jnp.zeros((), jnp.float32)
        x, _ = _scan_blocks(cfg, params["blocks"], x, body)

    x = L.apply_norm(params["final_norm"], cfg, x)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        x = x[:, -s:, :]                            # logits on text tokens
    logits = L.lm_logits(params["embed"], cfg, x)
    return logits, aux * cfg.router_aux_coef


def _encode(cfg: ModelConfig, params, frames):
    """Whisper encoder over stubbed conv-frontend frames (B,F,D)."""
    f = frames.shape[1]
    x = frames.astype(cfg.cdtype) + jnp.asarray(
        L.sinusoidal_positions(f, cfg.d_model), cfg.cdtype)[None]

    def body(x, p):
        fn = _maybe_remat(
            lambda xx: _apply_attn_mlp_block(
                p, cfg, xx, mode="bidir", positions=None)[0], cfg)
        return fn(x), jnp.zeros((), jnp.float32)

    x, _ = _scan_blocks(cfg, params["enc_blocks"], x, body)
    return L.apply_norm(params["enc_final_norm"], cfg, x)


def _hybrid_forward(cfg: ModelConfig, params, x, positions, *, mode,
                    cache=None, cache_pos=None):
    """zamba2: scan groups of `every` mamba layers + shared attn block,
    then a tail of leftover mamba layers."""
    every = cfg.shared_attn_every
    n_full = cfg.n_layers // every
    tail = cfg.n_layers % every
    blocks = params["blocks"]

    def take(tree, lo, hi, reshape=None):
        out = jax.tree.map(lambda a: a[lo:hi], tree)
        if reshape:
            out = jax.tree.map(
                lambda a: a.reshape(reshape + a.shape[1:]), out)
        return out

    grouped = take(blocks, 0, n_full * every, reshape=(n_full, every))
    tail_blocks = take(blocks, n_full * every, cfg.n_layers)

    mode_inner = mode if mode != "train" else "train"
    new_mamba_caches = []
    new_shared = None

    if cache is None:
        def group_body(x, p_group):
            def layer_body(x, p):
                fn = _maybe_remat(
                    lambda xx: _apply_mamba_block(p, cfg, xx,
                                                  mode=mode_inner)[0], cfg)
                return fn(x), jnp.zeros((), jnp.float32)
            x, _ = _scan_blocks(cfg, p_group, x, layer_body)
            x, _ = _apply_attn_mlp_block(params["shared"], cfg, x,
                                         mode="causal", positions=positions)
            return x, jnp.zeros((), jnp.float32)

        x, _ = _scan_blocks(cfg, grouped, x, group_body)
        if tail:
            def layer_body(x, p):
                fn = _maybe_remat(
                    lambda xx: _apply_mamba_block(p, cfg, xx,
                                                  mode=mode_inner)[0], cfg)
                return fn(x), jnp.zeros((), jnp.float32)
            x, _ = _scan_blocks(cfg, tail_blocks, x, layer_body)
        return x, None

    # stateful path (prefill/decode): scan with cache as xs/ys
    def group_body_cache(x, inp):
        p_group, mcache, app_idx = inp
        def layer_body(x, inp2):
            p, c = inp2
            x, nc = _apply_mamba_block(p, cfg, x, mode=mode, cache=c)
            return x, nc
        x, new_mc = _scan_with_cache(p_group, mcache, x, layer_body,
                                     unroll=cfg.scan_unroll)
        sc = {"k": cache["shared_k"][app_idx],
              "v": cache["shared_v"][app_idx]}
        x, new_sc = _apply_attn_mlp_block(
            params["shared"], cfg, x,
            mode="decode" if mode == "decode" else "causal",
            positions=positions, cache=sc, cache_pos=cache_pos)
        return x, (new_mc, new_sc)

    mcaches = {"h": cache["h"][:n_full * every].reshape(
                   (n_full, every) + cache["h"].shape[1:]),
               "conv": cache["conv"][:n_full * every].reshape(
                   (n_full, every) + cache["conv"].shape[1:])}

    def outer_body(x, inp):
        return group_body_cache(x, inp)

    x, (new_mc, new_sc) = _scan_with_cache(
        (grouped, mcaches, jnp.arange(n_full)), None, x, outer_body,
        packed=True, unroll=cfg.scan_unroll)

    new_h = new_mc["h"].reshape((n_full * every,) + cache["h"].shape[1:])
    new_conv = new_mc["conv"].reshape(
        (n_full * every,) + cache["conv"].shape[1:])

    if tail:
        tcache = {"h": cache["h"][n_full * every:],
                  "conv": cache["conv"][n_full * every:]}
        def layer_body(x, inp2):
            p, c = inp2
            x, nc = _apply_mamba_block(p, cfg, x, mode=mode, cache=c)
            return x, nc
        x, new_tc = _scan_with_cache(tail_blocks, tcache, x, layer_body,
                                         unroll=cfg.scan_unroll)
        new_h = jnp.concatenate([new_h, new_tc["h"]], axis=0)
        new_conv = jnp.concatenate([new_conv, new_tc["conv"]], axis=0)

    new_cache = {"h": new_h, "conv": new_conv,
                 "shared_k": new_sc["k"], "shared_v": new_sc["v"]}
    return x, new_cache


def _scan_with_cache(blocks, cache, x, body, packed=False, unroll=1):
    """scan over (params, cache) pairs, collecting new caches as ys."""
    xs = blocks if packed else (blocks, cache)

    def scan_body(x, inp):
        x, nc = body(x, inp)
        return x, nc

    n = jax.tree.leaves(xs)[0].shape[0]
    x, new_caches = jax.lax.scan(scan_body, x, xs,
                                 unroll=min(max(unroll, 1), n))
    return x, new_caches


# ---------------------------------------------------------------- decode ---


def init_cache(cfg: ModelConfig, b: int, max_seq: int, abstract=False):
    """Preallocated decode cache (zeros), or ShapeDtypeStructs."""
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    cdt = cfg.cdtype
    mk = (lambda shape, dt=cdt: jax.ShapeDtypeStruct(shape, dt)) if abstract \
        else (lambda shape, dt=cdt: jnp.zeros(shape, dt))
    L_ = cfg.n_layers
    c: Dict[str, Any] = {"pos": mk((), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm"):
        c["k"] = mk((L_, b, max_seq, kv, hd))
        c["v"] = mk((L_, b, max_seq, kv, hd))
    elif cfg.family == "hybrid":
        H, shd, ds = cfg.n_heads, cfg.ssm_head_dim, cfg.ssm_state
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        n_full = cfg.n_layers // cfg.shared_attn_every
        c["h"] = mk((L_, b, H, shd, ds), jnp.float32)
        c["conv"] = mk((L_, b, cfg.ssm_conv - 1, conv_dim))
        c["shared_k"] = mk((n_full, b, max_seq, kv, hd))
        c["shared_v"] = mk((n_full, b, max_seq, kv, hd))
    elif cfg.family == "ssm":
        H, hd_r = cfg.rwkv_n_heads, cfg.rwkv_head_dim
        c["s"] = mk((L_, b, H, hd_r, hd_r), jnp.float32)
        c["last_att"] = mk((L_, b, cfg.d_model))
        c["last_ffn"] = mk((L_, b, cfg.d_model))
    elif cfg.family == "encdec":
        c["k"] = mk((L_, b, max_seq, kv, hd))
        c["v"] = mk((L_, b, max_seq, kv, hd))
        c["ck"] = mk((L_, b, cfg.n_audio_frames, kv, hd))
        c["cv"] = mk((L_, b, cfg.n_audio_frames, kv, hd))
    return c


def abstract_cache(cfg, b, max_seq):
    return init_cache(cfg, b, max_seq, abstract=True)


def forward_decode(cfg: ModelConfig, params, tokens, cache,
                   batch: Optional[dict] = None):
    """One decode step.  tokens: (B,1) -> (logits (B,1,V), new cache)."""
    b = tokens.shape[0]
    pos = cache["pos"]
    x = L.embed_tokens(params["embed"], cfg, tokens)
    if cfg.family == "encdec":
        x = x + L.sinusoidal_position_at(pos, cfg.d_model).astype(
            x.dtype)[None]

    if cfg.family in ("dense", "moe", "vlm"):
        def body(x, inp):
            p, c = inp
            if cfg.family == "moe":
                x, nc, _ = _apply_moe_block(p, cfg, x, mode="decode",
                                            positions=None, cache=c,
                                            cache_pos=pos)
            else:
                x, nc = _apply_attn_mlp_block(p, cfg, x, mode="decode",
                                              positions=None, cache=c,
                                              cache_pos=pos)
            return x, nc
        x, new_kv = _scan_with_cache(
            params["blocks"], {"k": cache["k"], "v": cache["v"]}, x, body,
            unroll=cfg.scan_unroll)
        new_cache = dict(cache, k=new_kv["k"], v=new_kv["v"],
                         pos=pos + 1)

    elif cfg.family == "hybrid":
        x, nc = _hybrid_forward(cfg, params, x, None, mode="decode",
                                cache=cache, cache_pos=pos)
        new_cache = dict(cache, **nc, pos=pos + 1)

    elif cfg.family == "ssm":
        def body(x, inp):
            p, c = inp
            return _apply_rwkv_block(p, cfg, x, mode="decode", cache=c)
        x, nc = _scan_with_cache(
            params["blocks"],
            {"s": cache["s"], "last_att": cache["last_att"],
             "last_ffn": cache["last_ffn"]}, x, body,
            unroll=cfg.scan_unroll)
        new_cache = dict(cache, **nc, pos=pos + 1)

    elif cfg.family == "encdec":
        def body(x, inp):
            p, c = inp
            x, nc = _apply_attn_mlp_block(p, cfg, x, mode="decode",
                                          positions=None, cache=c,
                                          cache_pos=pos)
            return x, dict(nc, ck=c["ck"], cv=c["cv"])
        x, nc = _scan_with_cache(
            params["blocks"],
            {"k": cache["k"], "v": cache["v"], "ck": cache["ck"],
             "cv": cache["cv"]}, x, body,
            unroll=cfg.scan_unroll)
        new_cache = dict(cache, k=nc["k"], v=nc["v"], pos=pos + 1)

    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = L.lm_logits(params["embed"], cfg, x)
    return logits, new_cache


def forward_prefill(cfg: ModelConfig, params, batch, max_seq: int):
    """Prefill: run the full prompt, build the decode cache.

    Returns (last-position logits (B,1,V), cache at pos=S).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_seq)
    x = L.embed_tokens(params["embed"], cfg, tokens)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        vis = batch["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
        positions = batch["position_ids"]
        s = x.shape[1]                       # cache covers vision + text
    else:
        positions = _positions(cfg, b, s)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch["enc_frames"])
        x = x + jnp.asarray(
            L.sinusoidal_positions(s, cfg.d_model), x.dtype)[None]

    if cfg.family in ("dense", "moe", "vlm"):
        def body(x, inp):
            p, c = inp
            if cfg.family == "moe":
                x, nc, _ = _apply_moe_block(p, cfg, x, mode="causal",
                                            positions=positions, cache=c)
            else:
                x, nc = _apply_attn_mlp_block(p, cfg, x, mode="causal",
                                              positions=positions, cache=c)
            return x, nc
        x, new_kv = _scan_with_cache(
            params["blocks"], {"k": cache["k"], "v": cache["v"]}, x, body,
            unroll=cfg.scan_unroll)
        cache = dict(cache, k=new_kv["k"], v=new_kv["v"])

    elif cfg.family == "hybrid":
        x, nc = _hybrid_forward(cfg, params, x, positions, mode="prefill",
                                cache=cache, cache_pos=jnp.int32(0))
        cache = dict(cache, **nc)

    elif cfg.family == "ssm":
        def body(x, inp):
            p, c = inp
            return _apply_rwkv_block(p, cfg, x, mode="prefill", cache=c)
        x, nc = _scan_with_cache(
            params["blocks"],
            {"s": cache["s"], "last_att": cache["last_att"],
             "last_ffn": cache["last_ffn"]}, x, body,
            unroll=cfg.scan_unroll)
        cache = dict(cache, **nc)

    elif cfg.family == "encdec":
        hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

        def body(x, inp):
            p, c = inp
            # precompute this layer's cross k/v from enc_out
            ck = L._split_heads(L._proj(enc_out, p["cross"]["wk"],
                                        p["cross"].get("bk")), hkv, hd)
            cv = L._split_heads(L._proj(enc_out, p["cross"]["wv"],
                                        p["cross"].get("bv")), hkv, hd)
            c = dict(c, ck=ck.astype(c["ck"].dtype),
                     cv=cv.astype(c["cv"].dtype))
            x, nc = _apply_attn_mlp_block(p, cfg, x, mode="causal",
                                          positions=positions, cache=c,
                                          kv_x=enc_out)
            return x, dict(nc, ck=c["ck"], cv=c["cv"])
        x, nc = _scan_with_cache(
            params["blocks"],
            {"k": cache["k"], "v": cache["v"], "ck": cache["ck"],
             "cv": cache["cv"]}, x, body,
            unroll=cfg.scan_unroll)
        cache = dict(cache, **nc)

    cache["pos"] = jnp.int32(s)
    x = L.apply_norm(params["final_norm"], cfg, x[:, -1:, :])
    logits = L.lm_logits(params["embed"], cfg, x)
    return logits, cache
