"""Shared neural layers: norms, RoPE/M-RoPE, GQA attention, MLP.

Pure-function style: params are nested dicts of arrays; every function
takes (params, config, inputs).  Parameter *schemas* (shape + logical
axes + init) are declared once via :class:`PSpec`; init /
ShapeDtypeStruct / logical trees all derive from the same schema
(models/api.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding import shard
from .config import ModelConfig


# ----------------------------------------------------------------- schema --

@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"        # 'normal'|'zeros'|'ones'|'out_proj'
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape,
                                                      self.logical)


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


# ------------------------------------------------------------------ norms --

def rmsnorm(x, gamma, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, gamma, beta, eps):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return out.astype(x.dtype)


def norm_schema(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm_type == "rmsnorm":
        return {"gamma": PSpec((d,), ("embed",), init="ones")}
    return {"gamma": PSpec((d,), ("embed",), init="ones"),
            "beta": PSpec((d,), ("embed",), init="zeros")}


def _rmsnorm_lowp(x, gamma, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gamma.astype(x.dtype)


def _layernorm_lowp(x, gamma, beta, eps):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * gamma.astype(x.dtype)
            + beta.astype(x.dtype))


def apply_norm(p, cfg: ModelConfig, x):
    if not cfg.norm_f32:
        if cfg.norm_type == "rmsnorm":
            return _rmsnorm_lowp(x, p["gamma"], cfg.norm_eps)
        return _layernorm_lowp(x, p["gamma"], p["beta"], cfg.norm_eps)
    if cfg.norm_type == "rmsnorm":
        return rmsnorm(x, p["gamma"], cfg.norm_eps)
    return layernorm(x, p["gamma"], p["beta"], cfg.norm_eps)


# ------------------------------------------------------------------- rope --

def _rope_angles(positions, dim_half: int, theta: float):
    """positions (..., S) -> angles (..., S, dim_half)."""
    freqs = 1.0 / (theta ** (jnp.arange(dim_half, dtype=jnp.float32)
                             / dim_half))
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(q, k, positions, theta: float,
               mrope_sections: Optional[Tuple[int, ...]] = None,
               lowp: bool = False):
    """Rotary embedding.  q/k: (B, S, H, hd).

    positions: (B, S) — standard RoPE; or (3, B, S) — M-RoPE with
    ``mrope_sections`` splitting hd/2 into (t, h, w) frequency bands
    (qwen2-vl).  Text-only tokens pass identical ids in all 3 streams,
    which reduces exactly to standard RoPE.
    """
    hd = q.shape[-1]
    half = hd // 2
    if mrope_sections is None:
        ang = _rope_angles(positions, half, theta)        # (B,S,half)
    else:
        assert sum(mrope_sections) == half, (mrope_sections, half)
        parts = []
        for i, sec in enumerate(mrope_sections):
            start = sum(mrope_sections[:i])
            freqs = 1.0 / (theta ** (jnp.arange(start, start + sec,
                                                dtype=jnp.float32) / half))
            parts.append(positions[i].astype(jnp.float32)[..., None]
                         * freqs)                          # (B,S,sec)
        ang = jnp.concatenate(parts, axis=-1)              # (B,S,half)
    cos = jnp.cos(ang)[..., None, :]                       # (B,S,1,half)
    sin = jnp.sin(ang)[..., None, :]
    if lowp:       # keep the rotation in the activation dtype (§Perf A7)
        cos, sin = cos.astype(q.dtype), sin.astype(q.dtype)

    def rot(x):
        x1, x2 = x[..., :half], x[..., half:]
        out = jnp.concatenate([x1 * cos - x2 * sin,
                               x2 * cos + x1 * sin], axis=-1)
        return out.astype(x.dtype)

    return rot(q), rot(k)


def sinusoidal_positions(n_pos: int, d: int) -> jax.Array:
    """Whisper-style sinusoidal table, computed in-graph (no giant
    HLO constants)."""
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    return _sinusoid(pos, d)


def sinusoidal_position_at(pos, d: int) -> jax.Array:
    """Single-position sinusoid; pos scalar -> (1, d)."""
    p = jnp.asarray(pos, jnp.float32).reshape(1, 1)
    return _sinusoid(p, d)


def _sinusoid(pos, d: int) -> jax.Array:
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# -------------------------------------------------------------- attention --

def attn_schema(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "wq": PSpec((d, hq * hd), ("embed", "q_heads")),
        "wk": PSpec((d, hkv * hd), ("embed", "kv_heads")),
        "wv": PSpec((d, hkv * hd), ("embed", "kv_heads")),
        "wo": PSpec((hq * hd, d), ("q_heads", "embed"), init="out_proj"),
    }
    if cfg.use_bias:
        s.update({
            "bq": PSpec((hq * hd,), ("q_heads",), init="zeros"),
            "bk": PSpec((hkv * hd,), ("kv_heads",), init="zeros"),
            "bv": PSpec((hkv * hd,), ("kv_heads",), init="zeros"),
            "bo": PSpec((d,), ("embed",), init="zeros"),
        })
    return s


def _proj(x, w, b=None):
    y = jnp.einsum("bsd,df->bsf", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def gqa_scores_and_mix(q, k, v, mask, softcap: float = 0.0):
    """Grouped-query attention core.

    q: (B,S,Hq,hd); k/v: (B,T,Hkv,hd); mask broadcastable (B,1,1,S,T)
    or None.  Returns (B,S,Hq,hd).  Hq split into Hkv groups to avoid
    materializing repeated KV.
    """
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k) / np.sqrt(hd)
    scores = scores.astype(jnp.float32)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(b, s, hq, hd)


def blocked_causal_gqa(q, k, v, block: int, softcap: float = 0.0):
    """Flash-style blocked causal GQA (pure JAX, §Perf lever).

    Streams over (q-block, k-block) tiles with an online softmax
    (running max + denominator), so no (S, S) score tensor is ever
    materialized — the classic memory-roofline fix for long-context
    attention.  Tiles are emitted as straight-line HLO (static Python
    loop) so dry-run cost accounting stays exact and XLA fuses each
    tile.  q: (B,S,Hq,hd); k/v: (B,S,Hkv,hd).
    """
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    bq = bk = min(block, s)
    assert s % bq == 0, (s, bq)
    nq = s // bq
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, s, hkv, g, hd)

    out_blocks = []
    for qi in range(nq):
        qblk = qg[:, qi * bq:(qi + 1) * bq].astype(jnp.float32)
        m = jnp.full((b, hkv, g, bq), -1e30, jnp.float32)
        l = jnp.zeros((b, hkv, g, bq), jnp.float32)
        acc = jnp.zeros((b, hkv, g, bq, hd), jnp.float32)
        for kj in range(qi + 1):
            kblk = k[:, kj * bk:(kj + 1) * bk].astype(jnp.float32)
            vblk = v[:, kj * bk:(kj + 1) * bk].astype(jnp.float32)
            sc = jnp.einsum("bskgh,btkh->bkgst", qblk, kblk) * scale
            if softcap:
                sc = jnp.tanh(sc / softcap) * softcap
            if kj == qi:                       # diagonal tile: causal mask
                rows = jnp.arange(bq)[:, None]
                cols = jnp.arange(bk)[None, :]
                sc = jnp.where(cols <= rows, sc, -1e30)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(sc - m_new[..., None])
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgst,btkh->bkgsh", p, vblk)
            m = m_new
        out = acc / l[..., None]
        out_blocks.append(
            out.transpose(0, 3, 1, 2, 4).reshape(b, bq, hq, hd))
    return jnp.concatenate(out_blocks, axis=1).astype(q.dtype)


def causal_mask(s: int, t: int, offset) -> jax.Array:
    """mask[..., i, j] = j <= i + offset (offset = cache position)."""
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(t)[None, :]
    return (cols <= rows + offset)[None, None, None]


def attention(p, cfg: ModelConfig, x, *, positions=None,
              mode: str = "causal", cache=None, cache_pos=None,
              kv_x=None):
    """GQA attention for all modes.

    mode:
      'causal'  — self-attention over x (train / prefill)
      'bidir'   — encoder self-attention
      'cross'   — decoder cross-attention over kv_x (no rope, no mask)
      'decode'  — single-step with KV cache: x is (B,1,D); cache is
                  {'k': (B,T,Hkv,hd), 'v': ...}; cache_pos scalar.
    Returns (out, new_cache) — new_cache is None unless mode='decode'
    or cache-building prefill (pass cache with preallocated buffers).
    """
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(_proj(x, p["wq"], p.get("bq")), hq, hd)
    src = kv_x if mode == "cross" else x
    if mode == "cross" and cache is not None and "ck" in cache:
        k, v = cache["ck"], cache["cv"]     # precomputed at prefill
    else:
        k = _split_heads(_proj(src, p["wk"], p.get("bk")), hkv, hd)
        v = _split_heads(_proj(src, p["wv"], p.get("bv")), hkv, hd)

    q = shard(q, "batch", "seq", "act_heads", None)
    k = shard(k, "batch", "seq", "act_heads", None)
    v = shard(v, "batch", "seq", "act_heads", None)

    sections = cfg.mrope_sections if cfg.family == "vlm" else None
    new_cache = None
    if mode in ("causal", "bidir") and positions is not None \
            and cfg.family != "encdec":
        q, k = apply_rope(q, k, positions, cfg.rope_theta,
                          mrope_sections=sections,
                          lowp=not cfg.norm_f32)
    if mode == "decode":
        if cfg.family != "encdec":
            pos = jnp.asarray(cache_pos)[None, None]      # (1,1)
            if sections is not None:
                pos = jnp.broadcast_to(pos[None], (3,) + pos.shape)
            q, k = apply_rope(q, k, pos, cfg.rope_theta,
                              mrope_sections=sections,
                              lowp=not cfg.norm_f32)
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype),
            (0, jnp.asarray(cache_pos, jnp.int32), 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype),
            (0, jnp.asarray(cache_pos, jnp.int32), 0, 0))
        new_cache = {"k": ck, "v": cv}
        t = ck.shape[1]
        mask = (jnp.arange(t) <= cache_pos)[None, None, None, None, :]
        out = gqa_scores_and_mix(q, ck.astype(q.dtype),
                                 cv.astype(q.dtype), mask,
                                 cfg.logits_softcap)
    else:
        s, t = q.shape[1], k.shape[1]
        if cfg.attn_repeat_kv and hq != hkv:
            # repeat KV to Hq so scores carry a model-shardable head dim
            # (Hkv < model axis would force replicated scores); the
            # repeated K/V are tiny next to the (S,S) scores they shard.
            k = shard(jnp.repeat(k, hq // hkv, axis=2),
                      "batch", "seq", "act_heads", None)
            v = shard(jnp.repeat(v, hq // hkv, axis=2),
                      "batch", "seq", "act_heads", None)
        if (mode == "causal" and cfg.attn_block and s == t
                and s % min(cfg.attn_block, s) == 0):
            out = blocked_causal_gqa(q, k, v, cfg.attn_block,
                                     cfg.logits_softcap)
        else:
            mask = causal_mask(s, t, 0) if mode == "causal" else None
            out = gqa_scores_and_mix(q, k, v, mask, cfg.logits_softcap)
        if cache is not None and mode == "causal":
            # prefill: write k/v into the preallocated cache buffers
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
            new_cache = {"k": ck, "v": cv}

    out = out.reshape(x.shape[0], x.shape[1], hq * hd)
    out = _proj(out, p["wo"], p.get("bo"))
    return shard(out, "batch", "seq", "act_embed"), new_cache


# ------------------------------------------------------------------- mlp ---

def mlp_schema(cfg: ModelConfig, d_ff: Optional[int] = None,
               d: Optional[int] = None):
    d = d or cfg.d_model
    d_ff = d_ff or cfg.d_ff
    if cfg.mlp_type == "swiglu":
        s = {
            "wg": PSpec((d, d_ff), ("embed", "mlp")),
            "wu": PSpec((d, d_ff), ("embed", "mlp")),
            "wd": PSpec((d_ff, d), ("mlp", "embed"), init="out_proj"),
        }
    else:
        s = {
            "wu": PSpec((d, d_ff), ("embed", "mlp")),
            "wd": PSpec((d_ff, d), ("mlp", "embed"), init="out_proj"),
        }
    if cfg.use_bias:
        s["bu"] = PSpec((d_ff,), ("mlp",), init="zeros")
        s["bd"] = PSpec((d,), ("embed",), init="zeros")
    return s


def apply_mlp(p, cfg: ModelConfig, x):
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype)))
        h = h * jnp.einsum("bsd,df->bsf", x, p["wu"].astype(x.dtype))
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(x.dtype))
        if "bu" in p:
            h = h + p["bu"].astype(x.dtype)
        h = jax.nn.gelu(h)
    h = shard(h, "batch", "seq", "mlp")
    out = jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(x.dtype))
    if "bd" in p:
        out = out + p["bd"].astype(x.dtype)
    return out


# ------------------------------------------------------------- embedding ---

def embed_schema(cfg: ModelConfig):
    s = {"tok": PSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                      scale=1.0 / np.sqrt(cfg.d_model))}
    if not cfg.tie_embeddings:
        s["head"] = PSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"))
    return s


def embed_tokens(p, cfg: ModelConfig, tokens):
    emb = jnp.take(p["tok"], tokens, axis=0).astype(cfg.cdtype)
    return shard(emb, "batch", "seq", "act_embed")


def lm_logits(p, cfg: ModelConfig, x):
    w = p.get("head", p["tok"])
    logits = jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype))
    if cfg.logits_softcap:
        logits = jnp.tanh(logits / cfg.logits_softcap) * cfg.logits_softcap
    return logits
