"""RWKV6 "Finch" block (attention-free, data-dependent decay).

Time-mix with per-channel data-dependent decay via a LoRA on the decay
(the Finch innovation: w_t = exp(-exp(w0 + tanh(x_w @ w1) @ w2))), and
the WKV linear-attention recurrence per 64-dim head:

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Train/prefill run the recurrence with lax.scan over time (state is
O(1) in sequence length — this is why rwkv6 runs the long_500k cell);
decode is a single-step update against the cached state.

Simplification vs the full release (DESIGN.md §7): static token-shift
interpolation factors for r/k/v/g (the release uses a second
data-dependent LoRA there); the decay LoRA — the architecturally
defining part — is implemented in full.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import shard
from .config import ModelConfig
from .layers import PSpec


def rwkv_att_schema(cfg: ModelConfig):
    d, l = cfg.d_model, cfg.rwkv_lora_dim
    H, hd = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    return {
        "mu_r": PSpec((d,), ("embed",), init="zeros"),
        "mu_k": PSpec((d,), ("embed",), init="zeros"),
        "mu_v": PSpec((d,), ("embed",), init="zeros"),
        "mu_g": PSpec((d,), ("embed",), init="zeros"),
        "mu_w": PSpec((d,), ("embed",), init="zeros"),
        "w0": PSpec((d,), ("embed",), init="zeros"),
        "w1": PSpec((d, l), ("embed", None)),
        "w2": PSpec((l, d), (None, "embed")),
        "u": PSpec((H, hd), ("q_heads", None)),
        "wr": PSpec((d, d), ("embed", "q_heads")),
        "wk": PSpec((d, d), ("embed", "q_heads")),
        "wv": PSpec((d, d), ("embed", "q_heads")),
        "wg": PSpec((d, d), ("embed", "q_heads")),
        "ln_x": PSpec((d,), ("embed",), init="ones"),
        "wo": PSpec((d, d), ("q_heads", "embed"), init="out_proj"),
    }


def rwkv_ffn_schema(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": PSpec((d,), ("embed",), init="zeros"),
        "mu_r": PSpec((d,), ("embed",), init="zeros"),
        "wk": PSpec((d, f), ("embed", "mlp")),
        "wv": PSpec((f, d), ("mlp", "embed"), init="out_proj"),
        "wr": PSpec((d, d), ("embed", "q_heads")),
    }


def _token_shift(x, last: Optional[jax.Array]):
    """x: (B,S,D); last: (B,D) previous token (decode) or None (zeros)."""
    if x.shape[1] == 1 and last is not None:
        return last[:, None, :]
    prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
    if last is not None:
        prev = prev.at[:, 0, :].set(last)
    return prev


def _lerp(x, prev, mu):
    return x + (prev - x) * mu.astype(x.dtype)[None, None, :]


def _wkv_scan(r, k, v, w, u, s0):
    """WKV recurrence.  r/k/v: (B,S,H,hd); w: (B,S,H,hd) in (0,1);
    u: (H,hd); s0: (B,H,hd,hd).  Returns y (B,S,H,hd), s_last."""
    def step(s, inp):
        rt, kt, vt, wt = inp                       # (B,H,hd) each
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s_new = wt[..., None] * s + kv
        return s_new, y

    rs = jnp.moveaxis(r, 1, 0).astype(jnp.float32)
    ks = jnp.moveaxis(k, 1, 0).astype(jnp.float32)
    vs = jnp.moveaxis(v, 1, 0).astype(jnp.float32)
    ws = jnp.moveaxis(w, 1, 0).astype(jnp.float32)
    s_last, ys = jax.lax.scan(step, s0, (rs, ks, vs, ws))
    return jnp.moveaxis(ys, 0, 1), s_last          # (B,S,H,hd)


#: per-step log-decay floor.  Chunked WKV factorizes the decay ratio
#: exp(cprev_t − cum_s) into exp(cprev_t)·exp(−cum_s); bounding
#: |log w| ≤ LOG_DECAY_FLOOR per step keeps exp(−cum_s) ≤ e^80 < f32
#: max within a 16-token chunk.  Decays below e^-5 per step zero the
#: state within two tokens anyway, so the floor is numerically
#: inconsequential — applied identically in both implementations
#: (DESIGN.md §7).
LOG_DECAY_FLOOR = -5.0


def _wkv_chunked(r, k, v, lw, u, s0, chunk: int):
    """Chunked WKV (the TPU-native formulation, cf. GLA/SSD).

    Intra-chunk work is two batched matmuls (MXU-friendly, outside any
    scan so XLA cost analysis counts it exactly); only the O(S/chunk)
    inter-chunk state recurrence is sequential.

    r/k/v: (B,S,H,hd); lw: (B,S,H,hd) log-decay in [LOG_DECAY_FLOOR,0];
    u: (H,hd); s0: (B,H,hd,hd) [k-dim, v-dim].
    """
    b, s_orig, H, hd = r.shape
    C = min(chunk, s_orig)
    pad = (-s_orig) % C
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        lw = zp(lw)                      # lw=0 => w=1: identity on state
    s = s_orig + pad
    nc = s // C

    f32 = lambda a: a.reshape(b, nc, C, H, hd).astype(jnp.float32)
    rc, kc, vc, lwc = f32(r), f32(k), f32(v), f32(lw)

    cum = jnp.cumsum(lwc, axis=2)                   # Σ_{s<=t} log w
    cprev = cum - lwc                               # Σ_{s<t}
    total = cum[:, :, -1:, :, :]                    # per-chunk Σ

    q_dec = rc * jnp.exp(cprev)                     # ≤ |r|
    k_grow = kc * jnp.exp(-cum)                     # ≤ |k|·e^80 (safe)
    A = jnp.einsum("bnthd,bnshd->bnhts", q_dec, k_grow)
    tri = jnp.tril(jnp.ones((C, C), jnp.float32), -1)[None, None, None]
    A = A * tri                                     # strict lower
    y_intra = jnp.einsum("bnhts,bnshd->bnthd", A, vc)
    # bonus (diagonal) term: (r ∘ u ∘ k)·1 applied to v_t
    coef = jnp.einsum("bnthd,hd->bnth", rc * kc,
                      u.astype(jnp.float32))
    y_intra = y_intra + coef[..., None] * vc

    # inter-chunk state recurrence
    contrib = jnp.einsum("bnshk,bnshv->bnhkv",
                         kc * jnp.exp(total - cum), vc)
    decay = jnp.exp(total[:, :, 0])                 # (b,nc,H,hd)

    def step(S, inp):
        c_n, d_n = inp
        S_new = d_n[..., None] * S + c_n
        return S_new, S

    c_t = jnp.moveaxis(contrib, 1, 0)
    d_t = jnp.moveaxis(decay, 1, 0)
    s_last, s_starts = jax.lax.scan(step, s0, (c_t, d_t))
    s_starts = jnp.moveaxis(s_starts, 0, 1)         # (b,nc,H,hd,hd)

    y_cross = jnp.einsum("bnthk,bnhkv->bnthv", q_dec, s_starts)
    y = (y_intra + y_cross).reshape(b, s, H, hd)
    if pad:
        y = y[:, :s_orig]
    return y, s_last


def apply_rwkv_att(p, cfg: ModelConfig, x, *, mode: str = "train",
                   cache: Optional[dict] = None):
    """Time-mix block.  cache: {'s': (B,H,hd,hd), 'last': (B,D)}."""
    b, s, d = x.shape
    H, hd = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    last = cache["last"] if cache is not None else None
    prev = _token_shift(x, last)

    xr = _lerp(x, prev, p["mu_r"])
    xk = _lerp(x, prev, p["mu_k"])
    xv = _lerp(x, prev, p["mu_v"])
    xg = _lerp(x, prev, p["mu_g"])
    xw = _lerp(x, prev, p["mu_w"])

    r = jnp.einsum("bsd,df->bsf", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,df->bsf", xv, p["wv"].astype(x.dtype))
    g = jnp.einsum("bsd,df->bsf", xg, p["wg"].astype(x.dtype))

    # Finch data-dependent decay (LoRA), log-space with shared floor
    lora = jnp.einsum("bsl,ld->bsd",
                      jnp.tanh(jnp.einsum("bsd,dl->bsl", xw.astype(
                          jnp.float32), p["w1"].astype(jnp.float32))),
                      p["w2"].astype(jnp.float32))
    lw = -jnp.exp(jnp.clip(
        p["w0"].astype(jnp.float32)[None, None, :] + lora, -20.0, 10.0))
    lw = jnp.maximum(lw, LOG_DECAY_FLOOR)

    rh = r.reshape(b, s, H, hd)
    kh = k.reshape(b, s, H, hd)
    vh = v.reshape(b, s, H, hd)
    lwh = lw.reshape(b, s, H, hd)

    s0 = (cache["s"] if cache is not None
          else jnp.zeros((b, H, hd, hd), jnp.float32))
    if mode == "decode":
        kv = jnp.einsum("bhk,bhv->bhkv", kh[:, 0].astype(jnp.float32),
                        vh[:, 0].astype(jnp.float32))
        y = jnp.einsum("bhk,bhkv->bhv", rh[:, 0].astype(jnp.float32),
                       s0 + p["u"].astype(jnp.float32)[None, :, :, None]
                       * kv)
        wh0 = jnp.exp(lwh[:, 0].astype(jnp.float32))
        s_last = wh0[..., None] * s0 + kv
        y = y[:, None]                                   # (B,1,H,hd)
    elif cfg.rwkv_impl == "chunked":
        y, s_last = _wkv_chunked(rh, kh, vh, lwh,
                                 p["u"].astype(jnp.float32), s0,
                                 cfg.rwkv_chunk)
    else:
        y, s_last = _wkv_scan(rh, kh, vh, jnp.exp(lwh),
                              p["u"].astype(jnp.float32), s0)

    y = y.reshape(b, -1, d)
    # per-head group norm (ln_x)
    yh = y.reshape(b, -1, H, hd)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(b, -1, d) * p["ln_x"].astype(jnp.float32)[None, None, :]
    y = (y * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", y, p["wo"].astype(x.dtype))

    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"s": s_last, "last": x[:, -1, :]}
    return shard(out, "batch", "seq", "act_embed"), new_cache


def apply_rwkv_ffn(p, cfg: ModelConfig, x, *, mode: str = "train",
                   cache: Optional[dict] = None):
    """Channel-mix block.  cache: {'last': (B,D)}."""
    last = cache["last"] if cache is not None else None
    prev = _token_shift(x, last)
    xk = _lerp(x, prev, p["mu_k"])
    xr = _lerp(x, prev, p["mu_r"])
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    k = shard(k, "batch", "seq", "mlp")
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.einsum("bsd,df->bsf", xr,
                                  p["wr"].astype(x.dtype)))
    out = r * kv
    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"last": x[:, -1, :]}
    return shard(out, "batch", "seq", "act_embed"), new_cache
