"""Model configuration for the assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config describes any member of the supported families.

    family:
      'dense'  — decoder-only GQA transformer (llama3 / command-r)
      'moe'    — decoder-only with MoE FFN (olmoe / qwen2-moe)
      'hybrid' — Mamba2 backbone + periodic shared attention (zamba2)
      'ssm'    — RWKV6 (attention-free)
      'encdec' — whisper encoder-decoder (conv frontend stubbed)
      'vlm'    — decoder-only with M-RoPE + vision-embed stub (qwen2-vl)
    """

    arch_id: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None          # default d_model // n_heads
    rope_theta: float = 500000.0
    mlp_type: str = "swiglu"                # 'swiglu' | 'gelu'
    use_bias: bool = False                  # whisper: True
    tie_embeddings: bool = False
    norm_type: str = "rmsnorm"              # 'rmsnorm' | 'layernorm'
    norm_eps: float = 1e-5
    parallel_block: bool = False            # command-r: attn+mlp in parallel

    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    n_shared_experts: int = 0
    router_aux_coef: float = 0.01
    moe_fused_combine: int = 1              # fold gate-combine into the
                                            # expert contraction: the TP
                                            # partial-sum all-reduce shrinks
                                            # from (B,S,E,D) to (B,S,D)
                                            # (64x for qwen2-moe; §Perf C1).
                                            # 0 reproduces the naive baseline.

    # SSM / Mamba2
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 64                     # SSD chunk length

    # hybrid (zamba2)
    shared_attn_every: int = 6

    # RWKV6
    rwkv_head_dim: int = 64
    rwkv_lora_dim: int = 64
    rwkv_impl: str = "chunked"              # 'scan' | 'chunked' (see
                                            # models/rwkv6.py — chunked is
                                            # the MXU-friendly TPU form)
    rwkv_chunk: int = 16

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    n_audio_frames: int = 1500              # stubbed conv frontend output

    # VLM (qwen2-vl)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    n_vision_patches: int = 256             # stubbed patch embeds

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # attention execution
    attn_block: int = 0                     # >0: flash-style blocked causal
                                            # attention with this tile size
                                            # (no S x S materialization)
    attn_repeat_kv: int = 0                 # 1: repeat KV heads to Hq and
                                            # run flat per-head attention —
                                            # keeps scores shardable when
                                            # Hkv < model axis (§Perf A2)
    norm_f32: int = 1                       # 0: norms/RoPE in compute dtype
                                            # — cuts the unfused f32-upcast
                                            # elementwise traffic (§Perf A7;
                                            # numerics tradeoff, off by
                                            # default)
    bf16_params_compute: int = 0            # 1: cast params to compute dtype
                                            # before the forward pass, so
                                            # FSDP all-gathers move bf16
                                            # instead of f32 (§Perf lever)
    # execution
    sp_serve: int = 0                       # 1: sequence-parallel serving
                                            # rules (seq->model, weights
                                            # replicated) — §Perf lever
    dp_serve: int = 0                       # 1: decode batch over model
                                            # axis too (pure DP decode)
    # execution
    remat: str = "none"                     # 'none'|'full'|'dots'
    scan_layers: bool = True
    scan_unroll: int = 1                    # lax.scan unroll for layer scans
                                            # (dry-run sets full unroll so
                                            # cost_analysis counts every
                                            # layer — XLA counts while
                                            # bodies once)
    logits_softcap: float = 0.0

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))
        assert self.family in ("dense", "moe", "hybrid", "ssm", "encdec",
                               "vlm"), self.family
        if self.family == "moe":
            assert self.n_experts > 0 and self.top_k > 0

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_head_dim(self) -> int:
        return self.d_inner // self.n_heads

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Supports the long_500k cell (no full quadratic attention over
        the whole context)."""
        return self.family in ("ssm", "hybrid")


def reduced_for_smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid" else 5),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads
        else 4,
        head_dim=32,
        d_ff=256,
        vocab=512,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        expert_d_ff=64 if cfg.n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_chunk=8,
        shared_attn_every=2,
        rwkv_head_dim=32,
        rwkv_lora_dim=16,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        n_audio_frames=16 if cfg.family == "encdec" else cfg.n_audio_frames,
        n_vision_patches=8 if cfg.family == "vlm" else cfg.n_vision_patches,
        mrope_sections=(4, 6, 6) if cfg.family == "vlm" else cfg.mrope_sections,
        compute_dtype="float32",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
