"""Mamba2 block (SSD — state-space dual) for the zamba2 hybrid.

Chunked SSD formulation (Dao & Gu 2024): the sequence is split into
chunks of length C; within a chunk the output is a masked matmul
(MXU-friendly), and only the O(T/C) inter-chunk state recurrence is
sequential (lax.scan).  Per head h the state is (head_dim, d_state).

    h_t = a_t * h_{t-1} + dt_t * x_t ⊗ B_t          a_t = exp(A * dt_t)
    y_t = (h_t @ C_t) + D * x_t

Decode is the O(1) single-step recurrence on the carried state.

Conv frontend: depthwise causal conv (k=ssm_conv) over the x/B/C
projections, with a rolling buffer in the decode cache.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding import shard
from .config import ModelConfig
from .layers import PSpec, rmsnorm


def mamba2_schema(cfg: ModelConfig):
    d, di, ds, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_heads
    conv_dim = di + 2 * ds
    return {
        # in_proj -> [z (di), x (di), B (ds), C (ds), dt (h)]
        "in_proj": PSpec((d, 2 * di + 2 * ds + h), ("embed", "mlp")),
        "conv_w": PSpec((cfg.ssm_conv, conv_dim), (None, "mlp")),
        "conv_b": PSpec((conv_dim,), ("mlp",), init="zeros"),
        "A_log": PSpec((h,), (None,), init="ones"),
        "D": PSpec((h,), (None,), init="ones"),
        "dt_bias": PSpec((h,), (None,), init="zeros"),
        "norm": PSpec((di,), ("mlp",), init="ones"),
        "out_proj": PSpec((di, d), ("mlp", "embed"), init="out_proj"),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, ds, h = cfg.d_inner, cfg.ssm_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * ds]
    dt = zxbcdt[..., di + di + 2 * ds:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over (B,S,Cdim); w: (k, Cdim)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return jax.nn.silu(out + b[None, None, :])


def ssd_forward(A_log, xh, Bm, Cm, dt, chunk: int,
                h0: Optional[jax.Array] = None):
    """Chunked SSD.  Shapes:
      A_log: (H,);  xh: (B,S,H,hd);  Bm/Cm: (B,S,ds);  dt: (B,S,H) (>0)
    Returns y: (B,S,H,hd), h_last: (B,H,hd,ds).
    """
    b, s_orig, H, hd = xh.shape
    ds = Bm.shape[-1]
    C = min(chunk, s_orig)
    pad = (-s_orig) % C
    if pad:
        # zero-pad: dt=0 makes padded steps identity on the state
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) *
                               (a.ndim - 2))
        xh, Bm, Cm, dt = zp(xh), zp(Bm), zp(Cm), zp(dt)
    s = s_orig + pad
    nc = s // C
    A = -jnp.exp(A_log.astype(jnp.float32))                 # (H,) negative

    # reshape into chunks
    xc = xh.reshape(b, nc, C, H, hd).astype(jnp.float32)
    Bc = Bm.reshape(b, nc, C, ds).astype(jnp.float32)
    Cc = Cm.reshape(b, nc, C, ds).astype(jnp.float32)
    dtc = dt.reshape(b, nc, C, H).astype(jnp.float32)

    # per-step log decay and in-chunk cumulative sums
    la = dtc * A[None, None, None, :]                       # (b,nc,C,H) <= 0
    cum = jnp.cumsum(la, axis=2)                            # g_t
    total = cum[:, :, -1:, :]                               # g_C per chunk

    # intra-chunk: y_intra[t] = sum_{u<=t} exp(g_t-g_u) dt_u (C_t.B_u) x_u
    gt = cum[..., None, :]                                  # (b,nc,C,1,H)
    gu = cum[..., None, :, :]                               # (b,nc,1,C,H)
    decay = jnp.exp(jnp.clip(gt - gu, -60.0, 0.0))          # (b,nc,C,C,H)
    tri = jnp.tril(jnp.ones((C, C), jnp.float32))[None, None, :, :, None]
    cb = jnp.einsum("bnts,bnus->bntu", Cc, Bc)              # (b,nc,C,C)
    w = cb[..., None] * decay * tri                         # (b,nc,C,C,H)
    w = w * dtc[:, :, None, :, :]                           # dt_u factor
    y_intra = jnp.einsum("bntuh,bnuhd->bnthd", w, xc)

    # inter-chunk recurrence over chunk states
    # state contribution of chunk n: sum_u exp(g_C - g_u) dt_u x_u B_u
    sdecay = jnp.exp(jnp.clip(total - cum, -60.0, 0.0))     # (b,nc,C,H)
    contrib = jnp.einsum("bnuh,bnuhd,bnus->bnhds",
                         dtc * sdecay, xc, Bc)              # (b,nc,H,hd,ds)
    chunk_decay = jnp.exp(jnp.clip(total[:, :, 0, :], -60.0, 0.0))  # (b,nc,H)

    def step(h, inp):
        contrib_n, decay_n = inp
        h_new = h * decay_n[..., None, None] + contrib_n
        return h_new, h

    if h0 is None:
        h0 = jnp.zeros((b, H, hd, ds), jnp.float32)
    # scan over chunks: need leading axis nc
    contrib_t = jnp.moveaxis(contrib, 1, 0)                 # (nc,b,H,hd,ds)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)               # (nc,b,H)
    h_last, h_starts = jax.lax.scan(step, h0, (contrib_t, decay_t))
    h_starts = jnp.moveaxis(h_starts, 0, 1)                 # (b,nc,H,hd,ds)

    # cross-chunk output: y_cross[t] = exp(g_t) * C_t . h_start
    tdecay = jnp.exp(jnp.clip(cum, -60.0, 0.0))             # (b,nc,C,H)
    y_cross = jnp.einsum("bnts,bnhds,bnth->bnthd",
                         Cc, h_starts, tdecay)
    y = (y_intra + y_cross).reshape(b, s, H, hd)
    if pad:
        y = y[:, :s_orig]
    return y, h_last


def ssd_decode_step(A_log, xh, Bm, Cm, dt, h):
    """Single-token recurrence.  xh: (B,1,H,hd); h: (B,H,hd,ds)."""
    A = -jnp.exp(A_log.astype(jnp.float32))
    a = jnp.exp(dt[:, 0].astype(jnp.float32) * A[None, :])  # (B,H)
    upd = jnp.einsum("bh,bhd,bs->bhds", dt[:, 0].astype(jnp.float32),
                     xh[:, 0].astype(jnp.float32),
                     Bm[:, 0].astype(jnp.float32))
    h_new = h * a[..., None, None] + upd
    y = jnp.einsum("bhds,bs->bhd", h_new, Cm[:, 0].astype(jnp.float32))
    return y[:, None], h_new                                 # (B,1,H,hd)


def apply_mamba2(p, cfg: ModelConfig, x, *, mode: str = "train",
                 cache: Optional[dict] = None):
    """Mamba2 block.  x: (B,S,D).

    mode 'train'/'prefill': full-sequence chunked SSD; returns
    (y, new_cache or None) — prefill returns final state + conv tail.
    mode 'decode': S==1 single step against cache {'h','conv'}.
    """
    b, s, d = x.shape
    di, ds, H = cfg.d_inner, cfg.ssm_state, cfg.n_heads
    hd = cfg.ssm_head_dim
    k = cfg.ssm_conv

    zxbcdt = jnp.einsum("bsd,df->bsf", x, p["in_proj"].astype(x.dtype))
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))

    new_cache = None
    if mode == "decode":
        # rolling conv buffer: (B, k-1, conv_dim)
        conv_buf = cache["conv"]
        window = jnp.concatenate([conv_buf, xbc], axis=1)    # (B,k,cd)
        conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                              p["conv_w"].astype(jnp.float32))
        xbc_c = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
        xbc_c = xbc_c[:, None, :].astype(x.dtype)
        new_conv = window[:, 1:, :]
    else:
        xbc_c = _causal_conv(xbc, p["conv_w"].astype(jnp.float32),
                             p["conv_b"].astype(jnp.float32)).astype(x.dtype)
        new_conv = xbc[:, -(k - 1):, :] if s >= k - 1 else jnp.pad(
            xbc, ((0, 0), (k - 1 - s, 0), (0, 0)))

    xs = xbc_c[..., :di].reshape(b, xbc_c.shape[1], H, hd)
    Bm = xbc_c[..., di:di + ds]
    Cm = xbc_c[..., di + ds:di + 2 * ds]

    if mode == "decode":
        y, h_new = ssd_decode_step(p["A_log"], xs, Bm, Cm, dt, cache["h"])
        new_cache = {"h": h_new, "conv": new_conv}
    else:
        h0 = cache["h"] if cache is not None else None
        y, h_last = ssd_forward(p["A_log"], xs, Bm, Cm, dt,
                                cfg.ssm_chunk, h0=h0)
        if mode == "prefill":
            new_cache = {"h": h_last, "conv": new_conv}

    y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None,
                                                                :, None]
    y = y.reshape(b, -1, di).astype(x.dtype)
    # gated RMSNorm (mamba2's norm before out_proj)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["norm"], 1e-5)
    out = jnp.einsum("bsf,fd->bsd", y, p["out_proj"].astype(x.dtype))
    return shard(out, "batch", "seq", "act_embed"), new_cache
