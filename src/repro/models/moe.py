"""Mixture-of-Experts FFN (olmoe / qwen2-moe style).

Top-k softmax router + dense one-hot dispatch (einsum over the expert
axis).  Dense dispatch is the TPU-native choice: the dispatch/combine
einsums are MXU matmuls and shard cleanly with experts on the 'model'
mesh axis (expert parallelism); when experts are sharded, XLA lowers
the dispatch to the all-to-all the paper's one-sided puts would carry
(DESIGN.md §4: MoE dispatch = one-sided puts into remote expert
segments).

Shared experts (qwen2-moe): a standard always-on MLP with
``n_shared_experts * expert_d_ff`` hidden width added to the routed
output.

Aux losses: load-balancing (Switch-style fraction·probability product)
returned alongside so train_step can weight it.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import shard
from .config import ModelConfig
from .layers import PSpec, apply_mlp, mlp_schema


def moe_schema(cfg: ModelConfig):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.expert_d_ff
    s = {
        "router": PSpec((d, e), ("embed", None)),
        "wg": PSpec((e, d, f), ("experts", "embed", "mlp")),
        "wu": PSpec((e, d, f), ("experts", "embed", "mlp")),
        "wd": PSpec((e, f, d), ("experts", "mlp", "embed"),
                    init="out_proj"),
    }
    if cfg.n_shared_experts:
        s["shared"] = mlp_schema(cfg,
                                 d_ff=cfg.n_shared_experts * cfg.expert_d_ff)
    return s


def apply_moe(p, cfg: ModelConfig, x) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (out, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (B,S,k)
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9)              # renormalize

    # dense one-hot combine weights: (B,S,E)
    combine = jnp.zeros((b, s, e), jnp.float32)
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (B,S,k,E)
    combine = (onehot * gate_vals[..., None]).sum(axis=2)

    # expert FFN on all tokens, weighted combine (dense dispatch).
    xc = x.astype(cfg.cdtype)
    h = jnp.einsum("bsd,edf->bsef", xc, p["wg"].astype(xc.dtype))
    h = jax.nn.silu(h) * jnp.einsum("bsd,edf->bsef", xc,
                                    p["wu"].astype(xc.dtype))
    h = shard(h, "batch", "seq", "experts", "mlp")
    if cfg.moe_fused_combine:
        # scale by the gate BEFORE the down-projection so E and F are
        # contracted together: the partial-sum all-reduce (wd sharded on
        # F) then carries only (B,S,D) instead of (B,S,E,D) — 1/E the
        # bytes, and in bf16 (§Perf C1).
        hw = h * combine.astype(h.dtype)[..., None]
        out = jnp.einsum("bsef,efd->bsd", hw, p["wd"].astype(xc.dtype))
    else:
        y = jnp.einsum("bsef,efd->bsed", h, p["wd"].astype(xc.dtype))
        out = jnp.einsum("bsed,bse->bsd", y, combine.astype(y.dtype))

    if cfg.n_shared_experts:
        out = out + apply_mlp(p["shared"], cfg, x)

    # Switch load-balance loss: E * sum_e f_e * P_e
    f_e = onehot.sum(axis=2).mean(axis=(0, 1))               # fraction routed
    p_e = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e)
    return shard(out, "batch", "seq", "act_embed"), aux
