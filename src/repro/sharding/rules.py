"""Logical-axis sharding rules (MaxText-style).

Model code annotates parameters/activations with *logical* axis names;
a rule table maps logical → mesh axes per run mode.  Inside a
``sharding_context(mesh, rules)`` every ``shard(x, names)`` becomes a
``with_sharding_constraint``; outside, it is the identity, so the same
model code runs on 1 CPU device and on the 512-chip production mesh.

Mesh axes of the production mesh: ('pod', 'data', 'model')
(launch/mesh.py).  FSDP = mapping the params' long logical axes to
'data' as well; EP = 'experts' → 'model'; SP = 'seq' → 'data'.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]
AxisRules = Dict[str, MeshAxes]

#: baseline TP+DP(+FSDP) rule table used by train_step on the
#: production mesh.  'data' shards batch; 'model' shards heads /
#: mlp / vocab / experts; FSDP additionally shards the embed axis of
#: params over 'data' (see fsdp_rules).
DEFAULT_TRAIN_RULES: AxisRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "vocab": "model",
    "q_heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "state": None,
    "layers": None,
    "act_embed": None,
    "act_heads": "model",
    "conv": None,
}


def fsdp_rules(base: AxisRules) -> AxisRules:
    """ZeRO-3: additionally shard parameter 'embed' over the data axis."""
    r = dict(base)
    r["embed"] = "data"
    return r


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[AxisRules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_context(mesh: Optional[Mesh], rules: Optional[AxisRules]):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_rules() -> Tuple[Optional[Mesh], Optional[AxisRules]]:
    return _CTX.mesh, _CTX.rules


def logical_to_spec(names: Sequence[Optional[str]],
                    rules: AxisRules) -> P:
    """Map logical axis names to a PartitionSpec under ``rules``.

    Guarantees no mesh axis is used twice (later duplicates drop to
    None — replicated — which is always legal)."""
    used = set()
    out = []
    for nm in names:
        ax = rules.get(nm) if nm is not None else None
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if a not in used)
        used.update(axes)
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(axes)
    return P(*out)


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Annotate activation sharding; identity outside a context.

    Size-aware: a mesh axis is only claimed when the dim divides it —
    constraining an 8-way KV-head dim onto a 16-way 'model' axis would
    force XLA into involuntary full rematerializations."""
    mesh, rules = current_rules()
    if mesh is None or rules is None:
        return x
    spec = logical_to_spec_sized(names, x.shape, rules, mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def logical_to_spec_sized(names: Sequence[Optional[str]],
                          shape: Sequence[int], rules: AxisRules,
                          mesh: Mesh) -> P:
    """Size-aware mapping: a mesh axis is only assigned to a dim when
    the dim size is divisible by the axis size (XLA would pad
    otherwise); dropped axes become available to later dims.

    E.g. qwen2-moe's 60 experts don't divide model=16, so 'experts'
    drops its claim and the 'mlp' dim picks 'model' up instead.
    """
    used = set()
    out = []
    for nm, dim in zip(names, shape):
        ax = rules.get(nm) if nm is not None else None
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if a not in used
                     and a in mesh.axis_names)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if not axes or size <= 0 or dim % size != 0:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes[0] if len(axes) == 1 else axes)
    return P(*out)


def sized_spec_tree(logical_tree, shape_tree, rules: AxisRules,
                    mesh: Mesh):
    """NamedShardings for a params-like tree, size-aware."""
    return jax.tree.map(
        lambda names, sds: NamedSharding(
            mesh, logical_to_spec_sized(names, sds.shape, rules, mesh)),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def spec_tree(logical_tree, rules: AxisRules, mesh: Mesh):
    """Map a pytree of logical-name tuples to NamedShardings."""
    return jax.tree.map(
        lambda names: NamedSharding(mesh, logical_to_spec(names, rules)),
        logical_tree, is_leaf=lambda x: isinstance(x, tuple))
