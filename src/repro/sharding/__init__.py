from .rules import (AxisRules, DEFAULT_TRAIN_RULES, current_rules,
                    logical_to_spec, shard, sharding_context, spec_tree)
