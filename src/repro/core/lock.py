"""DART mutexes: the MCS list-based queuing lock (paper §IV.B.6).

Faithful implementation of the protocol in the paper (after
Mellor-Crummey & Scott [16]), Fig. 6:

* Lock creation is collective on a team; multiple locks per team.
* State: a ``tail`` cell — a non-collective global allocation on unit 0
  of the team (``dart_memalloc`` in the paper) — plus a distributed
  ``list`` (one "next waiter" cell per member, allocated via
  ``dart_team_memalloc_aligned``).  Both initialized to -1:
  lock free, queue empty.
* ``dart_lock_acquire`` (unit i): ``predecessor = fetch_and_store(tail, i)``.
  If ``predecessor == -1`` the lock was free and i holds it.  Otherwise
  i registers itself in ``list[predecessor]`` (a one-sided put) and
  blocks waiting for a zero-size notification from its predecessor
  (``MPI_Recv`` in the paper).
* ``dart_lock_release`` (unit i): ``compare_and_swap(tail, i, -1)``.
  If the CAS succeeds i was the only queued unit and the lock becomes
  free.  Otherwise a successor is (or is about to be) registered: spin
  until ``list[i] != -1``, then send the zero-size notification to the
  successor and reset ``list[i]``.

FIFO ordering and mutual exclusion follow from the atomicity of
fetch_and_store/CAS — both provided by :mod:`repro.core.atomics`.

Beyond-paper (§VI future work): the paper always places ``tail`` on
unit 0, concentrating atomic traffic there when many locks exist per
team.  ``tail_placement='round_robin'`` spreads tails across members by
lock id; ``benchmarks/lock_bench.py`` measures the per-home congestion
counters for both placements.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, List, Optional

from .atomics import AtomicsProvider, Cell
from .team import Team

FREE = -1


@dataclasses.dataclass
class DartLock:
    """Handle for one team lock (the paper's compound record)."""

    lock_id: int
    team: Team
    tail: Cell                       # non-collective gptr → atomic cell
    next_cells: Dict[int, Cell]      # absolute unit → its 'list' slot
    #: stats for benchmarks
    acquisitions: int = 0

    def is_free_hint(self, atomics: AtomicsProvider) -> bool:
        """Non-authoritative peek at the tail (debug/monitoring only)."""
        return atomics.load(self.tail) == FREE


class LockService:
    """Creates and operates DART team locks over an atomics provider."""

    def __init__(self, atomics: AtomicsProvider,
                 tail_placement: str = "unit0"):
        if tail_placement not in ("unit0", "round_robin"):
            raise ValueError(tail_placement)
        self.atomics = atomics
        self.tail_placement = tail_placement
        self._locks: Dict[int, DartLock] = {}
        self._next_lock_id = 0

    # -- dart_team_lock_init (collective on team) ------------------------
    def create_lock(self, team: Team) -> DartLock:
        lock_id = self._next_lock_id
        self._next_lock_id += 1
        members = team.group.members
        if self.tail_placement == "unit0":
            home = members[0]                      # paper: always unit 0
        else:
            home = members[lock_id % len(members)]  # beyond-paper balance
        tail = self.atomics.make_cell(("tail", lock_id), home, FREE)
        next_cells = {
            u: self.atomics.make_cell(("next", lock_id, u), u, FREE)
            for u in members
        }
        lock = DartLock(lock_id=lock_id, team=team, tail=tail,
                        next_cells=next_cells)
        self._locks[lock_id] = lock
        return lock

    def destroy_lock(self, lock: DartLock) -> None:
        """dart_team_lock_free: drop the registry entry AND return the
        tail/next cells to the provider (heap-backed providers reclaim
        the global-memory bytes; cells leaked here were unreclaimable
        until the provider grew ``free_cell``)."""
        self._locks.pop(lock.lock_id, None)
        self.atomics.free_cell(lock.tail)
        for cell in lock.next_cells.values():
            self.atomics.free_cell(cell)

    # -- dart_lock_acquire ------------------------------------------------
    def acquire(self, lock: DartLock, unit: int,
                timeout: Optional[float] = None) -> None:
        if unit not in lock.next_cells:
            raise KeyError(f"unit {unit} is not in team {lock.team.teamid}")
        predecessor = self.atomics.fetch_and_store(lock.tail, unit)
        if predecessor != FREE:
            # register with the predecessor (one-sided put into list[pred])
            self.atomics.store(lock.next_cells[predecessor], unit)
            # block until the predecessor's release notifies us
            self.atomics.wait_notify(unit, ("lock", lock.lock_id),
                                     timeout=timeout)
        lock.acquisitions += 1

    def try_acquire(self, lock: DartLock, unit: int) -> bool:
        """dart_lock_try_acquire: acquire only if currently free."""
        old = self.atomics.compare_and_swap(lock.tail, FREE, unit)
        if old == FREE:
            lock.acquisitions += 1
            return True
        return False

    @contextlib.contextmanager
    def held(self, lock: DartLock, unit: int,
             timeout: Optional[float] = None):
        """``with locks.held(lock, unit): ...`` — acquire on entry,
        release on exit **including on exception**, so a failing
        critical section can never wedge the queue (successors would
        otherwise block forever in ``wait_notify``)."""
        self.acquire(lock, unit, timeout=timeout)
        try:
            yield lock
        finally:
            self.release(lock, unit)

    # -- dart_lock_release ------------------------------------------------
    def release(self, lock: DartLock, unit: int,
                spin_sleep: float = 1e-6, max_spin_sleep: float = 1e-3,
                timeout: Optional[float] = None) -> None:
        """Release, handing off to the registered successor if any.

        The successor-registration wait uses bounded exponential
        backoff (``spin_sleep`` doubling up to ``max_spin_sleep``) —
        the old ``spin_sleep=0.0`` default was a GIL-held busy loop
        that starved the very successor thread it was waiting on under
        the threaded provider.  ``timeout`` mirrors ``acquire``: raise
        ``TimeoutError`` instead of spinning forever on a successor
        that swapped the tail but died before registering.
        """
        old = self.atomics.compare_and_swap(lock.tail, unit, FREE)
        if old == unit:
            return                                  # nobody queued behind us
        # A successor swapped the tail before our CAS: it is (or will be)
        # registered in our 'next' cell.  Back off until the
        # registration lands, then hand over.
        mine = lock.next_cells[unit]
        deadline = (time.monotonic() + timeout
                    if timeout is not None else None)
        sleep = max(spin_sleep, 1e-9)
        succ = self.atomics.load(mine)
        while succ == FREE:
            if deadline is not None and time.monotonic() >= deadline:
                raise TimeoutError(
                    f"lock {lock.lock_id}: successor swapped the tail "
                    f"but never registered in unit {unit}'s next cell "
                    f"within {timeout}s")
            time.sleep(sleep)
            sleep = min(sleep * 2, max_spin_sleep)
            succ = self.atomics.load(mine)
        self.atomics.store(mine, FREE)
        self.atomics.notify(succ, ("lock", lock.lock_id))
