"""DART one-sided communication (paper §III, §IV.B.5) + the
locality-aware non-blocking engine (§VI future work).

Two planes, mirroring how DART-MPI sits above MPI-3 RMA:

**Host plane** (single-controller, the analogue of the paper's
process-level API): ``dart_put/get`` dereference the global pointer
(flags → allocation kind, segid → team, absolute→relative unit
translation for collective pointers — §IV.B.4), then issue the
underlying substrate op.  The substrate here is XLA: a donated
``dynamic_update_slice`` on the sharded arena, which on a TPU mesh
compiles to a one-sided ICI DMA into the owning unit's HBM — the direct
analogue of ``MPI_Rput`` in a passive-target epoch.

**Epoch / flush / completion model** (the non-blocking engine):

The paper's non-blocking ops return request handles completed by
``dart_wait``/``dart_test``; underneath, MPI aggregates requests and a
``MPI_Win_flush`` completes them at the window.  We reproduce that
structure with :class:`CommEngine`, an **epoch-scoped pending-op
queue** over the symmetric heap:

* ``CommEngine.put/get`` *enqueue* — the pointer is dereferenced and
  bounds-checked at initiation (translation happens once, like the
  paper's dart_put), but no device work is dispatched.  The returned
  :class:`Handle` starts in the ``queued`` state.
  ``CommEngine.accumulate/get_accumulate`` (the ``MPI_Accumulate`` /
  ``MPI_Get_accumulate`` analogues — element-wise reductions applied
  *at the target*) enqueue the same way: same-(op, dtype) runs share
  one segmented read-modify-write dispatch — overlap included, the
  ops commute — while mixed-op or accumulate-vs-put overlap splits
  the run in queue order; fetch runs stay byte-disjoint so every
  fetched pre-value matches the sequential order (the *reduction
  plane*; identity-padded descriptors keep it on the same bucketed
  plan cache).
* ``CommEngine.flush`` closes the epoch: maximal runs of same-pool
  ops are **coalesced** into one batched jitted dispatch — N queued
  puts become a single XLA launch instead of N.  Same-size ops
  coalesce unconditionally; **mixed-size** ops share the dispatch
  when their byte ranges are disjoint and split the run when they
  overlap.  Program order is preserved run-by-run, so overlapping
  writes resolve exactly as the equivalent sequence of blocking ops
  (last writer wins).
* Dispatch is **shape-stable** (the DispatchPlan layer,
  :mod:`repro.kernels.segmented_copy`): run length and segment size
  are bucketed to powers of two, padded with masked no-op
  descriptors, so a steady-state loop of varying-size epochs hits a
  small fixed family of compiled kernels — zero recompiles after
  warmup (``compile_count`` / ``plan_cache_hits`` make this
  assertable).  Each flush stages its metadata as ONE packed
  ``(k, 4)`` descriptor array and its payload as ONE flat byte
  buffer (two host→device transfers, not 3–5 tiny ones per run), and
  provably disjoint put runs dispatch as one *vectorized* segmented
  update; only overlapping uniform runs keep the sequential in-order
  loop.  See docs/API.md "Flush cost model".
* ``CommEngine.flush(poolid, row)`` is the **per-target** form — the
  ``MPI_Win_flush_local(rank, win)`` analogue: only the named
  ``(pool, row)`` lane dispatches; other targets' queued epochs keep
  accumulating (rows are disjoint per-unit partitions, so this can
  never reorder visible effects).  ``handle.wait()`` flushes only its
  own lane; the runtime surfaces ``dart_flush(ctx, gptr,
  target=unit)`` and the typed layer ``ga[unit].flush()``.
* Handle lifecycle: ``queued`` → (flush) → ``issued`` → (XLA async
  dispatch drains) → ``complete`` — the paper's §III
  issued/locally-complete/remotely-complete ladder.  ``dart_wait`` on
  a queued handle triggers the flush itself; ``dart_test`` reports
  False until the op has been dispatched.

**Threading model**: the engine is thread-safe.  ``CommEngine.lock``
(a reentrant lock) serializes every mutation of the pending queue, the
instrumentation counters, and — critically — every ``holder.state``
swap: the batched kernels *donate* the arena, so an unserialized
``ctx.state`` read racing a flush could observe a deleted buffer.  Any
code that reads ``holder.state`` outside the engine (the heap atomics
in :mod:`repro.core.atomic_ops`, the zero-copy view in
:mod:`repro.core.shm`, the host-plane collectives) takes the same lock.
N submitter threads may enqueue/flush/wait/test concurrently; handle
state transitions (``queued → issued → complete`` / ``failed``) happen
under the lock, so ``dart_test``/``dart_wait``/``dart_waitall`` are
safe from any thread while a flusher runs — including the background
:class:`repro.core.progress.ProgressPlane`, which drains queued epochs
at a byte/op watermark or an idle deadline without any caller
involvement (the paper's passive-target progress, docs/API.md
"Threading model & progress").

The engine also carries ``dispatch_count``, a counter of jitted kernel
launches, so tests and benchmarks can *assert* that a coalesced flush
issues fewer dispatches than the equivalent blocking sequence.

**Locality classifier**: on deref, ``FLAG_SHM``-eligible pointers
whose arena is host-visible are routed through the zero-copy view in
:mod:`repro.core.shm` instead of a jitted dynamic-slice dispatch (the
paper's §VI shared-memory-window plan) — see
:func:`repro.core.shm.classify_locality` and the runtime-level
``dart_get_blocking``.

Epochs: MPI requires RMA calls to sit inside an access epoch; DART opens
a shared epoch on every window at init/alloc time so users never see it
(§IV.B.5).  In XLA the "epoch" is the program region between two
flushes — conflict freedom inside it is guaranteed by dataflow, exactly
the RMA *unified* memory model the paper adopts.

**Device plane** (inside ``shard_map``; the analogue of what DASH's
compiled kernels do): ``shmem_put/get`` move bytes between unit rows
with ``lax.ppermute`` (static peers → point-to-point ICI DMA) or an
``all_gather`` + dynamic row-select (dynamic peers).  The Pallas RDMA
kernels in ``repro.kernels.rdma`` are the hand-tiled fast path for the
same semantics.
"""

from __future__ import annotations

import bisect
import contextlib
import dataclasses
import functools
import random
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import segmented_copy as _sc

from .faults import (DartError, FaultPlane, FlushTimeoutError,
                     RetriesExhaustedError, TransientDispatchFault,
                     UnitFailedError)
from .globmem import (HeapState, SymmetricHeap, WindowDestroyedError,
                      copy_state, from_bytes, nbytes_of, to_bytes)
from .gptr import GlobalPtr


def _to_host_bytes(value) -> np.ndarray:
    """Typed value → host-staged 1-D uint8 bytes (little-endian bitcast,
    identical layout to :func:`~repro.core.globmem.to_bytes`).

    Puts stage their payload on the HOST at initiation so that flush
    can assemble one flat buffer with plain ``memcpy`` and ship it in a
    single host→device transfer — instead of one device bitcast per
    enqueue plus an eager concatenate chain at flush.
    """
    arr = np.asarray(value)
    canon = jax.dtypes.canonicalize_dtype(arr.dtype)
    if arr.dtype != canon:
        # mirror jnp.asarray: Python floats/ints arrive as 64-bit numpy
        # dtypes but the heap's byte layout is the canonical (32-bit
        # unless x64 is enabled) one the device path always used
        arr = arr.astype(canon)
    arr = np.ascontiguousarray(arr).reshape(-1)
    if arr.dtype != np.uint8:
        arr = arr.view(np.uint8)
    return arr


def _host_decode(raw: np.ndarray, shape: Tuple[int, ...], dtype
                 ) -> np.ndarray:
    """Inverse of :func:`_to_host_bytes` on a host byte window."""
    dt = jnp.dtype(dtype)
    return raw[: nbytes_of(shape, dt)].copy().view(dt).reshape(shape)

# --------------------------------------------------------------------------
# Request handles (paper: MPI_Rput/Rget handles + dart_wait/test[all])
# --------------------------------------------------------------------------


def _arr_done(a) -> bool:
    """is_deleted-or-is_ready, tolerating a flush donating the buffer
    BETWEEN the two probes (the TOCTOU a concurrent flusher opens up):
    donated ⇒ a successor consumed it ⇒ complete by program order."""
    try:
        return a.is_deleted() or a.is_ready()
    except Exception as e:  # noqa: BLE001 - narrow on message below
        if "deleted" in str(e) or "donated" in str(e):
            return True
        raise


def _block_ready(arrays) -> None:
    """Per-array ``block_until_ready`` with the same donation-race
    tolerance as :func:`_arr_done` — a batched
    ``jax.block_until_ready(list)`` would raise on a buffer donated
    after the caller's ``is_deleted`` filter ran."""
    for a in arrays:
        try:
            if not a.is_deleted():
                a.block_until_ready()
        except Exception as e:  # noqa: BLE001 - narrow on message below
            if "deleted" in str(e) or "donated" in str(e):
                continue
            raise


class Handle:
    """A DART communication handle.

    Lifecycle (paper §III): ``queued`` (enqueued on a
    :class:`CommEngine`, not yet dispatched) → ``issued`` (dispatched
    to XLA, asynchronously in flight) → ``complete`` (buffers ready).
    Handles constructed directly from arrays — the immediate path used
    by collectives — are born ``issued``.

    If an array has been *donated* to a later op (e.g. a subsequent put
    to the same pool), it is treated as complete: XLA executes ops on a
    device in program order, so a successor consuming the buffer is
    ordered after this op, and all reads flow through the successor's
    heap state anyway (dataflow = the RMA unified model, docs/API.md).
    """

    def __init__(self, arrays: Tuple[jax.Array, ...] = (),
                 engine: "Optional[CommEngine]" = None):
        self.arrays = tuple(arrays)
        self._engine = engine
        self._issued = engine is None
        self._error: Optional[BaseException] = None

    @property
    def state(self) -> str:
        if self._error is not None:
            return "failed"
        if not self._issued:
            return "queued"
        if all(_arr_done(a) for a in self.arrays):
            return "complete"
        return "issued"

    def _resolve(self, arrays: Tuple[jax.Array, ...]) -> None:
        self.arrays = tuple(arrays)
        self._issued = True

    def _fail(self, error) -> None:
        """Mark the op as terminally **failed** (window destroyed
        before dispatch, target unit dead, retries exhausted, ...);
        wait/test raise the typed error.  Accepts an exception from
        the :class:`~repro.core.faults.DartError` ladder, or a bare
        message (wrapped in ``DartError``)."""
        if isinstance(error, str):
            error = DartError(error)
        self._error = error

    def _check_failed(self) -> None:
        if self._error is not None:
            raise self._error

    def _dropped_error(self) -> DartError:
        err = DartError(
            f"queued op ({self._lane_repr()}) was dropped before "
            "dispatch (engine cleared by dart_exit?)")
        err.poolid = getattr(self, "poolid", None)
        err.row = getattr(self, "row", None)
        return err

    def _lane_repr(self) -> str:
        return (f"pool {getattr(self, 'poolid', '?')}, "
                f"row {getattr(self, 'row', '?')}")

    def wait(self) -> None:
        self._check_failed()
        if not self._issued and self._engine is not None:
            # close only this handle's (pool, row) lane — the
            # MPI_Win_flush_local(rank, win) analogue; other targets
            # keep accumulating ops for their own coalesced flush.
            # flush() serializes on the engine lock, so if a concurrent
            # flusher (another thread, or the background progress
            # plane) already dispatched this op, ours is a no-op and
            # the _issued re-check below observes the transition.
            self._engine.flush(getattr(self, "poolid", None),
                               getattr(self, "row", None))
            self._check_failed()
            if not self._issued:
                raise self._dropped_error()
        _block_ready(self.arrays)

    def test(self) -> bool:
        self._check_failed()
        if not self._issued:
            return False
        return all(_arr_done(a) for a in self.arrays)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Handle(state={self.state}, n_arrays={len(self.arrays)})"


class _GatherBatch:
    """One coalesced get dispatch: the ``(k, seg)`` pad-to-bucket byte
    windows every handle of the run shares.  The device→host copy is
    made ONCE, lazily, on the first ``value()``; per-op typed decoding
    is then pure host work (no per-op jitted slice/bitcast launches —
    the whole run stays inside the single counted dispatch)."""

    __slots__ = ("raws", "_host")

    def __init__(self, raws: jax.Array):
        self.raws = raws
        self._host: Optional[np.ndarray] = None

    def host(self) -> np.ndarray:
        if self._host is None:
            self._host = np.asarray(self.raws)
        return self._host


class GetHandle(Handle):
    """Handle of a queued get; ``value()`` flushes and returns the
    typed result (identical bytes to the blocking path)."""

    def __init__(self, shape: Tuple[int, ...], dtype,
                 engine: "CommEngine"):
        super().__init__((), engine)
        self.shape = tuple(shape)
        self.dtype = dtype
        self._value: Optional[jax.Array] = None
        self._batch: Optional[_GatherBatch] = None
        self._batch_idx = 0

    def _resolve_gather(self, batch: _GatherBatch, idx: int) -> None:
        self._batch = batch
        self._batch_idx = idx
        self._resolve((batch.raws,))

    def value(self) -> jax.Array:
        self.wait()
        if self._value is None and self._batch is not None:
            self._value = jnp.asarray(_host_decode(
                self._batch.host()[self._batch_idx], self.shape,
                self.dtype))
        if self._value is None:
            raise self._dropped_error()
        return self._value


def dart_wait(handle: Handle) -> None:
    handle.wait()


def dart_test(handle: Handle) -> bool:
    return handle.test()


def dart_waitall(handles: Sequence[Handle]) -> None:
    # group queued handles by (engine, pool) and flush each pool's
    # UNION of target lanes once: the whole batch coalesces into the
    # minimal number of dispatches (a per-handle lane flush would split
    # it N ways for zero benefit — every listed lane completes here
    # anyway), while untargeted lanes keep accumulating their epochs
    lanes: Dict = {}
    for h in handles:
        h._check_failed()
        if not h._issued and h._engine is not None:
            key = (h._engine, getattr(h, "poolid", None))
            row = getattr(h, "row", None)
            if key not in lanes:
                lanes[key] = None if row is None else {row}
            elif lanes[key] is not None:
                if row is None:
                    lanes[key] = None        # unknown lane: whole pool
                else:
                    lanes[key].add(row)
    for (engine, poolid), rows in lanes.items():
        engine.flush(poolid, rows)
    for h in handles:
        if not h._issued and h._engine is not None:
            # The lane scan above is a racy snapshot: a concurrent
            # flusher (another thread, the progress plane) may have
            # issued this handle between the scan and here — or may
            # even have been mid-flush while we scanned, so OUR flush
            # of its lane found nothing.  Never raise off the stale
            # scan; wait() re-flushes only the handle's own lane (a
            # no-op if it was issued meanwhile, serialized by the
            # engine lock) and raises the lane-named "dropped" error
            # only when the op is truly gone from a flushed lane.
            h._check_failed()
            h.wait()
    _block_ready([a for h in handles for a in h.arrays])


def dart_testall(handles: Sequence[Handle]) -> bool:
    return all(h.test() for h in handles)


# --------------------------------------------------------------------------
# Jitted substrate kernels (the "pure MPI" ops the runtime wraps).
# Shapes are static per (nbytes,) so re-dispatches hit the jit cache.
# --------------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=0, static_argnums=())
def _arena_write(arena: jax.Array, row: jax.Array, off: jax.Array,
                 payload: jax.Array) -> jax.Array:
    return jax.lax.dynamic_update_slice(arena, payload[None, :], (row, off))


@functools.partial(jax.jit, static_argnums=(3,))
def _arena_read(arena: jax.Array, row: jax.Array, off: jax.Array,
                nbytes: int) -> jax.Array:
    return jax.lax.dynamic_slice(arena, (row, off), (1, nbytes))[0]


# Batched (coalesced-run) dispatch goes through the shape-stable
# DispatchPlan layer instead: repro.kernels.segmented_copy buckets the
# run length and segment size to powers of two, packs rows/offs/lens/
# starts into ONE (k, 4) int32 descriptor array, and serves every epoch
# from a small cached family of compiled segmented scatter/gather
# kernels (XLA 'ref' or hand-tiled Pallas) — see CommEngine.


# --------------------------------------------------------------------------
# Global-pointer dereference (paper §IV.B.4)
# --------------------------------------------------------------------------


def deref(heap: SymmetricHeap, teams_by_slot, gptr: GlobalPtr
          ) -> Tuple[int, int, int]:
    """gptr → (poolid, row, offset).

    Collective pointers: segid is the owning team's teamlist slot; the
    absolute unitid is translated to the team-relative id, which indexes
    the team pool's rows.  The pool itself is resolved through the
    heap's :class:`~repro.core.globmem.WindowRegistry` (teamid → live
    PoolMeta) — the binding DART-MPI keeps between a team and its MPI
    window object.  Slots are reused after ``dart_team_destroy``
    (§IV.B.2) while pool ids grow monotonically, so any slot↔pool
    arithmetic would route a recreated team's pointers at a dropped (or
    worse, a foreign) pool; the registry makes the reuse case correct by
    construction.  Non-collective pointers address the WORLD pool
    directly by absolute unitid — "trivially dereferenced without the
    unit translations" (paper §IV.B.4).
    """
    if gptr.is_collective:
        team = teams_by_slot[gptr.segid]
        rel = team.myid(gptr.unitid)
        if rel < 0:
            raise KeyError(
                f"unit {gptr.unitid} is not a member of team {team.teamid}")
        meta = heap.windows.lookup(team.teamid)
        return meta.poolid, rel, gptr.addr
    return WORLD_POOLID, gptr.unitid, gptr.addr


#: poolid of the pre-reserved non-collective WORLD pool (reserved first
#: at dart_init, so it is always 0).
WORLD_POOLID = 0


# --------------------------------------------------------------------------
# The non-blocking engine: epoch-scoped pending-op queue + coalesced flush
# --------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class _PendingPut:
    poolid: int
    row: int
    off: int
    payload: np.ndarray         # 1-D uint8, host-staged at initiation
    handle: Handle
    ts: float = 0.0             # monotonic enqueue time (progress plane)
    stride: int = 0             # byte distance between strided segments
    count: int = 1              # segments (1 = contiguous)
    unit: int = -1              # absolute target unitid (fault plane)


@dataclasses.dataclass(eq=False)
class _PendingGet:
    poolid: int
    row: int
    off: int
    nbytes: int
    handle: GetHandle
    ts: float = 0.0
    stride: int = 0
    count: int = 1
    unit: int = -1


@dataclasses.dataclass(eq=False)
class _PendingAcc:
    """A queued element-wise accumulate (``MPI_Accumulate`` /
    ``MPI_Get_accumulate``): read-modify-write at the target inside
    the same epoch/flush discipline as puts.  ``fetch`` marks the
    get-accumulate form, whose handle yields the pre-update value."""
    poolid: int
    row: int
    off: int
    payload: np.ndarray         # 1-D uint8, host-staged at initiation
    op: str
    dtype: str                  # canonical dtype name (part of run key)
    fetch: bool
    handle: Handle
    ts: float = 0.0
    stride: int = 0
    count: int = 1
    unit: int = -1


def _check_strided(off: int, total: int, stride: int, count: int,
                   pool_bytes: int, what: str) -> Tuple[int, int, int]:
    """Validate a (possibly strided) op's geometry at initiation and
    return ``(seg_len, stride, count)`` normalized so contiguous ops
    are always ``(total, 0, 1)``.

    ``total`` bytes split into ``count`` equal segments placed
    ``stride`` bytes apart.  ``stride >= seg_len`` is required for
    ``count > 1``: segments of one op may never self-overlap, which is
    what licenses the vectorized unique-index scatter to treat every
    lane of a descriptor as a distinct arena byte."""
    count = int(count)
    stride = int(stride)
    if count < 1:
        raise ValueError(f"{what}: count must be >= 1, got {count}")
    if total % count:
        raise ValueError(
            f"{what}: {total} payload bytes do not split into {count} "
            "equal segments")
    seg_len = total // count
    if count == 1:
        stride = 0          # canonical contiguous form
    else:
        if stride < seg_len:
            raise ValueError(
                f"{what}: stride ({stride} B) must be >= the segment "
                f"length ({seg_len} B) — overlapping segments of one "
                "op are not addressable")
    span = off + (count - 1) * stride + seg_len if total else off
    if span > pool_bytes:
        raise ValueError(f"{what} overruns the target allocation's pool")
    return seg_len, stride, count


class CommEngine:
    """Epoch-scoped pending-op queue over a heap-state holder.

    ``holder`` is any object with a mutable ``state: HeapState``
    attribute (normally the :class:`repro.core.runtime.DartContext`).
    Ops enqueue with pointer translation + bounds checks done eagerly
    (initiation, paper DTIT); ``flush`` closes the epoch by dispatching
    coalesced runs and bumping ``epoch``.

    Instrumentation:

    * ``dispatch_count`` — jitted kernel launches issued by this engine
      (the quantity the coalescing is meant to minimize).
    * ``ops_enqueued`` / ``ops_coalesced`` — totals; ``ops_coalesced``
      counts ops that shared a dispatch with at least one neighbour.
    * ``compile_count`` / ``plan_cache_hits`` — DispatchPlan cache
      misses (each builds + compiles one bucketed kernel) vs hits.  A
      warm steady state must show hits only; tests assert
      ``compile_count`` stays flat across varying-size epochs.

    ``impl`` selects the batched-kernel implementation (matching
    :mod:`repro.kernels.ops`): ``'ref'`` = XLA segmented scatter/
    gather, ``'pallas'`` = the hand-tiled descriptor-grid kernel,
    ``'auto'`` = pallas on TPU, ref elsewhere.  Runs whose descriptors
    fail the Pallas window precondition fall back to ref per-dispatch,
    so the choice never changes semantics.

    **Thread safety**: ``lock`` (reentrant) guards ``_pending``, the
    counters, and the holder-state swap inside ``flush`` — submitters,
    waiters, and the background progress plane may run concurrently.
    External readers of ``holder.state`` (heap atomics, shm views,
    collectives) must take the same lock: the batched kernels donate
    the arena, so an unserialized raw read can observe a deleted
    buffer mid-flush.
    """

    def __init__(self, holder=None, impl: str = "auto"):
        self._holder = holder
        self._pending: List = []        # program order across pools
        #: serializes queue mutation, counters, and holder.state swaps
        #: (reentrant: flush may be re-entered from locked callers)
        self.lock = threading.RLock()
        self._on_enqueue: Optional[Callable[[], None]] = None
        self.epoch = 0
        self.dispatch_count = 0
        self.ops_enqueued = 0
        self.ops_coalesced = 0
        self.compile_count = 0
        self.plan_cache_hits = 0
        # -- shm plane (repro.core.shm; docs/API.md "Shared-memory
        # plane") ------------------------------------------------------
        #: locked host-side writes routed through the shm window (each
        #: one a put that cost ZERO jitted dispatches)
        self.shm_puts = 0
        #: collectives served as memcpy loops through the shm window
        self.shm_collective_ops = 0
        #: poolid -> jitted READ outputs (gather / fetch-accumulate
        #: batches) dispatched against that pool's arena and possibly
        #: still in flight.  An in-place shm write must not mutate an
        #: arena a dispatched-but-unmaterialized read is still sourcing
        #: from, so the shm plane blocks + clears a pool's fences
        #: before writing (_drain_read_fences).  Bounded: draining
        #: clears, and the recorder caps the per-pool backlog.
        self._read_fences: Dict[int, List[jax.Array]] = {}
        # -- fault plane (docs/API.md "Failure model & fault plane") ----
        #: attached injector (None = fault-free: zero-overhead dispatch)
        self.faults: Optional[FaultPlane] = None
        #: absolute unitids declared dead — enqueues fail fast
        self.dead_units: Set[int] = set()
        #: (pool, row) -> the DartError that killed the lane; enqueues
        #: to a failed lane fail fast until clear_lane()
        self.failed_lanes: Dict[Tuple[int, int], DartError] = {}
        # retry/deadline knobs (DartConfig overrides these defaults)
        self.retry_limit = 3            # retries after the first attempt
        self.retry_base_s = 0.001       # backoff = base * 2^retry
        self.retry_max_s = 0.05         # backoff cap
        self.flush_deadline_s: Optional[float] = None   # None = no deadline
        # deterministic jitter stream (differential chaos replays need
        # the backoff schedule reproducible, like everything else)
        self._retry_rng = random.Random(0xDA27)
        # fault counters (fault_stats())
        self.retries = 0
        self.retries_exhausted = 0
        self.flush_timeouts = 0
        self.at_most_once_aborts = 0
        self.failed_runs = 0
        self.enqueue_rejections = 0
        if impl == "auto":
            impl = "pallas" if jax.default_backend() == "tpu" else "ref"
        self.impl = impl

    def bind(self, holder) -> None:
        self._holder = holder

    # -- fault plane -----------------------------------------------------
    def attach_faults(self, plane: Optional[FaultPlane]) -> None:
        """Attach (or detach, with None) a fault injector.  With no
        plane attached the dispatch path takes the historical zero-
        overhead route — no gates, no retry loop."""
        with self.lock:
            self.faults = plane

    def mark_unit_dead(self, unit: int, reason: str = "") -> int:
        """Declare an absolute unit dead: every op queued against it
        fails with :class:`UnitFailedError` and subsequent enqueues to
        it fail fast.  Surviving lanes are untouched — their queued
        epochs keep flushing.  Returns the number of queued ops
        doomed."""
        with self.lock:
            return self._mark_unit_dead_locked(unit, reason)

    def _mark_unit_dead_locked(self, unit: int, reason: str = "") -> int:
        if unit in self.dead_units:
            return 0
        self.dead_units.add(unit)
        doomed = [op for op in self._pending
                  if getattr(op, "unit", -1) == unit]
        if doomed:
            self._pending = [op for op in self._pending
                             if getattr(op, "unit", -1) != unit]
            err = UnitFailedError(
                f"unit {unit} declared dead"
                f"{' (' + reason + ')' if reason else ''} with this op "
                "still queued")
            err.unit = unit
            for op in doomed:
                op.handle._fail(err)
        return len(doomed)

    def revive_unit(self, unit: int) -> None:
        """Clear a unit's dead mark (elastic re-admission); already-
        failed handles stay failed."""
        with self.lock:
            self.dead_units.discard(unit)

    def clear_lane(self, poolid: int, row: int) -> Optional[DartError]:
        """Clear a failed lane so new enqueues flow again; returns the
        error the lane carried (None if it was not failed)."""
        with self.lock:
            return self.failed_lanes.pop((poolid, row), None)

    def _precheck_enqueue(self, poolid: int, row: int,
                          unit: int) -> None:
        """Enqueue-boundary fault hook + fail-fast checks.  Called
        under the engine lock before appending a pending op: polls the
        injector's poison/unit-death schedule, then rejects ops bound
        for dead units or failed lanes with the recorded typed error."""
        if self.faults is not None:
            for spec in self.faults.poll_enqueue(poolid, row, unit):
                if spec.kind == "unit_dead":
                    dead = unit if spec.unit is None else spec.unit
                    self._mark_unit_dead_locked(dead,
                                                reason="fault injection")
                else:                                   # poison
                    err = DartError(
                        f"lane (pool {poolid}, row {row}) poisoned by "
                        "fault injection")
                    err.poolid, err.row = poolid, row
                    self.failed_lanes[(poolid, row)] = err
        if unit in self.dead_units:
            self.enqueue_rejections += 1
            err = UnitFailedError(
                f"unit {unit} is dead; op rejected at enqueue "
                f"(lane: pool {poolid}, row {row})")
            err.unit, err.poolid, err.row = unit, poolid, row
            raise err
        lane_err = self.failed_lanes.get((poolid, row))
        if lane_err is not None:
            self.enqueue_rejections += 1
            raise lane_err

    def _check_lane_live(self, poolid: int, row: int, unit: int) -> None:
        """Passive (no injector poll) dead-unit / failed-lane fail-fast
        — the shm plane re-checks a lane AFTER its ordering flush ran:
        if a queued op on the lane just failed, the host write behind
        it must not apply (program order), but the op already paid its
        one ``poll_enqueue`` in :meth:`_precheck_enqueue`."""
        if unit in self.dead_units:
            self.enqueue_rejections += 1
            err = UnitFailedError(
                f"unit {unit} is dead; shm write rejected "
                f"(lane: pool {poolid}, row {row})")
            err.unit, err.poolid, err.row = unit, poolid, row
            raise err
        lane_err = self.failed_lanes.get((poolid, row))
        if lane_err is not None:
            self.enqueue_rejections += 1
            raise lane_err

    # -- shm-plane read fences ------------------------------------------

    def _record_read_fence(self, poolid: int, arr) -> None:
        """Under the engine lock: remember a jitted read's output so an
        shm write to the pool can block on it before mutating the
        arena in place.  Caps the backlog (pure-engine workloads never
        drain) by blocking + dropping the oldest entries."""
        fences = self._read_fences.setdefault(poolid, [])
        fences.append(arr)
        if len(fences) > 64:
            drop = fences[: len(fences) - 64]
            del fences[: len(fences) - 64]
            _block_ready(drop)

    def _drain_read_fences(self, poolid: int) -> None:
        """Under the engine lock: block until every recorded jitted
        read of the pool's arena has materialized, then forget them —
        after this an in-place host write cannot race a reader."""
        fences = self._read_fences.pop(poolid, None)
        if fences:
            _block_ready(fences)

    def fault_stats(self) -> Dict[str, object]:
        """Process-wide fault counters: the engine's retry/abort/
        rejection totals plus (when attached) the injector's own."""
        with self.lock:
            s: Dict[str, object] = {
                "retries": self.retries,
                "retries_exhausted": self.retries_exhausted,
                "flush_timeouts": self.flush_timeouts,
                "at_most_once_aborts": self.at_most_once_aborts,
                "failed_runs": self.failed_runs,
                "enqueue_rejections": self.enqueue_rejections,
                "dead_units": sorted(self.dead_units),
                "failed_lanes": sorted(self.failed_lanes),
            }
            plane = self.faults
        if plane is not None:
            s["injector"] = plane.stats()
        return s

    def set_progress_notifier(self, cb: Optional[Callable[[], None]]
                              ) -> None:
        """Register (or clear) the enqueue callback the progress plane
        uses to wake its drain thread.  Called OUTSIDE the engine lock
        so the plane's condition variable never nests inside it."""
        self._on_enqueue = cb

    def _notify_enqueue(self) -> None:
        cb = self._on_enqueue
        if cb is not None:
            cb()

    def _note_plan(self, hit: bool) -> None:
        if hit:
            self.plan_cache_hits += 1
        else:
            self.compile_count += 1

    def _pick_impl(self, desc: np.ndarray, seg: int,
                   pool_bytes: int) -> str:
        if self.impl == "pallas" and _sc.pallas_ok(desc, seg, pool_bytes):
            return "pallas"
        return "ref"

    # -- enqueue (initiation) -------------------------------------------
    def put(self, heap: SymmetricHeap, teams_by_slot, gptr: GlobalPtr,
            value, *, stride: int = 0, count: int = 1) -> Handle:
        """Queue a put of the value's bytes at the target.  With
        ``count > 1`` the payload splits into ``count`` equal segments
        landing ``stride`` bytes apart (a strided run — ONE descriptor,
        ONE dispatch share, never one op per segment)."""
        poolid, row, off = deref(heap, teams_by_slot, gptr)
        payload = _to_host_bytes(value)
        stride, count = self._check_geom(
            "put", heap, poolid, off, int(payload.size), stride, count)
        h = Handle((), engine=self)
        h.poolid = poolid
        h.row = row
        with self.lock:
            self._precheck_enqueue(poolid, row, gptr.unitid)
            self._pending.append(_PendingPut(poolid, row, off, payload,
                                             h, time.monotonic(),
                                             stride=stride, count=count,
                                             unit=gptr.unitid))
            self.ops_enqueued += 1
        self._notify_enqueue()
        return h

    def get(self, heap: SymmetricHeap, teams_by_slot, gptr: GlobalPtr,
            shape: Tuple[int, ...], dtype, *, stride: int = 0,
            count: int = 1) -> GetHandle:
        """Queue a get of ``shape``/``dtype`` from the target; with
        ``count > 1`` the bytes are gathered from ``count`` equal
        segments ``stride`` bytes apart and returned densely packed in
        the requested shape."""
        poolid, row, off = deref(heap, teams_by_slot, gptr)
        n = nbytes_of(shape, dtype)
        stride, count = self._check_geom(
            "get", heap, poolid, off, n, stride, count)
        h = GetHandle(shape, dtype, engine=self)
        h.poolid = poolid
        h.row = row
        with self.lock:
            self._precheck_enqueue(poolid, row, gptr.unitid)
            self._pending.append(_PendingGet(poolid, row, off, n, h,
                                             time.monotonic(),
                                             stride=stride, count=count,
                                             unit=gptr.unitid))
            self.ops_enqueued += 1
        self._notify_enqueue()
        return h

    def _check_geom(self, what: str, heap: SymmetricHeap, poolid: int,
                    off: int, total: int, stride: int, count: int
                    ) -> Tuple[int, int]:
        _, stride, count = _check_strided(
            off, total, stride, count, heap.pools[poolid].pool_bytes,
            what)
        return stride, count

    def _stage_acc(self, heap: SymmetricHeap, teams_by_slot,
                   gptr: GlobalPtr, value, op: str, stride: int,
                   count: int):
        """Shared accumulate initiation: deref + canonicalize + the
        alignment/bounds checks the RMW kernels rely on."""
        if op not in _sc.REDUCE_OPS:
            raise ValueError(f"unknown reduction op {op!r} "
                             f"(supported: {sorted(_sc.REDUCE_OPS)})")
        poolid, row, off = deref(heap, teams_by_slot, gptr)
        arr = np.asarray(value)
        canon = jax.dtypes.canonicalize_dtype(arr.dtype)
        if arr.dtype != canon:
            arr = arr.astype(canon)
        dt = jnp.dtype(canon)
        payload = _to_host_bytes(arr)     # same staging rule as puts
        pool_bytes = heap.pools[poolid].pool_bytes
        if off % dt.itemsize or pool_bytes % dt.itemsize:
            raise ValueError(
                f"accumulate of {dt} needs an element-aligned offset "
                f"and pool (off={off}, pool_bytes={pool_bytes})")
        seg_len, stride, count = _check_strided(
            off, int(payload.size), stride, count, pool_bytes,
            "accumulate")
        if seg_len % dt.itemsize or stride % dt.itemsize:
            raise ValueError(
                f"strided accumulate of {dt} needs element-aligned "
                f"segment length and stride (seg={seg_len}, "
                f"stride={stride})")
        return poolid, row, off, arr, payload, dt, stride, count

    def accumulate(self, heap: SymmetricHeap, teams_by_slot,
                   gptr: GlobalPtr, value, op: str = "sum", *,
                   stride: int = 0, count: int = 1) -> Handle:
        """Queued element-wise accumulate at the target
        (``MPI_Accumulate``): enqueues like ``put``; same-op runs
        coalesce into one segmented read-modify-write dispatch at
        flush — even overlapping ones (the ops commute), while
        mixed-op or accumulate-vs-put overlap splits the run in queue
        order (last-writer-wins preserved run-by-run)."""
        poolid, row, off, _, payload, dt, stride, count = self._stage_acc(
            heap, teams_by_slot, gptr, value, op, stride, count)
        h = Handle((), engine=self)
        h.poolid = poolid
        h.row = row
        with self.lock:
            self._precheck_enqueue(poolid, row, gptr.unitid)
            self._pending.append(_PendingAcc(poolid, row, off, payload,
                                             op, str(dt), False, h,
                                             time.monotonic(),
                                             stride=stride, count=count,
                                             unit=gptr.unitid))
            self.ops_enqueued += 1
        self._notify_enqueue()
        return h

    def get_accumulate(self, heap: SymmetricHeap, teams_by_slot,
                       gptr: GlobalPtr, value, op: str = "sum", *,
                       stride: int = 0, count: int = 1) -> GetHandle:
        """Queued fetch-and-accumulate (``MPI_Get_accumulate``):
        ``handle.value()`` flushes and yields the target's value from
        *before* this op applied.  Byte-disjoint same-op fetches share
        one fused dispatch; overlap splits the run so every fetched
        value matches the sequential order."""
        poolid, row, off, arr, payload, dt, stride, count = self._stage_acc(
            heap, teams_by_slot, gptr, value, op, stride, count)
        h = GetHandle(arr.shape, dt, engine=self)
        h.poolid = poolid
        h.row = row
        with self.lock:
            self._precheck_enqueue(poolid, row, gptr.unitid)
            self._pending.append(_PendingAcc(poolid, row, off, payload,
                                             op, str(dt), True, h,
                                             time.monotonic(),
                                             stride=stride, count=count,
                                             unit=gptr.unitid))
            self.ops_enqueued += 1
        self._notify_enqueue()
        return h

    def pending_ops(self, poolid: Optional[int] = None,
                    row: Optional[int] = None) -> int:
        with self.lock:
            if poolid is None:
                return len(self._pending)
            return sum(1 for op in self._pending if op.poolid == poolid
                       and (row is None or op.row == row))

    def lane_stats(self) -> Dict[Tuple[int, int], Tuple[int, int, float]]:
        """Snapshot of the pending queue grouped by ``(pool, row)``
        lane: ``{lane: (ops, bytes, oldest_enqueue_ts)}``.  The
        progress plane's watermark/idle-deadline decisions key off
        this; ops are in queue order, so the first op seen per lane is
        its oldest."""
        with self.lock:
            stats: Dict[Tuple[int, int], List] = {}
            for op in self._pending:
                key = (op.poolid, op.row)
                n = _op_nbytes(op)
                s = stats.get(key)
                if s is None:
                    stats[key] = [1, n, op.ts]
                else:
                    s[0] += 1
                    s[1] += n
            return {k: (v[0], v[1], v[2]) for k, v in stats.items()}

    # -- flush (epoch close) --------------------------------------------
    def flush(self, poolid: Optional[int] = None,
              row=None) -> HeapState:
        """Dispatch pending ops in program order: all of them, one
        pool's, or — the ``MPI_Win_flush_local(rank, win)`` analogue —
        one ``(pool, row)`` target lane (``row`` may also be a
        collection of rows: the union of lanes flushes as one epoch, so
        a batch spanning targets still coalesces).

        Runs of same-pool ops of one kind are coalesced into one batched
        jitted dispatch; mixed payload sizes share a dispatch when their
        byte ranges are disjoint (:func:`_coalesced_runs`).  Ops on
        distinct pools touch distinct arrays, and ops on distinct rows
        of one pool touch disjoint per-unit partitions, so a per-pool or
        per-target flush cannot reorder visible effects.

        The whole epoch close — queue selection, dispatch (which
        donates the arenas), handle resolution, and the holder-state
        swap — runs under the engine lock, so concurrent flushes
        serialize and no thread can observe a half-donated state.

        **Failure isolation** (docs/API.md "Failure model"): a run
        whose dispatch fails terminally (retries exhausted, deadline,
        at-most-once abort) fails *its own* handles with the typed
        error and marks its lanes failed — later ops on those lanes in
        this epoch fail too (program order: op N dropped ⇒ op N+1 must
        not apply), while runs on surviving lanes keep dispatching.
        ``flush`` itself never raises for an injected fault; waiters
        see the error through ``wait()``/``test()``.
        """
        with self.lock:
            if poolid is None:
                todo, rest = self._pending, []
            else:
                rows = (None if row is None else
                        set(row) if isinstance(row, (set, frozenset,
                                                     list, tuple))
                        else {row})

                def _sel(op):
                    return op.poolid == poolid and (rows is None
                                                    or op.row in rows)
                todo = [op for op in self._pending if _sel(op)]
                rest = [op for op in self._pending if not _sel(op)]
            if not todo:
                return self._holder.state
            state = copy_state(self._holder.state)
            failed_now: Set[Tuple[int, int]] = set()
            for run, disjoint in _coalesced_runs(todo):
                pid = run[0].poolid
                if failed_now:
                    # program order on a lane that just failed: fail
                    # the lane's later ops instead of dispatching them
                    # past the hole the dropped run left
                    live = []
                    for op in run:
                        lane = (op.poolid, op.row)
                        if lane in failed_now:
                            op.handle._fail(self.failed_lanes[lane])
                        else:
                            live.append(op)
                    if not live:
                        continue
                    run = live
                try:
                    if isinstance(run[0], _PendingPut):
                        cell = {"arena": state[pid]}

                        def _put(cell=cell, run=run, disjoint=disjoint):
                            cell["arena"] = self._dispatch_put_run(
                                cell["arena"], run, disjoint)
                        try:
                            self._guarded("put", run, _put,
                                          retryable_post=True)
                        finally:
                            state[pid] = cell["arena"]
                        for op in run:
                            op.handle._resolve((state[pid],))
                    elif isinstance(run[0], _PendingAcc):
                        cell = {"arena": state[pid]}

                        def _acc(cell=cell, run=run, disjoint=disjoint):
                            cell["arena"] = self._dispatch_acc_run(
                                cell["arena"], run, disjoint)
                        try:
                            # at-most-once: a post-dispatch fault on an
                            # RMW run must never re-issue
                            self._guarded("gacc" if run[0].fetch
                                          else "acc", run, _acc,
                                          retryable_post=False)
                        finally:
                            state[pid] = cell["arena"]
                    else:
                        def _get(run=run, arena=state[pid]):
                            self._dispatch_get_run(arena, run)
                        self._guarded("get", run, _get,
                                      retryable_post=True)
                except DartError as e:
                    self.failed_runs += 1
                    lanes = {(op.poolid, op.row) for op in run}
                    for op in run:
                        op.handle._fail(e)
                    for lane in lanes:
                        self.failed_lanes[lane] = e
                    failed_now |= lanes
            self._pending = rest
            self._holder.state = state
            self.epoch += 1
            return state

    def _guarded(self, kind: str, run: Sequence, attempt: Callable[[], None],
                 retryable_post: bool) -> None:
        """Run one coalesced dispatch with fault gates + retry/deadline
        semantics.  ``attempt()`` performs one dispatch attempt,
        threading the arena through a caller-owned cell — critical for
        retry: the batched kernels DONATE the arena, so a retry after a
        post-dispatch fault re-applies the same packed descriptors to
        the attempt's *result* arena (idempotent for puts — the same
        bytes land at the same offsets — and for gets, which only
        read).  Accumulate runs pass ``retryable_post=False``: a fault
        after the RMW kernel ran aborts instead of re-issuing
        (at-most-once).

        Transient faults retry with exponential backoff + deterministic
        jitter up to ``retry_limit`` times, bounded by the per-flush
        ``flush_deadline_s``; exhaustion raises
        :class:`RetriesExhaustedError` / :class:`FlushTimeoutError`.
        With no injector attached this is a zero-overhead passthrough.
        """
        if self.faults is None:
            attempt()
            return
        # a coalesced run can span rows (one batched dispatch for many
        # lanes): consult the gate for EVERY distinct lane, and on a
        # terminal failure the whole run shares the dispatch's fate —
        # flush marks all its lanes failed.
        lanes = sorted({(op.poolid, op.row) for op in run})
        deadline = (None if self.flush_deadline_s is None
                    else time.monotonic() + self.flush_deadline_s)
        retries = 0
        while True:
            issued = False
            poolid, row = lanes[0]
            try:
                for poolid, row in lanes:
                    self.faults.dispatch_gate(kind, poolid, row, "pre")
                poolid, row = lanes[0]
                attempt()
                issued = True
                for poolid, row in lanes:
                    self.faults.dispatch_gate(kind, poolid, row, "post")
                return
            except TransientDispatchFault as e:
                e.poolid, e.row = poolid, row
                if issued and not retryable_post:
                    self.at_most_once_aborts += 1
                    err = DartError(
                        f"{kind} run on lane (pool {poolid}, row {row}) "
                        "faulted after dispatch; not retried "
                        "(at-most-once — re-issuing a read-modify-write "
                        "could double-apply it)")
                    err.poolid, err.row = poolid, row
                    raise err from e
                if retries >= self.retry_limit:
                    self.retries_exhausted += 1
                    err = RetriesExhaustedError(
                        f"{kind} run on lane (pool {poolid}, row {row}) "
                        f"still faulting after {retries} retries: {e}")
                    err.poolid, err.row = poolid, row
                    raise err from e
                backoff = min(self.retry_max_s,
                              self.retry_base_s * (1 << retries))
                backoff *= 0.5 + self._retry_rng.random()
                if (deadline is not None
                        and time.monotonic() + backoff > deadline):
                    self.flush_timeouts += 1
                    err = FlushTimeoutError(
                        f"flush deadline ({self.flush_deadline_s}s) "
                        f"exceeded retrying {kind} run on lane "
                        f"(pool {poolid}, row {row}): {e}")
                    err.poolid, err.row = poolid, row
                    raise err from e
                retries += 1
                self.retries += 1
                time.sleep(backoff)

    def drop_pool(self, poolid: int, reason: str = "",
                  teamid: Optional[int] = None) -> int:
        """Discard queued ops targeting ``poolid`` and fail their
        handles (the pool's window is being destroyed, so dispatching —
        or silently dropping — them would be wrong).  The failure is a
        typed :class:`~repro.core.globmem.WindowDestroyedError`
        carrying ``poolid`` (and ``teamid`` when the drop came from
        ``dart_team_destroy``).  Returns the number of ops dropped."""
        with self.lock:
            self._read_fences.pop(poolid, None)
            dropped = [op for op in self._pending if op.poolid == poolid]
            if not dropped:
                return 0
            self._pending = [op for op in self._pending
                             if op.poolid != poolid]
            err = WindowDestroyedError(
                f"window destroyed: pool {poolid} was dropped with "
                f"this op still queued"
                f"{' (' + reason + ')' if reason else ''}")
            err.poolid, err.teamid = poolid, teamid
            for op in dropped:
                op.handle._fail(err)
            return len(dropped)

    def _dispatch_put_run(self, arena: jax.Array,
                          run: Sequence[_PendingPut],
                          disjoint: bool = True) -> jax.Array:
        """One counted dispatch for the whole run: pack descriptors +
        flat payload on the host (one transfer each), then hit the
        cached bucketed plan — vectorized when the run's byte ranges
        are provably disjoint, the sequential in-order loop otherwise
        (overlapping uniform runs: last writer wins)."""
        self.dispatch_count += 1
        if len(run) > 1:
            self.ops_coalesced += len(run)
        desc, flat, seg = _sc.pack_descriptors(
            [op.row for op in run], [op.off for op in run],
            [int(op.payload.size) // op.count for op in run],
            [op.payload for op in run],
            strides=[op.stride for op in run],
            counts=[op.count for op in run])
        impl = self._pick_impl(desc, seg, int(arena.shape[1]))
        sseg, cb = (_sc.strided_buckets(desc, seg)
                    if impl == "pallas" else (None, None))
        fn, hit = _sc.scatter_plan(
            arena.shape, desc.shape[0], seg, flat.shape[0],
            ordered=not disjoint, impl=impl, sseg=sseg, cb=cb)
        self._note_plan(hit)
        return fn(arena, desc, flat)

    def _dispatch_acc_run(self, arena: jax.Array,
                          run: Sequence["_PendingAcc"],
                          disjoint: bool = True) -> jax.Array:
        """One counted dispatch for a same-(op, dtype) accumulate run:
        identity-padded descriptors + flat payload feed the segmented
        read-modify-write kernel — vectorized gather-combine-scatter
        when the run's byte ranges are provably disjoint, the ordered
        per-descriptor RMW loop otherwise (still one dispatch; the ops
        commute, so either order is the program-order result).  Fetch
        runs are byte-disjoint by the run rule and return every op's
        pre-update window from the same fused dispatch."""
        self.dispatch_count += 1
        if len(run) > 1:
            self.ops_coalesced += len(run)
        first = run[0]
        desc, flat, seg = _sc.pack_acc_descriptors(
            [op.row for op in run], [op.off for op in run],
            [int(op.payload.size) // op.count for op in run],
            [op.payload for op in run], first.op, first.dtype,
            strides=[op.stride for op in run],
            counts=[op.count for op in run])
        # strided RMW rides the ref kernels only: the Pallas accumulate
        # keeps its exact kb*seg identity-slot layout (contiguous runs)
        impl = ("ref" if any(op.count > 1 for op in run)
                else self._pick_impl(desc, seg, int(arena.shape[1])))
        fn, hit = _sc.accumulate_plan(
            arena.shape, desc.shape[0], seg, flat.shape[0],
            op=first.op, dtype=first.dtype, fetch=first.fetch,
            ordered=not disjoint, impl=impl)
        self._note_plan(hit)
        if first.fetch:
            arena, old = fn(arena, desc, flat)
            batch = _GatherBatch(old)
            self._record_read_fence(first.poolid, old)
            for i, op in enumerate(run):
                op.handle._resolve_gather(batch, i)
        else:
            arena = fn(arena, desc, flat)
            for op in run:
                op.handle._resolve((arena,))
        return arena

    def _dispatch_get_run(self, arena: jax.Array,
                          run: Sequence[_PendingGet]) -> None:
        """One counted dispatch for the whole run (uniform AND mixed
        sizes): a bucketed segmented gather returns every op's
        pad-to-bucket byte window; the typed decode happens on the
        host from ONE device→host copy, shared by the run
        (:class:`_GatherBatch`) — no per-op jitted slice/bitcast
        launches after the gather."""
        self.dispatch_count += 1
        if len(run) > 1:
            self.ops_coalesced += len(run)
        desc, _, seg = _sc.pack_descriptors(
            [op.row for op in run], [op.off for op in run],
            [op.nbytes // op.count for op in run],
            strides=[op.stride for op in run],
            counts=[op.count for op in run])
        impl = self._pick_impl(desc, seg, int(arena.shape[1]))
        sseg, cb = (_sc.strided_buckets(desc, seg)
                    if impl == "pallas" else (None, None))
        fn, hit = _sc.gather_plan(
            arena.shape, desc.shape[0], seg, impl=impl, sseg=sseg,
            cb=cb)
        self._note_plan(hit)
        batch = _GatherBatch(fn(arena, desc))
        self._record_read_fence(run[0].poolid, batch.raws)
        for i, op in enumerate(run):
            op.handle._resolve_gather(batch, i)

    @contextlib.contextmanager
    def epoch_scope(self, poolid: Optional[int] = None):
        """Explicit epoch as a ``with`` block (the typed front-end's
        ``ctx.epoch()``): ops enqueued inside stay queued; leaving the
        block closes the epoch with one coalesced flush — of everything,
        or of a single pool when ``poolid`` is given.  The flush runs
        even on error so no op is silently left queued."""
        try:
            yield self
        finally:
            self.flush(poolid)

    def clear(self) -> None:
        """Drop queued ops without dispatching (dart_exit teardown)."""
        with self.lock:
            self._pending = []
            self._read_fences.clear()


def _kind_key(op) -> Tuple:
    if isinstance(op, _PendingPut):
        return ("put", op.poolid)
    if isinstance(op, _PendingAcc):
        # accumulates coalesce only with the SAME (op, dtype, fetch?):
        # a mixed-op (or mixed-dtype) overlap is not commutative, so it
        # splits the run and dispatches in queue order — exactly the
        # last-writer-wins rule puts follow
        kind = "gacc" if op.fetch else "acc"
        return (kind, op.poolid, op.op, op.dtype)
    return ("get", op.poolid)


def _op_nbytes(op) -> int:
    if isinstance(op, _PendingPut) or isinstance(op, _PendingAcc):
        return int(op.payload.size)
    return op.nbytes


def _op_span(op) -> int:
    """Bytes of the op's *covering interval* ``[off, off + span)`` —
    for a strided op this includes the gaps between segments
    (``(count-1)*stride + seg_len``), a deliberately conservative
    overlap proxy: two interleaved strided ops whose bytes never
    collide still read as overlapping, which only demotes the run to
    the ordered kernel (or splits it) — always correct, never unsafe.
    Contiguous ops: span == nbytes, the historical rule unchanged."""
    n = _op_nbytes(op)
    if op.count <= 1:
        return n
    return (op.count - 1) * op.stride + n // op.count


class _RunMeta:
    """Bookkeeping for the run currently being grown: payload sizes,
    per-row byte intervals, and whether every recorded write range is
    pairwise *disjoint* — the proof the dispatcher uses to issue the
    run as one vectorized segmented update (disjoint) instead of the
    sequential in-order loop (overlapping).

    Intervals are kept per row as a *merged* sorted disjoint set
    (parallel ``starts``/``ends`` lists), so the disjointness query is
    a bisect against at most two neighbours — O(log k) per candidate
    instead of a linear scan over every recorded op.  Only put runs
    track intervals: reads commute, so a get run never needs the
    disjointness rule (a write would split the run by kind anyway).

    The bucketed flat-index kernels never read or write outside an
    op's exact byte range (masked lanes are dropped/filled, not
    clamped), so there is no pool-headroom constraint: mixed-size runs
    coalesce anywhere in the pool, including hard against its end.
    """

    __slots__ = ("kind", "sizes", "max_n", "disjoint", "intervals")

    def __init__(self, op, n: int):
        self.kind = _kind_key(op)
        self.sizes = {n}
        self.max_n = n
        self.disjoint = True
        # row -> (starts, ends): merged, sorted, pairwise-disjoint.
        # Tracked for puts and plain accumulates (the vectorized-vs-
        # ordered dispatch proof — accumulates never *split* on
        # overlap, they just demote to the ordered RMW loop) and for
        # fetch-accumulates (whose run rule *requires* disjointness so
        # the fused read-all-then-apply-all equals sequential order).
        self.intervals: Dict[int, Tuple[List[int], List[int]]] = {}
        if self.kind[0] in ("put", "acc", "gacc"):
            self._note(op.row, op.off, op.off + _op_span(op))

    def _note(self, row: int, off: int, end: int) -> None:
        starts, ends = self.intervals.setdefault(row, ([], []))
        i = bisect.bisect_right(starts, off)
        # absorb a left neighbour that reaches (or touches) us
        if i > 0 and ends[i - 1] >= off:
            i -= 1
            off = starts[i]
            end = max(end, ends[i])
            del starts[i], ends[i]
        # absorb every following interval we now cover
        while i < len(starts) and starts[i] <= end:
            end = max(end, ends[i])
            del starts[i], ends[i]
        starts.insert(i, off)
        ends.insert(i, end)

    def _disjoint(self, op, n: int) -> bool:
        row_ivs = self.intervals.get(op.row)
        if row_ivs is None:
            return True
        starts, ends = row_ivs
        end = op.off + _op_span(op)
        i = bisect.bisect_right(starts, op.off)
        if i > 0 and ends[i - 1] > op.off:
            return False
        return not (i < len(starts) and starts[i] < end)

    def can_extend(self, op, n: int) -> bool:
        if _kind_key(op) != self.kind:
            return False
        if self.kind[0] == "acc":
            # same-(op, dtype) accumulates commute: any mix of sizes
            # and overlaps shares ONE dispatch — an overlapping
            # extension just demotes it to the ordered RMW kernel
            return True
        if self.kind[0] == "gacc":
            # fetch-accumulate: each fetched value must equal what a
            # sequential execution would read, and the fused kernel
            # reads every window before applying any op — valid only
            # while the run stays byte-disjoint; overlap splits it
            return self._disjoint(op, n)
        if self.sizes == {n}:
            # uniform run: unconditional, exactly the pre-registry rule —
            # an overlapping extension just demotes the dispatch to the
            # ordered kernel, so even overlapping ranges keep
            # last-writer-wins
            return True
        # mixed-size extension (bucketed segmented dispatch): puts
        # require byte-range disjointness — overlapping writes stay in
        # separate, sequentially dispatched runs so program order is
        # preserved; gets commute, so they coalesce unconditionally
        return self.kind[0] != "put" or self._disjoint(op, n)

    def extend(self, op, n: int) -> None:
        self.sizes.add(n)
        self.max_n = max(self.max_n, n)
        if self.kind[0] in ("put", "acc"):
            if self.disjoint and not self._disjoint(op, n):
                self.disjoint = False
            self._note(op.row, op.off, op.off + _op_span(op))
        elif self.kind[0] == "gacc":
            self._note(op.row, op.off, op.off + _op_span(op))


def _coalesced_runs(ops: Sequence) -> List[Tuple[List, bool]]:
    """Split into maximal ``(run, disjoint)`` pairs, each run sharing
    one batched dispatch.

    An op extends the current run when it has the same kind and pool
    and either (a) the same payload size as a so-far-uniform run — the
    original coalescing rule — or (b) for mixed sizes, a byte range
    *disjoint* from every write already in the run.  Overlapping
    ranges of different sizes split the run, so dispatching runs in
    queue order preserves put/put and put/get program order (last
    writer wins, reads see prior writes), exactly like the blocking
    sequence.  ``disjoint`` reports whether every write range in the
    run is pairwise disjoint — the dispatcher's license to use the
    vectorized segmented kernel instead of the ordered loop.
    """
    runs: List[List] = []
    metas: List[_RunMeta] = []
    for op in ops:
        n = _op_nbytes(op)
        if runs and metas[-1].can_extend(op, n):
            runs[-1].append(op)
            metas[-1].extend(op, n)
        else:
            runs.append([op])
            metas.append(_RunMeta(op, n))
    return [(run, meta.disjoint) for run, meta in zip(runs, metas)]


# --------------------------------------------------------------------------
# Host-plane one-sided ops (immediate / functional path)
# --------------------------------------------------------------------------


def dart_put(state: HeapState, heap: SymmetricHeap, teams_by_slot,
             gptr: GlobalPtr, value) -> Tuple[HeapState, Handle]:
    """Non-blocking one-sided put (``dart_put``, paper §III).

    Returns the updated heap state and a handle.  The write is issued
    immediately (async dispatch); completion = handle.wait()/test().
    The engine-backed path in :mod:`repro.core.runtime` defers the
    dispatch instead (queued → flush-coalesced).
    """
    poolid, row, off = deref(heap, teams_by_slot, gptr)
    payload = to_bytes(jnp.asarray(value))
    meta = heap.pools[poolid]
    if off + payload.size > meta.pool_bytes:
        raise ValueError("put overruns the target allocation's pool")
    arena = _arena_write(state[poolid], jnp.int32(row), jnp.int32(off),
                         payload)
    new_state = copy_state(state)
    new_state[poolid] = arena
    return new_state, Handle((arena,))


def dart_put_blocking(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                      gptr: GlobalPtr, value) -> HeapState:
    """Blocking put: returns after local+remote completion (paper §III)."""
    new_state, h = dart_put(state, heap, teams_by_slot, gptr, value)
    h.wait()
    return new_state


def dart_get(state: HeapState, heap: SymmetricHeap, teams_by_slot,
             gptr: GlobalPtr, shape: Tuple[int, ...], dtype
             ) -> Tuple[jax.Array, Handle]:
    """Non-blocking one-sided get: returns (value-future, handle)."""
    poolid, row, off = deref(heap, teams_by_slot, gptr)
    n = nbytes_of(shape, dtype)
    meta = heap.pools[poolid]
    if off + n > meta.pool_bytes:
        raise ValueError("get overruns the target allocation's pool")
    raw = _arena_read(state[poolid], jnp.int32(row), jnp.int32(off), n)
    value = from_bytes(raw, shape, dtype)
    return value, Handle((value,))


def dart_get_blocking(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                      gptr: GlobalPtr, shape: Tuple[int, ...], dtype
                      ) -> jax.Array:
    value, h = dart_get(state, heap, teams_by_slot, gptr, shape, dtype)
    h.wait()
    return value


# --------------------------------------------------------------------------
# Device-plane (shard_map) one-sided ops — SPMD "shmem" style.
#
# These are called from inside ``shard_map`` bodies where ``arena_row``
# is this unit's (1, pool_bytes) row of a symmetric-heap pool and
# ``axis`` is the unit axis name.  Peers are specified *statically*
# (trace-time ints) for the ppermute fast path — on TPU this lowers to
# a point-to-point ICI DMA, i.e. a true one-sided put.
# --------------------------------------------------------------------------


def shmem_put(arena_row: jax.Array, value: jax.Array, offset,
              perm: Sequence[Tuple[int, int]], axis: str) -> jax.Array:
    """Every unit sends ``value`` along ``perm``; receivers store at
    ``offset`` (same offset everywhere — the aligned/symmetric property).

    Units not appearing as a destination in ``perm`` receive zeros and
    must not be considered written (mask accordingly at the call site or
    use a complete permutation).
    """
    payload = to_bytes(value)
    moved = jax.lax.ppermute(payload, axis, perm)
    return jax.lax.dynamic_update_slice(
        arena_row, moved[None, :], (jnp.int32(0), jnp.asarray(offset, jnp.int32)))


def shmem_get(arena_row: jax.Array, offset, nbytes: int,
              perm: Sequence[Tuple[int, int]], axis: str,
              shape: Tuple[int, ...], dtype) -> jax.Array:
    """One-sided get with static peers: fetch ``nbytes`` at ``offset``
    from the unit that maps to me under ``perm`` (src, dst) pairs."""
    raw = jax.lax.dynamic_slice(
        arena_row, (jnp.int32(0), jnp.asarray(offset, jnp.int32)),
        (1, nbytes))[0]
    fetched = jax.lax.ppermute(raw, axis, perm)
    return from_bytes(fetched, shape, dtype)


def shmem_get_dynamic(arena_row: jax.Array, offset, nbytes: int,
                      src_unit: jax.Array, axis: str,
                      shape: Tuple[int, ...], dtype,
                      axis_index_groups=None) -> jax.Array:
    """Dynamic-peer get: peer id is a traced scalar.

    Lowers to all_gather + one-hot row select.  Semantically exact;
    costs a team-wide gather of the addressed window, so the static
    ``shmem_get`` / Pallas RDMA path is preferred where the pattern is
    known at trace time (documented perf note, docs/API.md).
    """
    raw = jax.lax.dynamic_slice(
        arena_row, (jnp.int32(0), jnp.asarray(offset, jnp.int32)),
        (1, nbytes))[0]
    everyone = jax.lax.all_gather(raw, axis,
                                  axis_index_groups=axis_index_groups)
    n = everyone.shape[0]
    onehot = (jnp.arange(n, dtype=jnp.int32) ==
              jnp.asarray(src_unit, jnp.int32)).astype(jnp.uint8)
    picked = jnp.einsum("n,nb->b", onehot, everyone)
    return from_bytes(picked.astype(jnp.uint8), shape, dtype)


def shmem_halo_exchange(arena_row: jax.Array, left_val: jax.Array,
                        right_val: jax.Array, left_off, right_off,
                        axis: str, n_units: int,
                        wrap: bool = False) -> jax.Array:
    """Classic PGAS halo exchange built from two one-sided puts.

    Each unit puts ``right_val`` into its right neighbour at
    ``left_off`` (it arrives as the neighbour's *left* halo) and
    ``left_val`` into its left neighbour at ``right_off``.
    """
    def ring(delta):
        pairs = []
        for i in range(n_units):
            j = i + delta
            if wrap:
                pairs.append((i, j % n_units))
            elif 0 <= j < n_units:
                pairs.append((i, j))
        return pairs

    arena_row = shmem_put(arena_row, right_val, left_off, ring(+1), axis)
    arena_row = shmem_put(arena_row, left_val, right_off, ring(-1), axis)
    return arena_row
