"""DART one-sided communication (paper §III, §IV.B.5).

Two planes, mirroring how DART-MPI sits above MPI-3 RMA:

**Host plane** (single-controller, the analogue of the paper's
process-level API): ``dart_put/get`` dereference the global pointer
(flags → allocation kind, segid → team, absolute→relative unit
translation for collective pointers — §IV.B.4), then issue the
underlying substrate op.  The substrate here is XLA: a donated
``dynamic_update_slice`` on the sharded arena, which on a TPU mesh
compiles to a one-sided ICI DMA into the owning unit's HBM — the direct
analogue of ``MPI_Rput`` in a passive-target epoch.

Epochs: MPI requires RMA calls to sit inside an access epoch; DART opens
a shared epoch on every window at init/alloc time so users never see it
(§IV.B.5).  In XLA the "epoch" is the program region — conflict freedom
is guaranteed by dataflow, exactly the RMA *unified* memory model the
paper adopts.

Completion semantics (paper §III):

* blocking put/get return only after local *and* remote completion →
  we block on the updated arena / fetched value.
* non-blocking put/get return a :class:`Handle`; ``dart_wait``/
  ``dart_test`` map onto JAX async-dispatch completion
  (``block_until_ready`` / ``Array.is_ready``) — JAX's dispatch queue
  plays the role of MPI request handles.

**Device plane** (inside ``shard_map``; the analogue of what DASH's
compiled kernels do): ``shmem_put/get`` move bytes between unit rows
with ``lax.ppermute`` (static peers → point-to-point ICI DMA) or an
``all_gather`` + dynamic row-select (dynamic peers).  The Pallas RDMA
kernels in ``repro.kernels.rdma`` are the hand-tiled fast path for the
same semantics.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .globmem import (HeapState, SymmetricHeap, from_bytes, nbytes_of,
                      to_bytes)
from .gptr import GlobalPtr

# --------------------------------------------------------------------------
# Request handles (paper: MPI_Rput/Rget handles + dart_wait/test[all])
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Handle:
    """A DART communication handle over one or more in-flight arrays.

    If an array has been *donated* to a later op (e.g. a subsequent put
    to the same pool), it is treated as complete: XLA executes ops on a
    device in program order, so a successor consuming the buffer is
    ordered after this op, and all reads flow through the successor's
    heap state anyway (dataflow = the RMA unified model, DESIGN.md §2).
    """

    arrays: Tuple[jax.Array, ...]

    def wait(self) -> None:
        jax.block_until_ready([a for a in self.arrays
                               if not a.is_deleted()])

    def test(self) -> bool:
        return all(a.is_deleted() or a.is_ready() for a in self.arrays)


def dart_wait(handle: Handle) -> None:
    handle.wait()


def dart_test(handle: Handle) -> bool:
    return handle.test()


def dart_waitall(handles: Sequence[Handle]) -> None:
    jax.block_until_ready([a for h in handles for a in h.arrays
                           if not a.is_deleted()])


def dart_testall(handles: Sequence[Handle]) -> bool:
    return all(h.test() for h in handles)


# --------------------------------------------------------------------------
# Jitted substrate kernels (the "pure MPI" ops the runtime wraps).
# Shapes are static per (nbytes,) so re-dispatches hit the jit cache.
# --------------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=0, static_argnums=())
def _arena_write(arena: jax.Array, row: jax.Array, off: jax.Array,
                 payload: jax.Array) -> jax.Array:
    return jax.lax.dynamic_update_slice(arena, payload[None, :], (row, off))


@functools.partial(jax.jit, static_argnums=(3,))
def _arena_read(arena: jax.Array, row: jax.Array, off: jax.Array,
                nbytes: int) -> jax.Array:
    return jax.lax.dynamic_slice(arena, (row, off), (1, nbytes))[0]


# --------------------------------------------------------------------------
# Global-pointer dereference (paper §IV.B.4)
# --------------------------------------------------------------------------


def deref(heap: SymmetricHeap, teams_by_slot, gptr: GlobalPtr
          ) -> Tuple[int, int, int]:
    """gptr → (poolid, row, offset).

    Collective pointers: segid is the owning team's teamlist slot; the
    absolute unitid is translated to the team-relative id, which indexes
    the team pool's rows.  Non-collective pointers address the WORLD
    pool directly by absolute unitid — "trivially dereferenced without
    the unit translations" (paper §IV.B.4).
    """
    if gptr.is_collective:
        team = teams_by_slot[gptr.segid]
        rel = team.myid(gptr.unitid)
        if rel < 0:
            raise KeyError(
                f"unit {gptr.unitid} is not a member of team {team.teamid}")
        poolid = team_poolid(team)
        return poolid, rel, gptr.addr
    return WORLD_POOLID, gptr.unitid, gptr.addr


#: poolid of the pre-reserved non-collective WORLD pool (reserved first
#: at dart_init, so it is always 0).
WORLD_POOLID = 0


def team_poolid(team) -> int:
    """Teamlist slot → poolid.  Slot s keys pool s+1 (pool 0 = WORLD)."""
    return team.slot + 1


# --------------------------------------------------------------------------
# Host-plane one-sided ops
# --------------------------------------------------------------------------


def dart_put(state: HeapState, heap: SymmetricHeap, teams_by_slot,
             gptr: GlobalPtr, value) -> Tuple[HeapState, Handle]:
    """Non-blocking one-sided put (``dart_put``, paper §III).

    Returns the updated heap state and a handle.  The write is issued
    immediately (async dispatch); completion = handle.wait()/test().
    """
    poolid, row, off = deref(heap, teams_by_slot, gptr)
    payload = to_bytes(jnp.asarray(value))
    meta = heap.pools[poolid]
    if off + payload.size > meta.pool_bytes:
        raise ValueError("put overruns the target allocation's pool")
    arena = _arena_write(state[poolid], jnp.uint32(row), jnp.uint32(off),
                         payload)
    new_state = dict(state)
    new_state[poolid] = arena
    return new_state, Handle((arena,))


def dart_put_blocking(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                      gptr: GlobalPtr, value) -> HeapState:
    """Blocking put: returns after local+remote completion (paper §III)."""
    new_state, h = dart_put(state, heap, teams_by_slot, gptr, value)
    h.wait()
    return new_state


def dart_get(state: HeapState, heap: SymmetricHeap, teams_by_slot,
             gptr: GlobalPtr, shape: Tuple[int, ...], dtype
             ) -> Tuple[jax.Array, Handle]:
    """Non-blocking one-sided get: returns (value-future, handle)."""
    poolid, row, off = deref(heap, teams_by_slot, gptr)
    n = nbytes_of(shape, dtype)
    meta = heap.pools[poolid]
    if off + n > meta.pool_bytes:
        raise ValueError("get overruns the target allocation's pool")
    raw = _arena_read(state[poolid], jnp.uint32(row), jnp.uint32(off), n)
    value = from_bytes(raw, shape, dtype)
    return value, Handle((value,))


def dart_get_blocking(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                      gptr: GlobalPtr, shape: Tuple[int, ...], dtype
                      ) -> jax.Array:
    value, h = dart_get(state, heap, teams_by_slot, gptr, shape, dtype)
    h.wait()
    return value


# --------------------------------------------------------------------------
# Device-plane (shard_map) one-sided ops — SPMD "shmem" style.
#
# These are called from inside ``shard_map`` bodies where ``arena_row``
# is this unit's (1, pool_bytes) row of a symmetric-heap pool and
# ``axis`` is the unit axis name.  Peers are specified *statically*
# (trace-time ints) for the ppermute fast path — on TPU this lowers to
# a point-to-point ICI DMA, i.e. a true one-sided put.
# --------------------------------------------------------------------------


def shmem_put(arena_row: jax.Array, value: jax.Array, offset,
              perm: Sequence[Tuple[int, int]], axis: str) -> jax.Array:
    """Every unit sends ``value`` along ``perm``; receivers store at
    ``offset`` (same offset everywhere — the aligned/symmetric property).

    Units not appearing as a destination in ``perm`` receive zeros and
    must not be considered written (mask accordingly at the call site or
    use a complete permutation).
    """
    payload = to_bytes(value)
    moved = jax.lax.ppermute(payload, axis, perm)
    return jax.lax.dynamic_update_slice(
        arena_row, moved[None, :], (jnp.int32(0), jnp.asarray(offset, jnp.int32)))


def shmem_get(arena_row: jax.Array, offset, nbytes: int,
              perm: Sequence[Tuple[int, int]], axis: str,
              shape: Tuple[int, ...], dtype) -> jax.Array:
    """One-sided get with static peers: fetch ``nbytes`` at ``offset``
    from the unit that maps to me under ``perm`` (src, dst) pairs."""
    raw = jax.lax.dynamic_slice(
        arena_row, (jnp.int32(0), jnp.asarray(offset, jnp.int32)),
        (1, nbytes))[0]
    fetched = jax.lax.ppermute(raw, axis, perm)
    return from_bytes(fetched, shape, dtype)


def shmem_get_dynamic(arena_row: jax.Array, offset, nbytes: int,
                      src_unit: jax.Array, axis: str,
                      shape: Tuple[int, ...], dtype,
                      axis_index_groups=None) -> jax.Array:
    """Dynamic-peer get: peer id is a traced scalar.

    Lowers to all_gather + one-hot row select.  Semantically exact;
    costs a team-wide gather of the addressed window, so the static
    ``shmem_get`` / Pallas RDMA path is preferred where the pattern is
    known at trace time (documented perf note, DESIGN.md §2).
    """
    raw = jax.lax.dynamic_slice(
        arena_row, (jnp.int32(0), jnp.asarray(offset, jnp.int32)),
        (1, nbytes))[0]
    everyone = jax.lax.all_gather(raw, axis,
                                  axis_index_groups=axis_index_groups)
    n = everyone.shape[0]
    onehot = (jnp.arange(n, dtype=jnp.int32) ==
              jnp.asarray(src_unit, jnp.int32)).astype(jnp.uint8)
    picked = jnp.einsum("n,nb->b", onehot, everyone)
    return from_bytes(picked.astype(jnp.uint8), shape, dtype)


def shmem_halo_exchange(arena_row: jax.Array, left_val: jax.Array,
                        right_val: jax.Array, left_off, right_off,
                        axis: str, n_units: int,
                        wrap: bool = False) -> jax.Array:
    """Classic PGAS halo exchange built from two one-sided puts.

    Each unit puts ``right_val`` into its right neighbour at
    ``left_off`` (it arrives as the neighbour's *left* halo) and
    ``left_val`` into its left neighbour at ``right_off``.
    """
    def ring(delta):
        pairs = []
        for i in range(n_units):
            j = i + delta
            if wrap:
                pairs.append((i, j % n_units))
            elif 0 <= j < n_units:
                pairs.append((i, j))
        return pairs

    arena_row = shmem_put(arena_row, right_val, left_off, ring(+1), axis)
    arena_row = shmem_put(arena_row, left_val, right_off, ring(-1), axis)
    return arena_row
