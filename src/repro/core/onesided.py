"""DART one-sided communication (paper §III, §IV.B.5) + the
locality-aware non-blocking engine (§VI future work).

Two planes, mirroring how DART-MPI sits above MPI-3 RMA:

**Host plane** (single-controller, the analogue of the paper's
process-level API): ``dart_put/get`` dereference the global pointer
(flags → allocation kind, segid → team, absolute→relative unit
translation for collective pointers — §IV.B.4), then issue the
underlying substrate op.  The substrate here is XLA: a donated
``dynamic_update_slice`` on the sharded arena, which on a TPU mesh
compiles to a one-sided ICI DMA into the owning unit's HBM — the direct
analogue of ``MPI_Rput`` in a passive-target epoch.

**Epoch / flush / completion model** (the non-blocking engine):

The paper's non-blocking ops return request handles completed by
``dart_wait``/``dart_test``; underneath, MPI aggregates requests and a
``MPI_Win_flush`` completes them at the window.  We reproduce that
structure with :class:`CommEngine`, an **epoch-scoped pending-op
queue** over the symmetric heap:

* ``CommEngine.put/get`` *enqueue* — the pointer is dereferenced and
  bounds-checked at initiation (translation happens once, like the
  paper's dart_put), but no device work is dispatched.  The returned
  :class:`Handle` starts in the ``queued`` state.
* ``CommEngine.flush`` closes the epoch: maximal runs of consecutive
  same-pool, same-size ops are **coalesced** into one batched jitted
  scatter (:func:`_arena_scatter`) or gather (:func:`_arena_gather`) —
  N queued puts become a single XLA dispatch instead of N.  Program
  order is preserved run-by-run, so overlapping writes resolve exactly
  as the equivalent sequence of blocking ops (last writer wins).
* Handle lifecycle: ``queued`` → (flush) → ``issued`` → (XLA async
  dispatch drains) → ``complete`` — the paper's §III
  issued/locally-complete/remotely-complete ladder.  ``dart_wait`` on
  a queued handle triggers the flush itself; ``dart_test`` reports
  False until the op has been dispatched.

The engine also carries ``dispatch_count``, a counter of jitted kernel
launches, so tests and benchmarks can *assert* that a coalesced flush
issues fewer dispatches than the equivalent blocking sequence.

**Locality classifier**: on deref, ``FLAG_SHM``-eligible pointers
whose arena is host-visible are routed through the zero-copy view in
:mod:`repro.core.shm` instead of a jitted dynamic-slice dispatch (the
paper's §VI shared-memory-window plan) — see
:func:`repro.core.shm.classify_locality` and the runtime-level
``dart_get_blocking``.

Epochs: MPI requires RMA calls to sit inside an access epoch; DART opens
a shared epoch on every window at init/alloc time so users never see it
(§IV.B.5).  In XLA the "epoch" is the program region between two
flushes — conflict freedom inside it is guaranteed by dataflow, exactly
the RMA *unified* memory model the paper adopts.

**Device plane** (inside ``shard_map``; the analogue of what DASH's
compiled kernels do): ``shmem_put/get`` move bytes between unit rows
with ``lax.ppermute`` (static peers → point-to-point ICI DMA) or an
``all_gather`` + dynamic row-select (dynamic peers).  The Pallas RDMA
kernels in ``repro.kernels.rdma`` are the hand-tiled fast path for the
same semantics.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .globmem import (HeapState, SymmetricHeap, copy_state, from_bytes,
                      nbytes_of, to_bytes)
from .gptr import GlobalPtr

# --------------------------------------------------------------------------
# Request handles (paper: MPI_Rput/Rget handles + dart_wait/test[all])
# --------------------------------------------------------------------------


class Handle:
    """A DART communication handle.

    Lifecycle (paper §III): ``queued`` (enqueued on a
    :class:`CommEngine`, not yet dispatched) → ``issued`` (dispatched
    to XLA, asynchronously in flight) → ``complete`` (buffers ready).
    Handles constructed directly from arrays — the immediate path used
    by collectives — are born ``issued``.

    If an array has been *donated* to a later op (e.g. a subsequent put
    to the same pool), it is treated as complete: XLA executes ops on a
    device in program order, so a successor consuming the buffer is
    ordered after this op, and all reads flow through the successor's
    heap state anyway (dataflow = the RMA unified model, docs/API.md).
    """

    def __init__(self, arrays: Tuple[jax.Array, ...] = (),
                 engine: "Optional[CommEngine]" = None):
        self.arrays = tuple(arrays)
        self._engine = engine
        self._issued = engine is None

    @property
    def state(self) -> str:
        if not self._issued:
            return "queued"
        if all(a.is_deleted() or a.is_ready() for a in self.arrays):
            return "complete"
        return "issued"

    def _resolve(self, arrays: Tuple[jax.Array, ...]) -> None:
        self.arrays = tuple(arrays)
        self._issued = True

    def wait(self) -> None:
        if not self._issued and self._engine is not None:
            # close only this handle's pool epoch; other pools keep
            # accumulating ops for their own coalesced flush
            self._engine.flush(getattr(self, "poolid", None))
            if not self._issued:
                raise RuntimeError(
                    "queued op was dropped before dispatch (engine "
                    "cleared by dart_exit?)")
        jax.block_until_ready([a for a in self.arrays
                               if not a.is_deleted()])

    def test(self) -> bool:
        if not self._issued:
            return False
        return all(a.is_deleted() or a.is_ready() for a in self.arrays)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Handle(state={self.state}, n_arrays={len(self.arrays)})"


class GetHandle(Handle):
    """Handle of a queued get; ``value()`` flushes and returns the
    typed result (identical bytes to the blocking path)."""

    def __init__(self, shape: Tuple[int, ...], dtype,
                 engine: "CommEngine"):
        super().__init__((), engine)
        self.shape = tuple(shape)
        self.dtype = dtype
        self._value: Optional[jax.Array] = None

    def _resolve_value(self, value: jax.Array) -> None:
        self._value = value
        self._resolve((value,))

    def value(self) -> jax.Array:
        self.wait()
        if self._value is None:
            raise RuntimeError(
                "queued get was dropped before dispatch (engine cleared "
                "by dart_exit?)")
        return self._value


def dart_wait(handle: Handle) -> None:
    handle.wait()


def dart_test(handle: Handle) -> bool:
    return handle.test()


def dart_waitall(handles: Sequence[Handle]) -> None:
    # flushing one queued handle's pool resolves every queued handle on
    # the same (engine, pool); other pools are left accumulating
    for h in handles:
        if not h._issued and h._engine is not None:
            h._engine.flush(getattr(h, "poolid", None))
            if not h._issued:
                raise RuntimeError(
                    "queued op was dropped before dispatch (engine "
                    "cleared by dart_exit?)")
    jax.block_until_ready([a for h in handles for a in h.arrays
                           if not a.is_deleted()])


def dart_testall(handles: Sequence[Handle]) -> bool:
    return all(h.test() for h in handles)


# --------------------------------------------------------------------------
# Jitted substrate kernels (the "pure MPI" ops the runtime wraps).
# Shapes are static per (nbytes,) so re-dispatches hit the jit cache.
# --------------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=0, static_argnums=())
def _arena_write(arena: jax.Array, row: jax.Array, off: jax.Array,
                 payload: jax.Array) -> jax.Array:
    return jax.lax.dynamic_update_slice(arena, payload[None, :], (row, off))


@functools.partial(jax.jit, static_argnums=(3,))
def _arena_read(arena: jax.Array, row: jax.Array, off: jax.Array,
                nbytes: int) -> jax.Array:
    return jax.lax.dynamic_slice(arena, (row, off), (1, nbytes))[0]


@functools.partial(jax.jit, donate_argnums=0)
def _arena_scatter(arena: jax.Array, rows: jax.Array, offs: jax.Array,
                   payloads: jax.Array) -> jax.Array:
    """Batched put: apply k same-size updates in queue order — ONE
    dispatch for the whole run (the MPI request-aggregation analogue)."""
    def body(i, a):
        return jax.lax.dynamic_update_slice(
            a, payloads[i][None, :], (rows[i], offs[i]))
    return jax.lax.fori_loop(0, rows.shape[0], body, arena)


@functools.partial(jax.jit, static_argnums=(3,))
def _arena_gather(arena: jax.Array, rows: jax.Array, offs: jax.Array,
                  nbytes: int) -> jax.Array:
    """Batched get: fetch k same-size slices in one dispatch."""
    def one(r, o):
        return jax.lax.dynamic_slice(arena, (r, o), (1, nbytes))[0]
    return jax.vmap(one)(rows, offs)


# --------------------------------------------------------------------------
# Global-pointer dereference (paper §IV.B.4)
# --------------------------------------------------------------------------


def deref(heap: SymmetricHeap, teams_by_slot, gptr: GlobalPtr
          ) -> Tuple[int, int, int]:
    """gptr → (poolid, row, offset).

    Collective pointers: segid is the owning team's teamlist slot; the
    absolute unitid is translated to the team-relative id, which indexes
    the team pool's rows.  Non-collective pointers address the WORLD
    pool directly by absolute unitid — "trivially dereferenced without
    the unit translations" (paper §IV.B.4).
    """
    if gptr.is_collective:
        team = teams_by_slot[gptr.segid]
        rel = team.myid(gptr.unitid)
        if rel < 0:
            raise KeyError(
                f"unit {gptr.unitid} is not a member of team {team.teamid}")
        poolid = team_poolid(team)
        return poolid, rel, gptr.addr
    return WORLD_POOLID, gptr.unitid, gptr.addr


#: poolid of the pre-reserved non-collective WORLD pool (reserved first
#: at dart_init, so it is always 0).
WORLD_POOLID = 0


def team_poolid(team) -> int:
    """Teamlist slot → poolid.  Slot s keys pool s+1 (pool 0 = WORLD)."""
    return team.slot + 1


# --------------------------------------------------------------------------
# The non-blocking engine: epoch-scoped pending-op queue + coalesced flush
# --------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class _PendingPut:
    poolid: int
    row: int
    off: int
    payload: jax.Array          # 1-D uint8, already byte-converted
    handle: Handle


@dataclasses.dataclass(eq=False)
class _PendingGet:
    poolid: int
    row: int
    off: int
    nbytes: int
    handle: GetHandle


class CommEngine:
    """Epoch-scoped pending-op queue over a heap-state holder.

    ``holder`` is any object with a mutable ``state: HeapState``
    attribute (normally the :class:`repro.core.runtime.DartContext`).
    Ops enqueue with pointer translation + bounds checks done eagerly
    (initiation, paper DTIT); ``flush`` closes the epoch by dispatching
    coalesced runs and bumping ``epoch``.

    Instrumentation:

    * ``dispatch_count`` — jitted kernel launches issued by this engine
      (the quantity the coalescing is meant to minimize).
    * ``ops_enqueued`` / ``ops_coalesced`` — totals; ``ops_coalesced``
      counts ops that shared a dispatch with at least one neighbour.
    """

    def __init__(self, holder=None):
        self._holder = holder
        self._pending: List = []        # program order across pools
        self.epoch = 0
        self.dispatch_count = 0
        self.ops_enqueued = 0
        self.ops_coalesced = 0

    def bind(self, holder) -> None:
        self._holder = holder

    # -- enqueue (initiation) -------------------------------------------
    def put(self, heap: SymmetricHeap, teams_by_slot, gptr: GlobalPtr,
            value) -> Handle:
        poolid, row, off = deref(heap, teams_by_slot, gptr)
        payload = to_bytes(jnp.asarray(value))
        if off + payload.size > heap.pools[poolid].pool_bytes:
            raise ValueError("put overruns the target allocation's pool")
        h = Handle((), engine=self)
        h.poolid = poolid
        self._pending.append(_PendingPut(poolid, row, off, payload, h))
        self.ops_enqueued += 1
        return h

    def get(self, heap: SymmetricHeap, teams_by_slot, gptr: GlobalPtr,
            shape: Tuple[int, ...], dtype) -> GetHandle:
        poolid, row, off = deref(heap, teams_by_slot, gptr)
        n = nbytes_of(shape, dtype)
        if off + n > heap.pools[poolid].pool_bytes:
            raise ValueError("get overruns the target allocation's pool")
        h = GetHandle(shape, dtype, engine=self)
        h.poolid = poolid
        self._pending.append(_PendingGet(poolid, row, off, n, h))
        self.ops_enqueued += 1
        return h

    def pending_ops(self, poolid: Optional[int] = None) -> int:
        if poolid is None:
            return len(self._pending)
        return sum(1 for op in self._pending if op.poolid == poolid)

    # -- flush (epoch close) --------------------------------------------
    def flush(self, poolid: Optional[int] = None) -> HeapState:
        """Dispatch pending ops (all, or one pool's) in program order.

        Consecutive same-pool ops of the same kind and payload size are
        coalesced into one batched jitted dispatch.  Ops on distinct
        pools touch distinct arrays, so a per-pool flush cannot reorder
        visible effects.
        """
        if poolid is None:
            todo, rest = self._pending, []
        else:
            todo = [op for op in self._pending if op.poolid == poolid]
            rest = [op for op in self._pending if op.poolid != poolid]
        if not todo:
            return self._holder.state
        state = copy_state(self._holder.state)
        for run in _coalesced_runs(todo):
            pid = run[0].poolid
            if isinstance(run[0], _PendingPut):
                state[pid] = self._dispatch_put_run(state[pid], run)
                for op in run:
                    op.handle._resolve((state[pid],))
            else:
                self._dispatch_get_run(state[pid], run)
        self._pending = rest
        self._holder.state = state
        self.epoch += 1
        return state

    def _dispatch_put_run(self, arena: jax.Array,
                          run: Sequence[_PendingPut]) -> jax.Array:
        self.dispatch_count += 1
        if len(run) == 1:
            op = run[0]
            return _arena_write(arena, jnp.int32(op.row),
                                jnp.int32(op.off), op.payload)
        self.ops_coalesced += len(run)
        rows = jnp.asarray([op.row for op in run], jnp.int32)
        offs = jnp.asarray([op.off for op in run], jnp.int32)
        payloads = jnp.stack([op.payload for op in run])
        return _arena_scatter(arena, rows, offs, payloads)

    def _dispatch_get_run(self, arena: jax.Array,
                          run: Sequence[_PendingGet]) -> None:
        self.dispatch_count += 1
        if len(run) == 1:
            op = run[0]
            raw = _arena_read(arena, jnp.int32(op.row),
                              jnp.int32(op.off), op.nbytes)
            op.handle._resolve_value(
                from_bytes(raw, op.handle.shape, op.handle.dtype))
            return
        self.ops_coalesced += len(run)
        rows = jnp.asarray([op.row for op in run], jnp.int32)
        offs = jnp.asarray([op.off for op in run], jnp.int32)
        raws = _arena_gather(arena, rows, offs, run[0].nbytes)
        for i, op in enumerate(run):
            op.handle._resolve_value(
                from_bytes(raws[i], op.handle.shape, op.handle.dtype))

    @contextlib.contextmanager
    def epoch_scope(self, poolid: Optional[int] = None):
        """Explicit epoch as a ``with`` block (the typed front-end's
        ``ctx.epoch()``): ops enqueued inside stay queued; leaving the
        block closes the epoch with one coalesced flush — of everything,
        or of a single pool when ``poolid`` is given.  The flush runs
        even on error so no op is silently left queued."""
        try:
            yield self
        finally:
            self.flush(poolid)

    def clear(self) -> None:
        """Drop queued ops without dispatching (dart_exit teardown)."""
        self._pending = []


def _run_key(op) -> Tuple:
    if isinstance(op, _PendingPut):
        return ("put", op.poolid, int(op.payload.size))
    return ("get", op.poolid, op.nbytes)


def _coalesced_runs(ops: Sequence) -> List[List]:
    """Split into maximal runs of consecutive same-key ops.  Keeping
    runs in queue order preserves put/put and put/get program order
    for overlapping addresses (last writer wins, reads see prior
    writes), exactly like the blocking sequence."""
    runs: List[List] = []
    for op in ops:
        if runs and _run_key(runs[-1][-1]) == _run_key(op):
            runs[-1].append(op)
        else:
            runs.append([op])
    return runs


# --------------------------------------------------------------------------
# Host-plane one-sided ops (immediate / functional path)
# --------------------------------------------------------------------------


def dart_put(state: HeapState, heap: SymmetricHeap, teams_by_slot,
             gptr: GlobalPtr, value) -> Tuple[HeapState, Handle]:
    """Non-blocking one-sided put (``dart_put``, paper §III).

    Returns the updated heap state and a handle.  The write is issued
    immediately (async dispatch); completion = handle.wait()/test().
    The engine-backed path in :mod:`repro.core.runtime` defers the
    dispatch instead (queued → flush-coalesced).
    """
    poolid, row, off = deref(heap, teams_by_slot, gptr)
    payload = to_bytes(jnp.asarray(value))
    meta = heap.pools[poolid]
    if off + payload.size > meta.pool_bytes:
        raise ValueError("put overruns the target allocation's pool")
    arena = _arena_write(state[poolid], jnp.int32(row), jnp.int32(off),
                         payload)
    new_state = copy_state(state)
    new_state[poolid] = arena
    return new_state, Handle((arena,))


def dart_put_blocking(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                      gptr: GlobalPtr, value) -> HeapState:
    """Blocking put: returns after local+remote completion (paper §III)."""
    new_state, h = dart_put(state, heap, teams_by_slot, gptr, value)
    h.wait()
    return new_state


def dart_get(state: HeapState, heap: SymmetricHeap, teams_by_slot,
             gptr: GlobalPtr, shape: Tuple[int, ...], dtype
             ) -> Tuple[jax.Array, Handle]:
    """Non-blocking one-sided get: returns (value-future, handle)."""
    poolid, row, off = deref(heap, teams_by_slot, gptr)
    n = nbytes_of(shape, dtype)
    meta = heap.pools[poolid]
    if off + n > meta.pool_bytes:
        raise ValueError("get overruns the target allocation's pool")
    raw = _arena_read(state[poolid], jnp.int32(row), jnp.int32(off), n)
    value = from_bytes(raw, shape, dtype)
    return value, Handle((value,))


def dart_get_blocking(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                      gptr: GlobalPtr, shape: Tuple[int, ...], dtype
                      ) -> jax.Array:
    value, h = dart_get(state, heap, teams_by_slot, gptr, shape, dtype)
    h.wait()
    return value


# --------------------------------------------------------------------------
# Device-plane (shard_map) one-sided ops — SPMD "shmem" style.
#
# These are called from inside ``shard_map`` bodies where ``arena_row``
# is this unit's (1, pool_bytes) row of a symmetric-heap pool and
# ``axis`` is the unit axis name.  Peers are specified *statically*
# (trace-time ints) for the ppermute fast path — on TPU this lowers to
# a point-to-point ICI DMA, i.e. a true one-sided put.
# --------------------------------------------------------------------------


def shmem_put(arena_row: jax.Array, value: jax.Array, offset,
              perm: Sequence[Tuple[int, int]], axis: str) -> jax.Array:
    """Every unit sends ``value`` along ``perm``; receivers store at
    ``offset`` (same offset everywhere — the aligned/symmetric property).

    Units not appearing as a destination in ``perm`` receive zeros and
    must not be considered written (mask accordingly at the call site or
    use a complete permutation).
    """
    payload = to_bytes(value)
    moved = jax.lax.ppermute(payload, axis, perm)
    return jax.lax.dynamic_update_slice(
        arena_row, moved[None, :], (jnp.int32(0), jnp.asarray(offset, jnp.int32)))


def shmem_get(arena_row: jax.Array, offset, nbytes: int,
              perm: Sequence[Tuple[int, int]], axis: str,
              shape: Tuple[int, ...], dtype) -> jax.Array:
    """One-sided get with static peers: fetch ``nbytes`` at ``offset``
    from the unit that maps to me under ``perm`` (src, dst) pairs."""
    raw = jax.lax.dynamic_slice(
        arena_row, (jnp.int32(0), jnp.asarray(offset, jnp.int32)),
        (1, nbytes))[0]
    fetched = jax.lax.ppermute(raw, axis, perm)
    return from_bytes(fetched, shape, dtype)


def shmem_get_dynamic(arena_row: jax.Array, offset, nbytes: int,
                      src_unit: jax.Array, axis: str,
                      shape: Tuple[int, ...], dtype,
                      axis_index_groups=None) -> jax.Array:
    """Dynamic-peer get: peer id is a traced scalar.

    Lowers to all_gather + one-hot row select.  Semantically exact;
    costs a team-wide gather of the addressed window, so the static
    ``shmem_get`` / Pallas RDMA path is preferred where the pattern is
    known at trace time (documented perf note, docs/API.md).
    """
    raw = jax.lax.dynamic_slice(
        arena_row, (jnp.int32(0), jnp.asarray(offset, jnp.int32)),
        (1, nbytes))[0]
    everyone = jax.lax.all_gather(raw, axis,
                                  axis_index_groups=axis_index_groups)
    n = everyone.shape[0]
    onehot = (jnp.arange(n, dtype=jnp.int32) ==
              jnp.asarray(src_unit, jnp.int32)).astype(jnp.uint8)
    picked = jnp.einsum("n,nb->b", onehot, everyone)
    return from_bytes(picked.astype(jnp.uint8), shape, dtype)


def shmem_halo_exchange(arena_row: jax.Array, left_val: jax.Array,
                        right_val: jax.Array, left_off, right_off,
                        axis: str, n_units: int,
                        wrap: bool = False) -> jax.Array:
    """Classic PGAS halo exchange built from two one-sided puts.

    Each unit puts ``right_val`` into its right neighbour at
    ``left_off`` (it arrives as the neighbour's *left* halo) and
    ``left_val`` into its left neighbour at ``right_off``.
    """
    def ring(delta):
        pairs = []
        for i in range(n_units):
            j = i + delta
            if wrap:
                pairs.append((i, j % n_units))
            elif 0 <= j < n_units:
                pairs.append((i, j))
        return pairs

    arena_row = shmem_put(arena_row, right_val, left_off, ring(+1), axis)
    arena_row = shmem_put(arena_row, left_val, right_off, ring(-1), axis)
    return arena_row
