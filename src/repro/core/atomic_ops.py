"""DART atomic memory operations on global memory (paper §IV.B.6).

The paper builds its locks on MPI-3 ``MPI_Fetch_and_op`` /
``MPI_Compare_and_swap`` against window memory.  This module exposes
the same one-sided atomic API *on heap locations addressed by global
pointers* (int32 cells), completing the DART communication surface:

    dart_fetch_and_add(ctx, gptr, delta)        -> old value
    dart_fetch_and_store(ctx, gptr, value)      -> old value
    dart_compare_and_swap(ctx, gptr, exp, des)  -> old value

Atomicity model: under the single-controller runtime every atomic is a
read-modify-write issued from the one control thread, serialized by a
per-context mutex (multiple host threads — e.g. serving workers — may
share a context).  On a multi-controller deployment these map to the
remote-DMA + semaphore protocol sketched in core/atomics.py; the
*data-plane* layout (int32 cells in the symmetric heap, addressed by
gptr) is identical, which is the point: lock state lives in ordinary
DART global memory exactly as in the paper (Fig. 6).

Donation safety: the functional put/get below read and replace
``ctx.state`` directly, and the jitted put kernel *donates* the arena.
Every raw-state access therefore also holds the engine lock (inside
the per-context mutex — that lock order, mutex → engine lock, is the
rule everywhere), so a concurrent flush — foreground or the background
:class:`~repro.core.progress.ProgressPlane` — can never swap or delete
the arena mid-read.  This turns the old "single-writer rule for raw
state reads" from a documented caveat into an enforced invariant.
"""

from __future__ import annotations

import contextlib
import threading

import jax.numpy as jnp
import numpy as np

from .gptr import GlobalPtr
from .onesided import dart_get_blocking, dart_put_blocking

_ctx_locks: dict = {}
_ctx_locks_guard = threading.Lock()


def _mutex_for(ctx):
    with _ctx_locks_guard:
        key = id(ctx)
        if key not in _ctx_locks:
            _ctx_locks[key] = threading.Lock()
        return _ctx_locks[key]


def _engine_lock(ctx):
    """The engine lock when the ctx has an engine (DartContext), else a
    no-op — the functional plane is also used with bare state holders
    in unit tests."""
    engine = getattr(ctx, "engine", None)
    if engine is None:
        return contextlib.nullcontext()
    return engine.lock


def _flush_pending(ctx) -> None:
    # atomics are read-modify-write on heap cells: any queued (not yet
    # dispatched) engine ops must land first or the read is stale
    engine = getattr(ctx, "engine", None)
    if engine is not None and engine.pending_ops():
        engine.flush()


def _read_i32(ctx, gptr: GlobalPtr) -> int:
    with _engine_lock(ctx):
        _flush_pending(ctx)
        return int(np.asarray(dart_get_blocking(
            ctx.state, ctx.heap, ctx.teams_by_slot, gptr, (1,),
            jnp.int32))[0])


def _write_i32(ctx, gptr: GlobalPtr, value: int) -> None:
    with _engine_lock(ctx):
        _flush_pending(ctx)
        ctx.state = dart_put_blocking(
            ctx.state, ctx.heap, ctx.teams_by_slot, gptr,
            jnp.asarray([value], jnp.int32))


def dart_fetch_and_add(ctx, gptr: GlobalPtr, delta: int) -> int:
    with _mutex_for(ctx):
        old = _read_i32(ctx, gptr)
        _write_i32(ctx, gptr, old + delta)
        return old


def dart_fetch_and_store(ctx, gptr: GlobalPtr, value: int) -> int:
    with _mutex_for(ctx):
        old = _read_i32(ctx, gptr)
        _write_i32(ctx, gptr, value)
        return old


def dart_compare_and_swap(ctx, gptr: GlobalPtr, expected: int,
                          desired: int) -> int:
    with _mutex_for(ctx):
        old = _read_i32(ctx, gptr)
        if old == expected:
            _write_i32(ctx, gptr, desired)
        return old


class HeapAtomicsProvider:
    """AtomicsProvider backed by heap cells — lets the MCS LockService
    run with its lock state in DART global memory (paper Fig. 6
    layout: tail on one unit, next-cells spread across members)."""

    def __init__(self, ctx, notifier):
        self.ctx = ctx
        self._notifier = notifier             # reuse ThreadedAtomics' inbox
        self._cells: dict = {}

    def make_cell(self, name, home_unit, init) -> GlobalPtr:
        from .runtime import dart_memalloc
        g = dart_memalloc(self.ctx, 4, unit=home_unit)
        _write_i32(self.ctx, g, init)
        self._cells[name] = g
        return g

    def free_cell(self, cell) -> None:
        """Return the cell's heap bytes (LockService.destroy_lock)."""
        from .runtime import dart_memfree
        for name, g in list(self._cells.items()):
            if g == cell:
                del self._cells[name]
        dart_memfree(self.ctx, cell)

    def fetch_and_store(self, cell, value):
        return dart_fetch_and_store(self.ctx, cell, value)

    def fetch_and_add(self, cell, value):
        return dart_fetch_and_add(self.ctx, cell, value)

    def compare_and_swap(self, cell, expected, desired):
        return dart_compare_and_swap(self.ctx, cell, expected, desired)

    def load(self, cell):
        with _mutex_for(self.ctx):
            return _read_i32(self.ctx, cell)

    def store(self, cell, value):
        with _mutex_for(self.ctx):
            _write_i32(self.ctx, cell, value)

    def notify(self, unit, tag):
        self._notifier.notify(unit, tag)

    def wait_notify(self, unit, tag, timeout=None):
        self._notifier.wait_notify(unit, tag, timeout=timeout)
