"""DART global pointers (paper §III, §IV.B.4).

A DART global pointer is 128 bits wide:

    | unitid : 32 | segid : 16 | flags : 16 | addr : 64 |

* ``unitid`` — absolute unit id (position in DART_TEAM_ALL).
* ``segid``  — segment id.  For collective allocations this is the
  *teamlist slot index* of the owning team (paper §IV.B.2/3); for
  non-collective allocations it is ``NON_COLLECTIVE_SEG`` (0), i.e. the
  single pre-reserved WORLD window.
* ``flags``  — bit 0 marks a collective allocation; remaining bits are
  reserved (the paper reserves them too).
* ``addr``   — byte offset relative to the *base of the segment's memory
  pool* (paper: "relative to the base address of the memory region
  reserved for this team rather than the beginning of the sub-memory
  spanned by certain DART collective allocation").
"""

from __future__ import annotations

import dataclasses

import numpy as np

UNIT_BITS = 32
SEG_BITS = 16
FLAG_BITS = 16
ADDR_BITS = 64

UNIT_MAX = (1 << UNIT_BITS) - 1
SEG_MAX = (1 << SEG_BITS) - 1
FLAG_MAX = (1 << FLAG_BITS) - 1
ADDR_MAX = (1 << ADDR_BITS) - 1

#: segment id of the pre-reserved non-collective (WORLD) pool.
NON_COLLECTIVE_SEG = 0

#: flags bit 0: pointer refers to a collective (team-pool) allocation.
FLAG_COLLECTIVE = 1 << 0
#: flags bit 1: pointer was produced by the (beyond-paper) shared-memory
#: window path (§VI future work); informational only.
FLAG_SHM = 1 << 1


@dataclasses.dataclass(frozen=True, order=True)
class GlobalPtr:
    """An immutable 128-bit DART global pointer."""

    unitid: int
    segid: int
    flags: int
    addr: int

    def __post_init__(self):
        if not (0 <= self.unitid <= UNIT_MAX):
            raise ValueError(f"unitid {self.unitid} out of 32-bit range")
        if not (0 <= self.segid <= SEG_MAX):
            raise ValueError(f"segid {self.segid} out of 16-bit range")
        if not (0 <= self.flags <= FLAG_MAX):
            raise ValueError(f"flags {self.flags:#x} out of 16-bit range")
        if not (0 <= self.addr <= ADDR_MAX):
            raise ValueError(f"addr {self.addr} out of 64-bit range")

    # -- packing ---------------------------------------------------------
    def pack(self) -> int:
        """Pack into a single 128-bit integer."""
        return (
            (self.unitid << (SEG_BITS + FLAG_BITS + ADDR_BITS))
            | (self.segid << (FLAG_BITS + ADDR_BITS))
            | (self.flags << ADDR_BITS)
            | self.addr
        )

    @classmethod
    def unpack(cls, packed: int) -> "GlobalPtr":
        if not (0 <= packed < (1 << 128)):
            raise ValueError("packed global pointer out of 128-bit range")
        addr = packed & ADDR_MAX
        flags = (packed >> ADDR_BITS) & FLAG_MAX
        segid = (packed >> (FLAG_BITS + ADDR_BITS)) & SEG_MAX
        unitid = (packed >> (SEG_BITS + FLAG_BITS + ADDR_BITS)) & UNIT_MAX
        return cls(unitid=unitid, segid=segid, flags=flags, addr=addr)

    def to_words(self) -> np.ndarray:
        """Four little-endian uint32 words (device-transportable form)."""
        p = self.pack()
        return np.array(
            [(p >> (32 * i)) & 0xFFFFFFFF for i in range(4)], dtype=np.uint32
        )

    @classmethod
    def from_words(cls, words) -> "GlobalPtr":
        words = np.asarray(words, dtype=np.uint64)
        if words.shape != (4,):
            raise ValueError("expected 4 uint32 words")
        p = 0
        for i in range(4):
            p |= int(words[i]) << (32 * i)
        return cls.unpack(p)

    # -- queries ---------------------------------------------------------
    @property
    def is_collective(self) -> bool:
        return bool(self.flags & FLAG_COLLECTIVE)

    @property
    def is_shm(self) -> bool:
        """Minted by the shared-memory window path (§VI): eligible for
        the zero-copy locality fast path when the arena is host-visible."""
        return bool(self.flags & FLAG_SHM)

    @property
    def is_null(self) -> bool:
        return self == DART_GPTR_NULL

    # -- arithmetic ------------------------------------------------------
    def incaddr(self, nbytes: int) -> "GlobalPtr":
        """``dart_gptr_incaddr``: advance the offset by ``nbytes``.

        ``nbytes`` may be negative; the result must stay inside
        [0, ADDR_MAX] or a :class:`ValueError` is raised.
        """
        new = self.addr + nbytes
        if not (0 <= new <= ADDR_MAX):
            raise ValueError("global pointer arithmetic out of range "
                             f"(addr {self.addr} {nbytes:+d})")
        return dataclasses.replace(self, addr=new)

    def decaddr(self, nbytes: int) -> "GlobalPtr":
        """``dart_gptr_decaddr``: move the offset back by ``nbytes``
        (the negative-direction twin of :meth:`incaddr`)."""
        return self.incaddr(-nbytes)

    def addrdiff(self, other: "GlobalPtr") -> int:
        """Signed byte distance ``self.addr - other.addr``.

        Only meaningful for pointers into the same segment: both must
        share ``segid`` and collectivity, and non-collective pointers
        must also share ``unitid`` (their offsets are displacements into
        per-unit WORLD partitions, not a common pool).  Collective
        pointers may target different units — the allocation is aligned
        & symmetric, so offsets are unit-independent (paper §III).
        """
        if self.segid != other.segid:
            raise ValueError(
                f"pointer distance across segments ({self.segid} vs "
                f"{other.segid}) is undefined")
        if self.is_collective != other.is_collective:
            raise ValueError("pointer distance between collective and "
                             "non-collective pointers is undefined")
        if not self.is_collective and self.unitid != other.unitid:
            raise ValueError(
                "non-collective pointer distance requires the same unit "
                f"(got {self.unitid} vs {other.unitid})")
        return self.addr - other.addr

    def setunit(self, unitid: int) -> "GlobalPtr":
        """``dart_gptr_setunit``: retarget at another unit's portion.

        Valid for *aligned & symmetric* collective allocations — the same
        offset refers to the same datum on every member (paper §III).
        """
        return dataclasses.replace(self, unitid=unitid)

    def __add__(self, nbytes: int) -> "GlobalPtr":
        return self.incaddr(nbytes)

    def __sub__(self, other):
        """``gptr - int`` → :meth:`decaddr`; ``gptr - gptr`` →
        :meth:`addrdiff` (signed byte distance)."""
        if isinstance(other, GlobalPtr):
            return self.addrdiff(other)
        return self.decaddr(other)


#: the DART null pointer.
DART_GPTR_NULL = GlobalPtr(unitid=0, segid=0, flags=0, addr=0)
