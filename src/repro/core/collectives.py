"""DART collective communication (paper §III, §IV.B.5).

The paper implements DART collectives "straightforwardly by using the
MPI-3 collective counterparts", after resolving the team → communicator
translation.  We do the same against the JAX substrate:

* **Device plane** (inside ``shard_map``): team → ``axis_index_groups``
  (the JAX analogue of a sub-communicator).  ``psum`` lacks group
  support on some backends, so the team all-reduce is decomposed into
  reduce-scatter + all-gather — the canonical ring decomposition, and
  incidentally the DART-style construction of a collective from
  one-sided phases.

* **Host plane**: collectives over heap segments (bcast/scatter/gather)
  are expressed as row motions on the arena via jitted gather/scatter.

``dart_barrier`` on the host plane is a device-queue fence; inside a
step it is a zero-payload psum (token barrier).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import segmented_copy as _sc

from .globmem import (HeapState, SymmetricHeap, copy_state,
                      from_bytes, nbytes_of, to_bytes)
from .gptr import GlobalPtr
from .onesided import Handle, deref

# --------------------------------------------------------------------------
# Device-plane team collectives (call inside shard_map)
# --------------------------------------------------------------------------


def team_all_gather(x, axis: str, groups=None, tiled: bool = False):
    return jax.lax.all_gather(x, axis, axis_index_groups=groups, tiled=tiled)


def team_reduce_scatter(x, axis: str, groups=None):
    return jax.lax.psum_scatter(x, axis, axis_index_groups=groups,
                                tiled=True)


def team_psum(x, axis: str, groups=None):
    """Team all-reduce.

    With groups: reduce-scatter + all-gather (RS+AG) over a padded
    leading axis — ``lax.psum`` does not accept ``axis_index_groups`` on
    the CPU/interpret path.  Without groups: plain psum.
    """
    if groups is None:
        return jax.lax.psum(x, axis)
    g = len(groups[0])
    flat = x.reshape(-1)
    pad = (-flat.size) % g
    flat = jnp.pad(flat, (0, pad))
    scat = jax.lax.psum_scatter(flat, axis, axis_index_groups=groups,
                                tiled=True)
    full = jax.lax.all_gather(scat, axis, axis_index_groups=groups,
                              tiled=True)
    if pad:
        full = full[:-pad]
    return full.reshape(x.shape)


def team_pmax(x, axis: str, groups=None):
    return jax.lax.pmax(x, axis, axis_index_groups=groups)


def team_all_to_all(x, axis: str, split_axis: int, concat_axis: int,
                    groups=None):
    return jax.lax.all_to_all(x, axis, split_axis, concat_axis,
                              axis_index_groups=groups, tiled=True)


def team_broadcast(x, axis: str, root_rel: int, groups=None):
    """Broadcast from the team-relative root: all_gather + static pick."""
    g = jax.lax.all_gather(x, axis, axis_index_groups=groups)
    return jax.lax.index_in_dim(g, root_rel, axis=0, keepdims=False)


def team_barrier(axis: str, groups=None):
    """Token barrier: a zero-payload team reduction."""
    return team_psum(jnp.zeros((), jnp.int32) + 1, axis, groups)


# --------------------------------------------------------------------------
# Host-plane collectives over heap segments
#
# These share the engine's batched dispatch discipline: each collective
# is ONE jitted kernel over the addressed segment (not an eager op per
# lax call), and when a CommEngine is passed, the target pool's pending
# one-sided ops are flushed first (queued puts are ordered *before* the
# collective, matching the paper's epoch semantics) and the kernel
# launch is counted in engine.dispatch_count.
#
# The kernels follow the engine's shape-stable DispatchPlan discipline
# (repro.kernels.segmented_copy): segment bytes / element counts are
# bucketed to powers of two and the true length travels as a traced
# scalar in a packed int32 params array, so varying collective sizes
# hit a small cached kernel family instead of recompiling per size.
# Masked flat-index addressing (scatter mode='drop', gather
# mode='fill') keeps padded lanes from ever touching bytes outside the
# addressed segment.  Donation is ENGINE-GATED: with an engine the
# arena is holder-owned and donated; on the functional engine=None
# path the caller keeps its snapshot, so the kernels must not donate
# (previously _seg_bcast/_seg_scatter/_seg_scatter_typed donated
# unconditionally and deleted the caller's retained state —
# _seg_allreduce already documented why that is wrong).
# --------------------------------------------------------------------------


def _row_lane_dst(R: int, P: int, off, lane, valid):
    """(R, seg) flat arena positions for every row's segment lane;
    masked lanes get distinct out-of-range markers (dropped)."""
    rows = jnp.arange(R, dtype=jnp.int32)[:, None]
    seg = lane.shape[0]
    return jnp.where(valid[None, :], rows * P + off + lane[None, :],
                     R * P + rows * seg + lane[None, :])


def _donate(donate: bool):
    return (0,) if donate else ()


def _bcast_plan(arena_shape, seg: int, donate: bool):
    _sc.check_flat_addressable(arena_shape)
    key = ("coll_bcast", arena_shape, seg, donate)

    def build():
        def fn(arena, params):          # params = [root_row, off, nbytes]
            R, P = arena.shape
            root, off, n = params[0], params[1], params[2]
            lane = jnp.arange(seg, dtype=jnp.int32)
            valid = lane < n
            src = jnp.take(arena.reshape(-1),
                           jnp.where(valid, root * P + off + lane, R * P),
                           mode="fill", fill_value=0)
            dst = _row_lane_dst(R, P, off, lane, valid)
            out = arena.reshape(-1).at[dst.reshape(-1)].set(
                jnp.broadcast_to(src, (R, seg)).reshape(-1),
                mode="drop", unique_indices=True)
            return out.reshape(R, P)
        return jax.jit(fn, donate_argnums=_donate(donate))

    return _sc.cached_plan(key, build)


def _row_gather_plan(arena_shape, seg: int):
    _sc.check_flat_addressable(arena_shape)
    key = ("coll_gather", arena_shape, seg)

    def build():
        def fn(arena, params):          # params = [off, nbytes]
            R, P = arena.shape
            off, n = params[0], params[1]
            lane = jnp.arange(seg, dtype=jnp.int32)
            valid = lane < n
            rows = jnp.arange(R, dtype=jnp.int32)[:, None]
            idx = jnp.where(valid[None, :],
                            rows * P + off + lane[None, :], R * P)
            return jnp.take(arena.reshape(-1), idx, mode="fill",
                            fill_value=0)
        return jax.jit(fn)

    return _sc.cached_plan(key, build)


def _row_scatter_plan(arena_shape, seg: int, donate: bool):
    _sc.check_flat_addressable(arena_shape)
    key = ("coll_scatter", arena_shape, seg, donate)

    def build():
        def fn(arena, params, values):  # values (R, seg) uint8 padded
            R, P = arena.shape
            off, n = params[0], params[1]
            lane = jnp.arange(seg, dtype=jnp.int32)
            dst = _row_lane_dst(R, P, off, lane, lane < n)
            out = arena.reshape(-1).at[dst.reshape(-1)].set(
                values.reshape(-1), mode="drop", unique_indices=True)
            return out.reshape(R, P)
        return jax.jit(fn, donate_argnums=_donate(donate))

    return _sc.cached_plan(key, build)


def _row_scatter_typed_plan(arena_shape, dtype, eb: int, donate: bool):
    _sc.check_flat_addressable(arena_shape)
    key = ("coll_scatter_typed", arena_shape, str(jnp.dtype(dtype)), eb,
           donate)

    def build():
        def fn(arena, params, values):  # values (R, eb) dtype padded
            R, P = arena.shape
            off, n = params[0], params[1]
            rows = jax.vmap(to_bytes)(values)          # (R, eb*itemsize)
            seg = rows.shape[1]
            lane = jnp.arange(seg, dtype=jnp.int32)
            dst = _row_lane_dst(R, P, off, lane, lane < n)
            out = arena.reshape(-1).at[dst.reshape(-1)].set(
                rows.reshape(-1), mode="drop", unique_indices=True)
            return out.reshape(R, P)
        return jax.jit(fn, donate_argnums=_donate(donate))

    return _sc.cached_plan(key, build)


def _row_gather_typed_plan(arena_shape, dtype, eb: int):
    dt = jnp.dtype(dtype)
    _sc.check_flat_addressable(arena_shape)
    key = ("coll_gather_typed", arena_shape, str(dt), eb)

    def build():
        def fn(arena, params):          # params = [off, nbytes]
            R, P = arena.shape
            off, n = params[0], params[1]
            seg = eb * dt.itemsize
            lane = jnp.arange(seg, dtype=jnp.int32)
            valid = lane < n
            rows = jnp.arange(R, dtype=jnp.int32)[:, None]
            idx = jnp.where(valid[None, :],
                            rows * P + off + lane[None, :], R * P)
            raw = jnp.take(arena.reshape(-1), idx, mode="fill",
                           fill_value=0)
            return jax.vmap(lambda r: from_bytes(r, (eb,), dt))(raw)
        return jax.jit(fn)

    return _sc.cached_plan(key, build)


_REDUCERS = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min,
             "prod": jnp.prod}


def _reduce_plan(arena_shape, eb: int, dtype, op: str, root: bool,
                 donate: bool):
    """Shape-stable reduce/allreduce (the reduction plane's collective
    half): the element count is bucketed to ``eb`` (pow2, floor 4) and
    masked element lanes read as the **op identity**
    (:func:`repro.kernels.segmented_copy.op_identity` — 0/1/±inf by
    op), so the cross-row reduction of a padded lane is itself the
    identity and the kernel's output shape is a pure function of the
    bucket.  Varying (shape, dtype, op) steady-state loops therefore
    hit a small cached family — the kernel is keyed on ``eb``, never
    the exact shape; the true byte length travels as a traced scalar
    and the padded reduced vector is trimmed host-side.  ``root``
    selects the root-taking reduce (write-back to one row) vs the
    allreduce (write-back to every row).  Donation is engine-gated
    like the other collectives: with an engine the arena is
    holder-owned and donated (the write-back is in-place, no
    arena-sized copy); on the functional ``engine=None`` path the
    caller keeps its snapshot."""
    dt = jnp.dtype(dtype)
    _sc.check_flat_addressable(arena_shape)
    key = ("coll_reduce", arena_shape, eb, str(dt), op, root, donate)

    def build():
        def fn(arena, params):       # params = [off, nbytes, root_row]
            R, P = arena.shape
            off, n, root_row = params[0], params[1], params[2]
            isz = dt.itemsize
            seg = eb * isz
            blane = jnp.arange(seg, dtype=jnp.int32)
            bvalid = blane < n
            rows = jnp.arange(R, dtype=jnp.int32)[:, None]
            idx = jnp.where(bvalid[None, :],
                            rows * P + off + blane[None, :], R * P)
            raw = jnp.take(arena.reshape(-1), idx, mode="fill",
                           fill_value=0)                      # (R, seg)
            vals = jax.vmap(lambda r: from_bytes(r, (eb,), dt))(raw)
            evalid = jnp.arange(eb, dtype=jnp.int32) * isz < n
            ident = jnp.asarray(_sc.op_identity(op, dt))
            vals = jnp.where(evalid[None, :], vals, ident)
            red = _REDUCERS[op](vals, axis=0)                 # (eb,)
            out_b = to_bytes(red)                             # (seg,)
            if root:
                dst = jnp.where(bvalid, root_row * P + off + blane,
                                R * P + blane)
                payload = out_b
            else:
                dst = _row_lane_dst(R, P, off, blane, bvalid).reshape(-1)
                payload = jnp.broadcast_to(out_b, (R, seg)).reshape(-1)
            arena2 = arena.reshape(-1).at[dst].set(
                payload, mode="drop", unique_indices=True).reshape(R, P)
            return arena2, red
        return jax.jit(fn, donate_argnums=_donate(donate))

    return _sc.cached_plan(key, build)


def _pre_collective(state, poolid, engine):
    """Flush queued one-sided ops on the pool, count our dispatch.

    With an engine, the collective operates on the engine holder's
    freshly flushed state — the caller-passed ``state`` is superseded
    (runtime callers always pass ``ctx.state`` where ``ctx`` is the
    holder).  Pass ``engine=None`` to thread state purely functionally.

    Routing note: the runtime wrappers (``runtime.dart_bcast`` etc.)
    only reach this module's data movers when the shm-direct path
    declined — FLAG_SHM pointers on host-writable arenas are served by
    ``shm.try_shm_bcast``/``try_shm_gather``/``try_shm_scatter`` as
    memcpy loops with zero jitted dispatches (and therefore zero
    ``dispatch_count`` increments).  The ordering contract is shared:
    both paths flush the whole pool first, so queued one-sided ops are
    ordered before the collective either way.
    """
    if engine is not None:
        state = engine.flush(poolid)
        engine.dispatch_count += 1
    return state


def _note_plan(engine, hit: bool) -> None:
    if engine is not None:
        engine._note_plan(hit)


def dart_bcast(state: HeapState, heap: SymmetricHeap, teams_by_slot,
               root_gptr: GlobalPtr, nbytes: int, engine=None):
    """Broadcast ``nbytes`` at the root's allocation to every row of the
    segment (team members all see the root's bytes at the same offset)."""
    poolid, row, off = deref(heap, teams_by_slot, root_gptr)
    state = _pre_collective(state, poolid, engine)
    seg = _sc.bucket_pow2(nbytes, _sc.SEG_FLOOR)
    fn, hit = _bcast_plan(state[poolid].shape, seg,
                          donate=engine is not None)
    _note_plan(engine, hit)
    arena = fn(state[poolid], np.asarray([row, off, nbytes], np.int32))
    new_state = copy_state(state)
    new_state[poolid] = arena
    return new_state, Handle((arena,))


def dart_gather(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                gptr: GlobalPtr, per_unit_nbytes: int, engine=None):
    """Gather each row's ``per_unit_nbytes`` at gptr.addr → host value of
    shape (n_rows, per_unit_nbytes) uint8."""
    poolid, _, off = deref(heap, teams_by_slot, gptr)
    state = _pre_collective(state, poolid, engine)
    seg = _sc.bucket_pow2(per_unit_nbytes, _sc.SEG_FLOOR)
    fn, hit = _row_gather_plan(state[poolid].shape, seg)
    _note_plan(engine, hit)
    padded = fn(state[poolid],
                np.asarray([off, per_unit_nbytes], np.int32))
    # trim the bucket padding host-side (one device→host copy; no
    # extra jitted launch after the counted gather)
    out = jnp.asarray(np.asarray(padded)[:, :per_unit_nbytes])
    return out, Handle((out,))


def dart_scatter(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                 gptr: GlobalPtr, values: jax.Array, engine=None):
    """Scatter row i of ``values`` (uint8[n_rows, nbytes]) to unit i."""
    poolid, _, off = deref(heap, teams_by_slot, gptr)
    state = _pre_collective(state, poolid, engine)
    vh = np.asarray(values, np.uint8)
    nbytes = vh.shape[1]
    seg = _sc.bucket_pow2(nbytes, _sc.SEG_FLOOR)
    padded = np.zeros((vh.shape[0], seg), np.uint8)
    padded[:, :nbytes] = vh                      # host staging: one H2D
    fn, hit = _row_scatter_plan(state[poolid].shape, seg,
                                donate=engine is not None)
    _note_plan(engine, hit)
    arena = fn(state[poolid], np.asarray([off, nbytes], np.int32), padded)
    new_state = copy_state(state)
    new_state[poolid] = arena
    return new_state, Handle((arena,))


def dart_gather_typed(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                      gptr: GlobalPtr, shape, dtype, engine=None):
    """Typed gather: each row's value at ``gptr.addr`` decoded to its
    dtype → ``(n_rows, *shape)``.  Slice *and* decode run inside the
    single counted jitted dispatch, bucketed on the element count so
    varying gather sizes share a cached kernel; the bucket padding is
    trimmed host-side from the one device→host copy."""
    dt = jnp.dtype(dtype)
    shape = tuple(shape)
    n_elems = max(int(np.prod(shape, dtype=np.int64)), 1) if shape else 1
    poolid, _, off = deref(heap, teams_by_slot, gptr)
    state = _pre_collective(state, poolid, engine)
    eb = _sc.bucket_pow2(n_elems, 4)
    fn, hit = _row_gather_typed_plan(state[poolid].shape, dt, eb)
    _note_plan(engine, hit)
    padded = fn(state[poolid],
                np.asarray([off, n_elems * dt.itemsize], np.int32))
    n_rows = state[poolid].shape[0]
    vals = jnp.asarray(
        np.asarray(padded)[:, :n_elems].reshape((n_rows,) + shape))
    return vals, Handle((vals,))


def dart_scatter_typed(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                       gptr: GlobalPtr, values: jax.Array, engine=None):
    """Typed scatter: row i of ``values`` (``(n_rows, *shape)``, any
    dtype) lands at ``gptr.addr`` on unit i.  Encode + update run
    inside the single counted jitted dispatch, bucketed on the element
    count (values are host-padded to the bucket and masked to the true
    byte length in-kernel) so varying sizes share a cached kernel."""
    vh = np.asarray(values)
    canon = jax.dtypes.canonicalize_dtype(vh.dtype)
    if vh.dtype != canon:
        # mirror the old jnp.asarray path: the kernel's byte mask must
        # be computed from the dtype the jit will actually store
        # (int64/float64 inputs canonicalize to 32-bit without x64)
        vh = vh.astype(canon)
    vh = vh.reshape(vh.shape[0], -1)
    n_elems = vh.shape[1]
    dt = vh.dtype
    poolid, _, off = deref(heap, teams_by_slot, gptr)
    state = _pre_collective(state, poolid, engine)
    eb = _sc.bucket_pow2(n_elems, 4)
    padded = np.zeros((vh.shape[0], eb), dt)
    padded[:, :n_elems] = vh                     # host staging: one H2D
    fn, hit = _row_scatter_typed_plan(state[poolid].shape, dt, eb,
                                      donate=engine is not None)
    _note_plan(engine, hit)
    arena = fn(state[poolid],
               np.asarray([off, n_elems * dt.itemsize], np.int32), padded)
    new_state = copy_state(state)
    new_state[poolid] = arena
    return new_state, Handle((arena,))


def _run_reduce(state, heap, teams_by_slot, gptr, shape, dtype, op,
                engine, root_unit):
    dt = jnp.dtype(dtype)
    shape = tuple(shape)
    n_elems = int(np.prod(shape, dtype=np.int64)) if shape else 1
    if root_unit is None:
        poolid, root_row, off = deref(heap, teams_by_slot, gptr)
        root_row = 0
    else:
        poolid, root_row, off = deref(heap, teams_by_slot,
                                      gptr.setunit(root_unit))
    state = _pre_collective(state, poolid, engine)
    eb = _sc.bucket_pow2(max(n_elems, 1), 4)
    fn, hit = _reduce_plan(state[poolid].shape, eb, dt, op,
                           root=root_unit is not None,
                           donate=engine is not None)
    _note_plan(engine, hit)
    arena, red_padded = fn(
        state[poolid],
        np.asarray([off, n_elems * dt.itemsize, root_row], np.int32))
    new_state = copy_state(state)
    new_state[poolid] = arena
    # trim the bucket padding host-side (one device→host copy, no
    # extra jitted launch after the counted dispatch) — padded lanes
    # hold the op identity, never caller data
    red = jnp.asarray(
        np.asarray(red_padded)[:n_elems].reshape(shape))
    return new_state, red


def dart_allreduce(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                   gptr: GlobalPtr, shape, dtype, op: str = "sum",
                   engine=None):
    """All-reduce the typed value at gptr.addr across rows; the result
    replaces every row's copy.  Returns (new_state, reduced_value).

    Shape-stable: the element count buckets to pow2 with op-identity
    padding (see :func:`_reduce_plan`), so steady-state loops of
    varying (shape, dtype, op) hit the plan cache with zero
    recompiles."""
    return _run_reduce(state, heap, teams_by_slot, gptr, shape, dtype,
                       op, engine, root_unit=None)


def dart_reduce(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                gptr: GlobalPtr, shape, dtype, op: str = "sum",
                root: int = 0, engine=None):
    """Root-taking reduce: like :func:`dart_allreduce` but the reduced
    value replaces only ``root``'s row (absolute unit id); every other
    row keeps its own copy.  Returns (new_state, reduced_value)."""
    return _run_reduce(state, heap, teams_by_slot, gptr, shape, dtype,
                       op, engine, root_unit=root)


def dart_barrier(state: Optional[HeapState] = None) -> None:
    """Host-plane barrier: fence the device queue (single-controller)."""
    if state:
        jax.block_until_ready(list(state.values()))
    else:
        jax.block_until_ready(jnp.zeros(()))
