"""DART collective communication (paper §III, §IV.B.5).

The paper implements DART collectives "straightforwardly by using the
MPI-3 collective counterparts", after resolving the team → communicator
translation.  We do the same against the JAX substrate:

* **Device plane** (inside ``shard_map``): team → ``axis_index_groups``
  (the JAX analogue of a sub-communicator).  ``psum`` lacks group
  support on some backends, so the team all-reduce is decomposed into
  reduce-scatter + all-gather — the canonical ring decomposition, and
  incidentally the DART-style construction of a collective from
  one-sided phases.

* **Host plane**: collectives over heap segments (bcast/scatter/gather)
  are expressed as row motions on the arena via jitted gather/scatter.

``dart_barrier`` on the host plane is a device-queue fence; inside a
step it is a zero-payload psum (token barrier).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from .globmem import (HeapState, SymmetricHeap, copy_state,
                      from_bytes, nbytes_of, to_bytes)
from .gptr import GlobalPtr
from .onesided import Handle, deref

# --------------------------------------------------------------------------
# Device-plane team collectives (call inside shard_map)
# --------------------------------------------------------------------------


def team_all_gather(x, axis: str, groups=None, tiled: bool = False):
    return jax.lax.all_gather(x, axis, axis_index_groups=groups, tiled=tiled)


def team_reduce_scatter(x, axis: str, groups=None):
    return jax.lax.psum_scatter(x, axis, axis_index_groups=groups,
                                tiled=True)


def team_psum(x, axis: str, groups=None):
    """Team all-reduce.

    With groups: reduce-scatter + all-gather (RS+AG) over a padded
    leading axis — ``lax.psum`` does not accept ``axis_index_groups`` on
    the CPU/interpret path.  Without groups: plain psum.
    """
    if groups is None:
        return jax.lax.psum(x, axis)
    g = len(groups[0])
    flat = x.reshape(-1)
    pad = (-flat.size) % g
    flat = jnp.pad(flat, (0, pad))
    scat = jax.lax.psum_scatter(flat, axis, axis_index_groups=groups,
                                tiled=True)
    full = jax.lax.all_gather(scat, axis, axis_index_groups=groups,
                              tiled=True)
    if pad:
        full = full[:-pad]
    return full.reshape(x.shape)


def team_pmax(x, axis: str, groups=None):
    return jax.lax.pmax(x, axis, axis_index_groups=groups)


def team_all_to_all(x, axis: str, split_axis: int, concat_axis: int,
                    groups=None):
    return jax.lax.all_to_all(x, axis, split_axis, concat_axis,
                              axis_index_groups=groups, tiled=True)


def team_broadcast(x, axis: str, root_rel: int, groups=None):
    """Broadcast from the team-relative root: all_gather + static pick."""
    g = jax.lax.all_gather(x, axis, axis_index_groups=groups)
    return jax.lax.index_in_dim(g, root_rel, axis=0, keepdims=False)


def team_barrier(axis: str, groups=None):
    """Token barrier: a zero-payload team reduction."""
    return team_psum(jnp.zeros((), jnp.int32) + 1, axis, groups)


# --------------------------------------------------------------------------
# Host-plane collectives over heap segments
#
# These share the engine's batched dispatch discipline: each collective
# is ONE jitted kernel over the addressed segment (not an eager op per
# lax call), and when a CommEngine is passed, the target pool's pending
# one-sided ops are flushed first (queued puts are ordered *before* the
# collective, matching the paper's epoch semantics) and the kernel
# launch is counted in engine.dispatch_count.
# --------------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=0, static_argnums=(3,))
def _seg_bcast(arena, root_row, off, nbytes):
    src = jax.lax.dynamic_slice(arena, (root_row, off), (1, nbytes))
    tiled = jnp.broadcast_to(src, (arena.shape[0], nbytes))
    return jax.lax.dynamic_update_slice(arena, tiled, (jnp.int32(0), off))


@functools.partial(jax.jit, static_argnums=(2,))
def _seg_gather(arena, off, nbytes):
    return jax.lax.dynamic_slice(arena, (jnp.int32(0), off),
                                 (arena.shape[0], nbytes))


@functools.partial(jax.jit, donate_argnums=0)
def _seg_scatter(arena, off, values):
    return jax.lax.dynamic_update_slice(arena, values, (jnp.int32(0), off))


@functools.partial(jax.jit, static_argnums=(2, 3))
def _seg_gather_typed(arena, off, shape, dtype):
    """Typed gather as ONE kernel: slice + per-row byte decode fused,
    so the dispatch the engine counts is the dispatch that runs."""
    n = nbytes_of(shape, dtype)
    raw = jax.lax.dynamic_slice(arena, (jnp.int32(0), off),
                                (arena.shape[0], n))
    return jax.vmap(lambda r: from_bytes(r, shape, dtype))(raw)


@functools.partial(jax.jit, donate_argnums=0)
def _seg_scatter_typed(arena, off, values):
    """Typed scatter as ONE kernel: per-row byte encode + update fused."""
    rows = jax.vmap(to_bytes)(values.reshape(values.shape[0], -1))
    return jax.lax.dynamic_update_slice(arena, rows, (jnp.int32(0), off))


_REDUCERS = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min,
             "prod": jnp.prod}


# NOT donated: unlike the engine-holder-owned bcast/scatter paths, the
# functional engine=None contract lets callers keep the old snapshot.
@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def _seg_allreduce(arena, off, shape, dtype, op):
    n = nbytes_of(shape, dtype)
    raw = jax.lax.dynamic_slice(arena, (jnp.int32(0), off),
                                (arena.shape[0], n))
    vals = jax.vmap(lambda r: from_bytes(r, shape, dtype))(raw)
    red = _REDUCERS[op](vals, axis=0)
    payload = jnp.broadcast_to(to_bytes(red)[None, :], (arena.shape[0], n))
    return jax.lax.dynamic_update_slice(arena, payload,
                                        (jnp.int32(0), off)), red


def _pre_collective(state, poolid, engine):
    """Flush queued one-sided ops on the pool, count our dispatch.

    With an engine, the collective operates on the engine holder's
    freshly flushed state — the caller-passed ``state`` is superseded
    (runtime callers always pass ``ctx.state`` where ``ctx`` is the
    holder).  Pass ``engine=None`` to thread state purely functionally.
    """
    if engine is not None:
        state = engine.flush(poolid)
        engine.dispatch_count += 1
    return state


def dart_bcast(state: HeapState, heap: SymmetricHeap, teams_by_slot,
               root_gptr: GlobalPtr, nbytes: int, engine=None):
    """Broadcast ``nbytes`` at the root's allocation to every row of the
    segment (team members all see the root's bytes at the same offset)."""
    poolid, row, off = deref(heap, teams_by_slot, root_gptr)
    state = _pre_collective(state, poolid, engine)
    arena = _seg_bcast(state[poolid], jnp.int32(row), jnp.int32(off),
                       nbytes)
    new_state = copy_state(state)
    new_state[poolid] = arena
    return new_state, Handle((arena,))


def dart_gather(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                gptr: GlobalPtr, per_unit_nbytes: int, engine=None):
    """Gather each row's ``per_unit_nbytes`` at gptr.addr → host value of
    shape (n_rows, per_unit_nbytes) uint8."""
    poolid, _, off = deref(heap, teams_by_slot, gptr)
    state = _pre_collective(state, poolid, engine)
    out = _seg_gather(state[poolid], jnp.int32(off), per_unit_nbytes)
    return out, Handle((out,))


def dart_scatter(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                 gptr: GlobalPtr, values: jax.Array, engine=None):
    """Scatter row i of ``values`` (uint8[n_rows, nbytes]) to unit i."""
    poolid, _, off = deref(heap, teams_by_slot, gptr)
    state = _pre_collective(state, poolid, engine)
    values = jnp.asarray(values, jnp.uint8)
    arena = _seg_scatter(state[poolid], jnp.int32(off), values)
    new_state = copy_state(state)
    new_state[poolid] = arena
    return new_state, Handle((arena,))


def dart_gather_typed(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                      gptr: GlobalPtr, shape, dtype, engine=None):
    """Typed gather: each row's value at ``gptr.addr`` decoded to its
    dtype → ``(n_rows, *shape)``.  Slice *and* decode run inside the
    single counted jitted dispatch (:func:`_seg_gather_typed`), so the
    engine's ``dispatch_count`` covers the whole typed op — previously
    the vmap decode ran eagerly outside it and went uncounted."""
    poolid, _, off = deref(heap, teams_by_slot, gptr)
    state = _pre_collective(state, poolid, engine)
    vals = _seg_gather_typed(state[poolid], jnp.int32(off), tuple(shape),
                             jnp.dtype(dtype))
    return vals, Handle((vals,))


def dart_scatter_typed(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                       gptr: GlobalPtr, values: jax.Array, engine=None):
    """Typed scatter: row i of ``values`` (``(n_rows, *shape)``, any
    dtype) lands at ``gptr.addr`` on unit i.  Encode + update run inside
    the single counted jitted dispatch (:func:`_seg_scatter_typed`)."""
    values = jnp.asarray(values)
    poolid, _, off = deref(heap, teams_by_slot, gptr)
    state = _pre_collective(state, poolid, engine)
    arena = _seg_scatter_typed(state[poolid], jnp.int32(off), values)
    new_state = copy_state(state)
    new_state[poolid] = arena
    return new_state, Handle((arena,))


def dart_allreduce(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                   gptr: GlobalPtr, shape, dtype, op: str = "sum",
                   engine=None):
    """All-reduce the typed value at gptr.addr across rows; the result
    replaces every row's copy.  Returns (new_state, reduced_value)."""
    poolid, _, off = deref(heap, teams_by_slot, gptr)
    state = _pre_collective(state, poolid, engine)
    arena, red = _seg_allreduce(state[poolid], jnp.int32(off),
                                tuple(shape), jnp.dtype(dtype), op)
    new_state = copy_state(state)
    new_state[poolid] = arena
    return new_state, red


def dart_barrier(state: Optional[HeapState] = None) -> None:
    """Host-plane barrier: fence the device queue (single-controller)."""
    if state:
        jax.block_until_ready(list(state.values()))
    else:
        jax.block_until_ready(jnp.zeros(()))
