"""DART collective communication (paper §III, §IV.B.5).

The paper implements DART collectives "straightforwardly by using the
MPI-3 collective counterparts", after resolving the team → communicator
translation.  We do the same against the JAX substrate:

* **Device plane** (inside ``shard_map``): team → ``axis_index_groups``
  (the JAX analogue of a sub-communicator).  ``psum`` lacks group
  support on some backends, so the team all-reduce is decomposed into
  reduce-scatter + all-gather — the canonical ring decomposition, and
  incidentally the DART-style construction of a collective from
  one-sided phases.

* **Host plane**: collectives over heap segments (bcast/scatter/gather)
  are expressed as row motions on the arena via jitted gather/scatter.

``dart_barrier`` on the host plane is a device-queue fence; inside a
step it is a zero-payload psum (token barrier).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from .globmem import HeapState, SymmetricHeap, from_bytes, nbytes_of
from .gptr import GlobalPtr
from .onesided import Handle, deref

# --------------------------------------------------------------------------
# Device-plane team collectives (call inside shard_map)
# --------------------------------------------------------------------------


def team_all_gather(x, axis: str, groups=None, tiled: bool = False):
    return jax.lax.all_gather(x, axis, axis_index_groups=groups, tiled=tiled)


def team_reduce_scatter(x, axis: str, groups=None):
    return jax.lax.psum_scatter(x, axis, axis_index_groups=groups,
                                tiled=True)


def team_psum(x, axis: str, groups=None):
    """Team all-reduce.

    With groups: reduce-scatter + all-gather (RS+AG) over a padded
    leading axis — ``lax.psum`` does not accept ``axis_index_groups`` on
    the CPU/interpret path.  Without groups: plain psum.
    """
    if groups is None:
        return jax.lax.psum(x, axis)
    g = len(groups[0])
    flat = x.reshape(-1)
    pad = (-flat.size) % g
    flat = jnp.pad(flat, (0, pad))
    scat = jax.lax.psum_scatter(flat, axis, axis_index_groups=groups,
                                tiled=True)
    full = jax.lax.all_gather(scat, axis, axis_index_groups=groups,
                              tiled=True)
    if pad:
        full = full[:-pad]
    return full.reshape(x.shape)


def team_pmax(x, axis: str, groups=None):
    return jax.lax.pmax(x, axis, axis_index_groups=groups)


def team_all_to_all(x, axis: str, split_axis: int, concat_axis: int,
                    groups=None):
    return jax.lax.all_to_all(x, axis, split_axis, concat_axis,
                              axis_index_groups=groups, tiled=True)


def team_broadcast(x, axis: str, root_rel: int, groups=None):
    """Broadcast from the team-relative root: all_gather + static pick."""
    g = jax.lax.all_gather(x, axis, axis_index_groups=groups)
    return jax.lax.index_in_dim(g, root_rel, axis=0, keepdims=False)


def team_barrier(axis: str, groups=None):
    """Token barrier: a zero-payload team reduction."""
    return team_psum(jnp.zeros((), jnp.int32) + 1, axis, groups)


# --------------------------------------------------------------------------
# Host-plane collectives over heap segments
# --------------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=0, static_argnums=(2,))
def _rows_bcast(arena, root_row, n_rows):
    row = jax.lax.dynamic_slice(arena, (root_row, jnp.uint32(0)),
                                (1, arena.shape[1]))
    return jnp.broadcast_to(row, (n_rows, arena.shape[1])).astype(arena.dtype)


def dart_bcast(state: HeapState, heap: SymmetricHeap, teams_by_slot,
               root_gptr: GlobalPtr, nbytes: int):
    """Broadcast ``nbytes`` at the root's allocation to every row of the
    segment (team members all see the root's bytes at the same offset)."""
    poolid, row, off = deref(heap, teams_by_slot, root_gptr)
    arena = state[poolid]
    src = jax.lax.dynamic_slice(arena, (jnp.uint32(row), jnp.uint32(off)),
                                (1, nbytes))
    tiled = jnp.broadcast_to(src, (arena.shape[0], nbytes))
    arena = jax.lax.dynamic_update_slice(arena, tiled,
                                         (jnp.uint32(0), jnp.uint32(off)))
    new_state = dict(state)
    new_state[poolid] = arena
    return new_state, Handle((arena,))


def dart_gather(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                gptr: GlobalPtr, per_unit_nbytes: int):
    """Gather each row's ``per_unit_nbytes`` at gptr.addr → host value of
    shape (n_rows, per_unit_nbytes) uint8."""
    poolid, _, off = deref(heap, teams_by_slot, gptr)
    arena = state[poolid]
    out = jax.lax.dynamic_slice(
        arena, (jnp.uint32(0), jnp.uint32(off)),
        (arena.shape[0], per_unit_nbytes))
    return out, Handle((out,))


def dart_scatter(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                 gptr: GlobalPtr, values: jax.Array):
    """Scatter row i of ``values`` (uint8[n_rows, nbytes]) to unit i."""
    poolid, _, off = deref(heap, teams_by_slot, gptr)
    arena = state[poolid]
    values = jnp.asarray(values, jnp.uint8)
    arena = jax.lax.dynamic_update_slice(arena, values,
                                         (jnp.uint32(0), jnp.uint32(off)))
    new_state = dict(state)
    new_state[poolid] = arena
    return new_state, Handle((arena,))


def dart_allreduce(state: HeapState, heap: SymmetricHeap, teams_by_slot,
                   gptr: GlobalPtr, shape, dtype, op: str = "sum"):
    """All-reduce the typed value at gptr.addr across rows; the result
    replaces every row's copy.  Returns (new_state, reduced_value)."""
    poolid, _, off = deref(heap, teams_by_slot, gptr)
    n = nbytes_of(shape, dtype)
    arena = state[poolid]
    raw = jax.lax.dynamic_slice(arena, (jnp.uint32(0), jnp.uint32(off)),
                                (arena.shape[0], n))
    vals = jax.vmap(lambda r: from_bytes(r, shape, dtype))(raw)
    red = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min,
           "prod": jnp.prod}[op](vals, axis=0)
    from .globmem import to_bytes
    payload = jnp.broadcast_to(to_bytes(red)[None, :], (arena.shape[0], n))
    arena = jax.lax.dynamic_update_slice(arena, payload,
                                         (jnp.uint32(0), jnp.uint32(off)))
    new_state = dict(state)
    new_state[poolid] = arena
    return new_state, red


def dart_barrier(state: Optional[HeapState] = None) -> None:
    """Host-plane barrier: fence the device queue (single-controller)."""
    if state:
        jax.block_until_ready(list(state.values()))
    else:
        jax.block_until_ready(jnp.zeros(()))
