"""Atomic one-sided memory operations (paper §IV.B.6).

The MCS lock requires ``fetch_and_op`` (here: fetch-and-store /
fetch-and-add) and ``compare_and_swap`` with MPI-3 RMA atomicity, plus a
zero-byte notification channel (the paper blocks in ``MPI_Recv`` and the
releaser sends a zero-size message).

Where this lives on TPU: the *data plane* inside a step is SPMD and
dataflow-ordered, so locks are unnecessary there by construction
(DESIGN.md §2, assumption change 1).  Real concurrency in a JAX
framework is on the **host control plane**: checkpoint writer threads,
serving request handlers, and the elastic coordinator.  The providers
below give that plane MPI-3-equivalent atomics:

* :class:`ThreadedAtomics` — in-process provider; every cell op holds a
  per-provider mutex (the atomicity guarantee), and the notification
  channel is a per-unit ``queue.Queue`` (blocking ``recv`` ≙
  ``MPI_Recv`` of a zero-size message).

* On-device design (documented, exercised in ``kernels/``): cells map to
  SMEM words, fetch_and_op/CAS to Pallas semaphore protocols —
  ``pltpu.SemaphoreType.REGULAR`` signal/wait is the TPU-native analogue
  of the zero-byte wakeup message.

Cell placement is tracked so the (beyond-paper §VI) balanced-tail
placement can be measured: every cell knows its home unit and the
provider counts per-home accesses (the "communication congestion on
unit 0" the paper flags).
"""

from __future__ import annotations

import abc
import dataclasses
import queue
import threading
from collections import defaultdict
from typing import Callable, Dict, Hashable, Tuple


@dataclasses.dataclass(frozen=True)
class Cell:
    """A globally addressable atomic integer cell."""
    name: Hashable
    home_unit: int


class AtomicsProvider(abc.ABC):
    """MPI-3-RMA-equivalent atomic ops on integer cells."""

    @abc.abstractmethod
    def make_cell(self, name: Hashable, home_unit: int, init: int) -> Cell: ...

    @abc.abstractmethod
    def fetch_and_store(self, cell: Cell, value: int) -> int: ...

    @abc.abstractmethod
    def fetch_and_add(self, cell: Cell, value: int) -> int: ...

    @abc.abstractmethod
    def compare_and_swap(self, cell: Cell, expected: int,
                         desired: int) -> int:
        """Returns the *old* value (swap happened iff old == expected)."""

    @abc.abstractmethod
    def load(self, cell: Cell) -> int: ...

    @abc.abstractmethod
    def store(self, cell: Cell, value: int) -> None: ...

    def free_cell(self, cell: Cell) -> None:
        """Release a cell's backing storage (LockService.destroy_lock).
        Default no-op for providers without reclaimable cells."""

    # zero-byte notification channel (MPI_Send/Recv of size 0, §IV.B.6)
    @abc.abstractmethod
    def notify(self, unit: int, tag: Hashable) -> None: ...

    @abc.abstractmethod
    def wait_notify(self, unit: int, tag: Hashable,
                    timeout: float = None) -> None: ...


class ThreadedAtomics(AtomicsProvider):
    """In-process provider: units are threads (the test/control plane)."""

    def __init__(self, n_units: int):
        self.n_units = n_units
        self._mutex = threading.Lock()
        self._cells: Dict[Hashable, int] = {}
        self._inbox: Dict[Tuple[int, Hashable], queue.Queue] = defaultdict(
            queue.Queue)
        #: per-home-unit atomic-op counter (congestion accounting, §VI)
        self.home_traffic: Dict[int, int] = defaultdict(int)

    def make_cell(self, name, home_unit, init) -> Cell:
        with self._mutex:
            if name in self._cells:
                raise ValueError(f"cell {name!r} already exists")
            self._cells[name] = init
        return Cell(name=name, home_unit=home_unit)

    def free_cell(self, cell: Cell) -> None:
        with self._mutex:
            self._cells.pop(cell.name, None)

    def _rmw(self, cell: Cell, fn: Callable[[int], int]) -> int:
        with self._mutex:
            old = self._cells[cell.name]
            self._cells[cell.name] = fn(old)
            self.home_traffic[cell.home_unit] += 1
            return old

    def fetch_and_store(self, cell, value):
        return self._rmw(cell, lambda old: value)

    def fetch_and_add(self, cell, value):
        return self._rmw(cell, lambda old: old + value)

    def compare_and_swap(self, cell, expected, desired):
        with self._mutex:
            old = self._cells[cell.name]
            if old == expected:
                self._cells[cell.name] = desired
            self.home_traffic[cell.home_unit] += 1
            return old

    def load(self, cell):
        with self._mutex:
            self.home_traffic[cell.home_unit] += 1
            return self._cells[cell.name]

    def store(self, cell, value):
        with self._mutex:
            self._cells[cell.name] = value
            self.home_traffic[cell.home_unit] += 1

    def notify(self, unit, tag):
        self._inbox[(unit, tag)].put(None)

    def wait_notify(self, unit, tag, timeout=None):
        self._inbox[(unit, tag)].get(timeout=timeout)
