"""Shared-memory windows: zero-copy local access (paper §VI future work).

The paper's planned extension: "enable the MPI-3 shared-memory window
option for DART, which provides true zero-copy mechanisms, as opposed
to traditional single-copy mechanisms … especially for small message
sizes, intra- and inter-NUMA communication becomes a lot more
efficient."

DART-JAX analogue: when the target unit's partition is host-visible
(CPU backend, or a TPU host reading its own chips' HBM through dlpack),
the shm plane bypasses jitted dispatch in BOTH directions:

* **reads** — ``dart_shm_view`` returns a zero-copy numpy view of the
  addressed bytes (no dynamic-slice dispatch, no buffer copy).  The
  returned view stays read-only; with the write plane below it is a
  **live window** on the arena (MPI-3 shm semantics), not an epoch
  snapshot — a later shm put through the same window is visible in it.
* **writes** — ``dart_shm_put`` performs a locked host-side write into
  the arena's buffer and re-installs the arena under ``engine.lock``,
  exactly like a donating flush does, so XLA dataflow stays
  authoritative and program order holds against queued epochs, the
  ProgressPlane daemon, and the fault plane's failed-lane fail-fast.
* **collectives** — ``try_shm_bcast``/``try_shm_gather[_typed]``/
  ``try_shm_scatter[_typed]`` serve intra-node bcast/gather/scatter as
  memcpy loops through the window with ZERO jitted dispatches when the
  pool is SHM-writable (single-controller: one pool arena backs every
  member, so the locality proof is per pool — the per-subtree engine
  fallback of a multi-node tree degenerates to a per-pool fallback).

Pointers minted by ``dart_team_memalloc_shared`` (or ``ctx.alloc``'s
default ``shm=True``) carry ``FLAG_SHM`` to mark eligibility; actual
routing additionally requires the backing arena to be host-visible
(readable: dlpack) and, for writes, host-writable (a stable
``unsafe_buffer_pointer`` the host can store through).  Support is
probed ONCE per pool and cached per ``(context, poolid)`` —
``invalidate_shm_cache`` drops entries on ``dart_team_destroy`` /
``dart_exit``.  The cache used to be one boolean per *context*, so the
first probed pool poisoned routing for every other pool under mixed
visibility (host-visible CPU arena + device-only arena); it is keyed
by poolid now.

Measured effect (benchmarks/out/BENCH_engine.json, ``shm_plane``):
the ~300 µs constant per-op jitted dispatch drops to single-digit µs
for intra-node puts, and intra-node broadcast costs zero jitted
dispatches — the paper's "a lot more efficient for small messages"
expectation, now on the write side too.
"""

from __future__ import annotations

import contextlib
import ctypes
import enum
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from .faults import ShmBoundsError
from .globmem import nbytes_of
from .gptr import FLAG_SHM, GlobalPtr
from .onesided import Handle, _check_strided, _to_host_bytes, deref


class Locality(enum.Enum):
    """Where a deref'd target can be serviced (paper §VI routing)."""
    SHM_LOCAL = "shm_local"     # zero-copy host view, no jitted dispatch
    REMOTE = "remote"           # jitted arena dynamic-slice dispatch


# --------------------------------------------------------------------------
# Per-pool support probe + cache
# --------------------------------------------------------------------------


def _writable_arena_view(arena: jax.Array) -> np.ndarray:
    """Host-writable uint8 view of the arena's device buffer.

    ``np.from_dlpack`` on the CPU backend is read-only by design, so
    the write plane maps the buffer through its raw pointer instead.
    The view has NO lifetime anchor on the buffer — callers must hold
    the engine lock and keep ``arena`` alive for the view's whole use
    (the shm plane only ever uses it inside one locked write).
    """
    arena.block_until_ready()
    ptr = arena.unsafe_buffer_pointer()
    buf = (ctypes.c_uint8 * int(arena.size)).from_address(ptr)
    return np.frombuffer(buf, dtype=np.uint8).reshape(arena.shape)


def _probe_pool_locked(ctx, poolid: int) -> Tuple[bool, bool]:
    """Under the engine lock: ``(readable, writable)`` for ``poolid``.

    Cached per ``(context, poolid)`` in ``ctx._shm_cache`` — the
    classifier sits on the hot get path, so the dlpack/pointer probe
    must not re-run per deref (``ctx._shm_probe_count`` counts actual
    probes; tests pin it flat in the steady state).  Mixed-visibility
    heaps are why the key is the poolid: one pool's visibility proves
    nothing about another's.
    """
    cache: Optional[Dict[int, Tuple[bool, bool]]]
    cache = getattr(ctx, "_shm_cache", None)
    if cache is None:
        cache = {}
        try:
            ctx._shm_cache = cache
        except AttributeError:          # holder without attribute support
            cache = None
    if cache is not None and poolid in cache:
        return cache[poolid]
    arena = ctx.state[poolid]
    try:
        ctx._shm_probe_count = getattr(ctx, "_shm_probe_count", 0) + 1
    except AttributeError:
        pass
    try:
        np.from_dlpack(arena)
        readable = True
    except Exception:   # noqa: BLE001 - any failure means "not visible"
        readable = False
    writable = False
    if readable:
        try:
            _writable_arena_view(arena)
            writable = True
        except Exception:   # noqa: BLE001
            writable = False
    result = (readable, writable)
    if cache is not None:
        cache[poolid] = result
    return result


def invalidate_shm_cache(ctx, poolid: Optional[int] = None) -> None:
    """Drop the per-pool shm support cache — one pool's entry, or (with
    ``poolid=None``) the whole cache.  Called by ``dart_team_destroy``
    (the dropped window's pool) and ``dart_exit`` (everything): a
    destroyed pool's poolid is never reused, but the stale entry would
    leak, and a re-init must re-probe."""
    cache = getattr(ctx, "_shm_cache", None)
    if cache is not None:
        if poolid is None:
            cache.clear()
        else:
            cache.pop(poolid, None)
    # defensively retire the legacy one-bool-per-context cache so an
    # old-style reader can never see a stale positive after teardown
    if getattr(ctx, "_shm_supported", None) is not None:
        try:
            ctx._shm_supported = None
        except AttributeError:
            pass


def _engine_guard(ctx):
    engine = getattr(ctx, "engine", None)
    return engine, (engine.lock if engine is not None
                    else contextlib.nullcontext())


def shm_supported(ctx, poolid=None) -> bool:
    """True when the addressed pool's arena is host-visible.

    Probes the *addressed* pool when ``poolid`` is given (an arbitrary
    pool's visibility does not prove another's), and reports False —
    instead of raising — when the pool is absent or the heap state is
    empty (after ``dart_exit``).  The probe result is cached per
    ``(context, poolid)``; without an explicit ``poolid`` the first
    live pool is probed (a backend-visibility convenience — its cache
    entry is still keyed by that pool's id).
    """
    # liveness first, cache second: the cache records a live pool's
    # host-visibility, which says nothing about whether the addressed
    # pool (or any pool, after dart_exit) still exists.  The probe
    # dlpacks a live arena, so it holds the engine lock like every
    # other raw-state reader (donation safety).
    engine, guard = _engine_guard(ctx)
    with guard:
        if not ctx.state:
            return False        # post-exit: nothing is addressable
        if poolid is None:
            poolid = next(iter(ctx.state))
        elif poolid not in ctx.state:
            return False        # addressed pool is gone
        return _probe_pool_locked(ctx, poolid)[0]


def shm_writable(ctx, poolid=None) -> bool:
    """True when the addressed pool's arena is host-WRITABLE (the shm
    write plane's routing predicate; implies :func:`shm_supported`).
    Same liveness/caching rules as :func:`shm_supported`."""
    engine, guard = _engine_guard(ctx)
    with guard:
        if not ctx.state:
            return False
        if poolid is None:
            poolid = next(iter(ctx.state))
        elif poolid not in ctx.state:
            return False
        return _probe_pool_locked(ctx, poolid)[1]


# --------------------------------------------------------------------------
# Locality classifier
# --------------------------------------------------------------------------


def _classify_locked(ctx, gptr: GlobalPtr) -> Tuple[Locality, int, int, int]:
    """Deref + cached probe in ONE step: ``(locality, poolid, row,
    off)``.  Caller holds the engine lock (or has no engine).  This is
    the hoisted hot-path form — the public :func:`classify_locality`
    and the read/write routes below all build on it, so a routed get
    does a single lock acquisition for deref + probe + flush + view
    instead of re-taking the lock per layer."""
    poolid, row, off = deref(ctx.heap, ctx.teams_by_slot, gptr)
    if not gptr.is_shm:
        return Locality.REMOTE, poolid, row, off
    if poolid not in ctx.state:
        return Locality.REMOTE, poolid, row, off
    if not _probe_pool_locked(ctx, poolid)[0]:
        return Locality.REMOTE, poolid, row, off
    return Locality.SHM_LOCAL, poolid, row, off


def classify_locality(ctx, gptr: GlobalPtr) -> Locality:
    """Locality classifier used on deref by the runtime's routed paths.

    A target is SHM_LOCAL when its pointer was minted by
    ``dart_team_memalloc_shared`` (FLAG_SHM) *and* the backing arena is
    host-visible on this controller (CPU backend, or same-host HBM via
    dlpack).  Everything else takes the jitted one-sided path.
    """
    if not gptr.is_shm:
        return Locality.REMOTE
    engine, guard = _engine_guard(ctx)
    with guard:
        return _classify_locked(ctx, gptr)[0]


def mint_shm(gptr: GlobalPtr) -> GlobalPtr:
    """Return ``gptr`` with ``FLAG_SHM`` set: marks it *eligible* for
    the zero-copy plane — actual routing still depends on the backing
    arena being host-visible (:func:`classify_locality`)."""
    return GlobalPtr(unitid=gptr.unitid, segid=gptr.segid,
                     flags=gptr.flags | FLAG_SHM, addr=gptr.addr)


def dart_team_memalloc_shared(ctx, teamid: int,
                              nbytes_per_unit: int) -> GlobalPtr:
    """Collective aligned allocation whose pointers allow shm routing."""
    from .runtime import dart_team_memalloc_aligned
    return mint_shm(dart_team_memalloc_aligned(ctx, teamid,
                                               nbytes_per_unit))


# --------------------------------------------------------------------------
# Read side: zero-copy views
# --------------------------------------------------------------------------


def _check_headroom(ctx, poolid: int, row: int, off: int,
                    nbytes: int) -> None:
    """Typed bounds check against the pool's per-unit partition: a
    shape/dtype whose byte span overruns ``pool_bytes`` used to
    silently truncate the view slice and then die on a bare numpy
    reshape ``ValueError``; it raises :class:`ShmBoundsError` (lane-
    addressed, PR 9 error ladder) before any slicing now."""
    pool_bytes = ctx.heap.pools[poolid].pool_bytes
    if off < 0 or off + nbytes > pool_bytes:
        err = ShmBoundsError(
            f"shm window access overruns the unit partition: "
            f"off {off} + {nbytes} bytes > pool_bytes {pool_bytes} "
            f"(pool {poolid}, row {row})")
        err.poolid, err.row, err.unit = poolid, row, None
        err.off, err.nbytes = off, nbytes
        raise err


def dart_shm_view(ctx, gptr: GlobalPtr, shape: Tuple[int, ...],
                  dtype) -> np.ndarray:
    """Zero-copy read-only view of the addressed bytes.

    Requires a FLAG_SHM pointer and a host-visible arena (CPU backend /
    same-host HBM via dlpack).  Falls back with an explicit error
    rather than silently copying.  The view is a **live window**: a
    later ``dart_shm_put`` through the same arena is visible in it
    (writes that flush a jitted epoch re-install a NEW arena, which a
    previously taken view does not follow).
    """
    if not (gptr.flags & FLAG_SHM):
        raise ValueError("pointer was not minted by "
                         "dart_team_memalloc_shared (no FLAG_SHM)")
    view = try_shm_view(ctx, gptr, shape, dtype)
    if view is None:
        raise RuntimeError(
            "arena is not host-visible; use dart_get_blocking "
            "(zero-copy unavailable)")
    return view


def try_shm_view(ctx, gptr: GlobalPtr, shape: Tuple[int, ...],
                 dtype) -> Optional[np.ndarray]:
    """Routing form of :func:`dart_shm_view`: ``None`` when the target
    is not SHM_LOCAL (caller falls back to the engine path), the view
    otherwise.  One lock acquisition covers classify + flush + capture:

    * every read path flushes the target's ``(pool, row)`` lane first
      (ROADMAP completion semantics): queued puts to this target land
      before the view is taken, or direct callers see stale bytes.
      Per-target lane only — other targets' queued epochs keep
      accumulating.
    * flush + raw ``ctx.state`` read + the dlpack capture stay under
      the engine lock as ONE unit: a concurrent flush (e.g. the
      background ProgressPlane) donates the arena, so an unlocked read
      could dlpack a buffer deleted between the flush and the capture.
    """
    if not (gptr.flags & FLAG_SHM):
        return None
    n = nbytes_of(shape, dtype)
    engine, guard = _engine_guard(ctx)
    with guard:
        loc, poolid, row, off = _classify_locked(ctx, gptr)
        if loc is not Locality.SHM_LOCAL:
            return None
        _check_headroom(ctx, poolid, row, off, n)
        if engine is not None:
            engine.flush(poolid, row)
        arena = ctx.state[poolid]
        try:
            host = np.from_dlpack(arena)    # zero-copy on host backends
        except (TypeError, RuntimeError):
            return None
        flat = host[row, off:off + n]
    view = flat.view(np.dtype(dtype)).reshape(shape)
    view.flags.writeable = False
    return view


# --------------------------------------------------------------------------
# Write side: locked host-side puts (the tentpole)
# --------------------------------------------------------------------------


def _shm_write_locked(engine, ctx, poolid: int, row: int, off: int,
                      payload: np.ndarray, seg_len: int, stride: int,
                      count: int, unit: int) -> Handle:
    """The locked write protocol shared by puts and collectives.

    Order matters (docs/API.md "Shared-memory plane"):

    1. ``flush(pool, row)`` — queued jitted ops on the target lane land
       FIRST (program order; the flush may donate + replace the
       arena, so the arena is fetched after it).
    2. re-check the lane passively — if a queued op just failed in
       that flush, this write is ordered after the hole it left and
       must not apply.
    3. drain the pool's read fences — a dispatched-but-unmaterialized
       jitted gather still sources from this arena's buffer; the
       in-place write waits for it (the jitted path never needed this
       because its writes produce a NEW arena).
    4. write through the raw-pointer view and re-install the arena
       under ``engine.lock``, exactly like donation does — holder
       state stays the authoritative dataflow input for every later
       jitted op.
    """
    engine.flush(poolid, row)
    engine._check_lane_live(poolid, row, unit)
    arena = ctx.state[poolid]
    engine._drain_read_fences(poolid)
    host = _writable_arena_view(arena)
    if count == 1:
        host[row, off:off + payload.size] = payload
    else:
        for i in range(count):
            dst = off + i * stride
            host[row, dst:dst + seg_len] = payload[i * seg_len:
                                                   (i + 1) * seg_len]
    ctx.state[poolid] = arena
    engine.shm_puts += 1
    h = Handle((arena,))
    h.poolid, h.row = poolid, row
    return h


def try_shm_put(ctx, gptr: GlobalPtr, value, *, stride: int = 0,
                count: int = 1) -> Optional[Handle]:
    """Route a blocking put through the shm window when the target is
    SHM-writable; ``None`` otherwise (caller falls back to the engine).

    Semantics match the engine path bit-for-bit: same host staging
    (:func:`~repro.core.onesided._to_host_bytes` canonicalization),
    same strided-geometry validation and errors, same fault-plane
    enqueue boundary (injector poll + dead-unit/failed-lane
    fail-fast).  What changes is the cost: zero jitted dispatches —
    the write is a host memcpy under the engine lock.
    """
    if not (gptr.flags & FLAG_SHM):
        return None
    engine = getattr(ctx, "engine", None)
    if engine is None:
        return None
    payload = _to_host_bytes(value)
    with engine.lock:
        loc, poolid, row, off = _classify_locked(ctx, gptr)
        seg_len, stride, count = _check_strided(
            off, int(payload.size), stride, count,
            ctx.heap.pools[poolid].pool_bytes, "put")
        if loc is not Locality.SHM_LOCAL:
            return None
        if not _probe_pool_locked(ctx, poolid)[1]:
            return None         # readable but not writable: engine path
        engine._precheck_enqueue(poolid, row, gptr.unitid)
        return _shm_write_locked(engine, ctx, poolid, row, off, payload,
                                 seg_len, stride, count, gptr.unitid)


def dart_shm_put(ctx, gptr: GlobalPtr, value, *, stride: int = 0,
                 count: int = 1) -> Handle:
    """Zero-copy blocking put through the shm window (strict form of
    :func:`try_shm_put`: raises instead of falling back).  Returns a
    complete :class:`~repro.core.onesided.Handle` carrying the lane."""
    if not (gptr.flags & FLAG_SHM):
        raise ValueError("pointer was not minted by "
                         "dart_team_memalloc_shared (no FLAG_SHM)")
    h = try_shm_put(ctx, gptr, value, stride=stride, count=count)
    if h is None:
        raise RuntimeError(
            "arena is not host-writable; use dart_put / "
            "dart_put_blocking (zero-copy write unavailable)")
    return h


# --------------------------------------------------------------------------
# Intra-node shm-direct collectives
# --------------------------------------------------------------------------
#
# Single-controller locality proof: one pool arena backs every member
# row, so "every member is SHM_LOCAL" is exactly "the pool is
# host-writable" — probed once, cached per pool.  Each try_* routine
# returns None when the proof fails (or when the request would leave
# the engine kernels' masked-drop envelope), and the runtime wrapper
# falls back to the engine path for the whole team — the degenerate,
# per-pool form of the per-subtree fallback a multi-node tree would
# need.  Ordering matches collectives._pre_collective: the WHOLE pool
# flushes first (queued one-sided ops are ordered before the
# collective), then the memcpy loop runs under the same lock hold.


def _shm_collective_enter(ctx, gptr: GlobalPtr, off: int, nbytes: int):
    """Locked entry shared by the shm-direct collectives: routing
    proof + whole-pool flush + writable window.  Returns ``(engine,
    poolid, arena, host)`` or ``None`` to fall back.  Caller holds the
    engine lock."""
    engine = getattr(ctx, "engine", None)
    if engine is None:
        return None
    loc, poolid, _, _ = _classify_locked(ctx, gptr)
    if loc is not Locality.SHM_LOCAL:
        return None
    if not _probe_pool_locked(ctx, poolid)[1]:
        return None
    if off < 0 or off + nbytes > ctx.heap.pools[poolid].pool_bytes:
        # the jitted kernels mask out-of-range lanes (mode='drop');
        # keep that exact envelope by falling back instead of raising
        return None
    engine.flush(poolid)
    arena = ctx.state[poolid]
    engine._drain_read_fences(poolid)
    host = _writable_arena_view(arena)
    return engine, poolid, arena, host


def _shm_collective_exit(engine, ctx, poolid: int, arena) -> Handle:
    ctx.state[poolid] = arena
    engine.shm_collective_ops += 1
    return Handle((arena,))


def try_shm_bcast(ctx, root_gptr: GlobalPtr, nbytes: int
                  ) -> Optional[Handle]:
    """Shm-direct broadcast: the root row's ``nbytes`` window memcpy'd
    to every member row — zero jitted dispatches.  ``None`` = caller
    falls back to the engine collective."""
    if not (root_gptr.flags & FLAG_SHM):
        return None
    engine, guard = _engine_guard(ctx)
    if engine is None:
        return None
    with guard:
        poolid, root_row, off = deref(ctx.heap, ctx.teams_by_slot,
                                      root_gptr)
        entered = _shm_collective_enter(ctx, root_gptr, off, nbytes)
        if entered is None:
            return None
        engine, poolid, arena, host = entered
        seg = np.array(host[root_row, off:off + nbytes])   # copy: src row
        for r in range(host.shape[0]):
            host[r, off:off + nbytes] = seg
        return _shm_collective_exit(engine, ctx, poolid, arena)


def try_shm_gather(ctx, gptr: GlobalPtr, per_unit_nbytes: int):
    """Shm-direct byte gather: every row's window copied host-side →
    ``(n_rows, per_unit_nbytes)`` uint8 (same value type as the engine
    path).  ``None`` = fall back."""
    if not (gptr.flags & FLAG_SHM):
        return None
    engine, guard = _engine_guard(ctx)
    if engine is None:
        return None
    with guard:
        poolid, _, off = deref(ctx.heap, ctx.teams_by_slot, gptr)
        entered = _shm_collective_enter(ctx, gptr, off, per_unit_nbytes)
        if entered is None:
            return None
        engine, poolid, arena, host = entered
        raw = np.array(host[:, off:off + per_unit_nbytes])   # host copy
        engine.shm_collective_ops += 1
    import jax.numpy as jnp
    out = jnp.asarray(raw)
    return out, Handle((out,))


def try_shm_gather_typed(ctx, gptr: GlobalPtr, shape, dtype):
    """Shm-direct typed gather: every row's value decoded host-side →
    ``(n_rows, *shape)`` of ``dtype`` (byte-identical to the engine
    path's decode).  ``None`` = fall back."""
    if not (gptr.flags & FLAG_SHM):
        return None
    import jax.numpy as jnp
    dt = jnp.dtype(dtype)
    shape = tuple(shape)
    n_elems = (max(int(np.prod(shape, dtype=np.int64)), 1)
               if shape else 1)
    nbytes = n_elems * dt.itemsize
    engine, guard = _engine_guard(ctx)
    if engine is None:
        return None
    with guard:
        poolid, _, off = deref(ctx.heap, ctx.teams_by_slot, gptr)
        entered = _shm_collective_enter(ctx, gptr, off, nbytes)
        if entered is None:
            return None
        engine, poolid, arena, host = entered
        raw = np.array(host[:, off:off + nbytes])
        engine.shm_collective_ops += 1
    n_rows = raw.shape[0]
    vals = jnp.asarray(raw.view(dt).reshape((n_rows,) + shape))
    return vals, Handle((vals,))


def try_shm_scatter(ctx, gptr: GlobalPtr, values) -> Optional[Handle]:
    """Shm-direct byte scatter: row i of ``values`` (uint8
    ``(n_rows, nbytes)``) memcpy'd to unit i's window.  ``None`` =
    fall back (including shape mismatches: the engine path owns that
    error)."""
    if not (gptr.flags & FLAG_SHM):
        return None
    vh = np.asarray(values, np.uint8)
    engine, guard = _engine_guard(ctx)
    if engine is None:
        return None
    with guard:
        poolid, _, off = deref(ctx.heap, ctx.teams_by_slot, gptr)
        if (vh.ndim != 2
                or vh.shape[0] != ctx.heap.pools[poolid].n_rows):
            return None
        nbytes = int(vh.shape[1])
        entered = _shm_collective_enter(ctx, gptr, off, nbytes)
        if entered is None:
            return None
        engine, poolid, arena, host = entered
        host[:, off:off + nbytes] = vh
        return _shm_collective_exit(engine, ctx, poolid, arena)


def try_shm_scatter_typed(ctx, gptr: GlobalPtr, values
                          ) -> Optional[Handle]:
    """Shm-direct typed scatter: row i of ``values`` (``(n_rows,
    *shape)``, any dtype) encoded host-side — same canonicalization as
    the engine path (int64/float64 → 32-bit without x64) — and
    memcpy'd to unit i.  ``None`` = fall back."""
    if not (gptr.flags & FLAG_SHM):
        return None
    vh = np.asarray(values)
    canon = jax.dtypes.canonicalize_dtype(vh.dtype)
    if vh.dtype != canon:
        vh = vh.astype(canon)
    if vh.ndim < 1:
        return None
    rows_bytes = np.ascontiguousarray(
        vh.reshape(vh.shape[0], -1)).view(np.uint8)
    engine, guard = _engine_guard(ctx)
    if engine is None:
        return None
    with guard:
        poolid, _, off = deref(ctx.heap, ctx.teams_by_slot, gptr)
        if rows_bytes.shape[0] != ctx.heap.pools[poolid].n_rows:
            return None
        nbytes = int(rows_bytes.shape[1])
        entered = _shm_collective_enter(ctx, gptr, off, nbytes)
        if entered is None:
            return None
        engine, poolid, arena, host = entered
        host[:, off:off + nbytes] = rows_bytes
        return _shm_collective_exit(engine, ctx, poolid, arena)
