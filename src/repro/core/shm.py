"""Shared-memory windows: zero-copy local access (paper §VI future work).

The paper's planned extension: "enable the MPI-3 shared-memory window
option for DART, which provides true zero-copy mechanisms, as opposed
to traditional single-copy mechanisms … especially for small message
sizes, intra- and inter-NUMA communication becomes a lot more
efficient."

DART-JAX analogue: when the target unit's partition is host-visible
(CPU backend, or a TPU host reading its own chips' HBM through dlpack),
``dart_shm_view`` returns a **zero-copy numpy view** of the addressed
bytes — no jitted dynamic-slice dispatch, no buffer copy.  The view is
read-only (writes must go through ``dart_put`` so XLA dataflow stays
authoritative); pointers minted by ``dart_team_memalloc_shared`` carry
``FLAG_SHM`` to mark eligibility.

Measured effect (benchmarks/out/put_get.csv, `shm_view` rows): the
~300 µs constant per-get drops to ~2 µs — a direct reproduction of the
paper's "a lot more efficient for small messages" expectation.
"""

from __future__ import annotations

import contextlib
import enum
from typing import Tuple

import jax
import numpy as np

from .globmem import nbytes_of
from .gptr import FLAG_COLLECTIVE, FLAG_SHM, GlobalPtr
from .onesided import deref


class Locality(enum.Enum):
    """Where a deref'd target can be serviced (paper §VI routing)."""
    SHM_LOCAL = "shm_local"     # zero-copy host view, no jitted dispatch
    REMOTE = "remote"           # jitted arena dynamic-slice dispatch


def classify_locality(ctx, gptr: GlobalPtr) -> Locality:
    """Locality classifier used on deref by the runtime's get path.

    A target is SHM_LOCAL when its pointer was minted by
    ``dart_team_memalloc_shared`` (FLAG_SHM) *and* the backing arena is
    host-visible on this controller (CPU backend, or same-host HBM via
    dlpack).  Everything else takes the jitted one-sided path.
    """
    if not gptr.is_shm:
        return Locality.REMOTE
    poolid, _, _ = deref(ctx.heap, ctx.teams_by_slot, gptr)
    if not shm_supported(ctx, poolid):
        return Locality.REMOTE
    return Locality.SHM_LOCAL


def mint_shm(gptr: GlobalPtr) -> GlobalPtr:
    """Return ``gptr`` with ``FLAG_SHM`` set: marks it *eligible* for
    the zero-copy view — actual routing still depends on the backing
    arena being host-visible (:func:`classify_locality`)."""
    return GlobalPtr(unitid=gptr.unitid, segid=gptr.segid,
                     flags=gptr.flags | FLAG_SHM, addr=gptr.addr)


def dart_team_memalloc_shared(ctx, teamid: int,
                              nbytes_per_unit: int) -> GlobalPtr:
    """Collective aligned allocation whose pointers allow shm views."""
    from .runtime import dart_team_memalloc_aligned
    return mint_shm(dart_team_memalloc_aligned(ctx, teamid,
                                               nbytes_per_unit))


def dart_shm_view(ctx, gptr: GlobalPtr, shape: Tuple[int, ...],
                  dtype) -> np.ndarray:
    """Zero-copy read-only view of the addressed bytes.

    Requires a FLAG_SHM pointer and a host-visible arena (CPU backend /
    same-host HBM via dlpack).  Falls back with an explicit error
    rather than silently copying.
    """
    if not (gptr.flags & FLAG_SHM):
        raise ValueError("pointer was not minted by "
                         "dart_team_memalloc_shared (no FLAG_SHM)")
    poolid, row, off = deref(ctx.heap, ctx.teams_by_slot, gptr)
    # every read path flushes first (ROADMAP completion semantics):
    # queued puts to this target must land before the zero-copy view is
    # taken, or direct callers see stale bytes.  Per-target lane only —
    # other targets' queued epochs keep accumulating.  Flush + raw
    # ctx.state read + the dlpack capture stay under the engine lock as
    # ONE unit: a concurrent flush (e.g. the background ProgressPlane)
    # donates the arena, so an unlocked read could dlpack a buffer
    # deleted between the flush and the capture.
    engine = getattr(ctx, "engine", None)
    guard = engine.lock if engine is not None else contextlib.nullcontext()
    with guard:
        if engine is not None:
            engine.flush(poolid, row)
        arena = ctx.state[poolid]
        try:
            host = np.from_dlpack(arena)      # zero-copy on host backends
        except (TypeError, RuntimeError) as e:
            raise RuntimeError(
                "arena is not host-visible; use dart_get_blocking "
                f"(zero-copy unavailable: {e})") from None
    n = nbytes_of(shape, dtype)
    flat = host[row, off:off + n]
    view = flat.view(np.dtype(dtype)).reshape(shape)
    view.flags.writeable = False
    return view


def shm_supported(ctx, poolid=None) -> bool:
    """True when the current backend exposes host-visible arenas.

    Probes the *addressed* pool when ``poolid`` is given (an arbitrary
    pool's visibility does not prove another's), and reports False —
    instead of raising — when the pool is absent or the heap state is
    empty (after ``dart_exit``).  The positive/negative result is
    cached per context — the classifier sits on the hot get path, so
    the dlpack probe must not re-run per deref.
    """
    # liveness first, cache second: the cache records backend
    # host-visibility, which says nothing about whether the addressed
    # pool (or any pool, after dart_exit) still exists.  The probe
    # dlpacks a live arena, so it holds the engine lock like every
    # other raw-state reader (donation safety).
    engine = getattr(ctx, "engine", None)
    guard = engine.lock if engine is not None else contextlib.nullcontext()
    with guard:
        if not ctx.state:
            return False        # post-exit: nothing is addressable
        if poolid is not None and poolid not in ctx.state:
            return False        # addressed pool is gone
        cached = getattr(ctx, "_shm_supported", None)
        if cached is not None:
            return cached
        arena = (ctx.state[poolid] if poolid is not None
                 else next(iter(ctx.state.values())))
        try:
            np.from_dlpack(arena)
            ok = True
        except Exception:   # noqa: BLE001
            ok = False
    try:
        ctx._shm_supported = ok
    except AttributeError:      # holder without attribute support
        pass
    return ok
