"""Fault plane: the typed DART error ladder + a seedable fault injector.

DART's completion ladder (paper §III) and team/window machinery (§IV)
define *where* a one-sided op can fail — translation, enqueue,
dispatch, drain — but say nothing about what the runtime should do
when one does.  Zhou & Gracia's asynchronous-progress design
(arXiv:1609.08574) makes the progress entity exactly the component
that must survive and report partner failure; DASH (arXiv:1610.01482)
gives containers typed error contracts.  This module supplies both
halves for the reproduction:

* **the error taxonomy** — every runtime failure is a
  :class:`DartError` (itself a ``RuntimeError``, so pre-existing
  ``except RuntimeError`` / ``pytest.raises(RuntimeError)`` call sites
  keep working).  Subtypes name the failure domain:
  :class:`UnitFailedError` (the target unit is dead),
  :class:`FlushTimeoutError` (the per-flush deadline expired while
  retrying), :class:`RetriesExhaustedError` (the retry budget ran
  out), and :class:`TransientDispatchFault` (an *injected* transient —
  the only fault kind the engine's retry loop is allowed to absorb).
  The pre-existing ``WindowDestroyedError`` / ``OutOfGlobalMemory``
  (``repro.core.globmem``) are re-parented onto :class:`DartError`.
  Errors carry structured context (``poolid``/``row``/``unit``/
  ``teamid``) so handlers can route on the lane, not on message text.

* **the injector** — :class:`FaultPlane`, a seedable, deterministic
  schedule of :class:`FaultSpec` entries hooked at the CommEngine
  dispatch boundary (``dispatch_gate``), the enqueue path
  (``poll_enqueue``: lane poisoning, unit death at op N), and the
  progress plane's drain loop (``drain_gate``).  Determinism is the
  point: a chaos test replays the *same* fault schedule against the
  fault-free oracle and asserts surviving lanes are byte-identical.

This module is stdlib-only (no JAX) so both ``globmem`` and
``onesided`` can import the ladder without cycles.
"""

from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Dict, List, Optional

__all__ = [
    "DartError", "UnitFailedError", "FlushTimeoutError",
    "RetriesExhaustedError", "ShmBoundsError", "TransientDispatchFault",
    "FaultSpec", "FaultPlane",
]


# --------------------------------------------------------------------------
# Typed error ladder
# --------------------------------------------------------------------------


class DartError(RuntimeError):
    """Base of the typed DART failure ladder.

    A ``RuntimeError`` subclass on purpose: the runtime raised bare
    ``RuntimeError`` before the ladder existed, so every established
    ``except RuntimeError`` handler (and test) stays correct.
    Instances carry structured context on attributes — ``None`` when
    the domain does not apply.
    """

    poolid: Optional[int] = None
    row: Optional[int] = None
    unit: Optional[int] = None
    teamid: Optional[int] = None


class UnitFailedError(DartError):
    """The op's target unit has been declared dead (heartbeat sweep or
    injected death).  Raised at enqueue (fail-fast on a dead unit's
    lanes) and by handles whose queued ops were doomed by the death."""


class FlushTimeoutError(DartError):
    """The per-flush deadline expired while a run was still retrying
    transient dispatch faults; the run's handles fail with this."""


class RetriesExhaustedError(DartError):
    """A run kept faulting past the engine's retry budget."""


class ShmBoundsError(DartError, ValueError):
    """A shared-memory window access (``dart_shm_view`` / shm-plane
    read) whose byte span overruns the unit's pool partition.

    Previously the view sliced ``host[row, off:off+n]`` unchecked: the
    overrun silently truncated and surfaced as a bare numpy reshape
    ``ValueError``.  Also a ``ValueError`` so pre-existing handlers of
    that symptom keep catching the (now typed, lane-addressed) error.
    Carries ``poolid``/``row``/``off``/``nbytes``.
    """

    off: Optional[int] = None
    nbytes: Optional[int] = None


class TransientDispatchFault(DartError):
    """An injected transient failure of one dispatch attempt.

    ``issued`` reports whether the attempt's kernel ran before the
    fault struck (a *post*-dispatch fault): puts/gets are idempotent
    and retry either way, but accumulate runs may retry **only** when
    ``issued`` is False — the at-most-once rule (re-issuing an RMW
    whose first attempt may have applied would double-apply it).
    """

    def __init__(self, message: str, *, issued: bool = False):
        super().__init__(message)
        self.issued = issued


# --------------------------------------------------------------------------
# Fault specs + the injector
# --------------------------------------------------------------------------

#: spec kinds gated at the dispatch boundary
_DISPATCH_KINDS = ("fail", "drop", "delay")
#: spec kinds polled at enqueue
_ENQUEUE_KINDS = ("poison", "unit_dead")
#: spec kinds gated in the progress plane's drain loop
_DRAIN_KINDS = ("skip_drain",)


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault.

    ``kind``:

    * ``'fail'`` — raise :class:`TransientDispatchFault` at the
      dispatch gate; ``issued=True`` strikes *after* the kernel ran.
    * ``'drop'`` — alias of a never-issued ``'fail'`` (the dispatch is
      dropped before any kernel runs).
    * ``'delay'`` — sleep ``delay_s`` at the pre-dispatch gate.
    * ``'poison'`` — mark the matching ``(pool, row)`` lane failed at
      enqueue; subsequent enqueues fail fast until the lane is cleared.
    * ``'unit_dead'`` — declare the matching op's target unit dead at
      enqueue (the "unit dies at op N" schedule; ``after=N-1``).
    * ``'skip_drain'`` — suppress the progress plane's background
      drain of the matching lane (foreground flushes are unaffected).

    ``poolid``/``row``/``unit`` are match filters (``None`` = any);
    ``op_kind`` filters dispatch gates by run kind (``put``/``get``/
    ``acc``/``gacc``).  The spec skips its first ``after`` matching
    events, then fires ``times`` times (``times <= 0`` = unlimited).
    ``seen``/``fired`` are runtime counters.
    """

    kind: str
    poolid: Optional[int] = None
    row: Optional[int] = None
    unit: Optional[int] = None
    op_kind: Optional[str] = None
    after: int = 0
    times: int = 1
    delay_s: float = 0.0
    issued: bool = False
    seen: int = 0
    fired: int = 0

    def __post_init__(self):
        known = _DISPATCH_KINDS + _ENQUEUE_KINDS + _DRAIN_KINDS
        if self.kind not in known:
            raise ValueError(
                f"unknown fault kind {self.kind!r} (one of {known})")

    def _matches(self, poolid: Optional[int], row: Optional[int],
                 unit: Optional[int] = None,
                 op_kind: Optional[str] = None) -> bool:
        return ((self.poolid is None or self.poolid == poolid)
                and (self.row is None or self.row == row)
                and (self.unit is None or unit is None
                     or self.unit == unit)
                and (self.op_kind is None or op_kind is None
                     or self.op_kind == op_kind))

    def _due(self) -> bool:
        """Bump ``seen`` for a matching event; True when this firing
        is inside the ``(after, after + times]`` window."""
        self.seen += 1
        if self.seen <= self.after:
            return False
        if self.times > 0 and self.fired >= self.times:
            return False
        self.fired += 1
        return True


class FaultPlane:
    """Seedable deterministic fault injector for one CommEngine.

    Two sources of faults compose:

    * **scheduled** — :meth:`schedule` registers :class:`FaultSpec`
      entries that fire at exact event counts (fully deterministic,
      the chaos harness's tool of choice);
    * **rates** — ``fail_rate``/``post_fail_rate``/``delay_rate``
      draw from a ``random.Random(seed)`` stream per pre/post gate,
      deterministic given the seed and the call sequence.

    Thread-safe: the engine's dispatch path, N enqueueing threads, and
    the progress-plane daemon may all hit the gates concurrently.  The
    plane never calls back into the engine, so its lock nests freely
    inside ``engine.lock``.
    """

    def __init__(self, seed: int = 0, *, fail_rate: float = 0.0,
                 post_fail_rate: float = 0.0, delay_rate: float = 0.0,
                 delay_s: float = 0.0):
        for name, rate in (("fail_rate", fail_rate),
                           ("post_fail_rate", post_fail_rate),
                           ("delay_rate", delay_rate)):
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {rate}")
        self.seed = int(seed)
        self.rng = random.Random(seed)
        self.fail_rate = float(fail_rate)
        self.post_fail_rate = float(post_fail_rate)
        self.delay_rate = float(delay_rate)
        self.delay_s = float(delay_s)
        self.specs: List[FaultSpec] = []
        self._lock = threading.Lock()
        self.counters: Dict[str, int] = {
            "gates_pre": 0, "gates_post": 0, "enqueue_polls": 0,
            "injected_fails": 0, "injected_drops": 0,
            "injected_delays": 0, "poisons": 0, "unit_deaths": 0,
            "drains_skipped": 0,
        }

    def schedule(self, spec: Optional[FaultSpec] = None, /,
                 **kw) -> FaultSpec:
        """Register a spec (or build one from keyword fields)."""
        if spec is None:
            spec = FaultSpec(**kw)
        elif kw:
            raise TypeError("pass a FaultSpec or fields, not both")
        with self._lock:
            self.specs.append(spec)
        return spec

    # -- engine dispatch boundary ---------------------------------------

    def dispatch_gate(self, op_kind: str, poolid: int, row: int,
                      phase: str) -> None:
        """Called by the engine around every dispatch attempt
        (``phase`` ``'pre'`` before the kernel, ``'post'`` after).
        Sleeps for delay faults; raises
        :class:`TransientDispatchFault` for fail/drop faults."""
        sleep_s = 0.0
        fault: Optional[str] = None
        with self._lock:
            self.counters["gates_pre" if phase == "pre"
                          else "gates_post"] += 1
            for spec in self.specs:
                if spec.kind not in _DISPATCH_KINDS:
                    continue
                fires_post = spec.kind == "fail" and spec.issued
                if (phase == "post") != fires_post:
                    continue
                if not spec._matches(poolid, row, op_kind=op_kind):
                    continue
                if not spec._due():
                    continue
                if spec.kind == "delay":
                    sleep_s = max(sleep_s, spec.delay_s)
                    self.counters["injected_delays"] += 1
                else:
                    self.counters["injected_drops" if spec.kind == "drop"
                                  else "injected_fails"] += 1
                    fault = spec.kind
                    break
            if fault is None:
                # rate-driven faults: one deterministic draw per gate
                r = self.rng.random()
                if phase == "pre":
                    if self.fail_rate and r < self.fail_rate:
                        self.counters["injected_fails"] += 1
                        fault = "fail"
                    elif self.delay_rate and r < (self.fail_rate
                                                  + self.delay_rate):
                        self.counters["injected_delays"] += 1
                        sleep_s = self.delay_s
                elif self.post_fail_rate and r < self.post_fail_rate:
                    self.counters["injected_fails"] += 1
                    fault = "fail"
        if sleep_s > 0.0:
            time.sleep(sleep_s)
        if fault is not None:
            raise TransientDispatchFault(
                f"injected {fault} of {op_kind} dispatch on lane "
                f"(pool {poolid}, row {row}) [{phase}]",
                issued=phase == "post")

    # -- engine enqueue boundary ----------------------------------------

    def poll_enqueue(self, poolid: int, row: int,
                     unit: int) -> List[FaultSpec]:
        """Called by the engine on every enqueue; returns the poison/
        unit-death specs that fire on this op (the engine applies
        them: lane marked failed, unit marked dead)."""
        with self._lock:
            self.counters["enqueue_polls"] += 1
            out = []
            for spec in self.specs:
                if spec.kind not in _ENQUEUE_KINDS:
                    continue
                if not spec._matches(poolid, row, unit=unit):
                    continue
                if not spec._due():
                    continue
                self.counters["poisons" if spec.kind == "poison"
                              else "unit_deaths"] += 1
                out.append(spec)
            return out

    # -- progress-plane drain boundary ----------------------------------

    def drain_gate(self, poolid: int, row: int) -> bool:
        """Called by the progress plane before draining a lane; False
        suppresses this background drain (foreground flushes never
        consult this gate)."""
        with self._lock:
            for spec in self.specs:
                if spec.kind not in _DRAIN_KINDS:
                    continue
                if not spec._matches(poolid, row):
                    continue
                if not spec._due():
                    continue
                self.counters["drains_skipped"] += 1
                return False
        return True

    # -- observability ---------------------------------------------------

    def stats(self) -> Dict[str, int]:
        with self._lock:
            s = dict(self.counters)
            s["seed"] = self.seed
            s["n_specs"] = len(self.specs)
            s["specs_fired"] = sum(sp.fired for sp in self.specs)
            return s

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"FaultPlane(seed={self.seed}, specs={len(self.specs)}, "
                f"fail_rate={self.fail_rate})")
