"""Background progress plane: a daemon thread draining queued epochs.

The paper's passive-target model (§III) assumes one-sided traffic makes
progress without the target's involvement.  The queued host plane alone
does not deliver that: a submitter thread that enqueues puts and then
sleeps leaves the bytes stranded until some later call crosses a flush
point.  Zhou & Gracia's asynchronous-progress follow-up (PAPERS.md)
attacks exactly this gap with a helper thread inside the MPI runtime;
:class:`ProgressPlane` is our analogue over :class:`CommEngine`.

Design:

* one daemon thread per engine, woken by the engine's enqueue notifier
  (``CommEngine.set_progress_notifier``) through a condition variable —
  no polling while the queue is empty;
* a lane — one ``(poolid, row)`` pair, the unit of
  ``MPI_Win_flush_local`` in the paper's mapping — is flushed when it
  crosses ``watermark_bytes`` or ``watermark_ops``, or when its oldest
  op has sat queued for ``idle_s`` seconds (so small stragglers are
  never stranded);
* the sweep calls the ordinary per-target ``engine.flush(pool, row)``
  path, which serializes on the engine lock with every foreground
  flush, waiter, and raw-state reader — the plane adds no new
  synchronization rules, it is just another caller.  That includes the
  shm write plane (``shm.dart_shm_put`` and the shm-direct
  collectives): its flush-then-write-then-reinstall sequence runs
  under one ``engine.lock`` hold, so a drain-loop sweep either lands
  entirely before the host write or observes the re-installed arena
  after it — never a half-written window.

Lock ordering: the plane's condition variable is *never* held while
calling into the engine, and the engine's enqueue notifier is invoked
*after* the engine lock is released, so ``cond`` and ``engine.lock``
are never nested in either order.

Lifecycle mirrors ``serve/engine.py``'s loop thread: ``start()`` spawns
the daemon and registers the notifier; ``stop(drain=True)`` (the
default) unregisters, joins, and then flushes everything still queued —
shutdown flushes, it never drops.
"""

from __future__ import annotations

import threading
from typing import List, Optional

__all__ = ["ProgressPlane"]


class ProgressPlane:
    """Watermark/idle-deadline background flusher for one CommEngine.

    Instrumentation counters (read them from tests/benchmarks):

    * ``flushes`` — total background flush calls issued;
    * ``watermark_flushes`` / ``idle_flushes`` — split by trigger;
    * ``errors`` — exceptions raised by background flushes (the thread
      records and keeps running; handles carry the failure to their
      waiters through the normal ``_fail`` path);
    * ``drains_skipped`` — sweeps suppressed by an attached
      :class:`~repro.core.faults.FaultPlane` drain gate (chaos
      schedules use this to strand a lane and prove the foreground
      flush path still completes it).
    """

    def __init__(self, engine, *, watermark_bytes: int = 1 << 16,
                 watermark_ops: int = 32, idle_s: float = 0.005,
                 name: str = "dart-progress"):
        if watermark_bytes <= 0 or watermark_ops <= 0:
            raise ValueError("watermarks must be positive")
        if idle_s <= 0:
            raise ValueError("idle_s must be positive")
        self.engine = engine
        self.watermark_bytes = int(watermark_bytes)
        self.watermark_ops = int(watermark_ops)
        self.idle_s = float(idle_s)
        self.name = name
        self.flushes = 0
        self.watermark_flushes = 0
        self.idle_flushes = 0
        self.drains_skipped = 0
        self.errors: List[BaseException] = []
        self._cond = threading.Condition()
        self._wake = False
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle -------------------------------------------------------

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "ProgressPlane":
        if self.running:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, name=self.name,
                                        daemon=True)
        self.engine.set_progress_notifier(self._on_enqueue)
        self._thread.start()
        return self

    def stop(self, drain: bool = True, timeout: float = 5.0) -> None:
        """Stop the daemon.  With ``drain`` (default) everything still
        queued is flushed on the caller's thread after the join — queued
        ops are flushed, not dropped."""
        self.engine.set_progress_notifier(None)
        self._stop.set()
        with self._cond:
            self._wake = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None
        if drain:
            self.engine.flush()

    # -- engine-facing hook (called with engine lock NOT held) -----------

    def _on_enqueue(self) -> None:
        with self._cond:
            self._wake = True
            self._cond.notify_all()

    # -- daemon ----------------------------------------------------------

    def _next_timeout(self, now: float) -> Optional[float]:
        """Seconds until the earliest idle deadline, 0.0 if a lane has
        already crossed a watermark, or None when nothing is queued."""
        stats = self.engine.lane_stats()
        if not stats:
            return None
        deadline = None
        for ops, nbytes, oldest in stats.values():
            if ops >= self.watermark_ops or nbytes >= self.watermark_bytes:
                return 0.0
            d = oldest + self.idle_s - now
            if deadline is None or d < deadline:
                deadline = d
        return max(0.0, deadline)

    def _run(self) -> None:
        import time
        while not self._stop.is_set():
            now = time.monotonic()
            timeout = self._next_timeout(now)
            if timeout is None or timeout > 0:
                with self._cond:
                    if not self._wake and not self._stop.is_set():
                        self._cond.wait(timeout=timeout)
                    self._wake = False
                if self._stop.is_set():
                    break
            self._sweep(time.monotonic())

    def _sweep(self, now: float) -> None:
        for (poolid, row), (ops, nbytes, oldest) in \
                self.engine.lane_stats().items():
            by_mark = (ops >= self.watermark_ops
                       or nbytes >= self.watermark_bytes)
            by_idle = now - oldest >= self.idle_s
            if not (by_mark or by_idle):
                continue
            faults = getattr(self.engine, "faults", None)
            if faults is not None and not faults.drain_gate(poolid, row):
                self.drains_skipped += 1
                continue
            try:
                self.engine.flush(poolid, row)
            except BaseException as e:  # noqa: BLE001 - keep draining
                # the op's handle already carries the failure; record
                # for observability and back off so a persistently
                # failing lane cannot busy-loop the daemon
                self.errors.append(e)
                self._stop.wait(0.01)
            else:
                self.flushes += 1
                if by_mark:
                    self.watermark_flushes += 1
                else:
                    self.idle_flushes += 1
