"""Typed GlobalArray front-end: a DASH-style object API over the
byte-offset DART core (docs/API.md).

The paper's DART API is deliberately C-flavored — raw 128-bit global
pointers, byte offsets, untyped put/get.  The PGAS promise ("program it
like shared memory") is delivered by the typed layer built on top, as
DASH does over DART.  :class:`GlobalArray` is that layer:

* minted by ``ctx.alloc(shape, dtype, team=...)`` / ``Team.alloc`` —
  one collective symmetric allocation, one block of ``shape`` elements
  of ``dtype`` per team member, byte layout never exposed;
* addressed NumPy-style: ``ga[unit]`` is a typed :class:`GlobalRef`
  view of that member's block, ``ga.at[unit, 3:7]`` an element run
  inside it — including strided and multi-dimensional selections like
  ``ga.at[unit, :, 2]`` (a column) or ``ga.at[unit, ::4]``, which
  lower onto ONE strided engine descriptor — each supporting
  ``.put/.get`` (blocking) and ``.put_nb/.get_nb`` (engine-queued,
  coalescing at flush);
* collective ops are typed too: ``ga.allreduce("sum")``,
  ``ga.broadcast(root)``, ``ga.gather()``, ``ga.scatter(values)``;
* ``ga.local`` reads this controller's portion through the
  ``FLAG_SHM`` / :func:`repro.core.shm.classify_locality` fast path —
  a zero-copy, zero-dispatch numpy view on host-visible arenas.

Every data-plane op lowers onto the existing :class:`CommEngine`
enqueue path — never around it — so N typed non-blocking puts still
coalesce into one jitted dispatch, and ``with ctx.epoch(): ...``
(→ :meth:`CommEngine.epoch_scope`) preserves the paper's
queued→issued→complete ladder.  The raw ``dart_*`` byte API remains
the documented substrate layer underneath (docs/API.md has the
migration table).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .globmem import nbytes_of
from .gptr import GlobalPtr
from .team import DART_TEAM_ALL

Index = Union[int, slice, Tuple[Union[int, slice], ...]]


def _element_run(shape: Tuple[int, ...], index: Index
                 ) -> Tuple[int, Tuple[int, ...], int, int, int]:
    """Translate a NumPy-style index on ``shape`` (row-major) into ONE
    strided element run:
    ``(element_offset, out_shape, seg_elems, stride_elems, count)`` —
    ``count`` segments of ``seg_elems`` consecutive elements placed
    ``stride_elems`` apart.  A contiguous selection is the degenerate
    case ``(seg_elems == prod(out_shape), stride 0, count 1)``.

    Addressability rule: after collapsing every contiguous tail
    (integer axes, size-1 slices, and slices that continue the dense
    run), at most ONE strided level may remain — that's what a single
    engine descriptor expresses.  Two or more broken levels (e.g. a
    strided slice over rows *and* a partial slice over columns of a
    3-D block) would need one descriptor per outer segment; index the
    outer level per-iteration instead.  Negative-step slices raise
    ``ValueError`` — silently reversing bytes on the wire is the kind
    of misaddressing this front-end exists to prevent.  A step larger
    than the axis extent just selects the first element (count 1), and
    an empty slice yields a zero-element run (no data moves).
    """
    if not isinstance(index, tuple):
        index = (index,)
    if len(index) > len(shape):
        raise IndexError(f"too many indices for shape {shape}")
    elem_strides = [1] * len(shape)
    for ax in range(len(shape) - 2, -1, -1):
        elem_strides[ax] = elem_strides[ax + 1] * shape[ax + 1]
    offset = 0
    out_shape = []
    # (n, pitch) per non-trivial axis: n selected elements, pitch
    # element-stride between consecutive ones (= step * axis stride)
    levels = []
    for ax, idx in enumerate(index):
        extent = shape[ax]
        if isinstance(idx, (int, np.integer)):
            i = int(idx)
            if i < 0:
                i += extent
            if not (0 <= i < extent):
                raise IndexError(
                    f"index {idx} out of range for axis {ax} (size {extent})")
            offset += i * elem_strides[ax]
        elif isinstance(idx, slice):
            if idx.step is not None and idx.step < 0:
                raise ValueError(
                    f"negative-step slice {idx!r} on axis {ax}: "
                    "reversed runs are not addressable as one-sided "
                    "transfers (read forward and reverse locally)")
            start, stop, step = idx.indices(extent)
            n = max(0, -(-(stop - start) // step))
            offset += start * elem_strides[ax]
            out_shape.append(n)
            if n != 1:
                levels.append((n, step * elem_strides[ax]))
        else:
            raise TypeError(f"unsupported index {idx!r}")
    for ax in range(len(index), len(shape)):
        out_shape.append(shape[ax])
        if shape[ax] != 1:
            levels.append((shape[ax], elem_strides[ax]))
    if 0 in out_shape:
        # empty selection: a zero-element contiguous run — callers
        # skip the wire entirely (no descriptor, no dispatch)
        return offset, tuple(out_shape), 0, 0, 1
    # collapse the dense tail: innermost levels whose pitch continues
    # the contiguous block merge into one segment of seg elements
    seg = 1
    while levels and levels[-1][1] == seg:
        seg *= levels.pop()[0]
    if not levels:
        return offset, tuple(out_shape), seg, 0, 1
    if len(levels) > 1:
        raise IndexError(
            f"index {index!r} on shape {shape} addresses "
            f"{len(levels)} strided levels; one engine descriptor "
            "carries a single (stride, count) — index the outer "
            "level per-iteration instead")
    n, pitch = levels[0]
    return offset, tuple(out_shape), seg, pitch, n


class GlobalRef:
    """A typed reference to one (possibly strided) element run on one
    unit.

    Immutable and cheap: holds (array, unit, element offset, shape)
    plus the run geometry ``(seg, stride, count)`` — ``count``
    segments of ``seg`` consecutive elements, ``stride`` elements
    apart (contiguous refs are ``count == 1``).  A matrix column, a
    tile halo, or a block-cyclic slice is therefore ONE ref lowering
    onto ONE engine descriptor, never one op per element.  Data ops
    translate to engine ops on the underlying byte pointer — the
    translation the raw API forces every caller to hand-roll.
    """

    __slots__ = ("array", "unit", "offset", "shape", "seg", "stride",
                 "count")

    def __init__(self, array: "GlobalArray", unit: int, offset: int,
                 shape: Tuple[int, ...], seg: Optional[int] = None,
                 stride: int = 0, count: int = 1):
        self.array = array
        self.unit = unit
        self.offset = offset
        self.shape = shape
        self.seg = (int(np.prod(shape, dtype=np.int64)) if seg is None
                    else seg)
        self.stride = stride
        self.count = count

    @property
    def dtype(self):
        return self.array.dtype

    @property
    def size(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64))

    @property
    def gptr(self) -> GlobalPtr:
        """The substrate-layer byte pointer this ref denotes (its
        first segment's first element)."""
        return (self.array.gptr.setunit(self.unit)
                .incaddr(self.offset * self.array.itemsize))

    def _byte_geom(self) -> dict:
        """The engine kwargs of this run: stride in BYTES, count."""
        return {"stride": self.stride * self.array.itemsize,
                "count": self.count}

    def __getitem__(self, index: Index) -> "GlobalRef":
        if self.count != 1:
            raise IndexError(
                "cannot re-index a strided GlobalRef (one descriptor "
                "carries one (stride, count) level); index the parent "
                "block instead")
        off, shp, seg, stride, count = _element_run(self.shape, index)
        return GlobalRef(self.array, self.unit, self.offset + off, shp,
                         seg, stride, count)

    def _coerce(self, value) -> jax.Array:
        v = jnp.asarray(value, dtype=self.dtype)
        if v.shape == self.shape:
            return v
        if v.ndim == 0:
            return jnp.broadcast_to(v, self.shape)
        if v.size == int(np.prod(self.shape, dtype=np.int64)):
            return v.reshape(self.shape)
        raise ValueError(
            f"value of shape {v.shape} does not fit ref of shape "
            f"{self.shape}")

    def _empty_handle(self):
        """A born-complete Handle for zero-element refs: nothing moves,
        nothing dispatches."""
        from .onesided import Handle
        return Handle(())

    def _empty_get_handle(self):
        from .onesided import GetHandle
        h = GetHandle(self.shape, self.dtype, engine=None)
        h._value = jnp.zeros(self.shape, self.dtype)
        return h

    # -- data plane (lowers onto the CommEngine, never around it) --------
    def put(self, value) -> None:
        """Blocking put, locality-routed: SHM-writable targets take the
        zero-copy window write (no jitted dispatch); everything else is
        enqueue + flush + completion through the engine."""
        from . import runtime as rt
        if self.size == 0:
            return
        rt.dart_put_blocking(self.array.ctx, self.gptr,
                             self._coerce(value), **self._byte_geom())

    def put_nb(self, value):
        """Non-blocking put: queued on the engine; coalesces with its
        neighbours at the next epoch close.  Returns the Handle.
        Never shm-routed — a direct write would defeat the queued
        coalescing this method exists for."""
        from . import runtime as rt
        if self.size == 0:
            return self._empty_handle()
        return rt.dart_put(self.array.ctx, self.gptr,
                           self._coerce(value), **self._byte_geom())

    def get(self) -> jax.Array:
        """Blocking get, locality-routed (zero-copy on SHM_LOCAL) for
        contiguous refs; strided refs gather through the engine's one
        coalesced descriptor."""
        from . import runtime as rt
        if self.size == 0:
            return jnp.zeros(self.shape, self.dtype)
        if self.count == 1:
            return rt.dart_get_blocking(self.array.ctx, self.gptr,
                                        self.shape, self.dtype)
        val, _ = rt.dart_get(self.array.ctx, self.gptr, self.shape,
                             self.dtype, **self._byte_geom())
        return val

    def get_nb(self):
        """Non-blocking get: queued; ``handle.value()`` flushes and
        yields the typed result."""
        from . import runtime as rt
        if self.size == 0:
            return self._empty_get_handle()
        return rt.dart_get_nb(self.array.ctx, self.gptr, self.shape,
                              self.dtype, **self._byte_geom())

    # -- element-wise reductions at the target (the reduction plane) ----
    def accumulate(self, value, op: str = "sum"):
        """Non-blocking element-wise accumulate at the target (the
        ``MPI_Accumulate`` analogue): queued on the engine; consecutive
        same-``op`` accumulates coalesce into ONE read-modify-write
        dispatch at the next epoch close — overlapping runs included
        (the ops commute).  Returns the Handle."""
        from . import runtime as rt
        if self.size == 0:
            return self._empty_handle()
        return rt.dart_accumulate(self.array.ctx, self.gptr,
                                  self._coerce(value), op,
                                  **self._byte_geom())

    def add(self, value):
        """``ref.add(v)`` ≡ ``ref.accumulate(v, "sum")``."""
        return self.accumulate(value, "sum")

    def mul(self, value):
        return self.accumulate(value, "prod")

    def min(self, value):
        return self.accumulate(value, "min")

    def max(self, value):
        return self.accumulate(value, "max")

    def get_accumulate(self, value, op: str = "sum"):
        """Fetch-and-accumulate (``MPI_Get_accumulate``): applies
        ``value`` under ``op`` and returns the target's typed value
        from *before* the op, concrete (flushes this ref's lane)."""
        from . import runtime as rt
        if self.size == 0:
            return jnp.zeros(self.shape, self.dtype)
        old, _ = rt.dart_get_accumulate(self.array.ctx, self.gptr,
                                        self._coerce(value), op,
                                        **self._byte_geom())
        return old

    def flush(self) -> None:
        """Per-target flush (the ``MPI_Win_flush_local(rank, win)``
        analogue): dispatch only this unit's queued ops on the array's
        window, coalesced; other targets' queued epochs keep
        accumulating for their own flush."""
        from . import runtime as rt
        rt.dart_flush(self.array.ctx, self.array.gptr, target=self.unit)

    # -- one-sided atomics (paper §IV.B.6, typed) ------------------------
    def fetch_add(self, delta: int) -> int:
        """Atomic fetch-and-add on a single-element int32 ref (the
        typed ``dart_fetch_and_add`` / ``MPI_Fetch_and_op`` analogue);
        returns the pre-update value.  Atomic with respect to every
        other heap atomic on the context — the serving plane's
        refcount primitive.  Flushes queued ops on the heap first, so
        the read-modify-write never sees a stale cell."""
        if self.dtype != jnp.int32:
            raise TypeError(
                f"fetch_add needs an int32 ref, got {self.dtype}")
        if int(np.prod(self.shape, dtype=np.int64)) != 1:
            raise ValueError(
                f"fetch_add needs a single-element ref, got shape "
                f"{self.shape}")
        from . import atomic_ops as _ao
        return _ao.dart_fetch_and_add(self.array.ctx, self.gptr,
                                      int(delta))

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        geom = ("" if self.count == 1 else
                f", seg={self.seg}, stride={self.stride}, "
                f"count={self.count}")
        return (f"GlobalRef(unit={self.unit}, offset={self.offset}, "
                f"shape={self.shape}, dtype={self.dtype}{geom})")


class _AtIndexer:
    """``ga.at[unit, <element index>]`` → :class:`GlobalRef`."""

    __slots__ = ("_array",)

    def __init__(self, array: "GlobalArray"):
        self._array = array

    def __getitem__(self, key) -> GlobalRef:
        if isinstance(key, tuple):
            unit, index = key[0], key[1:]
        else:
            unit, index = key, ()
        return self._array[unit][index]


class GlobalArray:
    """A typed, team-distributed array over one symmetric allocation.

    Each member of ``team`` owns one block of ``shape`` elements of
    ``dtype`` at the same offset in the team pool (aligned & symmetric,
    paper §III) — so any unit's block is addressable from a locally
    computed pointer, which is exactly what :class:`GlobalRef` hides.
    """

    def __init__(self, ctx, gptr: GlobalPtr, shape: Sequence[int], dtype,
                 teamid: int):
        self.ctx = ctx
        self.gptr = gptr
        self.shape = tuple(int(s) for s in shape)
        self.dtype = jnp.dtype(dtype)
        self.teamid = teamid

    # -- allocation ------------------------------------------------------
    @classmethod
    def alloc(cls, ctx, shape: Sequence[int], dtype,
              team: int = DART_TEAM_ALL, shm: bool = True) -> "GlobalArray":
        """Collective symmetric allocation, typed.

        ``shm=True`` (default) mints a ``FLAG_SHM`` pointer so, on
        host-visible arenas, blocking reads AND writes take the
        zero-copy locality fast path and the data-moving collectives
        (``broadcast``/``gather``/``scatter``) go shm-direct with zero
        jitted dispatches; pass ``shm=False`` to force everything
        through the jitted one-sided path (useful for benchmarking the
        substrate, or when a test pins engine dispatch counts).
        """
        from . import runtime as rt
        from .shm import mint_shm
        shape = tuple(int(s) for s in shape)
        g = rt.dart_team_memalloc_aligned(ctx, team,
                                          nbytes_of(shape, dtype))
        if shm:
            g = mint_shm(g)
        return cls(ctx, g, shape, dtype, team)

    def free(self) -> None:
        """Release the backing allocation (``dart_team_memfree``)."""
        from . import runtime as rt
        rt.dart_team_memfree(self.ctx, self.teamid, self.gptr)

    # -- identity --------------------------------------------------------
    @property
    def team(self):
        return self.ctx.teams[self.teamid]

    @property
    def units(self) -> Tuple[int, ...]:
        """Absolute unit ids of the owning team's members."""
        return self.team.group.members

    @property
    def team_size(self) -> int:
        return self.team.size()

    @property
    def itemsize(self) -> int:
        return self.dtype.itemsize

    @property
    def nbytes_per_unit(self) -> int:
        return nbytes_of(self.shape, self.dtype)

    def _check_unit(self, unit: int) -> int:
        unit = int(unit)
        if self.team.myid(unit) < 0:
            raise KeyError(
                f"unit {unit} is not a member of team {self.teamid} "
                f"(members {self.units})")
        return unit

    # -- addressing ------------------------------------------------------
    def __getitem__(self, unit: int) -> GlobalRef:
        """Typed view of ``unit``'s whole block."""
        return GlobalRef(self, self._check_unit(unit), 0, self.shape)

    @property
    def at(self) -> _AtIndexer:
        """Element-granular addressing: ``ga.at[unit, 3:7]`` denotes a
        contiguous run inside ``unit``'s block."""
        return _AtIndexer(self)

    # -- local (zero-copy) view -----------------------------------------
    @property
    def local(self):
        """This controller's portion — in the single-controller runtime,
        the base pointer's owning unit (the team's first member).

        Routed through :func:`repro.core.shm.classify_locality`: on a
        host-visible arena with a ``FLAG_SHM`` pointer this is a
        read-only zero-copy numpy view with **zero** jitted dispatches
        (queued writes to the pool are flushed first, so the view sees
        them); otherwise it falls back to the jitted one-sided get.
        Writes must go through ``put``/``put_nb`` so XLA dataflow stays
        authoritative.
        """
        return self.local_view(self.gptr.unitid)

    def local_view(self, unit: int):
        """Locality-routed read of any member's block (see :attr:`local`)."""
        from . import runtime as rt
        return rt.dart_get_blocking(self.ctx,
                                    self.gptr.setunit(self._check_unit(unit)),
                                    self.shape, self.dtype)

    # -- element-wise reductions at the target --------------------------
    def accumulate(self, unit: int, index, value, op: str = "sum"):
        """Non-blocking accumulate into a contiguous run of ``unit``'s
        block: ``ga.accumulate(u, slice(3, 7), v, "sum")`` ≡
        ``ga.at[u, 3:7].accumulate(v, "sum")`` (pass ``index=None``
        for the whole block).  Returns the queued Handle."""
        ref = self[unit] if index is None else self[unit][index]
        return ref.accumulate(value, op)

    # -- typed collectives ----------------------------------------------
    def allreduce(self, op: str = "sum") -> jax.Array:
        """All-reduce the per-member blocks elementwise across the team;
        every member's block is replaced by the result, which is also
        returned typed.  Shape-stable: element counts bucket to pow2
        with op-identity padding, so varying-shape loops never
        recompile after warmup."""
        from . import runtime as rt
        return rt.dart_allreduce(self.ctx, self.gptr, self.shape,
                                 self.dtype, op=op)

    def reduce(self, op: str = "sum", root: int = 0) -> jax.Array:
        """Root-taking reduce: the reduced value replaces only
        ``root``'s block; other members keep theirs.  Returns the
        reduced value."""
        from . import runtime as rt
        return rt.dart_reduce(self.ctx, self.gptr, self.shape,
                              self.dtype, op=op,
                              root=self._check_unit(root))

    def broadcast(self, root: int):
        """Broadcast ``root``'s block to every member.  Returns the
        collective's Handle (born issued).  Shm-direct (zero jitted
        dispatches) on SHM-writable pools; one jitted dispatch
        otherwise."""
        from . import runtime as rt
        return rt.dart_bcast(self.ctx,
                             self.gptr.setunit(self._check_unit(root)),
                             self.nbytes_per_unit)

    def gather(self) -> jax.Array:
        """Gather every member's block → typed ``(team_size, *shape)``
        array, in team-relative order — shm-direct (zero jitted
        dispatches) on host-visible pools, one jitted dispatch
        otherwise."""
        from . import runtime as rt
        vals, _ = rt.dart_gather_typed(self.ctx, self.gptr, self.shape,
                                       self.dtype)
        return vals

    def scatter(self, values) -> None:
        """Scatter row i of ``values`` (``(team_size, *shape)``) to the
        team's i-th member — shm-direct on SHM-writable pools, one
        jitted dispatch otherwise."""
        values = jnp.asarray(values, dtype=self.dtype)
        want = (self.team_size,) + self.shape
        if values.shape != want:
            raise ValueError(
                f"scatter values of shape {values.shape}, expected {want}")
        from . import runtime as rt
        rt.dart_scatter_typed(self.ctx, self.gptr, values).wait()

    # -- epochs ----------------------------------------------------------
    def flush(self, unit: Optional[int] = None) -> None:
        """Flush this array's window: all queued ops on its pool, or —
        with ``unit`` — only that target's lane (``ga.flush(u)`` ≡
        ``ga[u].flush()``)."""
        from . import runtime as rt
        if unit is None:
            rt.dart_flush(self.ctx, self.gptr)
        else:
            rt.dart_flush(self.ctx, self.gptr,
                          target=self._check_unit(unit))

    def epoch(self):
        """Epoch scoped to this array's pool: non-blocking ops enqueued
        inside coalesce into one flush on exit (other pools keep
        accumulating)."""
        return self.ctx.epoch(self.gptr)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"GlobalArray(shape={self.shape}, dtype={self.dtype}, "
                f"team={self.teamid}, units={self.units})")
