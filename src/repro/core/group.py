"""DART groups (paper §III, §IV.B.1).

A DART group is an *ordered* set of absolute unit ids, maintained in
ascending order at all times.  This is the semantic gap the paper closes
against MPI: ``MPI_Group_incl`` orders by position in ``ranks`` and
``MPI_Group_union`` merely appends, so MPI groups are "arranged in a
random fashion" (paper Fig. 3).  DART therefore implements

* ``dart_group_union`` as an explicit **merge-sort** of the two sorted
  member lists, and
* ``dart_group_addmember(g, u)`` as ``incl(WORLD, 1, [u])`` followed by a
  union — exactly the construction of paper §IV.B.1.

Groups are *local* objects (no collective operations — paper §III), so
this module is pure host-side metadata, just as MPI groups are.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Tuple


@dataclasses.dataclass(frozen=True)
class DartGroup:
    """Ordered set of absolute unit ids (always sorted ascending)."""

    members: Tuple[int, ...] = ()

    def __post_init__(self):
        m = self.members
        if any(u < 0 for u in m):
            raise ValueError("unit ids must be non-negative")
        if any(m[i] >= m[i + 1] for i in range(len(m) - 1)):
            raise ValueError("DART group invariant violated: members must be "
                             "strictly ascending (sorted, no duplicates)")

    def size(self) -> int:
        return len(self.members)

    def ismember(self, unitid: int) -> bool:
        lo, hi = 0, len(self.members)
        while lo < hi:                      # binary search — members sorted
            mid = (lo + hi) // 2
            if self.members[mid] < unitid:
                lo = mid + 1
            else:
                hi = mid
        return lo < len(self.members) and self.members[lo] == unitid


def dart_group_init() -> DartGroup:
    """Create an empty group."""
    return DartGroup(())


def dart_group_union(g1: DartGroup, g2: DartGroup) -> DartGroup:
    """Merge-sort union of two groups (paper §IV.B.1).

    Implemented as an explicit two-finger merge (not ``sorted(set(..))``)
    to mirror the paper's mechanism; deduplicates on the fly.
    """
    a, b = g1.members, g2.members
    i = j = 0
    out = []
    while i < len(a) and j < len(b):
        if a[i] < b[j]:
            nxt = a[i]; i += 1
        elif b[j] < a[i]:
            nxt = b[j]; j += 1
        else:
            nxt = a[i]; i += 1; j += 1
        if not out or out[-1] != nxt:
            out.append(nxt)
    for rest, k in ((a, i), (b, j)):
        while k < len(rest):
            if not out or out[-1] != rest[k]:
                out.append(rest[k])
            k += 1
    return DartGroup(tuple(out))


def dart_group_addmember(g: DartGroup, unitid: int) -> DartGroup:
    """Add one absolute unit id (paper §IV.B.1).

    Faithful construction: build the singleton group (the analogue of
    ``MPI_Group_incl(MPI_COMM_WORLD, 1, [unitid])``) and merge-sort it
    into ``g`` via :func:`dart_group_union`, so the result stays ordered
    regardless of insertion order.
    """
    singleton = DartGroup((unitid,))
    return dart_group_union(g, singleton)


def dart_group_delmember(g: DartGroup, unitid: int) -> DartGroup:
    return DartGroup(tuple(u for u in g.members if u != unitid))


def dart_group_intersect(g1: DartGroup, g2: DartGroup) -> DartGroup:
    a, b = g1.members, g2.members
    i = j = 0
    out = []
    while i < len(a) and j < len(b):
        if a[i] < b[j]:
            i += 1
        elif b[j] < a[i]:
            j += 1
        else:
            out.append(a[i]); i += 1; j += 1
    return DartGroup(tuple(out))


def dart_group_split(g: DartGroup, n: int) -> Tuple[DartGroup, ...]:
    """Split into ``n`` contiguous, balanced sub-groups (DART spec)."""
    if n <= 0:
        raise ValueError("n must be positive")
    m = g.members
    base, extra = divmod(len(m), n)
    out, start = [], 0
    for k in range(n):
        take = base + (1 if k < extra else 0)
        out.append(DartGroup(m[start:start + take]))
        start += take
    return tuple(out)


def dart_group_copy(g: DartGroup) -> DartGroup:
    return DartGroup(g.members)


def group_from_units(units: Iterable[int]) -> DartGroup:
    """Convenience: build a group by repeated addmember (paper path)."""
    g = dart_group_init()
    for u in units:
        g = dart_group_addmember(g, u)
    return g
