"""DART global memory management (paper §III, §IV.B.3).

The global address space is realized as a **symmetric heap**: one byte
arena per *segment pool*, each a ``uint8[n_rows, pool_bytes]`` JAX array
whose rows are the per-unit partitions.  On a device mesh the arenas are
sharded ``P('unit', None)`` so row *i* physically lives in unit *i*'s
HBM; on the CPU test plane they are ordinary arrays.  This is the
analogue of the paper's MPI *windows*:

* **Non-collective allocations** (``dart_memalloc``) are local ops.  MPI
  windows are collective, so the paper pre-reserves one block of memory
  on every unit and creates a single WORLD window over it at init time
  (§IV.B.3, Fig. 4); every non-collective allocation then carves from
  the calling unit's partition.  We mirror this exactly: pool id 0 is
  reserved at ``dart_init`` with one row per unit in DART_TEAM_ALL and a
  *per-unit* allocator; offsets in non-collective global pointers are
  displacements into the owner's row, dereferenced **without unit
  translation** (§IV.B.4).

* **Collective allocations** (``dart_team_memalloc_aligned``) carve from
  the owning team's pre-reserved pool (one row per *team member*,
  addressed by relative id → unit translation required).  A single
  shared allocator cursor guarantees the *aligned & symmetric* property:
  every member sees the identical offset, so any member can locally
  compute a pointer to any member's portion (§III).  Each allocation is
  recorded in the team's **translation table** (§IV.B.3, Fig. 5).

Deallocation: the paper does not specify an allocator; we provide a
production-grade first-fit free-list allocator with coalescing (the MPI
implementation underneath DART-MPI does the same inside window pools).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .faults import DartError
from .gptr import (FLAG_COLLECTIVE, NON_COLLECTIVE_SEG, GlobalPtr)

#: allocation granularity (bytes).  128 matches the TPU lane width so a
#: row slice of any allocation is layout-friendly.
ALIGNMENT = 128


def align_up(n: int, a: int = ALIGNMENT) -> int:
    return (n + a - 1) // a * a


class OutOfGlobalMemory(DartError):
    """Allocation failure in a symmetric-heap pool (typed: part of the
    :class:`~repro.core.faults.DartError` ladder, still a
    ``RuntimeError``)."""


class WindowDestroyedError(DartError, KeyError):
    """A global pointer was dereferenced against a team whose window
    (collective pool) is no longer live — the pool was dropped by
    ``dart_team_destroy`` and the teamlist slot may since have been
    reused by an unrelated team (paper §IV.B.2).  Doubly parented:
    :class:`~repro.core.faults.DartError` (the typed ladder) and the
    historical ``KeyError`` (registry lookup semantics), so both
    established handler shapes keep working.  Instances raised through
    the engine's drop path carry ``poolid`` and ``teamid``."""


class BlockAllocator:
    """First-fit free-list allocator with coalescing over [0, size)."""

    def __init__(self, size: int):
        self.size = size
        self._free: List[Tuple[int, int]] = [(0, size)]   # (offset, len)
        self._live: Dict[int, int] = {}                   # offset -> len

    def alloc(self, nbytes: int) -> int:
        nbytes = align_up(max(nbytes, 1))
        for i, (off, ln) in enumerate(self._free):
            if ln >= nbytes:
                if ln == nbytes:
                    self._free.pop(i)
                else:
                    self._free[i] = (off + nbytes, ln - nbytes)
                self._live[off] = nbytes
                return off
        raise OutOfGlobalMemory(
            f"pool exhausted: need {nbytes}B, largest free block "
            f"{self.largest_free()}B")

    def free(self, offset: int) -> None:
        ln = self._live.pop(offset)
        self._free.append((offset, ln))
        self._free.sort()
        merged: List[Tuple[int, int]] = []
        for off, l in self._free:
            if merged and merged[-1][0] + merged[-1][1] == off:
                merged[-1] = (merged[-1][0], merged[-1][1] + l)
            else:
                merged.append((off, l))
        self._free = merged

    def bytes_live(self) -> int:
        return sum(self._live.values())

    def bytes_free(self) -> int:
        return sum(l for _, l in self._free)

    def largest_free(self) -> int:
        """Largest contiguous free block — the quantity coalescing on
        :meth:`free` exists to maximize."""
        return max((l for _, l in self._free), default=0)


@dataclasses.dataclass
class TranslationRecord:
    """One row of a team's translation table (paper Fig. 5)."""
    offset: int          # displacement in the team pool (== gptr.addr)
    nbytes: int          # per-unit extent of the allocation
    poolid: int          # which arena backs it ("window object")


class TranslationTable:
    """Per-team table mapping collective allocations → (pool, offset).

    The paper stores (window object, offset) per collective allocation;
    dereference walks the table to find the record *containing* a given
    address (§IV.B.3/4).
    """

    def __init__(self):
        self._records: List[TranslationRecord] = []

    def add(self, rec: TranslationRecord) -> None:
        self._records.append(rec)
        self._records.sort(key=lambda r: r.offset)

    def query(self, addr: int) -> TranslationRecord:
        for r in self._records:
            if r.offset <= addr < r.offset + r.nbytes:
                return r
        raise KeyError(f"address {addr} not inside any collective allocation")

    def remove(self, offset: int) -> TranslationRecord:
        for i, r in enumerate(self._records):
            if r.offset == offset:
                return self._records.pop(i)
        raise KeyError(f"no allocation at offset {offset}")

    def __len__(self) -> int:
        return len(self._records)


@dataclasses.dataclass
class PoolMeta:
    """Host-side metadata for one arena pool."""
    poolid: int
    n_rows: int
    pool_bytes: int
    collective: bool
    # collective pools: one shared cursor (aligned & symmetric);
    # non-collective pool: one allocator per unit row.
    shared_alloc: Optional[BlockAllocator] = None
    per_unit_alloc: Optional[List[BlockAllocator]] = None
    table: Optional[TranslationTable] = None


class WindowRegistry:
    """teamid → live :class:`PoolMeta` binding (the window-object table).

    DART-MPI binds every team to an MPI window object; dereference of a
    collective pointer goes team → window, never through slot
    arithmetic.  This registry is that binding made first-class: teams
    register their pool at creation, drop it at destroy, and ``deref``
    keys off it — so teamlist-slot reuse (paper §IV.B.2) can never
    route a new team's pointers at a dropped or foreign pool.

    TeamIDs are never reused (§IV.B.2), so a teamid uniquely identifies
    one window for the lifetime of the runtime.
    """

    def __init__(self):
        self._by_team: Dict[int, PoolMeta] = {}

    def register(self, teamid: int, meta: PoolMeta) -> None:
        if teamid in self._by_team:
            raise ValueError(f"team {teamid} already has a live window")
        self._by_team[teamid] = meta

    def lookup(self, teamid: int) -> PoolMeta:
        try:
            return self._by_team[teamid]
        except KeyError:
            raise WindowDestroyedError(
                f"team {teamid} has no live window (pool dropped by "
                "dart_team_destroy?)") from None

    def drop(self, teamid: int) -> PoolMeta:
        try:
            return self._by_team.pop(teamid)
        except KeyError:
            raise WindowDestroyedError(
                f"team {teamid} has no live window to drop") from None

    def clear(self) -> None:
        self._by_team.clear()

    def __contains__(self, teamid: int) -> bool:
        return teamid in self._by_team

    def __len__(self) -> int:
        return len(self._by_team)

    def live_teams(self) -> Tuple[int, ...]:
        return tuple(self._by_team)


# The device-resident heap state is a plain dict pytree:
#   {poolid: uint8[n_rows, pool_bytes]}
# Pending (queued, not-yet-dispatched) one-sided ops against it live in
# the epoch-scoped CommEngine queue (onesided.py); every functional
# update goes through copy_state so old epochs stay valid snapshots.
HeapState = Dict[int, jax.Array]


def copy_state(state: HeapState) -> HeapState:
    """Shallow epoch snapshot: new dict, same (immutable) arenas."""
    return dict(state)


class SymmetricHeap:
    """Host-side layout manager + factory for device heap state."""

    def __init__(self, n_units: int, mesh: Optional[jax.sharding.Mesh] = None,
                 unit_axes: Optional[Tuple[str, ...]] = None):
        self.n_units = n_units
        self.mesh = mesh
        self.unit_axes = unit_axes
        self.pools: Dict[int, PoolMeta] = {}
        self.windows = WindowRegistry()
        self._next_poolid = 0

    # -- pool management -------------------------------------------------
    def reserve_pool(self, n_rows: int, pool_bytes: int,
                     collective: bool) -> PoolMeta:
        pool_bytes = align_up(pool_bytes)
        pid = self._next_poolid
        self._next_poolid += 1
        meta = PoolMeta(
            poolid=pid, n_rows=n_rows, pool_bytes=pool_bytes,
            collective=collective,
            shared_alloc=BlockAllocator(pool_bytes) if collective else None,
            per_unit_alloc=(None if collective else
                            [BlockAllocator(pool_bytes) for _ in range(n_rows)]),
            table=TranslationTable() if collective else None,
        )
        self.pools[pid] = meta
        return meta

    def drop_pool(self, poolid: int) -> None:
        del self.pools[poolid]

    def _sharding_for(self):
        if self.mesh is None:
            return None
        from jax.sharding import NamedSharding, PartitionSpec as P
        return NamedSharding(self.mesh, P(self.unit_axes, None))

    def init_pool_state(self, meta: PoolMeta) -> jax.Array:
        """Zero-initialized device arena for one pool."""
        shape = (meta.n_rows, meta.pool_bytes)
        sh = self._sharding_for()
        if sh is None:
            return jnp.zeros(shape, dtype=jnp.uint8)
        return jax.jit(lambda: jnp.zeros(shape, dtype=jnp.uint8),
                       out_shardings=sh)()

    def init_state(self) -> HeapState:
        return {pid: self.init_pool_state(meta)
                for pid, meta in self.pools.items()}

    # -- allocation ------------------------------------------------------
    def memalloc_local(self, meta: PoolMeta, unit_row: int,
                       nbytes: int) -> int:
        """Non-collective allocation on one unit's partition (§IV.B.3)."""
        if meta.collective:
            raise ValueError("local alloc on a collective pool")
        return meta.per_unit_alloc[unit_row].alloc(nbytes)

    def memalloc_aligned(self, meta: PoolMeta, nbytes: int) -> int:
        """Collective aligned/symmetric allocation (§IV.B.3, Fig. 5)."""
        if not meta.collective:
            raise ValueError("aligned alloc on the non-collective pool")
        off = meta.shared_alloc.alloc(nbytes)
        meta.table.add(TranslationRecord(offset=off, nbytes=align_up(nbytes),
                                         poolid=meta.poolid))
        return off

    def memfree_local(self, meta: PoolMeta, unit_row: int,
                      offset: int) -> None:
        meta.per_unit_alloc[unit_row].free(offset)

    def memfree_aligned(self, meta: PoolMeta, offset: int) -> None:
        meta.shared_alloc.free(offset)
        meta.table.remove(offset)


# -- byte <-> typed-value conversion (jit-safe) ---------------------------

def to_bytes(value: jax.Array) -> jax.Array:
    """Flatten a typed array into a 1-D uint8 byte string (bitcast)."""
    value = jnp.asarray(value)
    if value.dtype == jnp.uint8:
        return value.reshape(-1)
    flat = value.reshape(-1)
    b = jax.lax.bitcast_convert_type(flat, jnp.uint8)  # (n, itemsize)
    return b.reshape(-1)


def from_bytes(raw: jax.Array, shape: Tuple[int, ...], dtype) -> jax.Array:
    """Inverse of :func:`to_bytes`."""
    dtype = jnp.dtype(dtype)
    if dtype == jnp.uint8:
        return raw.reshape(shape)
    itemsize = dtype.itemsize
    n = raw.size // itemsize
    return jax.lax.bitcast_convert_type(
        raw.reshape(n, itemsize), dtype).reshape(shape)


def nbytes_of(shape: Tuple[int, ...], dtype) -> int:
    return int(np.prod(shape, dtype=np.int64)) * jnp.dtype(dtype).itemsize
