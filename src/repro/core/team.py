"""DART teams and the teamlist slot allocator (paper §IV.B.2, §IV.B.4).

Teams are coherent, collective objects (unlike groups).  Each team maps
one-to-one onto an entry in the runtime's ``teams`` array — the analogue
of an MPI communicator.  Because DART teamIDs grow without bound (they
are never reused, paper §IV.B.2), the runtime keeps a bounded
``teamlist`` whose *slot index* — not the teamID itself — keys

* the ``teams`` communicator array,
* the team's collective global-memory pool, and
* the team's translation table.

The paper's allocator scans ``teamlist`` linearly for a ``-1`` slot on
team creation and resets the slot to ``-1`` on destruction.  Paper §VI
flags the linear scan as a scalability issue and suggests a linked list;
:class:`FreeListTeamList` is that beyond-paper O(1) variant (free-slot
stack + id→slot hash), benchmarked against the faithful one in
``benchmarks/teamlist_bench.py``.

Unit translation (paper §IV.B.4): collective global pointers carry
*absolute* unit ids which must be translated to *relative* ids (ranks)
within the owning team before the data plane can address the team's
memory pool.  :meth:`Team.myid` / :meth:`Team.unit_at` implement the two
directions; members are sorted so translation is a binary search.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .group import DartGroup

#: teamid of DART_TEAM_ALL.
DART_TEAM_ALL = 0

#: sentinel for an empty teamlist slot (paper uses -1).
EMPTY_SLOT = -1


class TeamListFullError(RuntimeError):
    pass


class TeamList:
    """Paper-faithful bounded slot allocator (linear scan, §IV.B.2)."""

    def __init__(self, capacity: int = 256):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._slots: List[int] = [EMPTY_SLOT] * capacity

    def alloc(self, teamid: int) -> int:
        """Allocate the first empty slot for ``teamid`` (linear scan)."""
        for i in range(self.capacity):
            if self._slots[i] == EMPTY_SLOT:
                self._slots[i] = teamid
                return i
        raise TeamListFullError(
            f"teamlist exhausted ({self.capacity} live teams)")

    def lookup(self, teamid: int) -> int:
        """Find the slot index of ``teamid`` (linear scan, paper §IV.B.2)."""
        for i in range(self.capacity):
            if self._slots[i] == teamid:
                return i
        raise KeyError(f"team {teamid} not in teamlist")

    def free(self, teamid: int) -> int:
        i = self.lookup(teamid)
        self._slots[i] = EMPTY_SLOT
        return i

    def live(self) -> Tuple[int, ...]:
        return tuple(t for t in self._slots if t != EMPTY_SLOT)


class FreeListTeamList(TeamList):
    """Beyond-paper O(1) allocator (paper §VI future work).

    Keeps the identical interface and slot-reuse semantics, but replaces
    both linear scans with a free-slot stack (alloc/free) and an
    id→slot dict (lookup).  Free slots are handed out lowest-index-first
    to preserve the paper allocator's deterministic slot assignment.
    """

    def __init__(self, capacity: int = 256):
        super().__init__(capacity)
        self._free: List[int] = list(range(capacity - 1, -1, -1))  # stack, low idx on top
        self._index: Dict[int, int] = {}

    def alloc(self, teamid: int) -> int:
        if not self._free:
            raise TeamListFullError(
                f"teamlist exhausted ({self.capacity} live teams)")
        i = self._free.pop()
        self._slots[i] = teamid
        self._index[teamid] = i
        return i

    def lookup(self, teamid: int) -> int:
        try:
            return self._index[teamid]
        except KeyError:
            raise KeyError(f"team {teamid} not in teamlist") from None

    def free(self, teamid: int) -> int:
        i = self._index.pop(teamid)
        self._slots[i] = EMPTY_SLOT
        # push back keeping the stack sorted descending so that the lowest
        # free index is always allocated next (matches paper allocator).
        bisect.insort(self._free, i, key=lambda v: -v)
        return i


@dataclasses.dataclass(frozen=True)
class Team:
    """A DART team: an ordered set of units with collective identity."""

    teamid: int
    group: DartGroup
    slot: int                      # teamlist slot index (gptr.segid routing)
    parent: Optional[int] = None   # parent teamid
    #: poolid of this team's collective pool, bound at creation and
    #: mirrored in the heap's :class:`~repro.core.globmem.WindowRegistry`
    #: (teamid → PoolMeta).  Slots are reused after destroy (§IV.B.2) but
    #: pool ids are not, so dereference keys off this binding — never off
    #: slot arithmetic.
    poolid: int = -1

    def size(self) -> int:
        return self.group.size()

    # -- unit translation (paper §IV.B.4) -------------------------------
    def myid(self, absolute_unit: int) -> int:
        """absolute unit id → relative id in this team (-1 if absent)."""
        m = self.group.members
        i = bisect.bisect_left(m, absolute_unit)
        if i < len(m) and m[i] == absolute_unit:
            return i
        return -1

    def unit_at(self, relative_id: int) -> int:
        """relative id in this team → absolute unit id."""
        return self.group.members[relative_id]

    def contains(self, absolute_unit: int) -> bool:
        return self.myid(absolute_unit) >= 0

    # -- typed front-end ------------------------------------------------
    def alloc(self, ctx, shape, dtype, shm: bool = True):
        """Ergonomic typed allocator on this team's collective pool:
        ``team.alloc(ctx, shape, dtype)`` ≡ ``ctx.alloc(shape, dtype,
        team=team.teamid)`` (see :class:`repro.core.array.GlobalArray`)."""
        from .array import GlobalArray
        return GlobalArray.alloc(ctx, shape, dtype, team=self.teamid,
                                 shm=shm)


@dataclasses.dataclass(frozen=True)
class TeamPartition:
    """A partition of DART_TEAM_ALL into equal-size teams.

    SPMD collectives on the data plane (``jax.lax`` with
    ``axis_index_groups``) require the groups to tile all devices with
    equal sizes.  This mirrors how sub-communicators are used on TPU
    meshes (rows/columns); arbitrary unequal teams remain fully usable on
    the host control plane and for one-sided ops (``ppermute`` accepts
    arbitrary pairs).
    """

    teams: Tuple[Team, ...]

    def __post_init__(self):
        sizes = {t.size() for t in self.teams}
        if len(sizes) != 1:
            raise ValueError("TeamPartition requires equal-size teams")
        seen = [u for t in self.teams for u in t.group.members]
        if sorted(seen) != list(range(len(seen))):
            raise ValueError("TeamPartition must tile units 0..N-1 exactly")

    @property
    def axis_index_groups(self) -> Sequence[Sequence[int]]:
        return [list(t.group.members) for t in self.teams]

    def team_of(self, absolute_unit: int) -> Team:
        for t in self.teams:
            if t.contains(absolute_unit):
                return t
        raise KeyError(absolute_unit)
