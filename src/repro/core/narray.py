"""DASH-style distributed N-dimensional arrays over the GlobalArray substrate.

The DASH papers (PAPERS.md: 1610.01482, 1609.09333) layer multi-dimensional
distributed containers and an STL-flavoured algorithm set on top of exactly
the one-sided substrate this repo reproduces.  :class:`NArray` is that layer:
a global-shape array whose elements are spread over the team's symmetric
blocks by a *distribution pattern*, with

- **blocked**     — axis-0 row blocks, one contiguous slab per unit
- **cyclic**      — element ``g`` lives on unit ``g % n`` (flat, 1-D)
- **blockcyclic** — blocks of ``b`` elements dealt round-robin (flat, 1-D)
- **tiled**       — 2-D tiles over a ``gr x gc`` unit grid

and a first algorithm set (``copy`` / ``transform`` / ``min_element`` /
``reduce``) whose per-unit accesses are routed **local vs one-sided** by
:func:`repro.core.shm.classify_locality` — host-visible SHM blocks are read
zero-copy, everything else goes through the jitted engine path.  Cross-tile
column access (``get_col`` / halo reads in the stencil example) lowers onto
the strided descriptor IR, so a whole tile column is ONE engine dispatch.

Element addressing is by *global flat index* (row-major over the global
shape); every pattern answers ``owner(g) -> (unit, local_flat)`` and its
inverse ``global_index_map(u)``, and padding slots (uneven division) carry
global index ``-1`` so algorithms can mask them out.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .team import DART_TEAM_ALL

__all__ = [
    "NArray",
    "BlockedDist",
    "CyclicDist",
    "BlockCyclicDist",
    "TileDist",
    "narray_copy",
]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# distribution patterns
# ---------------------------------------------------------------------------

class _Dist:
    """Pattern base: maps global flat indices <-> (unit slot, local slot).

    ``bind(shape, n)`` is called once by :class:`NArray` and returns the
    per-unit *local block shape* handed to the GlobalArray allocator.
    ``owner(g)`` maps a global flat index to ``(unit_slot, local_flat)``.
    ``global_index_map(u)`` returns an int64 array of the local block's
    shape holding each slot's global flat index, or ``-1`` for padding.
    """

    name = "dist"

    def bind(self, shape: Tuple[int, ...], n: int) -> Tuple[int, ...]:
        raise NotImplementedError

    def owner(self, g: int) -> Tuple[int, int]:
        raise NotImplementedError

    def global_index_map(self, u: int) -> np.ndarray:
        raise NotImplementedError

    def describe(self) -> str:
        return self.name


class BlockedDist(_Dist):
    """Axis-0 blocks: unit ``u`` owns rows ``[u*rpb, (u+1)*rpb)``."""

    name = "blocked"

    def bind(self, shape, n):
        if not shape:
            raise ValueError("blocked distribution needs >= 1 axis")
        self.shape, self.n = shape, n
        self.rows = shape[0]
        self.row_elems = int(np.prod(shape[1:], dtype=np.int64)) if shape[1:] else 1
        self.rpb = _ceil_div(self.rows, n)
        return (self.rpb,) + tuple(shape[1:])

    def owner(self, g):
        row, rem = divmod(g, self.row_elems)
        u, lrow = divmod(row, self.rpb)
        return u, lrow * self.row_elems + rem

    def global_index_map(self, u):
        rows = np.arange(u * self.rpb, (u + 1) * self.rpb, dtype=np.int64)
        gmap = rows[:, None] * self.row_elems + np.arange(
            self.row_elems, dtype=np.int64)[None, :]
        gmap[rows >= self.rows, :] = -1
        return gmap.reshape((self.rpb,) + tuple(self.shape[1:]))


class CyclicDist(_Dist):
    """Element ``g`` lives on unit ``g % n`` at local slot ``g // n``."""

    name = "cyclic"

    def bind(self, shape, n):
        if len(shape) != 1:
            raise ValueError("cyclic distribution is 1-D (flatten first)")
        self.total, self.n = shape[0], n
        self.epu = _ceil_div(max(self.total, 1), n)
        return (self.epu,)

    def owner(self, g):
        return g % self.n, g // self.n

    def global_index_map(self, u):
        gmap = np.arange(self.epu, dtype=np.int64) * self.n + u
        gmap[gmap >= self.total] = -1
        return gmap


class BlockCyclicDist(_Dist):
    """Blocks of ``b`` elements dealt round-robin over the team."""

    name = "blockcyclic"

    def __init__(self, b: int):
        if b < 1:
            raise ValueError("block size must be >= 1")
        self.b = int(b)

    def bind(self, shape, n):
        if len(shape) != 1:
            raise ValueError("blockcyclic distribution is 1-D (flatten first)")
        self.total, self.n = shape[0], n
        self.nblocks = _ceil_div(max(self.total, 1), self.b)
        self.bpu = _ceil_div(self.nblocks, n)
        self.epu = self.bpu * self.b
        return (self.epu,)

    def owner(self, g):
        blk, rem = divmod(g, self.b)
        return blk % self.n, (blk // self.n) * self.b + rem

    def global_index_map(self, u):
        lblk = np.arange(self.bpu, dtype=np.int64)
        blk = lblk * self.n + u                       # owned global block ids
        base = blk[:, None] * self.b + np.arange(self.b, dtype=np.int64)[None, :]
        base[blk >= self.nblocks, :] = -1
        gmap = base.reshape(-1)
        gmap[gmap >= self.total] = -1
        return gmap

    def describe(self):
        return f"blockcyclic({self.b})"


class TileDist(_Dist):
    """2-D tiles over a ``gr x gc`` unit grid (``gr*gc == team size``)."""

    name = "tiled"

    def __init__(self, grid: Tuple[int, int]):
        self.gr, self.gc = int(grid[0]), int(grid[1])
        if self.gr < 1 or self.gc < 1:
            raise ValueError("tile grid must be positive")

    def bind(self, shape, n):
        if len(shape) != 2:
            raise ValueError("tiled distribution is 2-D")
        if self.gr * self.gc != n:
            raise ValueError(
                f"tile grid {self.gr}x{self.gc} != team size {n}")
        self.R, self.C = shape
        self.tr = _ceil_div(self.R, self.gr)
        self.tc = _ceil_div(self.C, self.gc)
        return (self.tr, self.tc)

    def owner(self, g):
        r, c = divmod(g, self.C)
        ti, lr = divmod(r, self.tr)
        tj, lc = divmod(c, self.tc)
        return ti * self.gc + tj, lr * self.tc + lc

    def tile_of(self, u: int) -> Tuple[int, int]:
        return divmod(u, self.gc)

    def global_index_map(self, u):
        ti, tj = self.tile_of(u)
        rows = np.arange(ti * self.tr, (ti + 1) * self.tr, dtype=np.int64)
        cols = np.arange(tj * self.tc, (tj + 1) * self.tc, dtype=np.int64)
        gmap = rows[:, None] * self.C + cols[None, :]
        gmap[rows >= self.R, :] = -1
        gmap[:, cols >= self.C] = -1
        return gmap

    def describe(self):
        return f"tiled({self.gr}x{self.gc})"


# ---------------------------------------------------------------------------
# the container
# ---------------------------------------------------------------------------

class NArray:
    """A distributed N-d array: global ``shape`` spread over the team by
    ``dist`` (a :class:`_Dist` instance, or the strings ``"blocked"`` /
    ``"cyclic"``), backed by one :class:`GlobalArray` whose per-unit block
    is the pattern's local block.
    """

    def __init__(self, ctx, shape: Sequence[int], dtype,
                 dist="blocked", team: int = DART_TEAM_ALL, shm: bool = True):
        if isinstance(dist, str):
            dist = {"blocked": BlockedDist, "cyclic": CyclicDist}[dist]()
        self.ctx = ctx
        self.shape = tuple(int(s) for s in shape)
        self.total = int(np.prod(self.shape, dtype=np.int64)) if self.shape else 1
        self.dtype = jnp.dtype(dtype)
        self.dist = dist
        self.ga = ctx.alloc(dist.bind(self.shape, self._team_size(ctx, team)),
                            self.dtype, team=team, shm=shm)
        # local-vs-one-sided routing decisions taken by the algorithms
        self.route_stats = {"local": 0, "onesided": 0}

    @staticmethod
    def _team_size(ctx, team):
        return ctx.teams[team].size()

    # -- identity --------------------------------------------------------
    @property
    def units(self) -> Tuple[int, ...]:
        return self.ga.units

    @property
    def local_shape(self) -> Tuple[int, ...]:
        return self.ga.shape

    def free(self) -> None:
        self.ga.free()

    def __repr__(self):
        return (f"NArray(shape={self.shape}, dtype={self.dtype.name}, "
                f"dist={self.dist.describe()}, units={len(self.units)})")

    # -- locality-routed block reads ------------------------------------
    def _read_block(self, u: int) -> jax.Array:
        """Read unit ``u``'s whole local block, counting the route the
        locality classifier picks (zero-copy SHM view vs one-sided get)."""
        from .shm import Locality, classify_locality
        g = self.ga.gptr.setunit(u)
        route = classify_locality(self.ctx, g)
        self.route_stats[
            "local" if route is Locality.SHM_LOCAL else "onesided"] += 1
        return self.ga.local_view(u)

    def _unit_slot(self, slot: int) -> int:
        return self.units[slot]

    # -- element access --------------------------------------------------
    def _flat(self, index) -> int:
        if isinstance(index, tuple):
            if len(index) != len(self.shape):
                raise IndexError(
                    f"index {index} does not address all {len(self.shape)} axes")
            g = 0
            for ax, (i, s) in enumerate(zip(index, self.shape)):
                i = int(i)
                if not 0 <= i < s:
                    raise IndexError(f"index {i} out of range for axis {ax}")
                g = g * s + i
            return g
        g = int(index)
        if not 0 <= g < self.total:
            raise IndexError(f"flat index {g} out of range ({self.total})")
        return g

    def __getitem__(self, index):
        """Scalar read by global (tuple or flat) index, locality-routed."""
        u, loc = self.dist.owner(self._flat(index))
        return self._read_block(self._unit_slot(u)).reshape(-1)[loc]

    def __setitem__(self, index, value) -> None:
        """Scalar write by global index (one-sided put, flushed)."""
        u, loc = self.dist.owner(self._flat(index))
        ref = self.ga.at[self._unit_slot(u), loc] if len(
            self.local_shape) == 1 else self.ga.at[
                (self._unit_slot(u),) + np.unravel_index(loc, self.local_shape)]
        ref.put(jnp.asarray(value, self.dtype).reshape(ref.shape))

    # -- whole-array movement -------------------------------------------
    def from_numpy(self, arr) -> None:
        """Scatter a host array of the global shape into every block."""
        arr = np.asarray(arr, self.dtype)
        if arr.shape != self.shape:
            raise ValueError(f"shape {arr.shape} != global {self.shape}")
        flat = arr.reshape(-1)
        for slot, u in enumerate(self.units):
            gmap = self.dist.global_index_map(slot)
            blk = np.zeros(self.local_shape, self.dtype)
            mask = gmap >= 0
            blk[mask] = flat[gmap[mask]]
            self.ga[u].put(jnp.asarray(blk))

    def to_numpy(self) -> np.ndarray:
        """Assemble the global array (locality-routed per-unit reads)."""
        out = np.zeros(self.total, dtype=self.dtype)
        for slot, u in enumerate(self.units):
            gmap = self.dist.global_index_map(slot)
            blk = np.asarray(self._read_block(u))
            mask = gmap >= 0
            out[gmap[mask]] = blk[mask]
        return out.reshape(self.shape)

    def fill(self, value) -> None:
        for u in self.units:
            self.ga[u].put(jnp.full(self.local_shape, value, self.dtype))

    # -- strided cross-block access (tiled) ------------------------------
    def get_col(self, j: int) -> np.ndarray:
        """Global column ``j`` of a tiled 2-D NArray.

        Each owning tile contributes ONE strided gather
        (``ga.at[u, :, lc]`` -> seg=1 elem, stride=tile cols, count=tile
        rows) instead of ``tr`` scalar gets — the strided descriptor IR
        showcase this container exists for.
        """
        if not isinstance(self.dist, TileDist):
            raise TypeError("get_col needs a tiled distribution")
        d = self.dist
        if not 0 <= j < d.C:
            raise IndexError(f"column {j} out of range ({d.C})")
        tj, lc = divmod(j, d.tc)
        out = np.zeros(d.R, dtype=self.dtype)
        handles = []
        for ti in range(d.gr):
            u = self._unit_slot(ti * d.gc + tj)
            handles.append((ti, self.ga.at[u, :, lc].get_nb()))
        for ti, h in handles:
            col = np.asarray(h.value()).reshape(-1)
            r0 = ti * d.tr
            n = min(d.tr, d.R - r0)
            out[r0:r0 + n] = col[:n]
        return out

    # -- DASH algorithm set ----------------------------------------------
    def transform(self, fn: Callable[[jax.Array], jax.Array],
                  out: Optional["NArray"] = None) -> "NArray":
        """Elementwise ``out[i] = fn(self[i])`` (dash::transform).

        Reads route local-vs-one-sided via the classifier; writes are
        one-sided puts into ``out`` (defaults to in-place).
        """
        out = out or self
        if out.shape != self.shape or not isinstance(
                out.dist, type(self.dist)):
            raise ValueError("transform needs a same-shape, same-dist out")
        for u in self.units:
            blk = self._read_block(u)
            out.ga[u].put(jnp.asarray(fn(blk), out.dtype).reshape(
                out.local_shape))
        return out

    def min_element(self) -> Tuple[int, jax.Array]:
        """Global ``(flat_index, value)`` of the minimum (dash::min_element).

        Per-unit blocks are scanned with padding slots masked to +inf;
        ties resolve to the lowest global index.
        """
        best_g, best_v = -1, None
        for slot, u in enumerate(self.units):
            gmap = self.dist.global_index_map(slot).reshape(-1)
            blk = np.asarray(self._read_block(u)).reshape(-1)
            valid = gmap >= 0
            if not valid.any():
                continue
            vals = np.where(valid, blk, np.inf)
            order = np.lexsort((np.where(valid, gmap, np.iinfo(np.int64).max),
                                vals))
            i = order[0]
            v = blk[i]
            if best_g < 0 or v < best_v or (v == best_v and gmap[i] < best_g):
                best_g, best_v = int(gmap[i]), v
        return best_g, jnp.asarray(best_v, self.dtype)

    def reduce(self, op: str = "sum"):
        """Reduce every element to a scalar (dash::reduce / accumulate)."""
        combine = {"sum": np.add, "prod": np.multiply,
                   "min": np.minimum, "max": np.maximum}[op]
        acc = None
        for slot, u in enumerate(self.units):
            gmap = self.dist.global_index_map(slot)
            blk = np.asarray(self._read_block(u))
            vals = blk[gmap >= 0]
            if vals.size == 0:
                continue
            part = combine.reduce(vals)
            acc = part if acc is None else combine(acc, part)
        return jnp.asarray(acc, self.dtype)

    def sum(self):
        return self.reduce("sum")


def narray_copy(src: NArray, dst: NArray) -> NArray:
    """dash::copy — copy ``src`` into ``dst`` (same global shape; any
    distribution pair).  Same-pattern copies move whole blocks; mixed
    patterns redistribute through the assembled global array."""
    if src.shape != dst.shape:
        raise ValueError(f"shape {src.shape} != {dst.shape}")
    same = (type(src.dist) is type(dst.dist)
            and src.local_shape == dst.local_shape
            and src.units == dst.units)
    if same:
        for u in src.units:
            dst.ga[u].put(src._read_block(u).astype(dst.dtype))
    else:
        dst.from_numpy(src.to_numpy().astype(dst.dtype))
    return dst
