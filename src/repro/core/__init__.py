"""DART-JAX core: the paper's PGAS runtime (DART-MPI, §III/§IV) on JAX.

Two layers (docs/API.md):

* the **byte-offset substrate** mirroring the DART specification —
  initialization, team/group management, synchronization, global memory
  management, and communication (one-sided + collective) over raw
  128-bit global pointers;
* the **typed front-end** — :class:`GlobalArray` / :class:`GlobalRef`
  minted by ``ctx.alloc`` / ``Team.alloc``, which hides byte offsets,
  ``to_bytes``/``from_bytes``, and unit arithmetic entirely.
"""

from .faults import (DartError, FaultPlane, FaultSpec, FlushTimeoutError,
                     RetriesExhaustedError, ShmBoundsError,
                     TransientDispatchFault, UnitFailedError)
from .gptr import (ADDR_MAX, DART_GPTR_NULL, FLAG_COLLECTIVE, FLAG_SHM,
                   NON_COLLECTIVE_SEG, GlobalPtr)
from .group import (DartGroup, dart_group_addmember, dart_group_copy,
                    dart_group_delmember, dart_group_init,
                    dart_group_intersect, dart_group_split,
                    dart_group_union, group_from_units)
from .team import (DART_TEAM_ALL, EMPTY_SLOT, FreeListTeamList, Team,
                   TeamList, TeamListFullError, TeamPartition)
from .globmem import (ALIGNMENT, BlockAllocator, HeapState,
                      OutOfGlobalMemory, SymmetricHeap, TranslationRecord,
                      TranslationTable, WindowDestroyedError, WindowRegistry,
                      align_up, copy_state, from_bytes, nbytes_of, to_bytes)
from .onesided import (CommEngine, GetHandle, Handle, dart_test,
                       dart_testall, dart_wait, dart_waitall, deref,
                       shmem_get, shmem_get_dynamic, shmem_halo_exchange,
                       shmem_put)
from .collectives import (team_all_gather, team_all_to_all, team_barrier,
                          team_broadcast, team_pmax, team_psum,
                          team_reduce_scatter)
from .atomics import AtomicsProvider, Cell, ThreadedAtomics
from .lock import FREE, DartLock, LockService
from .progress import ProgressPlane
from .shm import (Locality, classify_locality, dart_shm_put, dart_shm_view,
                  dart_team_memalloc_shared, invalidate_shm_cache, mint_shm,
                  shm_supported, shm_writable, try_shm_put, try_shm_view)
from .atomic_ops import (HeapAtomicsProvider, dart_compare_and_swap,
                         dart_fetch_and_add, dart_fetch_and_store)
from .runtime import (DartConfig, DartContext, dart_accumulate,
                      dart_accumulate_blocking, dart_allreduce,
                      dart_barrier, dart_bcast, dart_exit, dart_flush,
                      dart_gather, dart_gather_typed, dart_get,
                      dart_get_accumulate, dart_get_blocking,
                      dart_get_nb, dart_init, dart_memalloc, dart_memfree,
                      dart_put, dart_put_blocking, dart_reduce,
                      dart_scatter, dart_scatter_typed, dart_team_create,
                      dart_team_destroy, dart_team_get_group,
                      dart_team_memalloc_aligned, dart_team_memfree,
                      dart_team_myid, dart_team_size, dart_team_split)
from .array import GlobalArray, GlobalRef
from .narray import (BlockCyclicDist, BlockedDist, CyclicDist, NArray,
                     TileDist, narray_copy)

# Curated public surface (no dir()-scraping: scraping re-exported the
# submodule names bound by the imports above, leaking e.g. ``gptr`` and
# ``runtime`` as if they were API and hiding the real surface).
__all__ = [
    # typed front-end
    "GlobalArray", "GlobalRef",
    # fault plane + typed error ladder
    "DartError", "FaultPlane", "FaultSpec", "FlushTimeoutError",
    "RetriesExhaustedError", "ShmBoundsError", "TransientDispatchFault",
    "UnitFailedError",
    # DASH-style distributed containers
    "NArray", "BlockedDist", "CyclicDist", "BlockCyclicDist", "TileDist",
    "narray_copy",
    # global pointers
    "ADDR_MAX", "DART_GPTR_NULL", "FLAG_COLLECTIVE", "FLAG_SHM",
    "NON_COLLECTIVE_SEG", "GlobalPtr",
    # groups
    "DartGroup", "dart_group_addmember", "dart_group_copy",
    "dart_group_delmember", "dart_group_init", "dart_group_intersect",
    "dart_group_split", "dart_group_union", "group_from_units",
    # teams
    "DART_TEAM_ALL", "EMPTY_SLOT", "FreeListTeamList", "Team", "TeamList",
    "TeamListFullError", "TeamPartition",
    # global memory
    "ALIGNMENT", "BlockAllocator", "HeapState", "OutOfGlobalMemory",
    "SymmetricHeap", "TranslationRecord", "TranslationTable",
    "WindowDestroyedError", "WindowRegistry", "align_up", "copy_state",
    "from_bytes", "nbytes_of", "to_bytes",
    # one-sided engine + handles + background progress
    "CommEngine", "GetHandle", "Handle", "ProgressPlane", "dart_test",
    "dart_testall",
    "dart_wait", "dart_waitall", "deref", "shmem_get", "shmem_get_dynamic",
    "shmem_halo_exchange", "shmem_put",
    # collectives
    "dart_gather_typed", "dart_scatter_typed", "team_all_gather",
    "team_all_to_all", "team_barrier", "team_broadcast", "team_pmax",
    "team_psum", "team_reduce_scatter",
    # atomics + locks
    "AtomicsProvider", "Cell", "ThreadedAtomics", "HeapAtomicsProvider",
    "dart_compare_and_swap", "dart_fetch_and_add", "dart_fetch_and_store",
    "FREE", "DartLock", "LockService",
    # shared-memory windows (read views + the zero-copy write plane)
    "Locality", "classify_locality", "dart_shm_put", "dart_shm_view",
    "dart_team_memalloc_shared", "invalidate_shm_cache", "mint_shm",
    "shm_supported", "shm_writable", "try_shm_put", "try_shm_view",
    # runtime
    "DartConfig", "DartContext", "dart_accumulate",
    "dart_accumulate_blocking", "dart_allreduce", "dart_barrier",
    "dart_bcast", "dart_exit", "dart_flush", "dart_gather", "dart_get",
    "dart_get_accumulate", "dart_get_blocking", "dart_get_nb",
    "dart_init", "dart_memalloc", "dart_memfree", "dart_put",
    "dart_put_blocking", "dart_reduce", "dart_scatter",
    "dart_team_create", "dart_team_destroy", "dart_team_get_group",
    "dart_team_memalloc_aligned", "dart_team_memfree", "dart_team_myid",
    "dart_team_size", "dart_team_split",
]
