"""DART runtime context: init/exit, teams, global memory (paper §III/IV).

Single-controller layout: one :class:`DartContext` owns

* the unit space (``n_units``; on a device mesh, the flattened devices),
* the teamlist + ``teams`` registry (slot-indexed, §IV.B.2),
* the symmetric heap layout + device heap state (§IV.B.3),
* the lock service (§IV.B.6).

``dart_init`` reserves the non-collective WORLD pool and creates
DART_TEAM_ALL with its collective pool — which "opens the shared access
epoch" in paper terms (a no-op under XLA's unified-model dataflow; see
docs/API.md, "Epochs, flush, and completion").

This module is the byte-offset *substrate* layer; the typed
:class:`repro.core.array.GlobalArray` front-end (``ctx.alloc``) sits on
top of it — docs/API.md describes the two-layer design.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax

from .atomics import ThreadedAtomics
from .globmem import HeapState, SymmetricHeap
from .gptr import (FLAG_COLLECTIVE, NON_COLLECTIVE_SEG, GlobalPtr)
from .group import DartGroup
from .lock import LockService
from .team import (DART_TEAM_ALL, FreeListTeamList, Team, TeamList,
                   TeamPartition)
from . import onesided as _os
from . import collectives as _coll
from . import progress as _prog
from . import shm as _shm


@dataclasses.dataclass
class DartConfig:
    non_collective_pool_bytes: int = 1 << 20   # per-unit WORLD partition
    team_pool_bytes: int = 1 << 20             # per-member team pool
    teamlist_capacity: int = 256
    teamlist_impl: str = "paper"               # 'paper' | 'freelist' (§VI)
    lock_tail_placement: str = "unit0"         # 'unit0' | 'round_robin' (§VI)
    # background progress plane defaults (ctx.start_progress();
    # docs/API.md "Threading model & progress")
    progress_watermark_bytes: int = 1 << 16
    progress_watermark_ops: int = 32
    progress_idle_s: float = 0.005
    # fault plane / retry knobs (docs/API.md "Failure model"): a flush
    # retrying past flush_deadline_s raises FlushTimeoutError; None
    # bounds retries only by flush_retry_limit.
    flush_deadline_s: Optional[float] = None
    flush_retry_limit: int = 3
    flush_retry_base_s: float = 0.001
    flush_retry_max_s: float = 0.05


class DartContext:
    """The live runtime (the paper's process-global DART state)."""

    def __init__(self, n_units: int, config: DartConfig,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 unit_axes: Optional[Tuple[str, ...]] = None):
        self.n_units = n_units
        self.config = config
        self.mesh = mesh
        self.heap = SymmetricHeap(n_units, mesh=mesh, unit_axes=unit_axes)
        tl_cls = TeamList if config.teamlist_impl == "paper" else FreeListTeamList
        self.teamlist = tl_cls(config.teamlist_capacity)
        self.teams: Dict[int, Team] = {}          # teamid -> Team
        self.teams_by_slot: Dict[int, Team] = {}  # slot   -> Team
        self._next_teamid = 0
        self.atomics = ThreadedAtomics(n_units)
        self.locks = LockService(self.atomics,
                                 tail_placement=config.lock_tail_placement)
        self.state: HeapState = {}
        # epoch-scoped pending-op queue (onesided.CommEngine): dart_put/
        # dart_get_nb enqueue here; dart_flush / handle.wait() dispatch
        # coalesced batches against self.state.
        self.engine = _os.CommEngine(holder=self)
        self.engine.retry_limit = config.flush_retry_limit
        self.engine.retry_base_s = config.flush_retry_base_s
        self.engine.retry_max_s = config.flush_retry_max_s
        self.engine.flush_deadline_s = config.flush_deadline_s
        # background progress plane (None until start_progress);
        # owns the daemon that drains queued lanes at the watermarks.
        self.progress: Optional["_prog.ProgressPlane"] = None
        # heartbeat monitor (None until attach_heartbeat_monitor);
        # sweep_failures() maps its dead hosts onto engine unit deaths.
        self.heartbeats = None
        self._devices_per_host = 1
        self._initialized = False

    # -- typed front-end (docs/API.md) ---------------------------------
    def alloc(self, shape, dtype, team: int = DART_TEAM_ALL,
              shm: bool = True):
        """Ergonomic typed allocator: a :class:`GlobalArray` of
        ``shape`` elements of ``dtype`` per member of ``team``."""
        from .array import GlobalArray
        return GlobalArray.alloc(self, shape, dtype, team=team, shm=shm)

    def epoch(self, gptr: Optional[GlobalPtr] = None):
        """Epoch as a ``with`` block: non-blocking ops enqueued inside
        are flushed — coalesced — on exit (``gptr`` scopes the flush to
        one pool).  The explicit form of the queued→issued→complete
        ladder (docs/API.md)."""
        poolid = None
        if gptr is not None:
            poolid, _, _ = _os.deref(self.heap, self.teams_by_slot, gptr)
        return self.engine.epoch_scope(poolid)

    # -- background progress plane (docs/API.md "Threading model") -----
    def start_progress(self, *, watermark_bytes: Optional[int] = None,
                       watermark_ops: Optional[int] = None,
                       idle_s: Optional[float] = None
                       ) -> "_prog.ProgressPlane":
        """Start (or return the already-running) background progress
        plane: a daemon thread that flushes a queued ``(pool, row)``
        lane when it crosses the byte/op watermark or sits idle past
        ``idle_s``.  Knobs default from :class:`DartConfig`."""
        if self.progress is not None and self.progress.running:
            return self.progress
        cfg = self.config
        self.progress = _prog.ProgressPlane(
            self.engine,
            watermark_bytes=(cfg.progress_watermark_bytes
                             if watermark_bytes is None else watermark_bytes),
            watermark_ops=(cfg.progress_watermark_ops
                           if watermark_ops is None else watermark_ops),
            idle_s=cfg.progress_idle_s if idle_s is None else idle_s)
        return self.progress.start()

    def stop_progress(self, drain: bool = True) -> None:
        """Stop the progress plane; with ``drain`` (default) everything
        still queued is flushed — shutdown never drops ops."""
        if self.progress is not None:
            self.progress.stop(drain=drain)

    # -- fault plane (docs/API.md "Failure model & fault plane") --------

    def attach_faults(self, plane=None, **kw):
        """Attach a :class:`~repro.core.faults.FaultPlane` to the
        engine's dispatch boundary (and, transitively, the progress
        plane's drain loop).  Pass an existing plane, or keyword args
        (``seed``, ``fail_rate``, ...) to build one.  Returns it."""
        from .faults import FaultPlane
        if plane is None:
            plane = FaultPlane(**kw)
        self.engine.attach_faults(plane)
        return plane

    def attach_heartbeat_monitor(self, monitor,
                                 devices_per_host: int = 1) -> None:
        """Bind a :class:`~repro.ft.elastic.HeartbeatMonitor`;
        :meth:`sweep_failures` maps its dead *hosts* onto engine unit
        deaths (``devices_per_host`` units per host, contiguous)."""
        if devices_per_host < 1:
            raise ValueError("devices_per_host must be >= 1")
        self.heartbeats = monitor
        self._devices_per_host = int(devices_per_host)

    def sweep_failures(self):
        """Sweep the attached heartbeat monitor and declare every unit
        of each newly dead host dead on the engine: their queued ops
        fail with :class:`~repro.core.faults.UnitFailedError`, later
        enqueues fail fast, and surviving lanes keep flushing.
        Returns the list of newly dead units (empty without a
        monitor)."""
        if self.heartbeats is None:
            return []
        from ..ft.elastic import units_of_host
        newly_dead_hosts = self.heartbeats.sweep()
        dead_units = []
        for host in newly_dead_hosts:
            for u in units_of_host(host, self._devices_per_host):
                if u >= self.n_units or u in self.engine.dead_units:
                    continue
                self.engine.mark_unit_dead(
                    u, reason=f"host {host} missed heartbeats")
                dead_units.append(u)
        return dead_units

    @property
    def windows(self):
        """The heap's teamid → live-PoolMeta window registry: the
        binding ``deref`` routes collective pointers through (the MPI
        window-object table; see ``globmem.WindowRegistry``)."""
        return self.heap.windows

    # ------------------------------------------------------------------
    def _create_team(self, group: DartGroup, parent: Optional[int]) -> Team:
        teamid = self._next_teamid
        self._next_teamid += 1                  # teamIDs never reused (§IV.B.2)
        slot = self.teamlist.alloc(teamid)
        # reserve the team's collective pool + empty translation table,
        # and bind it: registry entry + poolid carried on the Team.
        # Pool ids are monotonic while slots are reused (§IV.B.2), so
        # this binding — not slot arithmetic — is what deref keys off.
        meta = self.heap.reserve_pool(
            n_rows=group.size(), pool_bytes=self.config.team_pool_bytes,
            collective=True)
        team = Team(teamid=teamid, group=group, slot=slot, parent=parent,
                    poolid=meta.poolid)
        self.teams[teamid] = team
        self.teams_by_slot[slot] = team
        self.heap.windows.register(teamid, meta)
        self.state[meta.poolid] = self.heap.init_pool_state(meta)
        return team

    # ------------------------------------------------------------------


def dart_init(n_units: Optional[int] = None,
              mesh: Optional[jax.sharding.Mesh] = None,
              unit_axes: Optional[Tuple[str, ...]] = None,
              config: Optional[DartConfig] = None) -> DartContext:
    """Initialize the runtime (paper: ``dart_init``)."""
    config = config or DartConfig()
    if n_units is None:
        n_units = (int(np_prod(mesh.devices.shape)) if mesh is not None
                   else jax.device_count())
    ctx = DartContext(n_units, config, mesh=mesh, unit_axes=unit_axes)
    # pre-reserved WORLD window for non-collective allocations (§IV.B.3)
    world_meta = ctx.heap.reserve_pool(
        n_rows=n_units, pool_bytes=config.non_collective_pool_bytes,
        collective=False)
    assert world_meta.poolid == _os.WORLD_POOLID
    ctx.state[world_meta.poolid] = ctx.heap.init_pool_state(world_meta)
    # DART_TEAM_ALL
    all_group = DartGroup(tuple(range(n_units)))
    team_all = ctx._create_team(all_group, parent=None)
    assert team_all.teamid == DART_TEAM_ALL
    ctx._initialized = True
    return ctx


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def dart_exit(ctx: DartContext) -> None:
    """Tear down (paper: ``dart_exit``)."""
    # stop the progress plane first (drain=True flushes, never drops),
    # so no background flush races the state teardown below
    ctx.stop_progress(drain=True)
    ctx.engine.clear()
    ctx.state.clear()
    ctx.teams.clear()
    ctx.teams_by_slot.clear()
    ctx.heap.windows.clear()
    _shm.invalidate_shm_cache(ctx)     # every probe result dies with the heap
    ctx._initialized = False


# -- team management (paper §III) -------------------------------------------

def dart_team_create(ctx: DartContext, parent_teamid: int,
                     group: DartGroup) -> int:
    """Collective team creation from a group (paper: subset of parent)."""
    parent = ctx.teams[parent_teamid]
    for u in group.members:
        if not parent.contains(u):
            raise ValueError(f"unit {u} not in parent team {parent_teamid}")
    return ctx._create_team(group, parent=parent_teamid).teamid


def dart_team_destroy(ctx: DartContext, teamid: int) -> None:
    if teamid == DART_TEAM_ALL:
        raise ValueError("cannot destroy DART_TEAM_ALL")
    team = ctx.teams.pop(teamid)
    ctx.teams_by_slot.pop(team.slot)
    ctx.teamlist.free(teamid)            # slot becomes reusable (§IV.B.2)
    meta = ctx.heap.windows.drop(teamid)
    # queued engine ops against the dropped window can never be
    # dispatched (their arena is going away): fail their handles now
    # with a clear error instead of KeyError-ing a later flush of
    # unrelated pools.
    ctx.engine.drop_pool(meta.poolid, reason=f"team {teamid} destroyed",
                         teamid=teamid)
    ctx.state.pop(meta.poolid, None)
    ctx.heap.drop_pool(meta.poolid)
    # drop the dropped pool's shm-support cache entry (poolids are never
    # reused, but a stale positive must not outlive its arena)
    _shm.invalidate_shm_cache(ctx, meta.poolid)


def dart_team_get_group(ctx: DartContext, teamid: int) -> DartGroup:
    return ctx.teams[teamid].group


def dart_team_myid(ctx: DartContext, teamid: int, absolute_unit: int) -> int:
    return ctx.teams[teamid].myid(absolute_unit)


def dart_team_size(ctx: DartContext, teamid: int) -> int:
    return ctx.teams[teamid].size()


def dart_team_split(ctx: DartContext, teamid: int, n: int) -> TeamPartition:
    """Split a team into n equal sub-teams (device-plane collective use)."""
    from .group import dart_group_split
    subgroups = dart_group_split(ctx.teams[teamid].group, n)
    teams = tuple(ctx.teams[dart_team_create(ctx, teamid, g)]
                  for g in subgroups)
    return TeamPartition(teams)


# -- global memory (paper §III, §IV.B.3) -------------------------------------

def dart_memalloc(ctx: DartContext, nbytes: int, unit: int) -> GlobalPtr:
    """Non-collective allocation on ``unit``'s WORLD partition."""
    meta = ctx.heap.pools[_os.WORLD_POOLID]
    off = ctx.heap.memalloc_local(meta, unit, nbytes)
    return GlobalPtr(unitid=unit, segid=NON_COLLECTIVE_SEG, flags=0,
                     addr=off)


def dart_memfree(ctx: DartContext, gptr: GlobalPtr) -> None:
    if gptr.is_collective:
        raise ValueError("use dart_team_memfree for collective pointers")
    meta = ctx.heap.pools[_os.WORLD_POOLID]
    ctx.heap.memfree_local(meta, gptr.unitid, gptr.addr)


def dart_team_memalloc_aligned(ctx: DartContext, teamid: int,
                               nbytes_per_unit: int) -> GlobalPtr:
    """Collective aligned/symmetric allocation (paper Fig. 5).

    Returns a collective global pointer to the beginning of the
    allocation, owned by the team's first member; any member can
    ``setunit`` it to address any other member's portion at the same
    offset.
    """
    team = ctx.teams[teamid]
    meta = ctx.heap.windows.lookup(teamid)
    off = ctx.heap.memalloc_aligned(meta, nbytes_per_unit)
    return GlobalPtr(unitid=team.unit_at(0), segid=team.slot,
                     flags=FLAG_COLLECTIVE, addr=off)


def dart_team_memfree(ctx: DartContext, teamid: int,
                      gptr: GlobalPtr) -> None:
    meta = ctx.heap.windows.lookup(teamid)
    ctx.heap.memfree_aligned(meta, gptr.addr)


# -- one-sided + collective conveniences bound to a context ------------------
#
# Non-blocking ops ENQUEUE on ctx.engine (initiation = translation +
# bounds check only); dispatch happens at dart_flush / handle.wait() /
# a blocking op on the same pool, coalescing queued ops into batched
# jitted kernels (see onesided.py module docstring).

def dart_put(ctx: DartContext, gptr: GlobalPtr, value, *,
             stride: int = 0, count: int = 1):
    """Non-blocking put: enqueue on the engine, return a queued handle.
    ``count > 1`` splits the payload into ``count`` equal segments
    landing ``stride`` bytes apart (one strided descriptor, one
    coalesced dispatch share — see docs/API.md "Strided transfers")."""
    return ctx.engine.put(ctx.heap, ctx.teams_by_slot, gptr, value,
                          stride=stride, count=count)


def dart_put_blocking(ctx: DartContext, gptr: GlobalPtr, value, *,
                      stride: int = 0, count: int = 1) -> None:
    """Blocking put, locality-routed (write-side mirror of
    :func:`dart_get_blocking`).

    SHM-writable targets (FLAG_SHM pointer + host-writable arena) take
    the zero-copy window path: the target's queued lane is flushed
    (program order), then the bytes land via a locked host-side write
    with ZERO jitted dispatches — ``shm.try_shm_put``.  Everything else
    (device-only arenas, plain pointers) enqueues + flushes through the
    engine's jitted scatter exactly as before.  Non-blocking
    ``dart_put`` always stays on the engine: its contract is queued
    coalescing, which a direct write would defeat.
    """
    if _shm.try_shm_put(ctx, gptr, value, stride=stride,
                        count=count) is not None:
        return
    h = ctx.engine.put(ctx.heap, ctx.teams_by_slot, gptr, value,
                       stride=stride, count=count)
    h.wait()


def dart_accumulate(ctx: DartContext, gptr: GlobalPtr, value,
                    op: str = "sum", *, stride: int = 0,
                    count: int = 1):
    """Non-blocking element-wise accumulate at the target (the
    ``MPI_Accumulate`` analogue): enqueue on the engine, return a
    queued handle.  Consecutive same-``op`` accumulates to one pool
    coalesce into ONE segmented read-modify-write dispatch at flush —
    overlapping ranges included, since the ops commute; mixed-op or
    accumulate-vs-put overlap splits the run in queue order."""
    return ctx.engine.accumulate(ctx.heap, ctx.teams_by_slot, gptr,
                                 value, op, stride=stride, count=count)


def dart_accumulate_blocking(ctx: DartContext, gptr: GlobalPtr, value,
                             op: str = "sum", *, stride: int = 0,
                             count: int = 1) -> None:
    """Blocking accumulate: enqueue + flush + local/remote completion."""
    h = ctx.engine.accumulate(ctx.heap, ctx.teams_by_slot, gptr, value,
                              op, stride=stride, count=count)
    h.wait()


def dart_get_accumulate(ctx: DartContext, gptr: GlobalPtr, value,
                        op: str = "sum", *, stride: int = 0,
                        count: int = 1):
    """Fetch-and-accumulate (the ``MPI_Get_accumulate`` analogue):
    flushes the target's ``(pool, row)`` lane and returns
    ``(old_value, handle)`` — the target's typed value from *before*
    this op applied, decoded host-side from the fused dispatch.  For
    the queued form use ``ctx.engine.get_accumulate`` directly and
    ``handle.value()`` later."""
    h = ctx.engine.get_accumulate(ctx.heap, ctx.teams_by_slot, gptr,
                                  value, op, stride=stride, count=count)
    ctx.engine.flush(h.poolid, h.row)
    return h.value(), h


def dart_get_nb(ctx: DartContext, gptr: GlobalPtr, shape, dtype, *,
                stride: int = 0, count: int = 1):
    """Non-blocking get: enqueue; ``handle.value()`` flushes and yields
    the typed result.  Consecutive same-size gets coalesce at flush.
    ``count > 1`` gathers ``count`` equal segments ``stride`` bytes
    apart, returned densely packed in the requested shape."""
    return ctx.engine.get(ctx.heap, ctx.teams_by_slot, gptr, shape,
                          dtype, stride=stride, count=count)


def dart_get(ctx: DartContext, gptr: GlobalPtr, shape, dtype, *,
             stride: int = 0, count: int = 1):
    """Issue-immediately get: returns (value, handle).

    Flushes the target's ``(pool, row)`` lane (queued puts to that unit
    become visible — read-after-write ordering; other targets' queued
    epochs keep accumulating), then dispatches the read.  The value is
    decoded host-side from the run's single gathered byte window (the
    shape-stable flush path — docs/API.md "Flush cost model"), so it
    is concrete by the time this returns.
    """
    h = ctx.engine.get(ctx.heap, ctx.teams_by_slot, gptr, shape,
                       dtype, stride=stride, count=count)
    ctx.engine.flush(h.poolid, h.row)
    return h.value(), h


def dart_get_blocking(ctx: DartContext, gptr: GlobalPtr, shape, dtype):
    """Blocking get, locality-routed.

    SHM_LOCAL targets (FLAG_SHM pointer + host-visible arena) bypass
    XLA entirely: the queued ops on the target's lane are flushed and
    the bytes are read through the zero-copy view — no jitted dispatch,
    and (satellite 3) ONE engine-lock acquisition covering deref +
    cached probe + flush + view; the support probe itself runs once per
    pool, never per deref.  Remote targets take the engine's jitted
    gather path.
    """
    view = _shm.try_shm_view(ctx, gptr, shape, dtype)
    if view is not None:
        return view
    h = ctx.engine.get(ctx.heap, ctx.teams_by_slot, gptr, shape, dtype)
    return h.value()


def dart_flush(ctx: DartContext, gptr: Optional[GlobalPtr] = None,
               target: Optional[int] = None) -> None:
    """Close the epoch: dispatch all pending ops, only those against
    ``gptr``'s pool (the ``MPI_Win_flush`` analogue), or — with
    ``target`` — only those against one unit's row of that pool (the
    ``MPI_Win_flush_local(rank, win)`` analogue; other targets' queued
    epochs keep accumulating for their own coalesced flush).
    Completion of individual handles still goes through
    ``dart_wait``/``dart_test``."""
    if gptr is None:
        if target is not None:
            raise ValueError("per-target flush needs a gptr to name the "
                             "window (dart_flush(ctx, gptr, target=unit))")
        ctx.engine.flush()
        return
    if target is not None:
        gptr = gptr.setunit(target)
    poolid, row, _ = _os.deref(ctx.heap, ctx.teams_by_slot, gptr)
    ctx.engine.flush(poolid, row if target is not None else None)


# The context-bound collective wrappers below hold the engine lock for
# the whole read-compute-swap of ctx.state: the collectives donate the
# pool arena, so an unlocked sequence racing a background flush could
# swap in a state snapshot that misses the flush's writes (or hand the
# collective a mid-donation arena).  The lock is an RLock, so the
# nested engine.flush inside _pre_collective re-enters cleanly.
#
# Data-moving collectives (bcast/gather/scatter + typed) are
# locality-routed first: when every member is SHM_LOCAL — on the
# single controller, when the pool arena is host-writable — the
# shm-direct memcpy path serves them with ZERO jitted dispatches
# (shm.try_shm_*); otherwise (or when the shm routine declines, e.g. a
# masked out-of-range request) they fall back to the engine's
# one-dispatch jitted kernels.  Computing collectives
# (allreduce/reduce) always stay on the engine — they are arithmetic,
# not memcpy.

def dart_bcast(ctx: DartContext, root_gptr: GlobalPtr, nbytes: int):
    h = _shm.try_shm_bcast(ctx, root_gptr, nbytes)
    if h is not None:
        return h
    with ctx.engine.lock:
        ctx.state, h = _coll.dart_bcast(ctx.state, ctx.heap,
                                        ctx.teams_by_slot, root_gptr,
                                        nbytes, engine=ctx.engine)
    return h


def dart_gather(ctx: DartContext, gptr: GlobalPtr, per_unit_nbytes: int):
    shm_out = _shm.try_shm_gather(ctx, gptr, per_unit_nbytes)
    if shm_out is not None:
        return shm_out
    with ctx.engine.lock:
        out, h = _coll.dart_gather(ctx.state, ctx.heap, ctx.teams_by_slot,
                                   gptr, per_unit_nbytes, engine=ctx.engine)
    return out, h


def dart_gather_typed(ctx: DartContext, gptr: GlobalPtr, shape, dtype):
    """Typed gather: every row's value at ``gptr.addr`` → (n_rows, *shape)."""
    shm_out = _shm.try_shm_gather_typed(ctx, gptr, shape, dtype)
    if shm_out is not None:
        return shm_out
    with ctx.engine.lock:
        out, h = _coll.dart_gather_typed(ctx.state, ctx.heap,
                                         ctx.teams_by_slot, gptr, shape,
                                         dtype, engine=ctx.engine)
    return out, h


def dart_scatter_typed(ctx: DartContext, gptr: GlobalPtr, values):
    """Typed scatter: row i of ``values`` ((n_rows, *shape)) → unit i."""
    h = _shm.try_shm_scatter_typed(ctx, gptr, values)
    if h is not None:
        return h
    with ctx.engine.lock:
        ctx.state, h = _coll.dart_scatter_typed(ctx.state, ctx.heap,
                                                ctx.teams_by_slot, gptr,
                                                values, engine=ctx.engine)
    return h


def dart_scatter(ctx: DartContext, gptr: GlobalPtr, values):
    h = _shm.try_shm_scatter(ctx, gptr, values)
    if h is not None:
        return h
    with ctx.engine.lock:
        ctx.state, h = _coll.dart_scatter(ctx.state, ctx.heap,
                                          ctx.teams_by_slot, gptr, values,
                                          engine=ctx.engine)
    return h


def dart_allreduce(ctx: DartContext, gptr: GlobalPtr, shape, dtype,
                   op: str = "sum"):
    with ctx.engine.lock:
        ctx.state, red = _coll.dart_allreduce(ctx.state, ctx.heap,
                                              ctx.teams_by_slot, gptr,
                                              shape, dtype, op,
                                              engine=ctx.engine)
    return red


def dart_reduce(ctx: DartContext, gptr: GlobalPtr, shape, dtype,
                op: str = "sum", root: int = 0):
    """Root-taking reduce: the reduced value replaces only ``root``'s
    copy (other rows keep their own); returns the reduced value.
    Shares the allreduce's op-identity-padded bucketed plan family."""
    with ctx.engine.lock:
        ctx.state, red = _coll.dart_reduce(ctx.state, ctx.heap,
                                           ctx.teams_by_slot, gptr, shape,
                                           dtype, op, root,
                                           engine=ctx.engine)
    return red


def dart_barrier(ctx: DartContext) -> None:
    with ctx.engine.lock:
        ctx.engine.flush()
        _coll.dart_barrier(ctx.state)
