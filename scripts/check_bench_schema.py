#!/usr/bin/env python
"""Fail CI on BENCH_engine schema drift.

``benchmarks/out/BENCH_engine.json`` is the machine-readable engine
trajectory dashboards diff across PRs; this guard keeps its shape
stable so those diffs stay meaningful.  Checks the schema id, the
required series and their dispatch-count invariants, the flush cost
model (cold vs warm + zero steady-state recompiles — the
shape-stable-flush acceptance criteria), — v3 — the reduce_plane
block (coalesced accumulate = ONE dispatch, zero recompiles over a
varying (shape, dtype, op) allreduce+accumulate loop), and — v4 —
the overlap block (background-progress flush latency hidden under the
compute window: progress-on wall time strictly below progress-off,
still zero steady-state recompiles), and — v5 — the serving block
(continuous batching strictly above the synchronous wave in tokens/s
under the same open-loop Poisson trace, p50/p99 latency reported,
prefix-cache hits served through one-sided get_nb + per-target flush
with the dispatch counts to prove it, zero steady-state recompiles in
the timed pass), and — v6 — the strided + narray blocks (a strided
run of N elements is ONE dispatch with µs/op within 2x of the
contiguous path, a varying-stride loop at fixed buckets recompiles
nothing, and the tiled NArray's column gather costs one dispatch per
owning tile, not one per element), and — v7 — the faults block (the
fault plane's retry/degradation cost model: scheduled transient
dispatch faults are absorbed by a BOUNDED retry loop — retries fired,
none exhausted, no at-most-once aborts in a put-only epoch — survivor
throughput after a unit death stays above zero, and the retry path
replays the same compiled dispatch plan: zero steady-state
recompiles), and — v8 — the shm_plane block (write-side zero-copy:
intra-node shm puts at least 5x faster µs/op than the jitted
blocking path with ZERO jitted dispatches, shm-direct broadcast/
gather/scatter all at 0 dispatches, and zero steady-state recompiles
— the shm route never traces).
"""

from __future__ import annotations

import json
import pathlib
import sys

PATH = pathlib.Path(__file__).resolve().parents[1] / (
    "benchmarks/out/BENCH_engine.json")

SCHEMA = "BENCH_engine/v8"
SERIES_KEYS = {"dispatches", "ops", "us_per_op", "us_per_call"}
REQUIRED_SERIES = {"blocking", "coalesced", "per_target_flush",
                   "mixed_size_coalesced"}
FLUSH_COST_KEYS = {"cold_us_per_op", "warm_us_per_op",
                   "cold_vs_warm_speedup", "compiles_cold",
                   "recompiles_steady_state", "warm_epoch_shapes"}
REDUCE_PLANE_KEYS = {"acc_blocking_us_per_op", "acc_coalesced_us_per_op",
                     "acc_dispatches_blocking",
                     "acc_dispatches_coalesced",
                     "acc_coalesced_vs_blocking_speedup",
                     "allreduce_cold_us", "allreduce_warm_us",
                     "allreduce_cold_vs_warm_speedup",
                     "allreduce_compiles_cold",
                     "allreduce_warm_recompiles",
                     "recompiles_steady_state"}
OVERLAP_KEYS = {"n_ops", "nbytes", "compute_window_us", "flush_only_us",
                "progress_off_us", "progress_on_us", "overlap_speedup",
                "background_flushes", "watermark_ops",
                "recompiles_steady_state"}
PLAN_CACHE_KEYS = {"compile_count", "plan_cache_hits", "size", "builds"}
SERVING_KEYS = {"n_requests", "poisson_rate_rps", "seed", "max_batch",
                "wave", "continuous", "speedup_tokens_per_s",
                "prefix_lookups", "prefix_hits", "prefix_hit_rate",
                "hit_fetch_get_nb_ops", "hit_fetch_flushes",
                "hit_fetch_dispatches", "prefix_evictions"}
SERVING_ENGINE_KEYS = {"tokens_per_s", "p50_ms", "p99_ms", "makespan_s",
                       "tokens", "n_requests"}
STRIDED_KEYS = {"elems", "contiguous_put_us_per_op",
                "strided_put_us_per_op", "contiguous_get_us_per_op",
                "strided_get_us_per_op", "put_vs_contiguous_ratio",
                "get_vs_contiguous_ratio", "dispatches_per_strided_put",
                "dispatches_per_strided_get", "recompiles_steady_state"}
NARRAY_KEYS = {"dist", "col_elems", "get_col_us_per_elem",
               "get_col_dispatches", "owning_tiles", "reduce_us"}
FAULTS_KEYS = {"clean_us_per_op", "faulty_us_per_op",
               "retry_overhead_ratio", "retries", "retries_exhausted",
               "at_most_once_aborts", "injected_fails", "dead_unit",
               "degraded_ops_done", "degraded_ops_per_s",
               "enqueue_rejections", "recompiles_steady_state"}
SHM_PLANE_KEYS = {"shm_put_us_per_op", "jitted_put_us_per_op",
                  "shm_put_speedup", "shm_get_us_per_op",
                  "shm_put_dispatches", "broadcast_us",
                  "broadcast_dispatches", "gather_dispatches",
                  "scatter_dispatches", "shm_puts",
                  "shm_collective_ops", "recompiles_steady_state"}
#: acceptance (ISSUE 10): intra-node shm put >= 5x faster µs/op than
#: the jitted blocking path.  Measured headroom is ~50x; the pin stays
#: at the acceptance floor so CI noise can't flake it.
SHM_PUT_SPEEDUP_MIN = 5.0
#: acceptance (ISSUE 8): strided µs/op within ~2x of contiguous.  The
#: bound gets slack on the quick/CI profile (2-repeat timings on a
#: loaded 1-core box are noisy); the invariant that CANNOT flex is the
#: dispatch count — 1 per strided run — and zero recompiles.
STRIDED_RATIO_MAX = 2.0
STRIDED_RATIO_MAX_QUICK = 4.0


def fail(msg: str) -> None:
    print(f"BENCH_engine schema check FAILED: {msg}", file=sys.stderr)
    raise SystemExit(1)


def main() -> None:
    if not PATH.exists():
        fail(f"{PATH} missing (run `python -m benchmarks.run --quick`)")
    profile = json.loads(PATH.read_text())

    if profile.get("schema") != SCHEMA:
        fail(f"schema is {profile.get('schema')!r}, expected {SCHEMA!r}")
    series = profile.get("series", {})
    missing = REQUIRED_SERIES - series.keys()
    if missing:
        fail(f"missing series: {sorted(missing)}")
    for name in REQUIRED_SERIES:
        if not SERIES_KEYS <= series[name].keys():
            fail(f"series {name!r} lacks {sorted(SERIES_KEYS - series[name].keys())}")
    if series["coalesced"]["dispatches"] != 1:
        fail("coalesced series no longer flushes as ONE dispatch")
    if series["blocking"]["dispatches"] != profile["n_ops"]:
        fail("blocking series dispatch count drifted")

    fc = profile.get("flush_cost", {})
    if not FLUSH_COST_KEYS <= fc.keys():
        fail(f"flush_cost lacks {sorted(FLUSH_COST_KEYS - fc.keys())}")
    if fc["recompiles_steady_state"] != 0:
        fail("steady-state epochs recompiled — plan cache regressed")
    if fc["cold_vs_warm_speedup"] < 5.0:
        fail(f"warm flush only {fc['cold_vs_warm_speedup']}x faster than "
             "cold (acceptance: >= 5x)")
    rp = profile.get("reduce_plane", {})
    if not REDUCE_PLANE_KEYS <= rp.keys():
        fail(f"reduce_plane lacks {sorted(REDUCE_PLANE_KEYS - rp.keys())}")
    if rp["acc_dispatches_coalesced"] != 1:
        fail("coalesced accumulate no longer flushes as ONE dispatch")
    if rp["acc_dispatches_blocking"] != profile["n_ops"]:
        fail("blocking accumulate dispatch count drifted")
    if rp["recompiles_steady_state"] != 0:
        fail("varying (shape, dtype, op) allreduce+accumulate loop "
             "recompiled — the reduction plane's shape stability "
             "regressed")
    if rp["allreduce_warm_recompiles"] != 0:
        fail("warm varying-shape allreduce recompiled")

    ov = profile.get("overlap", {})
    if not OVERLAP_KEYS <= ov.keys():
        fail(f"overlap lacks {sorted(OVERLAP_KEYS - ov.keys())}")
    if ov["overlap_speedup"] <= 1.0:
        fail(f"background progress hides no flush latency (speedup "
             f"{ov['overlap_speedup']}x; acceptance: progress-on wall "
             "time strictly below progress-off)")
    if ov["progress_on_us"] >= ov["progress_off_us"]:
        fail("progress-on wall time not below progress-off")
    if ov["recompiles_steady_state"] != 0:
        fail("background-progress flushes recompiled — the daemon's "
             "coalesced runs left the foreground plan family")
    if ov["background_flushes"] < 1:
        fail("progress-on series never flushed in the background")

    pc = profile.get("plan_cache", {})
    if not PLAN_CACHE_KEYS <= pc.keys():
        fail(f"plan_cache lacks {sorted(PLAN_CACHE_KEYS - pc.keys())}")

    sv = profile.get("serving", {})
    if not SERVING_KEYS <= sv.keys():
        fail(f"serving lacks {sorted(SERVING_KEYS - sv.keys())} "
             "(run `python -m benchmarks.serve_bench --quick` after "
             "`python -m benchmarks.run --quick`)")
    for side in ("wave", "continuous"):
        if not SERVING_ENGINE_KEYS <= sv[side].keys():
            fail(f"serving.{side} lacks "
                 f"{sorted(SERVING_ENGINE_KEYS - sv[side].keys())}")
    if sv["speedup_tokens_per_s"] <= 1.0:
        fail(f"continuous batching not above the synchronous wave "
             f"({sv['speedup_tokens_per_s']}x tokens/s; acceptance: "
             "strictly > 1.0 under the same open-loop Poisson trace)")
    if sv["continuous"]["recompiles_steady_state"] != 0:
        fail("serving timed pass recompiled — the continuous engine's "
             "fixed-shape decode/prefill-bucket/plan-cache story "
             "regressed")
    if sv["prefix_hits"] < 1:
        fail("serving timed pass saw no prefix-cache hits")
    if sv["hit_fetch_get_nb_ops"] < 1:
        fail("prefix hits fetched no blocks via one-sided get_nb")
    if sv["hit_fetch_flushes"] < 1:
        fail("prefix-hit fetches issued no per-target flushes")
    if sv["hit_fetch_dispatches"] < 1:
        fail("prefix-hit traffic never reached the coalescing engine "
             "(zero dispatches attributed to hit fetches)")

    sd = profile.get("strided", {})
    if not STRIDED_KEYS <= sd.keys():
        fail(f"strided lacks {sorted(STRIDED_KEYS - sd.keys())}")
    if sd["dispatches_per_strided_put"] != 1:
        fail("a strided put no longer moves as ONE coalesced dispatch")
    if sd["dispatches_per_strided_get"] != 1:
        fail("a strided get no longer moves as ONE coalesced dispatch")
    if sd["recompiles_steady_state"] != 0:
        fail("varying-stride loop recompiled — stride/count must stay "
             "descriptor DATA, never part of the plan key")
    ratio_max = (STRIDED_RATIO_MAX_QUICK if profile.get("quick")
                 else STRIDED_RATIO_MAX)
    for k in ("put_vs_contiguous_ratio", "get_vs_contiguous_ratio"):
        if sd[k] > ratio_max:
            fail(f"strided {k} = {sd[k]}x exceeds {ratio_max}x "
                 "(acceptance: strided µs/op within ~2x of contiguous)")

    ft = profile.get("faults", {})
    if not FAULTS_KEYS <= ft.keys():
        fail(f"faults lacks {sorted(FAULTS_KEYS - ft.keys())}")
    if ft["retries"] < 1:
        fail("faulted epochs never exercised the retry loop")
    if ft["retries_exhausted"] != 0:
        fail(f"{ft['retries_exhausted']} retries exhausted — scheduled "
             "transient faults must stay within the bounded retry "
             "budget")
    if ft["at_most_once_aborts"] != 0:
        fail("a put-only faulted epoch hit the at-most-once abort "
             "path — idempotent retries regressed")
    if ft["degraded_ops_per_s"] <= 0:
        fail("survivor lanes moved nothing after the unit death — "
             "degraded-mode throughput must stay above zero")
    if ft["enqueue_rejections"] < 1:
        fail("dead-unit enqueues were not rejected fail-fast")
    if ft["recompiles_steady_state"] != 0:
        fail("the retry path recompiled — retries must replay the "
             "same compiled dispatch plan")

    sp = profile.get("shm_plane", {})
    if not SHM_PLANE_KEYS <= sp.keys():
        fail(f"shm_plane lacks {sorted(SHM_PLANE_KEYS - sp.keys())}")
    if sp["shm_put_us_per_op"] >= sp["jitted_put_us_per_op"]:
        fail(f"shm put ({sp['shm_put_us_per_op']}us/op) not below the "
             f"jitted path ({sp['jitted_put_us_per_op']}us/op)")
    if sp["shm_put_speedup"] < SHM_PUT_SPEEDUP_MIN:
        fail(f"shm put only {sp['shm_put_speedup']}x faster than the "
             f"jitted path (acceptance: >= {SHM_PUT_SPEEDUP_MIN}x)")
    if sp["shm_put_dispatches"] != 0:
        fail("shm puts issued jitted dispatches — the zero-copy write "
             "route regressed to the engine path")
    for k in ("broadcast_dispatches", "gather_dispatches",
              "scatter_dispatches"):
        if sp[k] != 0:
            fail(f"shm-direct collective {k} = {sp[k]} (acceptance: "
                 "intra-node collectives at ZERO jitted dispatches)")
    if sp["shm_puts"] < 1 or sp["shm_collective_ops"] < 1:
        fail("shm plane counters flat — the routed paths never ran")
    if sp["recompiles_steady_state"] != 0:
        fail("the shm plane recompiled — zero-copy routes must never "
             "trace")

    nr = profile.get("narray", {})
    if not NARRAY_KEYS <= nr.keys():
        fail(f"narray lacks {sorted(NARRAY_KEYS - nr.keys())}")
    if nr["get_col_dispatches"] > nr["owning_tiles"]:
        fail(f"NArray column gather took {nr['get_col_dispatches']} "
             f"dispatches for {nr['owning_tiles']} owning tiles — the "
             "strided lowering exploded per element")

    print(f"BENCH_engine schema OK ({SCHEMA}): "
          f"cold {fc['cold_us_per_op']}us/op -> warm "
          f"{fc['warm_us_per_op']}us/op "
          f"({fc['cold_vs_warm_speedup']}x), 0 steady-state recompiles; "
          f"reduce_plane acc {rp['acc_blocking_us_per_op']}us/op -> "
          f"{rp['acc_coalesced_us_per_op']}us/op coalesced, allreduce "
          f"cold {rp['allreduce_cold_us']}us -> warm "
          f"{rp['allreduce_warm_us']}us, 0 recompiles; overlap "
          f"{ov['progress_off_us']}us -> {ov['progress_on_us']}us "
          f"({ov['overlap_speedup']}x, 0 recompiles); serving "
          f"{sv['wave']['tokens_per_s']} -> "
          f"{sv['continuous']['tokens_per_s']} tok/s "
          f"({sv['speedup_tokens_per_s']}x, hit rate "
          f"{sv['prefix_hit_rate']}, 0 recompiles); strided put "
          f"{sd['put_vs_contiguous_ratio']}x / get "
          f"{sd['get_vs_contiguous_ratio']}x of contiguous, 1 dispatch, "
          f"0 recompiles; narray col {nr['get_col_dispatches']} "
          f"dispatches/{nr['owning_tiles']} tiles; faults clean "
          f"{ft['clean_us_per_op']}us/op -> faulted "
          f"{ft['faulty_us_per_op']}us/op ({ft['retries']} retries, "
          f"0 exhausted), degraded {ft['degraded_ops_per_s']} ops/s, "
          f"0 recompiles; shm put {sp['shm_put_us_per_op']}us/op vs "
          f"jitted {sp['jitted_put_us_per_op']}us/op "
          f"({sp['shm_put_speedup']}x, 0 dispatches), collectives "
          f"shm-direct at 0 dispatches")


if __name__ == "__main__":
    main()
