#!/usr/bin/env bash
# Tier-1 verification: the checks every PR must keep green.
#
#   make verify          (or: bash scripts/ci.sh)
#
# 1. tier-1 pytest suite (ROADMAP "Tier-1 verify")
# 2. benchmark harness smoke run (--quick): every suite must still run
#    and emit its artifacts
# 3. serving bench smoke run (--quick): the continuous-batching
#    engine vs the synchronous wave under one open-loop Poisson trace,
#    merged as the `serving` block into BENCH_engine.json
# 4. BENCH_engine schema guard: the machine-readable engine trajectory
#    (benchmarks/out/BENCH_engine.json) must keep the BENCH_engine/v8
#    shape and its dispatch/flush-cost/overlap/serving/strided/narray/
#    faults/shm_plane invariants (incl. the varying-stride
#    zero-recompile pin, the bounded-retry/degraded-throughput pins,
#    and the shm-plane pins: shm put >= 5x faster than jitted,
#    shm-direct collectives at 0 dispatches), so perf diffs stay
#    comparable across PRs
# 5. threaded stress suite, re-run standalone: the progress-plane
#    differential and the atomics/lock contention tests exercise real
#    thread interleavings, so an extra pass catches schedules the
#    tier-1 run happened to miss
# 6. chaos suite, re-run standalone: the seeded fault-schedule
#    differential (subject with injected faults vs fault-free oracle,
#    under both engine impls) — quick and deterministic, but it is
#    the only pass that drives the retry/degradation machinery
#    end-to-end, so it gets its own step; the shm-plane chaos tests
#    (fault-plane parity on the zero-copy write path) ride along
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 pytest =="
python -m pytest -x -q

echo "== threaded stress suite =="
python -m pytest -x -q tests/test_progress_plane.py tests/test_atomics_stress.py tests/test_core_lock.py

echo "== chaos fault schedules =="
python -m pytest -x -q -m chaos tests/test_fault_plane.py tests/test_shm_plane.py

echo "== benchmarks (quick) =="
python -m benchmarks.run --quick

echo "== serving bench (quick) =="
python -m benchmarks.serve_bench --quick

echo "== BENCH_engine schema =="
python scripts/check_bench_schema.py

echo "verify: OK"
