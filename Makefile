.PHONY: verify test bench bench-quick

# Tier-1 verification: pytest + quick benchmark smoke + BENCH_engine
# schema guard (see scripts/ci.sh).
verify:
	bash scripts/ci.sh

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q

bench-quick:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m benchmarks.run --quick

bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m benchmarks.run
