"""Classic PGAS application: 1-D heat diffusion with one-sided halo
exchange (the pattern DART/DASH was built for) — on the typed
GlobalArray front-end (docs/API.md).

Each of 8 units owns a block of the rod; every step it PUTs its edge
cells into its neighbours' halo slots (one-sided — neighbours don't
participate), then applies the stencil locally.  The halo array is a
``ctx.alloc((2,), float32)``: element 0 is a unit's *left* halo,
element 1 its *right* halo — no byte offsets, no to_bytes/from_bytes.

Per step the runtime does exactly ONE jitted dispatch on a
host-visible heap: every edge put of the epoch coalesces into one
batched scatter, and the typed ``ga.gather()`` goes shm-direct —
a zero-dispatch memcpy through the shared-memory window (two
dispatches/step on device-only arenas, where the gather stays on the
jitted engine path).  Result is checked against a single-device dense
reference.

    PYTHONPATH=src python examples/halo_exchange.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import DartConfig, dart_exit, dart_init, shm_supported

N_UNITS = 8
LOCAL = 32                      # cells per unit
ALPHA = 0.1
STEPS = 50

LEFT, RIGHT = 0, 1              # element slots in the halo array

ctx = dart_init(n_units=N_UNITS, config=DartConfig())
halo = ctx.alloc((2,), jnp.float32)

# initial condition: a hot spike in the middle
x0 = np.zeros(N_UNITS * LOCAL, np.float32)
x0[len(x0) // 2 - 4:len(x0) // 2 + 4] = 100.0
blocks = x0.reshape(N_UNITS, LOCAL).copy()

dispatches0 = ctx.engine.dispatch_count
for _ in range(STEPS):
    # one-sided halo exchange: each unit puts its edges into its
    # neighbours' halo slots; the epoch close coalesces all 14 puts
    # into a single jitted dispatch.
    with ctx.epoch():
        for u in range(N_UNITS):
            if u > 0:
                halo.at[u - 1, RIGHT].put_nb(blocks[u, 0])
            if u < N_UNITS - 1:
                halo.at[u + 1, LEFT].put_nb(blocks[u, -1])
    halos = np.asarray(halo.gather())   # (N_UNITS, 2), shm-direct: 0 dispatch
    # local stencil update (insulated ends: boundary units reuse their
    # own edge value as the missing halo)
    lh = np.where(np.arange(N_UNITS) == 0, blocks[:, 0], halos[:, LEFT])
    rh = np.where(np.arange(N_UNITS) == N_UNITS - 1, blocks[:, -1],
                  halos[:, RIGHT])
    padded = np.concatenate([lh[:, None], blocks, rh[:, None]], axis=1)
    blocks = blocks + ALPHA * (padded[:, :-2] - 2 * blocks + padded[:, 2:])

result = blocks.reshape(-1)
n_dispatch = ctx.engine.dispatch_count - dispatches0
per_step = 1 if shm_supported(ctx) else 2   # shm-direct gather costs 0
print(f"{STEPS} steps -> {n_dispatch} jitted dispatches "
      f"({n_dispatch / STEPS:.0f}/step: 1 coalesced put"
      f"{' + 1 gather' if per_step == 2 else ' + shm-direct gather'})")
assert n_dispatch == per_step * STEPS

# dense single-device reference
ref = x0.copy()
for _ in range(STEPS):
    padded = np.concatenate([ref[:1], ref, ref[-1:]])
    ref = ref + ALPHA * (padded[:-2] - 2 * ref + padded[2:])

err = np.max(np.abs(result - ref))
print(f"max |PGAS - dense| after {STEPS} steps: {err:.2e}")
assert err < 1e-4, "halo exchange diverged from the dense reference"
print("OK — one-sided halo exchange matches the dense stencil.")
print("temperature profile (coarse):",
      np.round(result.reshape(N_UNITS, LOCAL).mean(axis=1), 2))
dart_exit(ctx)
