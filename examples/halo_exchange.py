"""Classic PGAS application: 1-D heat diffusion with one-sided halo
exchange (the pattern DART/DASH was built for).

Each of 8 units owns a block of the rod; every step it PUTs its edge
cells into its neighbours' halo slots (one-sided — neighbours don't
participate), then applies the stencil locally.  Result is checked
against a single-device dense reference.

    PYTHONPATH=src python examples/halo_exchange.py
"""

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core.onesided import shmem_halo_exchange
from repro.core.globmem import from_bytes

N_UNITS = 8
LOCAL = 32                      # cells per unit
ALPHA = 0.1
STEPS = 50

mesh = make_mesh((N_UNITS,), ("unit",))

# arena layout per unit: [left_halo (4B) | right_halo (4B)]
LEFT_OFF, RIGHT_OFF = 0, 128
POOL = 256


def step_body(u, arena_row):
    """One diffusion step for this unit's block (SPMD)."""
    left_edge = u[:1]            # what the left neighbour needs
    right_edge = u[-1:]
    arena_row = shmem_halo_exchange(
        arena_row, left_edge, right_edge, LEFT_OFF, RIGHT_OFF,
        "unit", N_UNITS, wrap=False)
    lh = from_bytes(jax.lax.dynamic_slice(arena_row, (0, LEFT_OFF),
                                          (1, 4))[0], (1,), jnp.float32)
    rh = from_bytes(jax.lax.dynamic_slice(arena_row, (0, RIGHT_OFF),
                                          (1, 4))[0], (1,), jnp.float32)
    # boundary units keep their edge value (insulated ends)
    idx = jax.lax.axis_index("unit")
    lh = jnp.where(idx == 0, u[:1], lh)
    rh = jnp.where(idx == N_UNITS - 1, u[-1:], rh)
    padded = jnp.concatenate([lh, u, rh])
    new_u = u + ALPHA * (padded[:-2] - 2 * u + padded[2:])
    return new_u, arena_row


def run(u0):
    def body(carry, _):
        u, arena = carry
        u, arena = step_body(u, arena)
        return (u, arena), None

    arena0 = jnp.zeros((1, POOL), jnp.uint8)
    (u, _), _ = jax.lax.scan(body, (u0, arena0), None, length=STEPS)
    return u


spmd = jax.jit(shard_map(run, mesh=mesh, in_specs=P("unit"),
                             out_specs=P("unit"), check_vma=False))

# initial condition: a hot spike in the middle
x0 = np.zeros(N_UNITS * LOCAL, np.float32)
x0[len(x0) // 2 - 4:len(x0) // 2 + 4] = 100.0
result = np.asarray(spmd(jnp.asarray(x0)))

# dense single-device reference
ref = x0.copy()
for _ in range(STEPS):
    padded = np.concatenate([ref[:1], ref, ref[-1:]])
    ref = ref + ALPHA * (padded[:-2] - 2 * ref + padded[2:])

err = np.max(np.abs(result - ref))
print(f"max |PGAS - dense| after {STEPS} steps: {err:.2e}")
assert err < 1e-4, "halo exchange diverged from the dense reference"
print("OK — one-sided halo exchange matches the dense stencil.")
print("temperature profile (coarse):",
      np.round(result.reshape(N_UNITS, LOCAL).mean(axis=1), 2))
