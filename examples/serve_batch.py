"""Batched serving example: a small model answering queued requests.

    PYTHONPATH=src python examples/serve_batch.py

Submits a mixed bag of prompts to the ServeEngine; the engine packs
them into waves, prefills, and decodes greedily.  The KV cache is a
DART collective segment (see repro/serve/engine.py).
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.models.config import reduced_for_smoke
from repro.serve import Request, ServeEngine

cfg = reduced_for_smoke(get_config("llama3-8b"))
params = api.init_params(cfg, jax.random.PRNGKey(0))

engine = ServeEngine(cfg, params, max_batch=4, max_seq=64)

rng = np.random.RandomState(0)
reqs = []
for i in range(10):
    plen = rng.randint(4, 12)
    prompt = rng.randint(0, cfg.vocab, size=plen).astype(np.int32)
    reqs.append(engine.submit(prompt, max_new_tokens=8))

done = engine.drain()
print(f"completed {done} requests in "
      f"{(done + engine.max_batch - 1) // engine.max_batch} waves")
for r in reqs:
    assert r.done.is_set() and r.output is not None
    print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.output.tolist()}")
print("PGAS cache segment gptr:", engine.cache_gptr)
print("OK")
