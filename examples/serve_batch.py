"""Serving example: a small model answering queued requests.

    PYTHONPATH=src python examples/serve_batch.py

Part 1 submits a mixed bag of prompts to the synchronous-wave
ServeEngine (packs waves, prefills, decodes greedily).  Part 2 replays
the same prompts through the ContinuousEngine: per-step admit/retire
over fixed decode slots, with prefill KV state published into the PGAS
prefix/KV-block cache — the repeat pass is served from one-sided block
reads instead of recompute (see repro/serve/ and docs/API.md
"Serving plane").
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.models.config import reduced_for_smoke
from repro.serve import ContinuousEngine, Request, ServeEngine

cfg = reduced_for_smoke(get_config("llama3-8b"))
params = api.init_params(cfg, jax.random.PRNGKey(0))

engine = ServeEngine(cfg, params, max_batch=4, max_seq=64)

rng = np.random.RandomState(0)
reqs = []
for i in range(10):
    plen = rng.randint(4, 12)
    prompt = rng.randint(0, cfg.vocab, size=plen).astype(np.int32)
    reqs.append(engine.submit(prompt, max_new_tokens=8))

done = engine.drain()
print(f"completed {done} requests in "
      f"{(done + engine.max_batch - 1) // engine.max_batch} waves")
for r in reqs:
    assert r.done.is_set() and r.output is not None
    print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.output.tolist()}")
print("PGAS cache segment gptr:", engine.cache_gptr)

# -- continuous batching + the global prefix cache ---------------------
cont = ContinuousEngine(cfg, params, max_batch=4, max_seq=64,
                        block_tokens=8, n_cache_blocks=64)
prompts = [r.prompt for r in reqs]
creqs = [cont.submit(p, max_new_tokens=8) for p in prompts]
cont.run_until_idle()
# (outputs can differ from the wave engine's: each engine conditions
# on its own left-padding — wave-max vs pow2 bucket)
assert all(r.done.is_set() and r.output.shape == (8,) for r in creqs)
print(f"continuous pass completed {len(creqs)} requests")

again = [cont.submit(p, max_new_tokens=8) for p in prompts]
cont.run_until_idle()
for a, b in zip(creqs, again):
    np.testing.assert_array_equal(a.output, b.output)
st = cont.stats()
print(f"repeat pass: {st['prefix']['hits']} prefix hits, "
      f"{st['prefix']['fetch_get_nb_ops']} one-sided block reads, "
      f"prefills stayed at {st['prefills']}")
cont.stop()
print("OK")
