"""2-D heat stencil on a tiled DASH-style NArray (ISSUE 8 showcase).

A global ``(R, C)`` grid is distributed as 2-D tiles over a 2x2 unit
grid (``NArray`` with ``TileDist``).  Every step each tile pulls its
four halos one-sided from its neighbour tiles:

* row halos are contiguous runs — one descriptor each, as before;
* **column halos are strided runs** — ``ga.at[u, :, c]`` lowers onto a
  single ``(seg=1 elem, stride=tile cols, count=tile rows)`` descriptor,
  so fetching a whole tile column is ONE engine dispatch instead of
  ``tile rows`` scalar gets (the strided transfer IR this PR adds).

The result is checked against a dense single-array numpy reference,
and the per-step dispatch trajectory is asserted: 8 column halos ride
8 strided gathers, not ``8 * tile_rows`` element ops.

    PYTHONPATH=src python examples/narray_stencil.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import DartConfig, NArray, TileDist, dart_exit, dart_init

GR, GC = 2, 2                    # unit grid
TR, TC = 8, 8                    # tile shape
R, C = GR * TR, GC * TC          # global grid
ALPHA = 0.2
STEPS = 20

ctx = dart_init(n_units=GR * GC, config=DartConfig())
na = NArray(ctx, (R, C), jnp.float32, dist=TileDist((GR, GC)), shm=False)

# initial condition: a hot square in the middle
x0 = np.zeros((R, C), np.float32)
x0[R // 2 - 2:R // 2 + 2, C // 2 - 2:C // 2 + 2] = 100.0
na.from_numpy(x0)
ctx.engine.flush()

units = np.asarray(na.units).reshape(GR, GC)
ga = na.ga


def halo_col(ti, tj, lc):
    """One STRIDED one-sided gather of tile (ti,tj)'s local column lc."""
    return ga.at[int(units[ti, tj]), :, lc].get_nb()


def halo_row(ti, tj, lr):
    """One contiguous one-sided gather of the tile's local row lr."""
    return ga.at[int(units[ti, tj]), lr].get_nb()


ref = x0.copy()
strided_gathers_per_step = None
for step in range(STEPS):
    d0 = ctx.engine.dispatch_count
    # pull all halos one-sided (neighbour tiles don't participate)
    pulls = {}
    for ti in range(GR):
        for tj in range(GC):
            if tj > 0:
                pulls[(ti, tj, "L")] = halo_col(ti, tj - 1, TC - 1)
            if tj < GC - 1:
                pulls[(ti, tj, "R")] = halo_col(ti, tj + 1, 0)
            if ti > 0:
                pulls[(ti, tj, "T")] = halo_row(ti - 1, tj, TR - 1)
            if ti < GR - 1:
                pulls[(ti, tj, "B")] = halo_row(ti + 1, tj, 0)
    halos = {k: np.asarray(h.value()).reshape(-1) for k, h in pulls.items()}
    halo_dispatches = ctx.engine.dispatch_count - d0

    # local stencil update per tile, then publish the new tile
    blocks = {}
    for ti in range(GR):
        for tj in range(GC):
            t = np.asarray(na._read_block(int(units[ti, tj])))
            pad = np.pad(t, 1, mode="edge")
            for side, (sl_r, sl_c) in {
                    "L": (slice(1, TR + 1), 0), "R": (slice(1, TR + 1), TC + 1),
                    "T": (0, slice(1, TC + 1)), "B": (TR + 1, slice(1, TC + 1)),
            }.items():
                if (ti, tj, side) in halos:
                    pad[sl_r, sl_c] = halos[(ti, tj, side)]
            blocks[(ti, tj)] = t + ALPHA * (
                pad[:-2, 1:-1] + pad[2:, 1:-1] + pad[1:-1, :-2]
                + pad[1:-1, 2:] - 4 * t)
    for (ti, tj), t in blocks.items():
        ga[int(units[ti, tj])].put(jnp.asarray(t))
    ctx.engine.flush()

    # dense reference with the same edge-replicated boundary
    rpad = np.pad(ref, 1, mode="edge")
    ref = ref + ALPHA * (rpad[:-2, 1:-1] + rpad[2:, 1:-1]
                         + rpad[1:-1, :-2] + rpad[1:-1, 2:] - 4 * ref)

    # 8 column halos + 8 row halos; the 8 STRIDED column gathers must
    # each be one dispatch (they don't explode into TR element gets)
    assert halo_dispatches <= len(pulls), (halo_dispatches, len(pulls))
    strided_gathers_per_step = halo_dispatches

got = na.to_numpy()
np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-4)
print(f"halo dispatches/step: {strided_gathers_per_step} "
      f"(16 halos, {8 * TR} element gets avoided)")
print("OK — tiled NArray stencil matches dense reference")
dart_exit(ctx)
