"""End-to-end training example (deliverable b).

Trains a ~100M-parameter llama-family model for a few hundred steps on
the synthetic pipeline, with async checkpointing — then kills and
resumes to demonstrate fault-tolerant restart.

On this CPU container the default invocation is scaled down; pass
--full-100m for the real 100M x 300-step run (hours on 1 CPU core,
minutes on a TPU host).

    PYTHONPATH=src python examples/train_lm.py [--full-100m]
"""

import argparse
import pathlib
import shutil
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.launch.train import main as train_main


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args(argv)

    shutil.rmtree(args.ckpt, ignore_errors=True)

    if args.full_100m:
        # ~100M params: 12 x 768 llama-style, few hundred steps
        common = ["--arch", "llama3-8b", "--smoke",
                  "--d-model", "768", "--n-layers", "12",
                  "--batch", "8", "--seq", "512",
                  "--ckpt-dir", args.ckpt]
        steps = 300
    else:
        common = ["--arch", "llama3-8b", "--smoke",
                  "--batch", "4", "--seq", "64",
                  "--ckpt-dir", args.ckpt]
        steps = 60

    # phase 1: train halfway, checkpointing along the way
    half = steps // 2
    losses1 = train_main(common + ["--steps", str(half),
                                   "--ckpt-every", "10"])
    print(f"\n--- simulated failure after step {half}; restarting ---\n")
    # phase 2: rerun with the full step budget — resumes from the
    # latest checkpoint (params, optimizer, data cursor)
    losses2 = train_main(common + ["--steps", str(steps),
                                   "--ckpt-every", "10"])
    assert losses2[-1] < losses1[0], "loss should improve across restart"
    print("\nOK — training resumed from checkpoint and kept improving "
          f"({losses1[0]:.3f} -> {losses2[-1]:.3f}).")


if __name__ == "__main__":
    run()
