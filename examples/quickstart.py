"""DART-JAX quickstart: the PGAS runtime in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Covers the five DART API areas (paper §III): init/exit, teams+groups,
global memory, one-sided communication, synchronization.
"""

import threading

import jax.numpy as jnp
import numpy as np

from repro.core import (DART_TEAM_ALL, DartConfig, dart_allreduce,
                        dart_barrier, dart_exit, dart_flush,
                        dart_get_blocking, dart_get_nb, dart_init,
                        dart_memalloc, dart_put, dart_put_blocking,
                        dart_team_create, dart_team_memalloc_aligned,
                        dart_team_myid, dart_waitall, group_from_units)

# 1. initialize a runtime with 8 units -----------------------------------
ctx = dart_init(n_units=8, config=DartConfig())
print("units:", ctx.n_units)

# 2. teams & groups: split off the even units ----------------------------
evens = group_from_units([0, 2, 4, 6])
team = dart_team_create(ctx, DART_TEAM_ALL, evens)
print("unit 4 has relative id", dart_team_myid(ctx, team, 4),
      "in the even team")

# 3. global memory: collective aligned allocation ------------------------
gptr = dart_team_memalloc_aligned(ctx, team, 1024)
print(f"collective gptr: unit={gptr.unitid} seg={gptr.segid} "
      f"addr={gptr.addr} (same offset valid on every member)")

# 4. one-sided communication ---------------------------------------------
# blocking put to unit 6's partition, then get it back
dart_put_blocking(ctx, gptr.setunit(6), jnp.arange(8, dtype=jnp.float32))
out = dart_get_blocking(ctx, gptr.setunit(6), (8,), jnp.float32)
print("roundtrip:", np.asarray(out))

# non-blocking puts + waitall: the puts queue on the engine and the
# waitall flushes them as ONE coalesced jitted dispatch
d0 = ctx.engine.dispatch_count
handles = [dart_put(ctx, gptr.setunit(u) + 64,
                    jnp.full((4,), float(u), jnp.float32))
           for u in evens.members]
dart_waitall(handles)
print(f"coalesced {len(handles)} puts into "
      f"{ctx.engine.dispatch_count - d0} dispatch(es)")

# non-blocking gets: enqueue, flush once, then read the values
gets = [dart_get_nb(ctx, gptr.setunit(u) + 64, (4,), jnp.float32)
        for u in evens.members]
dart_flush(ctx)
assert all(float(np.asarray(h.value())[0]) == float(u)
           for h, u in zip(gets, evens.members))

# collective: allreduce the 4 floats each member just wrote
red = dart_allreduce(ctx, gptr + 64, (4,), jnp.float32, op="sum")
print("allreduce(sum):", np.asarray(red))       # 0+2+4+6 = 12

# 5. synchronization: the MCS queueing lock (paper §IV.B.6) --------------
lock = ctx.locks.create_lock(ctx.teams[DART_TEAM_ALL])
counter = {"v": 0}

def worker(u):
    for _ in range(100):
        ctx.locks.acquire(lock, u)
        counter["v"] += 1
        ctx.locks.release(lock, u)

threads = [threading.Thread(target=worker, args=(u,)) for u in range(8)]
for t in threads: t.start()
for t in threads: t.join()
print("lock-protected counter:", counter["v"], "(expected 800)")

dart_barrier(ctx)
dart_exit(ctx)
print("done.")
