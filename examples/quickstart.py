"""DART-JAX quickstart: the PGAS runtime in 60 lines — typed edition.

    PYTHONPATH=src python examples/quickstart.py

Covers the five DART API areas (paper §III) through the typed
GlobalArray front-end (docs/API.md): init/exit, teams+groups, global
memory, one-sided communication, synchronization.  No byte offsets, no
to_bytes/from_bytes — the raw ``dart_*`` substrate stays available one
layer down.
"""

import threading

import jax.numpy as jnp
import numpy as np

from repro.core import (DART_TEAM_ALL, DartConfig, dart_barrier, dart_exit,
                        dart_init, dart_team_create, dart_team_myid,
                        group_from_units)

# 1. initialize a runtime with 8 units -----------------------------------
ctx = dart_init(n_units=8, config=DartConfig())
print("units:", ctx.n_units)

# 2. teams & groups: split off the even units ----------------------------
evens = group_from_units([0, 2, 4, 6])
team = dart_team_create(ctx, DART_TEAM_ALL, evens)
print("unit 4 has relative id", dart_team_myid(ctx, team, 4),
      "in the even team")

# 3. global memory: typed collective allocation --------------------------
# 8 float32 per member — shape/dtype bookkeeping lives on the array,
# not on the caller (the substrate's byte offsets never appear).
ga = ctx.alloc((8,), jnp.float32, team=team)
print(f"GlobalArray: shape={ga.shape} dtype={ga.dtype} units={ga.units}")

# 4. one-sided communication ---------------------------------------------
# blocking put to unit 6's block, then get it back
ga[6].put(jnp.arange(8, dtype=jnp.float32))
print("roundtrip:", np.asarray(ga[6].get()))

# non-blocking puts inside an epoch: the puts queue on the engine and
# the epoch close flushes them as ONE coalesced jitted dispatch
d0 = ctx.engine.dispatch_count
with ctx.epoch():
    handles = [ga.at[u, 4:8].put_nb(jnp.full((4,), float(u)))
               for u in ga.units]
print(f"coalesced {len(handles)} puts into "
      f"{ctx.engine.dispatch_count - d0} dispatch(es)")

# non-blocking gets: enqueue, then value() flushes — per target: each
# handle dispatches only its own unit's lane, leaving other targets'
# queued epochs untouched (MPI_Win_flush_local analogue)
gets = {u: ga.at[u, 4:8].get_nb() for u in ga.units}
assert all(float(np.asarray(h.value())[0]) == float(u)
           for u, h in gets.items())

# collective: allreduce the blocks the members just wrote
print("allreduce(sum):", np.asarray(ga.allreduce("sum")[4:8]))  # 0+2+4+6

# zero-copy local view: routed through the locality classifier — on
# host-visible arenas this is a numpy view with zero jitted dispatches
print("local view:", np.asarray(ga.local[4:8]))

# 5. synchronization: the MCS queueing lock (paper §IV.B.6) --------------
lock = ctx.locks.create_lock(ctx.teams[DART_TEAM_ALL])
counter = {"v": 0}

def worker(u):
    for _ in range(100):
        ctx.locks.acquire(lock, u)
        counter["v"] += 1
        ctx.locks.release(lock, u)

threads = [threading.Thread(target=worker, args=(u,)) for u in range(8)]
for t in threads: t.start()
for t in threads: t.join()
print("lock-protected counter:", counter["v"], "(expected 800)")

dart_barrier(ctx)
dart_exit(ctx)
print("done.")
